"""Batched-execution throughput: signals/sec per backend at B in {1, 8, 64}.

The tentpole claim of the batched (..., N) contract is that B signals ride
one Chebyshev sweep (the recurrence is linear, Section III-D), so
signals/sec should grow superlinearly in B until the matvec saturates.
This benchmark measures it: for every backend it times
``jax.jit(plan.apply)`` on a (B, N) stack and reports B / wall_time, then
writes one ``BENCH_throughput.json`` (repo root by default) recording the
whole sweep — the perf trajectory the CI throughput-smoke step and the
acceptance gate (pallas: B=64 at >= 4x the B=1 signals/sec) read.

    PYTHONPATH=src python -m benchmarks.bench_throughput \
        [--n 500] [--k 20] [--batches 1,8,64] [--json-path BENCH_throughput.json]
"""
import argparse
import os

import jax

from .common import row, time_fn

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_JSON = os.path.join(REPO_ROOT, "BENCH_throughput.json")
DEFAULT_BACKENDS = ("dense", "pallas", "halo", "pallas_halo", "allgather")
DEFAULT_BATCHES = (1, 8, 64)


def run(backends=None, batch_sizes=DEFAULT_BATCHES, n=500, K=20, J=2,
        json_path=DEFAULT_JSON, iters=10):
    """Sweep plan.apply throughput over batch sizes; returns the result dict
    (also written to `json_path` unless it is falsy)."""
    from repro.core import wavelets
    from repro.dist import GraphOperator

    from .common import seeded_sensor_graph

    backends = list(backends or DEFAULT_BACKENDS)
    # banded (sorted) order so the halo backends are exact
    gs, key = seeded_sensor_graph(n, sort=True)
    g = gs
    lmax = gs.lambda_max_bound()
    op = GraphOperator(P=gs.laplacian(),
                       multipliers=wavelets.sgwt_multipliers(lmax, J=J),
                       lmax=lmax, K=K)
    results = {}
    for backend in backends:
        plan = op.plan(backend)
        apply_jit = jax.jit(plan.apply)
        per_batch = {}
        for B in batch_sizes:
            f = jax.random.normal(jax.random.PRNGKey(B), (B, g.n_vertices))
            us = time_fn(apply_jit, f, iters=iters)
            sps = B / (us * 1e-6)
            per_batch[str(B)] = {"us_per_call": us, "signals_per_sec": sps}
            row(f"throughput_{backend}_B{B}", us,
                f"signals_per_sec={sps:.0f}")
        b0 = per_batch[str(batch_sizes[0])]["signals_per_sec"]
        bmax = per_batch[str(batch_sizes[-1])]["signals_per_sec"]
        per_batch["speedup_maxB_vs_1"] = bmax / b0 if b0 else float("nan")
        results[backend] = per_batch
    payload = {
        "bench": "throughput",
        "n": int(g.n_vertices),
        "K": int(op.K),
        "eta": int(op.eta),
        "batch_sizes": [int(b) for b in batch_sizes],
        "device_count": len(jax.devices()),
        "backend_default": jax.default_backend(),
        "results": results,
    }
    if json_path:
        import json

        parent = os.path.dirname(os.path.abspath(json_path))
        os.makedirs(parent, exist_ok=True)
        with open(json_path, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
        print(f"# wrote {json_path}", flush=True)
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=500)
    ap.add_argument("--k", type=int, default=20)
    ap.add_argument("--batches", default="1,8,64")
    ap.add_argument("--backends", default=",".join(DEFAULT_BACKENDS))
    ap.add_argument("--json-path", default=DEFAULT_JSON,
                    help="output JSON; '' disables writing")
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--check", action="store_true",
                    help="fail unless pallas B=max >= --check-min x B=1 "
                    "signals/sec")
    ap.add_argument("--check-min", type=float, default=4.0,
                    help="minimum pallas batched speedup for --check; CI "
                    "smoke uses a lower bar than the tracked trajectory "
                    "because few-iteration wall-clock ratios are noisy on "
                    "shared runners")
    args = ap.parse_args()
    batches = tuple(int(b) for b in args.batches.split(","))
    payload = run(backends=args.backends.split(","), batch_sizes=batches,
                  n=args.n, K=args.k, json_path=args.json_path,
                  iters=args.iters)
    if args.check:
        speedup = payload["results"]["pallas"]["speedup_maxB_vs_1"]
        assert speedup >= args.check_min, (
            f"pallas batched speedup {speedup:.2f}x < {args.check_min}x — "
            "batching is not amortizing the structure sweeps")
        print(f"# throughput gate OK: pallas {speedup:.2f}x at "
              f"B={batches[-1]}", flush=True)


if __name__ == "__main__":
    main()
