"""Continuous-batching serving under offered load: latency vs throughput.

The production-trajectory metric for the serving engine: replay a seeded
Poisson request stream (80% filter applies, 20% jacobi solves —
`repro.serve.loadgen.DEFAULT_MIX`) against a wall-clock
:class:`repro.serve.ServeEngine` at several offered loads and record what
arriving users would see — p50/p99 latency, achieved signals/sec, mean
batch occupancy and padding waste per (backend, rate).  Writes repo-root
``BENCH_serving.json``.

The arrival stream is deterministic per seed (the same events the
virtual-clock tests replay); only the measured durations are wall-clock.
Buckets/max-wait mirror the engine defaults: at low offered load the
occupancy is set by ``rate x max_wait`` (deadline flushing), at high load
by the bucket ceiling (batch-full flushing) — the crossover is the
continuous-batching win this file tracks.

    PYTHONPATH=src python -m benchmarks.bench_serving \
        [--backends dense,pallas] [--rates 200,1000,4000] [--requests 300]
        [--n 500] [--k 20] [--buckets 1,8,64] [--max-wait-ms 5] [--check]

``--check`` (CI smoke): every request answered exactly once, finite p99,
and mean batch occupancy >= --check-occupancy at the HIGHEST offered rate
(the engine must actually be coalescing, not trickling B=1 launches).
"""
import argparse
import json
import os
import time

import jax
import numpy as np

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_JSON = os.path.join(REPO_ROOT, "BENCH_serving.json")
DEFAULT_BACKENDS = ("dense", "pallas")
DEFAULT_RATES = (200.0, 1000.0, 4000.0)
DEFAULT_BUCKETS = (1, 8, 64)


def serve_stream(plan, events, n, buckets, max_wait):
    """Replay `events` against a wall-clock engine; returns the metrics
    summary.  The submit loop polls continuously between arrivals (the
    serving thread's job), so deadline flushes happen on time."""
    from repro.serve import ServeEngine, WallClock, signal_for

    eng = ServeEngine(plan, buckets=buckets, max_wait=max_wait,
                      clock=WallClock(), sync_results=True)
    eng.warm()
    # warm() covers the apply kinds; pre-compile the stream's solve
    # signatures too so measured latency is steady-state, not first-batch
    # trace time
    solve_specs = {(ev.method, ev.solve_kwargs) for ev in events
                   if ev.kind == "solve"}
    for method, kw in sorted(solve_specs):
        plan.bucketed_callables(buckets, kinds=(),
                                solve_specs=[(method, dict(kw))],
                                warm=True)
    signals = [signal_for(ev, n) for ev in events]
    start = eng.clock.now()
    for ev, sig in zip(events, signals):
        target = start + ev.t
        while eng.clock.now() < target:
            if not eng.poll():
                # nothing due: yield the tiniest OS slice rather than
                # hard-spinning the submit loop
                time.sleep(1e-5)
        eng.submit(sig, op=ev.op, kind=ev.kind, method=ev.method,
                   **ev.kwargs())
    while eng.pending_count:
        eng.poll()
        time.sleep(1e-5)
    summary = eng.metrics.summary()
    summary["per_key"] = eng.metrics.per_key_counts()
    return summary


def run(backends=DEFAULT_BACKENDS, rates=DEFAULT_RATES, n=500, K=20, J=2,
        n_requests=300, buckets=DEFAULT_BUCKETS, max_wait=0.005, seed=0,
        json_path=DEFAULT_JSON):
    from repro.core import wavelets
    from repro.dist import GraphOperator
    from repro.serve import poisson_arrivals

    from .common import row, seeded_sensor_graph

    gs, _ = seeded_sensor_graph(n, sort=True)  # banded: halo-safe too
    lmax = gs.lambda_max_bound()
    op = GraphOperator(P=gs.laplacian(),
                       multipliers=wavelets.sgwt_multipliers(lmax, J=J),
                       lmax=lmax, K=K)
    results = {}
    for backend in backends:
        plan = op.plan(backend)
        per_rate = {}
        for rate in rates:
            events = poisson_arrivals(rate=rate, n_requests=n_requests,
                                      seed=seed)
            s = serve_stream(plan, events, gs.n_vertices, buckets,
                             max_wait)
            per_rate[str(int(rate))] = s
            row(f"serving_{backend}_rate{int(rate)}",
                s["latency_ms"]["p99"] * 1e3 if s["latency_ms"]["p99"]
                else 0.0,
                f"p50={s['latency_ms']['p50']:.2f}ms "
                f"p99={s['latency_ms']['p99']:.2f}ms "
                f"sps={s['signals_per_sec']:.0f} "
                f"occ={s['mean_batch_occupancy']:.1f}")
        results[backend] = per_rate
    payload = {
        "bench": "serving",
        "n": int(gs.n_vertices),
        "K": int(op.K),
        "eta": int(op.eta),
        "n_requests": int(n_requests),
        "buckets": [int(b) for b in buckets],
        "max_wait_ms": max_wait * 1e3,
        "offered_rates": [float(r) for r in rates],
        "seed": int(seed),
        "device_count": len(jax.devices()),
        "backend_default": jax.default_backend(),
        "results": results,
    }
    if json_path:
        parent = os.path.dirname(os.path.abspath(json_path))
        os.makedirs(parent, exist_ok=True)
        with open(json_path, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
        print(f"# wrote {json_path}", flush=True)
    return payload


def check(payload, min_occupancy: float) -> None:
    """CI gates: exactly-once service, finite tail latency, and real
    coalescing at the highest offered rate."""
    gate = []
    for backend, per_rate in payload["results"].items():
        for rate, s in per_rate.items():
            assert s["served_exactly_once"], (
                f"{backend}@{rate}: {s['n_served']}/{s['n_submitted']} "
                "served — requests lost or duplicated")
            p99 = s["latency_ms"]["p99"]
            assert p99 is not None and np.isfinite(p99), (
                f"{backend}@{rate}: p99 latency is not finite: {p99}")
        top = str(int(max(float(r) for r in per_rate)))
        occ = per_rate[top]["mean_batch_occupancy"]
        assert occ >= min_occupancy, (
            f"{backend}@{top}: mean batch occupancy {occ:.2f} < "
            f"{min_occupancy} — the engine is not coalescing under load")
        gate.append(f"{backend} occ={occ:.1f}")
    print("# serving gate OK: " + ", ".join(gate), flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backends", default=",".join(DEFAULT_BACKENDS))
    ap.add_argument("--rates", default=",".join(
        str(int(r)) for r in DEFAULT_RATES),
        help="offered loads in requests/sec")
    ap.add_argument("--requests", type=int, default=300,
                    help="requests per (backend, rate) leg")
    ap.add_argument("--n", type=int, default=500)
    ap.add_argument("--k", type=int, default=20)
    ap.add_argument("--buckets", default="1,8,64")
    ap.add_argument("--max-wait-ms", type=float, default=5.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-path", default=DEFAULT_JSON,
                    help="output JSON; '' disables writing")
    ap.add_argument("--check", action="store_true",
                    help="gate: exactly-once, finite p99, coalescing")
    ap.add_argument("--check-occupancy", type=float, default=2.0,
                    help="min mean batch occupancy at the highest rate")
    args = ap.parse_args()
    payload = run(
        backends=args.backends.split(","),
        rates=tuple(float(r) for r in args.rates.split(",")),
        n=args.n, K=args.k, n_requests=args.requests,
        buckets=tuple(int(b) for b in args.buckets.split(",")),
        max_wait=args.max_wait_ms * 1e-3, seed=args.seed,
        json_path=args.json_path)
    if args.check:
        check(payload, args.check_occupancy)


if __name__ == "__main__":
    main()
