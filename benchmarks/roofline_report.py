"""Render the §Dry-run / §Roofline tables from results/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.roofline_report results/dryrun/baseline
"""
import glob
import json
import os
import sys


def load(dirpath):
    recs = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(f) as fh:
            data = json.load(fh)
            recs.extend(data if isinstance(data, list) else [data])
    return recs


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 1e9:.2f}G"


def table(recs):
    hdr = ("arch", "shape", "mesh", "status", "comp_s", "mem_s(raw)",
           "mem_s(struct)", "coll_s", "dominant", "frac", "useful",
           "hbm/dev", "fits")
    rows = [hdr]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    recs = sorted(recs, key=lambda r: (r.get("mesh", ""), r["arch"],
                                       order.get(r["shape"], 9)))
    for r in recs:
        if r["status"] != "ok":
            rows.append((r["arch"], r["shape"], r.get("mesh", "?"),
                         r["status"], "-", "-", "-", "-", "-", "-", "-", "-",
                         "-"))
            continue
        rf = r["roofline"]
        mem = r.get("memory", {})
        rows.append((
            r["arch"], r["shape"], r["mesh"], "ok",
            f"{rf['compute_s']:.4f}", f"{rf['memory_s']:.3f}",
            f"{rf.get('memory_struct_s') or 0:.3f}",
            f"{rf['collective_s']:.3f}", rf["dominant"],
            f"{rf['compute_fraction']:.3f}",
            f"{(r.get('useful_flops_ratio') or 0):.2f}",
            fmt_bytes(mem.get("total_hbm_bytes")),
            {True: "y", False: "N", None: "?"}[r.get("fits_hbm_16g")],
        ))
    widths = [max(len(str(row[i])) for row in rows) for i in range(len(hdr))]
    out = []
    for i, row in enumerate(rows):
        out.append(" | ".join(str(c).ljust(w) for c, w in zip(row, widths)))
        if i == 0:
            out.append("-+-".join("-" * w for w in widths))
    return "\n".join(out)


def main():
    dirpath = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun/baseline"
    recs = load(dirpath)
    print(table(recs))
    ok = [r for r in recs if r["status"] == "ok"]
    sk = [r for r in recs if r["status"] == "skipped"]
    er = [r for r in recs if r["status"] == "error"]
    print(f"\n{len(ok)} ok / {len(sk)} skipped / {len(er)} error "
          f"(of {len(recs)} cells)")
    for r in er:
        print(f"  ERROR {r['arch']} x {r['shape']}: {r.get('error', '')[:120]}")


if __name__ == "__main__":
    main()
