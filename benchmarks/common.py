"""Shared benchmark utilities."""
import time

import jax
import jax.numpy as jnp
import numpy as np


def time_fn(fn, *args, warmup=2, iters=10):
    """Median wall time per call in microseconds (jits on first call)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}", flush=True)


def write_json(json_dir, name, payload):
    """Write one benchmark result dict as <json_dir>/<name>.json."""
    import json
    import os

    os.makedirs(json_dir, exist_ok=True)
    path = os.path.join(json_dir, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True, default=str)
    print(f"# wrote {path}", flush=True)
    return path


def make_backend_plan(op, backend):
    """Plan `op` under `backend` (sharded backends default to a 1-D mesh
    over every visible device)."""
    return op.plan(backend)


def seeded_sensor_graph(n, seed=0, sort=False):
    """The benches' shared deterministic sensor network.

    PRNGKey(seed) with a connection radius ~ 1/sqrt(n) (the scaling the
    comm/scaling/throughput benches use) keeps the expected degree — and
    the chance of a connected draw — stable across sizes.  `sort=True`
    returns the spatially sorted (banded) graph the halo backends need.
    Returns (graph, key)."""
    from repro.core import graph

    radius = 0.075 * float(np.sqrt(500.0 / n))
    key = jax.random.PRNGKey(seed)
    g, key = graph.connected_sensor_graph(key, n=n, theta=radius,
                                          kappa=radius)
    if sort:
        g, _ = graph.spatial_sort(g)
    return g, key
