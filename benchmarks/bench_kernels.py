"""Kernel micro-benchmarks: jitted reference path wall-time on CPU (TPU
kernels are validated in interpret mode — timing them interpreted is
meaningless, so the CSV times the jnp oracle the kernels must beat and
reports roofline-model bytes/flops per call as `derived`)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chebyshev as cheb
from repro.core import filters, graph
from repro.dist import GraphOperator
from repro.kernels import ops, ref

from .common import make_backend_plan, row, time_fn, write_json


def sweep_backends(backends, json_dir="."):
    """Time plan.apply/apply_adjoint/apply_gram per backend through the one
    GraphOperator.plan() entry point; one comparable JSON per backend."""
    key = jax.random.PRNGKey(0)
    g, key = graph.connected_sensor_graph(key, n=500, theta=0.075,
                                          kappa=0.075)
    gs, _ = graph.spatial_sort(g)  # banded order so 'halo' is exact
    lmax = gs.lambda_max_bound()
    op = GraphOperator(P=gs.laplacian(),
                       multipliers=[filters.tikhonov(1.0), filters.heat(0.5)],
                       lmax=lmax, K=20)
    f = jax.random.normal(key, (g.n_vertices,))
    a = jax.random.normal(key, (op.eta, g.n_vertices))
    for backend in backends:
        plan = make_backend_plan(op, backend)
        results = {}
        for fn_name, fn, arg in (("apply", plan.apply, f),
                                 ("apply_adjoint", plan.apply_adjoint, a),
                                 ("apply_gram", plan.apply_gram, f)):
            us = time_fn(jax.jit(fn), arg)
            results[f"{fn_name}_us"] = us
            row(f"plan_{fn_name}_{backend}", us, f"n=500;K={op.K};eta={op.eta}")
        write_json(json_dir, f"bench_kernels_{backend}", {
            "bench": "kernels",
            "backend": backend,
            "n": g.n_vertices,
            "K": op.K,
            "eta": op.eta,
            "device_count": len(jax.devices()),
            "results": results,
            "plan_info": dict(plan.info),
        })


def run(backends=None, json_dir="."):
    if backends:
        sweep_backends(backends, json_dir)
    key = jax.random.PRNGKey(0)
    g, key = graph.connected_sensor_graph(key, n=500)
    L = np.asarray(g.laplacian())
    A = graph.to_block_ell(L, (8, 128))
    x = jax.random.normal(key, (A.padded_n,))

    spmv = jax.jit(lambda v: ref.block_ell_spmv_ref(A.blocks, A.indices, v))
    us = time_fn(spmv, x)
    nnz_blocks = int(np.asarray(A.mask).sum())
    row("spmv_blockell_n500", us,
        f"slots={A.blocks.shape[1]};nnz_blocks={nnz_blocks};"
        f"flops={nnz_blocks * 2 * 8 * 128}")

    lmax = g.lambda_max_bound()
    coeffs = cheb.cheb_coeffs_stack(
        [filters.tikhonov(1.0), filters.heat(0.5)], 20, lmax)
    fused = jax.jit(lambda v: ops.fused_cheb_apply(A, v, coeffs, lmax,
                                                   use_pallas=False))
    us = time_fn(fused, x)
    row("fused_cheb_apply_K20", us, f"eta=2;matvecs=20")

    B, Hq, Hkv, S, D = 1, 8, 2, 1024, 64
    q = jax.random.normal(key, (B, Hq, S, D))
    k = jax.random.normal(key, (B, Hkv, S, D))
    v = jax.random.normal(key, (B, Hkv, S, D))
    att = jax.jit(lambda a, b, c: ref.attention_ref(a, b, c, causal=True))
    us = time_fn(att, q, k, v)
    row("attention_ref_1k", us, f"flops~{4 * B * Hq * S * S * D}")

    from repro.models.layers import attention_chunked
    attc = jax.jit(lambda a, b, c: attention_chunked(a, b, c, causal=True,
                                                     chunk=256))
    us = time_fn(attc, q, k, v)
    row("attention_chunked_1k", us, "chunk=256")

    eta, n = 7, 1 << 16
    a = jax.random.normal(key, (eta, n))
    th = jnp.full((eta, 1), 0.2)
    shr = jax.jit(lambda z: ref.ista_shrink_ref(z, z * 0.5, z * 0.1, th,
                                                gamma=0.2))
    us = time_fn(shr, a)
    row("ista_shrink_64k", us, f"eta={eta}")


if __name__ == "__main__":
    run()
