"""Kernel micro-benchmarks: jitted reference path wall-time on CPU (TPU
kernels are validated in interpret mode — timing them interpreted is
meaningless, so the CSV times the jnp oracle the kernels must beat and
reports roofline-model bytes/flops per call as `derived`).

`sweep_vs_step` is the single-launch-sweep acceptance microbenchmark: it
times the whole K-order Chebyshev application through the per-order path
(`ops.fused_cheb_apply(..., sweep=False)`: one SpMV + one cheb_step per
order) against the sweep path (`ops.fused_cheb_sweep`: the recurrence as
one fused trace / one kernel launch) over K in {5, 20, 50}, eta in {1, 3}
and B in {1, 64}, and writes the repo-root ``BENCH_kernels.json`` whose
top-level ``speedup_sweep_vs_step`` (geometric mean over configs) the CI
smoke step gates at >= 1.0 via ``--check``.  Each config also records the
mixed-precision sweep's VMEM footprint model (``vmem_bytes_f32`` /
``vmem_bytes_bf16``): bf16 blocks + iterate scratch roughly halve the
footprint, and ``--check`` additionally gates the config-geomean
``vmem_bf16_capacity_ratio`` at >= 1.8x (wall-time for the bf16 kernel is
a TPU effect; the capacity ratio is what decides which problems fit under
the sweep guard — ~2x where structure/iterates dominate, less at eta > 1
with large B where the deliberately-f32 accumulator is the biggest tile).

    PYTHONPATH=src python -m benchmarks.bench_kernels \
        [--n 500] [--ks 5,20,50] [--etas 1,3] [--batches 1,64] \
        [--json-path BENCH_kernels.json] [--check] [--check-min 1.0]
"""
import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chebyshev as cheb
from repro.core import filters, graph
from repro.dist import GraphOperator
from repro.kernels import ops, ref

from .common import make_backend_plan, row, time_fn, write_json

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_JSON = os.path.join(REPO_ROOT, "BENCH_kernels.json")
DEFAULT_KS = (5, 20, 50)
DEFAULT_ETAS = (1, 3)
DEFAULT_BATCHES = (1, 64)


def sweep_vs_step(n=500, Ks=DEFAULT_KS, etas=DEFAULT_ETAS,
                  batches=DEFAULT_BATCHES, iters=10, json_path=DEFAULT_JSON):
    """Time the single-launch sweep against the per-order path.

    Both arms run the jnp reference dispatch (`use_pallas=False`: the
    interpret/ref CI path — interpret-mode kernel timings are
    meaningless); the sweep arm is the same recurrence as ONE unrolled
    fused trace, which is exactly what the sweep kernel does on TPU minus
    the launch/HBM effects the CPU cannot model.  Writes `json_path` with
    per-config us/call and a top-level geomean ``speedup_sweep_vs_step``;
    returns the payload.
    """
    from .common import seeded_sensor_graph

    import time

    def time_pair(fa, fb, x, iters):
        """Interleaved min-of-N timing (us) for two arms of a comparison.

        Alternating the arms cancels machine-load drift between them, and
        the minimum is the robust per-call estimator under interference
        (any slowdown is additive noise); medians of separated runs flap
        on shared runners.
        """
        for _ in range(2):
            jax.block_until_ready(fa(x))
            jax.block_until_ready(fb(x))
        best_a = best_b = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fa(x))
            t1 = time.perf_counter()
            jax.block_until_ready(fb(x))
            t2 = time.perf_counter()
            best_a = min(best_a, t1 - t0)
            best_b = min(best_b, t2 - t1)
        return best_a * 1e6, best_b * 1e6

    gs, key = seeded_sensor_graph(n, sort=True)
    L = np.asarray(gs.laplacian())
    A = graph.to_block_ell(L, (8, 128))
    lmax = gs.lambda_max_bound()
    configs = {}
    speedups = []
    for K in Ks:
        for eta in etas:
            coeffs = cheb.cheb_coeffs_stack(
                [filters.tikhonov(1.0 + j) for j in range(eta)], K,
                lmax).astype(np.float32)
            per_order = jax.jit(lambda v, c=coeffs, K=K: ops.fused_cheb_apply(
                A, v, c, lmax, use_pallas=False, sweep=False))
            sweep = jax.jit(lambda v, c=coeffs, K=K: ops.fused_cheb_apply(
                A, v, c, lmax, use_pallas=False))
            for B in batches:
                x = jax.random.normal(jax.random.PRNGKey(B), (B, A.padded_n))
                us_step, us_sweep = time_pair(per_order, sweep, x, iters)
                ratio = us_step / us_sweep
                speedups.append(ratio)
                # mixed-precision capacity: the bf16-scratch kernel's VMEM
                # footprint model vs f32 (wall-time is a TPU effect the CPU
                # cannot measure; the footprint ratio is what decides which
                # problems fit under the sweep guard at all)
                v32 = ops.cheb_sweep_vmem_bytes(A, A.padded_n, eta, K, B)
                v16 = ops.cheb_sweep_vmem_bytes(A, A.padded_n, eta, K, B,
                                                scratch_dtype="bf16")
                configs[f"K{K}_eta{eta}_B{B}"] = {
                    "per_order_us": us_step,
                    "sweep_us": us_sweep,
                    "speedup": ratio,
                    "vmem_bytes_f32": v32,
                    "vmem_bytes_bf16": v16,
                    "vmem_capacity_ratio": v32 / v16,
                }
                row(f"cheb_sweep_K{K}_eta{eta}_B{B}", us_sweep,
                    f"per_order_us={us_step:.1f};speedup={ratio:.2f};"
                    f"vmem_bf16_ratio={v32 / v16:.2f}")
    geomean = float(np.exp(np.mean(np.log(speedups))))
    vmem_ratios = [c["vmem_capacity_ratio"] for c in configs.values()]
    payload = {
        "bench": "kernels_sweep",
        "n": int(gs.n_vertices),
        "padded_n": int(A.padded_n),
        "path": "ref",
        "configs": configs,
        "speedup_sweep_vs_step": geomean,
        # geomean over configs: ~2x where structure/iterates dominate,
        # less at eta > 1 + large B where the deliberately-f32 accumulator
        # (eta*B*n*4, numerical-safety floor) is the biggest tile
        "vmem_bf16_capacity_ratio": float(
            np.exp(np.mean(np.log(vmem_ratios)))),
    }
    if json_path:
        import json

        parent = os.path.dirname(os.path.abspath(json_path))
        os.makedirs(parent, exist_ok=True)
        with open(json_path, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
        print(f"# wrote {json_path}", flush=True)
    return payload


def sweep_backends(backends, json_dir="."):
    """Time plan.apply/apply_adjoint/apply_gram per backend through the one
    GraphOperator.plan() entry point; one comparable JSON per backend."""
    key = jax.random.PRNGKey(0)
    g, key = graph.connected_sensor_graph(key, n=500, theta=0.075,
                                          kappa=0.075)
    gs, _ = graph.spatial_sort(g)  # banded order so 'halo' is exact
    lmax = gs.lambda_max_bound()
    op = GraphOperator(P=gs.laplacian(),
                       multipliers=[filters.tikhonov(1.0), filters.heat(0.5)],
                       lmax=lmax, K=20)
    f = jax.random.normal(key, (g.n_vertices,))
    a = jax.random.normal(key, (op.eta, g.n_vertices))
    for backend in backends:
        plan = make_backend_plan(op, backend)
        results = {}
        for fn_name, fn, arg in (("apply", plan.apply, f),
                                 ("apply_adjoint", plan.apply_adjoint, a),
                                 ("apply_gram", plan.apply_gram, f)):
            us = time_fn(jax.jit(fn), arg)
            results[f"{fn_name}_us"] = us
            row(f"plan_{fn_name}_{backend}", us, f"n=500;K={op.K};eta={op.eta}")
        write_json(json_dir, f"bench_kernels_{backend}", {
            "bench": "kernels",
            "backend": backend,
            "n": g.n_vertices,
            "K": op.K,
            "eta": op.eta,
            "device_count": len(jax.devices()),
            "results": results,
            "plan_info": dict(plan.info),
        })


def run(backends=None, json_dir="."):
    if backends:
        sweep_backends(backends, json_dir)
    key = jax.random.PRNGKey(0)
    g, key = graph.connected_sensor_graph(key, n=500)
    L = np.asarray(g.laplacian())
    A = graph.to_block_ell(L, (8, 128))
    x = jax.random.normal(key, (A.padded_n,))

    spmv = jax.jit(lambda v: ref.block_ell_spmv_ref(A.blocks, A.indices, v))
    us = time_fn(spmv, x)
    nnz_blocks = int(np.asarray(A.mask).sum())
    row("spmv_blockell_n500", us,
        f"slots={A.blocks.shape[1]};nnz_blocks={nnz_blocks};"
        f"flops={nnz_blocks * 2 * 8 * 128}")

    lmax = g.lambda_max_bound()
    coeffs = cheb.cheb_coeffs_stack(
        [filters.tikhonov(1.0), filters.heat(0.5)], 20, lmax)
    fused = jax.jit(lambda v: ops.fused_cheb_apply(A, v, coeffs, lmax,
                                                   use_pallas=False))
    us = time_fn(fused, x)
    row("fused_cheb_apply_K20", us, f"eta=2;matvecs=20")

    B, Hq, Hkv, S, D = 1, 8, 2, 1024, 64
    q = jax.random.normal(key, (B, Hq, S, D))
    k = jax.random.normal(key, (B, Hkv, S, D))
    v = jax.random.normal(key, (B, Hkv, S, D))
    att = jax.jit(lambda a, b, c: ref.attention_ref(a, b, c, causal=True))
    us = time_fn(att, q, k, v)
    row("attention_ref_1k", us, f"flops~{4 * B * Hq * S * S * D}")

    from repro.models.layers import attention_chunked
    attc = jax.jit(lambda a, b, c: attention_chunked(a, b, c, causal=True,
                                                     chunk=256))
    us = time_fn(attc, q, k, v)
    row("attention_chunked_1k", us, "chunk=256")

    eta, n = 7, 1 << 16
    a = jax.random.normal(key, (eta, n))
    th = jnp.full((eta, 1), 0.2)
    shr = jax.jit(lambda z: ref.ista_shrink_ref(z, z * 0.5, z * 0.1, th,
                                                gamma=0.2))
    us = time_fn(shr, a)
    row("ista_shrink_64k", us, f"eta={eta}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=500)
    ap.add_argument("--ks", default="5,20,50")
    ap.add_argument("--etas", default="1,3")
    ap.add_argument("--batches", default="1,64")
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--json-path", default=DEFAULT_JSON,
                    help="output JSON; '' disables writing")
    ap.add_argument("--full", action="store_true",
                    help="also run the legacy kernel CSV sweep")
    ap.add_argument("--check", action="store_true",
                    help="fail unless the sweep path's geomean speedup over "
                    "the per-order path is >= --check-min")
    ap.add_argument("--check-min", type=float, default=1.0,
                    help="minimum speedup_sweep_vs_step for --check (the "
                    "sweep must at least not regress the per-order path)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.full:
        run()
    payload = sweep_vs_step(
        n=args.n,
        Ks=tuple(int(k) for k in args.ks.split(",")),
        etas=tuple(int(e) for e in args.etas.split(",")),
        batches=tuple(int(b) for b in args.batches.split(",")),
        iters=args.iters, json_path=args.json_path)
    if args.check:
        speedup = payload["speedup_sweep_vs_step"]
        assert speedup >= args.check_min, (
            f"sweep geomean speedup {speedup:.3f}x < {args.check_min}x — "
            "the single-launch sweep regresses the per-order path")
        vr = payload["vmem_bf16_capacity_ratio"]
        assert vr >= 1.8, (
            f"bf16-scratch VMEM capacity ratio {vr:.3f}x < 1.8x — the "
            "mixed-precision sweep no longer roughly doubles the ceiling")
        print(f"# sweep gate OK: {speedup:.2f}x vs per-order, "
              f"bf16 VMEM capacity {vr:.2f}x", flush=True)


if __name__ == "__main__":
    main()
