"""Paper Figure 1 (Section IV-D): distributed Tikhonov denoising.

Reports (a) Chebyshev approximation error B(K) of g(lambda)=1/(1+2 lambda)
for several orders (Fig. 1d), (b) the operator-norm error ||R - R~|| (Fig.
1e), and (c) the denoising experiment: average MSE of noisy vs denoised
signals over randomized trials (paper, 1000 trials: 0.250 -> 0.013).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SENSOR500
from repro.core import chebyshev as cheb
from repro.core import filters, graph
from repro.core.multiplier import graph_multiplier
from repro.data.pipeline import graph_signal_batch

from .common import row, time_fn


def run(n_trials: int = 20, n: int = None):
    p = SENSOR500
    n = n or p.n_vertices
    gfilt = filters.tikhonov(p.tau, p.r)

    # (a) scalar approximation error vs K (Fig. 1d)
    key = jax.random.PRNGKey(0)
    g0, key = graph.connected_sensor_graph(key, n=n, theta=p.theta,
                                           kappa=p.kappa)
    lmax = g0.lambda_max_bound()
    for K in (5, 10, 15, 20, 25):
        c = cheb.cheb_coeffs(gfilt, K, lmax)
        B = cheb.approx_error_bound([gfilt], c[None], lmax)
        row(f"fig1d_BK_K{K}", 0.0, f"B(K)={B:.3e}")

    # (b) operator error on one realization (Fig. 1e)
    op = graph_multiplier(g0.laplacian(), gfilt, lmax, K=p.K)
    lam, U = np.linalg.eigh(np.asarray(g0.laplacian()))
    R = U @ np.diag(gfilt(lam)) @ U.T
    probe = np.asarray(jax.random.normal(key, (n, 8)))
    # (..., N) contract: the 8 probe columns ride one sweep as a batch
    approx = np.asarray(op.apply(jnp.asarray(probe.T))).T
    opnorm_est = np.linalg.norm(R @ probe - approx, 2) / np.linalg.norm(probe, 2)
    row("fig1e_opnorm_err", 0.0, f"||R-R~||~={opnorm_est:.3e}")

    # (c) denoising MSE over trials
    mses_noisy, mses_den = [], []
    key = jax.random.PRNGKey(1)
    for _ in range(n_trials):
        g, key = graph.connected_sensor_graph(key, n=n, theta=p.theta,
                                              kappa=p.kappa)
        f0 = graph_signal_batch(key, g.coords, "smooth")
        key, sub = jax.random.split(key)
        y = f0 + p.noise_sigma * jax.random.normal(sub, f0.shape)
        lmax = g.lambda_max_bound()
        opk = graph_multiplier(g.laplacian(), gfilt, lmax, K=p.K)
        den = opk.apply(y)
        mses_noisy.append(float(jnp.mean((y - f0) ** 2)))
        mses_den.append(float(jnp.mean((den - f0) ** 2)))
    us = time_fn(jax.jit(lambda v: op.apply(v)), jnp.asarray(probe[:, 0]))
    row("fig1_denoise_apply", us,
        f"mse_noisy={np.mean(mses_noisy):.3f};mse_denoised="
        f"{np.mean(mses_den):.3f};paper=0.250->0.013;trials={n_trials}")


if __name__ == "__main__":
    run()
