"""Paper Section VI table: distributed wavelet-lasso denoising MSEs.

Paper (1000 connected trials, N=500, J=6, K=15, 300 ISTA iterations):
    noisy 0.250 | Tikhonov 0.098 | exact-operator lasso 0.088 |
    Chebyshev-approximate lasso 0.079.
Defaults here run fewer trials/iterations for CPU wall-time; flags scale up.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SENSOR500
from repro.core import filters, graph, lasso, wavelets
from repro.core.multiplier import UnionMultiplier, graph_multiplier
from repro.data.pipeline import graph_signal_batch

from .common import row


class _ExactUnion:
    """Eigendecomposition-backed exact operator (paper's 'exact lasso')."""

    def __init__(self, op: UnionMultiplier):
        self.op = op
        lam, U = np.linalg.eigh(np.asarray(op.P))
        self.mats = [
            jnp.asarray(U @ np.diag(np.asarray(g(lam))) @ U.T)
            for g in op.multipliers
        ]
        self.eta = op.eta

    def apply(self, f):
        return jnp.stack([M @ f for M in self.mats])

    def apply_adjoint(self, a):
        return sum(M @ a[j] for j, M in enumerate(self.mats))


def run(n_trials: int = 5, n_iters: int = 150, n: int = None):
    p = SENSOR500
    n = n or p.n_vertices
    key = jax.random.PRNGKey(3)
    res = {"noisy": [], "tikhonov": [], "lasso_exact": [], "lasso_cheb": []}
    mu = jnp.array([p.lasso_mu_scaling] + [p.lasso_mu_wavelet] * p.n_wavelet_scales)
    for _ in range(n_trials):
        g, key = graph.connected_sensor_graph(key, n=n, theta=p.theta,
                                              kappa=p.kappa)
        f0 = graph_signal_batch(key, g.coords, "piecewise")
        key, sub = jax.random.split(key)
        y = f0 + p.noise_sigma * jax.random.normal(sub, f0.shape)
        lmax = g.lambda_max_bound()

        tik = graph_multiplier(g.laplacian(), filters.tikhonov(p.tau, p.r),
                               lmax, K=p.K).apply(y)
        op = UnionMultiplier(
            P=g.laplacian(),
            multipliers=wavelets.sgwt_multipliers(lmax, J=p.n_wavelet_scales),
            lmax=lmax, K=p.lasso_K,
        )
        lo = lasso.distributed_lasso(op, y, mu=mu, gamma=p.lasso_gamma,
                                     n_iters=n_iters)
        ex = _ExactUnion(op)
        lo_ex = lasso.distributed_lasso(ex, y, mu=mu, gamma=p.lasso_gamma,
                                        n_iters=n_iters)
        res["noisy"].append(float(jnp.mean((y - f0) ** 2)))
        res["tikhonov"].append(float(jnp.mean((tik - f0) ** 2)))
        res["lasso_cheb"].append(float(jnp.mean((lo.signal - f0) ** 2)))
        res["lasso_exact"].append(float(jnp.mean((lo_ex.signal - f0) ** 2)))
    means = {k: np.mean(v) for k, v in res.items()}
    row("lasso_table", 0.0,
        f"noisy={means['noisy']:.3f};tikhonov={means['tikhonov']:.3f};"
        f"lasso_exact={means['lasso_exact']:.3f};"
        f"lasso_cheb={means['lasso_cheb']:.3f};"
        f"paper=0.250/0.098/0.088/0.079;trials={n_trials};iters={n_iters}")


if __name__ == "__main__":
    run()
