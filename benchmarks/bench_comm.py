"""Communication-scaling table (Sections IV-B, IV-C, VI).

The paper's central systems claim: distributed application costs 2K|E|
messages of length 1 (Phi~ f), 2K|E| of length eta (Phi~* a), 4K|E| of
length 1 (Phi~*Phi~ f), and one lasso ISTA iteration costs 2K|E| x (J+1)
+ 2K|E| — scaling with |E| only, independent of N otherwise. Verified by
counting on random graphs of increasing size, plus the ADMM distributed-
lasso alternative's 2|E| x N(J+1) per iteration for contrast (Section VI).
Also reports the TPU halo-byte analog of the sharded path.

`dtype_sweep` is the compressed-exchange acceptance benchmark: it runs the
sharded backends at every ``exchange_dtype`` on a bandwidth-24 banded
Laplacian (the int8 wire row is ``h + 4`` bytes, so the <= 0.3x ratio only
means anything at realistic halo widths), records measured bytes-per-round
ratios and accuracy vs the dense reference, and writes the repo-root
``BENCH_comm.json``.  ``--check`` gates: rounds stay exactly K for every
dtype (compression must ride the SAME two ppermutes per order), bf16
<= 0.5x and int8 <= 0.3x f32 bytes, and the accuracy ladder
f32 < 1e-5 / bf16 < 5e-3 / int8 <= 10x bf16.

    PYTHONPATH=src python -m benchmarks.bench_comm \
        [--n 512] [--bw 24] [--k 20] [--shards 8] \
        [--backends halo,pallas_halo] [--json-path BENCH_comm.json] \
        [--check] [--legacy]
"""
import argparse
import os
import subprocess
import sys

import jax
import numpy as np

from repro.core.wavelets import sgwt_multipliers
from repro.dist import GraphOperator
from repro.dist.backends import halo as dist

from .common import make_backend_plan, row, seeded_sensor_graph, write_json

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_JSON = os.path.join(REPO_ROOT, "BENCH_comm.json")
DEFAULT_DTYPES = ("f32", "bf16", "int8")
DEFAULT_DTYPE_BACKENDS = ("halo", "pallas_halo")
DEFAULT_SHARDS = 8


def sweep_backends(backends, json_dir=".", K=20, J=6):
    """Per-backend communication model through the plan API: the paper's
    scalar-message accounting plus each backend's collective-byte model."""
    gs, _ = seeded_sensor_graph(600, sort=True)
    g = gs
    lmax = gs.lambda_max_bound()
    op = GraphOperator(P=gs.laplacian(),
                       multipliers=sgwt_multipliers(lmax, J),
                       lmax=lmax, K=K)
    mc = op.message_counts(g.n_edges)
    for backend in backends:
        plan = make_backend_plan(op, backend)
        bytes_model = {k: v for k, v in plan.info.items()
                       if "bytes" in k or k in ("n_shards", "mesh_axis")}
        # measured collective counts (vacuous on a 1-shard mesh, where the
        # sharded backends skip their ppermutes — see bench_scaling for the
        # forced-multi-device measurement)
        from repro.dist import plan_comm_stats

        measured = {k: s.summary() for k, s in plan_comm_stats(plan).items()}
        row(f"comm_plan_{backend}", 0.0,
            f"E={g.n_edges};apply_msgs={mc['apply_messages']};"
            + ";".join(f"{k}={v}" for k, v in bytes_model.items()))
        write_json(json_dir, f"bench_comm_{backend}", {
            "bench": "comm",
            "backend": backend,
            "n": g.n_vertices,
            "E": g.n_edges,
            "K": K,
            "eta": op.eta,
            "device_count": len(jax.devices()),
            "paper_message_counts": mc,
            "plan_info": dict(plan.info),
            "measured_commstats": measured,
        })


def run(backends=None, json_dir="."):
    if backends:
        sweep_backends(backends, json_dir)
    K, J = 20, 6
    for n in (125, 250, 500, 1000):
        g, _ = seeded_sensor_graph(n)
        E = g.n_edges
        lmax = g.lambda_max_bound()
        op = GraphOperator(P=g.laplacian(),
                             multipliers=sgwt_multipliers(lmax, J),
                             lmax=lmax, K=K)
        mc = op.message_counts(E)
        ista_scalars = (mc["gram_messages"] * 1
                        + mc["adjoint_messages"] * (J + 1))
        admm_scalars = 2 * E * n * (J + 1)  # ADMM lasso [29,30] per iteration
        row(f"comm_N{n}", 0.0,
            f"E={E};apply={mc['apply_messages']};gram={mc['gram_messages']};"
            f"ista_scalars={ista_scalars};admm_scalars={admm_scalars};"
            f"ratio={admm_scalars / max(ista_scalars, 1):.1f}x")

    # sharded halo-byte analog (DESIGN.md §3)
    gs, _ = seeded_sensor_graph(600, sort=True)
    parts, leak = dist.partition_banded(np.asarray(gs.laplacian()), 8)
    row("comm_halo_8shards", 0.0,
        f"leak={leak};bytes_per_apply={dist.halo_bytes_per_apply(parts, K)};"
        f"bytes_per_ista_iter={dist.halo_bytes_per_apply(parts, K, eta=J + 1) + dist.halo_bytes_per_apply(parts, K)}")

    # Chebyshev gossip vs fabric all-reduce traffic model (DESIGN.md §4.1):
    # exact ring consensus needs K = ceil(n/2) rounds x 2 neighbour sends of
    # the gradient (G bytes fp32); ring all-reduce moves ~2G. int8 messages
    # (ref [31] extension) close most of the gap while tolerating link loss.
    from repro.dist import gossip

    for n_dev in (8, 16):
        Kg = len(gossip.consensus_coeffs(n_dev)) - 1
        err = gossip.consensus_error(n_dev, gossip.consensus_coeffs(n_dev))
        fp32 = 2 * Kg            # sends per device, units of G bytes
        int8 = 2 * Kg / 4.0
        row(f"comm_gossip_ring{n_dev}", 0.0,
            f"rounds={Kg};consensus_err={err:.1e};"
            f"gossip_fp32={fp32:.0f}G;gossip_int8={int8:.0f}G;allreduce=2G;"
            f"note=int8 gossip ~ all-reduce parity + straggler tolerance")


def _banded_operator(n, bw, K, seed=0):
    """Banded Laplacian operator + test signal: halo width == bw on both
    sharded backends, wide enough that the int8 scale row is amortized."""
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    B = np.zeros((n, n), dtype=np.float32)
    for i in range(n):
        lo, hi = max(0, i - bw), min(n, i + bw + 1)
        B[i, lo:hi] = rng.standard_normal(hi - lo) * 0.1
    B = np.abs(B + B.T) / 2
    L = np.diag(B.sum(1)) - B
    lmax = float(2 * B.sum(1).max())
    op = GraphOperator(P=jnp.asarray(L),
                       multipliers=[lambda lam: jnp.exp(-lam)],
                       lmax=lmax, K=K)
    x = jnp.asarray(rng.standard_normal((4, n)).astype(np.float32))
    return op, x


def _dtype_measure(n, bw, K, n_shards, backends, dtypes, json_path, check):
    import json

    import jax.numpy as jnp

    from repro.dist import plan_comm_stats

    op, x = _banded_operator(n, bw, K)
    mesh = jax.make_mesh((n_shards,), ("graph",))
    ref = op.plan("dense").apply(x)
    refmax = float(jnp.abs(ref).max())
    table = {}
    for backend in backends:
        table[backend] = {}
        base_bpr = None
        for dt in dtypes:
            plan = op.plan(backend, mesh=mesh, exchange_dtype=dt)
            st = plan_comm_stats(plan)["apply"]
            if base_bpr is None:      # dtypes start with f32
                base_bpr = st.bytes_per_round
            ratio = st.bytes_per_round / base_bpr
            rel = float(jnp.abs(plan.apply(x) - ref).max()) / refmax
            table[backend][dt] = {
                "exchange_rounds": int(st.exchange_rounds),
                "bytes_per_round": float(st.bytes_per_round),
                "bytes_per_apply": float(st.total_bytes),
                "bytes_ratio_vs_f32": float(ratio),
                "rel_err_vs_dense": rel,
            }
            row(f"comm_dtype_{backend}_{dt}", 0.0,
                f"rounds={st.exchange_rounds};"
                f"bytes_per_round={st.bytes_per_round:.0f};"
                f"ratio_vs_f32={ratio:.3f};rel_err={rel:.2e}")
    payload = {
        "bench": "comm_dtype",
        "n": n, "halo_width": bw, "K": K, "n_shards": n_shards,
        "backends": list(backends),
        "dtypes": list(dtypes),
        "table": table,
    }
    if json_path:
        parent = os.path.dirname(os.path.abspath(json_path))
        os.makedirs(parent, exist_ok=True)
        with open(json_path, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
        print(f"# wrote {json_path}", flush=True)
    if check:
        assert bw >= 20, "int8 <= 0.3x gate needs halo width >= 20"
        for backend, per_dt in table.items():
            errs = {dt: e["rel_err_vs_dense"] for dt, e in per_dt.items()}
            for dt, e in per_dt.items():
                assert e["exchange_rounds"] == K, (
                    f"{backend}/{dt}: {e['exchange_rounds']} rounds != K={K}"
                    " — compression must not add exchange rounds")
            assert per_dt["f32"]["bytes_ratio_vs_f32"] == 1.0
            assert per_dt["bf16"]["bytes_ratio_vs_f32"] <= 0.5, (backend,
                                                                 per_dt)
            assert per_dt["int8"]["bytes_ratio_vs_f32"] <= 0.3, (backend,
                                                                 per_dt)
            assert errs["f32"] < 1e-5, (backend, errs)
            assert errs["bf16"] < 5e-3, (backend, errs)
            assert errs["int8"] <= 10 * errs["bf16"], (backend, errs)
        print("# comm dtype gates OK: bytes bf16<=0.5x int8<=0.3x, "
              "rounds==K, accuracy ladder holds", flush=True)
    return payload


def dtype_sweep(n=512, bw=24, K=20, n_shards=DEFAULT_SHARDS, backends=None,
                dtypes=DEFAULT_DTYPES, json_path=DEFAULT_JSON, check=False):
    """Entry point used by `benchmarks.run`.

    Spawns a forced-host-device subprocess when this process cannot build
    an `n_shards`-wide mesh (1-shard plans skip their ppermutes, so the
    byte measurement would be vacuous) — same idiom as bench_scaling.
    """
    backends = tuple(backends or DEFAULT_DTYPE_BACKENDS)
    if len(jax.devices()) >= n_shards:
        return _dtype_measure(n, bw, K, n_shards, backends, dtypes,
                              json_path, check)

    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_shards} "
        + env.get("XLA_FLAGS", ""))
    src = os.path.join(REPO_ROOT, "src")
    env["PYTHONPATH"] = (src + os.pathsep + REPO_ROOT + os.pathsep
                         + env.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", "benchmarks.bench_comm",
           "--n", str(n), "--bw", str(bw), "--k", str(K),
           "--shards", str(n_shards), "--backends", ",".join(backends),
           "--json-path", json_path or ""]
    if check:
        cmd.append("--check")
    proc = subprocess.run(cmd, env=env, cwd=REPO_ROOT)
    if proc.returncode != 0:
        raise RuntimeError(
            f"bench_comm dtype subprocess failed (rc={proc.returncode})")
    return None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=512)
    ap.add_argument("--bw", type=int, default=24,
                    help="Laplacian coupling bandwidth == halo width")
    ap.add_argument("--k", type=int, default=20)
    ap.add_argument("--shards", type=int, default=DEFAULT_SHARDS)
    ap.add_argument("--backends", default=",".join(DEFAULT_DTYPE_BACKENDS))
    ap.add_argument("--json-path", default=DEFAULT_JSON,
                    help="output JSON; '' disables writing")
    ap.add_argument("--check", action="store_true",
                    help="fail unless the byte ratios, round counts and "
                    "accuracy ladder hold (see module docstring)")
    ap.add_argument("--legacy", action="store_true",
                    help="also print the paper's scalar-message CSV table")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.legacy:
        run()
    backends = tuple(args.backends.split(","))
    if len(jax.devices()) >= args.shards:
        _dtype_measure(args.n, args.bw, args.k, args.shards, backends,
                       DEFAULT_DTYPES, args.json_path, args.check)
    else:
        dtype_sweep(args.n, args.bw, args.k, args.shards, backends,
                    DEFAULT_DTYPES, args.json_path, args.check)


if __name__ == "__main__":
    main()
