"""Communication-scaling table (Sections IV-B, IV-C, VI).

The paper's central systems claim: distributed application costs 2K|E|
messages of length 1 (Phi~ f), 2K|E| of length eta (Phi~* a), 4K|E| of
length 1 (Phi~*Phi~ f), and one lasso ISTA iteration costs 2K|E| x (J+1)
+ 2K|E| — scaling with |E| only, independent of N otherwise. Verified by
counting on random graphs of increasing size, plus the ADMM distributed-
lasso alternative's 2|E| x N(J+1) per iteration for contrast (Section VI).
Also reports the TPU halo-byte analog of the sharded path."""
import jax
import numpy as np

from repro.core.wavelets import sgwt_multipliers
from repro.dist import GraphOperator
from repro.dist.backends import halo as dist

from .common import make_backend_plan, row, seeded_sensor_graph, write_json


def sweep_backends(backends, json_dir=".", K=20, J=6):
    """Per-backend communication model through the plan API: the paper's
    scalar-message accounting plus each backend's collective-byte model."""
    gs, _ = seeded_sensor_graph(600, sort=True)
    g = gs
    lmax = gs.lambda_max_bound()
    op = GraphOperator(P=gs.laplacian(),
                       multipliers=sgwt_multipliers(lmax, J),
                       lmax=lmax, K=K)
    mc = op.message_counts(g.n_edges)
    for backend in backends:
        plan = make_backend_plan(op, backend)
        bytes_model = {k: v for k, v in plan.info.items()
                       if "bytes" in k or k in ("n_shards", "mesh_axis")}
        # measured collective counts (vacuous on a 1-shard mesh, where the
        # sharded backends skip their ppermutes — see bench_scaling for the
        # forced-multi-device measurement)
        from repro.dist import plan_comm_stats

        measured = {k: s.summary() for k, s in plan_comm_stats(plan).items()}
        row(f"comm_plan_{backend}", 0.0,
            f"E={g.n_edges};apply_msgs={mc['apply_messages']};"
            + ";".join(f"{k}={v}" for k, v in bytes_model.items()))
        write_json(json_dir, f"bench_comm_{backend}", {
            "bench": "comm",
            "backend": backend,
            "n": g.n_vertices,
            "E": g.n_edges,
            "K": K,
            "eta": op.eta,
            "device_count": len(jax.devices()),
            "paper_message_counts": mc,
            "plan_info": dict(plan.info),
            "measured_commstats": measured,
        })


def run(backends=None, json_dir="."):
    if backends:
        sweep_backends(backends, json_dir)
    K, J = 20, 6
    for n in (125, 250, 500, 1000):
        g, _ = seeded_sensor_graph(n)
        E = g.n_edges
        lmax = g.lambda_max_bound()
        op = GraphOperator(P=g.laplacian(),
                             multipliers=sgwt_multipliers(lmax, J),
                             lmax=lmax, K=K)
        mc = op.message_counts(E)
        ista_scalars = (mc["gram_messages"] * 1
                        + mc["adjoint_messages"] * (J + 1))
        admm_scalars = 2 * E * n * (J + 1)  # ADMM lasso [29,30] per iteration
        row(f"comm_N{n}", 0.0,
            f"E={E};apply={mc['apply_messages']};gram={mc['gram_messages']};"
            f"ista_scalars={ista_scalars};admm_scalars={admm_scalars};"
            f"ratio={admm_scalars / max(ista_scalars, 1):.1f}x")

    # sharded halo-byte analog (DESIGN.md §3)
    gs, _ = seeded_sensor_graph(600, sort=True)
    parts, leak = dist.partition_banded(np.asarray(gs.laplacian()), 8)
    row("comm_halo_8shards", 0.0,
        f"leak={leak};bytes_per_apply={dist.halo_bytes_per_apply(parts, K)};"
        f"bytes_per_ista_iter={dist.halo_bytes_per_apply(parts, K, eta=J + 1) + dist.halo_bytes_per_apply(parts, K)}")

    # Chebyshev gossip vs fabric all-reduce traffic model (DESIGN.md §4.1):
    # exact ring consensus needs K = ceil(n/2) rounds x 2 neighbour sends of
    # the gradient (G bytes fp32); ring all-reduce moves ~2G. int8 messages
    # (ref [31] extension) close most of the gap while tolerating link loss.
    from repro.dist import gossip

    for n_dev in (8, 16):
        Kg = len(gossip.consensus_coeffs(n_dev)) - 1
        err = gossip.consensus_error(n_dev, gossip.consensus_coeffs(n_dev))
        fp32 = 2 * Kg            # sends per device, units of G bytes
        int8 = 2 * Kg / 4.0
        row(f"comm_gossip_ring{n_dev}", 0.0,
            f"rounds={Kg};consensus_err={err:.1e};"
            f"gossip_fp32={fp32:.0f}G;gossip_int8={int8:.0f}G;allreduce=2G;"
            f"note=int8 gossip ~ all-reduce parity + straggler tolerance")


if __name__ == "__main__":
    run()
