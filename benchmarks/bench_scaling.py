"""Communication-vs-network-size curve (paper Fig. 3 analogue).

Measures — via :mod:`repro.dist.commstats`, i.e. by counting the collectives
each compiled plan actually traces to — the messages per application of
Phi~ / Phi~* / Phi~*Phi~ on sensor graphs of growing size, and compares
them against the paper's closed forms (2K|E| / 2K|E| / 4K|E|, Section
IV-B/C).  The acceptance gate is that the measured count stays within 10%
of the prediction at every size; a faithful Algorithm 1 implementation
lands on it exactly.

Also reports the device-level byte curve of the sharded backends: the
`pallas_halo` boundary-rows-only exchange vs the `halo` full-block exchange
— the systems-level payoff of halo-aware tiling.

    PYTHONPATH=src python -m benchmarks.bench_scaling [--json-dir DIR]
        [--backend pallas_halo,halo] [--sizes 150,300,600] [--shards 8]

Measurement needs >= 2 mesh shards (1-shard plans skip collectives); when
the current process has a single device the module re-execs itself in a
subprocess with forced host devices, so it works from `benchmarks.run`
and standalone alike.
"""
import argparse
import os
import subprocess
import sys

DEFAULT_SIZES = (150, 300, 600)
DEFAULT_BACKENDS = ("pallas_halo", "halo")
DEFAULT_SHARDS = 8


def _measure(backends, sizes, n_shards, json_dir, K=15, J=3):
    import jax

    from repro.core.wavelets import sgwt_multipliers
    from repro.dist import GraphOperator, verify_message_scaling

    from .common import row, seeded_sensor_graph, write_json

    mesh = jax.make_mesh((n_shards,), ("graph",))
    curve = []
    for n in sizes:
        gs, _ = seeded_sensor_graph(n, sort=True)
        E = gs.n_edges
        lmax = gs.lambda_max_bound()
        op = GraphOperator(P=gs.laplacian(),
                           multipliers=sgwt_multipliers(lmax, J),
                           lmax=lmax, K=K)
        point = {"n": n, "E": E, "K": K, "eta": op.eta,
                 "predicted": op.message_counts(E), "backends": {}}
        for backend in backends:
            plan = op.plan(backend, mesh=mesh, allow_leak=True)
            v = verify_message_scaling(plan, E)
            apply_stats = v["stats"]["apply"]
            point["backends"][backend] = {
                "measured": v["measured"],
                "rel_dev": v["rel_dev"],
                "bytes_per_apply": apply_stats["total_bytes"],
                "rounds_per_apply": apply_stats["exchange_rounds"],
                "plan_info": {k: val for k, val in plan.info.items()
                              if isinstance(val, (int, float, str))},
            }
            row(f"scaling_{backend}_N{n}", 0.0,
                f"E={E};measured_apply={v['measured']['apply']};"
                f"predicted_apply={v['predicted']['apply']};"
                f"max_rel_dev={v['max_rel_dev']:.3f};"
                f"bytes_per_apply={apply_stats['total_bytes']}")
            assert v["max_rel_dev"] <= 0.10, (
                f"{backend} N={n}: measured messages deviate "
                f">10% from 2K|E| ({v['rel_dev']})")
        curve.append(point)

    write_json(json_dir, "bench_scaling", {
        "bench": "scaling",
        "n_shards": n_shards,
        "sizes": list(sizes),
        "backends": list(backends),
        "curve": curve,
    })
    return curve


def run(backends=None, json_dir=".", sizes=None, n_shards=DEFAULT_SHARDS):
    """Entry point used by `benchmarks.run`.

    Spawns a forced-host-device subprocess when this process cannot build
    an `n_shards`-wide mesh (collectives vanish on 1-shard meshes, so the
    measurement would be vacuous).
    """
    backends = tuple(backends or DEFAULT_BACKENDS)
    sizes = tuple(sizes or DEFAULT_SIZES)

    import jax

    if len(jax.devices()) >= n_shards:
        return _measure(backends, sizes, n_shards, json_dir)

    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_shards} "
        + env.get("XLA_FLAGS", ""))
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env["PYTHONPATH"] = (src + os.pathsep + root + os.pathsep
                         + env.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", "benchmarks.bench_scaling",
           "--json-dir", json_dir, "--backend", ",".join(backends),
           "--sizes", ",".join(str(s) for s in sizes),
           "--shards", str(n_shards)]
    proc = subprocess.run(cmd, env=env, cwd=root)
    if proc.returncode != 0:
        raise RuntimeError(
            f"bench_scaling subprocess failed (rc={proc.returncode})")
    return None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json-dir", default=".")
    ap.add_argument("--backend", default=",".join(DEFAULT_BACKENDS))
    ap.add_argument("--sizes", default=",".join(map(str, DEFAULT_SIZES)))
    ap.add_argument("--shards", type=int, default=DEFAULT_SHARDS)
    args = ap.parse_args()
    backends = tuple(args.backend.split(","))
    sizes = tuple(int(s) for s in args.sizes.split(","))

    import jax

    if len(jax.devices()) >= args.shards:
        print("name,us_per_call,derived")
        _measure(backends, sizes, args.shards, args.json_dir)
    else:
        run(backends, args.json_dir, sizes, args.shards)


if __name__ == "__main__":
    main()
