"""Communication-vs-network-size curve (paper Fig. 3 analogue).

Measures — via :mod:`repro.dist.commstats`, i.e. by counting the collectives
each compiled plan actually traces to — the messages per application of
Phi~ / Phi~* / Phi~*Phi~ on graphs of growing size, and compares them
against the paper's closed forms (2K|E| / 2K|E| / 4K|E|, Section IV-B/C).
The acceptance gate is that the measured count stays within 10% of the
prediction at every size; a faithful Algorithm 1 implementation lands on
it exactly, and ``--check`` tightens the gate to *exact* equality plus a
bytes-per-round == wire-model assert.

Two graph families:

* ``--graph sensor`` (default) — the banded spatially-sorted sensor graphs
  the ring partition handles, N in the hundreds (dense P).
* ``--graph community`` — synthetic community graphs at N up to 1e6,
  sharded by the edge-cut `GeneralPartition` (``--partition general``).
  P stays a CSR closure end to end (never densified) and the measurement
  is trace-only (`jax.make_jaxpr`), so the N=1e6 point needs no
  million-vertex execution.

Also reports the device-level byte curve of the sharded backends: the
boundary-rows-only exchange payload per round (the systems-level payoff of
halo-aware tiling — boundary-proportional, not N-proportional).

    PYTHONPATH=src python -m benchmarks.bench_scaling [--json-dir DIR]
        [--backend pallas_halo,halo] [--sizes 150,300,600] [--shards 8]
        [--graph sensor|community] [--partition banded|general]
        [--block 8x8] [--check]

Measurement needs >= 2 mesh shards (1-shard plans skip collectives); when
the current process has a single device the module re-execs itself in a
subprocess with forced host devices, so it works from `benchmarks.run`
and standalone alike.
"""
import argparse
import os
import subprocess
import sys

DEFAULT_SIZES = (150, 300, 600)
DEFAULT_COMMUNITY_SIZES = (10_000, 100_000, 1_000_000)
DEFAULT_BACKENDS = ("pallas_halo", "halo")
DEFAULT_SHARDS = 8


def _auto_block(n):
    """Block-ELL tile for the general partition: the lane-wide (8, 128)
    column block until the per-shard dense-column padding starts to bite,
    then (8, 8) so million-vertex Block-ELL storage stays O(nnz)."""
    return (8, 128) if n <= 20_000 else (8, 8)


def _build_point(graph, n, n_shards, K, J, partition, block, seed=0):
    """One curve point: (op, E, partition-or-None, graph metadata)."""
    from repro.core.wavelets import sgwt_multipliers
    from repro.dist import GraphOperator
    from repro.dist.partition import (community_graph_csr, csr_matvec_fn,
                                      partition_general)

    if graph == "community":
        if partition != "general":
            raise SystemExit(
                "--graph community needs --partition general: the banded "
                "ring partition only covers bandwidth-limited graphs")
        csr, meta = community_graph_csr(n, seed=seed)
        parts = partition_general(csr, n_shards,
                                  block=block or _auto_block(n))
        op = GraphOperator(P=csr_matvec_fn(csr),
                           multipliers=sgwt_multipliers(meta["lmax"], J),
                           lmax=meta["lmax"], K=K)
        return op, csr.n_edges, parts, {"graph": "community",
                                        "edge_cut": parts.edge_cut}

    from .common import seeded_sensor_graph

    gs, _ = seeded_sensor_graph(n, sort=True)
    lmax = gs.lambda_max_bound()
    op = GraphOperator(P=gs.laplacian(),
                       multipliers=sgwt_multipliers(lmax, J),
                       lmax=lmax, K=K)
    parts = None
    if partition == "general":
        parts = partition_general(gs.laplacian(), n_shards,
                                  block=block or _auto_block(n))
    return op, gs.n_edges, parts, {"graph": "sensor"}


def _measure(backends, sizes, n_shards, json_dir, K=15, J=3,
             graph="sensor", partition="banded", block=None, check=False):
    import jax

    from repro.dist import verify_message_scaling

    from .common import row, write_json

    mesh = jax.make_mesh((n_shards,), ("graph",))
    curve = []
    for n in sizes:
        op, E, parts, meta = _build_point(graph, n, n_shards, K, J,
                                          partition, block)
        point = {"n": n, "E": E, "K": K, "eta": op.eta,
                 "partition": partition, **meta,
                 "predicted": op.message_counts(E), "backends": {}}
        for backend in backends:
            if parts is not None:
                plan = op.plan(backend, mesh=mesh, partition=parts)
            else:
                plan = op.plan(backend, mesh=mesh, allow_leak=True)
            v = verify_message_scaling(plan, E, n=n)
            apply_stats = v["stats"]["apply"]
            rec = {
                "measured": v["measured"],
                "rel_dev": v["rel_dev"],
                "bytes_per_apply": apply_stats["total_bytes"],
                "rounds_per_apply": apply_stats["exchange_rounds"],
                "bytes_per_round": (apply_stats["bytes_per_shard"]
                                    / apply_stats["exchange_rounds"]),
                "plan_info": {k: val for k, val in plan.info.items()
                              if isinstance(val, (int, float, str))},
            }
            point["backends"][backend] = rec
            row(f"scaling_{graph}_{backend}_N{n}", 0.0,
                f"E={E};measured_apply={v['measured']['apply']};"
                f"predicted_apply={v['predicted']['apply']};"
                f"max_rel_dev={v['max_rel_dev']:.3f};"
                f"bytes_per_round={rec['bytes_per_round']:.0f}")
            assert v["max_rel_dev"] <= 0.10, (
                f"{backend} N={n}: measured messages deviate "
                f">10% from 2K|E| ({v['rel_dev']})")
            if check:
                # Exact-equality gates (the ISSUE's acceptance bar): a
                # faithful Algorithm 1 lands on 2K|E| exactly, and each
                # round ships exactly the boundary tiles' wire bytes —
                # boundary-proportional, never N-proportional.
                assert v["max_rel_dev"] == 0.0, (
                    f"{backend} N={n}: measured != 2K|E| exactly "
                    f"({v['measured']} vs {v['predicted']})")
                if parts is not None:
                    dt = plan.info.get("exchange_dtype", "f32")
                    want = parts.wire_bytes_per_round(dt)
                    got = rec["bytes_per_round"]
                    assert got == want, (
                        f"{backend} N={n}: bytes/round {got} != wire "
                        f"model {want} (boundary {parts.halo} rows x "
                        f"{dt})")
        curve.append(point)

    write_json(json_dir, f"bench_scaling_{graph}", {
        "bench": "scaling",
        "graph": graph,
        "partition": partition,
        "n_shards": n_shards,
        "sizes": list(sizes),
        "backends": list(backends),
        "curve": curve,
    })
    return curve


def run(backends=None, json_dir=".", sizes=None, n_shards=DEFAULT_SHARDS,
        graph="sensor", partition="banded", block=None, check=False):
    """Entry point used by `benchmarks.run`.

    Spawns a forced-host-device subprocess when this process cannot build
    an `n_shards`-wide mesh (collectives vanish on 1-shard meshes, so the
    measurement would be vacuous).
    """
    if backends is None:
        backends = ("pallas_halo",) if graph == "community" \
            else DEFAULT_BACKENDS
    backends = tuple(backends)
    if sizes is None:
        sizes = DEFAULT_COMMUNITY_SIZES if graph == "community" \
            else DEFAULT_SIZES
    sizes = tuple(sizes)

    import jax

    if len(jax.devices()) >= n_shards:
        return _measure(backends, sizes, n_shards, json_dir,
                        graph=graph, partition=partition, block=block,
                        check=check)

    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_shards} "
        + env.get("XLA_FLAGS", ""))
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env["PYTHONPATH"] = (src + os.pathsep + root + os.pathsep
                         + env.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", "benchmarks.bench_scaling",
           "--json-dir", json_dir, "--backend", ",".join(backends),
           "--sizes", ",".join(str(s) for s in sizes),
           "--shards", str(n_shards),
           "--graph", graph, "--partition", partition]
    if block is not None:
        cmd += ["--block", f"{block[0]}x{block[1]}"]
    if check:
        cmd += ["--check"]
    proc = subprocess.run(cmd, env=env, cwd=root)
    if proc.returncode != 0:
        raise RuntimeError(
            f"bench_scaling subprocess failed (rc={proc.returncode})")
    return None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json-dir", default=".")
    ap.add_argument("--backend", default=None,
                    help="comma list; default pallas_halo,halo (sensor) "
                         "or pallas_halo (community)")
    ap.add_argument("--sizes", default=None,
                    help="comma list; default 150,300,600 (sensor) or "
                         "10000,100000,1000000 (community)")
    ap.add_argument("--shards", type=int, default=DEFAULT_SHARDS)
    ap.add_argument("--graph", choices=("sensor", "community"),
                    default="sensor")
    ap.add_argument("--partition", choices=("banded", "general"),
                    default=None,
                    help="default banded (sensor) / general (community)")
    ap.add_argument("--block", default=None,
                    help="Block-ELL tile RxC for --partition general "
                         "(default: auto by size)")
    ap.add_argument("--check", action="store_true",
                    help="gate measured == 2K|E| EXACTLY and bytes/round "
                         "== the boundary wire model")
    args = ap.parse_args()
    backends = tuple(args.backend.split(",")) if args.backend else None
    sizes = (tuple(int(s) for s in args.sizes.split(","))
             if args.sizes else None)
    partition = args.partition or (
        "general" if args.graph == "community" else "banded")
    block = None
    if args.block:
        r, c = args.block.lower().split("x")
        block = (int(r), int(c))

    import jax

    if len(jax.devices()) >= args.shards:
        print("name,us_per_call,derived")
        _measure(backends or (("pallas_halo",) if args.graph == "community"
                              else DEFAULT_BACKENDS),
                 sizes or (DEFAULT_COMMUNITY_SIZES
                           if args.graph == "community" else DEFAULT_SIZES),
                 args.shards, args.json_dir, graph=args.graph,
                 partition=partition, block=block, check=args.check)
    else:
        run(backends, args.json_dir, sizes, args.shards, graph=args.graph,
            partition=partition, block=block, check=args.check)


if __name__ == "__main__":
    main()
