"""Graceful degradation under injected link faults (the robustness table).

Two legs, one tracked artifact (repo-root ``BENCH_faults.json``):

**Filter/solve ladder** (8 shards, banded Laplacian, halo exchange):
for every ``exchange_dtype`` (f32 / bf16 / int8+error-feedback), both
degradation policies and drop probability p in {0, 0.01, 0.05, 0.2},
measure (a) the relative error of ``plan.apply`` and (b) the relative
error of a ``plan.solve(..., "jacobi")`` against the same plan's clean
run, plus the measured exchange rounds — which must stay exactly K (the
paper's 2K|E| messages) under every fault configuration, because
injection is receiver-side substitution after the ppermute, never a
retry or an extra round.

The two policies split by workload, and the table records both sides:
on the *forward apply* the Chebyshev iterates oscillate (the shifted
operator has eigenvalues near -1), so re-serving last round's tile
(``hold_last``) is roughly a sign error and ``zero_fill`` wins; on the
*converging Jacobi solve* consecutive iterates approach the fixed point,
the carried tile is nearly current, and ``hold_last`` wins by orders of
magnitude.  The ``--check`` policy gate therefore anchors on the solve
leg (see :func:`check`).

**Serving leg** (virtual clock, deterministic): replay a seeded Poisson
stream through a hardened :class:`repro.serve.ServeEngine` (per-request
deadlines, bounded queue + loadgen retry/backoff) twice — clean, and
with injected stragglers (every k-th dispatch stalls the clock) — and
record p99 latency, goodput (served/sec; expired answers do not count),
and the failure-outcome tallies.

``--check`` gates (CI smoke):
  * p=0 rides the clean plan bitwise (``p0_bitwise_identical``) and
    ``exchange_rounds == K`` for every (dtype, policy, p);
  * apply error is monotone nondecreasing in p (f32, both policies);
  * ``hold_last`` solve error <= ``zero_fill`` solve error at p=0.05
    (f32 — the graceful-degradation claim, on the leg where it holds);
  * serving: every admitted request answered exactly once under
    stragglers, finite p99, and straggler goodput <= clean goodput.

    PYTHONPATH=src python -m benchmarks.bench_faults \
        [--n 256] [--bw 8] [--k 10] [--shards 8] [--solve-iters 12] \
        [--drop-probs 0,0.01,0.05,0.2] [--json-path BENCH_faults.json] \
        [--check]
"""
import argparse
import json
import os
import subprocess
import sys

import jax
import numpy as np

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_JSON = os.path.join(REPO_ROOT, "BENCH_faults.json")
DEFAULT_PROBS = (0.0, 0.01, 0.05, 0.2)
DEFAULT_DTYPES = ("f32", "bf16", "int8")
DEFAULT_BACKEND = "halo"
DEFAULT_SHARDS = 8
TAU = 0.5


# ---------------------------------------------------------------------------
# Filter/solve ladder
# ---------------------------------------------------------------------------
def _rel_err(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return float(np.abs(a - b).max() / max(np.abs(b).max(), 1e-30))


def fault_ladder(n, bw, K, n_shards, backend, dtypes, probs, solve_iters):
    """The (dtype x policy x p) error table on one sharded backend."""
    import jax.numpy as jnp

    from repro.dist import FaultSpec, plan_comm_stats
    from repro.dist.faults import DEGRADATIONS

    from .bench_comm import _banded_operator

    op, x = _banded_operator(n, bw, K)
    mesh = jax.make_mesh((n_shards,), ("graph",))
    y = x[0]
    table = {}
    for dt in dtypes:
        clean = op.plan(backend, mesh=mesh, exchange_dtype=dt)
        apply_ref = np.asarray(clean.apply(x))
        solve_ref = np.asarray(
            clean.solve(y, "jacobi", tau=TAU, n_iters=solve_iters).x)
        table[dt] = {}
        for degr in DEGRADATIONS:
            col = {}
            for p in probs:
                spec = FaultSpec(drop_prob=p, seed=0)
                plan = op.plan(backend, mesh=mesh, exchange_dtype=dt,
                               fault_spec=spec, degradation=degr)
                out = np.asarray(plan.apply(x))
                res = plan.solve(y, "jacobi", tau=TAU, n_iters=solve_iters,
                                 check_every=solve_iters)
                st = plan_comm_stats(plan)["apply"]
                col[f"{p:g}"] = {
                    "apply_rel_err": _rel_err(out, apply_ref),
                    "solve_rel_err": _rel_err(res.x, solve_ref),
                    "solve_residual": (None if res.info["residual"] is None
                                       else float(res.info["residual"])),
                    "solve_diverged": bool(res.info["diverged"]),
                    "exchange_rounds": int(st.exchange_rounds),
                    "p0_bitwise_identical": (
                        bool(np.array_equal(out, apply_ref))
                        if p == 0.0 else None),
                    "fault_key": plan.info["fault_key"],
                }
                print(f"faults,{backend},{dt},{degr},p={p:g},"
                      f"apply={col[f'{p:g}']['apply_rel_err']:.3e},"
                      f"solve={col[f'{p:g}']['solve_rel_err']:.3e},"
                      f"rounds={st.exchange_rounds}", flush=True)
            table[dt][degr] = col
    return {
        "backend": backend, "n": n, "halo_width": bw, "K": K,
        "n_shards": n_shards, "solve_iters": solve_iters, "tau": TAU,
        "drop_probs": [float(p) for p in probs],
        "table": table,
    }


# ---------------------------------------------------------------------------
# Serving leg (virtual clock — deterministic, single device)
# ---------------------------------------------------------------------------
def serving_leg(n, bw, K, n_requests=200, rate=2000.0,
                deadline=0.05, max_queue_depth=32,
                straggle_every=5, straggle_s=0.06, seed=0):
    """Clean vs straggler-injected replay through the hardened engine.

    Stragglers stall the virtual clock by `straggle_s` on every
    `straggle_every`-th dispatch — a deterministic stand-in for a slow
    device holding its whole batch.  Queued requests whose deadline
    passes during a stall complete with ``expired`` error Responses; the
    loadgen retry hook resubmits queue-full rejections.
    """
    from repro.serve import (RetryPolicy, ServeEngine, VirtualClock,
                             poisson_arrivals, replay_virtual)

    from .bench_comm import _banded_operator

    op, _x = _banded_operator(n, bw, K)
    events = poisson_arrivals(rate=rate, n_requests=n_requests, seed=seed)
    out = {}
    for label, straggle in (("clean", False), ("stragglers", True)):
        eng = ServeEngine(op.plan("dense"), buckets=(1, 8, 32),
                          max_wait=0.002, clock=VirtualClock(),
                          sync_results=False,
                          max_queue_depth=max_queue_depth)
        if straggle:
            orig, count = eng._callable, {"i": 0}

            def straggling(key, group, _orig=orig, _count=count,
                           _clock=eng.clock):
                fn = _orig(key, group)

                def wrapped(batch):
                    _count["i"] += 1
                    if _count["i"] % straggle_every == 0:
                        _clock.advance(straggle_s)
                    return fn(batch)

                return wrapped

            eng._callable = straggling
        futures = replay_virtual(eng, events, n=n, deadline=deadline,
                                 retry=RetryPolicy())
        s = eng.metrics.summary()
        out[label] = {
            "n_events": len(events),
            "all_futures_answered": all(f.done() for f in futures.values()),
            "p99_latency_ms": s["latency_ms"]["p99"],
            "goodput_signals_per_sec": s["signals_per_sec"],
            "n_served": s["n_served"], "n_failed": s["n_failed"],
            "n_expired": s["n_expired"], "n_rejected": s["n_rejected"],
            "served_exactly_once": s["served_exactly_once"],
        }
        print(f"faults,serving,{label},p99_ms={s['latency_ms']['p99']:.3f},"
              f"goodput={s['signals_per_sec']:.0f},"
              f"expired={s['n_expired']},rejected={s['n_rejected']}",
              flush=True)
    return {
        "n_requests": n_requests, "rate": rate, "deadline_s": deadline,
        "max_queue_depth": max_queue_depth,
        "straggle_every": straggle_every, "straggle_s": straggle_s,
        "runs": out,
    }


# ---------------------------------------------------------------------------
# Gates
# ---------------------------------------------------------------------------
def check(payload) -> None:
    probs = payload["ladder"]["drop_probs"]
    K = payload["ladder"]["K"]
    for dt, per_degr in payload["ladder"]["table"].items():
        for degr, col in per_degr.items():
            for p, e in col.items():
                assert e["exchange_rounds"] == K, (
                    f"{dt}/{degr}/p={p}: {e['exchange_rounds']} rounds "
                    f"!= K={K} — faults must not add exchange rounds")
            p0 = col["0"]
            assert p0["p0_bitwise_identical"], (
                f"{dt}/{degr}: p=0 is not the bitwise clean path")
            assert p0["fault_key"] == "none", (dt, degr, p0["fault_key"])
    for degr in ("zero_fill", "hold_last"):
        errs = [payload["ladder"]["table"]["f32"][degr][f"{p:g}"]
                ["apply_rel_err"] for p in probs]
        assert all(a <= b + 1e-12 for a, b in zip(errs, errs[1:])), (
            f"f32/{degr}: apply error not monotone in p: {errs}")
        assert errs[-1] > 0, (degr, errs)
    hl = payload["ladder"]["table"]["f32"]["hold_last"]["0.05"]
    zf = payload["ladder"]["table"]["f32"]["zero_fill"]["0.05"]
    assert hl["solve_rel_err"] <= zf["solve_rel_err"], (
        "hold_last must beat zero_fill on the converging solve at p=0.05: "
        f"hold_last={hl['solve_rel_err']:.3e} "
        f"zero_fill={zf['solve_rel_err']:.3e}")
    for label, run in payload["serving"]["runs"].items():
        assert run["served_exactly_once"], (label, run)
        assert run["all_futures_answered"], (label, run)
        assert run["p99_latency_ms"] is not None and np.isfinite(
            run["p99_latency_ms"]), (label, run)
    clean = payload["serving"]["runs"]["clean"]
    strag = payload["serving"]["runs"]["stragglers"]
    assert (strag["goodput_signals_per_sec"]
            <= clean["goodput_signals_per_sec"] + 1e-9), (clean, strag)
    print("# fault gates OK: rounds==K everywhere, p=0 bitwise clean, "
          "apply error monotone in p, hold_last<=zero_fill on the solve "
          "at p=0.05, serving exactly-once under stragglers", flush=True)


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------
def _measure(n, bw, K, n_shards, backend, probs, solve_iters, json_path,
             do_check):
    payload = {
        "bench": "faults",
        "ladder": fault_ladder(n, bw, K, n_shards, backend,
                               DEFAULT_DTYPES, probs, solve_iters),
        "serving": serving_leg(n, bw, K),
    }
    if json_path:
        os.makedirs(os.path.dirname(os.path.abspath(json_path)),
                    exist_ok=True)
        with open(json_path, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
        print(f"# wrote {json_path}", flush=True)
    if do_check:
        check(payload)
    return payload


def run(n=256, bw=8, K=10, n_shards=DEFAULT_SHARDS, backend=DEFAULT_BACKEND,
        probs=DEFAULT_PROBS, solve_iters=12, json_path=DEFAULT_JSON,
        do_check=False):
    """Entry point used by `benchmarks.run`.

    Spawns a forced-host-device subprocess when this process cannot build
    an `n_shards`-wide mesh (same idiom as bench_comm.dtype_sweep —
    1-shard plans skip their ppermutes, so fault injection is vacuous).
    """
    if len(jax.devices()) >= n_shards:
        return _measure(n, bw, K, n_shards, backend, probs, solve_iters,
                        json_path, do_check)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_shards} "
        + env.get("XLA_FLAGS", ""))
    src = os.path.join(REPO_ROOT, "src")
    env["PYTHONPATH"] = (src + os.pathsep + REPO_ROOT + os.pathsep
                         + env.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", "benchmarks.bench_faults",
           "--n", str(n), "--bw", str(bw), "--k", str(K),
           "--shards", str(n_shards), "--backend", backend,
           "--solve-iters", str(solve_iters),
           "--drop-probs", ",".join(f"{p:g}" for p in probs),
           "--json-path", json_path or ""]
    if do_check:
        cmd.append("--check")
    proc = subprocess.run(cmd, env=env, cwd=REPO_ROOT)
    if proc.returncode != 0:
        raise RuntimeError(
            f"bench_faults subprocess failed (rc={proc.returncode})")
    return None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--bw", type=int, default=8,
                    help="Laplacian coupling bandwidth == halo width")
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--shards", type=int, default=DEFAULT_SHARDS)
    ap.add_argument("--backend", default=DEFAULT_BACKEND)
    ap.add_argument("--solve-iters", type=int, default=12)
    ap.add_argument("--drop-probs", default=",".join(
        f"{p:g}" for p in DEFAULT_PROBS))
    ap.add_argument("--json-path", default=DEFAULT_JSON,
                    help="output JSON; '' disables writing")
    ap.add_argument("--check", action="store_true",
                    help="fail unless the degradation gates hold "
                    "(see module docstring)")
    args = ap.parse_args()
    probs = tuple(float(p) for p in args.drop_probs.split(","))
    run(args.n, args.bw, args.k, args.shards, args.backend, probs,
        args.solve_iters, args.json_path or None, args.check)


if __name__ == "__main__":
    main()
