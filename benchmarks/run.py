"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]
    PYTHONPATH=src python -m benchmarks.run --only kernels,comm,scaling \
        --backend dense,pallas,halo,pallas_halo,allgather [--json-dir bench-out]

Prints ``name,us_per_call,derived`` CSV rows.  --full uses paper-scale trial
counts (slow on CPU); the default is a reduced but statistically meaningful
configuration.  --backend sweeps bench_kernels/bench_comm through the
`GraphOperator.plan()` API for each named backend and writes one comparable
JSON file per backend to --json-dir.  The `kernels` benchmark additionally
runs the single-launch-sweep microbenchmark (`bench_kernels.sweep_vs_step`)
and writes the repo-root ``BENCH_kernels.json`` with its
``speedup_sweep_vs_step`` gate value.  The `scaling` benchmark
(bench_scaling) measures messages-per-apply with repro.dist.commstats and
checks them against the paper's 2K|E| closed form across graph sizes.
The `comm` benchmark additionally runs the compressed-exchange dtype sweep
(`bench_comm.dtype_sweep`: measured bytes-per-round and accuracy per
``exchange_dtype`` at 8 shards) and writes the repo-root BENCH_comm.json.
The `throughput` benchmark (bench_throughput) sweeps batch sizes
B in {1, 8, 64} through every backend's batched apply and writes the
repo-root BENCH_throughput.json signals/sec trajectory.  The `fig2`
benchmark drives the Section-V solvers (chebyshev/jacobi/cheb_jacobi/arma)
through the sharded `plan.solve` path and writes the repo-root
BENCH_fig2.json error-vs-measured-communication table.  The `serving`
benchmark (bench_serving) replays seeded Poisson request streams through
the repro.serve continuous-batching engine at several offered loads and
writes the repo-root BENCH_serving.json latency/throughput table.  The
`faults` benchmark (bench_faults) measures graceful degradation under
seeded link faults — the (exchange_dtype x degradation policy x drop
probability) error ladder at 8 shards plus a straggler-injected serving
replay — and writes the repo-root BENCH_faults.json.
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale trial counts")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig1,fig2,lasso,comm,"
                    "kernels,scaling,throughput,serving,faults")
    ap.add_argument("--backend", default=None,
                    help="comma-separated execution backends to sweep "
                    "(dense,pallas,halo,pallas_halo,allgather) through the "
                    "plan API; one JSON per backend is written to --json-dir")
    ap.add_argument("--json-dir", default=".",
                    help="directory for per-backend JSON results")
    args = ap.parse_args()

    from . import (bench_comm, bench_faults, bench_fig1_denoising,
                   bench_fig2_methods, bench_kernels, bench_lasso,
                   bench_scaling, bench_serving, bench_throughput)

    backends = args.backend.split(",") if args.backend else None
    wanted = set((args.only or
                  "fig1,fig2,lasso,comm,kernels,throughput,serving,faults")
                 .split(","))
    print("name,us_per_call,derived")
    if "fig1" in wanted:
        bench_fig1_denoising.run(n_trials=1000 if args.full else 20)
    if "fig2" in wanted:
        # Section-V method comparison through the distributed plan.solve
        # path; the tracked repo-root BENCH_fig2.json is only rewritten by
        # a default sweep (like BENCH_throughput.json below)
        import os

        if backends is None and args.json_dir == ".":
            fig2_json = bench_fig2_methods.DEFAULT_JSON
        else:
            fig2_json = os.path.join(args.json_dir, "BENCH_fig2.json")
        fig2_backend = (backends[0] if backends
                        else bench_fig2_methods.DEFAULT_BACKEND)
        bench_fig2_methods.run(budget=20, backend=fig2_backend,
                               json_path=fig2_json)
    if "lasso" in wanted:
        bench_lasso.run(n_trials=20 if args.full else 4,
                        n_iters=300 if args.full else 120)
    if "comm" in wanted:
        bench_comm.run(backends=backends, json_dir=args.json_dir)
        # compressed-exchange dtype sweep (8-shard subprocess when the
        # current process is single-device); the tracked repo-root
        # BENCH_comm.json is only rewritten by a default run, and the
        # sweep only makes sense for the halo-exchange backends
        import os

        sharded = [b for b in (backends or bench_comm.DEFAULT_DTYPE_BACKENDS)
                   if b in bench_comm.DEFAULT_DTYPE_BACKENDS]
        if sharded:
            if backends is None and args.json_dir == ".":
                comm_json = bench_comm.DEFAULT_JSON
            else:
                comm_json = os.path.join(args.json_dir, "BENCH_comm.json")
            bench_comm.dtype_sweep(backends=sharded, json_path=comm_json)
        else:
            print("# comm dtype sweep skipped: --backend lists no "
                  "halo-exchange backend (halo, pallas_halo)", flush=True)
    if "kernels" in wanted:
        bench_kernels.run(backends=backends, json_dir=args.json_dir)
        # single-launch sweep vs per-order microbenchmark; the tracked
        # repo-root BENCH_kernels.json is only rewritten by a default run
        import os

        if backends is None and args.json_dir == ".":
            kernels_json = bench_kernels.DEFAULT_JSON
        else:
            kernels_json = os.path.join(args.json_dir, "BENCH_kernels.json")
        bench_kernels.sweep_vs_step(json_path=kernels_json,
                                    iters=10 if args.full else 5)
    if "throughput" in wanted:
        # B-sweep of the batched (..., N) contract.  The tracked repo-root
        # BENCH_throughput.json (the full 5-backend trajectory) is only
        # rewritten by a default full sweep; --backend subsets or an
        # explicit --json-dir write next to the other bench JSONs instead.
        import os

        if backends is None and args.json_dir == ".":
            json_path = bench_throughput.DEFAULT_JSON
        else:
            json_path = os.path.join(args.json_dir, "BENCH_throughput.json")
        bench_throughput.run(backends=backends, json_path=json_path,
                             iters=20 if args.full else 5)
    if "serving" in wanted:
        # Offered-load replay through the continuous-batching engine.
        # The tracked repo-root BENCH_serving.json is only rewritten by a
        # default run (same gating as the other tracked bench JSONs).
        import os

        if backends is None and args.json_dir == ".":
            serving_json = bench_serving.DEFAULT_JSON
        else:
            serving_json = os.path.join(args.json_dir, "BENCH_serving.json")
        bench_serving.run(
            backends=(tuple(backends) if backends
                      else bench_serving.DEFAULT_BACKENDS),
            n_requests=300 if args.full else 150,
            json_path=serving_json)
    if "faults" in wanted:
        # Fault-injection degradation ladder + straggler serving replay
        # (8-shard subprocess when the current process is single-device).
        # The tracked repo-root BENCH_faults.json is only rewritten by a
        # default run; the ladder only runs on halo-exchange backends.
        import os

        fault_backend = bench_faults.DEFAULT_BACKEND
        if backends is not None:
            sharded = [b for b in backends if b in ("halo", "pallas_halo")]
            fault_backend = sharded[0] if sharded else None
        if fault_backend is None:
            print("# faults skipped: --backend lists no halo-exchange "
                  "backend (halo, pallas_halo)", flush=True)
        else:
            if backends is None and args.json_dir == ".":
                faults_json = bench_faults.DEFAULT_JSON
            else:
                faults_json = os.path.join(args.json_dir,
                                           "BENCH_faults.json")
            bench_faults.run(backend=fault_backend, json_path=faults_json)
    if "scaling" in wanted:
        if backends is None:
            bench_scaling.run(backends=None, json_dir=args.json_dir)
        else:
            sharded = [b for b in backends
                       if b in ("pallas_halo", "halo", "allgather")]
            if sharded:
                bench_scaling.run(backends=sharded, json_dir=args.json_dir)
            else:
                print("# scaling skipped: --backend lists no sharded "
                      "backend (pallas_halo, halo, allgather)", flush=True)


if __name__ == "__main__":
    main()
