"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Prints ``name,us_per_call,derived`` CSV rows. --full uses paper-scale trial
counts (slow on CPU); the default is a reduced but statistically meaningful
configuration.
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale trial counts")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig1,fig2,lasso,comm,kernels")
    args = ap.parse_args()

    from . import (bench_comm, bench_fig1_denoising, bench_fig2_methods,
                   bench_kernels, bench_lasso)

    wanted = set((args.only or "fig1,fig2,lasso,comm,kernels").split(","))
    print("name,us_per_call,derived")
    if "fig1" in wanted:
        bench_fig1_denoising.run(n_trials=1000 if args.full else 20)
    if "fig2" in wanted:
        bench_fig2_methods.run(budget=20)
    if "lasso" in wanted:
        bench_lasso.run(n_trials=20 if args.full else 4,
                        n_iters=300 if args.full else 120)
    if "comm" in wanted:
        bench_comm.run()
    if "kernels" in wanted:
        bench_kernels.run()


if __name__ == "__main__":
    main()
