"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]
    PYTHONPATH=src python -m benchmarks.run --only kernels,comm \
        --backend dense,pallas,halo,allgather [--json-dir bench-out]

Prints ``name,us_per_call,derived`` CSV rows.  --full uses paper-scale trial
counts (slow on CPU); the default is a reduced but statistically meaningful
configuration.  --backend sweeps bench_kernels/bench_comm through the
`GraphOperator.plan()` API for each named backend and writes one comparable
JSON file per backend to --json-dir.
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale trial counts")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig1,fig2,lasso,comm,kernels")
    ap.add_argument("--backend", default=None,
                    help="comma-separated execution backends to sweep "
                    "(dense,pallas,halo,allgather) through the plan API; "
                    "one JSON per backend is written to --json-dir")
    ap.add_argument("--json-dir", default=".",
                    help="directory for per-backend JSON results")
    args = ap.parse_args()

    from . import (bench_comm, bench_fig1_denoising, bench_fig2_methods,
                   bench_kernels, bench_lasso)

    backends = args.backend.split(",") if args.backend else None
    wanted = set((args.only or "fig1,fig2,lasso,comm,kernels").split(","))
    print("name,us_per_call,derived")
    if "fig1" in wanted:
        bench_fig1_denoising.run(n_trials=1000 if args.full else 20)
    if "fig2" in wanted:
        bench_fig2_methods.run(budget=20)
    if "lasso" in wanted:
        bench_lasso.run(n_trials=20 if args.full else 4,
                        n_iters=300 if args.full else 120)
    if "comm" in wanted:
        bench_comm.run(backends=backends, json_dir=args.json_dir)
    if "kernels" in wanted:
        bench_kernels.run(backends=backends, json_dir=args.json_dir)


if __name__ == "__main__":
    main()
