"""Paper Figure 2 (Section V-E): Chebyshev vs Jacobi vs accelerated Jacobi
vs ARMA, error against communication budget, three (P, S) settings:

  (a) P = L_norm, S = L_norm            (1 matvec per round for all methods)
  (b) P = L,      S = L^2               (Jacobi rounds cost 2 matvecs)
  (c) P = L_norm, S = (2I - L_norm)^-3  (Jacobi diverges; 3rd-order ARMA)

Prints the error after a fixed communication budget per method, normalized
the same way as the paper (matvec-equivalents)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SENSOR500
from repro.core import arma, filters, graph, jacobi
from repro.core.multiplier import graph_multiplier

from .common import row


def _setup(n):
    key = jax.random.PRNGKey(7)
    g, key = graph.connected_sensor_graph(key, n=n, theta=SENSOR500.theta,
                                          kappa=SENSOR500.kappa)
    f = jax.random.uniform(key, (g.n_vertices,), minval=-10.0, maxval=10.0)
    return g, f


def _forward(P, h, tau, f):
    lam, U = np.linalg.eigh(np.asarray(P))
    gfwd = (tau + np.asarray(h(lam))) / tau
    return jnp.asarray(U @ (gfwd * (U.T @ np.asarray(f))))


def run(n: int = None, budget: int = 20):
    n = n or SENSOR500.n_vertices
    tau = 0.5
    g, f = _setup(n)
    L = np.asarray(g.laplacian())
    Ln = np.asarray(g.laplacian("normalized"))
    lmaxL = g.lambda_max_bound()

    def err(x):
        return float(jnp.linalg.norm(x - f))

    # ---------------- (a) P = L_norm, S = L_norm --------------------------
    h = filters.power_kernel(1)
    y = _forward(Ln, h, tau, f)
    mv = lambda x: jnp.asarray(Ln) @ x
    K = budget
    op = graph_multiplier(jnp.asarray(Ln), filters.ssl_multiplier(h, tau),
                          2.0, K=K)
    e_cheb = err(op.apply(y))
    qmv, qd = jacobi.tikhonov_q(mv, jnp.diag(jnp.asarray(Ln)), tau)
    e_jac = err(jacobi.jacobi_solve(qmv, qd, y, K))
    Q = (tau * np.eye(n) + Ln) / tau
    QD = np.diag(np.diag(Q))
    rho = float(np.abs(np.linalg.eigvals(np.linalg.solve(QD, QD - Q))).max())
    e_jc = err(jacobi.jacobi_chebyshev_solve(qmv, qd, y, rho * 1.0001, K))
    r, p, c0 = arma.arma_tikhonov_first_order(tau, 2.0)
    # 1 pole -> length-1 messages, same cost per round as Chebyshev
    e_arma = err(arma.arma_apply(mv, y, r, p, 2.0, n_iters=K, const=c0))
    row("fig2a_Lnorm", 0.0,
        f"cheb={e_cheb:.2e};jacobi={e_jac:.2e};jacobi_acc={e_jc:.2e};"
        f"arma={e_arma:.2e};rounds={K}")

    # ---------------- (b) P = L, S = L^2 ----------------------------------
    h2 = filters.power_kernel(2)
    y2 = _forward(L, h2, tau, f)
    mvL = lambda x: jnp.asarray(L) @ x
    op2 = graph_multiplier(jnp.asarray(L), filters.ssl_multiplier(h2, tau),
                           lmaxL, K=budget)
    e_cheb = err(op2.apply(y2))
    qmv2, qd2 = jacobi.power_q(mvL, jnp.asarray(L), tau, 2)
    # one Jacobi round costs 2 matvecs -> budget/2 rounds
    e_jac = err(jacobi.jacobi_solve(qmv2, qd2, y2, budget // 2))
    L2 = L @ L
    Q = (tau * np.eye(n) + L2) / tau
    QD = np.diag(np.diag(Q))
    rho = float(np.abs(np.linalg.eigvals(np.linalg.solve(QD, QD - Q))).max())
    if rho < 1:
        e_jc = err(jacobi.jacobi_chebyshev_solve(qmv2, qd2, y2,
                                                 rho * 1.0001, budget // 2))
        jc_txt = f"{e_jc:.2e}"
    else:
        jc_txt = f"diverges(rho={rho:.2f})"
    r2, p2, c2 = arma.arma_tikhonov_second_order(tau, lmaxL)
    # 2 poles -> length-2 messages per round: budget/2 rounds at equal bytes
    e_arma = err(arma.arma_apply(mvL, y2, r2, p2, lmaxL,
                                 n_iters=budget // 2, const=c2))
    row("fig2b_L_S2", 0.0,
        f"cheb={e_cheb:.2e};jacobi={e_jac:.2e};jacobi_acc={jc_txt};"
        f"arma={e_arma:.2e};rounds={budget}")

    # ------- (c) P = L_norm, S = (2I - L_norm)^-3 (random walk) -----------
    h3 = filters.random_walk_kernel(2.0, 3)
    y3 = _forward(Ln, h3, tau, f)
    op3 = graph_multiplier(jnp.asarray(Ln), filters.ssl_multiplier(h3, tau),
                           2.0, K=budget)
    e_cheb = err(op3.apply(y3))
    r3, p3, c3 = arma.arma_random_walk_3(tau, 2.0)
    # 3 poles -> budget/3 rounds at equal communication
    e_arma = err(arma.arma_apply(mv, y3, r3, p3, 2.0, n_iters=budget // 3,
                                 const=c3))
    row("fig2c_randwalk", 0.0,
        f"cheb={e_cheb:.2e};jacobi=n/a(S dense/divergent);"
        f"arma={e_arma:.2e};rounds={budget}")


if __name__ == "__main__":
    run()
