"""Paper Figure 2 (Section V-E): Chebyshev vs Jacobi vs accelerated Jacobi
vs ARMA — error against *measured* communication budget, three (P, S)
settings:

  (a) P = L_norm, S = L_norm            (1 matvec per round for all methods)
  (b) P = L,      S = L^2               (Jacobi rounds cost 2 matvecs)
  (c) P = L_norm, S = (2I - L_norm)^-3  (Jacobi diverges; 3rd-order ARMA)

Every method runs through ``plan.solve`` on a *sharded* execution plan
(default backend: pallas_halo over forced host devices, like
bench_scaling), and the per-method communication is measured with
``repro.dist.commstats.solve_comm_stats`` — exchange rounds counted from
the compiled jaxpr, not assumed: Fig. 2(b)'s Jacobi rounds show their 2
matvecs, ARMA rounds carry length-n_poles messages.  Results land in
``BENCH_fig2.json`` (repo root by default) as an
error-vs-measured-communication-budget table.

The forward operator g_fwd = (tau + h)/tau is applied by exact *matvec*
polynomial evaluation for the polynomial kernels (a, b) — no
eigendecomposition at any size — and by the dense exact oracle only for
the rational kernel (c), guarded by ``EXACT_ORACLE_MAX_N`` (the setting is
skipped beyond it instead of silently paying O(N^3)).

    PYTHONPATH=src python -m benchmarks.bench_fig2_methods \
        [--n 500] [--budget 20] [--backend pallas_halo] [--shards 8] \
        [--json-path BENCH_fig2.json] [--check]

``--check`` gates on the paper's qualitative error ordering in setting (a)
(Chebyshev lowest at equal rounds; acceleration beats plain Jacobi) — the
CI fig2 smoke step runs it at small n.
"""
import argparse
import os
import subprocess
import sys

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_JSON = os.path.join(REPO_ROOT, "BENCH_fig2.json")
DEFAULT_SHARDS = 8
DEFAULT_BACKEND = "pallas_halo"

#: Largest n the dense exact oracle (np.linalg.eigh) may be used for — the
#: rational setting (c) is skipped beyond this instead of paying O(N^3).
EXACT_ORACLE_MAX_N = 1500


def _forward_poly(matvec, f, h_coeffs, tau):
    """y = g_fwd(P) f for g_fwd = (tau + h)/tau with polynomial h — exact,
    deg(h) matvecs, no eigendecomposition at any size (the same Horner
    evaluation the solvers use)."""
    from repro.dist.solvers import poly_matvec

    return f + poly_matvec(matvec, h_coeffs, f) / tau


def _forward_oracle(P, g_fwd_callable, lmax, f):
    """y = g_fwd(P) f through the dense exact-apply oracle (Eq. (3));
    callers guard on EXACT_ORACLE_MAX_N."""
    from repro.core.multiplier import graph_multiplier

    op = graph_multiplier(P, g_fwd_callable, lmax, K=1)
    return op.union.exact_apply(f)[..., 0, :]


def _run_method(plan, y, f, method, E, n_iters, **kw):
    """One method through plan.solve + solve_comm_stats; returns the
    error-vs-measured-budget record (or a skip record on ValueError)."""
    import jax.numpy as jnp

    from repro.dist import solve_comm_stats

    try:
        res = plan.solve(y, method, n_iters=n_iters, **kw)
    except ValueError as e:
        return {"skipped": str(e)}
    err = float(jnp.linalg.norm(res.x - f) / jnp.linalg.norm(f))
    stats = solve_comm_stats(plan, method, n_iters=n_iters, **kw)
    msg_len = res.info.get("n_poles", 1)
    rounds = stats.exchange_rounds
    return {
        "err": err,
        "n_iters": n_iters,
        "matvecs_per_round": res.info["matvecs_per_round"],
        "predicted_rounds": res.info["exchange_rounds"],
        "measured_rounds": rounds,
        "message_len": msg_len,
        # paper-level accounting at the MEASURED round count (the repo-wide
        # CommStats.paper_messages convention: rounds x 2|E| sensor-network
        # messages; x message_len for the scalar count) — the backend-
        # independent Fig. 2 x-axis.  The *_bytes fields below are the
        # device-level traffic this backend actually shipped (boundary rows
        # under pallas_halo, whole-iterate gathers under allgather).
        "paper_messages": stats.paper_messages(E),
        "paper_scalars": stats.paper_messages(E) * msg_len,
        "measured_bytes_per_shard": stats.bytes_per_shard,
        "measured_total_bytes": stats.total_bytes,
    }


def _measure(n, budget, backend, n_shards, json_path, check):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import filters
    from repro.dist import GraphOperator

    from .common import row, seeded_sensor_graph

    tau = 0.5
    g, key = seeded_sensor_graph(n, seed=0, sort=True)
    n = g.n_vertices
    E = g.n_edges
    mesh = jax.make_mesh((n_shards,), ("graph",))
    f = jax.random.uniform(key, (n,), minval=-10.0, maxval=10.0)
    L = jnp.asarray(g.laplacian())
    Ln = jnp.asarray(g.laplacian("normalized"))
    lmaxL = g.lambda_max_bound()
    mvL = lambda x: jnp.einsum("ij,...j->...i", L, x)       # noqa: E731
    mvLn = lambda x: jnp.einsum("ij,...j->...i", Ln, x)     # noqa: E731

    def plan_for(P, lmax):
        op = GraphOperator(P=P, multipliers=[filters.identity_multiplier()],
                           lmax=lmax, K=budget)
        return op.plan(backend, mesh=mesh, allow_leak=True)

    settings = {}

    # ---------------- (a) P = L_norm, S = L_norm --------------------------
    y = _forward_poly(mvLn, f, (0.0, 1.0), tau)   # h = lambda
    plan = plan_for(Ln, 2.0)
    kw = dict(tau=tau, r=1, h_scale=1.0)
    meth = {
        "chebyshev": _run_method(plan, y, f, "chebyshev", E, budget, **kw),
        "jacobi": _run_method(plan, y, f, "jacobi", E, budget, **kw),
        "cheb_jacobi": _run_method(plan, y, f, "cheb_jacobi", E, budget,
                                   **kw),
        "arma": _run_method(plan, y, f, "arma", E, budget, **kw),
    }
    settings["a_Lnorm"] = {"P": "L_norm", "S": "L_norm", "tau": tau,
                           "methods": meth}
    row("fig2a_Lnorm", 0.0, ";".join(
        f"{m}={v.get('err', 'n/a'):.2e}" if "err" in v else f"{m}=skipped"
        for m, v in meth.items()) + f";rounds={budget}")

    # ---------------- (b) P = L, S = L^2 ----------------------------------
    y2 = _forward_poly(mvL, f, (0.0, 0.0, 1.0), tau)   # h = lambda^2
    plan2 = plan_for(L, lmaxL)
    kw2 = dict(tau=tau, r=2, h_scale=1.0)
    meth2 = {
        "chebyshev": _run_method(plan2, y2, f, "chebyshev", E, budget,
                                 **kw2),
        # one Jacobi round costs 2 matvecs -> budget/2 rounds
        "jacobi": _run_method(plan2, y2, f, "jacobi", E, budget // 2,
                              **kw2),
        "cheb_jacobi": _run_method(plan2, y2, f, "cheb_jacobi", E,
                                   budget // 2, **kw2),
        # 2 poles -> length-2 messages per round: budget/2 rounds at equal
        # scalar traffic
        "arma": _run_method(plan2, y2, f, "arma", E, budget // 2, **kw2),
    }
    settings["b_L_S2"] = {"P": "L", "S": "L^2", "tau": tau,
                          "methods": meth2}
    row("fig2b_L_S2", 0.0, ";".join(
        f"{m}={v.get('err', 'n/a'):.2e}" if "err" in v else f"{m}=skipped"
        for m, v in meth2.items()) + f";rounds={budget}")

    # ------- (c) P = L_norm, S = (2I - L_norm)^-3 (random walk) -----------
    if n <= EXACT_ORACLE_MAX_N:
        h3 = filters.random_walk_kernel(2.0, 3)
        gfwd3 = filters.fig2_target(h3, tau)
        y3 = _forward_oracle(Ln, gfwd3, 2.0, f)
        num3, den3 = filters.random_walk_rational(tau, 2.0, 3)
        plan3 = plan_for(Ln, 2.0)
        kw3 = dict(num=num3, den=den3)
        meth3 = {
            "chebyshev": _run_method(plan3, y3, f, "chebyshev", E, budget,
                                     **kw3),
            # the Jacobi split of den(P) diverges here (the paper's point);
            # cheb_jacobi raises on rho >= 1 and records the skip
            "cheb_jacobi": _run_method(plan3, y3, f, "cheb_jacobi", E,
                                       budget // 3, **kw3),
            # 3 poles -> budget/3 rounds at equal scalar traffic
            "arma": _run_method(plan3, y3, f, "arma", E, budget // 3,
                                **kw3),
        }
        settings["c_randwalk"] = {"P": "L_norm", "S": "(2I - L_norm)^-3",
                                  "tau": tau, "methods": meth3}
        row("fig2c_randwalk", 0.0, ";".join(
            f"{m}={v.get('err', 'n/a'):.2e}" if "err" in v
            else f"{m}=skipped" for m, v in meth3.items())
            + f";rounds={budget}")
    else:
        settings["c_randwalk"] = {
            "skipped": f"n={n} > EXACT_ORACLE_MAX_N={EXACT_ORACLE_MAX_N}: "
                       "the rational forward operator needs the dense "
                       "exact oracle"}
        row("fig2c_randwalk", 0.0, "skipped=exact-oracle size guard")

    payload = {
        "bench": "fig2",
        "n": int(n),
        "E": int(E),
        "budget": int(budget),
        "backend": backend,
        "n_shards": int(n_shards),
        "device_count": len(jax.devices()),
        "settings": settings,
    }
    if json_path:
        import json

        parent = os.path.dirname(os.path.abspath(json_path))
        os.makedirs(parent, exist_ok=True)
        with open(json_path, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
        print(f"# wrote {json_path}", flush=True)

    if check:
        a = settings["a_Lnorm"]["methods"]
        skipped = {m: v["skipped"] for m, v in a.items() if "err" not in v}
        assert not skipped, (
            "fig2 check needs every setting-(a) method to run, but these "
            f"were skipped: {skipped}")
        assert a["chebyshev"]["err"] < a["jacobi"]["err"], (
            "Fig. 2(a) ordering violated: Chebyshev should beat Jacobi at "
            f"equal rounds ({a['chebyshev']['err']:.3e} vs "
            f"{a['jacobi']['err']:.3e})")
        assert a["chebyshev"]["err"] < a["arma"]["err"], (
            "Fig. 2(a) ordering violated: Chebyshev should beat ARMA at "
            f"equal rounds ({a['chebyshev']['err']:.3e} vs "
            f"{a['arma']['err']:.3e})")
        assert a["cheb_jacobi"]["err"] < a["jacobi"]["err"], (
            "Eq. (25) acceleration should beat plain Jacobi "
            f"({a['cheb_jacobi']['err']:.3e} vs {a['jacobi']['err']:.3e})")
        for name, rec in (("a", a), ("b", settings["b_L_S2"]["methods"])):
            for m, v in rec.items():
                if "measured_rounds" in v:
                    assert v["measured_rounds"] == v["predicted_rounds"], (
                        f"setting {name} {m}: measured rounds "
                        f"{v['measured_rounds']} != closed form "
                        f"{v['predicted_rounds']}")
        print("# fig2 check OK: method error ordering + measured rounds "
              "match closed forms", flush=True)
    return payload


def run(n: int = None, budget: int = 20, backend: str = DEFAULT_BACKEND,
        n_shards: int = DEFAULT_SHARDS, json_path: str = DEFAULT_JSON,
        check: bool = False):
    """Entry point used by `benchmarks.run`.

    Communication is *measured* (collectives vanish on 1-shard meshes), so
    when this process cannot build an `n_shards`-wide mesh it re-execs
    itself with forced host devices, like bench_scaling."""
    from repro.configs import SENSOR500

    n = n or SENSOR500.n_vertices

    import jax

    if len(jax.devices()) >= n_shards:
        return _measure(n, budget, backend, n_shards, json_path, check)

    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_shards} "
        + env.get("XLA_FLAGS", ""))
    src = os.path.join(REPO_ROOT, "src")
    env["PYTHONPATH"] = (src + os.pathsep + REPO_ROOT + os.pathsep
                         + env.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", "benchmarks.bench_fig2_methods",
           "--n", str(n), "--budget", str(budget), "--backend", backend,
           "--shards", str(n_shards), "--json-path", json_path or ""]
    if check:
        cmd.append("--check")
    proc = subprocess.run(cmd, env=env, cwd=REPO_ROOT)
    if proc.returncode != 0:
        raise RuntimeError(
            f"bench_fig2 subprocess failed (rc={proc.returncode})")
    return None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--budget", type=int, default=20)
    ap.add_argument("--backend", default=DEFAULT_BACKEND)
    ap.add_argument("--shards", type=int, default=DEFAULT_SHARDS)
    ap.add_argument("--json-path", default=DEFAULT_JSON,
                    help="output JSON; '' disables writing")
    ap.add_argument("--check", action="store_true",
                    help="fail unless the Fig. 2(a) error ordering holds "
                    "and measured rounds match the closed forms")
    args = ap.parse_args()

    import jax

    if len(jax.devices()) >= args.shards:
        from repro.configs import SENSOR500

        print("name,us_per_call,derived")
        _measure(args.n or SENSOR500.n_vertices, args.budget, args.backend,
                 args.shards, args.json_path, args.check)
    else:
        run(args.n, args.budget, args.backend, args.shards, args.json_path,
            args.check)


if __name__ == "__main__":
    main()
