#!/usr/bin/env python3
"""The repo's one lint entry point (CI `lint` job): `repro.analysis` CLI.

Runs up to three layers and applies `tools/lint_allowlist.txt`:

* ``ast``   — the repo-specific AST rules of `repro.analysis.astlint`
  (``RP-*``: dense materialization, order loops, host syncs, unlogged
  fallbacks, legacy-scaffold imports) over `src/repro`, plus the
  tracked-bytecode guard (``RP-TRACKED-BYTECODE``, folded in from the old
  CI `docs` job grep).
* ``jaxpr`` — the trace-level invariant checks of `repro.analysis.checks`
  (``JX-*``: ppermute bijection / deadlock-freedom, no collectives under
  while_loop, B=1 vs B=64 collective-schedule equality, pallas_call VMEM
  budgets, f64 / promotion discipline) over every registered execution
  backend on a bandwidth-1 path graph.  ``--shards 1,8`` runs the sharded
  meshes too: each extra shard count re-execs this script in a subprocess
  with ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the
  `tests/_subproc.py` idiom — the parent process stays single-device).
* ``docs``  — `tools/check_docs.py`'s link/coverage checks, reported as
  ``DOC-*`` findings so everything funnels through one allowlist and one
  exit code.

``--check`` exits nonzero on any non-allowlisted finding.  Stale allowlist
entries (matching nothing) are reported as warnings so audit records get
pruned.  Rule catalogue: docs/ARCHITECTURE.md, "Static invariants".
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
from typing import List

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SRC = os.path.join(REPO, "src")
ALLOWLIST = os.path.join(REPO, "tools", "lint_allowlist.txt")

if SRC not in sys.path:
    sys.path.insert(0, SRC)

#: Graph the jaxpr layer traces every backend on: a path graph is banded
#: with coupling bandwidth exactly 1, so every backend (including both halo
#: variants) builds on any contiguous shard split, and the 2K|E| schedule
#: is known in closed form.
LINT_N, LINT_K, LINT_J = 64, 10, 2
LINT_BATCHES = (1, 64)
MESH_AXIS = "graph"
#: Backends that take a mesh (the rest are single-device).
SHARDED_BACKENDS = ("halo", "pallas_halo", "allgather")


def ast_findings(allowlist) -> List:
    from repro.analysis import Finding, lint_tree

    # main() chdirs to the repo root, so paths come out repo-relative —
    # the form the allowlist and REF_PATHS match against
    findings = lint_tree("src/repro", src_root="src",
                         scaffold_globs=allowlist.scaffold_globs)
    # tracked-bytecode guard (was a raw grep in the CI docs job)
    try:
        tracked = subprocess.run(
            ["git", "ls-files"], cwd=REPO, capture_output=True, text=True,
            check=True).stdout.splitlines()
    except (OSError, subprocess.CalledProcessError):
        tracked = []
    for path in tracked:
        if "__pycache__/" in path or path.endswith((".pyc", ".pyo",
                                                    ".pyd")):
            findings.append(Finding(
                rule="RP-TRACKED-BYTECODE", path=path,
                message="Python bytecode is tracked by git — it churns "
                        "every PR and leaks local paths; git rm it "
                        "(__pycache__/ and *.pyc are gitignored)"))
    return findings


def docs_findings() -> List:
    from repro.analysis import Finding

    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import check_docs

    findings = []
    for path, target, resolved in check_docs.broken_links():
        findings.append(Finding(
            rule="DOC-LINK", path=path,
            message=f"broken link ({target}) -> {resolved}"))
    for name in check_docs.undocumented_backends():
        findings.append(Finding(
            rule="DOC-BACKEND-ARCH", path="docs/ARCHITECTURE.md",
            message=f"backend {name!r} is registered but not documented"))
    for name in check_docs.undocumented_backends_api():
        findings.append(Finding(
            rule="DOC-BACKEND-API", path="API.md",
            message=f"backend {name!r} is registered but missing"))
    for name in check_docs.undocumented_solve_methods():
        findings.append(Finding(
            rule="DOC-SOLVE-METHOD", path="API.md",
            message=f"plan.solve method {name!r} is not documented"))
    return findings


def _lint_operator():
    import jax

    from repro.core import graph, wavelets
    from repro.dist import GraphOperator

    g = graph.path_graph(LINT_N)
    lmax = g.lambda_max_bound()
    return GraphOperator(
        P=g.laplacian(),
        multipliers=wavelets.sgwt_multipliers(lmax, J=LINT_J),
        lmax=lmax, K=LINT_K)


def _lint_community_operator():
    """A small non-banded community graph: the GeneralPartition matrix's
    operator (the banded `_lint_operator` would reduce to the ring plan)."""
    import numpy as np

    from repro.core import wavelets
    from repro.dist import GraphOperator
    from repro.dist.partition import community_graph_csr

    csr, meta = community_graph_csr(64, n_communities=8, seed=0)
    lmax = meta["lmax"]
    return GraphOperator(
        P=np.asarray(csr.to_dense()),
        multipliers=wavelets.sgwt_multipliers(lmax, J=LINT_J),
        lmax=lmax, K=LINT_K)


#: The fault configuration the JX-FAULT-NO-EXTRA-COLLECTIVES gate traces
#: (all three channels firing, hold_last for the stateful carried tiles —
#: the config with the most machinery that could accidentally add rounds).
LINT_FAULT_SPEC = {"drop_prob": 0.1, "stale_prob": 0.1, "noise_prob": 0.1,
                   "seed": 0}


def jaxpr_findings(shards: int) -> List:
    import jax

    from repro.analysis import check_fault_schedule, check_plan
    from repro.dist.backends import available_backends

    n_dev = jax.device_count()
    if shards > n_dev:
        raise SystemExit(
            f"jaxpr layer needs {shards} devices, have {n_dev} — run via "
            f"--shards (the CLI sets XLA_FLAGS in a subprocess) instead "
            "of calling the inner layer directly")
    op = _lint_operator()
    mesh = jax.make_mesh((shards,), (MESH_AXIS,))
    findings = []
    for backend in available_backends():
        if backend in SHARDED_BACKENDS:
            plan = op.plan(backend, mesh=mesh)
        elif shards > 1:
            continue  # single-device backends are covered at shards=1
        else:
            plan = op.plan(backend)
        findings += check_plan(
            plan, batches=LINT_BATCHES,
            budget=plan.info.get("sweep_vmem_budget"),
            solve_methods=("jacobi",))
        if backend in ("halo", "pallas_halo"):
            faulted = op.plan(backend, mesh=mesh, exchange_dtype="int8",
                              fault_spec=LINT_FAULT_SPEC,
                              degradation="hold_last")
            findings += check_fault_schedule(
                op.plan(backend, mesh=mesh, exchange_dtype="int8"),
                faulted, solve_methods=("jacobi",))
    # GeneralPartition matrix: the same invariants (JX-PPERMUTE-BIJECTION
    # in particular — the multi-offset exchange realizes each round as
    # complete ppermute bijections) on a non-banded community graph.
    community_op = _lint_community_operator()
    for backend in ("halo", "pallas_halo"):
        if backend not in available_backends():
            continue
        plan = community_op.plan(backend, mesh=mesh, partition="general")
        findings += check_plan(
            plan, batches=LINT_BATCHES,
            budget=plan.info.get("sweep_vmem_budget"),
            solve_methods=("jacobi",))
        findings += check_fault_schedule(
            plan,
            community_op.plan(backend, mesh=mesh, partition="general",
                              fault_spec=LINT_FAULT_SPEC,
                              degradation="hold_last"),
            solve_methods=("jacobi",))
    return findings


def _spawn_sharded(shards: int, allowlist_path: str) -> int:
    """Run the jaxpr layer at `shards` host devices in a subprocess."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={shards} "
        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--check",
         "--layers", "jaxpr", "--inner-shards", str(shards),
         "--allowlist", allowlist_path],
        env=env)
    return proc.returncode


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        description="repro static analysis (jaxpr invariants + AST lint "
                    "+ docs)")
    parser.add_argument("--check", action="store_true",
                        help="exit nonzero on non-allowlisted findings")
    parser.add_argument("--layers", default="ast,docs,jaxpr",
                        help="comma-set of ast|docs|jaxpr (default: all)")
    parser.add_argument("--shards", default="1,8",
                        help="comma-list of shard counts for the jaxpr "
                             "layer; counts > 1 re-exec in a subprocess "
                             "with forced host devices (default: 1,8)")
    parser.add_argument("--inner-shards", type=int, default=None,
                        help=argparse.SUPPRESS)  # subprocess entry
    parser.add_argument("--allowlist", default=ALLOWLIST)
    args = parser.parse_args(argv)
    os.chdir(REPO)
    layers = [l.strip() for l in args.layers.split(",") if l.strip()]
    unknown = set(layers) - {"ast", "docs", "jaxpr"}
    if unknown:
        parser.error(f"unknown layers: {sorted(unknown)}")

    from repro.analysis import Allowlist, AllowlistError

    try:
        allowlist = Allowlist.load(args.allowlist)
    except FileNotFoundError:
        allowlist = Allowlist()
    except AllowlistError as e:
        print(f"allowlist error: {e}", file=sys.stderr)
        return 2

    findings = []
    if "ast" in layers:
        findings += ast_findings(allowlist)
    if "docs" in layers:
        findings += docs_findings()
    rc = 0
    if "jaxpr" in layers:
        if args.inner_shards is not None:
            findings += jaxpr_findings(args.inner_shards)
        else:
            shard_counts = sorted({int(s) for s in args.shards.split(",")})
            if shard_counts and shard_counts[0] == 1:
                findings += jaxpr_findings(1)
                shard_counts = shard_counts[1:]
            for s in shard_counts:
                sub_rc = _spawn_sharded(s, args.allowlist)
                if sub_rc:
                    print(f"jaxpr layer at {s} shards: FAILED "
                          f"(rc={sub_rc})", file=sys.stderr)
                    rc = max(rc, 1)

    kept, suppressed = allowlist.split(findings)
    for f in kept:
        print(str(f), file=sys.stderr)
    scope = f"layers={','.join(layers)}"
    if args.inner_shards is not None:
        scope += f" shards={args.inner_shards}"
    for entry in (allowlist.unused_entries(findings)
                  if args.inner_shards is None and
                  layers == ["ast", "docs", "jaxpr"] else ()):
        # only a full default run can judge staleness: partial layers
        # legitimately miss entries
        print(f"warning: stale allowlist entry matches nothing: "
              f"{entry.rule} {entry.path_glob}"
              + (f"::{entry.symbol}" if entry.symbol else ""),
              file=sys.stderr)
    print(f"lint_repro [{scope}]: {len(kept)} finding(s), "
          f"{len(suppressed)} allowlisted")
    if kept and args.check:
        rc = max(rc, 1)
    return rc


if __name__ == "__main__":
    sys.exit(main())
