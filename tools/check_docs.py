#!/usr/bin/env python3
"""Docs consistency checker (stdlib-only; the CI `lint` job runs it via
`tools/lint_repro.py`, which folds these checks in as DOC-* findings —
this module stays runnable standalone).

Two checks:

1. **Intra-repo links** — every relative markdown link in README.md,
   API.md and docs/*.md must resolve to an existing file (anchors are
   stripped; http(s)/mailto links are ignored).
2. **Backend coverage** — every execution backend registered in
   `src/repro/dist/backends/` (found statically via the
   `@register_backend("name")` decorators, so no jax import is needed)
   must be mentioned in docs/ARCHITECTURE.md AND in API.md (the backend
   table there is the user-facing reference).
3. **Solver-method coverage** — every `plan.solve` method string (the
   `METHODS` literal in `src/repro/dist/solvers.py`, scanned via AST)
   must appear in API.md.

Exit code 0 on success; 1 with a report on stderr otherwise.
`tests/test_docs.py` runs the same functions under pytest and
additionally cross-checks the static scan against the live registry.
"""
from __future__ import annotations

import ast
import os
import re
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

#: markdown inline links [text](target); images share the syntax.
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")


def doc_files(repo: str = REPO):
    """The markdown set the link check covers."""
    files = [os.path.join(repo, "README.md"), os.path.join(repo, "API.md")]
    docs_dir = os.path.join(repo, "docs")
    if os.path.isdir(docs_dir):
        for name in sorted(os.listdir(docs_dir)):
            if name.endswith(".md"):
                files.append(os.path.join(docs_dir, name))
    return [f for f in files if os.path.isfile(f)]


def broken_links(repo: str = REPO):
    """[(file, raw_target, resolved_path), ...] for unresolvable links."""
    broken = []
    for path in doc_files(repo):
        text = open(path, encoding="utf-8").read()
        # links inside fenced code blocks are examples, not references
        text = re.sub(r"```.*?```", "", text, flags=re.S)
        for target in _LINK_RE.findall(text):
            if re.match(r"^(https?:|mailto:|#)", target):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(path), rel))
            if not os.path.exists(resolved):
                broken.append((os.path.relpath(path, repo), target,
                               os.path.relpath(resolved, repo)))
    return broken


def registered_backends(repo: str = REPO):
    """Backend names declared via @register_backend decorators.

    AST-based so docstring examples (`@register_backend("my-backend")` in
    prose) don't count — only real decorators on real functions do.
    """
    backends_dir = os.path.join(repo, "src", "repro", "dist", "backends")
    names = set()
    for name in sorted(os.listdir(backends_dir)):
        if not name.endswith(".py"):
            continue
        src = open(os.path.join(backends_dir, name), encoding="utf-8").read()
        tree = ast.parse(src, filename=name)
        for node in ast.walk(tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for deco in node.decorator_list:
                if (isinstance(deco, ast.Call)
                        and getattr(deco.func, "id",
                                    getattr(deco.func, "attr", None))
                        == "register_backend"
                        and deco.args
                        and isinstance(deco.args[0], ast.Constant)
                        and isinstance(deco.args[0].value, str)):
                    names.add(deco.args[0].value)
    return names


def _names_missing_from(names, path):
    if not os.path.isfile(path):
        return sorted(names)  # everything is missing
    text = open(path, encoding="utf-8").read()
    return sorted(n for n in names if f"`{n}`" not in text and n not in text)


def undocumented_backends(repo: str = REPO):
    """Registered backend names missing from docs/ARCHITECTURE.md."""
    return _names_missing_from(registered_backends(repo),
                               os.path.join(repo, "docs", "ARCHITECTURE.md"))


def undocumented_backends_api(repo: str = REPO):
    """Registered backend names missing from API.md's backend reference."""
    return _names_missing_from(registered_backends(repo),
                               os.path.join(repo, "API.md"))


def solve_methods(repo: str = REPO):
    """The `plan.solve` method vocabulary, scanned statically from the
    METHODS tuple literal in src/repro/dist/solvers.py (no jax import)."""
    path = os.path.join(repo, "src", "repro", "dist", "solvers.py")
    tree = ast.parse(open(path, encoding="utf-8").read(),
                     filename="solvers.py")
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign):
            continue
        if any(getattr(t, "id", None) == "METHODS" for t in node.targets):
            value = ast.literal_eval(node.value)
            return set(value)
    raise AssertionError("METHODS literal not found in dist/solvers.py")


def undocumented_solve_methods(repo: str = REPO):
    """plan.solve method strings missing from API.md."""
    return _names_missing_from(solve_methods(repo),
                               os.path.join(repo, "API.md"))


def main() -> int:
    failures = 0
    for path, target, resolved in broken_links():
        print(f"broken link: {path}: ({target}) -> {resolved}",
              file=sys.stderr)
        failures += 1
    missing = undocumented_backends()
    for name in missing:
        print(f"backend {name!r} is registered but not documented in "
              "docs/ARCHITECTURE.md", file=sys.stderr)
        failures += 1
    for name in undocumented_backends_api():
        print(f"backend {name!r} is registered but missing from API.md",
              file=sys.stderr)
        failures += 1
    for name in undocumented_solve_methods():
        print(f"plan.solve method {name!r} is not documented in API.md",
              file=sys.stderr)
        failures += 1
    if failures:
        print(f"{failures} docs problem(s)", file=sys.stderr)
        return 1
    n_files = len(doc_files())
    n_backends = len(registered_backends())
    n_methods = len(solve_methods())
    print(f"docs OK: {n_files} files link-clean, "
          f"{n_backends} backends documented, "
          f"{n_methods} solve methods documented")
    return 0


if __name__ == "__main__":
    sys.exit(main())
