"""Distributed wavelet-lasso denoising (paper Section VI, Algorithm 3).

Piecewise-smooth field on the 500-sensor network, SGWT with 6 wavelet
scales, iterative soft thresholding over the Chebyshev-approximate frame.
With --sharded (and forced host devices) the whole ISTA loop runs inside a
shard_map over 8 graph shards with ring halo exchanges — the TPU analog of
the sensors' neighbour messages.  --backend pallas_halo runs the fused
Pallas Block-ELL recurrence per shard and exchanges only the boundary rows
each neighbour actually reads; the measured collective traffic
(repro.dist.commstats) is printed next to the paper's 2K|E| model.

    PYTHONPATH=src python examples/distributed_lasso.py
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_lasso.py --sharded
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_lasso.py --sharded \
        --backend pallas_halo
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SENSOR500
from repro.core import filters, graph, wavelets
from repro.core.multiplier import graph_multiplier
from repro.data.pipeline import graph_signal_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sharded", action="store_true")
    ap.add_argument("--backend", default=None,
                    help="explicit execution backend (default: dense, or "
                    "halo with --sharded)")
    ap.add_argument("--iters", type=int, default=150)
    args = ap.parse_args()

    p = SENSOR500
    key = jax.random.PRNGKey(11)
    g, key = graph.connected_sensor_graph(key, n=p.n_vertices,
                                          theta=p.theta, kappa=p.kappa)
    f0 = graph_signal_batch(key, g.coords, "piecewise")
    key, sub = jax.random.split(key)
    y = f0 + p.noise_sigma * jax.random.normal(sub, f0.shape)
    lmax = g.lambda_max_bound()
    mu = jnp.array([p.lasso_mu_scaling]
                   + [p.lasso_mu_wavelet] * p.n_wavelet_scales)
    op = wavelets.sgwt_operator(g.laplacian(), lmax,
                                J=p.n_wavelet_scales, K=p.lasso_K)

    tik = graph_multiplier(g.laplacian(), filters.tikhonov(p.tau, p.r),
                           lmax, K=p.K).apply(y)

    backend = args.backend or ("halo" if args.sharded else "dense")
    if backend in ("halo", "pallas_halo", "allgather"):
        n_dev = len(jax.devices())
        assert n_dev >= 8, "run with XLA_FLAGS=--xla_force_host_platform_device_count=8"
        gs, order = graph.spatial_sort(g)
        mesh = jax.make_mesh((8,), ("graph",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        lmax_s = gs.lambda_max_bound()
        op_s = wavelets.sgwt_operator(gs.laplacian(), lmax_s,
                                      J=p.n_wavelet_scales, K=p.lasso_K)
        plan = op_s.plan(backend, mesh=mesh)
        print(f"backend={backend} over 8 devices; "
              f"plan info: {plan.info}")
        from repro.dist import plan_comm_stats
        st = plan_comm_stats(plan)["apply"]
        print(f"measured per apply: {st.exchange_rounds} exchange rounds, "
              f"{st.total_bytes} bytes over the mesh "
              f"(paper model: {op.message_counts(g.n_edges)['apply_messages']}"
              f" scalar messages)")
        res = plan.solve_lasso(y[jnp.asarray(order)], mu,
                               gamma=p.lasso_gamma, n_iters=args.iters)
        signal = jnp.zeros_like(y).at[np.asarray(order)].set(res.signal)
    else:
        plan = op.plan(backend)
        print(f"backend={backend}; plan info: {plan.info}")
        res = plan.solve_lasso(y, mu, gamma=p.lasso_gamma,
                               n_iters=args.iters)
        signal = res.signal

    print(f"MSE noisy    : {float(jnp.mean((y - f0) ** 2)):.4f}  (paper 0.250)")
    print(f"MSE tikhonov : {float(jnp.mean((tik - f0) ** 2)):.4f}  (paper 0.098)")
    print(f"MSE lasso    : {float(jnp.mean((signal - f0) ** 2)):.4f}  (paper 0.079)")
    mc = op.message_counts(g.n_edges)
    per_iter = mc["gram_messages"] + mc["adjoint_messages"] * op.eta
    print(f"communication per ISTA iteration ~ {per_iter} scalar messages "
          f"(scales with |E|={g.n_edges}, independent of N beyond that)")


if __name__ == "__main__":
    main()
