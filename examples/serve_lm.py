"""Batched serving example: prefill + greedy decode with KV caches.

Runs the hybrid (attention + SSM) arch to show the sub-quadratic cache path.

    PYTHONPATH=src python examples/serve_lm.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import serve


def main():
    raise SystemExit(serve.main(
        ["--arch", "hymba-1.5b", "--smoke", "--batch", "4",
         "--prompt-len", "16", "--gen", "24"]
    ))


if __name__ == "__main__":
    main()
