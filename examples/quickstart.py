"""Quickstart: the paper's Section IV-D experiment end to end.

Builds the 500-sensor random network, observes a noisy smooth field, and
denoises it with the distributed-ready Chebyshev approximation of the
Tikhonov multiplier g(lambda) = tau / (tau + 2 lambda^r).

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

import jax
import jax.numpy as jnp

from repro.configs import SENSOR500
from repro.core import filters, graph
from repro.data.pipeline import graph_signal_batch
from repro.dist import GraphOperator, available_backends


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="dense",
                    choices=available_backends(),
                    help="execution backend for the multiplier application")
    ap.add_argument("--method", default="chebyshev",
                    choices=["chebyshev", "jacobi", "cheb_jacobi", "arma"],
                    help="Section-V solver for the Tikhonov application: "
                    "the Chebyshev approximation (Section IV) or an exact "
                    "iterative solve of (tau I + 2 L^r) f = tau y via "
                    "plan.solve (Eqs. (24)/(25)/(29)-(30))")
    args = ap.parse_args()

    p = SENSOR500
    key = jax.random.PRNGKey(0)
    g, key = graph.connected_sensor_graph(key, n=p.n_vertices,
                                          theta=p.theta, kappa=p.kappa)
    print(f"sensor network: N={g.n_vertices}, |E|={g.n_edges}")

    f0 = graph_signal_batch(key, g.coords, "smooth")   # h_n = nx^2+ny^2-1
    key, sub = jax.random.split(key)
    y = f0 + p.noise_sigma * jax.random.normal(sub, f0.shape)

    order = None
    if args.backend in ("halo", "pallas_halo"):
        # the halo-exchange backends need a banded (spatially sorted) order
        g, order = graph.spatial_sort(g)
        y = y[jnp.asarray(order)]

    lmax = g.lambda_max_bound()
    print(f"lambda_max bound (Anderson-Morley): {lmax:.2f}")
    R = GraphOperator(P=g.laplacian(),
                      multipliers=[filters.tikhonov(p.tau, p.r)],
                      lmax=lmax, K=p.K)
    plan = R.plan(args.backend)  # sharded backends build their own mesh
    if args.method == "chebyshev":
        denoised = plan.apply(y)[0]
    else:
        # the same multiplier served by the Section-V exact solvers: the
        # Prop. 2 filter tau/(tau + 2 lambda^r) is the rational problem
        # den(L) f = tau y with den = tau + 2 lambda^r
        res = plan.solve(y, args.method, tau=p.tau, r=p.r, h_scale=2.0,
                         n_iters=p.K)
        denoised = res.x
        print(f"plan.solve[{args.method}]: {res.n_iters} iterations x "
              f"{res.info['matvecs_per_round']} matvec(s)/round = "
              f"{res.info['exchange_rounds']} exchange rounds")

    if order is not None:  # undo the sort so the MSE lines up with f0
        import numpy as np
        inv = np.argsort(order)
        denoised, y = denoised[inv], y[inv]

    mse_noisy = float(jnp.mean((y - f0) ** 2))
    mse_den = float(jnp.mean((denoised - f0) ** 2))
    print(f"Chebyshev order K={p.K}; backend={plan.backend}; "
          f"error bound B(K)*sqrt(eta) = {R.error_bound():.2e}")
    print(f"MSE noisy    : {mse_noisy:.4f}   (paper avg: 0.250)")
    print(f"MSE denoised : {mse_den:.4f}   (paper avg: 0.013)")
    mc = plan.message_counts(g.n_edges)
    print(f"communication: {mc['apply_messages']} length-1 messages "
          f"(= 2K|E|)")


if __name__ == "__main__":
    main()
