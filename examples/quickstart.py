"""Quickstart: the paper's Section IV-D experiment end to end.

Builds the 500-sensor random network, observes a noisy smooth field, and
denoises it with the distributed-ready Chebyshev approximation of the
Tikhonov multiplier g(lambda) = tau / (tau + 2 lambda^r).

    PYTHONPATH=src python examples/quickstart.py

Pass ``--drop-prob 0.1 --backend halo`` to run the same experiment with
seeded link faults injected into the halo exchange (repro.dist.faults):
the script prints the degradation policy, the fault identity key, and
the achieved MSE so you can see graceful degradation directly.  Solver
methods additionally run with the divergence guard (``check_every``) and
report the measured residual.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

import jax
import jax.numpy as jnp

from repro.configs import SENSOR500
from repro.core import filters, graph
from repro.data.pipeline import graph_signal_batch
from repro.dist import GraphOperator, available_backends


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="dense",
                    choices=available_backends(),
                    help="execution backend for the multiplier application")
    ap.add_argument("--method", default="chebyshev",
                    choices=["chebyshev", "jacobi", "cheb_jacobi", "arma"],
                    help="Section-V solver for the Tikhonov application: "
                    "the Chebyshev approximation (Section IV) or an exact "
                    "iterative solve of (tau I + 2 L^r) f = tau y via "
                    "plan.solve (Eqs. (24)/(25)/(29)-(30))")
    ap.add_argument("--drop-prob", type=float, default=0.0,
                    help="per-(round, link) probability of dropping a "
                    "halo tile (seeded fault injection; needs a sharded "
                    "backend: halo or pallas_halo)")
    ap.add_argument("--degradation", default="zero_fill",
                    choices=["zero_fill", "hold_last"],
                    help="receiver-side substitute for dropped tiles")
    args = ap.parse_args()

    if args.drop_prob > 0:
        if args.backend not in ("halo", "pallas_halo"):
            ap.error("--drop-prob needs a halo-exchange backend "
                     "(--backend halo or pallas_halo); link faults are "
                     "meaningless without links")
        if len(jax.devices()) == 1:
            # one device = one shard = no links to drop; re-exec with
            # forced host devices so the exchange (and its faults) exist
            os.environ["XLA_FLAGS"] = (
                "--xla_force_host_platform_device_count=8 "
                + os.environ.get("XLA_FLAGS", ""))
            os.execv(sys.executable, [sys.executable] + sys.argv)

    p = SENSOR500
    key = jax.random.PRNGKey(0)
    g, key = graph.connected_sensor_graph(key, n=p.n_vertices,
                                          theta=p.theta, kappa=p.kappa)
    print(f"sensor network: N={g.n_vertices}, |E|={g.n_edges}")

    f0 = graph_signal_batch(key, g.coords, "smooth")   # h_n = nx^2+ny^2-1
    key, sub = jax.random.split(key)
    y = f0 + p.noise_sigma * jax.random.normal(sub, f0.shape)

    order = None
    if args.backend in ("halo", "pallas_halo"):
        # the halo-exchange backends need a banded (spatially sorted) order
        g, order = graph.spatial_sort(g)
        y = y[jnp.asarray(order)]

    lmax = g.lambda_max_bound()
    print(f"lambda_max bound (Anderson-Morley): {lmax:.2f}")
    R = GraphOperator(P=g.laplacian(),
                      multipliers=[filters.tikhonov(p.tau, p.r)],
                      lmax=lmax, K=p.K)
    plan_opts = {}
    if args.drop_prob > 0:
        from repro.dist import FaultSpec
        plan_opts = dict(fault_spec=FaultSpec(drop_prob=args.drop_prob,
                                              seed=0),
                         degradation=args.degradation)
    plan = R.plan(args.backend, **plan_opts)  # sharded backends build
    if args.drop_prob > 0:                    # their own mesh
        print(f"fault injection: drop_prob={args.drop_prob:g}, "
              f"degradation={args.degradation}, "
              f"fault_key={plan.info['fault_key']}")
    if args.method == "chebyshev":
        denoised = plan.apply(y)[0]
    else:
        # the same multiplier served by the Section-V exact solvers: the
        # Prop. 2 filter tau/(tau + 2 lambda^r) is the rational problem
        # den(L) f = tau y with den = tau + 2 lambda^r; check_every arms
        # the divergence guard so a fault-degraded solve reports an
        # honest residual instead of silently returning garbage
        res = plan.solve(y, args.method, tau=p.tau, r=p.r, h_scale=2.0,
                         n_iters=p.K, check_every=max(1, p.K // 2))
        denoised = res.x
        print(f"plan.solve[{args.method}]: {res.n_iters} iterations x "
              f"{res.info['matvecs_per_round']} matvec(s)/round = "
              f"{res.info['exchange_rounds']} exchange rounds")
        print(f"plan.solve[{args.method}]: residual "
              f"{float(res.info['residual']):.3e}, "
              f"diverged={bool(res.info['diverged'])}")

    if order is not None:  # undo the sort so the MSE lines up with f0
        import numpy as np
        inv = np.argsort(order)
        denoised, y = denoised[inv], y[inv]

    mse_noisy = float(jnp.mean((y - f0) ** 2))
    mse_den = float(jnp.mean((denoised - f0) ** 2))
    print(f"Chebyshev order K={p.K}; backend={plan.backend}; "
          f"error bound B(K)*sqrt(eta) = {R.error_bound():.2e}")
    print(f"MSE noisy    : {mse_noisy:.4f}   (paper avg: 0.250)")
    print(f"MSE denoised : {mse_den:.4f}   (paper avg: 0.013)")
    mc = plan.message_counts(g.n_edges)
    print(f"communication: {mc['apply_messages']} length-1 messages "
          f"(= 2K|E|)")


if __name__ == "__main__":
    main()
