"""Quickstart: the paper's Section IV-D experiment end to end.

Builds the 500-sensor random network, observes a noisy smooth field, and
denoises it with the distributed-ready Chebyshev approximation of the
Tikhonov multiplier g(lambda) = tau / (tau + 2 lambda^r).

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import SENSOR500
from repro.core import filters, graph
from repro.core.multiplier import graph_multiplier
from repro.data.pipeline import graph_signal_batch


def main():
    p = SENSOR500
    key = jax.random.PRNGKey(0)
    g, key = graph.connected_sensor_graph(key, n=p.n_vertices,
                                          theta=p.theta, kappa=p.kappa)
    print(f"sensor network: N={g.n_vertices}, |E|={g.n_edges}")

    f0 = graph_signal_batch(key, g.coords, "smooth")   # h_n = nx^2+ny^2-1
    key, sub = jax.random.split(key)
    y = f0 + p.noise_sigma * jax.random.normal(sub, f0.shape)

    lmax = g.lambda_max_bound()
    print(f"lambda_max bound (Anderson-Morley): {lmax:.2f}")
    R = graph_multiplier(g.laplacian(), filters.tikhonov(p.tau, p.r),
                         lmax, K=p.K)
    denoised = R.apply(y)

    mse_noisy = float(jnp.mean((y - f0) ** 2))
    mse_den = float(jnp.mean((denoised - f0) ** 2))
    print(f"Chebyshev order K={p.K}; error bound B(K)*sqrt(eta) = "
          f"{R.error_bound():.2e}")
    print(f"MSE noisy    : {mse_noisy:.4f}   (paper avg: 0.250)")
    print(f"MSE denoised : {mse_den:.4f}   (paper avg: 0.013)")
    mc = R.union.message_counts(g.n_edges)
    print(f"communication: {mc['apply_messages']} length-1 messages "
          f"(= 2K|E|)")


if __name__ == "__main__":
    main()
