"""End-to-end training driver example (deliverable (b)).

Trains a ~10M-param reduced deepseek-7b for a few hundred steps on CPU with
checkpointing, then demonstrates the paper-integration: data-parallel
training where gradient averaging is Chebyshev-polynomial *gossip* on the
device ring (Algorithm 1 with P = L(device graph)) instead of an all-reduce.

    PYTHONPATH=src python examples/train_lm.py                  # single dev
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/train_lm.py --gossip     # 4-dev DP

Scaling the same driver to the full 7B config on a real pod is
`python -m repro.launch.train --arch deepseek-7b --steps ...` under a
(data, model) mesh — the code path is identical.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--gossip", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    argv = ["--arch", "deepseek-7b", "--smoke", "--steps", str(args.steps),
            "--batch", "8", "--seq", "64", "--lr", "1e-3",
            "--ckpt-dir", "/tmp/repro_train_lm", "--ckpt-every", "50"]
    if args.gossip:
        import jax
        n = len(jax.devices())
        assert n >= 2, ("gossip DP needs multiple devices: run with "
                        "XLA_FLAGS=--xla_force_host_platform_device_count=4")
        argv += ["--dp-mode", "gossip", "--mesh", f"{n}x1"]
    raise SystemExit(train.main(argv))


if __name__ == "__main__":
    main()
