"""Distributed semi-supervised classification (paper Section III-D).

Two-cluster graph, 4 labeled nodes, labels propagated by applying the
optimal multiplier g(lambda) = tau/(tau + h(lambda)) to each class
indicator column — all classes share the same K communication rounds.

    PYTHONPATH=src python examples/semi_supervised.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

import jax
import jax.numpy as jnp

from repro.core import filters, graph, ssl
from repro.dist import available_backends


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="dense",
                    choices=available_backends(),
                    help="execution backend for the label propagation")
    args = ap.parse_args()
    key = jax.random.PRNGKey(3)
    g, labels = graph.two_cluster_graph(key, n_per=25, p_in=0.85, p_out=0.06)
    mask = jnp.zeros(50, bool).at[jnp.array([0, 1, 25, 26])].set(True)
    print(f"two-cluster graph: N={g.n_vertices}, labeled={int(mask.sum())}")

    kernels = {
        "tikhonov L_norm  (S = L_norm)": filters.power_kernel(1),
        "tikhonov L_norm^2": filters.power_kernel(2),
        "diffusion (Smola-Kondor)": filters.diffusion_kernel(1.0),
        "2-step random walk": filters.random_walk_kernel(2.0, 2),
    }
    Ln = g.laplacian("normalized")
    for name, h in kernels.items():
        res = ssl.semi_supervised_classify(Ln, labels, mask, 2, h=h,
                                           tau=0.5, lmax=2.0, K=20,
                                           backend=args.backend)
        acc = ssl.accuracy(res, labels, mask)
        print(f"  {name:34s} accuracy on unlabeled: {acc:.3f}")


if __name__ == "__main__":
    main()
