"""Model + shape configuration dataclasses for the assigned architecture pool."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | audio | ssm | hybrid | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    mixer: str = "attention"          # attention | mla | rwkv6 | hymba
    norm: str = "rms"                 # rms | ln
    act: str = "swiglu"               # swiglu | gelu
    rope_theta: float = 10_000.0
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # MLA (DeepSeek-V2)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    conv_width: int = 4
    sliding_window: int = 0           # 0 = full attention
    # encoder-decoder (whisper)
    n_encoder_layers: int = 0
    encoder_seq: int = 0              # precomputed frame embeddings length
    # VLM
    n_vision_tokens: int = 0
    mrope_sections: Tuple[int, ...] = ()
    # numerics
    dtype: str = "bfloat16"
    # capability flags
    sub_quadratic: bool = False       # eligible for long_500k
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def is_encoder_decoder(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def jnp_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    # -- parameter counts (for MODEL_FLOPS = 6 N D in §Roofline) -------------
    def param_count(self, active_only: bool = False) -> int:
        """Total (or MoE-active) parameter count of the backbone."""
        d, hd = self.d_model, self.hd
        n_q, n_kv = self.n_heads, self.n_kv_heads
        attn = 0
        if self.mixer == "mla":
            r_kv, r_q, r_rope = self.kv_lora_rank, self.q_lora_rank, self.rope_head_dim
            attn += d * r_q + r_q * n_q * (hd + r_rope)       # q down+up
            attn += d * (r_kv + r_rope)                        # kv down + k_rope
            attn += r_kv * n_q * 2 * hd                        # k_up, v_up
            attn += n_q * hd * d                               # out
        elif self.mixer == "rwkv6":
            attn += 6 * d * d                                  # r,k,v,g,w,out
        else:
            attn += d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d
            if self.mixer == "hymba":
                d_in = self.ssm_expand * d
                attn += d * 2 * d_in + d_in * d                # ssm in/out proj
                attn += d_in * (2 * self.ssm_state + 2)        # B,C,dt,A approx
        ffn_mult = 3 if self.act == "swiglu" else 2
        if self.n_experts > 0:
            experts = self.n_experts if not active_only else (
                self.top_k + self.n_shared_experts
            )
            total_experts = experts + (0 if active_only else self.n_shared_experts)
            ffn = total_experts * ffn_mult * d * self.d_ff + d * self.n_experts
        else:
            ffn = ffn_mult * d * self.d_ff
        per_layer = attn + ffn + 2 * d
        total = self.n_layers * per_layer
        if self.is_encoder_decoder:
            enc_attn = d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d
            cross = enc_attn
            total += self.n_encoder_layers * (enc_attn + ffn + 2 * d)
            total += self.n_layers * cross
        total += self.vocab_size * d * 2  # embed + lm head
        return int(total)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            top_k=min(self.top_k, 2) if self.top_k else 0,
            kv_lora_rank=32 if self.kv_lora_rank else 0,
            q_lora_rank=32 if self.q_lora_rank else 0,
            rope_head_dim=8 if self.mixer == "mla" else self.rope_head_dim,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            sliding_window=min(self.sliding_window, 32) if self.sliding_window else 0,
            n_encoder_layers=2 if self.n_encoder_layers else 0,
            encoder_seq=24 if self.encoder_seq else 0,
            n_vision_tokens=8 if self.n_vision_tokens else 0,
            mrope_sections=(2, 3, 3) if self.mrope_sections else (),  # hd//2 = 8
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell runs; reason recorded in EXPERIMENTS.md."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full quadratic attention at 524k decode — skipped per "
                       "assignment; see DESIGN.md §Arch-applicability")
    return True, ""
