"""qwen2-vl-2b — VLM backbone, M-RoPE [arXiv:2409.12191].

Backbone only per the assignment: the vision patch frontend is a stub —
input_specs() provides precomputed patch embeddings prepended to the token
stream. M-RoPE uses sections (16, 24, 24) over (temporal, h, w) position
streams; in the text-only stub all three streams coincide.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151_936,
    qkv_bias=True,
    n_vision_tokens=64,
    mrope_sections=(16, 24, 24),
    act="swiglu",
    norm="rms",
)
