"""deepseek-7b — dense llama-arch [arXiv:2401.02954]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab_size=102_400,
    act="swiglu",
    norm="rms",
)
