"""rwkv6-1.6b (Finch) — attention-free, data-dependent decay [arXiv:2404.05892]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,          # wkv heads of dim 64
    n_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65_536,
    mixer="rwkv6",
    act="rwkv",          # relu^2 channel mix with receptance gate
    norm="ln",
    sub_quadratic=True,
)
