"""deepseek-v2-236b — MoE 160e top-6, MLA kv_lora=512 [arXiv:2405.04434].

Per-expert d_ff = 1536; 2 shared + 160 routed experts, top-6. MLA with
kv_lora_rank 512, q_lora_rank 1536, decoupled RoPE head dim 64,
per-head dim 128. All layers MoE (the real model's dense first layer is a
constant-factor simplification recorded in DESIGN.md §4).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=1536,
    vocab_size=102_400,
    mixer="mla",
    n_experts=160,
    n_shared_experts=2,
    top_k=6,
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    act="swiglu",
    norm="rms",
)
