"""Architecture registry — `get_config(arch_id)` for every assigned arch."""
from __future__ import annotations

from typing import Dict, List

from .base import SHAPES, ModelConfig, ShapeSpec, shape_applicable
from . import (
    deepseek_7b,
    deepseek_v2_236b,
    hymba_1_5b,
    qwen1_5_32b,
    qwen1_5_4b,
    qwen2_vl_2b,
    qwen3_moe_30b_a3b,
    rwkv6_1_6b,
    sensor500,
    starcoder2_3b,
    whisper_large_v3,
)

_REGISTRY: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        deepseek_7b,
        starcoder2_3b,
        qwen1_5_4b,
        qwen1_5_32b,
        deepseek_v2_236b,
        qwen3_moe_30b_a3b,
        whisper_large_v3,
        rwkv6_1_6b,
        hymba_1_5b,
        qwen2_vl_2b,
    )
}

ARCH_IDS: List[str] = list(_REGISTRY)
SENSOR500 = sensor500.CONFIG


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_IDS}")
    return _REGISTRY[name]


__all__ = [
    "ARCH_IDS", "SHAPES", "SENSOR500", "ModelConfig", "ShapeSpec",
    "get_config", "shape_applicable",
]
