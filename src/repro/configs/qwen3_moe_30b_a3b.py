"""qwen3-moe-30b-a3b — MoE 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151_936,
    n_experts=128,
    top_k=8,
    act="swiglu",
    norm="rms",
)
