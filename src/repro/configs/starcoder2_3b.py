"""starcoder2-3b — dense, GQA kv=2, RoPE [arXiv:2402.19173]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab_size=49_152,
    qkv_bias=True,
    act="gelu",
    norm="ln",
)
