"""hymba-1.5b — parallel attention + mamba heads, ssm_state=16 [arXiv:2411.13676].

Sliding-window attention (1024) runs in parallel with an SSM branch in every
layer; decode keeps a ring-buffer KV cache of the window size plus O(1) SSM
state, making the arch sub-quadratic (long_500k eligible).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32_001,
    mixer="hymba",
    ssm_state=16,
    ssm_expand=2,
    sliding_window=1024,
    act="swiglu",
    norm="rms",
    sub_quadratic=True,
)
