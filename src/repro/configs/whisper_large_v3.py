"""whisper-large-v3 — encoder-decoder audio backbone [arXiv:2212.04356].

Backbone only per the assignment: the conv frontend is a stub —
input_specs() provides precomputed frame embeddings (batch, 1500, d_model).
Sinusoidal positions are used for both stacks (the real decoder uses learned
absolute positions; sinusoidal keeps parameter shapes independent of the
assigned sequence lengths — recorded in DESIGN.md §4).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51_866,
    n_encoder_layers=32,
    encoder_seq=1500,
    qkv_bias=True,
    act="gelu",
    norm="ln",
)
