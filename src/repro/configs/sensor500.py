"""sensor500 — the paper's own workload (Section IV-D / VI).

500 sensors uniform in [0,1]^2, thresholded Gaussian kernel weights
(theta = 0.074, kappa = 0.075), Chebyshev order K = 20 (K = 15 for the
lasso), SGWT with 6 wavelet scales.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class GraphWorkloadConfig:
    name: str = "sensor500"
    n_vertices: int = 500
    theta: float = 0.074
    kappa: float = 0.075
    K: int = 20
    lasso_K: int = 15
    n_wavelet_scales: int = 6
    tau: float = 1.0
    r: int = 1
    noise_sigma: float = 0.5
    lasso_gamma: float = 0.2
    lasso_mu_wavelet: float = 0.75
    lasso_mu_scaling: float = 0.01
    lasso_iters: int = 300


CONFIG = GraphWorkloadConfig()
