"""Pallas TPU kernels (validated in interpret mode on CPU) + jnp oracles."""
from . import ops, ref
from .bcsr_spmv import block_ell_spmv
from .cheb_step import cheb_step
from .cheb_sweep import cheb_sweep, jacobi_sweep
from .flash_attention import flash_attention
from .soft_threshold import ista_shrink

__all__ = [
    "ops", "ref", "block_ell_spmv", "cheb_step", "cheb_sweep",
    "jacobi_sweep", "flash_attention", "ista_shrink",
]
