"""Single-launch persistent Chebyshev / Jacobi sweep Pallas kernels.

The per-order hot path (`bcsr_spmv.block_ell_spmv` + `cheb_step.cheb_step`)
launches two kernels per Chebyshev order and round-trips the iterates
``t_k, t_{k-1}, acc`` through HBM between them: O(K * (3 + eta) * n)
iterate traffic for a K-order union.  The sweep kernels here move the
order loop *inside* the kernel body instead:

  * `cheb_sweep` — the full Algorithm-1 recurrence as ONE `pallas_call`.
    A `lax.fori_loop` over the K orders runs in-kernel; ``t_k / t_{k-1}``
    and the SpMV product live in VMEM scratch across all orders, the
    accumulator is the (VMEM-resident) output ref, and the Block-ELL
    blocks + per-order coefficients stream through.  Iterate HBM traffic
    drops to one load (x) + one store (acc) per application, and kernel
    launches from 2K to 1.
  * `jacobi_sweep` — the Section-V analog: a whole (accelerated-)Jacobi
    solve of ``den(P) x = b`` in one launch, the Horner evaluation of
    ``den(P) x`` (deg(den) in-kernel SpMVs) and the Eq. (24)/(25) update
    fused per round, iterates pinned in VMEM for all ``n_iters`` rounds.

Everything must fit in VMEM at once — iterates, accumulator, and the
Block-ELL structure — so the `kernels.ops` dispatchers guard on the
``(3 + eta) * B * n * 4 bytes + blocks`` footprint and fall back to the
per-order kernels when the budget is exceeded (see
``docs/ARCHITECTURE.md`` "Perf accounting" for the full model, and
`ops.fused_cheb_sweep` / `ops.fused_jacobi_sweep` for the dispatch).

Layout notes: coefficients ride in order-major ``(K+1, eta)`` so the
in-kernel dynamic index is on the leading (sublane) axis; the Block-ELL
column indices are scalar-prefetched exactly as in `bcsr_spmv`, so the
in-kernel SpMV gathers ``(B, bc)`` iterate tiles with `pl.ds` dynamic
slices and hits them with the same ``(B, bc) x (bc, br)``-shaped products
as the batched per-order kernel.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

#: Sanctioned sweep scratch dtypes.  "bf16" is the mixed-precision mode:
#: iterates / SpMV product / Block-ELL blocks live in bfloat16 VMEM (half
#: the pinned footprint, so the ops-layer VMEM guard admits ~2x larger
#: (B, n, eta) tiles), while every accumulator update runs in f32 — the
#: MXU products via ``preferred_element_type=jnp.float32`` in
#: :func:`_spmv_into`, the Chebyshev accumulator by explicit widening
#: casts before each AXPY.
SCRATCH_DTYPES = {"f32": jnp.float32, "bf16": jnp.bfloat16}


def _spmv_into(idx_ref, blocks_ref, src_ref, dst_ref, *, nrb: int, slots: int,
               br: int, bc: int) -> None:
    """In-kernel Block-ELL SpMV: dst <- A @ src along the last axis.

    src_ref / dst_ref: (B, n) VMEM refs (n = nrb * br = ncb * bc).  Each
    row block accumulates its slot products in registers and stores once;
    padded slots hold zero blocks, so they contribute nothing.
    """
    B = src_ref.shape[0]

    def row_body(rb, _):
        def slot_body(s, acc_row):
            col = idx_ref[rb, s]
            blk = blocks_ref[rb, s]                      # (br, bc)
            xb = pl.load(src_ref, (slice(None), pl.ds(col * bc, bc)))
            return acc_row + jax.lax.dot_general(
                xb, blk, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
        acc_row = jax.lax.fori_loop(0, slots, slot_body,
                                    jnp.zeros((B, br), jnp.float32))
        pl.store(dst_ref, (slice(None), pl.ds(rb * br, br)),
                 acc_row.astype(dst_ref.dtype))
        return 0

    jax.lax.fori_loop(0, nrb, row_body, 0)


def _cheb_sweep_kernel(idx_ref, coef_ref, blocks_ref, x_ref, acc_ref,
                       t1_ref, t0_ref, pt_ref, *, K: int, alpha: float,
                       nrb: int, slots: int, br: int, bc: int):
    spmv = functools.partial(_spmv_into, idx_ref, blocks_ref,
                             nrb=nrb, slots=slots, br=br, bc=bc)
    # iterates (and x) may live in bf16 scratch; the accumulator output is
    # always the wide dtype, so every AXPY widens its term explicitly —
    # mixed precision by convert_element_type, never implicit promotion
    out_dt = acc_ref.dtype
    x = x_ref[...]                                       # (B, n)
    # order 0: acc = (c_0 / 2) x                         (Algorithm 1 line 4)
    acc_ref[...] = (0.5 * coef_ref[0][None, :, None]
                    * x.astype(out_dt)[:, None, :])
    # order 1: t_1 = (P x) / alpha - x                   (line 5)
    spmv(x_ref, pt_ref)
    t1 = pt_ref[...] / alpha - x
    t0_ref[...] = x
    t1_ref[...] = t1
    acc_ref[...] = acc_ref[...] + (coef_ref[1][None, :, None]
                                   * t1.astype(out_dt)[:, None, :])

    def order_body(k, _):
        # t_k = (2/alpha) P t_{k-1} - 2 t_{k-1} - t_{k-2}     (line 9)
        spmv(t1_ref, pt_ref)
        tk = ((2.0 / alpha) * pt_ref[...] - 2.0 * t1_ref[...] - t0_ref[...])
        ck = pl.load(coef_ref, (pl.ds(k, 1), slice(None)))[0]     # (eta,)
        acc_ref[...] = acc_ref[...] + (ck[None, :, None]
                                       * tk.astype(out_dt)[:, None, :])
        t0_ref[...] = t1_ref[...]
        t1_ref[...] = tk
        return 0

    jax.lax.fori_loop(2, K + 1, order_body, 0)


@functools.partial(jax.jit,
                   static_argnames=("alpha", "interpret", "scratch_dtype"))
def cheb_sweep(
    blocks: Array,
    indices: Array,
    x: Array,
    coeffs: Array,
    *,
    alpha: float,
    interpret: bool = False,
    scratch_dtype: str = "f32",
) -> Array:
    """Full K-order shifted-Chebyshev recurrence in one kernel launch.

    blocks/indices: Block-ELL structure as in `bcsr_spmv.block_ell_spmv`.
    x: (..., n) with n the Block-ELL padded size (n = nrb * br); leading
    batch dims flatten to one VMEM-resident (B, n) iterate that advances
    through all orders without touching HBM.  coeffs: (eta, K+1), K >= 1.
    Returns (..., eta, n) — the same contract as the per-order path
    (`ops.fused_cheb_apply`), whose `cheb_step` docs and the
    ``docs/ARCHITECTURE.md`` "Perf accounting" section give the HBM
    round-trip model this kernel collapses.

    scratch_dtype: "f32" (default) or "bf16" — the mixed-precision mode
    of :data:`SCRATCH_DTYPES`: iterates, SpMV product, the x operand and
    the Block-ELL blocks are cast to bfloat16, the coefficient table and
    the (B, eta, n) accumulator output stay at x's dtype with f32 MXU
    accumulation (`preferred_element_type`).
    """
    if scratch_dtype not in SCRATCH_DTYPES:
        raise ValueError(f"scratch_dtype must be one of "
                         f"{tuple(SCRATCH_DTYPES)}, got {scratch_dtype!r}")
    sdt = SCRATCH_DTYPES[scratch_dtype]
    nrb, slots, br, bc = blocks.shape
    n = x.shape[-1]
    eta, K1 = coeffs.shape
    batch_shape = x.shape[:-1]
    B = x.size // n
    x2 = x.reshape(B, n).astype(sdt)
    blocks_k = blocks.astype(sdt)
    coefsT = jnp.asarray(coeffs, x.dtype).T              # (K+1, eta)

    kernel = functools.partial(
        _cheb_sweep_kernel, K=K1 - 1, alpha=float(alpha),
        nrb=nrb, slots=slots, br=br, bc=bc)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((K1, eta), lambda g, idx: (0, 0)),
            pl.BlockSpec((nrb, slots, br, bc), lambda g, idx: (0, 0, 0, 0)),
            pl.BlockSpec((B, n), lambda g, idx: (0, 0)),
        ],
        out_specs=pl.BlockSpec((B, eta, n), lambda g, idx: (0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((B, n), sdt),                     # t_{k-1}
            pltpu.VMEM((B, n), sdt),                     # t_{k-2}
            pltpu.VMEM((B, n), sdt),                     # P t_{k-1}
        ],
    )
    acc = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, eta, n), x.dtype),
        interpret=interpret,
    )(indices, coefsT, blocks_k, x2)
    return acc.reshape(batch_shape + (eta, n))


def _jacobi_sweep_kernel(idx_ref, ws_ref, blocks_ref, b_ref, invd_ref,
                         x0_ref, x_ref, xp_ref, q_ref, h_ref,
                         *, n_iters: int, den: Tuple[float, ...],
                         nrb: int, slots: int, br: int, bc: int):
    spmv = functools.partial(_spmv_into, idx_ref, blocks_ref,
                             nrb=nrb, slots=slots, br=br, bc=bc)
    # xp / q / h may live in bf16 scratch; the x iterate (the output ref)
    # and the b / D^{-1} operands stay wide, with explicit casts at every
    # scratch boundary so the update itself runs at full precision
    x_ref[...] = x0_ref[...]
    xp_ref[...] = x0_ref[...].astype(xp_ref.dtype)

    def round_body(t, _):
        x = x_ref[...]
        # den(P) x by Horner: deg(den) in-kernel SpMVs, coefficients baked
        # in as compile-time constants (the rational spec is host-known)
        h_ref[...] = (den[-1] * x).astype(h_ref.dtype)
        for c in den[-2::-1]:
            spmv(h_ref, q_ref)
            h_ref[...] = q_ref[...] + (c * x).astype(h_ref.dtype)
        wt = pl.load(ws_ref, (pl.ds(t, 1), slice(None)))[0]       # (2,)
        # x_next = w (x + D^{-1}(b - den(P) x)) - s x_prev   (Eq. (24)/(25))
        x_next = (wt[0] * (x + invd_ref[...]
                           * (b_ref[...] - h_ref[...].astype(x.dtype)))
                  - wt[1] * xp_ref[...].astype(x.dtype))
        xp_ref[...] = x.astype(xp_ref.dtype)
        x_ref[...] = x_next
        return 0

    jax.lax.fori_loop(0, n_iters, round_body, 0)


@functools.partial(jax.jit,
                   static_argnames=("den", "interpret", "scratch_dtype"))
def jacobi_sweep(
    blocks: Array,
    indices: Array,
    b: Array,
    inv_d: Array,
    weights: Array,
    x0: Array,
    *,
    den: Tuple[float, ...],
    interpret: bool = False,
    scratch_dtype: str = "f32",
) -> Array:
    """Whole (accelerated-)Jacobi solve of den(P) x = b in one launch.

    b / x0: (..., n) at the Block-ELL padded size; inv_d broadcastable to
    them (zeros on padded rows keep those rows exactly zero, the repo-wide
    zero-padding convention).  weights: (n_iters, 2) per-round (w_t, s_t)
    schedule — all (1, 0) for plain Jacobi (Eq. (24)),
    `core.jacobi.cheb_jacobi_weights` for Eq. (25).  den: monomial
    coefficients of the split polynomial, low-degree-first (static).
    Returns x after n_iters rounds, shape (..., n).

    scratch_dtype: "f32" or "bf16" (:data:`SCRATCH_DTYPES`) — under bf16
    the x_prev / SpMV-product / Horner scratch and the streamed blocks
    halve, while the x iterate, b, D^{-1} and the Eq. (24)/(25) update
    stay at b's dtype.
    """
    if scratch_dtype not in SCRATCH_DTYPES:
        raise ValueError(f"scratch_dtype must be one of "
                         f"{tuple(SCRATCH_DTYPES)}, got {scratch_dtype!r}")
    sdt = SCRATCH_DTYPES[scratch_dtype]
    nrb, slots, br, bc = blocks.shape
    n = b.shape[-1]
    batch_shape = jnp.broadcast_shapes(b.shape, x0.shape)[:-1]
    full = batch_shape + (n,)
    B = 1
    for d in batch_shape:
        B *= d
    b2 = jnp.broadcast_to(b, full).reshape(B, n)
    invd2 = jnp.broadcast_to(inv_d, full).reshape(B, n)
    x02 = jnp.broadcast_to(x0, full).reshape(B, n)
    ws = jnp.asarray(weights, b.dtype)
    n_iters = ws.shape[0]

    kernel = functools.partial(
        _jacobi_sweep_kernel, n_iters=n_iters,
        den=tuple(float(c) for c in den),
        nrb=nrb, slots=slots, br=br, bc=bc)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n_iters, 2), lambda g, idx: (0, 0)),
            pl.BlockSpec((nrb, slots, br, bc), lambda g, idx: (0, 0, 0, 0)),
            pl.BlockSpec((B, n), lambda g, idx: (0, 0)),
            pl.BlockSpec((B, n), lambda g, idx: (0, 0)),
            pl.BlockSpec((B, n), lambda g, idx: (0, 0)),
        ],
        out_specs=pl.BlockSpec((B, n), lambda g, idx: (0, 0)),
        scratch_shapes=[
            pltpu.VMEM((B, n), sdt),                     # x_prev
            pltpu.VMEM((B, n), sdt),                     # SpMV product
            pltpu.VMEM((B, n), sdt),                     # Horner accumulator
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, n), b2.dtype),
        interpret=interpret,
    )(indices, ws, blocks.astype(sdt), b2, invd2, x02)
    return out.reshape(full)
