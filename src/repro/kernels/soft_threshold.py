"""Fused soft-threshold (shrinkage) Pallas kernel — Algorithm 3 line 7.

    a_new = S_{mu gamma}( a + gamma * (phi_y - gram_a) )

Fusing the ISTA update with the shrinkage keeps the coefficient tensors
(eta x N per iterate) at a single HBM round trip per iteration.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

_BLOCK = 1024


def _ista_kernel(a_ref, phi_y_ref, gram_ref, thresh_ref, out_ref, *, gamma):
    z = a_ref[...] + gamma * (phi_y_ref[...] - gram_ref[...])
    t = thresh_ref[...]
    out_ref[...] = jnp.sign(z) * jnp.maximum(jnp.abs(z) - t, 0.0)


@functools.partial(jax.jit, static_argnames=("gamma", "interpret"))
def ista_shrink(
    a: Array,
    phi_y: Array,
    gram_a: Array,
    thresh: Array,
    *,
    gamma: float,
    interpret: bool = False,
) -> Array:
    """All inputs (eta, n) with n a multiple of 128; thresh (eta, 1)."""
    from .cheb_step import pick_block

    eta, n = a.shape
    blk = pick_block(n)
    kernel = functools.partial(_ista_kernel, gamma=gamma)
    return pl.pallas_call(
        kernel,
        grid=(n // blk,),
        in_specs=[
            pl.BlockSpec((eta, blk), lambda i: (0, i)),
            pl.BlockSpec((eta, blk), lambda i: (0, i)),
            pl.BlockSpec((eta, blk), lambda i: (0, i)),
            pl.BlockSpec((eta, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((eta, blk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((eta, n), a.dtype),
        interpret=interpret,
    )(a, phi_y, gram_a, thresh)
