"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def block_ell_spmv_ref(blocks: Array, indices: Array, x: Array) -> Array:
    """y = A @ x; blocks (nrb, slots, br, bc), indices (nrb, slots),
    x (..., ncb*bc) with arbitrary leading batch dims. Padded slots must
    hold zero blocks."""
    nrb, slots, br, bc = blocks.shape
    xb = x.reshape(x.shape[:-1] + (-1, bc))
    gathered = jnp.take(xb, indices, axis=-2)  # (..., nrb, slots, bc)
    y = jnp.einsum("rsij,...rsj->...ri", blocks, gathered)
    return y.reshape(x.shape[:-1] + (nrb * br,))


def cheb_step_ref(pt: Array, t_km1: Array, t_km2: Array, acc: Array,
                  coef: Array, *, alpha: float):
    """pt/t_km1/t_km2: (..., n); acc: (..., eta, n); coef: (eta,)."""
    tk = (2.0 / alpha) * pt - 2.0 * t_km1 - t_km2
    return tk, acc + coef[:, None] * tk[..., None, :]


#: Above this padded size the sweep oracles keep the gather-based Block-ELL
#: matvec instead of densifying (n^2 memory).
_DENSE_SWEEP_MAX_N = 4096


def block_ell_to_dense(blocks, indices) -> Array:
    """Reassemble the dense (padded_n, padded_n) matrix from Block-ELL.

    Padded slots must hold zero blocks (they scatter zeros).  Used by the
    sweep oracles below: the structure arrays are plan-time constants, so
    for concrete inputs the scatter runs eagerly in numpy at trace time
    and the sweep's matvecs become plain dense products against a literal
    matrix — on CPU several times faster than the per-order gather+einsum,
    which is tuned for the TPU kernel's streaming layout, not for host
    execution."""
    import numpy as np

    nrb, slots, br, bc = blocks.shape
    n = nrb * br
    if not isinstance(blocks, jax.core.Tracer) and \
            not isinstance(indices, jax.core.Tracer):
        bl = np.asarray(blocks)
        ix = np.asarray(indices)
        dense = np.zeros((n, n), bl.dtype)
        for rb in range(nrb):
            for s in range(slots):
                cb = int(ix[rb, s])
                dense[rb * br:(rb + 1) * br, cb * bc:(cb + 1) * bc] += \
                    bl[rb, s]
        return jnp.asarray(dense)
    one_hot = jax.nn.one_hot(indices, n // bc, dtype=blocks.dtype)
    return jnp.einsum("rsij,rsc->ricj", blocks, one_hot).reshape(n, n)


def _sweep_matvec(blocks, indices):
    """The sweep oracles' matvec: dense when small enough, gather otherwise."""
    n = blocks.shape[0] * blocks.shape[2]
    if n <= _DENSE_SWEEP_MAX_N:
        dense = block_ell_to_dense(blocks, indices)
        return lambda v: jnp.einsum("ij,...j->...i", dense, v)
    return lambda v: block_ell_spmv_ref(blocks, indices, v)


def cheb_sweep_ref(blocks: Array, indices: Array, x: Array, coeffs: Array,
                   *, alpha: float) -> Array:
    """Whole K-order recurrence as one fused jnp computation (the
    `cheb_sweep` oracle): the order loop is unrolled host-side (K is
    static), so XLA sees a single straight-line trace with no per-order
    scan machinery, and the matvec densifies at small n
    (:func:`block_ell_to_dense`) — the CPU analog of the single-launch
    kernel.

    x: (..., n) at the Block-ELL padded size; coeffs: (eta, K+1).
    Returns (..., eta, n)."""
    c = jnp.asarray(coeffs, x.dtype)
    mv = _sweep_matvec(blocks, indices)
    K = c.shape[1] - 1
    t0 = x
    acc = 0.5 * c[:, 0:1] * x[..., None, :]
    if K == 0:
        return acc
    t1 = mv(x) / alpha - x
    acc = acc + c[:, 1:2] * t1[..., None, :]
    for k in range(2, K + 1):
        pt = mv(t1)
        tk = (2.0 / alpha) * pt - 2.0 * t1 - t0
        acc = acc + c[:, k:k + 1] * tk[..., None, :]
        t0, t1 = t1, tk
    return acc


def jacobi_sweep_ref(blocks: Array, indices: Array, b: Array, inv_d: Array,
                     weights, x0: Array, *, den) -> Array:
    """Whole (accelerated-)Jacobi solve of den(P) x = b, rounds unrolled
    (the `jacobi_sweep` oracle).  weights: (n_iters, 2) host-side (w_t,
    s_t) schedule; den: monomial coefficients, low-first.  Returns x after
    n_iters rounds, shape broadcast(b, x0)."""
    import numpy as np

    ws = np.asarray(weights, dtype=np.float64)
    mv = _sweep_matvec(blocks, indices)
    x, x_prev = x0, x0
    for t in range(ws.shape[0]):
        h = den[-1] * x
        for c in den[-2::-1]:
            h = mv(h) + c * x
        x_next = jacobi_step_ref(h, x, x_prev, b, inv_d,
                                 w=float(ws[t, 0]), s=float(ws[t, 1]))
        x, x_prev = x_next, x
    return x


def jacobi_step_ref(qx: Array, x: Array, x_prev: Array, y: Array,
                    inv_d: Array, *, w, s) -> Array:
    """One (accelerated-)Jacobi update x_next = w (x + D^{-1}(y - Qx)) - s x_prev.

    qx = Q @ x; all of qx/x/x_prev: (..., n); y/inv_d broadcastable against
    them.  w = 1, s = 0 is the plain Jacobi sweep (Eq. (24)); the
    Chebyshev-accelerated weights of Eq. (25) vary per iteration."""
    return w * (x + inv_d * (y - qx)) - s * x_prev


def ista_shrink_ref(a: Array, phi_y: Array, gram_a: Array, thresh: Array,
                    *, gamma: float) -> Array:
    z = a + gamma * (phi_y - gram_a)
    return jnp.sign(z) * jnp.maximum(jnp.abs(z) - thresh, 0.0)


def attention_ref(q: Array, k: Array, v: Array, *, causal: bool = True,
                  scale: float | None = None) -> Array:
    """Naive softmax attention with GQA; q (B,Hq,Sq,D), k/v (B,Hkv,Sk,D)."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    if causal:
        rows = jnp.arange(sq)[:, None]
        cols = jnp.arange(sk)[None, :]
        s = jnp.where(cols <= rows, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)
