"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def block_ell_spmv_ref(blocks: Array, indices: Array, x: Array) -> Array:
    """y = A @ x; blocks (nrb, slots, br, bc), indices (nrb, slots),
    x (..., ncb*bc) with arbitrary leading batch dims. Padded slots must
    hold zero blocks."""
    nrb, slots, br, bc = blocks.shape
    xb = x.reshape(x.shape[:-1] + (-1, bc))
    gathered = jnp.take(xb, indices, axis=-2)  # (..., nrb, slots, bc)
    y = jnp.einsum("rsij,...rsj->...ri", blocks, gathered)
    return y.reshape(x.shape[:-1] + (nrb * br,))


def cheb_step_ref(pt: Array, t_km1: Array, t_km2: Array, acc: Array,
                  coef: Array, *, alpha: float):
    """pt/t_km1/t_km2: (..., n); acc: (..., eta, n); coef: (eta,)."""
    tk = (2.0 / alpha) * pt - 2.0 * t_km1 - t_km2
    return tk, acc + coef[:, None] * tk[..., None, :]


def jacobi_step_ref(qx: Array, x: Array, x_prev: Array, y: Array,
                    inv_d: Array, *, w, s) -> Array:
    """One (accelerated-)Jacobi update x_next = w (x + D^{-1}(y - Qx)) - s x_prev.

    qx = Q @ x; all of qx/x/x_prev: (..., n); y/inv_d broadcastable against
    them.  w = 1, s = 0 is the plain Jacobi sweep (Eq. (24)); the
    Chebyshev-accelerated weights of Eq. (25) vary per iteration."""
    return w * (x + inv_d * (y - qx)) - s * x_prev


def ista_shrink_ref(a: Array, phi_y: Array, gram_a: Array, thresh: Array,
                    *, gamma: float) -> Array:
    z = a + gamma * (phi_y - gram_a)
    return jnp.sign(z) * jnp.maximum(jnp.abs(z) - thresh, 0.0)


def attention_ref(q: Array, k: Array, v: Array, *, causal: bool = True,
                  scale: float | None = None) -> Array:
    """Naive softmax attention with GQA; q (B,Hq,Sq,D), k/v (B,Hkv,Sk,D)."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    if causal:
        rows = jnp.arange(sq)[:, None]
        cols = jnp.arange(sk)[None, :]
        s = jnp.where(cols <= rows, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)
