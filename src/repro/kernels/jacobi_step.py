"""Fused (accelerated-)Jacobi update Pallas kernel — Section V-A/V-B.

One iteration of the Section-V solvers after the matvec ``qx = Q @ x``:

    x_next = w * (x + D^{-1} (y - qx)) - s * x_prev

with ``w = 1, s = 0`` the plain Jacobi sweep (Eq. (24)) and the per-
iteration Chebyshev-accelerated weights of Eq. (25) otherwise.  Fusing the
five elementwise reads/writes into one pass keeps the iterate traffic at a
single HBM round-trip per solver round — the same treatment `cheb_step`
gives the Section-IV recurrence, extended to the Section-V solvers (see
docs/ARCHITECTURE.md "Perf accounting").  As with `cheb_step`, the
single-launch `cheb_sweep.jacobi_sweep` kernel subsumes this one when the
whole solve fits in VMEM; this per-round kernel is the guard fallback and
the collective-bearing sharded path.

Tiling mirrors `cheb_step`: iterates are zero-padded to the 128 lane width,
leading batch dims flatten into a grid axis (one kernel launch advances the
whole (..., n) batch one round), and per-shard sizes (the `pallas_halo`
backend runs this inside shard_map) need not be 128 multiples.  The
acceleration weights (w, s) vary per iteration and ride in as a (2, 1)
operand so the kernel stays trace-once inside `lax.scan`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .cheb_step import pick_block

Array = jax.Array


def _jacobi_step_kernel(ws_ref, qx_ref, x_ref, xp_ref, y_ref, invd_ref,
                        out_ref):
    w = ws_ref[0, 0]
    s = ws_ref[1, 0]
    qx = qx_ref[0]
    x = x_ref[0]
    xp = xp_ref[0]
    y = y_ref[0]
    invd = invd_ref[0]
    out_ref[0] = w * (x + invd * (y - qx)) - s * xp


@functools.partial(jax.jit, static_argnames=("interpret",))
def jacobi_step(
    qx: Array,
    x: Array,
    x_prev: Array,
    y: Array,
    inv_d: Array,
    *,
    w,
    s,
    interpret: bool = False,
) -> Array:
    """Returns ``w * (x + inv_d * (y - qx)) - s * x_prev``.

    qx/x/x_prev: (..., n) — any n (padded to a 128 multiple internally,
    padding stripped from the output).  y: (..., n) with the same batch
    shape or unbatched (n,); inv_d likewise (typically the (n,) reciprocal
    diagonal — zero on padded/virtual rows, which keeps them exactly zero).
    w/s: scalars, traced or concrete (the accelerated weights change per
    scan step).
    """
    n_logical = x.shape[-1]
    batch_shape = x.shape[:-1]
    qx, x, x_prev = (jnp.broadcast_to(a, x.shape) for a in (qx, x, x_prev))
    pad = (-n_logical) % 128
    if pad:
        widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
        qx = jnp.pad(qx, widths)
        x = jnp.pad(x, widths)
        x_prev = jnp.pad(x_prev, widths)
        y = jnp.pad(y, [(0, 0)] * (y.ndim - 1) + [(0, pad)])
        inv_d = jnp.pad(inv_d, [(0, 0)] * (inv_d.ndim - 1) + [(0, pad)])
    n = x.shape[-1]
    blk = pick_block(n)
    B = x.size // n
    x3 = x.reshape(B, n)
    qx3 = qx.reshape(B, n)
    xp3 = x_prev.reshape(B, n)
    # y / inv_d keep their own (possibly unbatched) row count; the index
    # map pins row 0 when they are shared across the batch
    y2 = y.reshape(-1, n)
    if y2.shape[0] not in (1, B):
        y2 = jnp.broadcast_to(y, x.shape).reshape(B, n)
    d2 = inv_d.reshape(-1, n)
    if d2.shape[0] not in (1, B):
        d2 = jnp.broadcast_to(inv_d, x.shape).reshape(B, n)
    y_row = (lambda b, i: (b, i)) if y2.shape[0] == B else (lambda b, i: (0, i))
    d_row = (lambda b, i: (b, i)) if d2.shape[0] == B else (lambda b, i: (0, i))
    ws = jnp.stack([jnp.asarray(w, x.dtype),
                    jnp.asarray(s, x.dtype)]).reshape(2, 1)
    out = pl.pallas_call(
        _jacobi_step_kernel,
        grid=(B, n // blk),
        in_specs=[
            pl.BlockSpec((2, 1), lambda b, i: (0, 0)),
            pl.BlockSpec((1, blk), lambda b, i: (b, i)),
            pl.BlockSpec((1, blk), lambda b, i: (b, i)),
            pl.BlockSpec((1, blk), lambda b, i: (b, i)),
            pl.BlockSpec((1, blk), y_row),
            pl.BlockSpec((1, blk), d_row),
        ],
        out_specs=pl.BlockSpec((1, blk), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((B, n), x.dtype),
        interpret=interpret,
    )(ws, qx3, x3, xp3, y2, d2)
    return out[..., :n_logical].reshape(batch_shape + (n_logical,))
