"""Fused Chebyshev recurrence step Pallas kernel.

One order of Algorithm 1 after the sparse matvec `pt = P @ t_{k-1}`:

    t_k   = (2/alpha) * pt - 2 * t_{k-1} - t_{k-2}      (line 9)
    acc_j += c_{j,k} * t_k   for every multiplier j       (line 12 running sum)

Fusing the AXPYs keeps the iterate traffic at one HBM round-trip per order
instead of four (the memory-bound part of the recurrence; see
docs/ARCHITECTURE.md "Perf accounting" for the full model).  The next rung
on that ladder is `cheb_sweep.cheb_sweep`, which collapses the K per-order
launches into ONE persistent kernel with the iterates pinned in VMEM —
this per-order kernel remains the fallback when the sweep's VMEM-footprint
guard trips, and the per-shard step for sharded matvecs that carry
collectives.

Halo-aware tiling: the kernel is also the per-shard recurrence step of the
`pallas_halo` backend, where it runs inside a shard_map on each shard's
local block (size nl, generally *not* a 128 multiple).  The internal
zero-pad-to-128 below is what makes the same tiling serve both the global
(padded_n) and the per-shard (nl) iterate shapes.

Batched iterates ((..., n) under the repo-wide (..., N) signal contract)
take a second tile path with grid (B, n/blk): one kernel launch advances
every batch signal one Chebyshev order, keeping the per-order HBM traffic
at one round-trip for the whole batch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

_BLOCK = 1024


def pick_block(n: int, maximum: int = _BLOCK) -> int:
    """Largest 128-multiple block size <= maximum that divides n.

    Callers with arbitrary n never see the ValueError: `cheb_step` pads its
    iterates to a 128 multiple before tiling and strips the padding from the
    outputs.
    """
    for b in range(min(maximum, n), 127, -128):
        if n % b == 0 and b % 128 == 0:
            return b
    raise ValueError(f"pad n (={n}) to a multiple of 128")


def _cheb_step_kernel(coef_ref, pt_ref, t1_ref, t2_ref, acc_ref,
                      tk_out_ref, acc_out_ref, *, two_over_alpha):
    pt = pt_ref[0]                  # (block,) — one signal's tile
    t1 = t1_ref[0]
    t2 = t2_ref[0]
    tk = two_over_alpha * pt - 2.0 * t1 - t2
    tk_out_ref[0] = tk
    # coef_ref: (eta, 1) broadcast against tk (block,)
    acc_out_ref[0] = acc_ref[0] + coef_ref[...] * tk[None, :]


@functools.partial(jax.jit, static_argnames=("alpha", "interpret"))
def cheb_step(
    pt: Array,
    t_km1: Array,
    t_km2: Array,
    acc: Array,
    coef: Array,
    *,
    alpha: float,
    interpret: bool = False,
):
    """Returns (t_k, acc + outer(coef, t_k)).

    pt, t_km1, t_km2: (..., n) — any n; iterates are zero-padded to a
    multiple of the 128 lane width for tiling and the padding is stripped
    from both outputs.  acc: (..., eta, n); coef: (eta,).  Leading batch
    dims take the batched tile path (grid over (B, n/blk)) so the whole
    batch advances one Chebyshev order in a single kernel launch.
    """
    n_logical = pt.shape[-1]
    pad = (-n_logical) % 128
    if pad:
        widths = [(0, 0)] * (pt.ndim - 1) + [(0, pad)]
        pt = jnp.pad(pt, widths)
        t_km1 = jnp.pad(t_km1, widths)
        t_km2 = jnp.pad(t_km2, widths)
        acc = jnp.pad(acc, [(0, 0)] * (acc.ndim - 1) + [(0, pad)])
    n = pt.shape[-1]
    eta = acc.shape[-2]
    blk = pick_block(n)
    # one tile path for every rank: leading dims flatten to a batch axis
    # (B=1 for the classic 1-D iterate), grid over (B, tiles)
    batch_shape = pt.shape[:-1]
    B = pt.size // n
    pt3 = pt.reshape(B, n)
    t13 = t_km1.reshape(B, n)
    t23 = t_km2.reshape(B, n)
    acc3 = acc.reshape(B, eta, n)
    kernel = functools.partial(_cheb_step_kernel, two_over_alpha=2.0 / alpha)
    tk, acc_out = pl.pallas_call(
        kernel,
        grid=(B, n // blk),
        in_specs=[
            pl.BlockSpec((eta, 1), lambda b, i: (0, 0)),
            pl.BlockSpec((1, blk), lambda b, i: (b, i)),
            pl.BlockSpec((1, blk), lambda b, i: (b, i)),
            pl.BlockSpec((1, blk), lambda b, i: (b, i)),
            pl.BlockSpec((1, eta, blk), lambda b, i: (b, 0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, blk), lambda b, i: (b, i)),
            pl.BlockSpec((1, eta, blk), lambda b, i: (b, 0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n), pt.dtype),
            jax.ShapeDtypeStruct((B, eta, n), acc.dtype),
        ],
        interpret=interpret,
    )(coef[:, None], pt3, t13, t23, acc3)
    tk = tk[..., :n_logical].reshape(batch_shape + (n_logical,))
    acc_out = acc_out[..., :n_logical].reshape(batch_shape + (eta, n_logical))
    return tk, acc_out
