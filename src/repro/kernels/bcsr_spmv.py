"""Block-ELL sparse matvec Pallas kernel — the Algorithm 1 hot loop on TPU.

The paper's per-Chebyshev-order cost is one sparse matvec with P (cost
proportional to |E|, Section IV-A). On TPU we store P in Block-ELL
(`core.graph.BlockELL`): every 8-row block keeps a fixed number of
(8 x 128) column-block slots, so the kernel is fully static and each slot
contributes one MXU-shaped (8,128)x(128,) product.

Grid: (n_row_blocks, max_slots); the slot axis is innermost so the output
row block is revisited and accumulated in VMEM. Column-block indices are
scalar-prefetched so the x BlockSpec can gather the right 128-slice of x
from HBM per slot.

Batched path (`block_ell_spmv_batched`): the (..., N) signal contract makes
B signals ride one sweep of the sparsity structure — the iterate is laid
out (ncb, bc, B) so each slot performs a single (br, bc) x (bc, B) MXU
product, amortizing every Block-ELL block load (and every index gather)
across the whole batch instead of re-walking the structure per signal as a
`jax.vmap` of the vector kernel would.

These kernels are one *launch per matvec*: an order-K recurrence pays K
launches plus the `cheb_step` AXPYs in between.  `cheb_sweep` streams the
same (blocks, indices) layout through its in-kernel SpMV
(`cheb_sweep._spmv_into` gathers the identical (B, bc) iterate tiles by
scalar-prefetched column index) so the whole recurrence runs in one
launch; this module stays the per-matvec primitive for sharded matvecs
whose orders are separated by halo exchanges.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _spmv_kernel(idx_ref, blocks_ref, x_ref, y_ref):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    blk = blocks_ref[0, 0]          # (br, bc)
    xb = x_ref[0]                   # (bc,)
    y_ref[0, :] += jnp.dot(blk, xb, preferred_element_type=jnp.float32).astype(
        y_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_ell_spmv(
    blocks: Array,
    indices: Array,
    x: Array,
    *,
    interpret: bool = False,
) -> Array:
    """y = A @ x for Block-ELL A.

    blocks:  (nrb, slots, br, bc) — padded slots must be zero blocks.
    indices: (nrb, slots) int32 column-block index per slot.
    x:       (nrb_cols * bc,) padded dense vector.
    Returns (nrb * br,).
    """
    nrb, slots, br, bc = blocks.shape
    x2 = x.reshape(-1, bc)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nrb, slots),
        in_specs=[
            pl.BlockSpec((1, 1, br, bc), lambda i, s, idx: (i, s, 0, 0)),
            pl.BlockSpec((1, bc), lambda i, s, idx: (idx[i, s], 0)),
        ],
        out_specs=pl.BlockSpec((1, br), lambda i, s, idx: (i, 0)),
    )
    out = pl.pallas_call(
        _spmv_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nrb, br), x.dtype),
        interpret=interpret,
    )(indices, blocks, x2)
    return out.reshape(nrb * br)


def _spmv_kernel_batched(idx_ref, blocks_ref, x_ref, y_ref):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    blk = blocks_ref[0, 0]          # (br, bc)
    xb = x_ref[0]                   # (bc, B)
    y_ref[0] += jnp.dot(blk, xb, preferred_element_type=jnp.float32).astype(
        y_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_ell_spmv_batched(
    blocks: Array,
    indices: Array,
    x: Array,
    *,
    interpret: bool = False,
) -> Array:
    """Y = A @ X^T for a batch of signals, one structure sweep total.

    blocks/indices as in :func:`block_ell_spmv`; x: (..., nrb_cols * bc)
    padded signals with arbitrary leading batch dims.  Returns
    (..., nrb * br).  Each grid step loads one (br, bc) block once and
    multiplies it against the (bc, B) tile of all batch signals — the block
    loads (the HBM-bound part of the sweep) are amortized over B.
    """
    nrb, slots, br, bc = blocks.shape
    batch_shape = x.shape[:-1]
    B = x.size // x.shape[-1]
    # (B, ncb, bc) -> (ncb, bc, B): batch innermost so every slot is one
    # MXU-shaped (br, bc) x (bc, B) product
    xt = x.reshape(B, -1, bc).transpose(1, 2, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nrb, slots),
        in_specs=[
            pl.BlockSpec((1, 1, br, bc), lambda i, s, idx: (i, s, 0, 0)),
            pl.BlockSpec((1, bc, B), lambda i, s, idx: (idx[i, s], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, br, B), lambda i, s, idx: (i, 0, 0)),
    )
    out = pl.pallas_call(
        _spmv_kernel_batched,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nrb, br, B), x.dtype),
        interpret=interpret,
    )(indices, blocks, xt)
    return out.transpose(2, 0, 1).reshape(batch_shape + (nrb * br,))
