"""Block-ELL sparse matvec Pallas kernel — the Algorithm 1 hot loop on TPU.

The paper's per-Chebyshev-order cost is one sparse matvec with P (cost
proportional to |E|, Section IV-A). On TPU we store P in Block-ELL
(`core.graph.BlockELL`): every 8-row block keeps a fixed number of
(8 x 128) column-block slots, so the kernel is fully static and each slot
contributes one MXU-shaped (8,128)x(128,) product.

Grid: (n_row_blocks, max_slots); the slot axis is innermost so the output
row block is revisited and accumulated in VMEM. Column-block indices are
scalar-prefetched so the x BlockSpec can gather the right 128-slice of x
from HBM per slot.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _spmv_kernel(idx_ref, blocks_ref, x_ref, y_ref):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    blk = blocks_ref[0, 0]          # (br, bc)
    xb = x_ref[0]                   # (bc,)
    y_ref[0, :] += jnp.dot(blk, xb, preferred_element_type=jnp.float32).astype(
        y_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_ell_spmv(
    blocks: Array,
    indices: Array,
    x: Array,
    *,
    interpret: bool = False,
) -> Array:
    """y = A @ x for Block-ELL A.

    blocks:  (nrb, slots, br, bc) — padded slots must be zero blocks.
    indices: (nrb, slots) int32 column-block index per slot.
    x:       (nrb_cols * bc,) padded dense vector.
    Returns (nrb * br,).
    """
    nrb, slots, br, bc = blocks.shape
    x2 = x.reshape(-1, bc)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nrb, slots),
        in_specs=[
            pl.BlockSpec((1, 1, br, bc), lambda i, s, idx: (i, s, 0, 0)),
            pl.BlockSpec((1, bc), lambda i, s, idx: (idx[i, s], 0)),
        ],
        out_specs=pl.BlockSpec((1, br), lambda i, s, idx: (i, 0)),
    )
    out = pl.pallas_call(
        _spmv_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nrb, br), x.dtype),
        interpret=interpret,
    )(indices, blocks, x2)
    return out.reshape(nrb * br)
