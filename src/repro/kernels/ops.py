"""Public jit'd wrappers around the Pallas kernels.

This module is the single dispatch point between the Pallas TPU kernels
(`bcsr_spmv.block_ell_spmv`, `cheb_step.cheb_step`, ...) and their pure-jnp
oracles in :mod:`repro.kernels.ref`.  Everything above it — the `pallas`
and `pallas_halo` execution backends, the benchmarks, the tests — calls
these wrappers and never touches `pallas_call` directly.

Dispatch policy: on TPU the Pallas kernels run natively; on CPU (this
container) `use_pallas=True` runs them under interpret=True (the kernel body
executed in Python — used by the kernel test sweeps), and the default takes
the pure-jnp reference path so smoke tests and benchmarks stay fast.

Sharded use: :func:`fused_cheb_recurrence` is the matvec-generic form of the
fused recurrence.  The `pallas_halo` backend calls it *inside* a shard_map
with a halo-exchanging matvec over the per-shard Block-ELL tiles, so the
same fused Chebyshev-step kernel serves both the single-device and the
sharded hot path (per-shard sizes need not be 128-multiples — `cheb_step`
pads its tiles internally).

Single-launch sweep dispatch: when the matvec is a *local* Block-ELL
product (no collectives — the `pallas` backend always, `pallas_halo` on a
1-shard mesh), the backend tags its matvec closure with ``mv.block_ell``
and :func:`fused_cheb_recurrence` upgrades the whole K-order loop to the
persistent `cheb_sweep` kernel: one launch, iterates pinned in VMEM
across all orders.  The upgrade is guarded by the VMEM footprint model
:func:`cheb_sweep_vmem_bytes` — oversized problems fall back to the
per-order path, logged at INFO (see docs/ARCHITECTURE.md "Perf
accounting").
"""
from __future__ import annotations

import logging
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core.graph import BlockELL
from . import ref
from .bcsr_spmv import block_ell_spmv, block_ell_spmv_batched
from .cheb_step import cheb_step
from .cheb_sweep import cheb_sweep, jacobi_sweep
from .jacobi_step import jacobi_step
from .flash_attention import flash_attention as _flash
from .soft_threshold import ista_shrink

Array = jax.Array

logger = logging.getLogger(__name__)

#: Default VMEM budget for the single-launch sweep kernels: ~16 MB/core on
#: current TPUs, minus headroom for the compiler's own buffers.
DEFAULT_SWEEP_VMEM_BUDGET = 12 * 1024 * 1024


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(use_pallas: Optional[bool]):
    """Returns (use_pallas, interpret)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    return use_pallas, (use_pallas and not _on_tpu())


def spmv(A: BlockELL, x: Array, use_pallas: Optional[bool] = None) -> Array:
    """Block-ELL y = A @ x on padded signals (..., padded_n).

    The Algorithm-1 hot loop: one call per Chebyshev order, cost
    proportional to the number of non-zero blocks (the paper's O(|E|)
    per-order cost).  Leading batch dims ride one sweep of the sparsity
    structure (`block_ell_spmv_batched`: each Block-ELL block is loaded
    once for the whole batch, not once per signal).  `x`'s last axis must
    already be at `A.padded_n`; use `fused_cheb_apply` / the `pallas`
    backend if you want padding handled for you.
    """
    use, interp = _resolve(use_pallas)
    if use:
        if x.ndim > 1:
            return block_ell_spmv_batched(A.blocks, A.indices, x,
                                          interpret=interp)
        return block_ell_spmv(A.blocks, A.indices, x, interpret=interp)
    return ref.block_ell_spmv_ref(A.blocks, A.indices, x)


def _scratch_itemsize(scratch_dtype: Optional[str], itemsize: int) -> int:
    """Bytes per element of the sweep scratch/operand buffers: 2 under the
    bf16 mixed-precision mode, the wide `itemsize` otherwise."""
    if scratch_dtype is not None and scratch_dtype not in ("f32", "bf16"):
        raise ValueError(f"scratch_dtype must be 'f32' or 'bf16', "
                         f"got {scratch_dtype!r}")
    return 2 if scratch_dtype == "bf16" else itemsize


def cheb_sweep_vmem_bytes(A: BlockELL, n: int, eta: int, K: int,
                          batch: int = 1, itemsize: int = 4,
                          scratch_dtype: Optional[str] = None) -> int:
    """VMEM footprint model for one `cheb_sweep` launch.

    Everything the persistent sweep pins on-chip at once, recomputed from
    the *actual* buffer dtypes: the three iterates (t_{k-1}, t_{k-2},
    P t_{k-1}), the x operand and the streamed Block-ELL blocks at the
    scratch width (2 B under ``scratch_dtype="bf16"``, else `itemsize`);
    the (B, eta, n) accumulator output, the (K+1, eta) coefficient table
    at the wide `itemsize`; int32 column indices.  At f32 this is the
    original ``(3 + eta) * B * n * 4B`` (+ B*n for x) model; under bf16
    the guarded footprint roughly halves, so `ops.fused_cheb_sweep`'s
    budget comparison admits ~2x larger (B, n, eta) tiles on the
    single-launch path.
    """
    sb = _scratch_itemsize(scratch_dtype, itemsize)
    iterates = 3 * batch * n * sb + eta * batch * n * itemsize
    operand = batch * n * sb
    structure = (int(np.prod(A.blocks.shape)) * sb
                 + int(np.prod(A.indices.shape)) * 4)
    table = (K + 1) * eta * itemsize
    return iterates + operand + structure + table


def _per_order_cheb(A: BlockELL, x: Array, coeffs: Array, lmax: float,
                    use_pallas: Optional[bool]) -> Array:
    """Per-order fallback: one SpMV + one `cheb_step` launch per order."""

    def mv(t):
        return spmv(A, t, use_pallas=use_pallas)

    return _cheb_recurrence_loop(mv, x, coeffs, lmax, use_pallas)


def fused_cheb_sweep(
    A: BlockELL,
    x: Array,
    coeffs: Union[Array, np.ndarray],
    lmax: float,
    use_pallas: Optional[bool] = None,
    vmem_budget: Optional[int] = None,
    scratch_dtype: Optional[str] = None,
) -> Array:
    """Phi_tilde x with the single-launch persistent sweep.

    x: (..., padded_n) at A's Block-ELL padded size; coeffs: (eta, K+1)
    (or (K+1,)).  Returns (..., eta, padded_n).  On the kernel path the
    whole K-order recurrence is ONE `pallas_call` (`kernels.cheb_sweep`)
    with iterates pinned in VMEM, guarded by
    :func:`cheb_sweep_vmem_bytes` against `vmem_budget` (default
    :data:`DEFAULT_SWEEP_VMEM_BUDGET`) — oversized problems fall back to
    the per-order `cheb_step` path (logged at INFO).  The reference path
    runs `ref.cheb_sweep_ref`, the same recurrence as one unrolled trace.

    scratch_dtype: None/"f32" or "bf16" — the mixed-precision kernel mode
    (`cheb_sweep.SCRATCH_DTYPES`); the footprint guard recomputes from
    the actual scratch width, so bf16 admits ~2x larger tiles.
    """
    use, interp = _resolve(use_pallas)
    sdt = scratch_dtype or "f32"
    c = jnp.atleast_2d(jnp.asarray(coeffs, dtype=x.dtype))
    eta, K1 = c.shape
    K = K1 - 1
    alpha = float(lmax) / 2.0
    if use:
        budget = DEFAULT_SWEEP_VMEM_BUDGET if vmem_budget is None \
            else int(vmem_budget)
        n = x.shape[-1]
        batch = max(1, x.size // n)
        need = cheb_sweep_vmem_bytes(A, n, eta, K, batch, scratch_dtype=sdt)
        if K < 2:
            return _per_order_cheb(A, x, c, lmax, use_pallas)
        if need > budget:
            logger.info(
                "cheb_sweep: VMEM footprint %d B exceeds budget %d B "
                "(n=%d, eta=%d, K=%d, B=%d) — falling back to the "
                "per-order cheb_step path", need, budget, n, eta, K, batch)
            return _per_order_cheb(A, x, c, lmax, use_pallas)
        return cheb_sweep(A.blocks, A.indices, x, c, alpha=alpha,
                          interpret=interp, scratch_dtype=sdt)
    return ref.cheb_sweep_ref(A.blocks, A.indices, x, c, alpha=alpha)


def fused_cheb_recurrence(
    matvec,
    x: Array,
    coeffs: Union[Array, np.ndarray],
    lmax: float,
    use_pallas: Optional[bool] = None,
) -> Array:
    """Fused shifted-Chebyshev recurrence over an arbitrary matvec.

    The three-term recurrence of Algorithm 1 with the per-order AXPYs fused
    into the `cheb_step` Pallas kernel (one HBM round-trip per order instead
    of four).  `matvec` applies P along the last axis of the iterate,
    broadcasting over leading batch dims; it may contain collectives — the
    `pallas_halo` backend passes a halo-exchanging matvec and runs this
    whole function inside a shard_map, where `x` is the per-shard block.

    Single-launch upgrade: a matvec tagged with ``mv.block_ell = A`` (a
    purely local Block-ELL product, no collectives) routes the whole loop
    to :func:`fused_cheb_sweep` — one kernel launch for all K orders,
    VMEM-guarded with a per-order fallback.  The `pallas` backend tags its
    matvec always; `pallas_halo` only on a 1-shard mesh, where the halo
    exchange is a no-op.  An optional ``mv.vmem_budget`` overrides the
    sweep budget, and an optional ``mv.sweep_dtype`` ("bf16") selects the
    mixed-precision scratch mode of `cheb_sweep`.

    x: (..., n) — any n; `cheb_step` pads its tiles to the 128 lane width
    internally, and leading batch dims take the batched tile paths (one
    structure sweep / kernel launch per order for the whole batch).
    coeffs: (eta, K+1) (or (K+1,), treated as eta=1).
    Returns (..., eta, n).
    """
    A_local = getattr(matvec, "block_ell", None)
    if A_local is not None:
        n_logical = x.shape[-1]
        out = fused_cheb_sweep(
            A_local, pad_trailing(x, A_local.padded_n), coeffs, lmax,
            use_pallas=use_pallas,
            vmem_budget=getattr(matvec, "vmem_budget", None),
            scratch_dtype=getattr(matvec, "sweep_dtype", None))
        return out[..., :n_logical]
    return _cheb_recurrence_loop(matvec, x, coeffs, lmax, use_pallas)


def _cheb_recurrence_loop(
    matvec,
    x: Array,
    coeffs: Union[Array, np.ndarray],
    lmax: float,
    use_pallas: Optional[bool] = None,
) -> Array:
    """The per-order recurrence loop (one matvec + one fused step/order).

    Supports the dual-signature stateful-matvec protocol of
    `core.chebyshev._stateful_matvec` (the int8 error-feedback halo
    exchange): a matvec exposing ``init_state(x)`` threads its state
    through the scan carry; plain matvecs get an empty-state shim.
    """
    use, interp = _resolve(use_pallas)
    from ..core.chebyshev import _stateful_matvec

    c = jnp.atleast_2d(jnp.asarray(coeffs, dtype=x.dtype))
    K = c.shape[1] - 1
    alpha = float(lmax) / 2.0

    t0 = x
    acc = 0.5 * c[:, 0:1] * x[..., None, :]
    if K == 0:
        return acc
    mv2, st = _stateful_matvec(matvec, x)
    px, st = mv2(x, st)
    t1 = px / alpha - x
    acc = acc + c[:, 1:2] * t1[..., None, :]
    if K == 1:
        return acc

    def body(carry, ck):
        t_km1, t_km2, acc, st = carry
        pt, st = mv2(t_km1, st)
        if use:
            tk, acc = cheb_step(pt, t_km1, t_km2, acc, ck,
                                alpha=alpha, interpret=interp)
        else:
            tk, acc = ref.cheb_step_ref(pt, t_km1, t_km2, acc, ck, alpha=alpha)
        return (tk, t_km1, acc, st), None

    (_, _, acc, _), _ = jax.lax.scan(body, (t1, t0, acc, st), c[:, 2:].T)
    return acc


def fused_cheb_apply(
    A: BlockELL,
    x: Array,
    coeffs: Union[Array, np.ndarray],
    lmax: float,
    use_pallas: Optional[bool] = None,
    *,
    sweep: Optional[bool] = None,
    vmem_budget: Optional[int] = None,
    scratch_dtype: Optional[str] = None,
) -> Array:
    """Phi_tilde x with the SpMV + fused-step kernels (Algorithm 1 on TPU).

    x: (..., padded_n), last axis matching A's Block-ELL padding; any
    padded_n works (the fused step kernel pads its tiles to the 128 lane
    width internally) and leading batch dims share the K structure sweeps.
    Returns (..., eta, padded_n).

    sweep: None (default) routes through the single-launch
    :func:`fused_cheb_sweep` (which itself guards on the VMEM budget and
    falls back to the per-order path); False forces the per-order
    SpMV + `cheb_step` loop — the benchmark baseline.
    scratch_dtype: the sweep path's mixed-precision mode ("bf16" halves
    the iterate/operand/structure VMEM, f32 accumulator) — ignored on
    the per-order path.
    """
    if sweep is None or sweep:
        return fused_cheb_sweep(A, x, coeffs, lmax, use_pallas=use_pallas,
                                vmem_budget=vmem_budget,
                                scratch_dtype=scratch_dtype)
    return _per_order_cheb(
        A, x, jnp.atleast_2d(jnp.asarray(coeffs, dtype=x.dtype)), lmax,
        use_pallas)


def flash_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    use_pallas: Optional[bool] = None,
) -> Array:
    """Flash attention (LM substrate): Pallas kernel on TPU, jnp oracle on
    CPU.  q: (B, Hq, S, D); k/v: (B, Hkv, S, D) with Hkv | Hq (GQA)."""
    use, interp = _resolve(use_pallas)
    if use:
        return _flash(q, k, v, causal=causal, scale=scale,
                      block_q=block_q, block_k=block_k, interpret=interp)
    return ref.attention_ref(q, k, v, causal=causal, scale=scale)


def jacobi_update(
    qx: Array,
    x: Array,
    x_prev: Array,
    y: Array,
    inv_d: Array,
    *,
    w,
    s,
    use_pallas: Optional[bool] = None,
) -> Array:
    """One fused (accelerated-)Jacobi round after the matvec ``qx = Q @ x``:

        x_next = w * (x + inv_d * (y - qx)) - s * x_prev

    (w = 1, s = 0 is the plain Jacobi sweep of Eq. (24); the Eq. (25)
    acceleration weights vary per iteration and may be traced scalars).
    The Section-V analog of `cheb_step`: five elementwise operands fused
    into one HBM round-trip per solver round.  Shapes as in
    :func:`repro.kernels.jacobi_step.jacobi_step`; complex iterates (none
    in the Jacobi solvers — ARMA carries its own real [Re, Im] stack) fall
    back to the jnp oracle.
    """
    use, interp = _resolve(use_pallas)
    if use and not jnp.iscomplexobj(x):
        return jacobi_step(qx, x, x_prev, y, inv_d, w=w, s=s,
                           interpret=interp)
    return ref.jacobi_step_ref(qx, x, x_prev, y, inv_d, w=w, s=s)


def jacobi_sweep_vmem_bytes(A: BlockELL, n: int, batch: int = 1,
                            itemsize: int = 4,
                            scratch_dtype: Optional[str] = None) -> int:
    """VMEM footprint model for one `jacobi_sweep` launch, from the actual
    buffer dtypes: x_prev, the SpMV product and the Horner accumulator at
    the scratch width (2 B under ``scratch_dtype="bf16"``), the x iterate,
    b and D^{-1} (three wide (B, n) buffers) plus the streamed Block-ELL
    structure at the scratch width.  At f32 this is the original
    six-buffer model."""
    sb = _scratch_itemsize(scratch_dtype, itemsize)
    buffers = 3 * batch * n * sb + 3 * batch * n * itemsize
    structure = (int(np.prod(A.blocks.shape)) * sb
                 + int(np.prod(A.indices.shape)) * 4)
    return buffers + structure


def fused_jacobi_sweep(
    A: BlockELL,
    b: Array,
    inv_d: Array,
    den: Sequence[float],
    weights,
    *,
    x0: Optional[Array] = None,
    use_pallas: Optional[bool] = None,
    vmem_budget: Optional[int] = None,
    scratch_dtype: Optional[str] = None,
) -> Array:
    """Whole (accelerated-)Jacobi solve of den(P) x = b, one launch.

    The Section-V counterpart of :func:`fused_cheb_sweep`: all n_iters
    rounds of Eq. (24)/(25) — deg(den) Block-ELL SpMVs per round (Horner)
    plus the fused five-operand update — run inside one `jacobi_sweep`
    kernel with the iterates pinned in VMEM.  b / x0: (..., n) at any n
    (padded to A's Block-ELL size internally, cropped on return); inv_d
    broadcastable, zeros on padded/virtual rows.  weights: (n_iters, 2)
    host-side (w_t, s_t) schedule (`core.jacobi.jacobi_weights` /
    `cheb_jacobi_weights`).  The same VMEM-budget guard and per-order
    fallback (one `jacobi_step` launch per round, logged at INFO) as the
    Chebyshev sweep apply.  ``scratch_dtype="bf16"`` selects the
    mixed-precision kernel mode (the guard recomputes from the actual
    scratch width).
    """
    use, interp = _resolve(use_pallas)
    sdt = scratch_dtype or "f32"
    n_logical = b.shape[-1]
    total = A.padded_n
    bp = pad_trailing(jnp.asarray(b), total)
    invdp = pad_trailing(jnp.asarray(inv_d), total)
    x0p = (jnp.zeros_like(bp) if x0 is None
           else pad_trailing(jnp.asarray(x0), total))
    den = tuple(float(c) for c in den)
    ws = np.asarray(weights, dtype=np.float64)

    if use:
        budget = DEFAULT_SWEEP_VMEM_BUDGET if vmem_budget is None \
            else int(vmem_budget)
        batch = max(1, bp.size // total)
        need = jacobi_sweep_vmem_bytes(A, total, batch, scratch_dtype=sdt)
        if need > budget:
            logger.info(
                "jacobi_sweep: VMEM footprint %d B exceeds budget %d B "
                "(n=%d, B=%d) — falling back to the per-round jacobi_step "
                "path", need, budget, total, batch)
        else:
            out = jacobi_sweep(A.blocks, A.indices, bp, invdp, ws, x0p,
                               den=den, interpret=interp, scratch_dtype=sdt)
            return out[..., :n_logical]
        # per-round fallback: one SpMV chain + one fused update per round

        def body(carry, ws_row):
            x, x_prev = carry
            h = den[-1] * x
            for c in den[-2::-1]:
                h = spmv(A, h, use_pallas=use_pallas) + c * x
            x_next = jacobi_update(h, x, x_prev, bp, invdp,
                                   w=ws_row[0], s=ws_row[1],
                                   use_pallas=use_pallas)
            return (x_next, x), None

        (x_final, _), _ = jax.lax.scan(
            body, (x0p, x0p), jnp.asarray(ws, bp.dtype))
        return x_final[..., :n_logical]
    out = ref.jacobi_sweep_ref(A.blocks, A.indices, bp, invdp, ws, x0p,
                               den=den)
    return out[..., :n_logical]


def ista_update(
    a: Array,
    phi_y: Array,
    gram_a: Array,
    thresh: Array,
    gamma: float,
    use_pallas: Optional[bool] = None,
) -> Array:
    """One fused ISTA update (Algorithm 3 line 5 + Eq. (32) shrinkage):
    ``soft_threshold(a + gamma * (phi_y - gram_a), thresh)`` in a single
    kernel pass.  a/phi_y/gram_a: (..., eta, N); thresh: (eta,) or (eta, 1)
    or any shape broadcastable against a.  Batched inputs (ndim > 2) use
    the elementwise jnp path — shrinkage is memory-bound either way."""
    use, interp = _resolve(use_pallas)
    if thresh.ndim == 1:
        thresh = thresh[:, None]
    if use and a.ndim == 2 and thresh.shape == (a.shape[0], 1):
        return ista_shrink(a, phi_y, gram_a, thresh, gamma=gamma,
                           interpret=interp)
    return ref.ista_shrink_ref(a, phi_y, gram_a, thresh, gamma=gamma)


def pad_trailing(x: Array, total: int) -> Array:
    """Zero-pad the last (vertex) axis up to the absolute size `total`;
    leading batch / eta axes pass through untouched.  The one padding
    primitive every execution backend shares under the (..., N) contract.
    """
    pad = total - x.shape[-1]
    if pad == 0:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])


def pad_for_kernels(x: Array, multiple: int = 1024) -> Array:
    """Zero-pad the last axis up to `multiple` (kernel tile alignment).

    Callers that hold the logical size are responsible for stripping the
    padding from outputs; the execution backends do this internally.
    """
    n = x.shape[-1]
    return pad_trailing(x, n + (-n) % multiple)
