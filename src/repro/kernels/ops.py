"""Public jit'd wrappers around the Pallas kernels.

Dispatch policy: on TPU the Pallas kernels run natively; on CPU (this
container) `use_pallas=True` runs them under interpret=True (the kernel body
executed in Python — used by the kernel test sweeps), and the default takes
the pure-jnp reference path so smoke tests and benchmarks stay fast.
"""
from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core.graph import BlockELL
from . import ref
from .bcsr_spmv import block_ell_spmv
from .cheb_step import cheb_step
from .flash_attention import flash_attention as _flash
from .soft_threshold import ista_shrink

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(use_pallas: Optional[bool]):
    """Returns (use_pallas, interpret)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    return use_pallas, (use_pallas and not _on_tpu())


def spmv(A: BlockELL, x: Array, use_pallas: Optional[bool] = None) -> Array:
    """Block-ELL y = A @ x on the padded vector (padded_n,)."""
    use, interp = _resolve(use_pallas)
    if use:
        return block_ell_spmv(A.blocks, A.indices, x, interpret=interp)
    return ref.block_ell_spmv_ref(A.blocks, A.indices, x)


def fused_cheb_apply(
    A: BlockELL,
    x: Array,
    coeffs: Union[Array, np.ndarray],
    lmax: float,
    use_pallas: Optional[bool] = None,
) -> Array:
    """Phi_tilde x with the SpMV + fused-step kernels (Algorithm 1 on TPU).

    x: (padded_n,) matching A's Block-ELL padding; any padded_n works (the
    fused step kernel pads its tiles to the 128 lane width internally).
    Returns (eta, padded_n).
    """
    use, interp = _resolve(use_pallas)
    c = jnp.atleast_2d(jnp.asarray(coeffs, dtype=x.dtype))
    eta, Kp1 = c.shape
    K = Kp1 - 1
    alpha = float(lmax) / 2.0

    def mv(t):
        return spmv(A, t, use_pallas=use_pallas)

    t0 = x
    acc = 0.5 * c[:, 0:1] * x[None, :]
    if K == 0:
        return acc
    t1 = mv(x) / alpha - x
    acc = acc + c[:, 1:2] * t1[None, :]
    if K == 1:
        return acc

    def body(carry, ck):
        t_km1, t_km2, acc = carry
        pt = mv(t_km1)
        if use:
            tk, acc = cheb_step(pt, t_km1, t_km2, acc, ck,
                                alpha=alpha, interpret=interp)
        else:
            tk, acc = ref.cheb_step_ref(pt, t_km1, t_km2, acc, ck, alpha=alpha)
        return (tk, t_km1, acc), None

    (_, _, acc), _ = jax.lax.scan(body, (t1, t0, acc), c[:, 2:].T)
    return acc


def flash_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    use_pallas: Optional[bool] = None,
) -> Array:
    use, interp = _resolve(use_pallas)
    if use:
        return _flash(q, k, v, causal=causal, scale=scale,
                      block_q=block_q, block_k=block_k, interpret=interp)
    return ref.attention_ref(q, k, v, causal=causal, scale=scale)


def ista_update(
    a: Array,
    phi_y: Array,
    gram_a: Array,
    thresh: Array,
    gamma: float,
    use_pallas: Optional[bool] = None,
) -> Array:
    use, interp = _resolve(use_pallas)
    if thresh.ndim == 1:
        thresh = thresh[:, None]
    if use:
        return ista_shrink(a, phi_y, gram_a, thresh, gamma=gamma,
                           interpret=interp)
    return ref.ista_shrink_ref(a, phi_y, gram_a, thresh, gamma=gamma)


def pad_for_kernels(x: Array, multiple: int = 1024) -> Array:
    n = x.shape[-1]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, widths)
