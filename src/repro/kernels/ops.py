"""Public jit'd wrappers around the Pallas kernels.

This module is the single dispatch point between the Pallas TPU kernels
(`bcsr_spmv.block_ell_spmv`, `cheb_step.cheb_step`, ...) and their pure-jnp
oracles in :mod:`repro.kernels.ref`.  Everything above it — the `pallas`
and `pallas_halo` execution backends, the benchmarks, the tests — calls
these wrappers and never touches `pallas_call` directly.

Dispatch policy: on TPU the Pallas kernels run natively; on CPU (this
container) `use_pallas=True` runs them under interpret=True (the kernel body
executed in Python — used by the kernel test sweeps), and the default takes
the pure-jnp reference path so smoke tests and benchmarks stay fast.

Sharded use: :func:`fused_cheb_recurrence` is the matvec-generic form of the
fused recurrence.  The `pallas_halo` backend calls it *inside* a shard_map
with a halo-exchanging matvec over the per-shard Block-ELL tiles, so the
same fused Chebyshev-step kernel serves both the single-device and the
sharded hot path (per-shard sizes need not be 128-multiples — `cheb_step`
pads its tiles internally).
"""
from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core.graph import BlockELL
from . import ref
from .bcsr_spmv import block_ell_spmv, block_ell_spmv_batched
from .cheb_step import cheb_step
from .jacobi_step import jacobi_step
from .flash_attention import flash_attention as _flash
from .soft_threshold import ista_shrink

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(use_pallas: Optional[bool]):
    """Returns (use_pallas, interpret)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    return use_pallas, (use_pallas and not _on_tpu())


def spmv(A: BlockELL, x: Array, use_pallas: Optional[bool] = None) -> Array:
    """Block-ELL y = A @ x on padded signals (..., padded_n).

    The Algorithm-1 hot loop: one call per Chebyshev order, cost
    proportional to the number of non-zero blocks (the paper's O(|E|)
    per-order cost).  Leading batch dims ride one sweep of the sparsity
    structure (`block_ell_spmv_batched`: each Block-ELL block is loaded
    once for the whole batch, not once per signal).  `x`'s last axis must
    already be at `A.padded_n`; use `fused_cheb_apply` / the `pallas`
    backend if you want padding handled for you.
    """
    use, interp = _resolve(use_pallas)
    if use:
        if x.ndim > 1:
            return block_ell_spmv_batched(A.blocks, A.indices, x,
                                          interpret=interp)
        return block_ell_spmv(A.blocks, A.indices, x, interpret=interp)
    return ref.block_ell_spmv_ref(A.blocks, A.indices, x)


def fused_cheb_recurrence(
    matvec,
    x: Array,
    coeffs: Union[Array, np.ndarray],
    lmax: float,
    use_pallas: Optional[bool] = None,
) -> Array:
    """Fused shifted-Chebyshev recurrence over an arbitrary matvec.

    The three-term recurrence of Algorithm 1 with the per-order AXPYs fused
    into the `cheb_step` Pallas kernel (one HBM round-trip per order instead
    of four).  `matvec` applies P along the last axis of the iterate,
    broadcasting over leading batch dims; it may contain collectives — the
    `pallas_halo` backend passes a halo-exchanging matvec and runs this
    whole function inside a shard_map, where `x` is the per-shard block.

    x: (..., n) — any n; `cheb_step` pads its tiles to the 128 lane width
    internally, and leading batch dims take the batched tile paths (one
    structure sweep / kernel launch per order for the whole batch).
    coeffs: (eta, K+1) (or (K+1,), treated as eta=1).
    Returns (..., eta, n).
    """
    use, interp = _resolve(use_pallas)
    c = jnp.atleast_2d(jnp.asarray(coeffs, dtype=x.dtype))
    K = c.shape[1] - 1
    alpha = float(lmax) / 2.0

    t0 = x
    acc = 0.5 * c[:, 0:1] * x[..., None, :]
    if K == 0:
        return acc
    t1 = matvec(x) / alpha - x
    acc = acc + c[:, 1:2] * t1[..., None, :]
    if K == 1:
        return acc

    def body(carry, ck):
        t_km1, t_km2, acc = carry
        pt = matvec(t_km1)
        if use:
            tk, acc = cheb_step(pt, t_km1, t_km2, acc, ck,
                                alpha=alpha, interpret=interp)
        else:
            tk, acc = ref.cheb_step_ref(pt, t_km1, t_km2, acc, ck, alpha=alpha)
        return (tk, t_km1, acc), None

    (_, _, acc), _ = jax.lax.scan(body, (t1, t0, acc), c[:, 2:].T)
    return acc


def fused_cheb_apply(
    A: BlockELL,
    x: Array,
    coeffs: Union[Array, np.ndarray],
    lmax: float,
    use_pallas: Optional[bool] = None,
) -> Array:
    """Phi_tilde x with the SpMV + fused-step kernels (Algorithm 1 on TPU).

    x: (..., padded_n), last axis matching A's Block-ELL padding; any
    padded_n works (the fused step kernel pads its tiles to the 128 lane
    width internally) and leading batch dims share the K structure sweeps.
    Returns (..., eta, padded_n).
    """

    def mv(t):
        return spmv(A, t, use_pallas=use_pallas)

    return fused_cheb_recurrence(mv, x, coeffs, lmax, use_pallas=use_pallas)


def flash_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    use_pallas: Optional[bool] = None,
) -> Array:
    """Flash attention (LM substrate): Pallas kernel on TPU, jnp oracle on
    CPU.  q: (B, Hq, S, D); k/v: (B, Hkv, S, D) with Hkv | Hq (GQA)."""
    use, interp = _resolve(use_pallas)
    if use:
        return _flash(q, k, v, causal=causal, scale=scale,
                      block_q=block_q, block_k=block_k, interpret=interp)
    return ref.attention_ref(q, k, v, causal=causal, scale=scale)


def jacobi_update(
    qx: Array,
    x: Array,
    x_prev: Array,
    y: Array,
    inv_d: Array,
    *,
    w,
    s,
    use_pallas: Optional[bool] = None,
) -> Array:
    """One fused (accelerated-)Jacobi round after the matvec ``qx = Q @ x``:

        x_next = w * (x + inv_d * (y - qx)) - s * x_prev

    (w = 1, s = 0 is the plain Jacobi sweep of Eq. (24); the Eq. (25)
    acceleration weights vary per iteration and may be traced scalars).
    The Section-V analog of `cheb_step`: five elementwise operands fused
    into one HBM round-trip per solver round.  Shapes as in
    :func:`repro.kernels.jacobi_step.jacobi_step`; complex iterates (none
    in the Jacobi solvers — ARMA carries its own real [Re, Im] stack) fall
    back to the jnp oracle.
    """
    use, interp = _resolve(use_pallas)
    if use and not jnp.iscomplexobj(x):
        return jacobi_step(qx, x, x_prev, y, inv_d, w=w, s=s,
                           interpret=interp)
    return ref.jacobi_step_ref(qx, x, x_prev, y, inv_d, w=w, s=s)


def ista_update(
    a: Array,
    phi_y: Array,
    gram_a: Array,
    thresh: Array,
    gamma: float,
    use_pallas: Optional[bool] = None,
) -> Array:
    """One fused ISTA update (Algorithm 3 line 5 + Eq. (32) shrinkage):
    ``soft_threshold(a + gamma * (phi_y - gram_a), thresh)`` in a single
    kernel pass.  a/phi_y/gram_a: (..., eta, N); thresh: (eta,) or (eta, 1)
    or any shape broadcastable against a.  Batched inputs (ndim > 2) use
    the elementwise jnp path — shrinkage is memory-bound either way."""
    use, interp = _resolve(use_pallas)
    if thresh.ndim == 1:
        thresh = thresh[:, None]
    if use and a.ndim == 2 and thresh.shape == (a.shape[0], 1):
        return ista_shrink(a, phi_y, gram_a, thresh, gamma=gamma,
                           interpret=interp)
    return ref.ista_shrink_ref(a, phi_y, gram_a, thresh, gamma=gamma)


def pad_trailing(x: Array, total: int) -> Array:
    """Zero-pad the last (vertex) axis up to the absolute size `total`;
    leading batch / eta axes pass through untouched.  The one padding
    primitive every execution backend shares under the (..., N) contract.
    """
    pad = total - x.shape[-1]
    if pad == 0:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])


def pad_for_kernels(x: Array, multiple: int = 1024) -> Array:
    """Zero-pad the last axis up to `multiple` (kernel tile alignment).

    Callers that hold the logical size are responsible for stripping the
    padding from outputs; the execution backends do this internally.
    """
    n = x.shape[-1]
    return pad_trailing(x, n + (-n) % multiple)
