"""Tiled (flash) attention Pallas kernel for the LM substrate.

Online-softmax attention with causal masking and GQA head grouping. Grid is
(batch, q_heads, q_blocks, k_blocks) with the k axis innermost: the output
block is revisited while running max / normalizer / accumulator live in VMEM
scratch. Fully-masked k blocks are skipped under the causal predicate, so
the causal kernel does ~half the MXU work of the dense one.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, scale, causal, block_q, block_k):
    i = pl.program_id(2)
    j = pl.program_id(3)
    nj = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    should_compute = True
    if causal:
        # block of k cols strictly above the last q row contributes nothing
        should_compute = j * block_k <= i * block_q + block_q - 1

    @pl.when(should_compute)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        if causal:
            rows = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(cols <= rows, s, _NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = corr * l_prev + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(j == nj - 1)
    def _finalize():
        l = l_scr[...]
        denom = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> Array:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D) with Hq % Hkv == 0.

    Sequence lengths must be multiples of the block sizes (caller pads).
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, "GQA requires Hq % Hkv == 0"
    group = hq // hkv
    assert sq % block_q == 0 and sk % block_k == 0
    scale = scale if scale is not None else 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal,
        block_q=block_q, block_k=block_k,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, hq, sq // block_q, sk // block_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
