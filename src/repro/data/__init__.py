from .pipeline import SyntheticLMData, graph_signal_batch

__all__ = ["SyntheticLMData", "graph_signal_batch"]
