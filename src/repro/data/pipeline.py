"""Deterministic synthetic data pipelines.

The LM stream is stateless-per-step (batch = f(seed, step)) so a restarted
job resumes bit-identically from a checkpoint — the property the fault-
tolerance integration test asserts. Sequences are noisy modular arithmetic
progressions: learnable structure so smoke-training shows loss decrease.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SyntheticLMData:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.05
    n_vision_tokens: int = 0
    d_model: int = 0
    encoder_seq: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState((self.seed * 1_000_003 + step) % 2**31)
        B, S, V = self.global_batch, self.seq_len, self.vocab_size
        start = rng.randint(0, V, size=(B, 1))
        stride = rng.randint(1, 7, size=(B, 1))
        toks = (start + stride * np.arange(S)[None, :]) % V
        flips = rng.rand(B, S) < self.noise
        toks = np.where(flips, rng.randint(0, V, size=(B, S)), toks)
        batch: Dict[str, np.ndarray] = {
            "tokens": toks.astype(np.int32),
            "labels": toks.astype(np.int32),
        }
        if self.n_vision_tokens:
            batch["vision_embeds"] = rng.randn(
                B, self.n_vision_tokens, self.d_model
            ).astype(np.float32)
        if self.encoder_seq:
            batch["encoder_frames"] = rng.randn(
                B, self.encoder_seq, self.d_model
            ).astype(np.float32)
        return batch

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


def graph_signal_batch(key: Array, coords: Array, kind: str = "smooth"):
    """Signals from the paper's experiments.

    'smooth'    — Section IV-D: h_n = n_x^2 + n_y^2 - 1.
    'piecewise' — Section VI: two smooth pieces split along n_y = 1 - n_x.
    'uniform'   — Section V-E: iid Uniform[-10, 10].
    """
    nx, ny = coords[:, 0], coords[:, 1]
    if kind == "smooth":
        return nx**2 + ny**2 - 1.0
    if kind == "piecewise":
        upper = -2.0 * nx + 0.5
        lower = nx**2 + ny**2 + 0.5
        return jnp.where(ny >= 1.0 - nx, upper, lower)
    if kind == "uniform":
        return jax.random.uniform(key, (coords.shape[0],), minval=-10.0,
                                  maxval=10.0)
    raise ValueError(kind)
