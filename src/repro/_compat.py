"""Version compatibility layer for the jax API surface this repo targets.

The codebase (and the multi-device test payloads) are written against the
jax >= 0.6 spellings — ``jax.shard_map``, ``jax.sharding.AxisType``,
``jax.make_mesh(..., axis_types=...)``, ``jax.lax.axis_size`` and the
``check_vma=`` keyword.  Older jaxlibs (this container ships 0.4.x) expose
the same functionality under the pre-stabilisation names
(``jax.experimental.shard_map``, ``check_rep=``, no axis types).  Importing
:mod:`repro` installs forward-compatible aliases for whichever of these are
missing, so the one modern spelling works everywhere.  On a modern jax this
module is a no-op.
"""
from __future__ import annotations

import enum
import functools

import jax


def _install_shard_map() -> None:
    if hasattr(jax, "shard_map"):
        return
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    @functools.wraps(_legacy_shard_map)
    def shard_map(f=None, *, mesh, in_specs, out_specs, check_vma=None,
                  check_rep=None, **kwargs):
        if check_rep is None:
            # modern `check_vma` maps onto the old `check_rep` machinery
            check_rep = bool(check_vma) if check_vma is not None else False
        bound = functools.partial(
            _legacy_shard_map, mesh=mesh, in_specs=in_specs,
            out_specs=out_specs, check_rep=check_rep, **kwargs)
        return bound if f is None else bound(f)

    jax.shard_map = shard_map


def _install_axis_type() -> None:
    import jax.sharding as _sharding

    if hasattr(_sharding, "AxisType"):
        return

    class AxisType(enum.Enum):
        """Stand-in for jax.sharding.AxisType (jax >= 0.6)."""

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    _sharding.AxisType = AxisType


def _install_make_mesh() -> None:
    import inspect

    if getattr(jax.make_mesh, "_repro_compat", False):
        return
    try:
        params = inspect.signature(jax.make_mesh).parameters
    except (TypeError, ValueError):  # pragma: no cover
        return
    if "axis_types" in params:
        return
    _legacy_make_mesh = jax.make_mesh

    @functools.wraps(_legacy_make_mesh)
    def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
        del axis_types  # pre-0.6 meshes have no axis-type concept
        return _legacy_make_mesh(axis_shapes, axis_names, devices=devices)

    make_mesh._repro_compat = True
    jax.make_mesh = make_mesh


def _install_axis_size() -> None:
    if hasattr(jax.lax, "axis_size"):
        return
    from jax._src import core as _core

    def axis_size(axis_name) -> int:
        """Static size of a named mesh axis (inside shard_map)."""
        return int(_core.axis_frame(axis_name))

    jax.lax.axis_size = axis_size


def install() -> None:
    _install_shard_map()
    _install_axis_type()
    _install_make_mesh()
    _install_axis_size()


install()
