"""AdamW with global-norm clipping; optimizer state mirrors the param tree so
param PartitionSpecs apply leaf-for-leaf (ZeRO-1 shards these same leaves
over the 'zero' logical axis via dist/sharding.py)."""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


class AdamWState(NamedTuple):
    step: Array
    m: Dict
    v: Dict


def adamw_init(params: Dict) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree_util.tree_map(jnp.copy, zeros))


def clip_by_global_norm(grads: Dict, max_norm: float) -> Tuple[Dict, Array]:
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gn


def adamw_update(
    grads: Dict,
    state: AdamWState,
    params: Dict,
    lr: float = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
) -> Tuple[Dict, AdamWState]:
    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m_new = b1 * m + (1.0 - b1) * g
        v_new = b2 * v + (1.0 - b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)
