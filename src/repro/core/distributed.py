"""DEPRECATED shim — the sharded Algorithm 1/2/3 implementations live in
the :mod:`repro.dist.backends` registry; this module only re-exports the
``halo`` / ``allgather`` free functions for old callers.

Prefer the unified plan API, which dispatches through the registry
(``repro.dist.available_backends()`` lists every strategy — ``dense``,
``pallas``, ``halo``, ``pallas_halo``, ``allgather``, plus anything
registered out of tree via ``repro.dist.register_backend``):

    op = repro.dist.GraphOperator(P, multipliers, lmax=lmax, K=K)
    plan = op.plan(backend="pallas_halo", mesh=mesh)
    plan.apply(f) / plan.apply_adjoint(a) / plan.solve_lasso(y, mu)

The old free functions keep working from here (same signatures, including
the caller-side padding contract) but new code should go through
``plan()`` — newer backends such as ``pallas_halo`` have **no** free-
function form and are reachable only via the registry.  See
docs/ARCHITECTURE.md for the registry contract.
"""
from __future__ import annotations

import warnings

from ..dist.backends.allgather import (  # noqa: F401
    _allgather_matvec,
    dist_cheb_apply_allgather,
)
from ..dist.backends.halo import (  # noqa: F401
    BandedPartition,
    _halo_matvec,
    dist_cheb_apply,
    dist_cheb_apply_adjoint,
    dist_cheb_apply_gram,
    dist_lasso,
    halo_bytes_per_apply,
    pad_signal,
    partition_banded,
    shard_map,
)

__all__ = [
    "BandedPartition",
    "dist_cheb_apply",
    "dist_cheb_apply_adjoint",
    "dist_cheb_apply_allgather",
    "dist_cheb_apply_gram",
    "dist_lasso",
    "halo_bytes_per_apply",
    "pad_signal",
    "partition_banded",
]

warnings.warn(
    "repro.core.distributed is deprecated; use repro.dist "
    "(GraphOperator.plan(backend='halo'|'allgather', mesh=...))",
    DeprecationWarning,
    stacklevel=2,
)
