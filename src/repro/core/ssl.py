"""Distributed semi-supervised / transductive classification (Section III-D).

Implements the 4-step recipe at the end of Section III-D: build the label
matrix Y, apply the optimal multiplier R (g(lambda) = tau/(tau + h(lambda)))
to each class column in a distributed-ready way (single union application on
the (N, kappa) matrix — the Chebyshev recurrence is linear so all classes
share the K communication rounds), then argmax per node.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import filters

Array = jax.Array


def label_matrix(labels: Array, mask: Array, n_classes: int) -> Array:
    """Y in R^{N x kappa}: Y_ij = 1 iff node i is labeled (mask) with class j."""
    onehot = jax.nn.one_hot(labels, n_classes, dtype=jnp.float32)
    return onehot * mask[:, None].astype(jnp.float32)


@dataclasses.dataclass
class SSLResult:
    scores: Array        # F^opt, (N, kappa)
    predictions: Array   # argmax_j F^opt_{nj}, (N,)


def semi_supervised_classify(
    P: Array,
    labels: Array,
    labeled_mask: Array,
    n_classes: int,
    h: Optional[Callable] = None,
    tau: float = 1.0,
    lmax: Optional[float] = None,
    K: int = 20,
    backend: str = "dense",
    mesh=None,
) -> SSLResult:
    """Steps 1-4 of Section III-D.

    P: PSD matrix with the graph's sparsity pattern (L, L_norm, or K-scaling).
    h: RKHS kernel spectral function (default: identity, i.e. S = P).
    backend/mesh: execution strategy for the multiplier application (any
    registered repro.dist backend; "dense" is the single-device default).
    """
    from ..dist.operator import GraphOperator

    if lmax is None:
        lam = jnp.linalg.eigvalsh(P)
        lmax = float(lam[-1]) * 1.01
    h = h or filters.power_kernel(1)
    g = filters.ssl_multiplier(h, tau)
    R = GraphOperator(P=P, multipliers=[g], lmax=lmax, K=K)
    Y = label_matrix(labels, labeled_mask, n_classes)  # (N, kappa)
    # One batched application on the class columns: every backend takes
    # (..., N) signals, so the kappa class columns ride the K communication
    # rounds together (Algorithm 1 runs once with length-kappa messages) —
    # no per-column loop on any backend.
    plan = R.plan(backend, mesh=mesh)
    F = plan.apply(Y.T)[..., 0, :].T  # (kappa, N) batch -> (N, kappa) scores
    return SSLResult(scores=F, predictions=jnp.argmax(F, axis=1))


def accuracy(result: SSLResult, labels: Array, labeled_mask: Array) -> float:
    """Accuracy over the unlabeled nodes."""
    unl = ~labeled_mask
    correct = (result.predictions == labels) & unl
    return float(jnp.sum(correct) / jnp.maximum(jnp.sum(unl), 1))
