"""Spectral graph wavelet transform multipliers (Hammond et al. [23]).

The distributed lasso of Section VI uses Phi = [h(L); g(t_1 L); ...; g(t_J L)]
— one lowpass scaling multiplier plus J bandpass wavelet multipliers. This
module reproduces the standard SGWT design (cubic-spline bandpass kernel,
log-spaced scales, Gaussian-like scaling function), matching the GSPBox
defaults the paper's experiments use.
"""
from __future__ import annotations

from typing import Callable, List, Sequence, Union

import numpy as np


def wavelet_kernel(
    alpha: float = 2.0, beta: float = 2.0, x1: float = 1.0, x2: float = 2.0
) -> Callable:
    """Bandpass kernel g: monic power ascent, cubic-spline belly, power decay.

    g(x) = x1^{-alpha} x^alpha            for x <  x1
           cubic spline s(x)              for x1 <= x <= x2
           x2^{beta} x^{-beta}            for x >  x2

    With the default (2, 2, 1, 2) the spline is s(x) = -5 + 11x - 6x^2 + x^3
    (the SGWT toolbox default), giving a C^1 kernel with g(x1)=g(x2)=1.
    """
    # Solve for cubic s(x)=a0+a1 x+a2 x^2+a3 x^3 matching value+slope at x1,x2.
    v1, v2 = 1.0, 1.0
    d1 = alpha / x1  # slope of x1^{-a} x^a at x1 is a/x1
    d2 = -beta / x2
    A = np.array(
        [
            [1, x1, x1**2, x1**3],
            [1, x2, x2**2, x2**3],
            [0, 1, 2 * x1, 3 * x1**2],
            [0, 1, 2 * x2, 3 * x2**2],
        ],
        dtype=np.float64,
    )
    a = np.linalg.solve(A, np.array([v1, v2, d1, d2], dtype=np.float64))

    def g(x):
        x = np.asarray(x, dtype=np.float64)
        x = np.maximum(x, 0.0)
        lo = (x / x1) ** alpha
        mid = a[0] + a[1] * x + a[2] * x**2 + a[3] * x**3
        hi = np.where(x > 0, (x2 / np.maximum(x, 1e-30)) ** beta, 0.0)
        out = np.where(x < x1, lo, np.where(x <= x2, mid, hi))
        return out

    return g


def set_scales(lmax: float, J: int, lpfactor: float = 20.0,
               x1: float = 1.0, x2: float = 2.0) -> np.ndarray:
    """Log-spaced wavelet scales t_1 > ... > t_J (SGWT sgwt_setscales)."""
    lmin = lmax / lpfactor
    smin = x1 / lmax
    smax = x2 / lmin
    return np.exp(np.linspace(np.log(smax), np.log(smin), J))


def sgwt_multipliers(
    lmax: float,
    J: int = 6,
    lpfactor: float = 20.0,
    kernel: Callable = None,
) -> List[Callable]:
    """[h, g(t_1 .), ..., g(t_J .)] — the union of Section VI, eta = J+1."""
    g = kernel or wavelet_kernel()
    scales = set_scales(lmax, J, lpfactor)
    lmin = lmax / lpfactor
    # Scaling function: gamma * exp(-(x / (0.6 lmin))^4), gamma = max_t g.
    grid = np.linspace(0.0, lmax, 4000)
    gamma = float(max(np.max(g(t * grid)) for t in scales))

    def h(x, _gamma=gamma, _l=0.6 * lmin):
        x = np.asarray(x, dtype=np.float64)
        return _gamma * np.exp(-((x / _l) ** 4))

    mults: List[Callable] = [h]
    for t in scales:
        mults.append(lambda x, _t=t: g(_t * np.asarray(x, dtype=np.float64)))
    return mults


def sgwt_operator(P, lmax: float, J: int = 6, K: int = 20,
                  lpfactor: float = 20.0):
    """The Chebyshev-approximate spectral graph wavelet frame Phi_tilde.

    Returns a :class:`repro.dist.GraphOperator` — a UnionMultiplier whose
    execution strategy is bound later via ``.plan(backend=..., mesh=...)``.
    """
    from ..dist.operator import GraphOperator

    return GraphOperator(
        P=P, multipliers=sgwt_multipliers(lmax, J, lpfactor), lmax=lmax, K=K
    )


def frame_bounds(mults: Sequence[Callable], lmax: float, n_grid: int = 4000):
    """(A, B) frame bounds: A <= sum_j g_j(lambda)^2 <= B on [0, lmax]."""
    lam = np.linspace(0.0, lmax, n_grid)
    s = np.zeros_like(lam)
    for g in mults:
        s = s + np.asarray(g(lam)) ** 2
    return float(np.min(s)), float(np.max(s))
