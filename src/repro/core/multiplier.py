"""Graph multiplier operators and unions thereof (Section II, Definition 1).

`UnionMultiplier` is the user-facing object: built from a PSD matrix P (dense
or matvec closure), a list of multiplier functions g_j, an upper bound on
lambda_max, and an approximation order K. It exposes

  .apply(f)        ~ Phi f        (Chebyshev, Algorithm 1)
  .apply_adjoint(a)~ Phi^* a      (Chebyshev, Algorithm 2)
  .apply_gram(f)   ~ Phi^*Phi f   (product coefficients, Section IV-C)
  .exact_apply(f)  = Phi f        (dense eigendecomposition oracle, Eq. (3))
  .error_bound()   = B(K) sqrt(eta)  (Prop. 4)

The exact oracle is O(N^3) and exists for validation at paper scale.
"""
from __future__ import annotations

import dataclasses
from functools import cached_property
from typing import Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from . import chebyshev as cheb

Array = jax.Array


def _as_matvec(P: Union[Array, Callable[[Array], Array]]):
    """P as a map along the *last* axis of its argument.

    The repo-wide signal contract is (..., N): matvecs contract the trailing
    vertex axis and broadcast over any leading batch dims.  Callable P must
    follow the same convention (see API.md, "Batched signals").
    """
    if callable(P):
        return P
    Pm = jnp.asarray(P)

    def mv(x: Array) -> Array:
        return jnp.einsum("ij,...j->...i", Pm, x)

    return mv


@dataclasses.dataclass(frozen=True)
class UnionMultiplier:
    """Union of eta graph multiplier operators w.r.t. a PSD matrix P."""

    P: Union[Array, Callable[[Array], Array]]
    multipliers: Sequence[Callable]
    lmax: float
    K: int = 20
    coeff_points: int = 1000

    @property
    def eta(self) -> int:
        return len(self.multipliers)

    @cached_property
    def coeffs(self) -> np.ndarray:
        return cheb.cheb_coeffs_stack(
            self.multipliers, self.K, self.lmax, self.coeff_points
        )

    @cached_property
    def matvec(self):
        return _as_matvec(self.P)

    # -- Chebyshev-approximate applications ---------------------------------
    def apply(self, f: Array) -> Array:
        """Phi_tilde f; f: (..., N) -> (..., eta, N).  Leading axes are
        batch signals sharing the K communication rounds (the recurrence is
        linear, Section III-D)."""
        out = cheb.cheb_apply(
            self.matvec, f, jnp.asarray(self.coeffs, f.dtype), self.lmax
        )
        return out

    def apply_adjoint(self, a: Array) -> Array:
        """Phi_tilde^* a; a: (..., eta, N) -> (..., N)."""
        return cheb.cheb_apply_adjoint(
            self.matvec, a, jnp.asarray(self.coeffs, a.dtype), self.lmax
        )

    def apply_gram(self, f: Array) -> Array:
        """Phi_tilde^* Phi_tilde f; f: (..., N) -> (..., N)."""
        return cheb.cheb_apply_gram(self.matvec, f, self.coeffs, self.lmax)

    # -- Exact oracle ---------------------------------------------------------
    @cached_property
    def _eig(self):
        if callable(self.P):
            raise ValueError("exact oracle needs a dense P")
        lam, U = jnp.linalg.eigh(jnp.asarray(self.P))
        return lam, U

    def exact_apply(self, f: Array) -> Array:
        """Phi f by Eq. (3) — dense eigendecomposition, validation only.

        f: (..., N) -> (..., eta, N), matching the Chebyshev `apply`."""
        lam, U = self._eig
        fhat = jnp.einsum("...i,ij->...j", f, U)  # U^T f along the last axis
        outs = []
        for g in self.multipliers:
            glam = jnp.asarray(g(np.asarray(lam)), dtype=f.dtype)
            outs.append(jnp.einsum("...j,ij->...i", glam * fhat, U))
        return jnp.stack(outs, axis=-2)

    def exact_apply_adjoint(self, a: Array) -> Array:
        """a: (..., eta, N) -> (..., N)."""
        lam, U = self._eig
        acc = None
        for j, g in enumerate(self.multipliers):
            glam = jnp.asarray(g(np.asarray(lam)), dtype=a.dtype)
            ahat = jnp.einsum("...i,ij->...j", a[..., j, :], U)
            term = jnp.einsum("...j,ij->...i", glam * ahat, U)
            acc = term if acc is None else acc + term
        return acc

    # -- Error bound (Prop. 4) -------------------------------------------------
    def B(self) -> float:
        return cheb.approx_error_bound(self.multipliers, self.coeffs, self.lmax)

    def error_bound(self) -> float:
        """Prop. 4: ||Phi - Phi_tilde||_2 <= B(K) sqrt(eta)."""
        return self.B() * float(np.sqrt(self.eta))

    # -- Execution planning (see repro.dist.operator) -------------------------
    def plan(self, backend: str = "dense", *, mesh=None, partition=None,
             **options):
        """Bind an execution strategy from the repro.dist backend registry.

        Returns an ExecutionPlan with uniform `apply / apply_adjoint /
        apply_gram / solve_lasso`.  `backend` is one of
        `repro.dist.available_backends()` (dense | pallas | halo | allgather
        built in); sharded backends take `mesh=` (and optionally a
        precomputed `partition=`).
        """
        from ..dist.backends import get_backend

        return get_backend(backend)(self, mesh=mesh, partition=partition,
                                    **options)

    # -- Communication model (Section IV-B/C) ---------------------------------
    def message_counts(self, n_edges: int) -> dict:
        """The paper's communication accounting for one application."""
        return {
            "apply_messages": 2 * self.K * n_edges,
            "apply_message_len": 1,
            "adjoint_messages": 2 * self.K * n_edges,
            "adjoint_message_len": self.eta,
            "gram_messages": 4 * self.K * n_edges,
            "gram_message_len": 1,
        }


def graph_multiplier(
    P: Union[Array, Callable],
    g: Callable,
    lmax: float,
    K: int = 20,
    coeff_points: int = 1000,
) -> "ScalarMultiplier":
    return ScalarMultiplier(
        UnionMultiplier(P=P, multipliers=[g], lmax=lmax, K=K, coeff_points=coeff_points)
    )


@dataclasses.dataclass(frozen=True)
class ScalarMultiplier:
    """Single graph multiplier operator — squeezes the union axis."""

    union: UnionMultiplier

    def apply(self, f: Array) -> Array:
        return self.union.apply(f)[..., 0, :]

    def exact_apply(self, f: Array) -> Array:
        return self.union.exact_apply(f)[..., 0, :]

    def error_bound(self) -> float:
        return self.union.error_bound()

    @property
    def coeffs(self) -> np.ndarray:
        return self.union.coeffs[0]

    @property
    def K(self) -> int:
        return self.union.K

    @property
    def lmax(self) -> float:
        return self.union.lmax
