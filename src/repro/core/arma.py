"""Parallel ARMA (rational / IIR) graph filters — Section V-D, Eqs. (29)-(30).

A rational filter written in pole/residue form

    g~(lambda) = const + sum_k 2 r_k / (lmax - lmin - 2 lambda - 2 p_k)   (29)

is applied by iterating, for each k in parallel,

    x_k^{(t+1)} = (1/p_k) [ ((lmax - lmin)/2) I - P ] x_k^{(t)} - (r_k/p_k) y
                                                                          (30)
and summing x = const*y + sum_k x_k. Convergence requires
|p_k| > (lmax - lmin)/2 for all k (Loukas et al. [35]).

Poles/residues may be complex (they appear in conjugate pairs for real
filters); iterates are carried in complex dtype and the real part is
returned.

Distributed form: `matvec` follows the repo-wide (..., N) contract (applies
P along the last axis, broadcasting over leading dims).  The K parallel
pole recursions are *stacked* on a leading axis and the complex iterate is
carried as a real [Re, Im] stack, so one iteration issues exactly ONE
matvec — in a sharded backend that is one neighbour exchange of length-K
messages per round (Section V-D's communication accounting), and the real
stack keeps the Pallas/Block-ELL kernels (which are real-dtype) on the hot
path.  `repro.dist.solvers` runs this loop inside every execution backend.
"""
from __future__ import annotations

from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
MatVec = Callable[[Array], Array]


def arma_from_partial_fractions(
    poles: Sequence[complex],
    residues: Sequence[complex],
    lmax: float,
    lmin: float = 0.0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Convert g(lambda) = sum_i rho_i/(lambda - lambda_i) to ARMA (r, p).

    2 r/(lmax - lmin - 2 lambda - 2 p) = -r/(lambda - ((lmax-lmin)/2 - p)),
    so p_i = (lmax-lmin)/2 - lambda_i and r_i = -rho_i.
    """
    mid = (lmax - lmin) / 2.0
    p = np.array([mid - li for li in poles], dtype=np.complex128)
    r = np.array([-ri for ri in residues], dtype=np.complex128)
    return r, p


def arma_from_rational(
    num: Sequence[float],
    den: Sequence[float],
    lmax: float,
    lmin: float = 0.0,
) -> Tuple[np.ndarray, np.ndarray, float]:
    """ARMA (r, p, const) for an arbitrary rational g = num(lambda)/den(lambda).

    `num` / `den` are monomial coefficients low-degree-first (index m is the
    lambda^m coefficient).  Requires deg(num) <= deg(den) and simple
    (pairwise-distinct) denominator roots; the partial-fraction residues are
    rho_i = rem(lambda_i) / den'(lambda_i) with `rem` the polynomial-division
    remainder, and the poles map through
    :func:`arma_from_partial_fractions`.  Generalizes the ready-made
    Section V-E presets below — e.g. `arma_from_rational((tau,), (tau, 1.0),
    lmax)` reproduces :func:`arma_tikhonov_first_order`.
    """
    num_hi = np.trim_zeros(np.asarray(num, dtype=np.float64)[::-1], "f")
    den_hi = np.trim_zeros(np.asarray(den, dtype=np.float64)[::-1], "f")
    if den_hi.size == 0:
        raise ValueError("den must be a nonzero polynomial")
    if num_hi.size > den_hi.size:
        raise ValueError(
            f"deg(num)={num_hi.size - 1} > deg(den)={den_hi.size - 1}: "
            "g must be proper (or at most biproper) for the ARMA form (29)")
    if den_hi.size == 1:
        raise ValueError("den is constant — g is polynomial, use Chebyshev")
    if num_hi.size == 0:
        num_hi = np.zeros(1)
    # deg(num) <= deg(den), so the quotient is the constant term of g
    quo, rem = np.polydiv(num_hi, den_hi)
    const = float(quo[-1])
    roots = np.roots(den_hi)
    if roots.size > 1:
        dist = np.abs(roots[:, None] - roots[None, :])
        np.fill_diagonal(dist, np.inf)
        scale = max(float(np.abs(roots).max()), 1.0)
        if float(dist.min()) < 1e-8 * scale:
            raise ValueError(
                "den has (numerically) repeated roots — the simple-pole "
                "partial-fraction form (29) does not apply")
    dden = np.polyder(den_hi)
    residues = [np.polyval(rem, li) / np.polyval(dden, li) for li in roots]
    r, p = arma_from_partial_fractions(list(roots), residues, lmax, lmin)
    return r, p, const


def arma_stable(p: np.ndarray, lmax: float, lmin: float = 0.0) -> bool:
    """Convergence check |p_k| > (lmax - lmin)/2 (Section V-D)."""
    return bool(np.all(np.abs(p) > (lmax - lmin) / 2.0))


def arma_eval(r: np.ndarray, p: np.ndarray, lam, lmax: float,
              lmin: float = 0.0, const: float = 0.0):
    """Evaluate the rational filter (29) at scalar abscissae (for tests)."""
    lam = np.asarray(lam, dtype=np.float64)
    out = np.full(lam.shape, const, dtype=np.complex128)
    for rk, pk in zip(r, p):
        out = out + 2.0 * rk / (lmax - lmin - 2.0 * lam - 2.0 * pk)
    return out.real


def _complex_matvec(matvec: MatVec) -> Callable[[Array], Array]:
    """Apply a real matvec to a complex iterate as one [Re, Im] stack.

    The stack rides the matvec's leading batch dims ((..., N) contract), so
    the complex application still costs ONE exchange round — and the matvec
    only ever sees real arrays, keeping real-dtype kernels/collectives
    usable."""

    def mv(z: Array) -> Array:
        st = jnp.stack([z.real, z.imag])
        out = matvec(st)
        return jax.lax.complex(out[0], out[1])

    return mv


def arma_apply(
    matvec: MatVec,
    y: Array,
    r: np.ndarray,
    p: np.ndarray,
    lmax: float,
    lmin: float = 0.0,
    n_iters: int = 50,
    const: float = 0.0,
    return_history: bool = False,
):
    """Iterate (30) for each (r_k, p_k) in parallel; return const*y + sum_k x_k.

    y: (..., N) batched signals; `matvec` must follow the (..., N) contract
    (contract the LAST axis, broadcast over leading dims — e.g.
    ``lambda v: jnp.einsum("ij,...j->...i", P, v)``).  The poles are
    stacked on a leading axis and the complex iterate is carried as a real
    [Re, Im] stack, so each iteration costs exactly one matvec — the
    distributed analog is one neighbourhood exchange of length-K messages
    per iteration (Section V-D's communication accounting), for the whole
    batch.  With `return_history=True` also returns the (n_iters, ..., N)
    real iterate history.
    """
    rj = jnp.asarray(r, dtype=jnp.complex64)
    pj = jnp.asarray(p, dtype=jnp.complex64)
    mid = (lmax - lmin) / 2.0
    yc = y.astype(jnp.complex64)
    Kp = rj.shape[0]
    x0 = jnp.zeros((Kp,) + y.shape, dtype=jnp.complex64)
    mv = _complex_matvec(matvec)

    def shape_coef(c):
        return c[(...,) + (None,) * y.ndim]

    def body(x, _):
        # (1/p_k)(mid I - P) x_k - (r_k/p_k) y
        Mx = mid * x - mv(x)
        x_new = shape_coef(1.0 / pj) * Mx - shape_coef(rj / pj) * yc[None]
        out = (const * yc + jnp.sum(x_new, axis=0)).real if return_history else None
        return x_new, out

    x_final, hist = jax.lax.scan(body, x0, None, length=n_iters)
    result = (const * yc + jnp.sum(x_final, axis=0)).real.astype(y.dtype)
    if return_history:
        return result, hist.astype(y.dtype)
    return result


# -- Ready-made pole/residue sets used in Section V-E -------------------------
def arma_tikhonov_first_order(tau: float, lmax: float):
    """g(lambda) = tau/(tau + lambda): single real pole at -tau.
    g = tau/(lambda+tau) => rho = tau at pole lambda = -tau."""
    r, p = arma_from_partial_fractions([-tau], [tau], lmax)
    return r, p, 0.0


def arma_tikhonov_second_order(tau: float, lmax: float):
    """g(lambda) = tau/(tau + lambda^2) (Section V-E, P = L, S = L^2).

    Poles at lambda = +- i sqrt(tau); g = tau/((l - i s)(l + i s)), s=sqrt(tau)
    residues rho = tau / (2 lambda_pole) = -+ i sqrt(tau)/2.
    Matches the paper's p_{1,2} = +-sqrt(tau) i + lmax/2, r_{1,2} = -+ sqrt(tau) i / 2.
    """
    s = np.sqrt(tau)
    poles = [1j * s, -1j * s]
    residues = [tau / (2j * s), -tau / (2j * s)]
    r, p = arma_from_partial_fractions(poles, residues, lmax)
    return r, p, 0.0


def arma_random_walk_3(tau: float, lmax: float):
    """g(lambda) = 1 - 2/((2-lambda)^3 + 2)  (Section V-E third setting,
    S = (2 I - L_norm)^{-3}, tau = 0.5 gives the paper's filter; here we keep
    tau general: g = tau/(tau + (2-lambda)^{-3}) = 1 - tau'/( (2-l)^3 + tau')
    with tau' = 1/tau).

    Partial fractions computed numerically from the cubic's roots.
    """
    tp = 1.0 / tau
    # Poles where (2 - lambda)^3 = -tp:  2 - lambda = tp^{1/3} e^{i pi (2m+1)/3}.
    cbrt = tp ** (1.0 / 3.0)
    poles = [2.0 - cbrt * np.exp(1j * np.pi * (2 * m + 1) / 3.0) for m in range(3)]
    # f(l) = -tp / D(l) with D(l) = (2-l)^3 + tp, D'(l) = -3 (2-l)^2;
    # residue of f at pole li is -tp / D'(li).
    residues = [-tp / (-3.0 * (2.0 - li) ** 2) for li in poles]
    r, p = arma_from_partial_fractions(poles, residues, lmax)
    return r, p, 1.0
