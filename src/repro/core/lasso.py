"""Distributed lasso / wavelet denoising — Section VI, Algorithm 3.

Iterative soft thresholding (ISTA, Eq. (32)) over the Chebyshev-approximated
spectral graph wavelet frame Phi_tilde:

    argmin_a  (1/2) || y - Phi~* a ||_2^2 + || a ||_{1, mu}        (33)

Each iteration needs Phi~ y (computed once, Algorithm 1) and
Phi~ Phi~* a^{(beta-1)} (Algorithm 2 then Algorithm 1). The step size must
satisfy gamma < 2 / ||Phi~||_2^2 for convergence [58].
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .multiplier import UnionMultiplier

Array = jax.Array


def soft_threshold(z: Array, thresh: Array) -> Array:
    """S_t(z) = 0 if |z| <= t else z - sgn(z) t   (shrinkage operator)."""
    return jnp.sign(z) * jnp.maximum(jnp.abs(z) - thresh, 0.0)


def lasso_objective(op: UnionMultiplier, y: Array, a: Array, mu: Array) -> Array:
    """Eq. (33) objective; for batched y/a the objectives are summed over
    the batch (each signal's problem is separable, so the sum is what the
    batched ISTA minimizes)."""
    resid = y - op.apply_adjoint(a)
    return 0.5 * jnp.sum(resid * resid) + jnp.sum(mu * jnp.abs(a))


@dataclasses.dataclass
class LassoResult:
    coeffs: Array       # a_*, shape (..., eta, N) — leading batch dims of y
    signal: Array       # Phi~* a_*, shape (..., N)
    objective: Array    # objective value per recorded iteration
    n_iters: int
    fused: bool = False  # True iff a backend's in-shard_map ISTA ran


def _mu_threshold(mu: Union[float, Array], eta: int, dtype, gamma: float,
                  n: Optional[int] = None) -> Array:
    """Shrinkage threshold mu*gamma broadcastable against a (..., eta, N).

    mu: scalar (shared), (eta,) per-scale (the paper's 0.01 / 0.75 split),
    (..., eta) per-signal-per-scale for batched solves, or — when the
    vertex count `n` is given — (..., eta, N) per-vertex weights.  When
    ``n == eta`` an (eta, n)-shaped mu is read as per-vertex (the
    pre-batch meaning of a 2-D mu).
    """
    mu_arr = jnp.asarray(mu, dtype=dtype)
    if mu_arr.ndim == 0:
        mu_arr = jnp.full((eta,), mu_arr)
    if (n is not None and mu_arr.ndim >= 2
            and mu_arr.shape[-1] == n and mu_arr.shape[-2] == eta):
        return mu_arr * gamma  # per-vertex: already (..., eta, N)
    if mu_arr.shape[-1] != eta:
        per_vertex_hint = (
            f", or (..., eta, N) with N={n} for per-vertex weights"
            if n is not None else
            "; per-vertex (..., eta, N) weights are not supported on this "
            "(fused/padded) path — use the generic ISTA loop")
        raise ValueError(
            f"mu trailing axis must be eta={eta}{per_vertex_hint}; "
            f"got shape {mu_arr.shape}")
    return mu_arr[..., None] * gamma


def distributed_lasso(
    op: UnionMultiplier,
    y: Array,
    mu: Union[float, Array],
    gamma: float = 0.2,
    n_iters: int = 300,
    a0: Optional[Array] = None,
    record_objective: bool = False,
    soft_threshold_fn: Callable = soft_threshold,
    backend: Optional[str] = None,
    mesh=None,
) -> LassoResult:
    """Algorithm 3. `y` may be a single (N,) signal or a batched (..., N)
    stack — every signal rides the same Chebyshev exchange rounds (the
    recurrence is linear).  `mu` may be a scalar, an (eta,)-vector
    (per-scale weights, as in the paper: 0.01 for scaling coefficients,
    0.75 for wavelets), a per-signal (..., eta) array for batched y, or a
    per-vertex (..., eta, N) array (the fused backend paths support the
    first three; per-vertex weights run the generic loop here).

    `op` may be a UnionMultiplier/GraphOperator or an already-built
    ExecutionPlan; passing `backend=` (plus `mesh=` for sharded backends)
    plans the operator here — `backend="halo"` runs the whole ISTA loop
    inside one shard_map (repro.dist.backends.halo.dist_lasso).

    The whole ISTA loop is a single lax.scan whose body applies
    Phi~ Phi~* (2*K matvecs via Algorithms 2+1) plus local shrinkage — the
    same structure a real sensor network would execute.
    """
    if backend is not None:
        plan = op.plan(backend, mesh=mesh)
        # the fused (in-shard_map) path supports none of the loop knobs —
        # fall through to the generic ISTA over the plan if any is set
        if (plan.solve_lasso_fn is not None and a0 is None
                and not record_objective
                and soft_threshold_fn is soft_threshold):
            return plan.solve_lasso(y, mu, gamma=gamma, n_iters=n_iters)
        op = plan
    thresh = _mu_threshold(mu, op.eta, y.dtype, gamma, n=y.shape[-1])

    phi_y = op.apply(y)  # Algorithm 3 line 3 (stored); (..., eta, N)
    a = jnp.zeros_like(phi_y) if a0 is None else a0

    def body(a, _):
        # line 5: Phi~ Phi~* a    (Algorithm 2 then Algorithm 1)
        gram_a = op.apply(op.apply_adjoint(a))
        a_new = soft_threshold_fn(a + gamma * (phi_y - gram_a), thresh)
        obj = (lasso_objective(op, y, a_new, thresh / gamma)
               if record_objective else jnp.nan)
        return a_new, obj

    a_final, objs = jax.lax.scan(body, a, None, length=n_iters)
    signal = op.apply_adjoint(a_final)  # line 14
    return LassoResult(coeffs=a_final, signal=signal, objective=objs,
                       n_iters=n_iters)


def distributed_lasso_masked(
    op: UnionMultiplier,
    y: Array,
    mask: Array,
    mu: Union[float, Array],
    gamma: float = 0.2,
    n_iters: int = 150,
) -> LassoResult:
    """Algorithm 3 with a vertex observation mask M (data term
    ||M(y - Phi~* a)||^2/2): the ISTA gradient picks up M elementwise —
    still fully local, used by the cross-validation below."""
    thresh = _mu_threshold(mu, op.eta, y.dtype, gamma, n=y.shape[-1])
    m = mask.astype(y.dtype)
    phi_my = op.apply(m * y)

    def body(a, _):
        resid = m * op.apply_adjoint(a)
        a_new = soft_threshold(a + gamma * (phi_my - op.apply(resid)), thresh)
        return a_new, None

    a0 = jnp.zeros_like(phi_my)
    a_star, _ = jax.lax.scan(body, a0, None, length=n_iters)
    return LassoResult(coeffs=a_star, signal=op.apply_adjoint(a_star),
                       objective=jnp.nan, n_iters=n_iters)


def lasso_cross_validate(
    op: UnionMultiplier,
    y: Array,
    mu_grid,
    key: Array,
    holdout_frac: float = 0.2,
    n_folds: int = 3,
    gamma: float = 0.2,
    n_iters: int = 120,
):
    """Distributed cross-validation of the lasso weights mu (the optional
    extension the paper points to in Section VI / refs [29,30]).

    Random vertex subsets are held out; each candidate mu is fit on the
    observed vertices (masked ISTA) and scored by MSE on the held-out ones
    (both computable with the same local message passing). Returns
    (best_mu, scores).
    """
    n = y.shape[0]
    scores = []
    for mu in mu_grid:
        fold_mse = []
        for fold in range(n_folds):
            key, sub = jax.random.split(key)
            held = jax.random.uniform(sub, (n,)) < holdout_frac
            res = distributed_lasso_masked(op, y, ~held, mu, gamma=gamma,
                                           n_iters=n_iters)
            err = (res.signal - y) * held.astype(y.dtype)
            fold_mse.append(float(jnp.sum(err * err)
                                  / jnp.maximum(jnp.sum(held), 1)))
        scores.append(sum(fold_mse) / n_folds)
    best = int(np.argmin(scores))
    return mu_grid[best], scores


def ista_step_size(op: UnionMultiplier, safety: float = 0.9) -> float:
    """gamma < 2/||Phi~||^2; we bound ||Phi~||^2 <= max_lambda sum_j p_j(lambda)^2
    on a dense grid (B(K)-style estimate)."""
    from .chebyshev import cheb_eval

    lam = np.linspace(0.0, op.lmax, 4000)
    vals = np.asarray(cheb_eval(np.asarray(op.coeffs), jnp.asarray(lam), op.lmax))
    frame = np.max(np.sum(vals**2, axis=0))
    return float(safety * 2.0 / max(frame, 1e-12))
