"""Shifted Chebyshev polynomial machinery (Section IV of the paper).

Implements:
  * truncated shifted-Chebyshev coefficients c_{j,k} of Eq. (14), computed by
    Chebyshev-Gauss quadrature (exact for integrands of matching degree);
  * the three-term recurrence Eq. (15) as a single `lax.scan` whose body does
    exactly one application of P — the distributed hot loop of Algorithm 1;
  * union application  f -> Phi_tilde f           (Algorithm 1, Eq. (17));
  * adjoint application a -> Phi_tilde^* a         (Algorithm 2, Eq. (19));
  * Gram application    f -> Phi_tilde^* Phi_tilde f  via the Chebyshev
    product-coefficient identity T_k T_k' = (T_{k+k'} + T_{|k-k'|})/2
    (Section IV-C), costing 2K matvecs instead of 2·(K + K·eta);
  * scalar polynomial evaluation for the B(K) bound of Prop. 4.

Conventions follow the paper: a series is represented by coefficients
(c_0, ..., c_K) with   g(x) ~= c_0/2 + sum_{k>=1} c_k Tbar_k(x),
Tbar_k(x) = T_k((x - alpha)/alpha), alpha = lmax/2, on x in [0, lmax].
"""
from __future__ import annotations

from typing import Callable, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
MatVec = Callable[[Array], Array]


def _stateful_matvec(matvec: MatVec, x: Array):
    """Adapt `matvec` to the dual-signature stateful protocol.

    Matvecs that carry cross-order state (the int8 error-feedback halo
    exchange in `repro.dist.quantize` / `dist.backends.halo`) expose an
    ``init_state(x)`` attribute and accept ``matvec(x, state) ->
    (y, state)``.  Plain matvecs keep their stateless signature and get
    an empty-state shim, so every recurrence below threads state
    uniformly through its scan carry at zero cost for the common case.

    Returns ``(mv2, state0)`` with ``mv2(v, s) -> (y, s')``.
    """
    init_state = getattr(matvec, "init_state", None)
    if init_state is None:
        return (lambda v, s: (matvec(v), s)), ()
    return matvec, init_state(x)


# ---------------------------------------------------------------------------
# Coefficients — Eq. (14)
# ---------------------------------------------------------------------------
def cheb_coeffs(
    g: Callable[[np.ndarray], np.ndarray],
    K: int,
    lmax: float,
    n_points: int = 1000,
    dtype=np.float64,
) -> np.ndarray:
    """Truncated shifted-Chebyshev coefficients of `g` on [0, lmax].

    c_k = (2/pi) * integral_0^pi cos(k phi) g(alpha (cos phi + 1)) dphi,
    evaluated with the midpoint rule at Chebyshev angles (equivalently,
    Chebyshev-Gauss quadrature), which converges spectrally for smooth g.

    Returns shape (K+1,) float array in the paper's half-c0 convention.
    """
    alpha = lmax / 2.0
    m = np.arange(n_points, dtype=dtype)
    phi = np.pi * (m + 0.5) / n_points
    vals = np.asarray(g(alpha * (np.cos(phi) + 1.0)), dtype=dtype)
    ks = np.arange(K + 1, dtype=dtype)[:, None]
    c = (2.0 / n_points) * np.sum(np.cos(ks * phi[None, :]) * vals[None, :], axis=1)
    return c.astype(dtype)


def cheb_coeffs_stack(
    gs: Sequence[Callable[[np.ndarray], np.ndarray]],
    K: int,
    lmax: float,
    n_points: int = 1000,
) -> np.ndarray:
    """Coefficients for a union of multipliers; shape (eta, K+1)."""
    return np.stack([cheb_coeffs(g, K, lmax, n_points) for g in gs], axis=0)


# ---------------------------------------------------------------------------
# Scalar polynomial evaluation (for bounds / tests)
# ---------------------------------------------------------------------------
def cheb_eval(coeffs: Union[Array, np.ndarray], x, lmax: float):
    """Evaluate the truncated series at scalar/array abscissae x in [0,lmax].

    coeffs: (K+1,) or (eta, K+1). Returns x.shape or (eta,) + x.shape.
    """
    c = jnp.atleast_2d(jnp.asarray(coeffs))
    x = jnp.asarray(x)
    alpha = lmax / 2.0
    y = (x - alpha) / alpha  # in [-1, 1]
    K = c.shape[1] - 1
    t_km2 = jnp.ones_like(y)
    acc = 0.5 * c[:, 0][(...,) + (None,) * y.ndim] * t_km2
    if K >= 1:
        t_km1 = y
        acc = acc + c[:, 1][(...,) + (None,) * y.ndim] * t_km1
        for k in range(2, K + 1):
            t_k = 2.0 * y * t_km1 - t_km2
            acc = acc + c[:, k][(...,) + (None,) * y.ndim] * t_k
            t_km2, t_km1 = t_km1, t_k
    if jnp.asarray(coeffs).ndim == 1:
        return acc[0]
    return acc


def approx_error_bound(
    gs: Sequence[Callable],
    coeffs: np.ndarray,
    lmax: float,
    n_grid: int = 4000,
) -> float:
    """B(K) of Prop. 4 Eq. (20): max_j sup_{lambda in [0,lmax]} |g_j - p_j^K|.

    Estimated on a dense grid (the paper's bound is a sup over the continuous
    interval; a 4000-point grid is what the reference MATLAB code uses).
    """
    lam = np.linspace(0.0, lmax, n_grid)
    worst = 0.0
    approx = np.asarray(cheb_eval(np.asarray(coeffs), jnp.asarray(lam), lmax))
    approx = np.atleast_2d(approx)
    for j, g in enumerate(gs):
        exact = np.asarray(g(lam))
        worst = max(worst, float(np.max(np.abs(exact - approx[j]))))
    return worst


# ---------------------------------------------------------------------------
# Operator application — Algorithm 1 / Eq. (17)
# ---------------------------------------------------------------------------
def _outer(c: Array, t: Array) -> Array:
    """(eta,) x (..., N) -> (..., eta, N): per-multiplier scaled copies,
    the eta axis inserted before the vertex axis."""
    return c[:, None] * t[..., None, :]


def cheb_apply(
    matvec: MatVec,
    x: Array,
    coeffs: Union[Array, np.ndarray],
    lmax: float,
) -> Array:
    """Compute Phi_tilde x for a union of multipliers given by `coeffs`.

    matvec: linear map applying P along the *last* axis of its argument,
            broadcasting over any leading batch dims ((N,) and (..., N)
            inputs both work).
    x: (..., N) — leading axes are batch signals riding the same recurrence.
    coeffs: (K+1,) single multiplier or (eta, K+1) union.
    Returns (..., N) (single) or (..., eta, N) (union).

    The body performs exactly one matvec per Chebyshev order — the same
    communication/computation structure as Algorithm 1 lines 6-10 — and the
    recurrence is linear, so every batch signal shares the K rounds
    (Section III-D's shared-rounds trick generalized to arbitrary batches).
    """
    single = jnp.asarray(coeffs).ndim == 1
    c = jnp.atleast_2d(jnp.asarray(coeffs, dtype=x.dtype))
    K = c.shape[1] - 1
    alpha = lmax / 2.0

    t0 = x
    acc = _outer(0.5 * c[:, 0], t0)
    if K == 0:
        return acc[..., 0, :] if single else acc

    mv2, st = _stateful_matvec(matvec, x)
    # Tbar_1(P) x = (P x)/alpha - x     (Algorithm 1 line 5)
    px, st = mv2(x, st)
    t1 = px / alpha - x
    acc = acc + _outer(c[:, 1], t1)

    if K >= 2:
        def body(carry, ck):
            t_km1, t_km2, acc, st = carry
            # Tbar_k = (2/alpha) P t_{k-1} - 2 t_{k-1} - t_{k-2}   (line 9)
            pt, st = mv2(t_km1, st)
            t_k = (2.0 / alpha) * pt - 2.0 * t_km1 - t_km2
            acc = acc + _outer(ck, t_k)
            return (t_k, t_km1, acc, st), None

        (_, _, acc, _), _ = jax.lax.scan(body, (t1, t0, acc, st),
                                         c[:, 2:].T)
    return acc[..., 0, :] if single else acc


def cheb_apply_adjoint(
    matvec: MatVec,
    a: Array,
    coeffs: Union[Array, np.ndarray],
    lmax: float,
    matvec_batched: MatVec = None,
) -> Array:
    """Compute Phi_tilde^* a per Eq. (19) / Algorithm 2.

    a: (..., eta, N) stacked coefficient signals a_j (eta on axis -2,
    leading axes are batch).
    coeffs: (eta, K+1).
    Returns (..., N). Each Chebyshev order applies P to all eta streams (and
    all batch signals) at once — the paper's length-eta messages.

    matvec_batched: deprecated alias kept for old callers; `matvec` itself
    must broadcast over leading dims under the (..., N) contract, so the
    eta axis needs no special handling.  If given, it is used instead of
    `matvec`.
    """
    c = jnp.asarray(coeffs, dtype=a.dtype)
    assert c.ndim == 2 and a.shape[-2] == c.shape[0], "eta mismatch"
    K = c.shape[1] - 1
    alpha = lmax / 2.0
    mv = matvec_batched if matvec_batched is not None else matvec

    def combine(ck: Array, t: Array) -> Array:
        # sum_j ck[j] * t[..., j, :]
        return jnp.einsum("j,...jn->...n", ck, t)

    t0 = a
    acc = combine(0.5 * c[:, 0], t0)
    if K == 0:
        return acc
    mv2, st = _stateful_matvec(mv, a)
    pa, st = mv2(a, st)
    t1 = pa / alpha - a
    acc = acc + combine(c[:, 1], t1)
    if K >= 2:
        def body(carry, ck):
            t_km1, t_km2, acc, st = carry
            pt, st = mv2(t_km1, st)
            t_k = (2.0 / alpha) * pt - 2.0 * t_km1 - t_km2
            return (t_k, t_km1, acc + combine(ck, t_k), st), None

        (_, _, acc, _), _ = jax.lax.scan(body, (t1, t0, acc, st),
                                         c[:, 2:].T)
    return acc


# ---------------------------------------------------------------------------
# Product / Gram coefficients — Section IV-C
# ---------------------------------------------------------------------------
def cheb_product_coeffs(c1: np.ndarray, c2: np.ndarray) -> np.ndarray:
    """Coefficients of the product of two truncated series (paper convention).

    Uses T_j T_k = (T_{j+k} + T_{|j-k|}) / 2. If c1 has degree K1 and c2 has
    degree K2, the product has degree K1+K2 and shape (K1+K2+1,).
    """
    a = np.array(c1, dtype=np.float64).copy()
    b = np.array(c2, dtype=np.float64).copy()
    a[0] *= 0.5  # convert half-c0 convention -> plain coefficients
    b[0] *= 0.5
    K1, K2 = len(a) - 1, len(b) - 1
    out = np.zeros(K1 + K2 + 1, dtype=np.float64)
    for j in range(K1 + 1):
        if a[j] == 0.0:
            continue
        for k in range(K2 + 1):
            v = 0.5 * a[j] * b[k]
            if v == 0.0:
                continue
            out[j + k] += v
            out[abs(j - k)] += v
    out[0] *= 2.0  # back to half-c0 convention
    return out


def gram_coeffs(coeffs: np.ndarray) -> np.ndarray:
    """d_k such that Phi_tilde^* Phi_tilde = d0/2 + sum_k d_k Tbar_k(P).

    coeffs: (eta, K+1). Returns (2K+1,). See Section IV-C: this lets
    Phi*Phi f be computed with 2K matvecs (4K|E| messages) instead of
    sequential adjoint-after-forward.
    """
    coeffs = np.atleast_2d(np.asarray(coeffs, dtype=np.float64))
    K = coeffs.shape[1] - 1
    d = np.zeros(2 * K + 1, dtype=np.float64)
    for j in range(coeffs.shape[0]):
        d += cheb_product_coeffs(coeffs[j], coeffs[j])
    return d


def cheb_apply_gram(
    matvec: MatVec,
    x: Array,
    coeffs: np.ndarray,
    lmax: float,
) -> Array:
    """Phi_tilde^* Phi_tilde x via the product coefficients (Section IV-C).

    x: (..., N) -> (..., N); batch signals share the 2K exchange rounds."""
    d = gram_coeffs(coeffs)
    return cheb_apply(matvec, x, jnp.asarray(d, dtype=x.dtype), lmax)
