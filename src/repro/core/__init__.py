"""Core library: the paper's contribution (Chebyshev graph multipliers).

`repro.core.distributed` is a deprecated shim over repro.dist.backends and
is intentionally not imported eagerly (importing it emits the deprecation
warning); `from repro.core import distributed` still works.
"""
from . import arma, chebyshev, filters, graph, jacobi, lasso, ssl, wavelets


def __getattr__(name):  # PEP 562: keep `repro.core.distributed` working
    if name == "distributed":
        import importlib

        return importlib.import_module(".distributed", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
from .chebyshev import (
    cheb_apply,
    cheb_apply_adjoint,
    cheb_apply_gram,
    cheb_coeffs,
    cheb_coeffs_stack,
    cheb_eval,
    gram_coeffs,
)
from .graph import Graph, laplacian, lambda_max_bound, sensor_graph
from .multiplier import ScalarMultiplier, UnionMultiplier, graph_multiplier
from .wavelets import sgwt_multipliers, sgwt_operator

__all__ = [
    "arma", "chebyshev", "distributed", "filters", "graph", "jacobi",
    "lasso", "ssl", "wavelets",
    "cheb_apply", "cheb_apply_adjoint", "cheb_apply_gram", "cheb_coeffs",
    "cheb_coeffs_stack", "cheb_eval", "gram_coeffs",
    "Graph", "laplacian", "lambda_max_bound", "sensor_graph",
    "ScalarMultiplier", "UnionMultiplier", "graph_multiplier",
    "sgwt_multipliers", "sgwt_operator",
]
