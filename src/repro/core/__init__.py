"""Core library: the paper's contribution (Chebyshev graph multipliers)."""
from . import arma, chebyshev, distributed, filters, graph, jacobi, lasso, ssl, wavelets
from .chebyshev import (
    cheb_apply,
    cheb_apply_adjoint,
    cheb_apply_gram,
    cheb_coeffs,
    cheb_coeffs_stack,
    cheb_eval,
    gram_coeffs,
)
from .graph import Graph, laplacian, lambda_max_bound, sensor_graph
from .multiplier import ScalarMultiplier, UnionMultiplier, graph_multiplier
from .wavelets import sgwt_multipliers, sgwt_operator

__all__ = [
    "arma", "chebyshev", "distributed", "filters", "graph", "jacobi",
    "lasso", "ssl", "wavelets",
    "cheb_apply", "cheb_apply_adjoint", "cheb_apply_gram", "cheb_coeffs",
    "cheb_coeffs_stack", "cheb_eval", "gram_coeffs",
    "Graph", "laplacian", "lambda_max_bound", "sensor_graph",
    "ScalarMultiplier", "UnionMultiplier", "graph_multiplier",
    "sgwt_multipliers", "sgwt_operator",
]
