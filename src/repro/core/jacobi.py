"""Jacobi iteration and Chebyshev-accelerated Jacobi (Section V-A / V-B).

Computing R y for a multiplier with g(lambda) != 0 is equivalent to solving
Q x = y with Q = g(P)^{-1} (Eq. (23)-(24)). With Q = Q_D - Q_O (diagonal /
off-diagonal split) the Jacobi iteration is

    x^{(t+1)} = Q_D^{-1} Q_O x^{(t)} + Q_D^{-1} y,            (24)

and the Chebyshev-accelerated variant (Saad / Demmel [51, Alg. 6.7]) is
Eq. (25). Note (paper, Section V-B): the "Chebyshev" here reweights Jacobi
iterates; it is *not* the polynomial approximation of Section IV.

Distributed form: both solvers follow the repo-wide (..., N) signal
contract — `q_matvec` applies Q along the *last* axis of its argument and
broadcasts over leading batch dims, so a (B, N) stack of right-hand sides
rides the same exchange rounds as a single signal, and the iteration body
runs unchanged inside a shard_map (see `repro.dist.solvers`, which drives
these loops through every registered execution backend).  The update is
written as

    x^{(t+1)} = x^{(t)} + Q_D^{-1} (y - Q x^{(t)})

(algebraically identical to (24)) so that only the *reciprocal* diagonal
appears: a shard whose padded tail carries `inv_diag == 0` keeps those
rows identically zero instead of NaN-poisoning the halo exchange.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
MatVec = Callable[[Array], Array]


def jacobi_weights(n_iters: int) -> np.ndarray:
    """The (w_t, s_t) schedule of the plain Jacobi sweep: every round of
    Eq. (24) is the update with w = 1, s = 0.  Returned as an
    (n_iters, 2) host array — the single-launch `jacobi_sweep` kernel
    (`kernels.ops.fused_jacobi_sweep`) consumes it directly."""
    return np.tile(np.array([1.0, 0.0]), (n_iters, 1))


def cheb_jacobi_weights(rho: float, n_iters: int) -> np.ndarray:
    """Host-side (w_t, s_t) schedule of Chebyshev-accelerated Jacobi.

    Row 0 is the plain bootstrap step x^{(1)}; rows t >= 1 replay the
    xi-recurrence of Eq. (25) exactly as `jacobi_chebyshev_solve` computes
    it in its scan carry — but since rho is a concrete float, the whole
    schedule is known at trace time, which is what lets the
    single-launch `jacobi_sweep` kernel bake the weights in as a streamed
    (n_iters, 2) operand instead of a traced recurrence.
    """
    rho = float(rho)
    ws = np.zeros((n_iters, 2))
    ws[0] = (1.0, 0.0)
    xi_prev, xi = 1.0, rho
    for t in range(1, n_iters):
        xi_next = 1.0 / (2.0 / (rho * xi) - 1.0 / xi_prev)
        ws[t] = (2.0 * xi_next / (rho * xi), xi_next / xi_prev)
        xi_prev, xi = xi, xi_next
    return ws


def _resolve_inv_diag(q_diag, inv_diag):
    if inv_diag is not None:
        return jnp.asarray(inv_diag)
    if q_diag is None:
        raise ValueError("pass q_diag or inv_diag")
    return 1.0 / jnp.asarray(q_diag)


def jacobi_solve(
    q_matvec: MatVec,
    q_diag: Optional[Array],
    y: Array,
    n_iters: int,
    x0: Array = None,
    return_history: bool = False,
    inv_diag: Optional[Array] = None,
    use_pallas: Optional[bool] = None,
):
    """Jacobi iteration (24) for Q x = y.

    q_matvec: applies the full Q along the last axis ((..., N) contract).
    q_diag: diagonal of Q (length N); alternatively pass `inv_diag`
    (= 1/q_diag) directly — the sharded solver path does, with zeros on
    padded rows.  y: (..., N) batched right-hand sides.
    Convergence iff spectral_radius(Q_D^{-1} Q_O) < 1 [50, Thm 4.1]
    (e.g. Q strictly diagonally dominant).  `use_pallas` routes the
    elementwise update through the fused `kernels.ops.jacobi_update`
    (kernels.ops dispatch policy; None = native on TPU, jnp oracle on CPU).

    With `return_history=True` also returns the (n_iters, ..., N) stack of
    iterates (the Fig. 2 error-vs-budget hook).
    """
    from ..kernels import ops  # lazy: core stays importable without kernels
    from .chebyshev import _stateful_matvec

    inv_d = _resolve_inv_diag(q_diag, inv_diag)
    x = jnp.zeros_like(y) if x0 is None else x0
    # stateful-matvec protocol: an int8+error-feedback exchange carries its
    # quantization residual across the rounds (converging iterates re-send
    # nearly the same boundary tiles, so the residual cancels the
    # otherwise-systematic rounding bias); plain matvecs ride a shim
    mv2, st0 = _stateful_matvec(q_matvec, x)

    def body(carry, _):
        x, st = carry
        qx, st = mv2(x, st)
        x_new = ops.jacobi_update(qx, x, x, y, inv_d,
                                  w=1.0, s=0.0, use_pallas=use_pallas)
        return (x_new, st), x_new if return_history else None

    (x_final, _), hist = jax.lax.scan(body, (x, st0), None, length=n_iters)
    if return_history:
        return x_final, hist
    return x_final


def jacobi_chebyshev_solve(
    q_matvec: MatVec,
    q_diag: Optional[Array],
    y: Array,
    rho: float,
    n_iters: int,
    x0: Array = None,
    return_history: bool = False,
    inv_diag: Optional[Array] = None,
    use_pallas: Optional[bool] = None,
):
    """Chebyshev-accelerated Jacobi, Eq. (25).

    rho: upper bound on the spectral radius of Q_D^{-1} Q_O (must be < 1).
    Same (..., N) batched contract and `inv_diag` escape hatch as
    :func:`jacobi_solve`; each iteration costs exactly one `q_matvec`.
    """
    from ..kernels import ops
    from .chebyshev import _stateful_matvec

    inv_d = _resolve_inv_diag(q_diag, inv_diag)
    x_prev = jnp.zeros_like(y) if x0 is None else x0
    # same stateful-matvec protocol as jacobi_solve
    mv2, st0 = _stateful_matvec(q_matvec, x_prev)

    def jac_step(x, st):
        qx, st = mv2(x, st)
        return ops.jacobi_update(qx, x, x, y, inv_d,
                                 w=1.0, s=0.0, use_pallas=use_pallas), st

    x, st0 = jac_step(x_prev, st0)  # x^{(1)}
    xi_prev, xi = 1.0, rho

    def body(carry, _):
        x, x_prev, xi, xi_prev, st = carry
        xi_next = 1.0 / (2.0 / (rho * xi) - 1.0 / xi_prev)
        w = 2.0 * xi_next / (rho * xi)
        s = xi_next / xi_prev
        qx, st = mv2(x, st)
        # x_next = w * (x + inv_d (y - Q x)) - s * x_prev    (Eq. (25))
        x_next = ops.jacobi_update(qx, x, x_prev, y, inv_d,
                                   w=w, s=s, use_pallas=use_pallas)
        return ((x_next, x, xi_next, xi, st),
                (x_next if return_history else None))

    (x_final, _, _, _, _), hist = jax.lax.scan(
        body, (x, x_prev, jnp.asarray(xi), jnp.asarray(xi_prev), st0), None,
        length=max(n_iters - 1, 0),
    )
    if return_history:
        # the scan records x^(2)..x^(n_iters); prepend x^(1) so the history
        # is the full (n_iters, ..., N) stack like jacobi_solve's
        return x_final, jnp.concatenate([x[None], hist], axis=0)
    return x_final


def tikhonov_q(P_matvec: MatVec, P_diag: Array, tau: float) -> Tuple[MatVec, Array]:
    """Q = g(P)^{-1} = (tau I + P)/tau for the SSL multiplier tau/(tau+lambda)
    (the Zhou et al. iteration (22) is Jacobi on exactly this Q)."""

    def q_mv(x):
        return (tau * x + P_matvec(x)) / tau

    return q_mv, (tau + P_diag) / tau


def power_q(P_matvec: MatVec, P: Array, tau: float, r: int) -> Tuple[MatVec, Array]:
    """Q = (tau I + P^r)/tau for g(lambda)=tau/(tau+lambda^r). Needs the
    diagonal of P^r; communication per iteration is r matvecs (Section V-E:
    'computing W x requires twice the communication' for r = 2)."""
    Pr = jnp.linalg.matrix_power(P, r)

    def q_mv(x):
        z = x
        for _ in range(r):
            z = P_matvec(z)
        return (tau * x + z) / tau

    return q_mv, (tau + jnp.diag(Pr)) / tau
