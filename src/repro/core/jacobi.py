"""Jacobi iteration and Chebyshev-accelerated Jacobi (Section V-A / V-B).

Computing R y for a multiplier with g(lambda) != 0 is equivalent to solving
Q x = y with Q = g(P)^{-1} (Eq. (23)-(24)). With Q = Q_D - Q_O (diagonal /
off-diagonal split) the Jacobi iteration is

    x^{(t+1)} = Q_D^{-1} Q_O x^{(t)} + Q_D^{-1} y,            (24)

and the Chebyshev-accelerated variant (Saad / Demmel [51, Alg. 6.7]) is
Eq. (25). Note (paper, Section V-B): the "Chebyshev" here reweights Jacobi
iterates; it is *not* the polynomial approximation of Section IV.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
MatVec = Callable[[Array], Array]


def jacobi_solve(
    q_matvec: MatVec,
    q_diag: Array,
    y: Array,
    n_iters: int,
    x0: Array = None,
    return_history: bool = False,
):
    """Jacobi iteration (24) for Q x = y.

    q_matvec: applies the full Q.  q_diag: diagonal of Q (length N).
    Convergence iff spectral_radius(Q_D^{-1} Q_O) < 1 [50, Thm 4.1]
    (e.g. Q strictly diagonally dominant).
    """
    x = jnp.zeros_like(y) if x0 is None else x0
    inv_d = 1.0 / q_diag

    def body(x, _):
        # Q_O x = Q_D x - Q x
        qo_x = q_diag * x - q_matvec(x)
        x_new = inv_d * qo_x + inv_d * y
        return x_new, x_new if return_history else None

    x_final, hist = jax.lax.scan(body, x, None, length=n_iters)
    if return_history:
        return x_final, hist
    return x_final


def jacobi_chebyshev_solve(
    q_matvec: MatVec,
    q_diag: Array,
    y: Array,
    rho: float,
    n_iters: int,
    x0: Array = None,
    return_history: bool = False,
):
    """Chebyshev-accelerated Jacobi, Eq. (25).

    rho: upper bound on the spectral radius of Q_D^{-1} Q_O (must be < 1).
    """
    inv_d = 1.0 / q_diag
    x_prev = jnp.zeros_like(y) if x0 is None else x0

    def jac_step(x):
        return inv_d * (q_diag * x - q_matvec(x)) + inv_d * y

    x = jac_step(x_prev)  # x^{(1)}
    xi_prev, xi = 1.0, rho
    history = [x_prev, x]

    def body(carry, _):
        x, x_prev, xi, xi_prev = carry
        xi_next = 1.0 / (2.0 / (rho * xi) - 1.0 / xi_prev)
        w = 2.0 * xi_next / (rho * xi)
        qo_x = q_diag * x - q_matvec(x)
        x_next = w * inv_d * qo_x - (xi_next / xi_prev) * x_prev + w * inv_d * y
        return (x_next, x, xi_next, xi), (x_next if return_history else None)

    (x_final, _, _, _), hist = jax.lax.scan(
        body, (x, x_prev, jnp.asarray(xi), jnp.asarray(xi_prev)), None,
        length=max(n_iters - 1, 0),
    )
    if return_history:
        return x_final, hist
    return x_final


def tikhonov_q(P_matvec: MatVec, P_diag: Array, tau: float) -> Tuple[MatVec, Array]:
    """Q = g(P)^{-1} = (tau I + P)/tau for the SSL multiplier tau/(tau+lambda)
    (the Zhou et al. iteration (22) is Jacobi on exactly this Q)."""

    def q_mv(x):
        return (tau * x + P_matvec(x)) / tau

    return q_mv, (tau + P_diag) / tau


def power_q(P_matvec: MatVec, P: Array, tau: float, r: int) -> Tuple[MatVec, Array]:
    """Q = (tau I + P^r)/tau for g(lambda)=tau/(tau+lambda^r). Needs the
    diagonal of P^r; communication per iteration is r matvecs (Section V-E:
    'computing W x requires twice the communication' for r = 2)."""
    Pr = jnp.linalg.matrix_power(P, r)

    def q_mv(x):
        z = x
        for _ in range(r):
            z = P_matvec(z)
        return (tau * x + z) / tau

    return q_mv, (tau + jnp.diag(Pr)) / tau
