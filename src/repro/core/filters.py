"""Multiplier (graph spectral filter) families from Section III of the paper.

Every function here returns a scalar callable g(lambda) suitable for
`UnionMultiplier` / `cheb_coeffs`. All are vectorized over numpy arrays.
"""
from __future__ import annotations

from typing import Callable, Sequence

import numpy as np


# -- Section III-A: distributed Tikhonov denoising ---------------------------
def tikhonov(tau: float, r: int = 1) -> Callable:
    """Prop. 2: solution of argmin (tau/2)||f-y||^2 + f^T L^r f  is R y with
    g(lambda) = tau / (tau + 2 lambda^r)."""

    def g(lam):
        lam = np.asarray(lam, dtype=np.float64)
        return tau / (tau + 2.0 * np.power(np.maximum(lam, 0.0), r))

    return g


# -- Section III-B: distributed smoothing ------------------------------------
def heat(t: float) -> Callable:
    """Heat kernel lowpass g(lambda) = exp(-t lambda)."""

    def g(lam):
        return np.exp(-t * np.asarray(lam, dtype=np.float64))

    return g


# -- Section III-C: distributed inverse filtering -----------------------------
def inverse_filter(g_psi: Callable, tau: float, r: int = 1) -> Callable:
    """Prop. 3: regularized deconvolution multiplier
    h(lambda) = tau g_psi(lambda) / (tau g_psi(lambda)^2 + 2 lambda^r)."""

    def h(lam):
        lam = np.asarray(lam, dtype=np.float64)
        gp = np.asarray(g_psi(lam), dtype=np.float64)
        return tau * gp / (tau * gp * gp + 2.0 * np.power(np.maximum(lam, 0.0), r))

    return h


# -- Section III-D: semi-supervised classification kernels -------------------
def ssl_multiplier(h: Callable, tau: float) -> Callable:
    """Optimal multiplier for argmin tau||f - Y_j||^2 + f^T h(P) f:
    g(lambda) = tau / (tau + h(lambda))."""

    def g(lam):
        return tau / (tau + np.asarray(h(lam), dtype=np.float64))

    return g


def power_kernel(r: int = 1) -> Callable:
    """h(lambda) = lambda^r — Tikhonov RKHS (S = L^r or L_norm^r)."""

    def h(lam):
        return np.power(np.maximum(np.asarray(lam, dtype=np.float64), 0.0), r)

    return h


def diffusion_kernel(beta: float) -> Callable:
    """Smola-Kondor diffusion: S = [exp(-(beta^2/2) L_norm)]^{-1}, i.e.
    h(lambda) = exp((beta^2/2) lambda)."""

    def h(lam):
        return np.exp(0.5 * beta * beta * np.asarray(lam, dtype=np.float64))

    return h


def inverse_cosine_kernel() -> Callable:
    """Smola-Kondor inverse cosine: S = [cos(pi lambda / 4)]^{-1} on L_norm,
    i.e. h(lambda) = 1 / cos(pi lambda / 4) (finite on [0, 2])."""

    def h(lam):
        return 1.0 / np.cos(np.pi * np.asarray(lam, dtype=np.float64) / 4.0)

    return h


def random_walk_kernel(beta: float, r: int) -> Callable:
    """r-step random walk: S = (beta I - L_norm)^{-r}, beta >= 2,
    i.e. h(lambda) = (beta - lambda)^{-r}."""

    def h(lam):
        return np.power(beta - np.asarray(lam, dtype=np.float64), -float(r))

    return h


def identity_multiplier() -> Callable:
    return lambda lam: np.ones_like(np.asarray(lam, dtype=np.float64))


# -- Section V-E experiment filters -------------------------------------------
def fig2_target(h: Callable, tau: float) -> Callable:
    """The Section V-E forward operator g(lambda) = (tau + h(lambda))/tau,
    whose inverse g^{-1} = tau/(tau+h) is what the methods compete to apply."""

    def g(lam):
        return (tau + np.asarray(h(lam), dtype=np.float64)) / tau

    return g
