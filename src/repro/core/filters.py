"""Multiplier (graph spectral filter) families from Section III of the paper.

Every function here returns a scalar callable g(lambda) suitable for
`UnionMultiplier` / `cheb_coeffs`. All are vectorized over numpy arrays.
"""
from __future__ import annotations

from typing import Callable, Sequence

import numpy as np


# -- Section III-A: distributed Tikhonov denoising ---------------------------
def tikhonov(tau: float, r: int = 1) -> Callable:
    """Prop. 2: solution of argmin (tau/2)||f-y||^2 + f^T L^r f  is R y with
    g(lambda) = tau / (tau + 2 lambda^r)."""

    def g(lam):
        lam = np.asarray(lam, dtype=np.float64)
        return tau / (tau + 2.0 * np.power(np.maximum(lam, 0.0), r))

    return g


# -- Section III-B: distributed smoothing ------------------------------------
def heat(t: float) -> Callable:
    """Heat kernel lowpass g(lambda) = exp(-t lambda)."""

    def g(lam):
        return np.exp(-t * np.asarray(lam, dtype=np.float64))

    return g


# -- Section III-C: distributed inverse filtering -----------------------------
def inverse_filter(g_psi: Callable, tau: float, r: int = 1) -> Callable:
    """Prop. 3: regularized deconvolution multiplier
    h(lambda) = tau g_psi(lambda) / (tau g_psi(lambda)^2 + 2 lambda^r)."""

    def h(lam):
        lam = np.asarray(lam, dtype=np.float64)
        gp = np.asarray(g_psi(lam), dtype=np.float64)
        return tau * gp / (tau * gp * gp + 2.0 * np.power(np.maximum(lam, 0.0), r))

    return h


# -- Section III-D: semi-supervised classification kernels -------------------
def ssl_multiplier(h: Callable, tau: float) -> Callable:
    """Optimal multiplier for argmin tau||f - Y_j||^2 + f^T h(P) f:
    g(lambda) = tau / (tau + h(lambda))."""

    def g(lam):
        return tau / (tau + np.asarray(h(lam), dtype=np.float64))

    return g


def power_kernel(r: int = 1) -> Callable:
    """h(lambda) = lambda^r — Tikhonov RKHS (S = L^r or L_norm^r)."""

    def h(lam):
        return np.power(np.maximum(np.asarray(lam, dtype=np.float64), 0.0), r)

    return h


def diffusion_kernel(beta: float) -> Callable:
    """Smola-Kondor diffusion: S = [exp(-(beta^2/2) L_norm)]^{-1}, i.e.
    h(lambda) = exp((beta^2/2) lambda)."""

    def h(lam):
        return np.exp(0.5 * beta * beta * np.asarray(lam, dtype=np.float64))

    return h


def inverse_cosine_kernel() -> Callable:
    """Smola-Kondor inverse cosine: S = [cos(pi lambda / 4)]^{-1} on L_norm,
    i.e. h(lambda) = 1 / cos(pi lambda / 4) (finite on [0, 2])."""

    def h(lam):
        return 1.0 / np.cos(np.pi * np.asarray(lam, dtype=np.float64) / 4.0)

    return h


def random_walk_kernel(beta: float, r: int) -> Callable:
    """r-step random walk: S = (beta I - L_norm)^{-r}, beta >= 2,
    i.e. h(lambda) = (beta - lambda)^{-r}."""

    def h(lam):
        return np.power(beta - np.asarray(lam, dtype=np.float64), -float(r))

    return h


def identity_multiplier() -> Callable:
    return lambda lam: np.ones_like(np.asarray(lam, dtype=np.float64))


# -- Section V rational (num/den) solve specs ---------------------------------
# Monomial-coefficient forms (low-degree-first tuples) of the filters whose
# application the Section-V solvers frame as Q x = y: `plan.solve` consumes
# these as num=/den= and derives the Jacobi split, the accelerated weights
# and the ARMA pole/residue recursion from one spec (see
# repro.dist.solvers / docs/PAPER_MAP.md Eqs. (23)-(30)).
def power_rational(tau: float, r: int = 1, scale: float = 1.0):
    """(num, den) of g(lambda) = tau / (tau + scale * lambda^r).

    scale=1 is the Section V-E / SSL family tau/(tau + lambda^r)
    (`ssl_multiplier(power_kernel(r), tau)`); scale=2 is Prop. 2's
    Tikhonov multiplier (see :func:`tikhonov_rational`)."""
    if r < 1:
        raise ValueError(f"r must be >= 1, got {r}")
    den = [float(tau)] + [0.0] * (r - 1) + [float(scale)]
    return (float(tau),), tuple(den)


def tikhonov_rational(tau: float, r: int = 1):
    """(num, den) of the Prop. 2 denoising multiplier tau/(tau + 2 lambda^r)
    — the rational form of :func:`tikhonov`, i.e. the exact-solver route to
    the Section IV-D denoising experiment (quickstart `--method jacobi`)."""
    return power_rational(tau, r, scale=2.0)


def inverse_filter_rational(psi_coeffs, tau: float, r: int = 1):
    """(num, den) of Prop. 3's regularized deconvolution multiplier for a
    *polynomial* blur g_psi(lambda) = sum_m psi_m lambda^m:

        h = tau g_psi / (tau g_psi^2 + 2 lambda^r),

    the rational form of :func:`inverse_filter`.  Computing h(P) y then
    solves (tau Psi^2 + 2 P^r) f = tau Psi y — `plan.solve` runs exactly
    that system distributed (numerator matvecs for the right-hand side,
    Jacobi/ARMA rounds for the solve)."""
    psi = np.asarray(psi_coeffs, dtype=np.float64)
    num = tau * psi
    den = tau * np.convolve(psi, psi)
    if len(den) < r + 1:
        den = np.concatenate([den, np.zeros(r + 1 - len(den))])
    den[r] += 2.0
    return tuple(float(c) for c in num), tuple(float(c) for c in den)


def random_walk_rational(tau: float, beta: float = 2.0, r: int = 3):
    """(num, den) of g = tau/(tau + (beta - lambda)^{-r}), the Fig. 2(c)
    random-walk setting (S = (beta I - L_norm)^{-r}): multiplying through by
    (beta - lambda)^r gives the biproper rational form
    tau (beta-l)^r / (tau (beta-l)^r + 1) whose partial fractions are the
    third-order ARMA recursion (`arma_random_walk_3` for tau=0.5, r=3)."""
    from numpy.polynomial import polynomial as npoly

    base = npoly.polypow([float(beta), -1.0], r)  # (beta - lambda)^r, low-first
    num = tau * np.asarray(base)
    den = num.copy()
    den[0] += 1.0
    return tuple(float(c) for c in num), tuple(float(c) for c in den)


# -- Section V-E experiment filters -------------------------------------------
def fig2_target(h: Callable, tau: float) -> Callable:
    """The Section V-E forward operator g(lambda) = (tau + h(lambda))/tau,
    whose inverse g^{-1} = tau/(tau+h) is what the methods compete to apply."""

    def g(lam):
        return (tau + np.asarray(h(lam), dtype=np.float64)) / tau

    return g
