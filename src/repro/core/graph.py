"""Weighted graphs, Laplacians and spectral bounds.

Implements the communication-graph model of Section I-A / II-C of the paper:
undirected weighted graphs G = {V, E, W}, the combinatorial Laplacian
L = D - W, the normalized Laplacian L_norm = D^{-1/2} L D^{-1/2}, the
Anderson-Morley upper bound on lambda_max used by Algorithm 1, and the
random sensor-network generator of Section IV-D.

Dense (N, N) arrays are used for the paper-scale experiments (N = 500); a
static Block-ELL sparse format (`BlockELL`) backs the Pallas SpMV kernel and
the sharded distributed path for large N.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


# ---------------------------------------------------------------------------
# Graph container
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Graph:
    """An undirected weighted graph held as a dense weight matrix.

    Attributes:
      W: (N, N) symmetric non-negative weight matrix, zero diagonal.
      coords: optional (N, d) vertex coordinates (sensor positions).
    """

    W: Array
    coords: Optional[Array] = None

    @property
    def n_vertices(self) -> int:
        return self.W.shape[0]

    @property
    def n_edges(self) -> int:
        """|E| — number of undirected edges with non-zero weight."""
        return int(jnp.count_nonzero(jnp.triu(self.W, k=1)))

    def degrees(self) -> Array:
        return jnp.sum(self.W, axis=1)

    def laplacian(self, kind: str = "combinatorial") -> Array:
        return laplacian(self.W, kind=kind)

    def lambda_max_bound(self, kind: str = "combinatorial") -> float:
        return lambda_max_bound(self.W, kind=kind)

    def is_connected(self) -> bool:
        return is_connected(np.asarray(self.W))


def laplacian(W: Array, kind: str = "combinatorial") -> Array:
    """Graph Laplacian of a weight matrix (Section II-C).

    kind:
      'combinatorial' : L = D - W
      'normalized'    : L_norm = D^{-1/2} L D^{-1/2}  (conventional 0/0 -> 0)
    """
    d = jnp.sum(W, axis=1)
    L = jnp.diag(d) - W
    if kind == "combinatorial":
        return L
    if kind == "normalized":
        inv_sqrt = jnp.where(d > 0, 1.0 / jnp.sqrt(jnp.where(d > 0, d, 1.0)), 0.0)
        return inv_sqrt[:, None] * L * inv_sqrt[None, :]
    raise ValueError(f"unknown Laplacian kind: {kind!r}")


def lambda_max_bound(W: Array, kind: str = "combinatorial") -> float:
    """Upper bound on lambda_max(L), computable from local degrees only.

    For the combinatorial Laplacian this is the Anderson-Morley bound
    lambda_max <= max{ d(m) + d(n) : m ~ n }  ([46], [47, Cor. 3.2]),
    exactly the bound suggested in Section IV-B. For the normalized
    Laplacian the spectrum is contained in [0, 2].
    """
    if kind == "normalized":
        return 2.0
    d = jnp.sum(W, axis=1)
    pair = d[:, None] + d[None, :]
    bound = jnp.max(jnp.where(W > 0, pair, 0.0))
    # Fall back to 2*max degree for edgeless graphs.
    bound = jnp.maximum(bound, jnp.max(d))
    return float(bound)


def k_scaling_matrix(W: Array, gamma: float) -> Array:
    """Ando & Zhang's K-scaling kernel matrix (Section III-D):

       S = (gamma I + D)^{-1/2} (gamma I + L) (gamma I + D)^{-1/2}

    Has the sparsity pattern of L; reduces to L_norm at gamma = 0.
    """
    n = W.shape[0]
    d = jnp.sum(W, axis=1)
    L = jnp.diag(d) - W
    scale = 1.0 / jnp.sqrt(gamma + d)
    return scale[:, None] * (gamma * jnp.eye(n) + L) * scale[None, :]


def is_connected(W: np.ndarray) -> bool:
    """BFS connectivity check (numpy; used by experiment drivers, as the paper
    discards disconnected random graph realizations — footnote 5)."""
    n = W.shape[0]
    adj = W > 0
    seen = np.zeros(n, dtype=bool)
    stack = [0]
    seen[0] = True
    while stack:
        u = stack.pop()
        nbrs = np.nonzero(adj[u] & ~seen)[0]
        seen[nbrs] = True
        stack.extend(nbrs.tolist())
    return bool(seen.all())


# ---------------------------------------------------------------------------
# Random sensor network of Section IV-D
# ---------------------------------------------------------------------------
def sensor_graph(
    key: Array,
    n: int = 500,
    theta: float = 0.074,
    kappa: float = 0.075,
) -> Graph:
    """Random sensor network of Section IV-D.

    n sensors placed uniformly in [0,1]^2; thresholded Gaussian kernel
    weights  w(e) = exp(-d(i,j)^2 / (2 theta^2)) if d(i,j) <= kappa else 0.
    """
    coords = jax.random.uniform(key, (n, 2))
    diff = coords[:, None, :] - coords[None, :, :]
    dist2 = jnp.sum(diff * diff, axis=-1)
    w = jnp.exp(-dist2 / (2.0 * theta * theta))
    w = jnp.where(dist2 <= kappa * kappa, w, 0.0)
    w = w - jnp.diag(jnp.diag(w))
    return Graph(W=w, coords=coords)


def connected_sensor_graph(
    key: Array, n: int = 500, theta: float = 0.074, kappa: float = 0.075,
    max_tries: int = 50,
) -> Tuple[Graph, Array]:
    """Draw sensor graphs until a connected one appears (paper footnote 5)."""
    for _ in range(max_tries):
        key, sub = jax.random.split(key)
        g = sensor_graph(sub, n=n, theta=theta, kappa=kappa)
        if g.is_connected():
            return g, key
    raise RuntimeError("could not draw a connected sensor graph")


def ring_graph(n: int, weight: float = 1.0) -> Graph:
    """Ring graph — the device-communication graph used by Chebyshev gossip."""
    W = np.zeros((n, n), dtype=np.float32)
    for i in range(n):
        W[i, (i + 1) % n] = weight
        W[(i + 1) % n, i] = weight
    return Graph(W=jnp.asarray(W))


def torus_graph(rows: int, cols: int, weight: float = 1.0) -> Graph:
    """2-D torus graph (device mesh topology analog: ICI torus)."""
    n = rows * cols
    W = np.zeros((n, n), dtype=np.float32)

    def idx(r, c):
        return (r % rows) * cols + (c % cols)

    for r in range(rows):
        for c in range(cols):
            u = idx(r, c)
            for v in (idx(r + 1, c), idx(r, c + 1)):
                W[u, v] = weight
                W[v, u] = weight
    return Graph(W=jnp.asarray(W))


def path_graph(n: int, weight: float = 1.0) -> Graph:
    W = np.zeros((n, n), dtype=np.float32)
    for i in range(n - 1):
        W[i, i + 1] = weight
        W[i + 1, i] = weight
    return Graph(W=jnp.asarray(W))


def two_cluster_graph(
    key: Array, n_per: int = 20, p_in: float = 0.9, p_out: float = 0.05
) -> Tuple[Graph, Array]:
    """Stochastic two-block graph + ground-truth labels, for SSL tests."""
    n = 2 * n_per
    labels = jnp.concatenate([jnp.zeros(n_per, jnp.int32), jnp.ones(n_per, jnp.int32)])
    u = jax.random.uniform(key, (n, n))
    u = jnp.triu(u, k=1)
    same = labels[:, None] == labels[None, :]
    p = jnp.where(same, p_in, p_out)
    upper = (u < p) & (jnp.triu(jnp.ones((n, n), bool), k=1))
    W = jnp.where(upper | upper.T, 1.0, 0.0)
    return Graph(W=W), labels


# ---------------------------------------------------------------------------
# Block-ELL static sparse format (TPU adaptation — DESIGN.md §3)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class BlockELL:
    """Static block-sparse matrix: fixed number of column-block slots per row
    block. Shapes are static, making the format compatible with XLA/Pallas.

      blocks:  (n_row_blocks, max_slots, bs_r, bs_c) block values
      indices: (n_row_blocks, max_slots) int32 column-block index per slot
      mask:    (n_row_blocks, max_slots) bool slot validity
      n:       logical (unpadded) dimension
    """

    blocks: Array
    indices: Array
    mask: Array
    n: int

    @property
    def block_shape(self) -> Tuple[int, int]:
        return (self.blocks.shape[2], self.blocks.shape[3])

    @property
    def n_row_blocks(self) -> int:
        return self.blocks.shape[0]

    @property
    def padded_n(self) -> int:
        return self.n_row_blocks * self.blocks.shape[2]

    def todense(self) -> Array:
        bs_r, bs_c = self.block_shape
        nb = self.n_row_blocks
        pn = self.padded_n
        out = jnp.zeros((pn, pn), self.blocks.dtype)
        for rb in range(nb):
            for s in range(self.blocks.shape[1]):
                cb = int(self.indices[rb, s])
                valid = bool(self.mask[rb, s])
                if valid:
                    out = out.at[
                        rb * bs_r : (rb + 1) * bs_r, cb * bs_c : (cb + 1) * bs_c
                    ].add(self.blocks[rb, s])
        return out[: self.n, : self.n]


def to_block_ell(
    M: np.ndarray, block_shape: Tuple[int, int] = (8, 128)
) -> BlockELL:
    """Convert a dense (sparse-in-content) matrix to Block-ELL.

    Blocks that are entirely zero are dropped; every row block gets the same
    (max over row blocks) number of slots, padded with masked zero blocks.
    Block shape defaults to the TPU-native (8, 128) tile.
    """
    M = np.asarray(M)
    n = M.shape[0]
    bs_r, bs_c = block_shape
    # Pad the (square) matrix to a multiple of lcm(bs_r, bs_c) in both dims so
    # the SpMV output vector can feed straight back in (Chebyshev recurrence).
    unit = int(np.lcm(bs_r, bs_c))
    n_pad = -(-n // unit) * unit
    nrb = n_pad // bs_r
    ncb = n_pad // bs_c
    Mp = np.pad(M, ((0, n_pad - n), (0, n_pad - n)))
    # Find nonzero blocks per row block.
    per_row: list[list[tuple[int, np.ndarray]]] = []
    for rb in range(nrb):
        row = []
        for cb in range(ncb):
            blk = Mp[rb * bs_r : (rb + 1) * bs_r, cb * bs_c : (cb + 1) * bs_c]
            if np.any(blk != 0):
                row.append((cb, blk))
        per_row.append(row)
    max_slots = max(1, max(len(r) for r in per_row))
    blocks = np.zeros((nrb, max_slots, bs_r, bs_c), dtype=M.dtype)
    indices = np.zeros((nrb, max_slots), dtype=np.int32)
    mask = np.zeros((nrb, max_slots), dtype=bool)
    for rb, row in enumerate(per_row):
        for s, (cb, blk) in enumerate(row):
            blocks[rb, s] = blk
            indices[rb, s] = cb
            mask[rb, s] = True
    return BlockELL(
        blocks=jnp.asarray(blocks),
        indices=jnp.asarray(indices),
        mask=jnp.asarray(mask),
        n=n,
    )


def block_ell_matvec_ref(A: BlockELL, x: Array) -> Array:
    """Reference Block-ELL matvec (pure jnp, vectorized over slots)."""
    bs_r, bs_c = A.block_shape
    pn = A.padded_n
    xp = jnp.pad(x, (0, pn - x.shape[0]))
    xb = xp.reshape(-1, bs_c)  # (n_col_blocks, bs_c)
    gathered = xb[A.indices]  # (nrb, slots, bs_c)
    prod = jnp.einsum("rsij,rsj->rsi", A.blocks, gathered)
    prod = jnp.where(A.mask[:, :, None], prod, 0.0)
    y = jnp.sum(prod, axis=1).reshape(pn)
    return y[: A.n]


def spatial_sort(graph: Graph) -> Tuple[Graph, np.ndarray]:
    """Reorder vertices by their y coordinate (strip order).

    With a thresholded-kernel sensor graph (connection radius kappa), two
    adjacent vertices differ in y-rank by at most the population of a
    kappa-height strip, so equal contiguous index blocks of size
    nl >> n*kappa couple only with adjacent blocks: W becomes block-
    tridiagonal and the sharded halo path of `core.distributed` is exact
    (`partition_banded` reports the residual `leak` so callers can verify).
    """
    assert graph.coords is not None, "spatial_sort needs coordinates"
    coords = np.asarray(graph.coords)
    order = np.argsort(coords[:, 1], kind="stable")
    W = np.asarray(graph.W)[np.ix_(order, order)]
    return Graph(W=jnp.asarray(W), coords=jnp.asarray(coords[order])), order
