from .checkpoint import (
    latest_checkpoint,
    load_checkpoint,
    restore_arrays,
    save_checkpoint,
)

__all__ = [
    "latest_checkpoint", "load_checkpoint", "restore_arrays", "save_checkpoint",
]
