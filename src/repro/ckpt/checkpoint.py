"""Atomic, reshardable checkpoints (fault-tolerance substrate).

Layout: <dir>/step_<N>/ holding arrays.npz (path-keyed leaves) +
manifest.json. Writes go to a tmp directory then os.replace — a crashed
writer never leaves a half checkpoint visible. Arrays are stored unsharded
(gathered); on restore the caller device_puts them under *any* mesh, so a
job restarted on a different topology (elastic restart) resharding is free.
Async saves run on a daemon thread; `wait_pending()` joins them (called
before exit and before deleting old checkpoints).

At 1000+-node scale the gather-on-save would be replaced by per-shard files
keyed by (leaf, shard-index) — the manifest format already records shapes
and dtypes per leaf to support that layout.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_PENDING: List[threading.Thread] = []


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(
    directory: str,
    step: int,
    trees: Dict[str, Any],
    keep_last: int = 3,
    async_save: bool = False,
    extra: Optional[Dict] = None,
) -> str:
    """trees: named pytrees, e.g. {'params': ..., 'opt_state': ...}."""
    os.makedirs(directory, exist_ok=True)
    arrays: Dict[str, np.ndarray] = {}
    manifest = {"step": int(step), "trees": {}, "extra": extra or {}}
    for name, tree in trees.items():
        flat = _flatten_with_paths(tree)
        manifest["trees"][name] = {
            k: {"shape": list(v.shape), "dtype": str(v.dtype)}
            for k, v in flat.items()
        }
        for k, v in flat.items():
            arrays[f"{name}/{k}"] = v

    final = os.path.join(directory, f"step_{step:08d}")
    tmp = f"{final}.tmp{os.getpid()}_{threading.get_ident()}_{id(trees)}"

    def write():
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
        _gc(directory, keep_last)

    if async_save:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        _PENDING.append(t)
    else:
        write()
    return final


def wait_pending() -> None:
    while _PENDING:
        _PENDING.pop().join()


def _gc(directory: str, keep_last: int) -> None:
    steps = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and ".tmp" not in d
    )
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_checkpoint(directory: str) -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    steps = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and ".tmp" not in d
        and os.path.exists(os.path.join(directory, d, "manifest.json"))
    )
    return os.path.join(directory, steps[-1]) if steps else None


def load_checkpoint(path: str) -> Tuple[int, Dict[str, Dict[str, np.ndarray]], Dict]:
    """Returns (step, {tree_name: {leaf_path: array}}, extra)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    trees: Dict[str, Dict[str, np.ndarray]] = {}
    for name, leaves in manifest["trees"].items():
        trees[name] = {k: data[f"{name}/{k}"] for k in leaves}
    return manifest["step"], trees, manifest.get("extra", {})


def restore_arrays(flat: Dict[str, np.ndarray], target_tree,
                   shardings=None):
    """Rebuild a pytree like `target_tree` from path-keyed arrays; if
    `shardings` (same-structure tree) is given, device_put each leaf under
    it — this is the elastic-reshard path (any mesh works)."""
    paths = jax.tree_util.tree_flatten_with_path(target_tree)[0]
    treedef = jax.tree_util.tree_structure(target_tree)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    leaves = []
    for (path, leaf), shd in zip(paths, shard_leaves):
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        arr = np.asarray(flat[key]).astype(leaf.dtype)
        if shd is not None:
            leaves.append(jax.device_put(arr, shd))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)
