"""Mixture-of-experts FFN with capacity-bounded scatter dispatch.

Static-shape dispatch suitable for pjit/GSPMD at scale:
  1. route: top-k experts per token (softmax over the selected logits);
  2. sort the (token, expert) assignments by expert and compute each
     assignment's slot within its expert's capacity C (assignments past C
     drop — standard capacity-factor semantics);
  3. scatter tokens into a (E, C, d) buffer, run the expert FFNs as one
     batched einsum (E experts on the 'expert'->model mesh axis), gather
     back and combine with routing weights.

Memory: the (E, C, d) buffer is top_k/capacity_factor times the token
activations — sharded over ('expert' x 'batch'), never materialized as the
(T, E, C) one-hot of GShard-style dispatch.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

Array = jax.Array


def capacity(n_tokens: int, n_experts: int, top_k: int,
             capacity_factor: float, multiple: int = 8) -> int:
    c = int(n_tokens * top_k * capacity_factor / n_experts)
    c = max(multiple, -(-c // multiple) * multiple)
    return min(c, n_tokens)


def moe_ffn(
    x: Array,
    router: Array,
    we_gate: Array,
    we_up: Array,
    we_down: Array,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    router_dtype=jnp.float32,
) -> Array:
    """x: (T, d); router: (d, E); we_*: (E, d, F)/(E, F, d). Returns (T, d)."""
    T, d = x.shape
    E = router.shape[1]
    C = capacity(T, E, top_k, capacity_factor)

    logits = (x.astype(router_dtype) @ router.astype(router_dtype))  # (T, E)
    gate_vals, gate_idx = jax.lax.top_k(logits, top_k)               # (T, k)
    gate_w = jax.nn.softmax(gate_vals, axis=-1)                      # (T, k)

    # Flatten assignments and compute capacity slots.
    expert_flat = gate_idx.reshape(-1)                               # (T*k,)
    token_flat = jnp.repeat(jnp.arange(T), top_k)                    # (T*k,)
    weight_flat = gate_w.reshape(-1)

    order = jnp.argsort(expert_flat)                                 # stable
    e_sorted = expert_flat[order]
    t_sorted = token_flat[order]
    w_sorted = weight_flat[order]
    counts = jnp.bincount(expert_flat, length=E)                     # (E,)
    starts = jnp.cumsum(counts) - counts                             # exclusive
    slot = jnp.arange(T * top_k) - starts[e_sorted]                  # (T*k,)
    keep = slot < C
    e_safe = jnp.where(keep, e_sorted, 0)
    s_safe = jnp.where(keep, slot, 0)

    # Dispatch: (E, C, d)
    buf = jnp.zeros((E, C, d), x.dtype)
    contrib = jnp.where(keep[:, None], x[t_sorted], 0.0)
    buf = buf.at[e_safe, s_safe].add(contrib, mode="drop")

    # Expert FFN (swiglu) as batched einsum over the expert axis.
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, we_gate))
    h = h * jnp.einsum("ecd,edf->ecf", buf, we_up)
    y_buf = jnp.einsum("ecf,efd->ecd", h, we_down)                   # (E, C, d)

    # Combine: gather back, weight, scatter-add over tokens.
    y_assign = y_buf[e_safe, s_safe]                                 # (T*k, d)
    y_assign = jnp.where(keep[:, None], y_assign, 0.0) * w_sorted[:, None].astype(x.dtype)
    out = jnp.zeros((T, d), x.dtype).at[t_sorted].add(y_assign)
    return out


def shared_expert_ffn(x: Array, p: Dict) -> Array:
    h = jax.nn.silu(x @ p["ws_gate"]) * (x @ p["ws_up"])
    return h @ p["ws_down"]


def moe_ffn_grouped(
    x: Array,
    router: Array,
    we_gate: Array,
    we_up: Array,
    we_down: Array,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    n_groups: int = 1,
    rules=None,
    router_dtype=jnp.float32,
) -> Array:
    """Group-local dispatch (§Perf optimization over `moe_ffn`).

    The baseline sorts all T*k assignments globally — under GSPMD a global
    sort of a sharded array is a cross-device sorting network (massive
    collective traffic). Here tokens are split into `n_groups` groups
    aligned with the data shards; the sort/slotting is per-group (local),
    and the only cross-device movement is the dispatch scatter into the
    (G, E, Cg, d) buffer — the classic MoE all-to-all, O(token bytes).
    """
    T, d = x.shape
    G = n_groups
    assert T % G == 0, (T, G)
    Tg = T // G
    E = router.shape[1]
    C = capacity(Tg, E, top_k, capacity_factor)

    xg = x.reshape(G, Tg, d)
    if rules is not None:
        xg = rules.constrain(xg, "moe_group", None, None)
    logits = jnp.einsum("gtd,de->gte", xg.astype(router_dtype),
                        router.astype(router_dtype))
    gate_vals, gate_idx = jax.lax.top_k(logits, top_k)       # (G, Tg, k)
    gate_w = jax.nn.softmax(gate_vals, axis=-1)

    e_flat = gate_idx.reshape(G, Tg * top_k)
    t_flat = jnp.tile(jnp.repeat(jnp.arange(Tg), top_k)[None], (G, 1))
    w_flat = gate_w.reshape(G, Tg * top_k)

    order = jnp.argsort(e_flat, axis=1)                      # per-group sort
    e_sorted = jnp.take_along_axis(e_flat, order, axis=1)
    t_sorted = jnp.take_along_axis(t_flat, order, axis=1)
    w_sorted = jnp.take_along_axis(w_flat, order, axis=1)
    if rules is not None:
        # keep the assignment metadata group-sharded so the dispatch
        # gather/scatter stays local to each group's shard
        e_sorted = rules.constrain(e_sorted, "moe_group", None)
        t_sorted = rules.constrain(t_sorted, "moe_group", None)
        w_sorted = rules.constrain(w_sorted, "moe_group", None)
    counts = jax.vmap(lambda e: jnp.bincount(e, length=E))(e_flat)
    starts = jnp.cumsum(counts, axis=1) - counts
    slot = jnp.arange(Tg * top_k)[None, :] - jnp.take_along_axis(
        starts, e_sorted, axis=1)
    keep = slot < C
    e_safe = jnp.where(keep, e_sorted, 0)
    s_safe = jnp.where(keep, slot, 0)

    # Dispatch into (G, E, C, d): cross-device all-to-all happens here.
    def disp(xg_g, tok, es, ss, kp):
        contrib = jnp.where(kp[:, None], xg_g[tok], 0.0)
        return jnp.zeros((E, C, d), x.dtype).at[es, ss].add(
            contrib, mode="drop")

    buf = jax.vmap(disp)(xg, t_sorted, e_safe, s_safe, keep)  # (G,E,C,d)
    if rules is not None:
        buf = rules.constrain(buf, "moe_group", "expert", None, None)

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, we_gate))
    h = h * jnp.einsum("gecd,edf->gecf", buf, we_up)
    y_buf = jnp.einsum("gecf,efd->gecd", h, we_down)
    if rules is not None:
        y_buf = rules.constrain(y_buf, "moe_group", "expert", None, None)

    def comb(yb, tok, es, ss, kp, w):
        vals = yb[es, ss]
        vals = jnp.where(kp[:, None], vals, 0.0) * w[:, None].astype(x.dtype)
        return jnp.zeros((Tg, d), x.dtype).at[tok].add(vals)

    out = jax.vmap(comb)(y_buf, t_sorted, e_safe, s_safe, keep, w_sorted)
    if rules is not None:
        out = rules.constrain(out, "moe_group", None, None)
    return out.reshape(T, d)
