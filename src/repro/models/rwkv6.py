"""RWKV6 (Finch) token/channel mixing — attention-free, data-dependent decay.

Faithful to the RWKV6 recurrence

    y_t = r_t . (S_{t-1} + (u * k_t) v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T,   w_t = exp(-exp(base + lora(x_t)))

with per-(head, channel) data-dependent decay w_t. Static token-shift mix
coefficients stand in for RWKV6's LoRA token-shift (DESIGN.md §4 records the
simplification). Carried state per layer:
    wkv   (B, H, hd, hd)   matrix-valued wkv state
    shift (B, D)           last normed input of the time-mix block
    cm_shift (B, D)        last normed input of the channel-mix block

The matrix state is the whole "KV cache": decode at 500k context carries
O(H * hd^2), not O(S) — why this arch runs the long_500k cell.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from . import layers as nn

Array = jax.Array


def _project(x, xprev, mu, w):
    return (x + mu * (xprev - x)) @ w


def _decay(x, xprev, p):
    xw = x + p["mu_w"] * (xprev - x)
    lora = jnp.tanh(xw @ p["w_dd1"]) @ p["w_dd2"]
    return jnp.exp(-jnp.exp((p["decay_base"] + lora).astype(jnp.float32)))


def time_mix(
    x: Array, p: Dict, state: Tuple[Array, Array], n_heads: int
) -> Tuple[Array, Tuple[Array, Array]]:
    """x: (B, S, D) normed input. state: (wkv (B,H,K,V), shift (B,D))."""
    B, S, D = x.shape
    H = n_heads
    hd = D // H
    wkv0, shift0 = state
    xprev = nn.token_shift(x, shift0)

    r = _project(x, xprev, p["mu_r"], p["w_r"]).reshape(B, S, H, hd)
    k = _project(x, xprev, p["mu_k"], p["w_k"]).reshape(B, S, H, hd)
    v = _project(x, xprev, p["mu_v"], p["w_v"]).reshape(B, S, H, hd)
    g = jax.nn.silu(_project(x, xprev, p["mu_g"], p["w_g"]))
    w = _decay(x, xprev, p).reshape(B, S, H, hd)
    u = p["bonus"].astype(jnp.float32)  # (H, hd)

    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def step(S_carry, inputs):
        r_t, k_t, v_t, w_t = inputs  # (B,H,hd) each
        kv = k_t[..., :, None] * v_t[..., None, :]          # (B,H,K,V)
        y_t = jnp.einsum(
            "bhk,bhkv->bhv", r_t, S_carry + u[None, :, :, None] * kv
        )
        S_new = w_t[..., None] * S_carry + kv
        return S_new, y_t

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, w.astype(jnp.float32)))
    wkv_final, y = jax.lax.scan(step, wkv0.astype(jnp.float32), xs)
    y = jnp.moveaxis(y, 0, 1)                                # (B,S,H,hd)
    y = nn.group_norm_heads(y, p["ln_x"]).astype(x.dtype)
    y = (y.reshape(B, S, D) * g) @ p["w_o"]
    return y, (wkv_final.astype(wkv0.dtype), x[:, -1, :])


def channel_mix(
    x: Array, p: Dict, shift0: Array
) -> Tuple[Array, Array]:
    xprev = nn.token_shift(x, shift0)
    out = nn.rwkv_channel_mix(
        x, xprev, p["mu_ck"], p["mu_cr"], p["w_ck"], p["w_cv"], p["w_cr"]
    )
    return out, x[:, -1, :]


def init_state(cfg, batch: int, dtype) -> Dict:
    H, hd, D = cfg.n_heads, cfg.hd, cfg.d_model
    L = cfg.n_layers
    return {
        "wkv": jnp.zeros((L, batch, H, hd, hd), jnp.float32),
        "shift": jnp.zeros((L, batch, D), dtype),
        "cm_shift": jnp.zeros((L, batch, D), dtype),
    }
