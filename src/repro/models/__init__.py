"""LM substrate: architecture-generic models for the assigned pool."""
from . import decode, layers, mamba, model, moe, params, rwkv6, steps
from .model import RunConfig, forward, lm_loss
from .params import count_params, init_params, param_pspecs, param_shapes

__all__ = [
    "decode", "layers", "mamba", "model", "moe", "params", "rwkv6", "steps",
    "RunConfig", "forward", "lm_loss",
    "count_params", "init_params", "param_pspecs", "param_shapes",
]
