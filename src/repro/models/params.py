"""Parameter metadata trees: one source of truth for shapes, logical sharding
axes, and initialization of every architecture in the pool.

`abstract_params(cfg)` builds a pytree of ParamMeta; from it we derive
  * init_params(cfg, key)        — materialized tree (smoke tests / training)
  * param_shapes(cfg)            — ShapeDtypeStruct tree (dry-run lowering)
  * param_pspecs(cfg, rules)     — PartitionSpec tree (in_shardings)

All per-layer tensors are stacked with a leading 'layers' axis and consumed
by lax.scan in models/model.py.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..dist.sharding import ShardingRules

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ParamMeta:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"       # normal | zeros | ones
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _attn_metas(cfg: ModelConfig, L: int, cross: bool = False) -> Dict:
    d, hd = cfg.d_model, cfg.hd
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    sfx = "_x" if cross else ""
    # Self-attention uses a FUSED qkv projection: one column-parallel matmul
    # -> one partial-sum all-reduce of dx in backward instead of three
    # (§Perf iteration C2; Megatron fused-QKV). Cross attention keeps k/v
    # separate (different input stream).
    if cross:
        m = {
            f"wq{sfx}": ParamMeta((L, d, nq * hd), ("layers", "embed", "heads")),
            f"wk{sfx}": ParamMeta((L, d, nkv * hd), ("layers", "embed", "kv_heads")),
            f"wv{sfx}": ParamMeta((L, d, nkv * hd), ("layers", "embed", "kv_heads")),
            f"wo{sfx}": ParamMeta((L, nq * hd, d), ("layers", "heads", "embed")),
        }
        if cfg.qkv_bias:
            m[f"bq{sfx}"] = ParamMeta((L, nq * hd), ("layers", "heads"), "zeros")
            m[f"bk{sfx}"] = ParamMeta((L, nkv * hd), ("layers", "kv_heads"), "zeros")
            m[f"bv{sfx}"] = ParamMeta((L, nkv * hd), ("layers", "kv_heads"), "zeros")
        return m
    fused = (nq + 2 * nkv) * hd
    m = {
        "wqkv": ParamMeta((L, d, fused), ("layers", "embed", "heads")),
        "wo": ParamMeta((L, nq * hd, d), ("layers", "heads", "embed")),
    }
    if cfg.qkv_bias:
        m["bqkv"] = ParamMeta((L, fused), ("layers", "heads"), "zeros")
    return m


def _mla_metas(cfg: ModelConfig, L: int) -> Dict:
    d, hd = cfg.d_model, cfg.hd
    nq = cfg.n_heads
    r_kv, r_q, r_rope = cfg.kv_lora_rank, cfg.q_lora_rank, cfg.rope_head_dim
    return {
        "wdq": ParamMeta((L, d, r_q), ("layers", "embed", "kv_lora")),
        "q_norm": ParamMeta((L, r_q), ("layers", "kv_lora"), "ones"),
        "wuq": ParamMeta((L, r_q, nq * hd), ("layers", "kv_lora", "heads")),
        "wq_rope": ParamMeta((L, r_q, nq * r_rope), ("layers", "kv_lora", "heads")),
        "wdkv": ParamMeta((L, d, r_kv), ("layers", "embed", "kv_lora")),
        "kv_norm": ParamMeta((L, r_kv), ("layers", "kv_lora"), "ones"),
        "wk_rope": ParamMeta((L, d, r_rope), ("layers", "embed", "head_dim")),
        "wuk": ParamMeta((L, r_kv, nq * hd), ("layers", "kv_lora", "heads")),
        "wuv": ParamMeta((L, r_kv, nq * hd), ("layers", "kv_lora", "heads")),
        "wo": ParamMeta((L, nq * hd, d), ("layers", "heads", "embed")),
    }


def _rwkv_metas(cfg: ModelConfig, L: int) -> Dict:
    d, F = cfg.d_model, cfg.d_ff
    H, hd = cfg.n_heads, cfg.hd
    lora = 64
    return {
        # time mix
        "w_r": ParamMeta((L, d, d), ("layers", "embed", "heads")),
        "w_k": ParamMeta((L, d, d), ("layers", "embed", "heads")),
        "w_v": ParamMeta((L, d, d), ("layers", "embed", "heads")),
        "w_g": ParamMeta((L, d, d), ("layers", "embed", "heads")),
        "w_o": ParamMeta((L, d, d), ("layers", "heads", "embed")),
        "mu_r": ParamMeta((L, d), ("layers", "embed"), "zeros"),
        "mu_k": ParamMeta((L, d), ("layers", "embed"), "zeros"),
        "mu_v": ParamMeta((L, d), ("layers", "embed"), "zeros"),
        "mu_g": ParamMeta((L, d), ("layers", "embed"), "zeros"),
        "mu_w": ParamMeta((L, d), ("layers", "embed"), "zeros"),
        "decay_base": ParamMeta((L, d), ("layers", "embed"), "zeros"),
        "w_dd1": ParamMeta((L, d, lora), ("layers", "embed", None)),
        "w_dd2": ParamMeta((L, lora, d), ("layers", None, "embed")),
        "bonus": ParamMeta((L, H, hd), ("layers", "heads", None), "zeros"),
        "ln_x": ParamMeta((L, H, hd), ("layers", "heads", None), "ones"),
        # channel mix
        "w_ck": ParamMeta((L, d, F), ("layers", "embed", "ffn")),
        "w_cv": ParamMeta((L, F, d), ("layers", "ffn", "embed")),
        "w_cr": ParamMeta((L, d, d), ("layers", "embed", None)),
        "mu_ck": ParamMeta((L, d), ("layers", "embed"), "zeros"),
        "mu_cr": ParamMeta((L, d), ("layers", "embed"), "zeros"),
    }


def _mamba_metas(cfg: ModelConfig, L: int) -> Dict:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    st = cfg.ssm_state
    dt_rank = max(1, d // 16)
    return {
        "w_in": ParamMeta((L, d, 2 * d_in), ("layers", "embed", "ffn")),
        "conv_w": ParamMeta((L, cfg.conv_width, d_in), ("layers", None, "ffn")),
        "conv_b": ParamMeta((L, d_in), ("layers", "ffn"), "zeros"),
        "w_bcdt": ParamMeta((L, d_in, 2 * st + dt_rank), ("layers", "ffn", None)),
        "w_dt": ParamMeta((L, dt_rank, d_in), ("layers", None, "ffn")),
        "dt_bias": ParamMeta((L, d_in), ("layers", "ffn"), "zeros"),
        "A_log": ParamMeta((L, d_in, st), ("layers", "ffn", "state"), "ones"),
        "D_skip": ParamMeta((L, d_in), ("layers", "ffn"), "ones"),
        "w_ssm_out": ParamMeta((L, d_in, d), ("layers", "ffn", "embed")),
    }


def _ffn_metas(cfg: ModelConfig, L: int) -> Dict:
    d, F = cfg.d_model, cfg.d_ff
    if cfg.n_experts > 0:
        E = cfg.n_experts
        m = {
            "router": ParamMeta((L, d, E), ("layers", "embed", "expert")),
            "we_gate": ParamMeta((L, E, d, F), ("layers", "expert", "embed", "ffn")),
            "we_up": ParamMeta((L, E, d, F), ("layers", "expert", "embed", "ffn")),
            "we_down": ParamMeta((L, E, F, d), ("layers", "expert", "ffn", "embed")),
        }
        if cfg.n_shared_experts > 0:
            Fs = F * cfg.n_shared_experts
            m.update({
                "ws_gate": ParamMeta((L, d, Fs), ("layers", "embed", "ffn")),
                "ws_up": ParamMeta((L, d, Fs), ("layers", "embed", "ffn")),
                "ws_down": ParamMeta((L, Fs, d), ("layers", "ffn", "embed")),
            })
        return m
    if cfg.act == "swiglu":
        # Fused gate+up: one column-parallel matmul -> one dx all-reduce in
        # backward instead of two (§Perf iteration C2).
        return {
            "w_gu": ParamMeta((L, d, 2 * F), ("layers", "embed", "ffn")),
            "w_down": ParamMeta((L, F, d), ("layers", "ffn", "embed")),
        }
    # gelu MLP (starcoder2 / whisper)
    return {
        "w_in": ParamMeta((L, d, F), ("layers", "embed", "ffn")),
        "b_in": ParamMeta((L, F), ("layers", "ffn"), "zeros"),
        "w_out": ParamMeta((L, F, d), ("layers", "ffn", "embed")),
        "b_out": ParamMeta((L, d), ("layers", "embed"), "zeros"),
    }


def _norm_metas(cfg: ModelConfig, L: int, names) -> Dict:
    d = cfg.d_model
    m = {}
    for nm in names:
        m[nm] = ParamMeta((L, d), ("layers", "embed"), "ones")
        if cfg.norm == "ln":
            m[nm + "_bias"] = ParamMeta((L, d), ("layers", "embed"), "zeros")
    return m


def abstract_params(cfg: ModelConfig) -> Dict:
    L = cfg.n_layers
    d = cfg.d_model
    layers: Dict = {}
    if cfg.mixer == "mla":
        layers.update(_mla_metas(cfg, L))
    elif cfg.mixer == "rwkv6":
        layers.update(_rwkv_metas(cfg, L))
    else:
        layers.update(_attn_metas(cfg, L))
        if cfg.mixer == "hymba":
            layers.update(_mamba_metas(cfg, L))
    if cfg.mixer != "rwkv6":  # rwkv's channel mix is its FFN
        layers.update(_ffn_metas(cfg, L))
    norm_names = ["norm1", "norm2"]
    if cfg.is_encoder_decoder:
        layers.update(_attn_metas(cfg, L, cross=True))
        norm_names.append("norm3")
    layers.update(_norm_metas(cfg, L, norm_names))

    tree: Dict = {
        "embed": ParamMeta((cfg.vocab_size, d), ("vocab", "embed")),
        "lm_head": ParamMeta((cfg.vocab_size, d), ("vocab", "embed")),
        "final_norm": ParamMeta((d,), ("embed",), "ones"),
        "layers": layers,
    }
    if cfg.norm == "ln":
        tree["final_norm_bias"] = ParamMeta((d,), ("embed",), "zeros")
    if cfg.is_encoder_decoder:
        E = cfg.n_encoder_layers
        enc: Dict = {}
        enc.update(_attn_metas(cfg, E))
        enc.update(_ffn_metas(cfg, E))
        enc.update(_norm_metas(cfg, E, ["norm1", "norm2"]))
        tree["encoder"] = {
            "layers": enc,
            "final_norm": ParamMeta((d,), ("embed",), "ones"),
        }
        if cfg.norm == "ln":
            tree["encoder"]["final_norm_bias"] = ParamMeta((d,), ("embed",), "zeros")
    return tree


# ---------------------------------------------------------------------------
# Materialization / shapes / shardings
# ---------------------------------------------------------------------------
def _is_meta(x) -> bool:
    return isinstance(x, ParamMeta)


def init_params(cfg: ModelConfig, key: Array, dtype=None) -> Dict:
    dtype = dtype or cfg.jnp_dtype
    metas, treedef = jax.tree_util.tree_flatten(
        abstract_params(cfg), is_leaf=_is_meta
    )
    keys = jax.random.split(key, len(metas))
    leaves = []
    for meta, k in zip(metas, keys):
        if meta.init == "zeros":
            leaves.append(jnp.zeros(meta.shape, dtype))
        elif meta.init == "ones":
            leaves.append(jnp.ones(meta.shape, dtype))
        else:
            leaves.append(
                (jax.random.normal(k, meta.shape, jnp.float32) * meta.scale)
                .astype(dtype)
            )
    return jax.tree_util.tree_unflatten(treedef, leaves)


def param_shapes(cfg: ModelConfig, dtype=None) -> Dict:
    dtype = dtype or cfg.jnp_dtype
    return jax.tree_util.tree_map(
        lambda m: jax.ShapeDtypeStruct(m.shape, dtype),
        abstract_params(cfg),
        is_leaf=_is_meta,
    )


def param_pspecs(cfg: ModelConfig, rules: ShardingRules) -> Dict:
    return jax.tree_util.tree_map(
        lambda m: rules.spec(*m.axes), abstract_params(cfg), is_leaf=_is_meta
    )


def param_shardings(cfg: ModelConfig, rules: ShardingRules) -> Dict:
    from jax.sharding import NamedSharding

    return jax.tree_util.tree_map(
        lambda m: NamedSharding(rules.mesh, rules.spec(*m.axes)),
        abstract_params(cfg),
        is_leaf=_is_meta,
    )


def count_params(cfg: ModelConfig) -> int:
    metas = jax.tree_util.tree_leaves(abstract_params(cfg), is_leaf=_is_meta)
    return int(sum(np.prod(m.shape) for m in metas))
