"""train_step / serve_step builders — the functions the launcher jits.

Under pjit, data parallelism is implicit in the sharded global batch; the
optimizer update runs on ZeRO-friendly sharded state. `serve_step` is one
token of batched decoding against the KV cache.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..dist.sharding import ShardingRules
from ..optim.adamw import AdamWState, adamw_update, clip_by_global_norm
from . import decode as dec
from .model import RunConfig, forward, lm_loss

Array = jax.Array


def build_loss_fn(cfg: ModelConfig, rules: ShardingRules, run: RunConfig):
    def loss_fn(params: Dict, batch: Dict) -> Array:
        logits = forward(
            cfg, params, batch["tokens"], rules, run,
            vision_embeds=batch.get("vision_embeds"),
            encoder_frames=batch.get("encoder_frames"),
        )
        return lm_loss(logits, batch["labels"])

    return loss_fn


def build_train_step(
    cfg: ModelConfig,
    rules: ShardingRules,
    run: RunConfig,
    lr: float = 3e-4,
    max_grad_norm: float = 1.0,
    weight_decay: float = 0.01,
):
    loss_fn = build_loss_fn(cfg, rules, run)

    def train_step(params: Dict, opt_state: AdamWState, batch: Dict):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        params, opt_state = adamw_update(
            grads, opt_state, params, lr=lr, weight_decay=weight_decay
        )
        metrics = {"loss": loss, "grad_norm": gnorm, "step": opt_state.step}
        return params, opt_state, metrics

    return train_step


def build_serve_step(cfg: ModelConfig, rules: ShardingRules, run: RunConfig):
    def serve_step(params: Dict, cache: Dict, tokens: Array):
        """One batched decode step: tokens (B, 1) -> (next (B,), cache)."""
        logits, cache = dec.decode_step(cfg, params, cache, tokens, rules, run)
        nxt = jnp.argmax(logits, axis=-1).astype(tokens.dtype)
        return nxt, cache

    return serve_step
