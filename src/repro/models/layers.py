"""Shared neural primitives: norms, rotary embeddings, FFNs, attention math.

All functions are pure and operate on explicitly-shaped arrays; sharding
annotations are applied by the caller (models/model.py) via ShardingRules.
Attention exposes three implementations — 'ref' (materialized logits),
'chunked' (lax.scan over query blocks; flash-attention-style O(chunk*S)
working set at the XLA level), and 'flash' (the Pallas kernel, TPU) — the
§Perf hillclimb toggles these.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops as kops

Array = jax.Array

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def group_norm_heads(x: Array, scale: Array, eps: float = 64e-5) -> Array:
    """Per-head LayerNorm used by RWKV's wkv output; x (..., H, hd)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings (+ M-RoPE)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float = 10_000.0) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: Array, positions: Array, theta: float = 10_000.0) -> Array:
    """x: (B, H, S, hd); positions: (B, S) absolute token positions."""
    b, h, s, d = x.shape
    freqs = rope_freqs(d, theta)                       # (d/2,)
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,S,d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: Array,
    positions: Array,
    sections: Tuple[int, ...],
    theta: float = 10_000.0,
) -> Array:
    """M-RoPE (Qwen2-VL): positions (B, 3, S) = (temporal, h, w) id streams;
    `sections` splits the half-dim rotary frequency bands among the streams.
    In the text-only backbone stub the three streams coincide."""
    b, h, s, d = x.shape
    assert sum(sections) == d // 2, "sections must cover half the head dim"
    freqs = rope_freqs(d, theta)                       # (d/2,)
    # Pick the position stream per frequency band.
    stream = jnp.concatenate([
        jnp.full((sec,), i, dtype=jnp.int32) for i, sec in enumerate(sections)
    ])                                                  # (d/2,)
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32), stream[None, :, None].repeat(b, 0), axis=1
    )  # (B, d/2, S) — per-band positions
    ang = jnp.einsum("bfs,f->bsf", pos, freqs)[:, None]  # (B,1,S,d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_at(positions: Array, d_model: int) -> Array:
    """Whisper-style sinusoidal embeddings at given (possibly traced)
    positions; positions (..., S) -> (..., S, d_model)."""
    pos = positions.astype(jnp.float32)[..., None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)
    ang = pos / jnp.power(10_000.0, dim / d_model)
    pe = jnp.zeros(positions.shape + (d_model,), jnp.float32)
    pe = pe.at[..., 0::2].set(jnp.sin(ang)).at[..., 1::2].set(jnp.cos(ang))
    return pe


def sinusoidal_positions(seq: int, d_model: int) -> Array:
    return sinusoidal_at(jnp.arange(seq), d_model)


# ---------------------------------------------------------------------------
# Attention math
# ---------------------------------------------------------------------------
def _window_mask(rows: Array, cols: Array, causal: bool, window: int) -> Array:
    ok = jnp.ones(jnp.broadcast_shapes(rows.shape, cols.shape), bool)
    if causal:
        ok &= cols <= rows
    if window > 0:
        ok &= cols > rows - window
    return ok


def _compute_dtype(x: Array) -> Array:
    """f8 caches compute in bf16 (dequant fuses into the dot on TPU);
    fp32 accumulation comes from preferred_element_type."""
    if x.dtype in (jnp.float8_e4m3fn, jnp.float8_e5m2):
        return x.astype(jnp.bfloat16)
    return x


def attention_ref(q, k, v, *, causal=True, window=0, scale=None,
                  kv_valid: Optional[Array] = None) -> Array:
    """Materialized-logits attention. q (B,Hq,Sq,hd), k/v (B,Hkv,Sk,hd).
    kv_valid: optional (B, Sk) bool mask of valid cache slots (decode).

    Operands stay in their storage dtype (bf16 / dequantized f8) with fp32
    accumulation via preferred_element_type — the KV cache is never
    materialized as an fp32 copy (§Perf decode iteration)."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    k = _compute_dtype(k)
    v = _compute_dtype(v)
    qg = q.reshape(b, hkv, group, sq, d).astype(k.dtype)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    rows = jnp.arange(sk - sq, sk)[:, None] if causal else jnp.arange(sq)[:, None]
    cols = jnp.arange(sk)[None, :]
    mask = _window_mask(rows, cols, causal, window)
    if kv_valid is not None:
        mask = mask[None] & kv_valid[:, None, :]
        mask = mask[:, None, None]  # (B,1,1,Sq,Sk)
    s = jnp.where(mask, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, hq, sq, v.shape[-1]).astype(q.dtype)


def attention_chunked(q, k, v, *, causal=True, window=0, scale=None,
                      chunk: int = 1024, unroll: bool = False) -> Array:
    """lax.scan over query chunks: working set O(chunk * Sk) instead of
    O(Sq * Sk). Equivalent numerics to attention_ref. `unroll` unrolls the
    chunk scan (dry-run FLOP accounting — while bodies are counted once)."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    if sq <= chunk or sq % chunk != 0:
        # short or non-chunk-multiple sequences (e.g. whisper's 1500 frames)
        return attention_ref(q, k, v, causal=causal, window=window, scale=scale)
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    n_chunks = sq // chunk
    qc = q.reshape(b, hkv, group, n_chunks, chunk, d).transpose(3, 0, 1, 2, 4, 5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    cols = jnp.arange(sk)[None, :]

    def body(_, args):
        i, qi = args  # qi: (B, Hkv, G, chunk, d)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qi.astype(jnp.float32), kf) * scale
        rows = i * chunk + jnp.arange(chunk)[:, None]
        mask = _window_mask(rows, cols, causal, window)
        s = jnp.where(mask, s, _NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        oi = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
        return None, oi.astype(q.dtype)

    _, out = jax.lax.scan(body, None, (jnp.arange(n_chunks), qc),
                          unroll=True if unroll else 1)
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(b, hq, sq, v.shape[-1])
    return out


def attention(q, k, v, *, impl="chunked", causal=True, window=0, scale=None,
              chunk: int = 1024, kv_valid=None, unroll: bool = False) -> Array:
    if (impl == "flash" and window == 0 and kv_valid is None
            and q.shape[-1] == v.shape[-1]):
        return kops.flash_attention(q, k, v, causal=causal, scale=scale,
                                    use_pallas=True)
    if impl == "chunked" and kv_valid is None:
        return attention_chunked(q, k, v, causal=causal, window=window,
                                 scale=scale, chunk=chunk, unroll=unroll)
    return attention_ref(q, k, v, causal=causal, window=window, scale=scale,
                         kv_valid=kv_valid)


# ---------------------------------------------------------------------------
# FFNs
# ---------------------------------------------------------------------------
def ffn_swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def ffn_gelu(x, w_in, b_in, w_out, b_out):
    h = jax.nn.gelu(x @ w_in + b_in, approximate=True)
    return h @ w_out + b_out


def rwkv_channel_mix(x, x_prev, mu_k, mu_r, w_k, w_v, w_r):
    """RWKV channel mix: k = relu(xk W_k)^2, out = sigmoid(xr W_r) * (k W_v)."""
    xk = x + mu_k * (x_prev - x)
    xr = x + mu_r * (x_prev - x)
    k = jnp.square(jax.nn.relu(xk @ w_k))
    return jax.nn.sigmoid(xr @ w_r) * (k @ w_v)


def token_shift(x: Array, last: Optional[Array] = None) -> Array:
    """RWKV token shift: x_{t-1} along seq; `last` seeds position -1."""
    shifted = jnp.pad(x[:, :-1], ((0, 0), (1, 0), (0, 0)))
    if last is not None:
        shifted = shifted.at[:, 0].set(last)
    return shifted
