"""Selective SSM (S6 / Mamba) branch used by the Hymba hybrid layers.

    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t
    y_t = C_t . h_t + D x_t,            dt_t, B_t, C_t input-dependent.

Carried state per layer: h (B, d_in, ssm_state) and the depthwise-conv tail
(B, conv_width-1, d_in) — O(1) in sequence length (long_500k eligible).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def _causal_conv(x: Array, w: Array, b: Array, tail: Array) -> Tuple[Array, Array]:
    """Depthwise causal conv1d. x (B,S,d_in); w (cw, d_in); tail (B,cw-1,d_in)."""
    cw = w.shape[0]
    xin = jnp.concatenate([tail, x], axis=1)             # (B, S+cw-1, d_in)
    out = jnp.zeros_like(x)
    for i in range(cw):
        out = out + xin[:, i : i + x.shape[1], :] * w[i][None, None, :]
    new_tail = xin[:, xin.shape[1] - (cw - 1):, :] if cw > 1 else tail
    return out + b, new_tail


def ssm_branch(
    x: Array, p: Dict, state: Tuple[Array, Array], ssm_state: int
) -> Tuple[Array, Tuple[Array, Array]]:
    """x: (B, S, D) normed input. state: (h (B,d_in,st), conv_tail)."""
    B, S, D = x.shape
    h0, conv_tail = state
    d_in = h0.shape[1]
    st = ssm_state
    dt_rank = p["w_dt"].shape[0]

    xz = x @ p["w_in"]                                   # (B,S,2*d_in)
    xp, z = jnp.split(xz, 2, axis=-1)
    xc, new_tail = _causal_conv(xp, p["conv_w"], p["conv_b"], conv_tail)
    xc = jax.nn.silu(xc)

    bcdt = xc @ p["w_bcdt"]                              # (B,S,2st+dt_rank)
    Bmat = bcdt[..., :st]
    Cmat = bcdt[..., st : 2 * st]
    dt = jax.nn.softplus(bcdt[..., 2 * st :] @ p["w_dt"] + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))         # (d_in, st)

    dA = jnp.exp(dt[..., None].astype(jnp.float32) * A)  # (B,S,d_in,st)
    dBx = (dt * xc)[..., None] * Bmat[:, :, None, :]     # (B,S,d_in,st)

    def step(h, inputs):
        dA_t, dBx_t, C_t = inputs
        h_new = dA_t * h + dBx_t
        y_t = jnp.einsum("bds,bs->bd", h_new, C_t)
        return h_new, y_t

    xs = (
        jnp.moveaxis(dA, 1, 0),
        jnp.moveaxis(dBx.astype(jnp.float32), 1, 0),
        jnp.moveaxis(Cmat.astype(jnp.float32), 1, 0),
    )
    h_final, y = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    y = jnp.moveaxis(y, 0, 1).astype(x.dtype)            # (B,S,d_in)
    y = y + p["D_skip"] * xc
    y = y * jax.nn.silu(z)
    return y @ p["w_ssm_out"], (h_final.astype(h0.dtype), new_tail)


def init_state(cfg, batch: int, dtype) -> Dict:
    d_in = cfg.ssm_expand * cfg.d_model
    L = cfg.n_layers
    return {
        "ssm_h": jnp.zeros((L, batch, d_in, cfg.ssm_state), jnp.float32),
        "conv_tail": jnp.zeros((L, batch, cfg.conv_width - 1, d_in), dtype),
    }
