"""Architecture-generic LM: forward, loss, KV-cache decode, for every arch in
the assigned pool. Layers are scanned (stacked params) so the HLO is O(1) in
depth; mixers dispatch per config (GQA attention / MLA / RWKV6 / Hymba
attn+SSM hybrid); whisper adds an encoder stack + cross attention.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from jax.ad_checkpoint import checkpoint_name

from ..configs.base import ModelConfig
from ..dist.sharding import ShardingRules
from . import layers as nn
from . import mamba, moe, rwkv6

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Performance levers (the §Perf hillclimb knobs)."""

    attn_impl: str = "chunked"      # ref | chunked | flash
    attn_chunk: int = 1024
    remat: str = "none"             # none | full | dots | named
    scheme: str = "default"         # sharding scheme (dist/sharding.py)
    moe_capacity_factor: Optional[float] = None  # override config
    # --- §Perf hillclimb levers -------------------------------------------
    # recompute attention internals in bwd (drops stored chunk logits)
    attn_remat: bool = False
    # emit with_sharding_constraint on q/k/v (baseline) or let GSPMD propagate
    qkv_constraints: bool = True
    # MoE dispatch: 'global_sort' (baseline, one global argsort) or
    # 'grouped' (per-data-shard local sort + expert all-to-all)
    moe_dispatch: str = "global_sort"
    moe_groups: int = 1
    # Fully unroll the layer scan. Used by the dry-run: XLA cost_analysis
    # counts a while-loop body ONCE, so scanned-layer FLOPs/collective bytes
    # would be under-reported by ~n_layers. Unrolling makes them exact.
    unroll_layers: bool = False


def _norm(cfg: ModelConfig, x: Array, p: Dict, name: str) -> Array:
    if cfg.norm == "ln":
        return nn.layer_norm(x, p[name], p[name + "_bias"])
    return nn.rms_norm(x, p[name])


def _split_heads(x: Array, n_heads: int) -> Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, -1).transpose(0, 2, 1, 3)


def _merge_heads(x: Array) -> Array:
    b, h, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * d)


# ---------------------------------------------------------------------------
# Attention branches (train/prefill path)
# ---------------------------------------------------------------------------
def _qkv(cfg: ModelConfig, x: Array, kv_src: Array, p: Dict, sfx: str = ""):
    nq, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    if sfx == "":
        # fused projection (one matmul, one bwd dx all-reduce — §Perf C2)
        qkv = x @ p["wqkv"]
        if cfg.qkv_bias:
            qkv = qkv + p["bqkv"]
        q = qkv[..., : nq * hd]
        k = qkv[..., nq * hd : (nq + nkv) * hd]
        v = qkv[..., (nq + nkv) * hd :]
    else:
        q = x @ p["wq" + sfx]
        k = kv_src @ p["wk" + sfx]
        v = kv_src @ p["wv" + sfx]
        if cfg.qkv_bias:
            q = q + p["bq" + sfx]
            k = k + p["bk" + sfx]
            v = v + p["bv" + sfx]
    return (_split_heads(q, nq), _split_heads(k, nkv), _split_heads(v, nkv))


def attn_branch(
    cfg: ModelConfig, x: Array, p: Dict, rules: ShardingRules, run: RunConfig,
    positions: Array, *, causal: bool = True, use_rope: bool = True,
    window: int = 0, kv_src: Optional[Array] = None, sfx: str = "",
) -> Array:
    kv_src = x if kv_src is None else kv_src
    q, k, v = _qkv(cfg, x, kv_src, p, sfx)
    if use_rope:
        if cfg.mrope_sections:
            pos3 = jnp.broadcast_to(positions[:, None, :],
                                    (positions.shape[0], 3, positions.shape[1]))
            q = nn.apply_mrope(q, pos3, cfg.mrope_sections, cfg.rope_theta)
            k = nn.apply_mrope(k, pos3, cfg.mrope_sections, cfg.rope_theta)
        else:
            q = nn.apply_rope(q, positions, cfg.rope_theta)
            k = nn.apply_rope(k, positions, cfg.rope_theta)
    if run.qkv_constraints:
        q = rules.constrain(q, "batch", "heads", "seq", "head_dim")
        k = rules.constrain(k, "batch", "kv_heads", None, "head_dim")
        v = rules.constrain(v, "batch", "kv_heads", None, "head_dim")
    attn = functools.partial(nn.attention, impl=run.attn_impl, causal=causal,
                             window=window, chunk=run.attn_chunk,
                             unroll=run.unroll_layers)
    if run.attn_remat:
        attn = jax.checkpoint(attn)
    out = attn(q, k, v)
    return _merge_heads(out) @ p["wo" + sfx]


def mla_branch(
    cfg: ModelConfig, x: Array, p: Dict, rules: ShardingRules, run: RunConfig,
    positions: Array,
) -> Array:
    b, s, _ = x.shape
    hq, hd, rd = cfg.n_heads, cfg.hd, cfg.rope_head_dim
    cq = nn.rms_norm(x @ p["wdq"], p["q_norm"])
    q_nope = _split_heads(cq @ p["wuq"], hq)                    # (B,H,S,hd)
    q_rope = nn.apply_rope(_split_heads(cq @ p["wq_rope"], hq),
                           positions, cfg.rope_theta)
    ckv = nn.rms_norm(x @ p["wdkv"], p["kv_norm"])              # (B,S,r_kv)
    k_rope = nn.apply_rope(_split_heads(x @ p["wk_rope"], 1),
                           positions, cfg.rope_theta)           # (B,1,S,rd)
    k_nope = _split_heads(ckv @ p["wuk"], hq)
    v = _split_heads(ckv @ p["wuv"], hq)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, hq, s, rd))],
                        axis=-1)
    if run.qkv_constraints:
        q = rules.constrain(q, "batch", "heads", "seq", None)
        k = rules.constrain(k, "batch", "heads", None, None)
        v = rules.constrain(v, "batch", "heads", None, None)
    attn = functools.partial(nn.attention, impl=run.attn_impl, causal=True,
                             scale=1.0 / ((hd + rd) ** 0.5),
                             chunk=run.attn_chunk, unroll=run.unroll_layers)
    if run.attn_remat:
        attn = jax.checkpoint(attn)
    out = attn(q, k, v)
    return _merge_heads(out) @ p["wo"]


# ---------------------------------------------------------------------------
# FFN dispatch
# ---------------------------------------------------------------------------
def ffn_branch(cfg: ModelConfig, x: Array, p: Dict, rules: ShardingRules,
               run: RunConfig) -> Array:
    b, s, d = x.shape
    if cfg.n_experts > 0:
        cf = run.moe_capacity_factor or cfg.capacity_factor
        if run.moe_dispatch == "grouped":
            y = moe.moe_ffn_grouped(
                x.reshape(b * s, d), p["router"], p["we_gate"], p["we_up"],
                p["we_down"], top_k=cfg.top_k, capacity_factor=cf,
                n_groups=run.moe_groups, rules=rules,
            ).reshape(b, s, d)
        else:
            y = moe.moe_ffn(
                x.reshape(b * s, d), p["router"], p["we_gate"], p["we_up"],
                p["we_down"], top_k=cfg.top_k, capacity_factor=cf,
            ).reshape(b, s, d)
        if cfg.n_shared_experts > 0:
            y = y + moe.shared_expert_ffn(x, p)
        return y
    if cfg.act == "swiglu":
        gu = x @ p["w_gu"]                      # fused gate+up (§Perf C2)
        gate, up = jnp.split(gu, 2, axis=-1)
        h = jax.nn.silu(gate) * up
        h = rules.constrain(h, "batch", "seq", "ffn")
        return h @ p["w_down"]
    return nn.ffn_gelu(x, p["w_in"], p["b_in"], p["w_out"], p["b_out"])


# ---------------------------------------------------------------------------
# Decoder blocks (train path)
# ---------------------------------------------------------------------------
def _make_block(cfg: ModelConfig, rules: ShardingRules, run: RunConfig,
                positions: Array, enc_out: Optional[Array] = None):
    """Returns block(x, layer_params) -> x for the lax.scan over layers."""

    def block(x: Array, lp: Dict) -> Array:
        if cfg.mixer == "rwkv6":
            B = x.shape[0]
            st = (
                jnp.zeros((B, cfg.n_heads, cfg.hd, cfg.hd), jnp.float32),
                jnp.zeros((B, cfg.d_model), x.dtype),
            )
            h = _norm(cfg, x, lp, "norm1")
            y, _ = rwkv6.time_mix(h, lp, st, cfg.n_heads)
            x = x + y
            h = _norm(cfg, x, lp, "norm2")
            y, _ = rwkv6.channel_mix(h, lp, jnp.zeros((B, cfg.d_model), x.dtype))
            return x + y

        h = _norm(cfg, x, lp, "norm1")
        if cfg.mixer == "mla":
            y = mla_branch(cfg, h, lp, rules, run, positions)
        elif cfg.mixer == "hymba":
            y_attn = attn_branch(cfg, h, lp, rules, run, positions,
                                 window=cfg.sliding_window)
            B = x.shape[0]
            d_in = cfg.ssm_expand * cfg.d_model
            st = (
                jnp.zeros((B, d_in, cfg.ssm_state), jnp.float32),
                jnp.zeros((B, cfg.conv_width - 1, d_in), x.dtype),
            )
            y_ssm, _ = mamba.ssm_branch(h, lp, st, cfg.ssm_state)
            y = 0.5 * (y_attn + y_ssm)
        else:
            y = attn_branch(cfg, h, lp, rules, run, positions, causal=True,
                            use_rope=not cfg.is_encoder_decoder,
                            window=cfg.sliding_window)
        y = checkpoint_name(y, "mix_out")
        x = x + y
        if cfg.is_encoder_decoder:
            h = _norm(cfg, x, lp, "norm3")
            y = attn_branch(cfg, h, lp, rules, run, positions, causal=False,
                            use_rope=False, kv_src=enc_out, sfx="_x")
            x = x + y
        h = _norm(cfg, x, lp, "norm2")
        x = x + checkpoint_name(ffn_branch(cfg, h, lp, rules, run), "ffn_out")
        return rules.constrain(x, "batch", "seq", "embed")

    return block


def _maybe_remat(fn, run: RunConfig):
    if run.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if run.remat == "dots":
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        )
    if run.remat == "named":
        # Save exactly the post-collective block activations: the backward
        # recompute then never re-runs the forward all-reduces, at a memory
        # cost of 2 x (B, S, D) per layer.
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.save_only_these_names(
                "mix_out", "ffn_out"),
        )
    return fn


def _scan_layers(x: Array, layer_params: Dict, block, run: RunConfig) -> Array:
    body = _maybe_remat(lambda c, lp: (block(c, lp), None), run)
    x, _ = jax.lax.scan(body, x, layer_params,
                        unroll=True if run.unroll_layers else 1)
    return x


# ---------------------------------------------------------------------------
# Encoder (whisper)
# ---------------------------------------------------------------------------
def _make_encoder_block(cfg: ModelConfig, rules: ShardingRules,
                        run: RunConfig):
    def block(x: Array, lp: Dict) -> Array:
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
        h = _norm(cfg, x, lp, "norm1")
        x = x + attn_branch(cfg, h, lp, rules, run, positions, causal=False,
                            use_rope=False)
        h = _norm(cfg, x, lp, "norm2")
        x = x + ffn_branch(cfg, h, lp, rules, run)
        return rules.constrain(x, "batch", "frames", "embed")

    return block


def encode(cfg: ModelConfig, params: Dict, frames: Array,
           rules: ShardingRules, run: RunConfig) -> Array:
    """frames: (B, enc_seq, D) precomputed frame embeddings (conv stub)."""
    x = frames + nn.sinusoidal_positions(frames.shape[1], cfg.d_model).astype(
        frames.dtype
    )
    block = _make_encoder_block(cfg, rules, run)
    x = _scan_layers(x, params["encoder"]["layers"], block, run)
    if cfg.norm == "ln":
        return nn.layer_norm(x, params["encoder"]["final_norm"],
                             params["encoder"]["final_norm_bias"])
    return nn.rms_norm(x, params["encoder"]["final_norm"])


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------
def forward(
    cfg: ModelConfig,
    params: Dict,
    tokens: Array,
    rules: ShardingRules,
    run: RunConfig,
    *,
    vision_embeds: Optional[Array] = None,
    encoder_frames: Optional[Array] = None,
) -> Array:
    """tokens (B, S) -> logits (B, S, V)."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.jnp_dtype)
    if cfg.family == "vlm" and vision_embeds is not None:
        nv = vision_embeds.shape[1]
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x[:, nv:]], axis=1)
    if cfg.is_encoder_decoder:
        x = x + nn.sinusoidal_positions(S, cfg.d_model).astype(x.dtype)
    x = rules.constrain(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    enc_out = None
    if cfg.is_encoder_decoder:
        assert encoder_frames is not None, "whisper needs encoder frames"
        enc_out = encode(cfg, params, encoder_frames, rules, run)

    block = _make_block(cfg, rules, run, positions, enc_out)
    x = _scan_layers(x, params["layers"], block, run)

    if cfg.norm == "ln":
        x = nn.layer_norm(x, params["final_norm"], params["final_norm_bias"])
    else:
        x = nn.rms_norm(x, params["final_norm"])
    logits = x @ params["lm_head"].T.astype(x.dtype)
    return rules.constrain(logits, "batch", "seq", "vocab")


def lm_loss(logits: Array, tokens: Array) -> Array:
    """Next-token cross entropy (fp32 logsumexp), mean over tokens."""
    lg = logits[:, :-1].astype(jnp.float32)
    tg = tokens[:, 1:]
    lse = jax.scipy.special.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, tg[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)
