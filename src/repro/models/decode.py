"""KV-cache serving path: cache init/specs, prefill, single-token decode.

Cache layout is one stacked pytree (leading 'layers' axis) consumed by the
layer scan. Sub-quadratic archs carry O(1)-in-sequence state:
  * rwkv6  — matrix wkv state + token-shift tails, no KV cache at all;
  * hymba  — ring-buffer KV of the sliding window + SSM state.
MLA caches the compressed latents (c_kv, k_rope) and decodes with the
weight-absorbed trick, so its per-token cache is kv_lora+rope wide instead
of 2 * H * hd.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..dist.sharding import ShardingRules
from . import layers as nn
from . import mamba, rwkv6
from .model import RunConfig, _merge_heads, _norm, _qkv, _split_heads, encode, ffn_branch

Array = jax.Array


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------
def cache_len(cfg: ModelConfig, max_seq: int) -> int:
    if cfg.sliding_window > 0:
        return min(cfg.sliding_window, max_seq)
    return max_seq


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=None) -> Dict:
    """dtype: storage dtype for the K/V tensors only (e.g. f8 quantized
    cache); recurrent SSM/shift states always stay at model precision."""
    kv_dtype = dtype or cfg.jnp_dtype
    mdt = cfg.jnp_dtype
    L, hd = cfg.n_layers, cfg.hd
    cache: Dict = {"idx": jnp.zeros((), jnp.int32)}
    if cfg.mixer == "rwkv6":
        cache.update(rwkv6.init_state(cfg, batch, mdt))
        return cache
    sc = cache_len(cfg, max_seq)
    if cfg.mixer == "mla":
        cache["ckv"] = jnp.zeros((L, batch, sc, cfg.kv_lora_rank), kv_dtype)
        cache["krope"] = jnp.zeros((L, batch, sc, cfg.rope_head_dim), kv_dtype)
    else:
        cache["k"] = jnp.zeros((L, batch, cfg.n_kv_heads, sc, hd), kv_dtype)
        cache["v"] = jnp.zeros((L, batch, cfg.n_kv_heads, sc, hd), kv_dtype)
    if cfg.mixer == "hymba":
        cache.update(mamba.init_state(cfg, batch, mdt))
    if cfg.is_encoder_decoder:
        cache["xk"] = jnp.zeros((L, batch, cfg.n_kv_heads, cfg.encoder_seq, hd),
                                kv_dtype)
        cache["xv"] = jnp.zeros((L, batch, cfg.n_kv_heads, cfg.encoder_seq, hd),
                                kv_dtype)
    return cache


def cache_axes(cfg: ModelConfig) -> Dict:
    """Logical sharding axes matching init_cache's structure."""
    ax: Dict = {"idx": ()}
    if cfg.mixer == "rwkv6":
        ax.update({
            "wkv": ("layers", "batch", "heads", None, None),
            "shift": ("layers", "batch", "embed"),
            "cm_shift": ("layers", "batch", "embed"),
        })
        return ax
    if cfg.mixer == "mla":
        ax["ckv"] = ("layers", "batch", "kv_seq", "kv_lora")
        ax["krope"] = ("layers", "batch", "kv_seq", None)
    else:
        ax["k"] = ("layers", "batch", "kv_heads", "kv_seq", "head_dim")
        ax["v"] = ("layers", "batch", "kv_heads", "kv_seq", "head_dim")
    if cfg.mixer == "hymba":
        ax["ssm_h"] = ("layers", "batch", "ffn", "state")
        ax["conv_tail"] = ("layers", "batch", None, "ffn")
    if cfg.is_encoder_decoder:
        ax["xk"] = ("layers", "batch", "kv_heads", "frames", "head_dim")
        ax["xv"] = ("layers", "batch", "kv_heads", "frames", "head_dim")
    return ax


def cache_pspecs(cfg: ModelConfig, rules: ShardingRules) -> Dict:
    return {k: rules.spec(*axes) if axes else rules.spec()
            for k, axes in cache_axes(cfg).items()}


def _layer_cache(cache: Dict) -> Dict:
    return {k: v for k, v in cache.items() if k != "idx"}


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------
def _write_slot(buf: Array, val: Array, slot: Array, axis: int) -> Array:
    """dynamic_update_slice of a single position along `axis`."""
    starts = [jnp.zeros((), jnp.int32)] * buf.ndim
    starts[axis] = slot
    return jax.lax.dynamic_update_slice(buf, val.astype(buf.dtype), starts)


def _attn_decode(cfg, h, lc, idx, rules, run, sfx=""):
    """Single-token attention over the (ring or full) cache."""
    b = h.shape[0]
    sc = lc["k"].shape[2]
    ring = cfg.sliding_window > 0
    q, k_t, v_t = _qkv(cfg, h, h, lc["p"], sfx)
    positions = jnp.full((b, 1), idx, jnp.int32)
    if cfg.mrope_sections:
        pos3 = jnp.broadcast_to(positions[:, None, :], (b, 3, 1))
        q = nn.apply_mrope(q, pos3, cfg.mrope_sections, cfg.rope_theta)
        k_t = nn.apply_mrope(k_t, pos3, cfg.mrope_sections, cfg.rope_theta)
    elif not cfg.is_encoder_decoder:
        q = nn.apply_rope(q, positions, cfg.rope_theta)
        k_t = nn.apply_rope(k_t, positions, cfg.rope_theta)
    slot = jnp.mod(idx, sc) if ring else idx
    k_cache = _write_slot(lc["k"], k_t, slot, axis=2)
    v_cache = _write_slot(lc["v"], v_t, slot, axis=2)
    # slots written so far (ring: all once wrapped); attention over keys is
    # permutation-invariant given absolute-rope'd k, so ring order is fine.
    valid = jnp.arange(sc) <= jnp.minimum(idx, sc - 1)
    kv_valid = jnp.broadcast_to(valid[None, :], (b, sc))
    out = nn.attention(q, k_cache, v_cache, impl="ref", causal=False,
                       kv_valid=kv_valid)
    return _merge_heads(out) @ lc["p"]["wo" + sfx], k_cache, v_cache


def _mla_decode(cfg, h, lc, idx, rules, run):
    p = lc["p"]
    b = h.shape[0]
    hq, hd, rd, r_kv = cfg.n_heads, cfg.hd, cfg.rope_head_dim, cfg.kv_lora_rank
    sc = lc["ckv"].shape[1]  # per-layer slice: (B, sc, r_kv)
    positions = jnp.full((b, 1), idx, jnp.int32)
    cq = nn.rms_norm(h @ p["wdq"], p["q_norm"])
    q_nope = _split_heads(cq @ p["wuq"], hq)                   # (B,H,1,hd)
    q_rope = nn.apply_rope(_split_heads(cq @ p["wq_rope"], hq),
                           positions, cfg.rope_theta)          # (B,H,1,rd)
    ckv_t = nn.rms_norm(h @ p["wdkv"], p["kv_norm"])           # (B,1,r_kv)
    krope_t = nn.apply_rope(_split_heads(h @ p["wk_rope"], 1),
                            positions, cfg.rope_theta)[:, 0]   # (B,1,rd)
    ckv = _write_slot(lc["ckv"], ckv_t, idx, axis=1)
    krope = _write_slot(lc["krope"], krope_t, idx, axis=1)
    # weight-absorbed scores: q_abs (B,H,r_kv)
    wuk = p["wuk"].reshape(r_kv, hq, hd)
    wuv = p["wuv"].reshape(r_kv, hq, hd)
    q_abs = jnp.einsum("bhd,rhd->bhr", q_nope[:, :, 0].astype(jnp.float32),
                       wuk.astype(jnp.float32))
    s = jnp.einsum("bhr,bsr->bhs", q_abs, ckv.astype(jnp.float32))
    s = s + jnp.einsum("bhr,bsr->bhs", q_rope[:, :, 0].astype(jnp.float32),
                       krope.astype(jnp.float32))
    s = s / ((hd + rd) ** 0.5)
    valid = (jnp.arange(sc) <= idx)[None, None, :]
    s = jnp.where(valid, s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", pr, ckv.astype(jnp.float32))
    out = jnp.einsum("bhr,rhd->bhd", ctx, wuv.astype(jnp.float32))
    out = out.reshape(b, 1, hq * hd).astype(h.dtype)
    return out @ p["wo"], ckv, krope


def decode_step(
    cfg: ModelConfig,
    params: Dict,
    cache: Dict,
    tokens: Array,
    rules: ShardingRules,
    run: RunConfig,
    token_embeds: Optional[Array] = None,
) -> Tuple[Array, Dict]:
    """tokens (B, 1) -> (logits (B, V), updated cache).

    token_embeds: optional (B, 1, D) embedding override (VLM vision tokens
    during prefill)."""
    B = tokens.shape[0]
    idx = cache["idx"]
    if token_embeds is not None:
        x = token_embeds.astype(cfg.jnp_dtype)
    else:
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.jnp_dtype)
    if cfg.is_encoder_decoder:
        pos = jnp.full((B, 1), idx, jnp.int32)
        x = x + nn.sinusoidal_at(pos, cfg.d_model).astype(x.dtype)

    def body(x, scan_in):
        lp, lc = scan_in
        lc = dict(lc)
        lc["p"] = lp
        new_lc = {}
        if cfg.mixer == "rwkv6":
            h = _norm(cfg, x, lp, "norm1")
            y, (wkv, shift) = rwkv6.time_mix(
                h, lp, (lc["wkv"], lc["shift"]), cfg.n_heads)
            x = x + y
            h = _norm(cfg, x, lp, "norm2")
            y, cm_shift = rwkv6.channel_mix(h, lp, lc["cm_shift"])
            x = x + y
            new_lc.update({"wkv": wkv, "shift": shift, "cm_shift": cm_shift})
            return x, new_lc

        h = _norm(cfg, x, lp, "norm1")
        if cfg.mixer == "mla":
            y, ckv, krope = _mla_decode(cfg, h, lc, idx, rules, run)
            new_lc.update({"ckv": ckv, "krope": krope})
        elif cfg.mixer == "hymba":
            y_attn, kc, vc = _attn_decode(cfg, h, lc, idx, rules, run)
            y_ssm, (ssm_h, conv_tail) = mamba.ssm_branch(
                h, lp, (lc["ssm_h"], lc["conv_tail"]), cfg.ssm_state)
            y = 0.5 * (y_attn + y_ssm)
            new_lc.update({"k": kc, "v": vc, "ssm_h": ssm_h,
                           "conv_tail": conv_tail})
        else:
            y, kc, vc = _attn_decode(cfg, h, lc, idx, rules, run)
            new_lc.update({"k": kc, "v": vc})
        x = x + y
        if cfg.is_encoder_decoder:
            h = _norm(cfg, x, lp, "norm3")
            q = _split_heads(h @ lp["wq_x"] + (lp["bq_x"] if cfg.qkv_bias else 0.0),
                             cfg.n_heads)
            out = nn.attention(q, lc["xk"], lc["xv"], impl="ref", causal=False)
            x = x + _merge_heads(out) @ lp["wo_x"]
            new_lc.update({"xk": lc["xk"], "xv": lc["xv"]})
        h = _norm(cfg, x, lp, "norm2")
        x = x + ffn_branch(cfg, h, lp, rules, run)
        return rules.constrain(x, "batch", None, "embed"), new_lc

    x, new_layer_cache = jax.lax.scan(
        body, x, (params["layers"], _layer_cache(cache)),
        unroll=True if run.unroll_layers else 1,
    )
    if cfg.norm == "ln":
        x = nn.layer_norm(x, params["final_norm"], params["final_norm_bias"])
    else:
        x = nn.rms_norm(x, params["final_norm"])
    logits = (x @ params["lm_head"].T.astype(x.dtype))[:, 0]
    new_cache = dict(new_layer_cache)
    new_cache["idx"] = idx + 1
    return rules.constrain(logits, "batch", "vocab"), new_cache


# ---------------------------------------------------------------------------
# Prefill / generation helpers (serving examples + equivalence tests)
# ---------------------------------------------------------------------------
def start_cache(
    cfg: ModelConfig,
    params: Dict,
    batch: int,
    max_seq: int,
    rules: ShardingRules,
    run: RunConfig,
    encoder_frames: Optional[Array] = None,
) -> Dict:
    """Fresh cache; for encoder-decoder archs also runs the encoder and
    precomputes the per-layer cross-attention K/V."""
    cache = init_cache(cfg, batch, max_seq)
    if cfg.is_encoder_decoder:
        assert encoder_frames is not None
        enc_out = encode(cfg, params, encoder_frames, rules, run)
        lw = params["layers"]
        hd, hkv = cfg.hd, cfg.n_kv_heads

        def proj(w, b):
            y = jnp.einsum("bsd,ldh->lbsh", enc_out, w)
            if b is not None:
                y = y + b[:, None, None, :]
            L, B, S, _ = y.shape
            return y.reshape(L, B, S, hkv, hd).transpose(0, 1, 3, 2, 4)

        cache["xk"] = proj(lw["wk_x"], lw.get("bk_x")).astype(cache["xk"].dtype)
        cache["xv"] = proj(lw["wv_x"], lw.get("bv_x")).astype(cache["xv"].dtype)
    return cache


def prefill(
    cfg: ModelConfig,
    params: Dict,
    tokens: Array,
    cache: Dict,
    rules: ShardingRules,
    run: RunConfig,
    vision_embeds: Optional[Array] = None,
) -> Tuple[Array, Dict]:
    """Sequential prefill: feed the prompt token-by-token through
    decode_step (a lax.scan). Returns (last logits (B,V), cache).

    vision_embeds: optional (B, nv, D) — overrides the first nv token
    embeddings (VLM image tokens), mirroring forward()."""
    embeds = jnp.take(params["embed"], tokens, axis=0).astype(cfg.jnp_dtype)
    if vision_embeds is not None:
        nv = vision_embeds.shape[1]
        embeds = jnp.concatenate(
            [vision_embeds.astype(embeds.dtype), embeds[:, nv:]], axis=1
        )

    def body(cache, xs):
        tok, emb = xs
        logits, cache = decode_step(cfg, params, cache, tok[:, None], rules,
                                    run, token_embeds=emb[:, None])
        return cache, logits

    cache, logits = jax.lax.scan(
        body, cache, (tokens.T, jnp.moveaxis(embeds, 1, 0))
    )
    return logits[-1], cache


def generate(
    cfg: ModelConfig,
    params: Dict,
    prompt: Array,
    n_tokens: int,
    rules: ShardingRules,
    run: RunConfig,
    encoder_frames: Optional[Array] = None,
) -> Array:
    """Greedy generation; returns (B, n_tokens) of generated ids."""
    B = prompt.shape[0]
    cache = start_cache(cfg, params, B, prompt.shape[1] + n_tokens, rules, run,
                        encoder_frames)
    logits, cache = prefill(cfg, params, prompt, cache, rules, run)

    def body(carry, _):
        logits, cache = carry
        tok = jnp.argmax(logits, axis=-1).astype(prompt.dtype)
        logits, cache = decode_step(cfg, params, cache, tok[:, None], rules, run)
        return (logits, cache), tok

    (_, _), toks = jax.lax.scan(body, (logits, cache), None, length=n_tokens)
    return toks.T
