"""Distributed graph signal processing via Chebyshev polynomial approximation.

Importing any ``repro`` submodule first installs the jax version-compat
aliases (see :mod:`repro._compat`) so the modern jax spellings used across
the codebase work on the pinned container jax as well.
"""
from . import _compat  # noqa: F401  (side effect: jax compat aliases)

__all__ = ["_compat"]
