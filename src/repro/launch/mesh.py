"""Production mesh construction (kept as functions — importing this module
never touches jax device state)."""
from __future__ import annotations

import math

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips. Multi-pod: 2 pods x 256.

    The 'pod' axis is the slow (DCN) dimension: only batch is sharded over
    it, so cross-pod traffic is gradient all-reduce only.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh needs {n} devices, have {len(devices)} — the dry-run must "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax"
        )
    return jax.make_mesh(
        shape, axes, devices=devices,
        axis_types=(AxisType.Auto,) * len(axes),
    )


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CI-scale multi-device tests."""
    n = math.prod(shape)
    return jax.make_mesh(
        shape, axes, devices=jax.devices()[:n],
        axis_types=(AxisType.Auto,) * len(axes),
    )
