"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

`input_specs` returns weak-type-correct, shardable stand-ins (no device
allocation): token batches for train/prefill, token + KV-cache trees for
decode. Modality frontends are stubs — whisper gets precomputed frame
embeddings, qwen2-vl gets patch embeddings (DESIGN.md §4)."""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeSpec
from ..dist.sharding import ShardingRules
from ..models import decode as dec

Array = jax.Array


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    B, S = shape.global_batch, shape.seq_len
    dt = cfg.jnp_dtype
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if cfg.family == "vlm":
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_vision_tokens, cfg.d_model), dt)
    if cfg.is_encoder_decoder:
        specs["encoder_frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), dt)
    return specs


def batch_pspecs(cfg: ModelConfig, rules: ShardingRules) -> Dict:
    specs = {
        "tokens": rules.spec("batch", "seq"),
        "labels": rules.spec("batch", "seq"),
    }
    if cfg.family == "vlm":
        specs["vision_embeds"] = rules.spec("batch", None, "embed")
    if cfg.is_encoder_decoder:
        specs["encoder_frames"] = rules.spec("batch", "frames", "embed")
    return specs


def decode_specs(
    cfg: ModelConfig, shape: ShapeSpec, kv_dtype=None
) -> Tuple[Dict, jax.ShapeDtypeStruct]:
    """(cache ShapeDtypeStruct tree, tokens (B,1))."""
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(
        functools.partial(dec.init_cache, cfg, B, S, dtype=kv_dtype)
    )
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    return cache, tokens


def input_specs(cfg: ModelConfig, shape: ShapeSpec, kv_dtype=None) -> Dict:
    """All model inputs for a cell, keyed by step-function argument."""
    if shape.is_decode:
        cache, tokens = decode_specs(cfg, shape, kv_dtype)
        return {"cache": cache, "tokens": tokens}
    return {"batch": batch_specs(cfg, shape)}
