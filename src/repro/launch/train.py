"""Training driver: config-driven, checkpointed, fault-tolerant.

    PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b --smoke \
        --steps 50 --ckpt-dir /tmp/run1 [--resume] [--fail-at-step 30] \
        [--dp-mode gossip|psum|none] [--mesh dxm]

Fault tolerance demonstrated end-to-end on CPU:
  * checkpoints are atomic (tmp + rename) and reshardable (gathered arrays,
    device_put on restore under any mesh) -> elastic restarts;
  * --fail-at-step N raises mid-run; re-launching with --resume reproduces
    the exact same loss curve (data pipeline is stateless-per-step);
  * --dp-mode gossip runs the paper's Algorithm 1 on the device ring for
    gradient consensus (dist/gossip.py) instead of a fabric all-reduce.
"""
from __future__ import annotations

import argparse
import functools
import math
import sys
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..ckpt import (latest_checkpoint, load_checkpoint, restore_arrays,
                    save_checkpoint)
from ..ckpt.checkpoint import wait_pending
from ..configs import get_config
from ..data import SyntheticLMData
from ..dist import gossip
from ..dist.sharding import ShardingRules, make_rules
from ..models import decode as dec
from ..models import params as mparams
from ..models.model import RunConfig
from ..models.steps import build_loss_fn, build_train_step
from ..optim.adamw import AdamWState, adamw_init, adamw_update, clip_by_global_norm

try:
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore


def build_gossip_train_step(cfg, rules, run, mesh, lr, K: Optional[int] = None,
                            quantize: bool = False):
    """Explicit data-parallel step: per-shard grads + Chebyshev-gossip
    consensus over the 'data' ring (the paper's Algorithm 1 on devices).
    `quantize` sends int8 messages (4x less ring traffic, approximate
    consensus — see repro.dist.gossip)."""
    loss_fn = build_loss_fn(cfg, ShardingRules.null(), run)
    n = mesh.shape["data"]
    coeffs = gossip.consensus_coeffs(n, K)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(), P(), P("data")),
        out_specs=(P(), P(), P()),
        check_vma=False,
    )
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads = gossip.gossip_mean_tree(grads, "data", coeffs,
                                        quantize=quantize)
        loss = gossip.gossip_mean(loss[None], "data", coeffs)[0]
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = adamw_update(grads, opt_state, params, lr=lr)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm,
                                   "step": opt_state.step}

    return step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at-step", type=int, default=None,
                    help="inject a failure (fault-tolerance test)")
    ap.add_argument("--dp-mode", choices=["none", "pjit", "gossip"],
                    default="none")
    ap.add_argument("--gossip-quantize", action="store_true")
    ap.add_argument("--mesh", default=None,
                    help="DxM device mesh, e.g. 4x1 (needs forced host devices)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    run = RunConfig(attn_impl="ref")

    mesh = None
    rules = ShardingRules.null()
    if args.mesh:
        d, m = (int(v) for v in args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"),
                             devices=jax.devices()[: d * m])
        rules = make_rules(mesh, "default")

    key = jax.random.PRNGKey(args.seed)
    params = mparams.init_params(cfg, key)
    opt_state = adamw_init(params)
    data = SyntheticLMData(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=args.seed,
        n_vision_tokens=cfg.n_vision_tokens if cfg.family == "vlm" else 0,
        d_model=cfg.d_model,
        encoder_seq=cfg.encoder_seq,
    )
    start_step = 0

    if args.resume and args.ckpt_dir:
        path = latest_checkpoint(args.ckpt_dir)
        if path:
            step_saved, trees, _ = load_checkpoint(path)
            params = restore_arrays(trees["params"], params)
            opt_state = restore_arrays(trees["opt_state"], opt_state)
            start_step = step_saved
            print(f"[train] resumed from {path} at step {start_step}",
                  flush=True)

    if args.dp_mode == "gossip":
        assert mesh is not None, "--dp-mode gossip needs --mesh"
        step_fn = build_gossip_train_step(cfg, rules, run, mesh, args.lr,
                                          quantize=args.gossip_quantize)
    else:
        step_fn = jax.jit(build_train_step(cfg, rules, run, lr=args.lr))

    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        if args.fail_at_step is not None and step == args.fail_at_step:
            print(f"[train] INJECTED FAILURE at step {step}", flush=True)
            raise SystemExit(42)
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"({(time.time()-t0):.1f}s)", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1,
                            {"params": params, "opt_state": opt_state},
                            async_save=True)
    if args.ckpt_dir:
        wait_pending()
        if args.steps % args.ckpt_every != 0:
            save_checkpoint(args.ckpt_dir, args.steps,
                            {"params": params, "opt_state": opt_state})
    print(f"[train] done: first loss {losses[0]:.4f} last {losses[-1]:.4f}",
          flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
