"""Batched serving driver: prefill a batch of prompts, decode greedily.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-4b --smoke \
        --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..dist.sharding import ShardingRules
from ..models import decode as dec
from ..models import params as mparams
from ..models.model import RunConfig
from ..models.steps import build_serve_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    rules = ShardingRules.null()
    run = RunConfig(attn_impl="ref")
    key = jax.random.PRNGKey(args.seed)
    params = mparams.init_params(cfg, key)

    B = args.batch
    prompts = jax.random.randint(key, (B, args.prompt_len), 0, cfg.vocab_size)
    enc = None
    if cfg.is_encoder_decoder:
        enc = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model),
                                dtype=cfg.jnp_dtype)

    serve_step = jax.jit(build_serve_step(cfg, rules, run))
    max_seq = args.prompt_len + args.gen
    cache = dec.start_cache(cfg, params, B, max_seq, rules, run,
                            encoder_frames=enc)
    t0 = time.time()
    logits, cache = dec.prefill(cfg, params, prompts, cache, rules, run)
    t_prefill = time.time() - t0
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        tok, cache = serve_step(params, cache, tok[:, None])
        out.append(tok)
    gen = jnp.stack(out, axis=1)
    dt = time.time() - t0
    print(f"[serve] batch={B} prompt={args.prompt_len} gen={args.gen}")
    print(f"[serve] prefill {t_prefill:.2f}s, decode {dt:.2f}s "
          f"({B * (args.gen - 1) / max(dt, 1e-9):.1f} tok/s)")
    print(f"[serve] sample generations (ids): {gen[:2, :12].tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
