"""Roofline-term extraction from compiled dry-run artifacts.

Terms (per §Roofline in EXPERIMENTS.md), for TPU v5e:
    compute    = HLO_FLOPs_per_device / 197e12 FLOP/s (bf16)
    memory     = HLO_bytes_per_device / 819e9 B/s HBM
    collective = collective_bytes_per_device / 50e9 B/s ICI

Post-SPMD HLO shapes are per-device shards, so cost_analysis() and the
collective scan below are already per-device; multiplying by the chip count
recovers the global quantities of the §Roofline formulas (they divide by
chips, so the two conventions agree).

Collective bytes are summed from the optimized HLO text: for every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
the *result* buffer bytes count once, except all-reduce which counts twice
(ring reduce-scatter + all-gather). This is the standard ring-collective
traffic model; replica-group size corrections ((g-1)/g) are ignored
(<7% at g=16).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12     # bf16 FLOP/s per v5e chip
HBM_BW = 819e9          # B/s per chip
ICI_BW = 50e9           # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?P<type>[^=]*?)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(",
)


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str, top_k: int = 0) -> Dict:
    """Per-device collective traffic from optimized (post-SPMD) HLO text.
    top_k > 0 additionally returns the largest individual collectives
    (op, bytes, result type) for §Perf diagnosis."""
    bytes_by_op: Dict[str, int] = {op: 0 for op in _COLLECTIVES}
    count_by_op: Dict[str, int] = {op: 0 for op in _COLLECTIVES}
    f32_ar_bytes = 0
    items = []
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        op = m.group("op")
        if line.lstrip().startswith("%") and "-done" in line.split("=")[0]:
            continue  # async -done repeats the -start result type
        b = _type_bytes(m.group("type"))
        mult = 2 if op == "all-reduce" else 1
        bytes_by_op[op] += b * mult
        count_by_op[op] += 1
        # XLA:CPU promotes bf16 all-reduces to f32 (FloatSupport); on TPU
        # they stay bf16. Track the f32-AR share so a TPU-corrected total
        # (f32 ARs counted at bf16 width) can be reported alongside.
        if op == "all-reduce" and "f32[" in m.group("type"):
            f32_ar_bytes += b * mult
        if top_k:
            items.append((b * mult, op, m.group("type").strip()[:90]))
    total = sum(bytes_by_op.values())
    out = {
        "collective_bytes_per_device": total,
        "collective_bytes_bf16_corrected": total - f32_ar_bytes // 2,
        "bytes_by_op": bytes_by_op,
        "count_by_op": count_by_op,
    }
    if top_k:
        items.sort(reverse=True)
        out["top_collectives"] = [
            {"bytes": b, "op": op, "type": t} for b, op, t in items[:top_k]
        ]
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    # Structural HBM-traffic estimate: args + outputs + 2*temps (each temp
    # written once and read once). The raw cost_analysis 'bytes accessed' on
    # the CPU backend counts every unfused op's operands and overstates TPU
    # traffic; both are reported, the structural one drives optimization.
    struct_bytes_per_device: Optional[float] = None

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def memory_struct_s(self) -> Optional[float]:
        if self.struct_bytes_per_device is None:
            return None
        return self.struct_bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Lower bound assuming perfect overlap: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def compute_fraction(self) -> float:
        """Roofline fraction: useful-compute share of the bound step time.
        1.0 = compute-bound at peak."""
        t = self.step_time_s
        return self.compute_s / t if t > 0 else 0.0

    def to_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "struct_bytes_per_device": self.struct_bytes_per_device,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "memory_struct_s": self.memory_struct_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_time_s": self.step_time_s,
            "compute_fraction": self.compute_fraction,
        }


def model_flops(cfg, shape, n_chips: int) -> Dict:
    """MODEL_FLOPS = 6 N D (train) or 2 N D (inference), N = active params."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mf = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mf = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        mf = 2.0 * n_active * tokens
    return {"model_flops": mf, "model_flops_per_device": mf / n_chips,
            "tokens": tokens, "active_params": n_active}
