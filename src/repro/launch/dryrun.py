import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + \
    os.environ.get("XLA_FLAGS", "")
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and extract memory / cost / collective analyses.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-7b \
      --shape train_4k [--multipod] [--scheme default] [--out out.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod]

The first two lines of this file force 512 host platform devices BEFORE any
jax import — smoke tests and benchmarks (which import other modules) still
see 1 device.
"""
import argparse
import json
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

import dataclasses

from ..configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from ..dist.sharding import make_rules
from ..models import decode as dec
from ..models import model as mmodel
from ..models import params as mparams
from ..models.model import RunConfig, forward
from ..models.steps import build_serve_step, build_train_step
from ..optim.adamw import AdamWState, adamw_init
from . import inputs as inp
from .mesh import make_production_mesh
from .roofline import Roofline, collective_stats, model_flops


def _cost_dict(compiled):
    """compiled.cost_analysis() as a dict: jax >= 0.6 returns the dict
    directly, older jax returns a one-element list of dicts."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _ns(mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree (P is itself a pytree node,
    so guard with is_leaf)."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _fit_one(shape, spec: P, mesh) -> P:
    """Trim a PartitionSpec so every dim divides evenly (jit rejects uneven
    input shardings): drop trailing mesh axes per dim until divisible."""
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    ndim = len(shape.shape) if hasattr(shape, "shape") else len(shape)
    dims = shape.shape if hasattr(shape, "shape") else shape
    entries = list(spec) + [None] * (ndim - len(spec))
    out = []
    for d, e in zip(dims, entries[:ndim]):
        if e is None:
            out.append(None)
            continue
        axes = (e,) if isinstance(e, str) else tuple(e)
        while axes:
            total = 1
            for a in axes:
                total *= sizes[a]
            if d % total == 0:
                break
            axes = axes[:-1]
        out.append(axes[0] if len(axes) == 1 else (tuple(axes) if axes else None))
    return P(*out)


def _fit(shape_tree, spec_tree, mesh):
    """Apply _fit_one leaf-wise (specs tree must match shapes tree)."""
    flat_shapes, treedef = jax.tree_util.tree_flatten(shape_tree)
    flat_specs = treedef.flatten_up_to(spec_tree)
    fitted = [_fit_one(sh, sp, mesh) for sh, sp in zip(flat_shapes, flat_specs)]
    return jax.tree_util.tree_unflatten(treedef, fitted)


def _mem_dict(compiled) -> Dict:
    try:
        m = compiled.memory_analysis()
    except Exception:
        m = None
    if m is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(m, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        out["total_hbm_bytes"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0)
        )
    return out


def _layer_cost(cfg, rules, run, shape, mesh, encoder: bool = False) -> Dict:
    """Compile ONE transformer layer's fwd+bwd with the cell's shardings and
    return its per-device flops / bytes / collective bytes.

    Used by the hybrid train accounting: the full train step is lowered with
    the layer *scan* (fast compile, correct memory analysis), whose while
    body XLA cost_analysis counts once; this per-layer cost times (L-1)
    recovers the exact totals. Inner attention-chunk scans are unrolled here
    so they are counted exactly."""
    B = shape.global_batch
    S = cfg.encoder_seq if encoder else shape.seq_len
    dt = cfg.jnp_dtype
    d = cfg.d_model
    run_l = dataclasses.replace(run, unroll_layers=True,
                                attn_chunk=max(run.attn_chunk, S // 8))
    metas = mparams.abstract_params(cfg)
    lmetas = metas["encoder"]["layers"] if encoder else metas["layers"]
    lp_sds = {k: jax.ShapeDtypeStruct(m.shape[1:], dt) for k, m in lmetas.items()}
    lp_specs = {k: rules.spec(*m.axes[1:]) for k, m in lmetas.items()}
    x_sds = jax.ShapeDtypeStruct((B, S, d), dt)
    x_spec = rules.spec("batch", "frames" if encoder else "seq", "embed")
    arg_shapes = [x_sds, lp_sds, x_sds]
    arg_specs = [x_spec, lp_specs, x_spec]
    if cfg.is_encoder_decoder and not encoder:
        arg_shapes.append(jax.ShapeDtypeStruct((B, cfg.encoder_seq, d), dt))
        arg_specs.append(rules.spec("batch", "frames", "embed"))

    def f(x, lp, ct, enc_out=None):
        if encoder:
            blk = mmodel._make_encoder_block(cfg, rules, run_l)
        else:
            positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
            blk = mmodel._make_block(cfg, rules, run_l, positions, enc_out)
        blk = mmodel._maybe_remat(blk, run)
        y, vjp = jax.vjp(blk, x, lp)
        dx, dlp = vjp(ct)
        return y, dx, dlp

    fitted = _fit(tuple(arg_shapes), tuple(arg_specs), mesh)
    jitted = jax.jit(f, in_shardings=_ns(mesh, fitted))
    compiled = jitted.lower(*arg_shapes).compile()
    cost = _cost_dict(compiled)
    coll = collective_stats(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll["collective_bytes_per_device"],
        "collective_bytes_bf16": coll["collective_bytes_bf16_corrected"],
    }


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    scheme: str = "default",
    run_cfg: Optional[RunConfig] = None,
    kv_dtype: Optional[str] = None,
    dump_collectives: int = 0,
) -> Dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_chips = 512 if multi_pod else 256
    record: Dict = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": n_chips,
        "scheme": scheme,
    }
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        record.update({"status": "skipped", "reason": why})
        return record

    if run_cfg is None:
        run_cfg = RunConfig(attn_impl="chunked", attn_chunk=512,
                            remat="dots", scheme=scheme)
    prefill_last_only = getattr(run_cfg, "_prefill_last_only", False)
    run = dataclasses.replace(
        run_cfg,
        unroll_layers=(shape.kind != "train"),
        attn_chunk=(max(run_cfg.attn_chunk, shape.seq_len // 8)
                    if shape.kind == "prefill" else run_cfg.attn_chunk),
        # dispatch groups can't exceed the batch's shardable width — a
        # group count above it misaligns with the trimmed batch sharding
        # and GSPMD falls back to replicated dispatch buffers (measured:
        # 654 s vs 46 s collective on deepseek-v2 multi-pod train).
        moe_groups=max(1, min(run_cfg.moe_groups, shape.global_batch)),
    )
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(mesh, scheme)
    pshapes = mparams.param_shapes(cfg)
    pspecs = mparams.param_pspecs(cfg, rules)
    kvdt = {"f8": jnp.float8_e4m3fn, None: None, "model": None}[kv_dtype]

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            step = build_train_step(cfg, rules, run)
            opt_shapes = jax.eval_shape(adamw_init, pshapes)
            arg_shapes = (pshapes, opt_shapes, inp.batch_specs(cfg, shape))
            arg_specs = (pspecs, AdamWState(step=P(), m=pspecs, v=pspecs),
                         inp.batch_pspecs(cfg, rules))
            out_specs = (arg_specs[0], arg_specs[1],
                         {"loss": P(), "grad_norm": P(), "step": P()})
        elif shape.kind == "prefill":
            def step(params, batch):
                logits = forward(
                    cfg, params, batch["tokens"], rules, run,
                    vision_embeds=batch.get("vision_embeds"),
                    encoder_frames=batch.get("encoder_frames"),
                )
                if prefill_last_only:
                    return logits[:, -1:]
                return logits
            bspecs = {k: v for k, v in inp.batch_pspecs(cfg, rules).items()
                      if k != "labels"}
            bshapes = {k: v for k, v in inp.batch_specs(cfg, shape).items()
                       if k != "labels"}
            arg_shapes = (pshapes, bshapes)
            arg_specs = (pspecs, bspecs)
            out_specs = rules.spec("batch", "seq", "vocab")
        else:  # decode
            step = build_serve_step(cfg, rules, run)
            cache_shapes, tok_shape = inp.decode_specs(cfg, shape, kvdt)
            cache_specs = dec.cache_pspecs(cfg, rules)
            arg_shapes = (pshapes, cache_shapes, tok_shape)
            arg_specs = (pspecs, cache_specs, rules.spec("batch", None))
            out_specs = (rules.spec("batch"), cache_specs)

        arg_specs = _fit(arg_shapes, arg_specs, mesh)
        out_shapes = jax.eval_shape(step, *arg_shapes)
        out_specs = _fit(out_shapes, out_specs, mesh)
        jitted = jax.jit(step, in_shardings=_ns(mesh, arg_specs),
                         out_shardings=_ns(mesh, out_specs))
        lowered = jitted.lower(*arg_shapes)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = _cost_dict(compiled)
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = collective_stats(compiled.as_text(), top_k=dump_collectives)
    layer_costs = {}
    if shape.kind == "train":
        # hybrid accounting: add (L-1) x per-layer cost (scan body counted
        # once by cost_analysis) — see _layer_cost.
        with mesh:
            lc = _layer_cost(cfg, rules, run, shape, mesh)
            layer_costs["decoder"] = lc
            flops += (cfg.n_layers - 1) * lc["flops"]
            byts += (cfg.n_layers - 1) * lc["bytes"]
            coll["collective_bytes_per_device"] += (
                (cfg.n_layers - 1) * lc["collective_bytes"])
            coll["collective_bytes_bf16_corrected"] += (
                (cfg.n_layers - 1) * lc["collective_bytes_bf16"])
            if cfg.is_encoder_decoder:
                ec = _layer_cost(cfg, rules, run, shape, mesh, encoder=True)
                layer_costs["encoder"] = ec
                flops += (cfg.n_encoder_layers - 1) * ec["flops"]
                byts += (cfg.n_encoder_layers - 1) * ec["bytes"]
                coll["collective_bytes_per_device"] += (
                    (cfg.n_encoder_layers - 1) * ec["collective_bytes"])
                coll["collective_bytes_bf16_corrected"] += (
                    (cfg.n_encoder_layers - 1) * ec["collective_bytes_bf16"])
    mem = _mem_dict(compiled)
    struct_bytes = None
    if mem:
        struct_bytes = float(
            mem.get("argument_size_in_bytes", 0)
            + mem.get("output_size_in_bytes", 0)
            + 2 * mem.get("temp_size_in_bytes", 0)
        )
    rf = Roofline(
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_bytes_per_device=coll["collective_bytes_per_device"],
        struct_bytes_per_device=struct_bytes,
    )
    mf = model_flops(cfg, shape, n_chips)
    hlo_flops_global = flops * n_chips
    record["collective_s_bf16_corrected"] = (
        coll["collective_bytes_bf16_corrected"] / 50e9)
    record.update({
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem,
        "cost": {k: cost[k] for k in ("flops", "bytes accessed")
                 if k in cost},
        "collectives": coll,
        "layer_costs": layer_costs,
        "roofline": rf.to_dict(),
        "model_flops": mf,
        "fits_hbm_16g": (mem.get("total_hbm_bytes", 0) <= 16e9) if mem else None,
        "useful_flops_ratio": (
            mf["model_flops"] / hlo_flops_global if hlo_flops_global else None
        ),
    })
    return record


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--scheme", default="default")
    ap.add_argument("--kv-dtype", choices=["model", "f8"], default=None)
    ap.add_argument("--attn-impl", default="chunked")
    ap.add_argument("--attn-chunk", type=int, default=512)
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--moe-capacity", type=float, default=None)
    ap.add_argument("--moe-dispatch", choices=["global_sort", "grouped"],
                    default="global_sort")
    ap.add_argument("--moe-groups", type=int, default=None)
    ap.add_argument("--attn-remat", action="store_true")
    ap.add_argument("--no-qkv-constraints", action="store_true")
    ap.add_argument("--dump-collectives", type=int, default=0,
                    help="record the top-N largest collectives per cell")
    ap.add_argument("--no-unroll", action="store_true",
                    help="keep the layer scan (faster compile, approximate "
                         "FLOP/collective accounting)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    # dispatch groups = number of DP shards of the token stream
    if args.moe_groups:
        groups = args.moe_groups
    elif args.scheme in ("fsdp", "fsdp_noep"):
        groups = 512 if args.multipod else 256
    else:
        groups = 32 if args.multipod else 16
    run = RunConfig(attn_impl=args.attn_impl, attn_chunk=args.attn_chunk,
                    remat=args.remat, scheme=args.scheme,
                    moe_capacity_factor=args.moe_capacity,
                    moe_dispatch=args.moe_dispatch,
                    moe_groups=groups,
                    attn_remat=args.attn_remat,
                    qkv_constraints=not args.no_qkv_constraints,
                    unroll_layers=not args.no_unroll)
    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    records = []
    for arch, shape in cells:
        try:
            rec = run_cell(arch, shape, multi_pod=args.multipod,
                           scheme=args.scheme, run_cfg=run,
                           kv_dtype=args.kv_dtype,
                           dump_collectives=args.dump_collectives)
        except Exception as e:  # noqa: BLE001 — record the failure, keep going
            rec = {"arch": arch, "shape": shape, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
        records.append(rec)
        status = rec["status"]
        extra = ""
        if status == "ok":
            r = rec["roofline"]
            extra = (f"dom={r['dominant']} comp={r['compute_s']:.4f}s "
                     f"mem={r['memory_s']:.4f}s coll={r['collective_s']:.4f}s "
                     f"compile={rec['compile_s']:.0f}s")
        elif status == "skipped":
            extra = rec["reason"][:60]
        else:
            extra = rec["error"][:120]
        print(f"[dryrun] {arch} x {shape} ({rec.get('mesh', '')}): "
              f"{status} {extra}", flush=True)

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
    bad = [r for r in records if r["status"] == "error"]
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
