"""Precision-controlled wire codec for the sharded halo exchange.

Extracted and generalized from ``gossip.py``'s quantize path (PR 4's
fake-int f32 round-trip) into a real on-the-wire codec shared by both
sharded backends (`halo`, `pallas_halo`) and by gossip itself.

Three exchange dtypes, selected per plan via ``exchange_dtype=``:

``"f32"``
    Identity — the (..., h) boundary tile crosses the wire untouched
    (4h bytes per boundary row).
``"bf16"``
    ``astype(bfloat16)`` truncation (2h bytes per row).  No scale, no
    state; decode is a widening cast back to the compute dtype.
``"int8"``
    Per-tile symmetric quantization: each boundary tile row is scaled by
    its max-abs, rounded to 127 levels, and shipped as int8.  The f32
    scale **rides inside the same wire buffer** — bitcast to 4 int8
    lanes and concatenated after the payload, so the message is one
    (..., h + 4) int8 array (h + 4 bytes per row).  This keeps the
    measured exchange-round count at exactly the paper's 2K|E|: a
    separate scale operand would be a second ppermute per direction and
    `commstats.exchange_rounds` (= ppermute_count // 2) would double.

Error feedback (:func:`ef_encode` / :func:`ef_init`) closes the loop on
int8's per-round truncation: the residual ``r = t - decode(encode(t))``
of round k is added back into the tile before encoding round k+1, so
quantization error accumulates like a random walk instead of a bias.
The iterative inverse-filter literature (arxiv 2504.14341) shows the
Chebyshev/Jacobi iterations tolerate exactly this bounded per-round
perturbation.  The residual state is threaded across the K orders by
the stateful-matvec protocol in `core.chebyshev` / `kernels.ops` (see
``init_state`` there).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

#: The sanctioned wire dtypes for the halo exchange, in decreasing width.
EXCHANGE_DTYPES = ("f32", "bf16", "int8")

#: Symmetric int8 quantization levels (sign bit + 7 magnitude bits).
_INT8_LEVELS = 127.0

#: Bytes of the bitcast-packed f32 scale appended to each int8 tile row.
_SCALE_TAIL = 4


def validate_exchange_dtype(dtype: str) -> str:
    """Return `dtype` if sanctioned, else raise ValueError."""
    if dtype not in EXCHANGE_DTYPES:
        raise ValueError(
            f"exchange_dtype must be one of {EXCHANGE_DTYPES}, "
            f"got {dtype!r}")
    return dtype


def tile_wire_bytes(h: int, dtype: str) -> int:
    """Wire bytes of one encoded boundary row of width `h`.

    f32 -> 4h, bf16 -> 2h, int8 -> h + 4 (payload + packed f32 scale).
    This is the closed-form model `halo_bytes_per_apply` and the
    commstats tests check measured traffic against.
    """
    validate_exchange_dtype(dtype)
    if dtype == "f32":
        return 4 * h
    if dtype == "bf16":
        return 2 * h
    return h + _SCALE_TAIL


def encode(x: jax.Array, dtype: str) -> jax.Array:
    """Encode a (..., h) boundary tile for the wire.

    f32 is the identity; bf16 truncates; int8 returns the
    (..., h + 4) payload-plus-packed-scale described in the module
    docstring.  The last axis is the halo width h.
    """
    validate_exchange_dtype(dtype)
    if dtype == "f32":
        return x
    if dtype == "bf16":
        return x.astype(jnp.bfloat16)
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.where(scale > 0.0, scale, 1.0)
    q = jnp.clip(jnp.round(xf / scale * _INT8_LEVELS),
                 -_INT8_LEVELS, _INT8_LEVELS).astype(jnp.int8)
    # pack the f32 scale into 4 int8 lanes so data + scale ship as ONE
    # ppermute operand (rounds stay 2K|E|)
    packed = jax.lax.bitcast_convert_type(scale, jnp.int8)  # (..., 1, 4)
    packed = packed.reshape(scale.shape[:-1] + (_SCALE_TAIL,))
    return jnp.concatenate([q, packed], axis=-1)


def decode(wire: jax.Array, dtype: str,
           out_dtype=jnp.float32) -> jax.Array:
    """Invert :func:`encode`: recover the (..., h) tile in `out_dtype`."""
    validate_exchange_dtype(dtype)
    if dtype == "f32":
        return wire.astype(out_dtype)
    if dtype == "bf16":
        return wire.astype(out_dtype)
    q = wire[..., :-_SCALE_TAIL].astype(jnp.float32)
    packed = wire[..., -_SCALE_TAIL:]
    packed = packed.reshape(packed.shape[:-1] + (1, _SCALE_TAIL))
    scale = jax.lax.bitcast_convert_type(packed, jnp.float32)  # (..., 1)
    return (q * (scale / _INT8_LEVELS)).astype(out_dtype)


def ef_init(x: jax.Array) -> jax.Array:
    """Zero error-feedback residual matching one boundary tile `x`."""
    return jnp.zeros_like(x, dtype=jnp.float32)


def ef_encode(x: jax.Array, residual: jax.Array,
              dtype: str) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback encode: ``(wire, new_residual)``.

    Encodes ``t = x + residual`` and returns the fresh residual
    ``t - decode(wire)``, to be carried into the next exchange round.
    For f32 the residual stays zero (lossless wire).
    """
    t = x.astype(jnp.float32) + residual
    wire = encode(t, dtype)
    new_residual = t - decode(wire, dtype, jnp.float32)
    return wire, new_residual
