"""Logical-axis sharding rules: one mapping from logical tensor axes to mesh
axes, consumed everywhere (models, launch, dist backends).

A :class:`ShardingRules` turns logical axis names ("batch", "embed",
"vertex", ...) into :class:`~jax.sharding.PartitionSpec` entries against a
concrete mesh.  The mapping is scheme-based: ``_BASE`` holds the
tensor-parallel default and ``_SCHEMES`` holds named overrides (fsdp, ...).
Rules are pure metadata — constructing them never touches device state, and
`spec` silently drops mesh axes the mesh doesn't have (so one mapping
serves 1-D test meshes, 2-D single-pod meshes, and 3-D multi-pod meshes).

Usage::

    rules = make_rules(mesh, scheme="fsdp")
    w_spec = rules.spec("embed", "ffn")        # PartitionSpec for a weight
    x = rules.constrain(x, "batch", None, "embed")   # sharding constraint

Two consumer families share this vocabulary: the LM substrate (models /
launch, axes like "batch"/"embed"/"heads") and the sharded graph backend
`repro.dist.backends.pallas_halo`, which resolves the "vertex" axis — one
contiguous block of graph vertices per device — through `make_rules` for
the conventional 1-D "graph" mesh (and builds a local override for meshes
whose axis is named differently).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Mapping, Optional, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

AxisTarget = Union[str, Tuple[str, ...], None]

# Scheme-independent logical-axis vocabulary with the tensor-parallel
# (megatron-style) defaults: batch over the data axes, weight matrices
# column/row split over 'model', everything else replicated.
_BASE: Dict[str, AxisTarget] = {
    # graph signals (dist backends: one contiguous vertex block per device
    # on the 1-D "graph" mesh; see repro.dist.backends.halo / pallas_halo)
    "vertex": "graph",
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "frames": None,
    "moe_group": "data",
    # weights
    "layers": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "kv_lora": None,
    "ffn": "model",
    "state": None,
    "expert": "model",
    "vocab": "model",
}

# Named scheme overrides applied on top of _BASE.
_SCHEMES: Dict[str, Dict[str, AxisTarget]] = {
    # tensor parallel (the _BASE defaults)
    "default": {},
    "tp": {},
    # fully-sharded data parallel: weights sharded over every mesh axis on
    # their embed dimension, activations batch-sharded over every axis, no
    # tensor parallelism on heads/ffn/vocab; MoE keeps expert parallelism.
    "fsdp": {
        "batch": ("pod", "data", "model"),
        "embed": ("data", "model"),
        "heads": None,
        "kv_heads": None,
        "ffn": None,
        "vocab": None,
        "expert": "model",
        "moe_group": "data",
    },
    # fsdp without expert parallelism (dense-expert debugging scheme)
    "fsdp_noep": {
        "batch": ("pod", "data", "model"),
        "embed": ("data", "model"),
        "heads": None,
        "kv_heads": None,
        "ffn": None,
        "vocab": None,
        "expert": None,
        "moe_group": "data",
    },
}


@dataclasses.dataclass
class ShardingRules:
    """Logical-axis -> mesh-axis mapping bound to a mesh (or to None = no-op).

    ``mapping`` values may be a mesh axis name, a tuple of mesh axis names
    (sharded over their product), or None (replicated).  Mesh axes absent
    from the bound mesh are dropped, and a mesh axis already consumed by an
    earlier dimension of the same spec is dropped too (a mesh axis can shard
    at most one dimension of a tensor).
    """

    mapping: Mapping[str, AxisTarget]
    mesh: Any = None

    @classmethod
    def null(cls) -> "ShardingRules":
        """Rules that replicate everything and make `constrain` a no-op."""
        return cls(mapping={}, mesh=None)

    def _mesh_axes(self) -> Tuple[str, ...]:
        return tuple(getattr(self.mesh, "axis_names", ()) or ())

    def spec(self, *logical_axes: Optional[str]) -> P:
        """PartitionSpec for a tensor whose dims carry these logical names."""
        available = self._mesh_axes()
        used: set = set()
        entries = []
        for name in logical_axes:
            target = self.mapping.get(name) if name is not None else None
            if target is None:
                entries.append(None)
                continue
            if isinstance(target, str):
                target = (target,)
            live = [ax for ax in target if ax in available and ax not in used]
            used.update(live)
            if not live:
                entries.append(None)
            elif len(live) == 1:
                entries.append(live[0])
            else:
                entries.append(tuple(live))
        return P(*entries)

    def constrain(self, x, *logical_axes: Optional[str]):
        """with_sharding_constraint under the bound mesh (identity if none)."""
        if self.mesh is None or not self._mesh_axes():
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(*logical_axes)))


@functools.lru_cache(maxsize=None)
def make_rules(mesh, scheme: str = "default") -> ShardingRules:
    """Build the rules for a named scheme bound to `mesh` (cached)."""
    try:
        overrides = _SCHEMES[scheme]
    except KeyError:
        raise KeyError(
            f"unknown sharding scheme {scheme!r}; "
            f"available: {sorted(_SCHEMES)}") from None
    mapping = dict(_BASE)
    mapping.update(overrides)
    return ShardingRules(mapping=mapping, mesh=mesh)
