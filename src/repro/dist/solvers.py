"""Distributed Section-V solvers behind one entry point: `plan.solve()`.

The paper's Section V frames *exact* inverse filtering as solving

    Q x = y,   Q = g(P)^{-1}                                     (Eq. (23))

by iterations that cost one-or-a-few matvecs per round — Jacobi (Eq. (24)),
Chebyshev-accelerated Jacobi (Eq. (25)) and the parallel ARMA recursion
(Eqs. (29)-(30)) — which makes them exactly as distributable as the
Section-IV Chebyshev recurrence.  This module runs all of them (plus the
Section-IV truncated-Chebyshev approximation itself, for like-for-like
error-vs-communication comparisons) under every registered execution
backend:

    plan = op.plan("pallas_halo", mesh=mesh)
    res  = plan.solve(y, method="jacobi", tau=0.5, r=2, n_iters=20)
    res.x           # (..., N) solutions, batched signals share the rounds
    res.history     # optional (n_iters, ..., N) iterate history
    res.info        # matvecs/round, rho, ARMA stability, ...

The solver problem is a *rational* filter g(lambda) = num(lambda)/den(lambda)
given by monomial coefficients (low-degree-first; see
`repro.core.filters.power_rational` & friends), from which every method is
derived:

  * ``chebyshev``  — truncated shifted-Chebyshev approximation of g
    (Section IV; n_iters = order K, one matvec per round);
  * ``jacobi``     — Jacobi on den(P) x = num(P) y (Eq. (24);
    deg(den) matvecs per round — Fig. 2(b)'s "2 matvecs per iteration");
  * ``cheb_jacobi``— Chebyshev-accelerated Jacobi (Eq. (25); needs a
    spectral-radius bound rho < 1, estimated by power iteration if omitted);
  * ``arma``       — pole/residue parallel recursion (Eqs. (29)-(30);
    converges iff |p_k| > (lmax - lmin)/2, checked and recorded).

Backends participate through one extracted primitive: the plan's
``matvec_runner`` executes an arbitrary jit-compatible iteration body
against the backend's distributed matvec (padding, sharding specs and halo
exchange handled by the backend), so a solver round costs exactly the
boundary-only exchanges of one matvec — measured, not assumed, by
:func:`repro.dist.commstats.solve_comm_stats`.  Backends without a runner
(out-of-tree registrations) fall back to the single-device reference
matvec, logged at INFO.

Single-launch fast path: a runner matvec tagged with ``mv.block_ell``
(a purely local Block-ELL product — `pallas`; `pallas_halo` on one
shard) collapses a whole Jacobi / accelerated-Jacobi solve into ONE
`kernels.cheb_sweep.jacobi_sweep` kernel launch (the Chebyshev method
rides the same upgrade inside `ops.fused_cheb_recurrence`), VMEM-guarded
with a logged per-round fallback — see docs/ARCHITECTURE.md "Perf
accounting".
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import arma as _arma
from ..core import chebyshev as cheb
from ..core import jacobi as _jacobi

Array = jax.Array

logger = logging.getLogger(__name__)

#: The `plan.solve` method vocabulary (tools/check_docs.py asserts every
#: entry is documented in API.md).
METHODS = ("chebyshev", "jacobi", "cheb_jacobi", "arma")


@dataclasses.dataclass
class SolveResult:
    """Result of one `plan.solve` call.

    x: (..., N) solutions (same leading batch dims as the input y).
    history: (n_iters, ..., N) iterate stack when `history=True` — the
    error-vs-communication-budget hook Fig. 2 plots; `history_errors`
    converts it to per-round errors against a reference.
    info: method/backend diagnostics — `matvecs_per_round` (Jacobi rounds
    that cost deg(den) matvecs show it), `exchange_rounds` (the closed-form
    matvec count; `commstats.solve_comm_stats` measures the same number
    from the jaxpr), `rho` / `arma_stable` convergence data.
    """

    x: Array
    method: str
    backend: str
    n_iters: int
    history: Optional[Array] = None
    info: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def history_errors(self, target: Array) -> np.ndarray:
        """Per-iterate l2 errors ||x^{(t)} - target|| (summed over batch).

        Pairs with `info["matvecs_per_round"]` to plot error against
        communication budget in matvec-equivalents (Fig. 2's axes)."""
        if self.history is None:
            raise ValueError("solve(..., history=True) to record iterates")
        h = np.asarray(self.history)
        t = np.asarray(target)
        diff = h - t[None]
        return np.sqrt((diff * diff).reshape(h.shape[0], -1).sum(axis=1))


# ---------------------------------------------------------------------------
# Rational-spec plumbing
# ---------------------------------------------------------------------------
def _resolve_rational(num, den, tau, r, h_scale):
    """(num, den) monomial coefficients (low-first) or (None, None)."""
    if den is not None:
        num = (1.0,) if num is None else num
        return (tuple(float(c) for c in num), tuple(float(c) for c in den))
    if num is not None:
        raise ValueError("num= given without den=")
    if tau is not None:
        from ..core.filters import power_rational

        return power_rational(tau, r, h_scale)
    return None, None


def _rational_callable(num, den):
    nh = np.asarray(num, dtype=np.float64)[::-1]
    dh = np.asarray(den, dtype=np.float64)[::-1]

    def g(lam):
        lam = np.asarray(lam, dtype=np.float64)
        return np.polyval(nh, lam) / np.polyval(dh, lam)

    return g


def poly_matvec(mv, coeffs: Tuple[float, ...], x: Array) -> Array:
    """p(P) x by Horner — exactly deg(p) matvecs (= exchange rounds)."""
    acc = coeffs[-1] * x
    for c in reversed(coeffs[:-1]):
        acc = mv(acc) + c * x
    return acc


def _poly_matvec_protocol(mv, coeffs: Tuple[float, ...]):
    """:func:`poly_matvec` as a stateful-protocol matvec.

    When `mv` carries the dual-signature error-feedback protocol
    (``mv.init_state``; see `repro.core.chebyshev._stateful_matvec`), the
    returned ``p(P)``-matvec forwards it so the iteration loops can thread
    the quantizer residual through every Horner step.  Plain matvecs come
    back as the plain closure.
    """
    init = getattr(mv, "init_state", None)
    if init is None:
        def pmv(x):
            return poly_matvec(mv, coeffs, x)
        return pmv

    def pmv2(x, state=None):
        if state is None:
            return poly_matvec(mv, coeffs, x)
        acc = coeffs[-1] * x
        for c in reversed(coeffs[:-1]):
            h, state = mv(acc, state)
            acc = h + c * x
        return acc, state

    pmv2.init_state = init
    return pmv2


def _poly_diag(P_dense: np.ndarray, coeffs: Sequence[float]) -> np.ndarray:
    """diag(p(P)) for the Jacobi split, computed once at solve setup.

    diag(P^0) = 1 and diag(P^1) = diag(P) are free; diag(P^2) is one
    O(N^2) einsum; higher powers accumulate dense matrix powers (setup-time
    numpy, acceptable at validation scale — pass `den_diag=` to skip)."""
    P_dense = np.asarray(P_dense)
    n = P_dense.shape[0]
    d = np.full(n, float(coeffs[0]))
    if len(coeffs) > 1 and coeffs[1] != 0.0:
        d = d + coeffs[1] * np.diag(P_dense)
    if len(coeffs) > 2 and coeffs[2] != 0.0:
        d = d + coeffs[2] * np.einsum("ij,ji->i", P_dense, P_dense)
    for m in range(3, len(coeffs)):
        if coeffs[m] == 0.0:
            continue
        d = d + coeffs[m] * np.diag(np.linalg.matrix_power(P_dense, m))
    return d


def _estimate_rho(op, den: Tuple[float, ...], inv_d: np.ndarray,
                  n_iters: int = 100) -> float:
    """Spectral radius of M = I - D^{-1} den(P) by power iteration.

    Pure-numpy setup-time estimate (a scalar, not part of the distributed
    hot loop — and deliberately outside any jax trace so
    `solve_comm_stats` can trace `plan.solve` without concretization
    errors).  D^{-1} den(P) is similar to a symmetric matrix for symmetric
    P, so the dominant eigenvalue is real and plain power iteration
    converges.  The returned value carries a 2% safety factor — pass
    `rho=` for the exact bound.  Needs a dense P (like the Jacobi diagonal
    itself); closure-P operators must pass `rho=` explicitly.
    """
    if callable(op.P):
        raise ValueError(
            "cheb_jacobi needs a spectral-radius bound; P is a matvec "
            "closure — pass rho= explicitly")
    Pm = np.asarray(op.P, dtype=np.float64)

    def mv(v):
        return Pm @ v

    rng = np.random.default_rng(0)
    v = rng.standard_normal(Pm.shape[0])
    v = v / np.linalg.norm(v)
    nrm = 0.0
    for _ in range(n_iters):
        w = v - inv_d * np.asarray(poly_matvec(mv, den, v))
        nrm = float(np.linalg.norm(w))
        v = w / nrm
    return nrm * 1.02


def _fallback_runner(plan):
    mv = plan.op.matvec

    def runner(fn, signals, consts=()):
        return fn(mv, *signals, *consts)

    return runner


def _with_budget(mv, vmem_budget):
    """Re-tag a runner matvec with a per-solve sweep VMEM budget.

    The single-launch paths read the ``mv.block_ell`` / ``mv.vmem_budget``
    tags (see `kernels.ops.fused_cheb_recurrence`); a per-call
    ``vmem_budget=`` must reach them *without* mutating the backend's
    shared matvec object — other plans and cached solves read the same
    tags — so wrap the callable and stamp the override on the wrapper.
    No-op for untagged matvecs: the budget only governs the Block-ELL
    sweep launch.
    """
    if vmem_budget is None or getattr(mv, "block_ell", None) is None:
        return mv

    def wrapped(x):
        return mv(x)

    wrapped.block_ell = mv.block_ell
    wrapped.vmem_budget = int(vmem_budget)
    wrapped.sweep_dtype = getattr(mv, "sweep_dtype", None)
    return wrapped


def _op_solver_cache(op) -> Dict[Any, Any]:
    """Per-operator memo for the dense solve setup (diag(den(P)), rho).

    Stored in the instance __dict__ exactly like the frozen dataclass's
    `cached_property` coefficients, keyed by the den tuple — repeat solves
    (budget sweeps, solve_comm_stats re-traces) pay only the distributed
    iteration, not the O(N^2)-O(N^3) numpy setup."""
    return op.__dict__.setdefault("_solver_cache", {})


def _resolve_den_diag(op, den, den_diag):
    if den_diag is not None:
        return np.asarray(den_diag)
    if callable(op.P):
        raise ValueError(
            "the Jacobi split needs diag(den(P)); P is a matvec closure — "
            "pass den_diag= explicitly")
    cache = _op_solver_cache(op)
    key = ("den_diag", den)
    if key not in cache:
        cache[key] = _poly_diag(np.asarray(op.P), den)
    return cache[key]


# ---------------------------------------------------------------------------
# The entry point behind ExecutionPlan.solve
# ---------------------------------------------------------------------------
def solve_plan(
    plan,
    y: Array,
    method: str = "chebyshev",
    *,
    num: Optional[Sequence[float]] = None,
    den: Optional[Sequence[float]] = None,
    tau: Optional[float] = None,
    r: int = 1,
    h_scale: float = 1.0,
    n_iters: Optional[int] = None,
    rho: Optional[float] = None,
    den_diag: Optional[Array] = None,
    poles: Optional[Sequence[complex]] = None,
    residues: Optional[Sequence[complex]] = None,
    const: Optional[float] = None,
    x0: Optional[Array] = None,
    history: bool = False,
    use_pallas: Optional[bool] = None,
    vmem_budget: Optional[int] = None,
    check_every: int = 0,
) -> SolveResult:
    """Apply x = g(P) y by the Section-V method of choice, distributed.

    See :meth:`repro.dist.operator.ExecutionPlan.solve` for the user-facing
    reference; this is the implementation shared by every backend.

    ``vmem_budget=`` overrides the single-launch sweep's VMEM guard for
    this call only (bytes; default `kernels.ops.DEFAULT_SWEEP_VMEM_BUDGET`)
    — tightening it forces the logged per-order fallback, the knob
    `tools/lint_repro.py`'s JX-VMEM-BUDGET check and the budget-sweep
    benchmarks share.  It changes the traced program, so it is part of the
    `compiled_solve` cache key like every other solver kwarg.

    ``check_every=r`` (default 0 = off, exactly today's behavior) arms the
    **divergence guard**: the solve evaluates the relative residual
    ``||num(P) y - den(P) x|| / ||num(P) y|`` under the plan's own
    (possibly fault-injected) matvec and reports it honestly in
    ``info["residual"]`` / ``info["diverged"]``, with
    ``info["exchange_rounds"]`` counting the residual evaluations' extra
    matvecs.  For plain ``method="jacobi"`` (a stationary iteration, so
    restarting from the current iterate is trajectory-exact) the solve
    runs in chunks of r rounds with a residual/NaN check between chunks
    and exits early once the iteration has demonstrably diverged
    (non-finite, or growing past ``2 x max(best, 1)``); the other methods
    run to completion and take a single post-solve residual/NaN check.
    Guarded runs are eager (one runner launch per chunk), so serving
    loops should keep ``check_every=0`` on known-convergent systems."""
    if method not in METHODS:
        raise ValueError(
            f"unknown solve method {method!r}; available: {METHODS}")
    op = plan.op
    num, den = _resolve_rational(num, den, tau, r, h_scale)
    K = int(n_iters) if n_iters is not None else op.K
    if K < 1:
        raise ValueError("n_iters must be >= 1")

    runner = plan.matvec_runner
    if runner is None:
        logger.info(
            "solve[%s]: backend provides no matvec_runner; falling back to "
            "the single-device reference matvec (results are exact, but the "
            "iteration does not run under the backend's execution strategy)",
            plan.backend)
        runner = _fallback_runner(plan)

    y = jnp.asarray(y)
    info: Dict[str, Any] = {"num": num, "den": den}
    check_every = int(check_every)
    if check_every < 0:
        raise ValueError("check_every must be >= 0")

    if method == "chebyshev":
        res = _solve_chebyshev(plan, runner, y, num, den, K, history,
                               use_pallas, vmem_budget, info)
        if check_every > 0:
            _post_solve_check(res, runner, y, num, den, use_pallas,
                              vmem_budget, check_every)
        return res
    if den is None and not (method == "arma" and poles is not None):
        raise ValueError(
            f"method {method!r} needs the rational filter spec: pass "
            "tau= (+ r=, h_scale=) or num=/den= monomial coefficients "
            "(see repro.core.filters.power_rational / tikhonov_rational / "
            "inverse_filter_rational)" + (
                "; arma also accepts an explicit poles=/residues= form"
                if method == "arma" else ""))
    if method == "jacobi" and check_every > 0 and not history:
        return _solve_jacobi_guarded(plan, runner, y, num, den, K, rho,
                                     den_diag, x0, use_pallas, vmem_budget,
                                     check_every, info)
    if method in ("jacobi", "cheb_jacobi"):
        res = _solve_jacobi(plan, runner, y, num, den, K, method, rho,
                            den_diag, x0, history, use_pallas, vmem_budget,
                            info)
    else:
        res = _solve_arma(plan, runner, y, num, den, K, poles, residues,
                          const, x0, history, info)
    if check_every > 0:
        _post_solve_check(res, runner, y, num, den, use_pallas, vmem_budget,
                          check_every)
    return res


# ---------------------------------------------------------------------------
# Divergence guard (check_every=r)
# ---------------------------------------------------------------------------
#: A checked residual counts as divergence once it exceeds this factor
#: times max(best residual so far, 1.0) — 1.0 being the zero iterate's
#: relative residual, so a solve that never beats "do nothing" and is
#: growing is flagged while honest slow convergence is not.
_DIVERGENCE_FACTOR = 2.0


def _solve_residual(runner, y, x, num, den, use_pallas, vmem_budget):
    """Relative residual ||num(P) y - den(P) x|| / ||num(P) y|| evaluated
    through the plan's own matvec (the fault-injected one, if any) — the
    number a real deployment could actually measure.  Costs
    deg(num) + deg(den) exchange rounds; callers account for them."""

    def fn(mv, yl, xl):
        mv = _with_budget(mv, vmem_budget)
        return poly_matvec(mv, num, yl), poly_matvec(mv, den, xl)

    b, ax = runner(fn, (y, x))
    bn = float(jnp.linalg.norm(b))
    rn = float(jnp.linalg.norm(b - ax))
    return rn / max(bn, 1e-30)


def _post_solve_check(res, runner, y, num, den, use_pallas, vmem_budget,
                      check_every):
    """Single residual/NaN check after a completed solve (methods whose
    trajectory cannot restart mid-run: chebyshev, cheb_jacobi, arma, and
    any history-recording run).  Mutates ``res.info`` in place."""
    finite = bool(jnp.all(jnp.isfinite(res.x)))
    residual = None
    if den is not None:
        residual = _solve_residual(runner, y, res.x, num, den, use_pallas,
                                   vmem_budget)
        res.info["exchange_rounds"] = (
            res.info.get("exchange_rounds", 0)
            + (len(num) - 1) + (len(den) - 1))
    diverged = (not finite) or (residual is not None
                                and not np.isfinite(residual))
    if residual is not None and np.isfinite(residual):
        diverged = diverged or residual > _DIVERGENCE_FACTOR
    res.info.update(check_every=check_every, residual=residual,
                    diverged=bool(diverged))


def _solve_jacobi_guarded(plan, runner, y, num, den, K, rho, den_diag, x0,
                          use_pallas, vmem_budget, check_every, info):
    """Plain Jacobi in chunks of `check_every` rounds with a residual/NaN
    check between chunks and early exit on divergence.

    Jacobi (Eq. (24)) is a stationary iteration — restarting from the
    current iterate reproduces the unchunked trajectory exactly (the one
    caveat is per-runner-launch state like the fault injector's round
    counter and the int8 error-feedback residuals, which reset per chunk;
    determinism per configuration is preserved).  ``exchange_rounds``
    reports what actually ran: per chunk, deg(num) for the right-hand
    side + iters x deg(den) for the sweep + deg(num) + deg(den) for the
    residual evaluation.
    """
    deg_den = len(den) - 1
    deg_num = len(num) - 1
    x = x0
    rounds = 0
    done = 0
    residuals = []
    best = 1.0  # the zero iterate's relative residual
    diverged = False
    while done < K:
        iters = min(check_every, K - done)
        sub = _solve_jacobi(plan, runner, y, num, den, iters, "jacobi",
                            rho, den_diag, x, False, use_pallas,
                            vmem_budget, dict(info))
        x = sub.x
        done += iters
        rounds += iters * deg_den + deg_num
        res = _solve_residual(runner, y, x, num, den, use_pallas,
                              vmem_budget)
        rounds += deg_den + deg_num
        residuals.append(res)
        if not np.isfinite(res) or res > _DIVERGENCE_FACTOR * max(best, 1.0):
            diverged = True
            logger.warning(
                "solve[jacobi]: diverged at round %d/%d "
                "(residual %.3e, best %.3e) — stopping early", done, K, res,
                best)
            break
        best = min(best, res)
    info.update(matvecs_per_round=deg_den, exchange_rounds=rounds,
                check_every=check_every, residual=residuals[-1],
                residual_history=tuple(residuals), diverged=diverged,
                rounds_run=done)
    return SolveResult(x=x, method="jacobi", backend=plan.backend,
                       n_iters=done, info=info)


# ---------------------------------------------------------------------------
# Method implementations (each runs inside the backend's matvec_runner)
# ---------------------------------------------------------------------------
def _cheb_partial_sums(mv, x, c, alpha):
    """Chebyshev recurrence recording the order-k partial sums (history)."""
    t0 = x
    acc = 0.5 * c[0] * t0
    t1 = mv(x) / alpha - x
    acc1 = acc + c[1] * t1

    def body(carry, ck):
        t_km1, t_km2, acc = carry
        t_k = (2.0 / alpha) * mv(t_km1) - 2.0 * t_km1 - t_km2
        acc = acc + ck * t_k
        return (t_k, t_km1, acc), acc

    (_, _, acc_f), hist = jax.lax.scan(body, (t1, t0, acc1), c[2:])
    hist = jnp.concatenate([acc1[None], hist], axis=0)
    return acc_f, hist


def _solve_chebyshev(plan, runner, y, num, den, K, history, use_pallas,
                     vmem_budget, info):
    """Section-IV truncated Chebyshev approximation of g at order K."""
    from ..kernels import ops as kops

    op = plan.op
    lmax = op.lmax
    if den is not None:
        coeffs = cheb.cheb_coeffs(_rational_callable(num, den), K, lmax)
    else:
        # no rational spec: approximate the plan's own (scalar) multiplier
        if op.eta != 1:
            raise ValueError(
                "solve(method='chebyshev') without a rational spec needs a "
                f"scalar operator (eta == 1); this one has eta={op.eta}. "
                "Pass tau=/num=/den= or use plan.apply for the union.")
        coeffs = (np.asarray(op.coeffs)[0] if K == op.K
                  else cheb.cheb_coeffs(op.multipliers[0], K, lmax,
                                        op.coeff_points))
    alpha = lmax / 2.0

    def fn(mv, yl, c):
        mv = _with_budget(mv, vmem_budget)
        if history:
            x, hist = _cheb_partial_sums(mv, yl, c, alpha)
            return x, hist
        return kops.fused_cheb_recurrence(mv, yl, c, lmax,
                                          use_pallas=use_pallas)[..., 0, :]

    c = jnp.asarray(coeffs, y.dtype)
    info.update(matvecs_per_round=1, exchange_rounds=K, order=K)
    if history:
        x, hist = runner(fn, (y,), (c,))
        return SolveResult(x=x, method="chebyshev", backend=plan.backend,
                           n_iters=K, history=hist, info=info)
    x = runner(fn, (y,), (c,))
    return SolveResult(x=x, method="chebyshev", backend=plan.backend,
                       n_iters=K, info=info)


def _solve_jacobi(plan, runner, y, num, den, K, method, rho, den_diag, x0,
                  history, use_pallas, vmem_budget, info):
    """Jacobi (Eq. (24)) / Chebyshev-accelerated Jacobi (Eq. (25)) on
    den(P) x = num(P) y; deg(den) matvecs per round, deg(num) once for the
    right-hand side."""
    op = plan.op
    dd = _resolve_den_diag(op, den, den_diag)
    inv_d = jnp.asarray(1.0 / dd, y.dtype)
    deg_den = len(den) - 1
    deg_num = len(num) - 1
    if method == "cheb_jacobi":
        if rho is None:
            cache = _op_solver_cache(op)
            key = ("rho", den)
            if key not in cache:
                cache[key] = _estimate_rho(op, den, 1.0 / dd)
            rho = cache[key]
            info["rho_estimated"] = True
        rho = float(rho)
        if not 0.0 < rho < 1.0:
            raise ValueError(
                f"cheb_jacobi needs a spectral-radius bound 0 < rho < 1 "
                f"(got {rho:.4f}): the Jacobi split of den(P) diverges — "
                "use method='arma' (Fig. 2(c)'s regime) or a different "
                "splitting")
        info["rho"] = rho
    else:
        # record the estimate for diagnostics but run regardless (plain
        # Jacobi simply diverges when rho >= 1, as Fig. 2(c) shows)
        info["rho"] = float(rho) if rho is not None else None

    info.update(matvecs_per_round=deg_den,
                exchange_rounds=K * deg_den + deg_num)

    signals = [y, inv_d] + ([x0] if x0 is not None else [])

    def fn(mv, yl, inv_dl, *rest):
        from ..kernels import ops as kops

        mv = _with_budget(mv, vmem_budget)
        x0l = rest[0] if rest else None
        b = poly_matvec(mv, num, yl)
        # Single-launch upgrade: a matvec tagged with its local Block-ELL
        # structure (pallas backend; pallas_halo on a 1-shard mesh) runs
        # the whole Eq. (24)/(25) iteration — deg(den) in-kernel SpMVs +
        # the fused update per round — in ONE jacobi_sweep launch, the
        # weight schedule computed host-side.  History recording needs the
        # per-round iterates in HBM, so it stays on the per-round path.
        A_local = getattr(mv, "block_ell", None)
        if A_local is not None and not history:
            if K * deg_den > 256:
                # the in-kernel round loop unrolls the Horner chain; past
                # this many SpMVs the trace/compile cost outweighs the
                # launch savings — logged like every other fallback
                logger.info(
                    "solve[%s]: %d rounds x %d matvecs exceeds the "
                    "single-launch unroll budget (256) — running the "
                    "per-round jacobi_step path", method, K, deg_den)
            else:
                ws = (_jacobi.cheb_jacobi_weights(rho, K)
                      if method == "cheb_jacobi"
                      else _jacobi.jacobi_weights(K))
                return kops.fused_jacobi_sweep(
                    A_local, b, inv_dl, den, ws, x0=x0l,
                    use_pallas=use_pallas,
                    vmem_budget=getattr(mv, "vmem_budget", None),
                    scratch_dtype=getattr(mv, "sweep_dtype", None))

        a_mv = _poly_matvec_protocol(mv, den)

        if method == "jacobi":
            return _jacobi.jacobi_solve(
                a_mv, None, b, K, x0=x0l, return_history=history,
                inv_diag=inv_dl, use_pallas=use_pallas)
        return _jacobi.jacobi_chebyshev_solve(
            a_mv, None, b, rho, K, x0=x0l, return_history=history,
            inv_diag=inv_dl, use_pallas=use_pallas)

    out = runner(fn, tuple(signals))
    if history:
        x, hist = out
        return SolveResult(x=x, method=method, backend=plan.backend,
                           n_iters=K, history=hist, info=info)
    return SolveResult(x=out, method=method, backend=plan.backend,
                       n_iters=K, info=info)


def _solve_arma(plan, runner, y, num, den, K, poles, residues, const, x0,
                history, info):
    """Parallel ARMA recursion (Eqs. (29)-(30)): poles stacked on a leading
    axis, complex iterate carried as a real [Re, Im] stack — one matvec
    (one neighbour exchange of length-K_p messages) per round."""
    op = plan.op
    lmax = op.lmax
    if x0 is not None:
        raise ValueError(
            "method='arma' carries per-pole internal state; a warm-start "
            "x0 in signal space has no (29)-(30) analog")
    if poles is not None:
        if residues is None:
            raise ValueError("poles= given without residues=")
        p_arr = np.asarray(poles, dtype=np.complex128)
        r_arr = np.asarray(residues, dtype=np.complex128)
        c0 = float(const) if const is not None else 0.0
    else:
        r_arr, p_arr, c0 = _arma.arma_from_rational(num, den, lmax)
        if const is not None:
            c0 = float(const)
    stable = _arma.arma_stable(p_arr, lmax)
    if not stable:
        logger.warning(
            "solve[arma]: |p_k| > lmax/2 fails for some pole "
            "(min |p_k| = %.4f vs lmax/2 = %.4f) — the recursion (30) "
            "will diverge (Section V-D)", float(np.abs(p_arr).min()),
            lmax / 2.0)
    info.update(matvecs_per_round=1, exchange_rounds=K,
                n_poles=int(p_arr.shape[0]), arma_stable=stable,
                arma_const=c0)

    rj = jnp.asarray(r_arr, jnp.complex64)
    pj = jnp.asarray(p_arr, jnp.complex64)

    def fn(mv, yl, rjl, pjl):
        return _arma.arma_apply(mv, yl, rjl, pjl, lmax, n_iters=K,
                                const=c0, return_history=history)

    out = runner(fn, (y,), (rj, pj))
    if history:
        x, hist = out
        return SolveResult(x=x, method="arma", backend=plan.backend,
                           n_iters=K, history=hist, info=info)
    return SolveResult(x=out, method="arma", backend=plan.backend,
                       n_iters=K, info=info)
