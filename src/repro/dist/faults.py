"""Deterministic, seeded fault injection for the sharded exchange path.

The paper's premise is distributed filtering on *real* networks — links
drop packets, deliveries go stale, cheap radios flip bits — and the
polynomial-recurrence literature (arxiv 2504.14341, 2205.04019) shows the
Chebyshev/Jacobi iterations tolerate exactly this class of bounded
per-round perturbation: a lost boundary tile costs accuracy, not
correctness.  This module makes that claim *measurable*: a
:class:`FaultSpec` wraps the receive side of every sharded exchange
(`halo`, `pallas_halo`, banded and `GeneralPartition`) with three seeded,
reproducible fault channels plus a graceful-degradation policy.

Fault channels (all per-(round, link) Bernoulli, keyed by
``fold_in(seed, shard, round, link)`` so the same seed replays the same
fault trace bit-for-bit on any backend):

``drop_prob``
    The link delivers nothing this round.  The receiver substitutes per
    its ``degradation`` policy: ``"zero_fill"`` (treat the tile as zero —
    the neighbour's contribution vanishes for one order) or
    ``"hold_last"`` (reuse the last delivered tile, carried across rounds
    through the stateful-matvec protocol of `core.chebyshev` alongside
    the int8 error-feedback residuals).
``stale_prob``
    The link delivers, but late: the receiver consumes the *previous*
    round's tile (the carried tile) instead of this round's.
``noise_prob``
    Per-lane bit-noise on *quantized* wires (bf16 / int8): each wire lane
    independently has one of its low 8 bits flipped with this
    probability.  int8 wires flip payload lanes only — the 4
    bitcast-packed f32 scale lanes ride untouched (a corrupted scale
    would be a codec failure, not the per-element wire noise modelled
    here).  f32 wires are unaffected (the lossless-wire baseline).

Honest accounting is the design constraint: every fault is applied to the
*received* operand **after** the ``ppermute`` — inside jit, with no
control flow around the collective — so the traced collective schedule is
*identical* to the clean plan's (checked by the
``JX-FAULT-NO-EXTRA-COLLECTIVES`` rule in `repro.analysis`) and
`commstats` measures exactly the paper's 2K|E| rounds under every
injected configuration.  A dropped message still crosses the wire; what
degrades is what the receiver *uses*, which is also how lossy physical
links behave (the sender cannot unsend).

``fault_spec=None`` (or any spec with every probability 0) takes the
backends' untouched code path — bitwise-identical traces to today's
exchange, property-tested in ``tests/test_faults.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp

from . import quantize

#: Receiver policies for a dropped link.
DEGRADATIONS = ("zero_fill", "hold_last")

#: fold_in salts separating the per-link fault channels.
_SALT_NOISE, _SALT_STALE, _SALT_DROP = 101, 103, 107


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Seeded link-fault model for one plan (see module docstring).

    drop_prob / stale_prob are per-(round, link) scalar Bernoullis;
    noise_prob is per-wire-lane.  `seed` makes the whole fault trace a
    pure function of (seed, shard, round, link): same seed => the same
    faults, bitwise, on every run and every backend.
    """

    drop_prob: float = 0.0
    stale_prob: float = 0.0
    noise_prob: float = 0.0
    seed: int = 0

    def __post_init__(self):
        for name in ("drop_prob", "stale_prob", "noise_prob"):
            p = float(getattr(self, name))
            if not 0.0 <= p <= 1.0:
                raise ValueError(
                    f"FaultSpec.{name} must be in [0, 1], got {p}")
            object.__setattr__(self, name, p)
        object.__setattr__(self, "seed", int(self.seed))

    @property
    def active(self) -> bool:
        """True when any channel can fire — an all-zero spec is the
        clean exchange and compiles to the identical trace."""
        return (self.drop_prob > 0.0 or self.stale_prob > 0.0
                or self.noise_prob > 0.0)


def validate_degradation(degradation: str) -> str:
    if degradation not in DEGRADATIONS:
        raise ValueError(
            f"degradation must be one of {DEGRADATIONS}, "
            f"got {degradation!r}")
    return degradation


def resolve_fault_spec(
    fault_spec: Union[None, FaultSpec, dict, float]
) -> Optional[FaultSpec]:
    """Normalize a backend's ``fault_spec=`` argument.

    Accepts None (no injection), a :class:`FaultSpec`, a kwargs dict, or
    a bare float shorthand for ``FaultSpec(drop_prob=p)``.
    """
    if fault_spec is None:
        return None
    if isinstance(fault_spec, FaultSpec):
        return fault_spec
    if isinstance(fault_spec, dict):
        return FaultSpec(**fault_spec)
    if isinstance(fault_spec, (int, float)) and not isinstance(
            fault_spec, bool):
        return FaultSpec(drop_prob=float(fault_spec))
    raise TypeError(
        f"fault_spec must be None, a FaultSpec, a dict, or a drop "
        f"probability, got {type(fault_spec).__name__}")


def fault_key(fault_spec, degradation: str = "zero_fill") -> str:
    """Hashable identity of one (spec, policy) configuration.

    Joins the plan ``info`` dict, the `ExecutionPlan.compiled*` memo keys
    and the serving `CompatKey` — plans injecting different faults must
    never share a compiled entry.  Inactive specs collapse to ``"none"``:
    a p=0 spec traces the identical program, so sharing the clean
    plan's cache entry is correct (and is what the p=0 identity test
    asserts).
    """
    validate_degradation(degradation)
    spec = resolve_fault_spec(fault_spec)
    if spec is None or not spec.active:
        return "none"
    return (f"drop{spec.drop_prob:g}-stale{spec.stale_prob:g}"
            f"-noise{spec.noise_prob:g}-seed{spec.seed}-{degradation}")


def spec_info(fault_spec) -> Optional[dict]:
    """JSON-able form of the spec for `plan.info` / bench artifacts."""
    spec = resolve_fault_spec(fault_spec)
    if spec is None:
        return None
    return dataclasses.asdict(spec)


def make_injector(fault_spec, degradation: str, axis: str,
                  exchanging: bool) -> Optional["LinkFaultInjector"]:
    """Injector for one exchange matvec, or None for the clean path.

    `exchanging` is the site's static "this closure really ppermutes"
    predicate (size > 1, and for the general plan: a nonempty send list)
    — on a 1-shard mesh there are no links to fail, so the clean path
    runs and the stateless matvec signature is preserved.  Degradation
    strings are validated unconditionally so typos raise even at p=0.
    """
    validate_degradation(degradation)
    spec = resolve_fault_spec(fault_spec)
    if spec is None or not spec.active or not exchanging:
        return None
    return LinkFaultInjector(spec, degradation, axis)


def _flip_low_bits(bits: jax.Array, key: jax.Array,
                   prob: float) -> jax.Array:
    """Flip one of the low 8 bits of each unsigned-int lane w.p. `prob`."""
    kf, kp = jax.random.split(key)
    flip = jax.random.bernoulli(kf, prob, bits.shape)
    pos = jax.random.randint(kp, bits.shape, 0, 8, dtype=jnp.int32)
    mask = jnp.left_shift(jnp.ones((), bits.dtype),
                          pos.astype(bits.dtype))
    return jnp.where(flip, bits ^ mask, bits)


class LinkFaultInjector:
    """Receiver-side fault application for one exchange closure.

    Lives inside the shard_map body; every method is jit-pure and
    collective-free (the `JX-FAULT-NO-EXTRA-COLLECTIVES` contract).  The
    per-call key chain ``PRNGKey(seed) -> fold_in(shard) ->
    fold_in(round) -> fold_in(link)`` makes each (shard, round, link)
    draw independent and reproducible; `round` is the int32 counter the
    exchange matvec threads through its state alongside the carried
    tiles, `link` is the static receive-direction index (banded: 0 =
    from-left, 1 = from-right; general: the offset index).
    """

    def __init__(self, spec: FaultSpec, degradation: str, axis: str):
        self.spec = spec
        self.degradation = validate_degradation(degradation)
        self.axis = axis

    def _key(self, round_idx, link: int) -> jax.Array:
        key = jax.random.PRNGKey(self.spec.seed)
        key = jax.random.fold_in(key, jax.lax.axis_index(self.axis))
        key = jax.random.fold_in(key, round_idx)
        return jax.random.fold_in(key, link)

    def init_round(self):
        """Round-0 counter for the fault state."""
        return jnp.zeros((), jnp.int32)

    def init_carried(self, tiles):
        """Zero carried tiles (one per incoming link): round-0 drops
        deliver zeros under BOTH policies — before anything arrived,
        hold_last has nothing to hold."""
        return tuple(jnp.zeros_like(t) for t in tiles)

    def wire(self, wire: jax.Array, round_idx, link: int,
             exchange_dtype: str) -> jax.Array:
        """Bit-noise on one received *encoded* wire (pre-decode)."""
        if self.spec.noise_prob <= 0.0 or exchange_dtype == "f32":
            return wire
        key = jax.random.fold_in(self._key(round_idx, link), _SALT_NOISE)
        if exchange_dtype == "bf16":
            bits = jax.lax.bitcast_convert_type(wire, jnp.uint16)
            bits = _flip_low_bits(bits, key, self.spec.noise_prob)
            return jax.lax.bitcast_convert_type(bits, jnp.bfloat16)
        # int8: payload lanes only; the packed f32 scale tail is exempt
        payload = wire[..., :-quantize._SCALE_TAIL]
        scale = wire[..., -quantize._SCALE_TAIL:]
        bits = jax.lax.bitcast_convert_type(payload, jnp.uint8)
        bits = _flip_low_bits(bits, key, self.spec.noise_prob)
        payload = jax.lax.bitcast_convert_type(bits, jnp.int8)
        return jnp.concatenate([payload, scale], axis=-1)

    def recv(self, tile: jax.Array, carried: jax.Array, round_idx,
             link: int):
        """Apply stale-delivery and link-drop to one *decoded* tile.

        Returns ``(delivered, new_carried)``: `delivered` is what the
        boundary coupling consumes this round, and it becomes the
        carried tile for the next round (so consecutive drops under
        hold_last keep re-serving the last real delivery).
        """
        key = self._key(round_idx, link)
        out = tile
        if self.spec.stale_prob > 0.0:
            stale = jax.random.bernoulli(
                jax.random.fold_in(key, _SALT_STALE),
                self.spec.stale_prob)
            out = jnp.where(stale, carried, out)
        if self.spec.drop_prob > 0.0:
            drop = jax.random.bernoulli(
                jax.random.fold_in(key, _SALT_DROP), self.spec.drop_prob)
            fallback = (carried if self.degradation == "hold_last"
                        else jnp.zeros_like(out))
            out = jnp.where(drop, fallback, out)
        return out, out
