"""Pluggable graph partitions: edge-cut sharding for arbitrary sparse graphs.

The paper's headline systems claim — 2K|E| messages per filter application,
for *any* sparse graph (Section IV-B) — does not depend on P being banded.
What the banded `halo.BandedPartition` hard-codes is merely one *exchange
plan*: each shard ships one boundary tile left and one right per Chebyshev
order.  This module extracts the general contract and supplies a
dependency-free partitioner for graphs with no usable bandwidth (community,
k-NN, random-geometric):

* :class:`GeneralPartition` — per-shard Block-ELL structure for the
  intra-shard (interior) edges plus an explicit exchange plan for the cut
  edges.  The plan is a static tuple of ring **offsets**: round ``d`` has
  shard ``i`` send a gathered boundary tile to shard ``(i + d) % S`` via
  one ``ppermute`` whose permutation ``[(i, (i+d) % S)]`` is a complete
  bijection *by construction* — arbitrary neighbour sets are realized as a
  sequence of complete permutation rounds, so the `JX-PPERMUTE-BIJECTION`
  invariant of :mod:`repro.analysis` holds for free and no shard ever
  deadlocks waiting on a partner that isn't sending.  Shards with no cut
  edges at some offset ship a (zero-coupled, hence ignored) padded tile:
  uniform tile shapes keep the collective schedule static and
  batch-invariant.
* :func:`edge_cut_order` — greedy-BFS (default) or recursive spectral-
  bisection vertex ordering, chopped into S contiguous blocks of
  ``nl = ceil(n/S)``.  Pure numpy, no METIS/scipy dependency.
* :func:`partition_general` — builds the partition from a dense matrix or
  a :class:`CSRMatrix` (the million-vertex path: nothing dense is ever
  materialized).
* :func:`build_general_plan` — the shared ExecutionPlan builder both
  sharded backends delegate to (``halo`` with a dense per-shard interior,
  ``pallas_halo`` with the Block-ELL interior), preserving the
  encode→exchange→interior-compute overlap and the PR-8 ``exchange_dtype``
  codec on arbitrary boundary tiles.

Communication per application is exactly K exchange rounds (one per
Chebyshev order; each round = ``len(offsets)`` ppermutes), each moving only
the boundary rows that cross the cut — the general-graph form of the
paper's one-scalar-per-directed-edge-per-order accounting, measured (not
assumed) by :mod:`repro.dist.commstats` and property-tested in
``tests/test_property.py`` / ``tests/test_partition.py``.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .. import _compat  # noqa: F401  (jax.shard_map / axis_size on old jax)
from ..core import chebyshev as cheb
from ..core.lasso import soft_threshold
from ..core import graph as graphmod
from ..kernels import ops
from . import faults, quantize
from .sharding import ShardingRules, make_rules

Array = jax.Array

shard_map = jax.shard_map


class OverfullSlotsError(ValueError):
    """A row block needs more column-block slots than the uniform budget.

    Raised instead of silently truncating: dropping blocks would produce a
    *wrong answer* (missing edges) with no error, the worst failure class.
    Raise the ``max_slots`` budget, use a smaller column block, or let the
    slot count float (``max_slots=None`` sizes slots to the actual max).
    """


# ---------------------------------------------------------------------------
# CSR container + synthetic community graphs (million-vertex scale)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CSRMatrix:
    """A square sparse matrix in CSR form (numpy, host-side).

    The partitioner's native input: at N = 1e6 a dense P would be 4 TB, so
    the whole partition pipeline (ordering, Block-ELL packing, exchange
    plan) is built from CSR without ever materializing a dense array.
    """

    indptr: np.ndarray   # (n + 1,) int64
    indices: np.ndarray  # (nnz,) column ids
    data: np.ndarray     # (nnz,) values

    @property
    def n(self) -> int:
        return len(self.indptr) - 1

    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])

    @property
    def n_edges(self) -> int:
        """|E| — undirected off-diagonal edges (assumes symmetric support)."""
        rows = self.row_ids()
        return int(np.count_nonzero((rows < self.indices)
                                    & (self.data != 0)))

    def row_ids(self) -> np.ndarray:
        return np.repeat(np.arange(self.n, dtype=np.int64),
                         np.diff(self.indptr))

    def matvec(self, x: np.ndarray) -> np.ndarray:
        out = np.zeros(self.n, dtype=np.result_type(self.data, x))
        np.add.at(out, self.row_ids(), self.data * x[self.indices])
        return out

    def to_dense(self) -> np.ndarray:
        out = np.zeros((self.n, self.n), dtype=self.data.dtype)
        out[self.row_ids(), self.indices] = self.data
        return out

    @classmethod
    def from_coo(cls, n: int, rows, cols, vals) -> "CSRMatrix":
        rows = np.asarray(rows, np.int64)
        cols = np.asarray(cols, np.int64)
        vals = np.asarray(vals)
        order = np.lexsort((cols, rows))
        rows, cols, vals = rows[order], cols[order], vals[order]
        indptr = np.searchsorted(rows, np.arange(n + 1))
        return cls(indptr=indptr, indices=cols, data=vals)

    @classmethod
    def from_dense(cls, M) -> "CSRMatrix":
        M = np.asarray(M)
        rows, cols = np.nonzero(M)
        return cls.from_coo(M.shape[0], rows, cols, M[rows, cols])


def as_csr(Pmat: Union[np.ndarray, Array, CSRMatrix]) -> CSRMatrix:
    if isinstance(Pmat, CSRMatrix):
        return Pmat
    return CSRMatrix.from_dense(np.asarray(Pmat))


def csr_matvec_fn(csr: CSRMatrix):
    """A jnp closure ``x -> L x`` over the (..., N) contract — the callable
    P for `GraphOperator` when the graph is too large to densify."""
    rows = jnp.asarray(csr.row_ids(), jnp.int32)
    cols = jnp.asarray(csr.indices, jnp.int32)
    vals = jnp.asarray(csr.data, jnp.float32)
    n = csr.n

    def mv(x):
        contrib = vals * jnp.take(x, cols, axis=-1)
        zero = jnp.zeros(x.shape[:-1] + (n,), x.dtype)
        return zero.at[..., rows].add(contrib.astype(x.dtype))

    return mv


def community_graph_csr(
    n: int,
    n_communities: Optional[int] = None,
    inter_per_comm: int = 2,
    seed: int = 0,
) -> Tuple[CSRMatrix, dict]:
    """Synthetic community graph, Laplacian in CSR, at any scale.

    Each community is a chain + a ring-closing wrap edge; communities are
    linked by a spanning chain of random-endpoint edges plus
    ``inter_per_comm`` extra edges to uniformly random other communities.
    Random endpoints make the inter-community edges *long-range* in any
    contiguous vertex order, so the graph is genuinely non-banded — the
    `GeneralPartition` workload — while the intra-community chains keep it
    connected and give the partitioner real structure to find.  Fully
    vectorized numpy: N = 1e6 builds in seconds.

    Returns ``(L, meta)`` with ``meta = {"n_edges", "lmax",
    "n_communities"}`` — ``lmax`` is the Anderson-Morley bound computed
    from local degrees only (Section IV-B), so no dense spectral work.
    """
    if n < 4:
        raise ValueError(f"community graph needs n >= 4, got {n}")
    if n_communities is None:
        n_communities = max(2, n // 250)
    n_communities = min(n_communities, n // 2)
    c = -(-n // n_communities)
    comm = np.arange(n) // c
    starts = np.arange(n_communities) * c
    ends = np.minimum(starts + c, n) - 1
    rng = np.random.default_rng(seed)

    # chain within each community
    i = np.arange(n - 1)
    keep = comm[i] == comm[i + 1]
    e_u = [i[keep]]
    e_v = [i[keep] + 1]
    # ring-closing wrap edge per community (size >= 3)
    big = (ends - starts) >= 2
    e_u.append(starts[big])
    e_v.append(ends[big])

    def _rand_in(comms):
        sizes = ends[comms] - starts[comms] + 1
        return starts[comms] + rng.integers(0, sizes)

    # spanning inter-community chain (random endpoints: long-range edges)
    k = np.arange(n_communities - 1)
    e_u.append(_rand_in(k))
    e_v.append(_rand_in(k + 1))
    # extra inter edges to random other communities
    if inter_per_comm > 0 and n_communities > 1:
        src = np.repeat(np.arange(n_communities), inter_per_comm)
        dst = rng.integers(0, n_communities - 1, src.size)
        dst = np.where(dst >= src, dst + 1, dst)
        e_u.append(_rand_in(src))
        e_v.append(_rand_in(dst))

    u = np.concatenate(e_u)
    v = np.concatenate(e_v)
    lo, hi = np.minimum(u, v), np.maximum(u, v)
    keep = lo != hi
    lo, hi = lo[keep], hi[keep]
    key = lo * n + hi
    _, uniq_idx = np.unique(key, return_index=True)
    lo, hi = lo[uniq_idx], hi[uniq_idx]
    m = lo.size
    w = rng.uniform(0.5, 1.5, m).astype(np.float32)

    deg = np.zeros(n, np.float64)
    np.add.at(deg, lo, w)
    np.add.at(deg, hi, w)
    lmax = float((deg[lo] + deg[hi]).max())

    rows = np.concatenate([lo, hi, np.arange(n)])
    cols = np.concatenate([hi, lo, np.arange(n)])
    vals = np.concatenate([-w, -w, deg.astype(np.float32)]).astype(np.float32)
    L = CSRMatrix.from_coo(n, rows, cols, vals)
    return L, {"n_edges": int(m), "lmax": lmax,
               "n_communities": int(n_communities)}


# ---------------------------------------------------------------------------
# Edge-cut orderings (dependency-free: greedy BFS / spectral bisection)
# ---------------------------------------------------------------------------
def _ragged_gather(indptr: np.ndarray, indices: np.ndarray,
                   verts: np.ndarray) -> np.ndarray:
    """All CSR column ids of `verts`, concatenated (vectorized ragged
    gather — the partitioner's frontier-expansion primitive)."""
    starts = indptr[verts]
    lens = indptr[verts + 1] - starts
    total = int(lens.sum())
    if total == 0:
        return np.empty(0, indices.dtype)
    offs = np.repeat(starts - np.concatenate(([0], np.cumsum(lens)[:-1])),
                     lens)
    return indices[offs + np.arange(total)]


def _bfs_order(csr: CSRMatrix) -> np.ndarray:
    """Global BFS ordering with min-degree restarts (handles disconnected
    graphs); chopping it into contiguous blocks is the greedy-BFS
    partition.  Each frontier expansion is one vectorized ragged gather."""
    n = csr.n
    deg = np.diff(csr.indptr)
    visited = np.zeros(n, bool)
    order = np.empty(n, np.int64)
    pos = 0
    while pos < n:
        unv = np.flatnonzero(~visited)
        frontier = np.array([unv[np.argmin(deg[unv])]])
        visited[frontier] = True
        while frontier.size:
            order[pos:pos + frontier.size] = frontier
            pos += frontier.size
            nbr = _ragged_gather(csr.indptr, csr.indices, frontier)
            nbr = nbr[~visited[nbr]]
            frontier = np.unique(nbr)
            visited[frontier] = True
    return order


def _sub_csr(csr: CSRMatrix, idx: np.ndarray):
    """Extract the principal submatrix on `idx` with remapped local ids."""
    n = csr.n
    local = np.full(n, -1, np.int64)
    local[idx] = np.arange(idx.size)
    rows_l = np.repeat(np.arange(idx.size),
                       csr.indptr[idx + 1] - csr.indptr[idx])
    cols_g = _ragged_gather(csr.indptr, csr.indices, idx)
    starts = csr.indptr[idx]
    lens = csr.indptr[idx + 1] - starts
    offs = (np.repeat(starts - np.concatenate(([0], np.cumsum(lens)[:-1])),
                      lens) + np.arange(int(lens.sum())))
    vals = csr.data[offs]
    keep = local[cols_g] >= 0
    return rows_l[keep], local[cols_g[keep]], vals[keep]


def _fiedler_vector(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                    n: int, rng, iters: int = 80) -> np.ndarray:
    """Approximate Fiedler vector of the Laplacian submatrix by power
    iteration on sigma*I - L (constant mode deflated each step)."""
    diag = np.zeros(n)
    on_diag = rows == cols
    np.add.at(diag, rows[on_diag], vals[on_diag])
    absrow = np.zeros(n)
    np.add.at(absrow, rows, np.abs(vals))
    sigma = float(absrow.max()) + 1.0  # Gershgorin upper bound on lmax
    v = rng.standard_normal(n)
    for _ in range(iters):
        Lv = np.zeros(n)
        np.add.at(Lv, rows, vals * v[cols])
        v = sigma * v - Lv
        v = v - v.mean()
        nrm = np.linalg.norm(v)
        if nrm < 1e-12:
            v = rng.standard_normal(n)
            v = v - v.mean()
            nrm = np.linalg.norm(v)
        v = v / nrm
    return v


def _spectral_order(csr: CSRMatrix, n_shards: int, nl: int,
                    seed: int = 0) -> np.ndarray:
    """Recursive spectral bisection; split sizes are multiples of nl so the
    recursion's cut planes coincide with the final contiguous shard
    boundaries."""
    rng = np.random.default_rng(seed)

    def bisect(idx: np.ndarray, parts: int) -> list:
        if parts <= 1 or idx.size <= 2:
            return [idx]
        rows, cols, vals = _sub_csr(csr, idx)
        f = _fiedler_vector(rows, cols, vals, idx.size, rng)
        left_parts = parts // 2
        n_left = min(left_parts * nl, idx.size)
        sel = np.argsort(f, kind="stable")
        return (bisect(idx[sel[:n_left]], left_parts)
                + bisect(idx[sel[n_left:]], parts - left_parts))

    chunks = bisect(np.arange(csr.n, dtype=np.int64), n_shards)
    return np.concatenate(chunks)


def edge_cut_order(Pmat, n_shards: int, method: str = "bfs",
                   seed: int = 0) -> np.ndarray:
    """Vertex ordering whose contiguous nl-chunks form the edge-cut
    partition.  `method`: "bfs" (greedy BFS, vectorized frontier
    expansion — the million-vertex default) or "spectral" (recursive
    spectral bisection via power-iteration Fiedler vectors)."""
    csr = as_csr(Pmat)
    if method == "bfs":
        return _bfs_order(csr)
    if method == "spectral":
        nl = -(-csr.n // n_shards)
        return _spectral_order(csr, n_shards, nl, seed=seed)
    raise ValueError(f"unknown partition method {method!r}; "
                     "use 'bfs' or 'spectral'")


# ---------------------------------------------------------------------------
# Vectorized COO -> per-shard Block-ELL
# ---------------------------------------------------------------------------
def _block_ell_shards(shard: np.ndarray, rows: np.ndarray, cols: np.ndarray,
                      vals: np.ndarray, n_shards: int, nl: int,
                      block: Tuple[int, int],
                      max_slots: Optional[int] = None):
    """Pack per-shard COO triples (local rows/cols in [0, nl)) into a
    uniform-slot Block-ELL stack (S, nrb, slots, br, bc) — O(nnz log nnz),
    no python loop over blocks (to_block_ell's dense scan is quadratic in
    block count and unusable at N = 1e6)."""
    br, bc = block
    unit = int(np.lcm(br, bc))
    pnl = -(-nl // unit) * unit
    nrb, ncb = pnl // br, pnl // bc
    dtype = vals.dtype if vals.size else np.float32

    nz = vals != 0
    shard, rows, cols, vals = shard[nz], rows[nz], cols[nz], vals[nz]
    if rows.size == 0:
        slots = 1
        blocks = np.zeros((n_shards, nrb, slots, br, bc), dtype)
        indices = np.zeros((n_shards, nrb, slots), np.int32)
        mask = np.zeros((n_shards, nrb, slots), bool)
        return blocks, indices, mask, pnl

    rb, cb = rows // br, cols // bc
    gkey = (shard.astype(np.int64) * nrb + rb) * ncb + cb
    uniq, inv = np.unique(gkey, return_inverse=True)
    urow = uniq // ncb  # shard * nrb + rb, sorted non-decreasing
    firsts = np.flatnonzero(np.r_[True, urow[1:] != urow[:-1]])
    counts = np.diff(np.r_[firsts, uniq.size])
    slots = int(counts.max())
    if max_slots is not None and slots > max_slots:
        raise OverfullSlotsError(
            f"a row block couples {slots} column blocks but the uniform "
            f"slot budget is {max_slots} — refusing to truncate (silently "
            "dropped blocks = silently wrong matvecs); raise max_slots or "
            "shrink the column block")
    slot_of_uniq = np.arange(uniq.size) - np.repeat(firsts, counts)
    flat_blocks = np.zeros((n_shards * nrb * slots, br, bc), dtype)
    block_id = urow * slots + slot_of_uniq
    np.add.at(flat_blocks, (block_id[inv], rows % br, cols % bc), vals)
    flat_idx = np.zeros((n_shards * nrb, slots), np.int32)
    flat_mask = np.zeros((n_shards * nrb, slots), bool)
    flat_idx[urow, slot_of_uniq] = (uniq % ncb).astype(np.int32)
    flat_mask[urow, slot_of_uniq] = True
    return (flat_blocks.reshape(n_shards, nrb, slots, br, bc),
            flat_idx.reshape(n_shards, nrb, slots),
            flat_mask.reshape(n_shards, nrb, slots),
            pnl)


# ---------------------------------------------------------------------------
# The partition contract
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class GeneralPartition:
    """Edge-cut partition of a sparse P over S shards + explicit exchange
    plan.

    Vertices are relabeled by `order` (original vertex id at partition slot
    i) and chopped into S contiguous blocks of nl rows.  Intra-shard
    entries live in the per-shard Block-ELL stack; every cut entry
    P[u, v] with u on shard r and v on shard o is realized as one exchange
    round at ring offset ``d = (r - o) % S`` plus one scatter coupling:

      blocks/indices/mask: (S, nrb, slots, br, bc) / (S, nrb, slots)
          per-shard Block-ELL of the interior (diagonal) block.
      offsets: static ring offsets, ascending.  Round k: every shard i
          gathers its boundary tile ``x[send_idx[k][i]]`` and ppermutes it
          with the complete bijection ``[(i, (i + offsets[k]) % S)]``.
      send_idx[k]: (S, h_k) int32 — local rows shard i ships at offset k
          (padded with row 0; receivers index only real positions).
      send_counts[k]: (S,) — how many of the h_k rows are real per shard.
      cpl_rows/cpl_cols/cpl_vals[k]: (S, m_k) — receiver-side scatter:
          shard i adds ``vals * tile[cols]`` into its rows, where `tile`
          arrived from shard ``(i - offsets[k]) % S`` (zero-val padding).
      order / n / n_local / edge_cut / method: bookkeeping.

    A banded graph under the identity order reduces exactly to the ring
    plan: offsets (1, S-1) with the tail/head boundary tiles —
    property-tested in tests/test_property.py.
    """

    blocks: Array
    indices: Array
    mask: Array
    offsets: Tuple[int, ...]
    send_idx: Tuple[Array, ...]
    send_counts: Tuple[Tuple[int, ...], ...]
    cpl_rows: Tuple[Array, ...]
    cpl_cols: Tuple[Array, ...]
    cpl_vals: Tuple[Array, ...]
    order: np.ndarray
    n: int
    n_local: int
    edge_cut: int
    method: str

    @property
    def n_shards(self) -> int:
        return self.blocks.shape[0]

    @property
    def n_padded(self) -> int:
        """Global padded signal size (S * nl); `halo.pad_signal` reads it."""
        return self.n_shards * self.n_local

    @property
    def n_local_padded(self) -> int:
        """Per-shard Block-ELL padded domain (nrb * br >= nl)."""
        return self.blocks.shape[1] * self.blocks.shape[3]

    @property
    def nnz_blocks(self) -> int:
        return int(np.asarray(self.mask).sum())

    @property
    def tile_widths(self) -> Tuple[int, ...]:
        return tuple(int(s.shape[1]) for s in self.send_idx)

    @property
    def halo(self) -> int:
        """Widest exchange tile (the banded plan's h analog; 0 = no cut)."""
        return max(self.tile_widths, default=0)

    @property
    def inv_order(self) -> np.ndarray:
        inv = self.__dict__.get("_inv_order")
        if inv is None:
            inv = np.empty_like(self.order)
            inv[self.order] = np.arange(self.order.size)
            self.__dict__["_inv_order"] = inv
        return inv

    @property
    def fingerprint(self) -> str:
        """Stable identity of the partition (order + exchange plan shape);
        joins plan memo keys and serving compat keys so plans built over
        different partitions never share a compiled entry."""
        fp = self.__dict__.get("_fingerprint")
        if fp is None:
            h = hashlib.sha1()
            h.update(np.ascontiguousarray(self.order).tobytes())
            h.update(repr((self.n, self.n_local, self.offsets,
                           self.tile_widths)).encode())
            fp = h.hexdigest()[:12]
            self.__dict__["_fingerprint"] = fp
        return fp

    def _order_jnp(self):
        cached = self.__dict__.get("_order_j")
        if cached is None:
            cached = (jnp.asarray(self.order, jnp.int32),
                      jnp.asarray(self.inv_order, jnp.int32))
            self.__dict__["_order_j"] = cached
        return cached

    def to_partition_order(self, x: Array) -> Array:
        """Permute the trailing (vertex) axis into partition order."""
        return jnp.take(x, self._order_jnp()[0], axis=-1)

    def from_partition_order(self, y: Array) -> Array:
        """Inverse of :meth:`to_partition_order` (trailing axis length n)."""
        return jnp.take(y, self._order_jnp()[1], axis=-1)

    def dense_diag(self) -> np.ndarray:
        """(S, nl, nl) dense per-shard diagonal blocks — the `halo`
        backend's interior representation (small-n use only)."""
        S, nrb, slots, br, bc = self.blocks.shape
        pnl = self.n_local_padded
        blocks = np.asarray(self.blocks)
        indices = np.asarray(self.indices)
        mask = np.asarray(self.mask)
        out = np.zeros((S, pnl, pnl), blocks.dtype)
        for s in range(S):
            for rb in range(nrb):
                for k in range(slots):
                    if mask[s, rb, k]:
                        cb = int(indices[s, rb, k])
                        out[s, rb * br:(rb + 1) * br,
                            cb * bc:(cb + 1) * bc] += blocks[s, rb, k]
        return out[:, :self.n_local, :self.n_local]

    def wire_bytes_per_round(self, exchange_dtype: str = "f32") -> int:
        """Bytes ONE shard ships per exchange round (= per matvec): the sum
        of its per-offset tile wire sizes under the PR-8 codec."""
        return sum(quantize.tile_wire_bytes(h, exchange_dtype)
                   for h in self.tile_widths)


def general_bytes_per_apply(parts: GeneralPartition, K: int, eta: int = 1,
                            exchange_dtype: str = "f32") -> int:
    """Collective-traffic model for one application under a general
    partition: K rounds x S shards x the per-shard wire bytes of all
    offset tiles (eta-wide iterates for the adjoint) — the arbitrary-graph
    analog of `halo.halo_bytes_per_apply`."""
    return K * parts.n_shards * eta * parts.wire_bytes_per_round(
        exchange_dtype)


def partition_general(
    Pmat: Union[np.ndarray, Array, CSRMatrix],
    n_shards: int,
    *,
    method: str = "bfs",
    block: Tuple[int, int] = (8, 128),
    max_slots: Optional[int] = None,
    order: Optional[np.ndarray] = None,
    seed: int = 0,
) -> GeneralPartition:
    """Build a :class:`GeneralPartition` from a dense matrix or CSRMatrix.

    `order` overrides the partitioner (method becomes "precomputed") —
    pass ``np.arange(n)`` to shard an already-sorted graph in place.
    ``max_slots`` bounds the uniform Block-ELL slot count and *raises*
    :class:`OverfullSlotsError` when exceeded (never truncates).
    """
    csr = as_csr(Pmat)
    n = csr.n
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if order is None:
        order = edge_cut_order(csr, n_shards, method=method, seed=seed)
    else:
        order = np.asarray(order, np.int64)
        if sorted(order.tolist()) != list(range(n)):
            raise ValueError("order= must be a permutation of range(n)")
        method = "precomputed"
    nl = -(-n // n_shards)
    pos = np.empty(n, np.int64)
    pos[order] = np.arange(n)

    rows_g = csr.row_ids()
    nz = csr.data != 0
    pr = pos[rows_g[nz]]
    pc = pos[csr.indices[nz]]
    w = csr.data[nz].astype(np.float32)
    sr, sc = pr // nl, pc // nl

    intra = sr == sc
    blocks, indices, mask, _pnl = _block_ell_shards(
        sr[intra], pr[intra] - sr[intra] * nl, pc[intra] - sc[intra] * nl,
        w[intra], n_shards, nl, block, max_slots=max_slots)

    cut = ~intra
    d_all = (sr[cut] - sc[cut]) % n_shards
    offsets, send_idx, send_counts = [], [], []
    cpl_rows, cpl_cols, cpl_vals = [], [], []
    for d in np.unique(d_all).tolist():
        sel = d_all == d
        snd = sc[cut][sel]                  # sender shard per cut entry
        lv = pc[cut][sel] - snd * nl        # sender-local boundary row
        rcv = sr[cut][sel]                  # receiver shard
        lu = pr[cut][sel] - rcv * nl        # receiver-local target row
        wv = w[cut][sel]

        okey = snd * nl + lv
        u = np.unique(okey)
        uo, ulv = u // nl, u % nl
        counts = np.bincount(uo, minlength=n_shards)
        h = int(counts.max())
        first = np.concatenate(([0], np.cumsum(counts)))[:-1]
        rank_u = np.arange(u.size) - first[uo]
        sidx = np.zeros((n_shards, h), np.int32)
        sidx[uo, rank_u] = ulv.astype(np.int32)
        col_pos = rank_u[np.searchsorted(u, okey)]

        mcounts = np.bincount(rcv, minlength=n_shards)
        m = int(mcounts.max())
        firstm = np.concatenate(([0], np.cumsum(mcounts)))[:-1]
        eidx = np.argsort(rcv, kind="stable")
        rank_e = np.arange(eidx.size) - firstm[rcv[eidx]]
        crows = np.zeros((n_shards, m), np.int32)
        ccols = np.zeros((n_shards, m), np.int32)
        cvals = np.zeros((n_shards, m), np.float32)
        crows[rcv[eidx], rank_e] = lu[eidx].astype(np.int32)
        ccols[rcv[eidx], rank_e] = col_pos[eidx].astype(np.int32)
        cvals[rcv[eidx], rank_e] = wv[eidx]

        offsets.append(int(d))
        send_idx.append(jnp.asarray(sidx))
        send_counts.append(tuple(int(c) for c in counts))
        cpl_rows.append(jnp.asarray(crows))
        cpl_cols.append(jnp.asarray(ccols))
        cpl_vals.append(jnp.asarray(cvals))

    return GeneralPartition(
        blocks=jnp.asarray(blocks),
        indices=jnp.asarray(indices),
        mask=jnp.asarray(mask),
        offsets=tuple(offsets),
        send_idx=tuple(send_idx),
        send_counts=tuple(send_counts),
        cpl_rows=tuple(cpl_rows),
        cpl_cols=tuple(cpl_cols),
        cpl_vals=tuple(cpl_vals),
        order=order,
        n=n,
        n_local=nl,
        edge_cut=int(cut.sum()) // 2,
        method=method,
    )


def partition_to_dense(parts: GeneralPartition) -> np.ndarray:
    """Reassemble the dense P from interior blocks + exchange plan, back in
    the ORIGINAL vertex order — the correctness oracle of the property
    suite: equality with the input P proves every edge is covered exactly
    once across intra-shard blocks and the exchange plan (a dropped edge
    shows as a zero, a double-covered one as a doubled weight)."""
    S, nl = parts.n_shards, parts.n_local
    np_tot = parts.n_padded
    A = np.zeros((np_tot, np_tot), np.float64)
    diag = parts.dense_diag()
    for s in range(S):
        A[s * nl:(s + 1) * nl, s * nl:(s + 1) * nl] += diag[s]
    for k, d in enumerate(parts.offsets):
        sidx = np.asarray(parts.send_idx[k])
        crows = np.asarray(parts.cpl_rows[k])
        ccols = np.asarray(parts.cpl_cols[k])
        cvals = np.asarray(parts.cpl_vals[k])
        for r in range(S):
            o = (r - d) % S
            nzc = cvals[r] != 0
            gr = r * nl + crows[r][nzc]
            gc = o * nl + sidx[o][ccols[r][nzc]]
            np.add.at(A, (gr, gc), cvals[r][nzc])
    A = A[:parts.n, :parts.n]
    inv = parts.inv_order
    return A[np.ix_(inv, inv)]


# ---------------------------------------------------------------------------
# The shared exchange matvec (runs inside shard_map)
# ---------------------------------------------------------------------------
def make_exchange_matvec(interior, sends, couplings, axis: str, size: int,
                         exchange_dtype: str = "f32",
                         error_feedback: bool = True,
                         fault_spec=None, degradation: str = "zero_fill"):
    """Interior/boundary-split matvec over an arbitrary exchange plan.

    `interior(x)` is the shard-local product (dense diag einsum or
    Block-ELL SpMV); `sends` is a tuple of ``(idx, offset)`` boundary-tile
    gathers and `couplings` the matching ``(rows, cols, vals)`` receiver
    scatters.  Per call, in the same order as the banded `_halo_matvec`:

    1. every boundary tile is gathered, encoded to `exchange_dtype`
       (`repro.dist.quantize` — the PR-8 codec works on arbitrary tiles)
       and put on the wire: one ppermute per offset, each a complete
       bijection ``[(i, (i + d) % size)]``;
    2. the interior product runs while the exchange is in flight;
    3. received tiles decode and scatter-add into the output rows
       (`y.at[rows].add(vals * tile[cols])` — duplicate rows accumulate).

    Under ``exchange_dtype="int8"`` with error feedback the closure follows
    the dual-signature stateful protocol of `core.chebyshev`
    (``mv(x, state) -> (y, state)``, ``mv.init_state``), threading one
    quantization residual per offset tile across the K orders.

    With an *active* ``fault_spec`` (see `repro.dist.faults`) the state
    additionally carries the round counter and one last-delivered tile
    per offset; every received tile passes the injector's wire-noise /
    stale / drop channels AFTER its ppermute, so the traced collective
    schedule — and the measured 2K|E| rounds — is identical to the clean
    plan's.  The offset index is the injector's link id.
    """
    dt = quantize.validate_exchange_dtype(exchange_dtype)
    exchanging = size > 1 and len(sends) > 0
    inj = faults.make_injector(fault_spec, degradation, axis, exchanging)
    use_ef = dt == "int8" and error_feedback and exchanging

    def _run(x, state):
        if inj is not None:
            k, carried, ef_state = state
        else:
            ef_state = state
        if exchanging:
            tiles = [jnp.take(x, idx, axis=-1) for idx, _ in sends]
            if ef_state is None:
                wires = [quantize.encode(t, dt) for t in tiles]
                new_ef = None
            else:
                wires, new_ef = [], []
                for t, r in zip(tiles, ef_state):
                    wt, rt = quantize.ef_encode(t, r, dt)
                    wires.append(wt)
                    new_ef.append(rt)
                new_ef = tuple(new_ef)
            # (1) one complete-bijection ppermute per ring offset — the
            # multi-peer generalization of the banded left/right pair
            recvs = [
                jax.lax.ppermute(
                    wt, axis,
                    perm=[(i, (i + off) % size) for i in range(size)])
                for wt, (_, off) in zip(wires, sends)
            ]
            # (2) interior product overlaps the exchange
            y = interior(x)
            # (3) decode on arrival; injected faults perturb only what the
            # receiver consumes — the wire traffic is already committed
            if inj is not None:
                recvs = [inj.wire(rv, k, j, dt)
                         for j, rv in enumerate(recvs)]
            recvs = [quantize.decode(rv, dt, x.dtype) for rv in recvs]
            if inj is not None:
                new_carried = []
                faulted = []
                for j, (rv, c) in enumerate(zip(recvs, carried)):
                    rv, c = inj.recv(rv, c, k, j)
                    faulted.append(rv)
                    new_carried.append(c)
                recvs = faulted
                new_state = (k + 1, tuple(new_carried), new_ef)
            else:
                new_state = new_ef
        else:
            recvs = [jnp.take(x, idx, axis=-1) for idx, _ in sends]
            new_state = state
            y = interior(x)
        for (rows, cols, vals), rv in zip(couplings, recvs):
            y = y.at[..., rows].add(
                vals.astype(x.dtype) * jnp.take(rv, cols, axis=-1))
        return y, new_state

    def mv(x, state=None):
        if state is None:
            if inj is not None:
                return _run(x, mv.init_state(x))[0]
            return _run(x, None)[0]
        return _run(x, state)

    if inj is not None:
        def init_state(x):
            tiles = tuple(jnp.take(x, idx, axis=-1) for idx, _ in sends)
            ef0 = (tuple(quantize.ef_init(t) for t in tiles)
                   if use_ef else None)
            return (inj.init_round(), inj.init_carried(tiles), ef0)

        mv.init_state = init_state
    elif use_ef:
        def init_state(x):
            return tuple(quantize.ef_init(jnp.take(x, idx, axis=-1))
                         for idx, _ in sends)

        mv.init_state = init_state
    return mv


# ---------------------------------------------------------------------------
# The shared ExecutionPlan builder (both sharded backends delegate here)
# ---------------------------------------------------------------------------
def resolve_partition_arg(op, partition, n_shards: int,
                          block: Tuple[int, int] = (8, 128),
                          method: str = "bfs"):
    """Normalize a backend's ``partition=`` argument.

    Returns a `GeneralPartition` when the general path should run (the
    instance itself, or one built from a dense P for ``"general"``), else
    None (banded family: None / "banded" / BandedPartition /
    ShardedBlockELL are handled by the calling backend)."""
    if isinstance(partition, GeneralPartition):
        if partition.n_shards != n_shards:
            raise ValueError(
                f"partition has {partition.n_shards} shards but the mesh "
                f"axis has {n_shards}")
        return partition
    if isinstance(partition, str):
        if partition == "banded":
            return None
        if partition == "general":
            if callable(op.P):
                raise ValueError(
                    "partition='general' needs a dense P (or pass a "
                    "precomputed GeneralPartition built from CSR)")
            return partition_general(np.asarray(op.P), n_shards,
                                     method=method, block=block)
        raise ValueError(f"unknown partition {partition!r}; use 'banded', "
                         "'general', or a partition instance")
    return None


def _sharded(fn, mesh, in_specs, out_specs):
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_vma=False)


def build_general_plan(op, parts: GeneralPartition, mesh, axis: str, *,
                       interior: str = "block_ell",
                       use_pallas: Optional[bool] = None,
                       vmem_budget: Optional[int] = None,
                       sweep_dtype: Optional[str] = None,
                       exchange_dtype: str = "f32",
                       error_feedback: bool = True,
                       fault_spec=None, degradation: str = "zero_fill",
                       backend_name: str = "pallas_halo"):
    """ExecutionPlan over a :class:`GeneralPartition`.

    `interior` selects the shard-local representation: "block_ell" (the
    `pallas_halo` hot loop — Pallas SpMV + fused Chebyshev step, padded
    Block-ELL domain) or "dense" (the `halo` backend's per-shard dense
    diagonal einsum, small-n only).  Everything else — signatures, the
    exchange codec, the Section-V `matvec_runner` substrate, the fused
    in-shard_map lasso — matches the banded builders; signals are permuted
    into partition order on entry and back on exit, so callers never see
    the relabeling (solver state like Jacobi's 1/diag travels as signals
    and is permuted consistently).
    """
    from .operator import ExecutionPlan
    from ..core.lasso import LassoResult, _mu_threshold

    quantize.validate_exchange_dtype(exchange_dtype)
    faults.validate_degradation(degradation)
    fault_spec = faults.resolve_fault_spec(fault_spec)
    if interior not in ("block_ell", "dense"):
        raise ValueError(f"unknown interior {interior!r}")
    S, n, nl = parts.n_shards, parts.n, parts.n_local
    dl = parts.n_local_padded if interior == "block_ell" else nl
    coeffs, lmax = op.coeffs, op.lmax
    n_off = len(parts.offsets)

    if interior == "block_ell":
        base_mats: Tuple[Array, ...] = (parts.blocks, parts.indices,
                                        parts.mask)
    else:
        base_mats = (jnp.asarray(parts.dense_diag()),)
    nbase = len(base_mats)
    ex_mats = []
    for k in range(n_off):
        ex_mats += [parts.send_idx[k], parts.cpl_rows[k],
                    parts.cpl_cols[k], parts.cpl_vals[k]]
    mats = base_mats + tuple(ex_mats)

    def _mk_mv(local_mats, size):
        base, ex = local_mats[:nbase], local_mats[nbase:]
        if interior == "block_ell":
            local_A = graphmod.BlockELL(blocks=base[0], indices=base[1],
                                        mask=base[2], n=nl)

            def interior_mv(x):
                return ops.spmv(local_A, x, use_pallas=use_pallas)
        else:
            local_A = None
            dg = base[0]

            def interior_mv(x):
                return jnp.einsum("ij,...j->...i", dg, x)

        sends = tuple((ex[4 * k], parts.offsets[k]) for k in range(n_off))
        coupl = tuple((ex[4 * k + 1], ex[4 * k + 2], ex[4 * k + 3])
                      for k in range(n_off))
        mv = make_exchange_matvec(interior_mv, sends, coupl, axis, size,
                                  exchange_dtype, error_feedback,
                                  fault_spec, degradation)
        if size == 1 and interior == "block_ell":
            # no exchange on a 1-shard mesh: tag for the single-launch
            # sweep kernel, exactly like the banded 1-shard path
            mv.block_ell = local_A
            mv.vmem_budget = vmem_budget
            mv.sweep_dtype = sweep_dtype
        return mv

    info = {
        "mesh_axis": axis,
        "n_shards": S,
        "n_local": nl,
        "halo_width": parts.halo,
        "partition": "general",
        "partition_method": parts.method,
        "partition_fingerprint": parts.fingerprint,
        "partition_offsets": parts.offsets,
        "partition_tile_widths": parts.tile_widths,
        "edge_cut": parts.edge_cut,
        "exchange_dtype": exchange_dtype,
        "error_feedback": bool(error_feedback),
        "fault_spec": faults.spec_info(fault_spec),
        "degradation": degradation,
        "fault_key": faults.fault_key(fault_spec, degradation),
        "exchange_collectives_per_round": n_off if S > 1 else 0,
        "halo_bytes_per_apply": general_bytes_per_apply(
            parts, op.K, 1, exchange_dtype) if S > 1 else 0,
        "halo_bytes_per_adjoint": general_bytes_per_apply(
            parts, op.K, op.eta, exchange_dtype) if S > 1 else 0,
    }
    if interior == "block_ell":
        info.update({
            "n_local_padded": dl,
            "block": (int(parts.blocks.shape[3]),
                      int(parts.blocks.shape[4])),
            "nnz_blocks": parts.nnz_blocks,
            "sweep_dtype": sweep_dtype or "f32",
            "sweep_vmem_bytes": ops.cheb_sweep_vmem_bytes(
                graphmod.BlockELL(blocks=parts.blocks[0],
                                  indices=parts.indices[0],
                                  mask=parts.mask[0], n=nl),
                dl, op.eta, op.K, scratch_dtype=sweep_dtype),
        })

    def _pin(x):
        """Vertex order -> partition order, padded to the global S*nl."""
        return ops.pad_trailing(
            parts.to_partition_order(jnp.asarray(x)), S * nl)

    def _pout(y):
        """Partition order (padded) -> vertex order (logical n)."""
        return parts.from_partition_order(y[..., :n])

    if S == 1:
        mv = _mk_mv(tuple(m[0] for m in mats), 1)

        def _pad1(x):
            return ops.pad_trailing(parts.to_partition_order(
                jnp.asarray(x)), dl)

        def apply(f: Array) -> Array:
            c2 = jnp.atleast_2d(jnp.asarray(coeffs, f.dtype))
            out = ops.fused_cheb_recurrence(mv, _pad1(f), c2, lmax,
                                            use_pallas=use_pallas)
            return _pout(out)

        def apply_adjoint(a: Array) -> Array:
            c = jnp.asarray(coeffs, a.dtype)
            return _pout(cheb.cheb_apply_adjoint(mv, _pad1(a), c, lmax))

        def apply_gram(f: Array) -> Array:
            d = jnp.asarray(cheb.gram_coeffs(coeffs), f.dtype)[None]
            out = ops.fused_cheb_recurrence(mv, _pad1(f), d, lmax,
                                            use_pallas=use_pallas)
            return _pout(out[..., 0, :])

        def solve_lasso(y, mu, gamma, n_iters):
            c = jnp.asarray(coeffs, y.dtype)
            thresh = _mu_threshold(mu, op.eta, y.dtype, gamma)
            phi_y = ops.fused_cheb_recurrence(mv, _pad1(y), c, lmax,
                                              use_pallas=use_pallas)

            def body(a, _):
                back = cheb.cheb_apply_adjoint(mv, a, c, lmax)
                gram_a = ops.fused_cheb_recurrence(mv, back, c, lmax,
                                                   use_pallas=use_pallas)
                a_new = soft_threshold(a + gamma * (phi_y - gram_a), thresh)
                return a_new, None

            a_star, _ = jax.lax.scan(body, jnp.zeros_like(phi_y), None,
                                     length=n_iters)
            y_star = cheb.cheb_apply_adjoint(mv, a_star, c, lmax)
            return LassoResult(coeffs=_pout(a_star), signal=_pout(y_star),
                               objective=jnp.nan, n_iters=n_iters,
                               fused=True)

        def matvec_runner(fn, signals, consts=()):
            padded = tuple(_pad1(s) for s in signals)
            outs = fn(mv, *padded, *consts)
            return jax.tree.map(_pout, outs)

        return ExecutionPlan(op=op, backend=backend_name, apply=apply,
                             apply_adjoint=apply_adjoint,
                             apply_gram=apply_gram,
                             solve_lasso_fn=solve_lasso,
                             matvec_runner=matvec_runner, info=info)

    rules = (make_rules(mesh) if axis == "graph"
             else ShardingRules(mapping={"vertex": axis}, mesh=mesh))
    mat_specs = (rules.spec("vertex"),) * len(mats)

    def _sig_spec(ndim: int) -> P:
        return rules.spec(*([None] * (ndim - 1)), "vertex")

    def apply(f: Array) -> Array:
        def run(*args):
            mv = _mk_mv(tuple(a[0] for a in args[:len(mats)]), S)
            xl, c = args[len(mats):]
            out = ops.fused_cheb_recurrence(mv, ops.pad_trailing(xl, dl),
                                            c, lmax, use_pallas=use_pallas)
            return out[..., :nl]

        c2 = jnp.atleast_2d(jnp.asarray(coeffs, f.dtype))
        out = _sharded(run, mesh, mat_specs + (_sig_spec(f.ndim), P()),
                       _sig_spec(f.ndim + 1))(*mats, _pin(f), c2)
        return _pout(out)

    def apply_adjoint(a: Array) -> Array:
        def run(*args):
            mv = _mk_mv(tuple(x[0] for x in args[:len(mats)]), S)
            al, c = args[len(mats):]
            out = cheb.cheb_apply_adjoint(mv, ops.pad_trailing(al, dl),
                                          c, lmax)
            return out[..., :nl]

        c = jnp.asarray(coeffs, a.dtype)
        out = _sharded(run, mesh, mat_specs + (_sig_spec(a.ndim), P()),
                       _sig_spec(a.ndim - 1))(*mats, _pin(a), c)
        return _pout(out)

    def apply_gram(f: Array) -> Array:
        def run(*args):
            mv = _mk_mv(tuple(x[0] for x in args[:len(mats)]), S)
            xl, d = args[len(mats):]
            out = ops.fused_cheb_recurrence(mv, ops.pad_trailing(xl, dl),
                                            d, lmax, use_pallas=use_pallas)
            return out[..., 0, :nl]

        d = jnp.asarray(cheb.gram_coeffs(coeffs), f.dtype)[None]
        out = _sharded(run, mesh, mat_specs + (_sig_spec(f.ndim), P()),
                       _sig_spec(f.ndim))(*mats, _pin(f), d)
        return _pout(out)

    def solve_lasso(y, mu, gamma, n_iters):
        def run(*args):
            mv = _mk_mv(tuple(x[0] for x in args[:len(mats)]), S)
            yl, c, thresh = args[len(mats):]
            phi_y = ops.fused_cheb_recurrence(mv, ops.pad_trailing(yl, dl),
                                              c, lmax,
                                              use_pallas=use_pallas)

            def body(a, _):
                back = cheb.cheb_apply_adjoint(mv, a, c, lmax)
                gram_a = ops.fused_cheb_recurrence(mv, back, c, lmax,
                                                   use_pallas=use_pallas)
                a_new = soft_threshold(a + gamma * (phi_y - gram_a), thresh)
                return a_new, None

            a0 = jnp.zeros_like(phi_y)
            a_star, _ = jax.lax.scan(body, a0, None, length=n_iters)
            y_star = cheb.cheb_apply_adjoint(mv, a_star, c, lmax)
            return a_star[..., :nl], y_star[..., :nl]

        c = jnp.asarray(coeffs, y.dtype)
        thresh = _mu_threshold(mu, op.eta, y.dtype, gamma)
        a_star, y_star = _sharded(
            run, mesh, mat_specs + (_sig_spec(y.ndim), P(), P()),
            (_sig_spec(y.ndim + 1), _sig_spec(y.ndim)),
        )(*mats, _pin(y), c, thresh)
        return LassoResult(coeffs=_pout(a_star), signal=_pout(y_star),
                           objective=jnp.nan, n_iters=n_iters, fused=True)

    def matvec_runner(fn, signals, consts=()):
        # Section-V solver substrate under the general partition: signals
        # (incl. vertex-indexed solver state such as Jacobi's 1/diag) are
        # permuted into partition order, padded, sharded; outputs crop and
        # permute back — so solver bodies are partition-agnostic.
        pinned = tuple(_pin(s) for s in signals)
        local = tuple(
            jax.ShapeDtypeStruct(s.shape[:-1] + (dl,), s.dtype)
            for s in pinned)
        out_sds = jax.eval_shape(
            lambda *a: jax.tree.map(
                lambda o: o[..., :nl], fn(lambda v: v, *a)),
            *local, *consts)
        in_specs = (mat_specs
                    + tuple(_sig_spec(s.ndim) for s in pinned)
                    + tuple(P() for _ in consts))
        out_specs = jax.tree.map(lambda sd: _sig_spec(len(sd.shape)),
                                 out_sds)

        def run(*args):
            mv = _mk_mv(tuple(x[0] for x in args[:len(mats)]), S)
            rest = args[len(mats):]
            sigs = tuple(ops.pad_trailing(s, dl)
                         for s in rest[:len(pinned)])
            outs = fn(mv, *sigs, *rest[len(pinned):])
            return jax.tree.map(lambda o: o[..., :nl], outs)

        outs = _sharded(run, mesh, in_specs, out_specs)(
            *mats, *pinned, *consts)
        return jax.tree.map(_pout, outs)

    return ExecutionPlan(op=op, backend=backend_name, apply=apply,
                         apply_adjoint=apply_adjoint, apply_gram=apply_gram,
                         solve_lasso_fn=solve_lasso,
                         matvec_runner=matvec_runner, info=info)
