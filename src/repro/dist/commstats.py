"""Communication accounting for execution plans (Section IV-B/C made
measurable).

The paper's headline systems claim is that one distributed application of a
union of M graph multipliers of order K costs ``2K|E|`` messages — per
Chebyshev order, every vertex sends one scalar to every neighbour, and the
count scales with the edge set only (Section IV-B; 4K|E| for the Gram
operator, length-eta messages for the adjoint).  This module *measures*
what a compiled plan actually does instead of trusting the closed form:

  * :func:`measure` traces a plan method to its jaxpr and tallies every
    collective primitive it will execute — ``ppermute``, ``all_gather``,
    ``psum``, ... — walking nested jaxprs (scan bodies are multiplied by
    their trip count, so a K-order recurrence reports K matvec exchanges,
    not one).
  * :class:`CommStats` converts the tally into the two accountings used
    throughout the repo:
      - **device level** — collectives / bytes actually crossing the mesh
        per application (what `plan.info`'s ``*_bytes_per_apply`` models;
        both halo backends ship only the h-row boundary tile per direction
        per order — :attr:`CommStats.bytes_per_round` exposes it);
      - **paper level** — :meth:`CommStats.paper_messages`, the sensor-
        network message count ``rounds x 2|E|`` where `rounds` is the
        measured number of neighbour-exchange rounds.  For a faithful
        Algorithm 1 implementation ``rounds == K`` and the measured count
        equals the ``2K|E|`` prediction of
        :meth:`repro.core.multiplier.UnionMultiplier.message_counts`.
  * :func:`plan_comm_stats` runs the measurement over a plan's
    apply / apply_adjoint / apply_gram in one call; ``batch=B`` traces the
    batched (B, N) signatures of the (..., N) contract, and
    :meth:`CommStats.paper_messages_per_signal` reports the amortized
    2K|E|/B count (total rounds are batch-invariant —
    :func:`verify_message_scaling` asserts it).

``benchmarks/bench_scaling.py`` sweeps this over growing sensor graphs to
emit the communication-vs-network-size curve, and
``tests/test_commstats.py`` pins the closed form on known graphs.

Caveats: counts are static (trace-time) quantities.  Backends that skip
collectives on a 1-shard mesh (halo / pallas_halo guard ``size > 1``)
measure zero there — measure on >= 2 shards.  A collective under a
`while` body has *no* static count (the trip count is unknown at trace
time), so :func:`measure` refuses to undercount it: it raises by default
(``while_loops="error"``; pass ``"warn"`` to tally one trip loudly
instead).  The jaxpr traversal itself lives in
:mod:`repro.analysis.jaxpr_walk` (extracted from this module's original
private walker), where `repro.analysis.checks` reuses it for the static
invariant checks (`JX-COLLECTIVE-IN-WHILE` is this same rule, CI-gated).
"""
from __future__ import annotations

import dataclasses
import logging
import warnings
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

# The shared jaxpr visitor (Layer-1 substrate of `repro.analysis`); this
# module re-exports COLLECTIVE_PRIMITIVES from it for compatibility.
from ..analysis.jaxpr_walk import (COLLECTIVE_PRIMITIVES, eqn_payload,
                                   walk_jaxpr)

logger = logging.getLogger(__name__)


@dataclasses.dataclass(frozen=True)
class CollectiveCall:
    """One collective site, aggregated over loop trips.

    count: executions per plan application (per shard);
    elems / nbytes: payload per shard per execution;
    perm: for ppermute, the (src, dst) permutation as a tuple of pairs —
    distinct perms are distinct exchange directions (`exchange_rounds`
    groups by it when the plan does not declare its own divisor).
    """

    primitive: str
    count: int
    elems: int
    nbytes: int
    perm: Optional[Tuple] = None


@dataclasses.dataclass(frozen=True)
class CommStats:
    """Measured communication of one traced function (one plan method).

    `batch` is the number of signals the traced call processed at once
    (the leading batch size of the (..., N) contract); exchange *rounds*
    are batch-invariant — the recurrence is linear, so B signals share the
    K rounds — and the per-signal accessors divide the paper-level message
    count by `batch` to expose the amortization (2K|E|/B per signal).
    """

    collectives: Tuple[CollectiveCall, ...]
    n_shards: int
    batch: int = 1
    ppermutes_per_round: Optional[int] = None

    @property
    def n_collectives(self) -> int:
        """Total collective executions per application (per shard)."""
        return sum(c.count for c in self.collectives)

    @property
    def exchange_rounds(self) -> int:
        """Neighbour-exchange rounds == matvec applications of P.

        The banded ring backends issue one ppermute *pair* per matvec
        (halo / pallas_halo), a `GeneralPartition` plan issues one
        ppermute per active ring offset per matvec, and allgather issues
        one all_gather per matvec; everything else (psum, ...) is not a
        recurrence round.  Resolution order: the plan-declared divisor
        (`ppermutes_per_round`, from plan.info's
        ``exchange_collectives_per_round`` — authoritative, since e.g. at
        S=2 the two ring directions share one perm and perm-grouping alone
        would halve the count), then the max per-perm tally (each matvec
        touches every exchange direction once), then the legacy pair
        assumption.
        """
        pp = sum(c.count for c in self.collectives
                 if c.primitive == "ppermute")
        ag = sum(c.count for c in self.collectives
                 if c.primitive in ("all_gather", "pgather"))
        if self.ppermutes_per_round:
            return pp // self.ppermutes_per_round + ag
        if pp:
            by_perm: Dict[Any, int] = {}
            for c in self.collectives:
                if c.primitive == "ppermute":
                    by_perm[c.perm] = by_perm.get(c.perm, 0) + c.count
            if None not in by_perm:
                return max(by_perm.values()) + ag
        return pp // 2 + ag

    @property
    def bytes_per_shard(self) -> int:
        """Payload bytes one shard sends per application."""
        return sum(c.count * c.nbytes for c in self.collectives)

    @property
    def bytes_per_round(self) -> float:
        """Average payload bytes one shard ships per exchange round.

        The device-level view of the interior/boundary split: the halo
        backends should measure ``2 * h * dtype_bytes`` here (both
        directions of one boundary-tile exchange, h = coupling bandwidth)
        regardless of K — the per-order payload is what shrank, the round
        count (the paper-level accounting) is untouched.
        """
        r = self.exchange_rounds
        return self.bytes_per_shard / r if r else 0.0

    @property
    def total_bytes(self) -> int:
        """Payload bytes crossing the mesh per application (all shards)."""
        return self.bytes_per_shard * self.n_shards

    def paper_messages(self, n_edges: int) -> int:
        """Sensor-network message count: measured rounds x 2|E| scalars.

        In the paper's fully distributed model every matvec (= exchange
        round) moves one scalar along each *directed* edge, so a plan that
        really implements Algorithm 1 at order K measures exactly the
        predicted ``2K|E|`` of `op.message_counts(n_edges)`.  This is the
        *total* for the whole batched application; see
        :meth:`paper_messages_per_signal` for the amortized view.
        """
        return self.exchange_rounds * 2 * n_edges

    def paper_messages_per_signal(self, n_edges: int) -> float:
        """Amortized message count per signal: 2K|E| / batch.

        The batch shares the K rounds, so B-batched execution costs each
        signal a 1/B share of the paper's message bound — the quantity
        :func:`verify_message_scaling` asserts against the closed form.
        """
        return self.paper_messages(n_edges) / self.batch

    def summary(self) -> Dict[str, Any]:
        return {
            "n_shards": self.n_shards,
            "batch": self.batch,
            "n_collectives": self.n_collectives,
            "exchange_rounds": self.exchange_rounds,
            "bytes_per_shard": self.bytes_per_shard,
            "total_bytes": self.total_bytes,
            "collectives": [dataclasses.asdict(c) for c in self.collectives],
        }


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------
class UncountableCollectiveError(RuntimeError):
    """A collective sits under a `while_loop`: its execution count is not a
    static (trace-time) quantity, so any tally would be wrong.  Restructure
    the loop as a `scan` (fixed trip count) or measure the bounded inner
    function directly."""


def measure(fn: Callable, *example_args, n_shards: int = 1,
            batch: int = 1, while_loops: str = "error",
            ppermutes_per_round: Optional[int] = None) -> CommStats:
    """Trace `fn` on example arguments and tally its collectives.

    `example_args` may be concrete arrays or `jax.ShapeDtypeStruct`s —
    tracing is abstract, nothing is executed on devices.  `n_shards` scales
    the per-shard byte counts to mesh totals (pass the plan's shard count);
    `batch` records how many signals the traced call carries so the
    per-signal accessors can amortize.

    A collective under a ``while_loop`` executes once per trip of a count
    unknown at trace time — no static tally is correct.
    ``while_loops="error"`` (default) raises
    :class:`UncountableCollectiveError`; ``"warn"`` emits a `UserWarning`
    (+ WARNING log) and counts the site once per enclosing-scan trip, so
    the returned stats are an explicit *lower bound*.

    `ppermutes_per_round` forwards a plan-declared
    ``exchange_collectives_per_round`` to :attr:`CommStats.exchange_rounds`
    (how many ppermutes one neighbour-exchange round comprises: 2 for the
    banded ring, the number of active ring offsets for a
    `GeneralPartition`).
    """
    if while_loops not in ("error", "warn"):
        raise ValueError(
            f"while_loops must be 'error' or 'warn', got {while_loops!r}")
    closed = jax.make_jaxpr(fn)(*example_args)
    tally: Dict[Tuple[str, int, int, Any], int] = {}

    def visit(eqn, ctx):
        name = eqn.primitive.name
        if name not in COLLECTIVE_PRIMITIVES:
            return
        if ctx.in_while:
            msg = (
                f"collective `{name}` under a while_loop (path "
                f"{'/'.join(ctx.path) or '<top>'}): trip count is unknown "
                "at trace time, so no static tally is correct")
            if while_loops == "error":
                raise UncountableCollectiveError(msg)
            warnings.warn(msg + " — counting ONE trip; stats are a lower "
                          "bound", stacklevel=3)
            logger.warning("commstats.measure: %s (counting one trip)", msg)
        elems, nbytes = eqn_payload(eqn)
        perm = eqn.params.get("perm") if name == "ppermute" else None
        if perm is not None:
            perm = tuple(tuple(int(v) for v in p) for p in perm)
        key = (name, elems, nbytes, perm)
        tally[key] = tally.get(key, 0) + ctx.mult

    walk_jaxpr(closed, visit)
    calls = tuple(
        CollectiveCall(primitive=k[0], count=v, elems=k[1], nbytes=k[2],
                       perm=k[3])
        for k, v in sorted(tally.items(),
                           key=lambda kv: (kv[0][:3], repr(kv[0][3]))))
    return CommStats(collectives=calls, n_shards=n_shards, batch=batch,
                     ppermutes_per_round=ppermutes_per_round)


def plan_comm_stats(plan, n: int = None, batch: int = None) -> Dict[str, CommStats]:
    """Measure a plan's apply / apply_adjoint / apply_gram communication.

    `n` (logical signal size) defaults to the operator's dense-P dimension;
    pass it explicitly for closure-P operators.  `batch=None` traces the
    unbatched (N,) signatures; `batch=B` traces (B, N) / (B, eta, N) ones
    (the (..., N) contract) and stamps B on the returned stats so
    `paper_messages_per_signal` reports the 2K|E|/B amortization.  Returns
    ``{"apply": CommStats, "apply_adjoint": ..., "apply_gram": ...}``.
    """
    op = plan.op
    if n is None:
        if callable(op.P):
            raise ValueError("plan_comm_stats needs n= for a closure P")
        n = int(np.asarray(op.P).shape[0])
    shards = int(plan.info.get("n_shards", 1))
    ppr = plan.info.get("exchange_collectives_per_round")
    lead = () if batch is None else (int(batch),)
    b = 1 if batch is None else int(batch)
    f = jax.ShapeDtypeStruct(lead + (n,), np.float32)
    a = jax.ShapeDtypeStruct(lead + (op.eta, n), np.float32)
    return {
        "apply": measure(plan.apply, f, n_shards=shards, batch=b,
                         ppermutes_per_round=ppr),
        "apply_adjoint": measure(plan.apply_adjoint, a, n_shards=shards,
                                 batch=b, ppermutes_per_round=ppr),
        "apply_gram": measure(plan.apply_gram, f, n_shards=shards, batch=b,
                              ppermutes_per_round=ppr),
    }


def solve_comm_stats(plan, method: str = "chebyshev", n: int = None,
                     batch: int = None, **solve_kwargs) -> CommStats:
    """Measure the communication of one `plan.solve(method=...)` call.

    Traces ``plan.solve(y, method, **solve_kwargs).x`` on a (batch, n) (or
    unbatched (n,)) float32 signal and tallies its collectives — the
    Section-V accounting made measurable: a Jacobi round on
    den(P) x = num(P) y costs deg(den) matvec exchanges (Fig. 2(b)'s "2
    matvecs per iteration" shows up as ``exchange_rounds == 2 * n_iters``),
    the ARMA recursion's stacked poles cost ONE exchange of length-K_p
    messages per round, and batched signals leave the round count invariant
    (`SolveResult.info["exchange_rounds"]` is the closed form this should
    land on exactly).  Backends skip collectives on 1-shard meshes —
    measure on >= 2 shards, like :func:`plan_comm_stats`.
    """
    op = plan.op
    if n is None:
        if callable(op.P):
            raise ValueError("solve_comm_stats needs n= for a closure P")
        n = int(np.asarray(op.P).shape[0])
    shards = int(plan.info.get("n_shards", 1))
    ppr = plan.info.get("exchange_collectives_per_round")
    lead = () if batch is None else (int(batch),)
    b = 1 if batch is None else int(batch)
    y = jax.ShapeDtypeStruct(lead + (n,), np.float32)

    def run(sig):
        return plan.solve(sig, method, **solve_kwargs).x

    return measure(run, y, n_shards=shards, batch=b,
                   ppermutes_per_round=ppr)


def verify_message_scaling(plan, n_edges: int, n: int = None,
                           batch: int = None) -> Dict[str, Any]:
    """Measured-vs-predicted message counts for one plan.

    Compares :meth:`CommStats.paper_messages` for each plan method against
    the closed forms of `op.message_counts(n_edges)` (2K|E| apply, 2K|E|
    adjoint, 4K|E| gram).  Returns a dict with measured, predicted and the
    max relative deviation — the quantity `bench_scaling.py` asserts is
    within 10%.

    With `batch=B` the batched signatures are traced as well and the
    exchange-round counts are *asserted* batch-invariant (the tentpole
    claim: B signals share the K rounds, so per-signal messages are
    2K|E|/B).  The result then carries ``batch``, ``measured_batched``
    (total rounds at B — must equal the unbatched totals) and
    ``per_signal_messages`` (the amortized counts).
    """
    stats = plan_comm_stats(plan, n=n)
    predicted = plan.op.message_counts(n_edges)
    pred = {
        "apply": predicted["apply_messages"],
        "apply_adjoint": predicted["adjoint_messages"],
        "apply_gram": predicted["gram_messages"],
    }
    meas = {k: s.paper_messages(n_edges) for k, s in stats.items()}
    rel = {
        k: (abs(meas[k] - pred[k]) / pred[k]) if pred[k] else 0.0
        for k in pred
    }
    out = {
        "measured": meas,
        "predicted": pred,
        "rel_dev": rel,
        "max_rel_dev": max(rel.values()),
        "stats": {k: s.summary() for k, s in stats.items()},
    }
    if batch is not None:
        bstats = plan_comm_stats(plan, n=n, batch=batch)
        for k in stats:
            r1, rb = stats[k].exchange_rounds, bstats[k].exchange_rounds
            if r1 != rb:
                raise AssertionError(
                    f"{plan.backend}.{k}: exchange rounds are not batch-"
                    f"invariant ({r1} at B=1 vs {rb} at B={batch}) — the "
                    "batched path is re-running the recurrence per signal")
        out["batch"] = int(batch)
        out["measured_batched"] = {
            k: s.paper_messages(n_edges) for k, s in bstats.items()}
        out["per_signal_messages"] = {
            k: s.paper_messages_per_signal(n_edges)
            for k, s in bstats.items()}
        out["stats_batched"] = {k: s.summary() for k, s in bstats.items()}
    return out
