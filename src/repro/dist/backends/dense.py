"""'dense' execution backend: Algorithm 1/2 against P as given.

P may be a dense matrix or a matvec closure (applying P along the *last*
axis, broadcasting over leading batch dims); this is the single-device
reference path (what `UnionMultiplier.apply` always did) wrapped in the
uniform ExecutionPlan signature, including the batched (..., N) contract.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core import chebyshev as cheb
from . import register_backend

Array = jax.Array


@register_backend("dense")
def build(op, *, mesh=None, partition=None, **options):
    from ..operator import ExecutionPlan

    del mesh, partition  # single-device backend
    mv = op.matvec
    coeffs = op.coeffs
    lmax = op.lmax

    def apply(f: Array) -> Array:
        c2 = jnp.atleast_2d(jnp.asarray(coeffs, f.dtype))
        return cheb.cheb_apply(mv, f, c2, lmax)

    def apply_adjoint(a: Array) -> Array:
        return cheb.cheb_apply_adjoint(mv, a, jnp.asarray(coeffs, a.dtype),
                                       lmax)

    def apply_gram(f: Array) -> Array:
        return cheb.cheb_apply_gram(mv, f, coeffs, lmax)

    def matvec_runner(fn, signals, consts=()):
        # single-device reference: the logical N is the execution domain,
        # so no padding/cropping and `mv` is P as given
        return fn(mv, *signals, *consts)

    return ExecutionPlan(
        op=op, backend="dense",
        apply=apply, apply_adjoint=apply_adjoint, apply_gram=apply_gram,
        matvec_runner=matvec_runner,
        info={"matvecs_per_apply": op.K},
    )
