"""'pallas_halo' execution backend: sharded Block-ELL + fused Pallas kernels
with boundary-row ("halo") exchange.

This backend unites the two fastest paths in the registry:

* the `pallas` backend's hot loop — Block-ELL SpMV + the fused Chebyshev
  step kernel (`kernels.ops.fused_cheb_recurrence`), one HBM round-trip per
  order — but run *per shard* inside a shard_map;
* the `halo` backend's distribution strategy — a block-tridiagonal partition
  of a banded (spatially sorted) P over a 1-D device mesh with ring
  neighbour exchange per Chebyshev order.

Where `halo` ships each shard's **entire** block (nl values) to both
neighbours per order, this backend ships only the **boundary rows** that the
neighbour actually reads: the halo width `h` is the bandwidth of the
off-diagonal coupling blocks, so per order each shard sends 2·h values
instead of 2·nl.  That is the TPU analog of the paper's accounting — one
scalar per directed edge per order, 2K|E| messages per application
(Section IV-B) — with the intra-shard edges folded into the local Block-ELL
SpMV and only the cut edges crossing the network.

Per-shard structure (shard s owns rows [s·nl, (s+1)·nl)):

    y_s = D_s x_s  +  L_s x_{s-1}[-h:]  +  R_s x_{s+1}[:h]

`D_s` is the shard's diagonal block in Block-ELL form driven through the
Pallas SpMV kernel; `L_s`/`R_s` are the (nl, h) boundary couplings applied
as small dense matmuls to the halo rows received from the ring neighbours.

Communication per application: K orders x 2 ppermutes of an (h,)-block
(forward/gram; (eta, h) for the adjoint; (..., h) tiles for batched
signals — the round count is batch-invariant, only the tile grows) —
measurable with :mod:`repro.dist.commstats` and compared against the
paper's closed form in ``benchmarks/bench_scaling.py``.

Latency structure (docs/ARCHITECTURE.md "Perf accounting"): the per-order
matvec is an explicit **interior/boundary split** — the boundary-tile
ppermutes are issued first, the interior Block-ELL SpMV (no remote data)
runs while they are in flight, and the received halo rows are applied on
arrival, so the exchange hides behind interior compute instead of
serializing in front of it.  The whole per-shard recurrence runs on the
shard's Block-ELL padded domain (padded once on entry, cropped once on
exit — no per-order pad/crop traffic), and on a 1-shard mesh, where the
exchange is a no-op, the matvec is tagged for the single-launch
`cheb_sweep` kernel so the entire K-order loop collapses into one
`pallas_call`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ... import _compat  # noqa: F401  (jax.shard_map / axis_size on old jax)
from ...core import chebyshev as cheb
from ...core import graph as graphmod
from ...core.lasso import soft_threshold
from ...kernels import ops
from .. import faults, quantize
from ..sharding import ShardingRules, make_rules
from . import register_backend
from .halo import (BandedPartition, _coupling_bandwidth, _sharded,
                   pad_signal, partition_banded)

Array = jax.Array


# ---------------------------------------------------------------------------
# Sharded Block-ELL partition
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShardedBlockELL:
    """Per-shard Block-ELL diagonal blocks + dense boundary couplings.

    blocks:  (S, nrb, slots, br, bc) per-shard Block-ELL values of D_s
    indices: (S, nrb, slots) int32 column-block index per slot
    mask:    (S, nrb, slots) bool slot validity
    left:    (S, nl, h) coupling of shard s's rows to the *last* h columns
             of shard s-1 (zero for s = 0)
    right:   (S, nl, h) coupling of shard s's rows to the *first* h columns
             of shard s+1 (zero for s = S-1)
    n:       logical (unpadded) global size; S * nl >= n
    n_local: rows per shard (nl)
    halo:    boundary bandwidth h (rows exchanged per direction per order)
    """

    blocks: Array
    indices: Array
    mask: Array
    left: Array
    right: Array
    n: int
    n_local: int
    halo: int

    @property
    def n_shards(self) -> int:
        return self.blocks.shape[0]

    @property
    def n_padded(self) -> int:
        """Global padded signal size consumed by the plan (S * nl);
        `halo.pad_signal` reads this, so the partition is passed to it
        directly."""
        return self.n_shards * self.n_local

    @property
    def nnz_blocks(self) -> int:
        return int(np.asarray(self.mask).sum())


def partition_block_ell(
    P_dense: np.ndarray,
    n_shards: int,
    block: Tuple[int, int] = (8, 128),
    max_slots: Optional[int] = None,
) -> Tuple[ShardedBlockELL, float]:
    """Split P into per-shard Block-ELL diagonals + boundary couplings.

    Returns (partition, leak); `leak` is the Frobenius norm of entries
    outside the block-tridiagonal band (see `halo.partition_banded` — must
    be ~0 for exactness, use `graph.spatial_sort` first).  ``max_slots``
    bounds the uniform slot count and *raises*
    `repro.dist.partition.OverfullSlotsError` when a row block needs more —
    never truncates (dropped blocks would be silently wrong matvecs).
    """
    banded, leak = partition_banded(np.asarray(P_dense), n_shards)
    diag = np.asarray(banded.diag)
    left = np.asarray(banded.left)
    right = np.asarray(banded.right)
    nl = banded.n_local
    h = _coupling_bandwidth(left, right)

    cells = [graphmod.to_block_ell(diag[s], block) for s in range(n_shards)]
    slots = max(c.blocks.shape[1] for c in cells)
    if max_slots is not None and slots > max_slots:
        from ..partition import OverfullSlotsError

        raise OverfullSlotsError(
            f"a row block couples {slots} column blocks but the uniform "
            f"slot budget is {max_slots} — refusing to truncate (silently "
            "dropped blocks = silently wrong matvecs); raise max_slots or "
            "shrink the column block")
    blocks, indices, mask = [], [], []
    for c in cells:
        pad = slots - c.blocks.shape[1]
        blocks.append(np.pad(np.asarray(c.blocks),
                             ((0, 0), (0, pad), (0, 0), (0, 0))))
        indices.append(np.pad(np.asarray(c.indices), ((0, 0), (0, pad))))
        mask.append(np.pad(np.asarray(c.mask), ((0, 0), (0, pad))))
    return (
        ShardedBlockELL(
            blocks=jnp.asarray(np.stack(blocks)),
            indices=jnp.asarray(np.stack(indices)),
            mask=jnp.asarray(np.stack(mask)),
            left=jnp.asarray(left[:, :, nl - h:]),
            right=jnp.asarray(right[:, :, :h]),
            n=banded.n,
            n_local=nl,
            halo=h,
        ),
        leak,
    )


# ---------------------------------------------------------------------------
# Per-shard matvec (runs inside shard_map)
# ---------------------------------------------------------------------------
def _halo_row_matvec(local_A: graphmod.BlockELL, left: Array, right: Array,
                     nl: int, h: int, axis: str, use_pallas,
                     vmem_budget=None, n_shards=None,
                     exchange_dtype: str = "f32",
                     error_feedback: bool = True,
                     sweep_dtype: Optional[str] = None,
                     fault_spec=None, degradation: str = "zero_fill"):
    """Interior/boundary-split matvec along the last axis of x.

    x: (..., pnl) local block on the shard's **Block-ELL padded domain**
    (pnl = local_A.padded_n; callers pad once per application, not per
    order — rows past nl are zero and stay zero).  left/right are the
    boundary couplings row-padded to (pnl, h).  Per call:

    1. **boundary tiles encoded and on the wire first** — each shard's
       first/last h *logical* entries are compressed to `exchange_dtype`
       (`repro.dist.quantize`: identity for f32, truncating cast for
       bf16, per-tile-scale int8 with the scale bitcast-packed into the
       same wire buffer) and ppermute to the ring neighbours (the only
       inter-shard traffic — a (..., h) tile, so B batched signals ship
       (B, h) per direction in the same exchange round);
    2. **interior compute while the exchange is in flight** — the Pallas
       Block-ELL SpMV over the shard's diagonal block reads no remote
       data, so it overlaps the collective (batched tile path: one
       structure sweep for the whole batch);
    3. **decode + boundary coupling on arrival** — the received tiles
       widen back to the compute dtype, then two small (pnl, h) dense
       products.

    Under ``exchange_dtype="int8"`` with ``error_feedback=True`` on a
    real multi-shard axis, the closure follows the dual-signature
    stateful protocol (see `halo._halo_matvec`): ``mv(x)`` stays
    stateless (plain quantize), ``mv(x, state) -> (y, state)`` threads
    the per-tile quantization residuals across orders, and
    ``mv.init_state(x)`` builds the zero residuals.

    The ring wraps; the first/last shard's out-of-range contribution is
    killed by the zero left/right coupling blocks.  On a 1-shard mesh the
    exchange is a no-op and the returned closure is tagged with
    ``mv.block_ell`` so `ops.fused_cheb_recurrence` / the Section-V
    solvers collapse the whole iteration into a single-launch sweep
    kernel (the couplings are identically zero there); ``mv.sweep_dtype``
    forwards the mixed-precision scratch mode to those sweep kernels.
    """
    size = n_shards if n_shards is not None else jax.lax.axis_size(axis)
    dt = quantize.validate_exchange_dtype(exchange_dtype)
    inj = faults.make_injector(fault_spec, degradation, axis, size > 1)
    use_ef = dt == "int8" and error_feedback and size > 1

    def _run(x, state):
        head = x[..., :h]
        tail = x[..., nl - h:nl]
        if inj is not None:
            k, carried, ef_state = state
        else:
            ef_state = state
        if size > 1:
            if ef_state is None:
                wire_tail = quantize.encode(tail, dt)
                wire_head = quantize.encode(head, dt)
                new_ef = None
            else:
                r_tail, r_head = ef_state
                wire_tail, r_tail = quantize.ef_encode(tail, r_tail, dt)
                wire_head, r_head = quantize.ef_encode(head, r_head, dt)
                new_ef = (r_tail, r_head)
            # (1) boundary-row exchange: shard s receives s-1's tail (read
            # by `left`) and s+1's head (read by `right`); one ppermute
            # per direction keeps measured rounds at the paper's 2K|E|
            from_left = jax.lax.ppermute(
                wire_tail, axis,
                perm=[(i, (i + 1) % size) for i in range(size)])
            from_right = jax.lax.ppermute(
                wire_head, axis,
                perm=[(i, (i - 1) % size) for i in range(size)])
            # (2) interior Block-ELL SpMV — overlaps the exchange
            y = ops.spmv(local_A, x, use_pallas=use_pallas)
            # (3) decode + boundary couplings on arrival; injected faults
            # perturb only what the receiver consumes — the wire traffic
            # above is already committed
            if inj is not None:
                from_left = inj.wire(from_left, k, 0, dt)
                from_right = inj.wire(from_right, k, 1, dt)
            from_left = quantize.decode(from_left, dt, x.dtype)
            from_right = quantize.decode(from_right, dt, x.dtype)
            if inj is not None:
                c_l, c_r = carried
                from_left, c_l = inj.recv(from_left, c_l, k, 0)
                from_right, c_r = inj.recv(from_right, c_r, k, 1)
                new_state = (k + 1, (c_l, c_r), new_ef)
            else:
                new_state = new_ef
        else:
            from_left, from_right = tail, head
            new_state = state
            y = ops.spmv(local_A, x, use_pallas=use_pallas)
        y = y + jnp.einsum("ij,...j->...i", left, from_left)
        y = y + jnp.einsum("ij,...j->...i", right, from_right)
        return y, new_state

    def mv(x, state=None):
        if state is None:
            if inj is not None:
                return _run(x, mv.init_state(x))[0]
            return _run(x, None)[0]
        return _run(x, state)

    if inj is not None:
        def init_state(x):
            tail = x[..., nl - h:nl]
            head = x[..., :h]
            ef0 = ((quantize.ef_init(tail), quantize.ef_init(head))
                   if use_ef else None)
            return (inj.init_round(), inj.init_carried((tail, head)), ef0)

        mv.init_state = init_state
    elif use_ef:
        def init_state(x):
            return (quantize.ef_init(x[..., nl - h:nl]),
                    quantize.ef_init(x[..., :h]))

        mv.init_state = init_state
    if size == 1:
        mv.block_ell = local_A
        mv.vmem_budget = vmem_budget
        mv.sweep_dtype = sweep_dtype
    return mv


def pallas_halo_bytes_per_apply(parts: ShardedBlockELL, K: int, eta: int = 1,
                                dtype_bytes: int = 4,
                                exchange_dtype: Optional[str] = None) -> int:
    """Collective-traffic model for one application: per order each shard
    sends its h boundary rows left+right; K orders, S shards.  Since the
    interior/boundary split, `halo.halo_bytes_per_apply` follows the same
    boundary-tile formula (it used to ship the full nl block); this one
    reads the width off a `ShardedBlockELL`, that one off a
    `BandedPartition`.  With `exchange_dtype` given, the per-row wire
    width comes from `quantize.tile_wire_bytes` (4h / 2h / h + 4 bytes
    for f32 / bf16 / int8+packed-scale) instead of ``h * dtype_bytes``."""
    if exchange_dtype is not None:
        row = quantize.tile_wire_bytes(parts.halo, exchange_dtype)
    else:
        row = parts.halo * dtype_bytes
    return 2 * K * parts.n_shards * eta * row


# ---------------------------------------------------------------------------
# Plan builder
# ---------------------------------------------------------------------------
@register_backend("pallas_halo")
def build(op, *, mesh=None, partition=None, axis: Optional[str] = None,
          allow_leak: bool = False, block: Tuple[int, int] = (8, 128),
          use_pallas: Optional[bool] = None,
          vmem_budget: Optional[int] = None,
          exchange_dtype: str = "f32", error_feedback: bool = True,
          sweep_dtype: Optional[str] = None,
          partition_method: str = "bfs",
          fault_spec=None, degradation: str = "zero_fill", **options):
    """Build an ExecutionPlan running the fused Pallas Chebyshev recurrence
    per shard with boundary-row halo exchange.

    Requires a dense, banded P (spatially sorted sensor graph) or a
    precomputed `partition=` (a `ShardedBlockELL`, or a `halo.
    BandedPartition` which is converted).  `partition="general"` (or a
    `repro.dist.partition.GeneralPartition`) switches to the edge-cut
    exchange plan for arbitrary sparse graphs — `partition_method`
    ("bfs" | "spectral") picks the partitioner when the string form is
    used.  Without `mesh=`, a 1-D "graph" mesh over every visible
    device is built.  `use_pallas` follows the
    `kernels.ops` dispatch policy (None: native on TPU, jnp oracle on CPU);
    `vmem_budget` overrides the single-launch sweep kernel's VMEM guard
    (`ops.DEFAULT_SWEEP_VMEM_BUDGET`) on 1-shard meshes, where the whole
    per-shard recurrence collapses into one `cheb_sweep` launch.

    ``exchange_dtype`` ("f32" | "bf16" | "int8") sets the wire precision
    of the boundary tiles and ``error_feedback`` (int8 only) threads the
    quantization residual across orders — see `repro.dist.quantize`.
    ``sweep_dtype`` (None/"f32" or "bf16") selects the mixed-precision
    scratch mode of the single-launch sweep kernels; the plan's
    ``sweep_vmem_bytes`` guard value is recomputed from the actual
    scratch dtype, so bf16 roughly doubles the admissible tile.
    """
    from ..operator import ExecutionPlan

    from ..partition import build_general_plan, resolve_partition_arg

    quantize.validate_exchange_dtype(exchange_dtype)
    faults.validate_degradation(degradation)
    fault_spec = faults.resolve_fault_spec(fault_spec)
    if mesh is None:
        mesh = jax.make_mesh((len(jax.devices()),), ("graph",))
    axis = axis or mesh.axis_names[0]
    n_shards = int(mesh.shape[axis])
    general = resolve_partition_arg(op, partition, n_shards, block=block,
                                    method=partition_method)
    if general is not None:
        return build_general_plan(op, general, mesh, axis,
                                  interior="block_ell",
                                  use_pallas=use_pallas,
                                  vmem_budget=vmem_budget,
                                  sweep_dtype=sweep_dtype,
                                  exchange_dtype=exchange_dtype,
                                  error_feedback=error_feedback,
                                  fault_spec=fault_spec,
                                  degradation=degradation,
                                  backend_name="pallas_halo")
    if isinstance(partition, str):
        partition = None
    leak = 0.0
    if partition is None:
        if callable(op.P):
            raise ValueError("pallas_halo backend needs a dense P or "
                             "partition=")
        partition, leak = partition_block_ell(np.asarray(op.P), n_shards,
                                              block)
        if leak > 1e-10 and not allow_leak:
            raise ValueError(
                f"P is not block-tridiagonal under {n_shards} shards "
                f"(leak={leak:.3e}); spatial_sort the graph first, pass "
                "allow_leak=True, or use backend='allgather'")
    elif isinstance(partition, BandedPartition):
        repacked, leak = partition_block_ell(
            np.asarray(_banded_to_dense(partition)), partition.n_shards,
            block)
        partition = repacked
    parts = partition
    if parts.n_shards != n_shards:
        raise ValueError(f"partition has {parts.n_shards} shards but mesh "
                         f"axis {axis!r} has {n_shards}")
    n, nl, h = parts.n, parts.n_local, parts.halo
    # the shard's Block-ELL padded domain: the whole recurrence runs here,
    # padded once on entry and cropped once on exit (no per-order pads)
    pnl = parts.blocks.shape[1] * parts.blocks.shape[3]
    left_p = ops.pad_trailing(parts.left.swapaxes(-1, -2),
                              pnl).swapaxes(-1, -2)
    right_p = ops.pad_trailing(parts.right.swapaxes(-1, -2),
                               pnl).swapaxes(-1, -2)
    coeffs = op.coeffs
    lmax = op.lmax

    def _mk_mv(blocks, indices, mask, left, right):
        local_A = graphmod.BlockELL(blocks=blocks[0], indices=indices[0],
                                    mask=mask[0], n=nl)
        return _halo_row_matvec(local_A, left[0], right[0], nl, h, axis,
                                use_pallas, vmem_budget, n_shards,
                                exchange_dtype, error_feedback, sweep_dtype,
                                fault_spec, degradation)

    info = {
        "mesh_axis": axis,
        "n_shards": n_shards,
        "n_local": nl,
        "n_local_padded": pnl,
        "halo_width": h,
        "partition": "banded",
        "partition_leak": leak,
        # one exchange round = the left+right ppermute pair (commstats
        # divides the measured ppermute tally by this)
        "exchange_collectives_per_round": 2,
        "block": block,
        "nnz_blocks": parts.nnz_blocks,
        "exchange_dtype": exchange_dtype,
        "error_feedback": bool(error_feedback),
        "fault_spec": faults.spec_info(fault_spec),
        "degradation": degradation,
        "fault_key": faults.fault_key(fault_spec, degradation),
        "sweep_dtype": sweep_dtype or "f32",
        "sweep_vmem_bytes": ops.cheb_sweep_vmem_bytes(
            graphmod.BlockELL(blocks=parts.blocks[0],
                              indices=parts.indices[0],
                              mask=parts.mask[0], n=nl),
            pnl, op.eta, op.K, scratch_dtype=sweep_dtype),
        "halo_bytes_per_apply": pallas_halo_bytes_per_apply(
            parts, op.K, 1, exchange_dtype=exchange_dtype),
        "halo_bytes_per_adjoint": pallas_halo_bytes_per_apply(
            parts, op.K, op.eta, exchange_dtype=exchange_dtype),
    }

    if n_shards == 1:
        # A 1-shard mesh needs no collectives and no shard_map: build the
        # plan directly on the (concrete) local Block-ELL — the matvec's
        # `block_ell` tag holds plan-time constants, so the single-launch
        # sweep dispatch (and its eager-dense CPU oracle) engages exactly
        # as in the `pallas` backend, minus the shard_map trace overhead.
        return _build_single_shard(op, parts, pnl, left_p, right_p,
                                   use_pallas, vmem_budget, info,
                                   sweep_dtype)

    # PartitionSpecs through the logical-axis rules: every per-shard tensor
    # is sharded on its leading "vertex"-block dimension.  The shared _BASE
    # vocabulary maps "vertex" to the conventional "graph" mesh axis; a
    # mesh with a differently-named axis gets a local override.  Signals
    # carry leading batch dims ((..., N) contract), so their specs are
    # built per input rank: batch/eta axes replicate, vertex axis shards.
    rules = (make_rules(mesh) if axis == "graph"
             else ShardingRules(mapping={"vertex": axis}, mesh=mesh))
    vspec = rules.spec("vertex")
    mats = (parts.blocks, parts.indices, parts.mask, left_p, right_p)
    mat_specs = (vspec,) * 5

    def _sig_spec(ndim: int) -> P:
        return rules.spec(*([None] * (ndim - 1)), "vertex")

    def apply(f: Array) -> Array:
        def run(blocks, indices, mask, left, right, xl, c):
            mv = _mk_mv(blocks, indices, mask, left, right)
            out = ops.fused_cheb_recurrence(mv, ops.pad_trailing(xl, pnl),
                                            c, lmax, use_pallas=use_pallas)
            return out[..., :nl]

        c2 = jnp.atleast_2d(jnp.asarray(coeffs, f.dtype))
        out = _sharded(run, mesh, mat_specs + (_sig_spec(f.ndim), P()),
                       _sig_spec(f.ndim + 1))(*mats,
                                              pad_signal(f, parts),
                                              c2)
        return out[..., :n]

    def apply_adjoint(a: Array) -> Array:
        def run(blocks, indices, mask, left, right, al, c):
            mv = _mk_mv(blocks, indices, mask, left, right)
            out = cheb.cheb_apply_adjoint(mv, ops.pad_trailing(al, pnl),
                                          c, lmax)
            return out[..., :nl]

        c = jnp.asarray(coeffs, a.dtype)
        return _sharded(run, mesh, mat_specs + (_sig_spec(a.ndim), P()),
                        _sig_spec(a.ndim - 1))(*mats, pad_signal(a, parts),
                                               c)[..., :n]

    def apply_gram(f: Array) -> Array:
        def run(blocks, indices, mask, left, right, xl, d):
            mv = _mk_mv(blocks, indices, mask, left, right)
            out = ops.fused_cheb_recurrence(mv, ops.pad_trailing(xl, pnl),
                                            d, lmax, use_pallas=use_pallas)
            return out[..., 0, :nl]

        d = jnp.asarray(cheb.gram_coeffs(coeffs), f.dtype)[None]
        return _sharded(run, mesh, mat_specs + (_sig_spec(f.ndim), P()),
                        _sig_spec(f.ndim))(*mats, pad_signal(f, parts),
                                           d)[..., :n]

    def solve_lasso(y, mu, gamma, n_iters):
        from ...core.lasso import LassoResult, _mu_threshold

        def run(blocks, indices, mask, left, right, yl, c, thresh):
            mv = _mk_mv(blocks, indices, mask, left, right)
            # the whole ISTA loop runs on the padded Block-ELL domain;
            # padded rows stay identically zero (zero signal, zero blocks,
            # zero couplings), cropped once on the way out
            phi_y = ops.fused_cheb_recurrence(mv, ops.pad_trailing(yl, pnl),
                                              c, lmax, use_pallas=use_pallas)

            def body(a, _):
                back = cheb.cheb_apply_adjoint(mv, a, c, lmax)
                gram_a = ops.fused_cheb_recurrence(mv, back, c, lmax,
                                                   use_pallas=use_pallas)
                a_new = soft_threshold(a + gamma * (phi_y - gram_a), thresh)
                return a_new, None

            a0 = jnp.zeros_like(phi_y)
            a_star, _ = jax.lax.scan(body, a0, None, length=n_iters)
            y_star = cheb.cheb_apply_adjoint(mv, a_star, c, lmax)
            return a_star[..., :nl], y_star[..., :nl]

        c = jnp.asarray(coeffs, y.dtype)
        thresh = _mu_threshold(mu, op.eta, y.dtype, gamma)
        a_star, y_star = _sharded(
            run, mesh, mat_specs + (_sig_spec(y.ndim), P(), P()),
            (_sig_spec(y.ndim + 1), _sig_spec(y.ndim)),
        )(*mats, pad_signal(y, parts), c, thresh)
        return LassoResult(coeffs=a_star[..., :n], signal=y_star[..., :n],
                           objective=jnp.nan, n_iters=n_iters, fused=True)

    def matvec_runner(fn, signals, consts=()):
        # Section-V solver substrate: one shard_map running `fn` against
        # the per-shard Block-ELL matvec with boundary-rows-only halo
        # exchange — a solver round costs the same 2·h-row traffic as one
        # Chebyshev order.  Vertex-last signals shard (zero-padded tails
        # stay zero under the solvers' reciprocal-diagonal updates) and are
        # lifted to the shard's Block-ELL padded domain once per call, so
        # the iteration bodies run pad-free; every output's vertex axis is
        # cropped per shard, then to the logical n.  On a 1-shard mesh the
        # matvec carries its `block_ell` tag, so eligible solver bodies
        # collapse into the single-launch sweep kernels.
        padded = tuple(pad_signal(jnp.asarray(s), parts) for s in signals)
        local = tuple(
            jax.ShapeDtypeStruct(s.shape[:-1] + (pnl,), s.dtype)
            for s in padded)
        out_sds = jax.eval_shape(
            lambda *a: jax.tree.map(
                lambda o: o[..., :nl], fn(lambda v: v, *a)),
            *local, *consts)
        in_specs = (mat_specs
                    + tuple(_sig_spec(s.ndim) for s in padded)
                    + tuple(P() for _ in consts))
        out_specs = jax.tree.map(lambda sd: _sig_spec(len(sd.shape)),
                                 out_sds)

        def run(blocks, indices, mask, left, right, *rest):
            mv = _mk_mv(blocks, indices, mask, left, right)
            sigs = tuple(ops.pad_trailing(s, pnl) for s in rest[:len(padded)])
            outs = fn(mv, *sigs, *rest[len(padded):])
            return jax.tree.map(lambda o: o[..., :nl], outs)

        outs = _sharded(run, mesh, in_specs, out_specs)(
            *mats, *padded, *consts)
        return jax.tree.map(lambda o: o[..., :n], outs)

    return ExecutionPlan(
        op=op, backend="pallas_halo",
        apply=apply, apply_adjoint=apply_adjoint, apply_gram=apply_gram,
        solve_lasso_fn=solve_lasso,
        matvec_runner=matvec_runner,
        info=info,
    )


def _build_single_shard(op, parts, pnl, left_p, right_p, use_pallas,
                        vmem_budget, info, sweep_dtype=None):
    """The 1-shard degenerate of the pallas_halo plan: same partition, same
    matvec (the zero boundary couplings included, so `plan.info` and the
    byte models stay comparable), but no shard_map and a concrete
    Block-ELL — the single-launch sweep path of `kernels.ops` applies."""
    from ...core.lasso import LassoResult, _mu_threshold
    from ..operator import ExecutionPlan

    n, nl, h = parts.n, parts.n_local, parts.halo
    coeffs = op.coeffs
    lmax = op.lmax
    local_A = graphmod.BlockELL(blocks=parts.blocks[0],
                                indices=parts.indices[0],
                                mask=parts.mask[0], n=nl)
    mv = _halo_row_matvec(local_A, left_p[0], right_p[0], nl, h,
                          info["mesh_axis"], use_pallas, vmem_budget,
                          n_shards=1, sweep_dtype=sweep_dtype)

    def _pad(x):
        return ops.pad_trailing(jnp.asarray(x), pnl)

    def apply(f: Array) -> Array:
        c2 = jnp.atleast_2d(jnp.asarray(coeffs, f.dtype))
        out = ops.fused_cheb_recurrence(mv, _pad(f), c2, lmax,
                                        use_pallas=use_pallas)
        return out[..., :n]

    def apply_adjoint(a: Array) -> Array:
        c = jnp.asarray(coeffs, a.dtype)
        return cheb.cheb_apply_adjoint(mv, _pad(a), c, lmax)[..., :n]

    def apply_gram(f: Array) -> Array:
        d = jnp.asarray(cheb.gram_coeffs(coeffs), f.dtype)[None]
        out = ops.fused_cheb_recurrence(mv, _pad(f), d, lmax,
                                        use_pallas=use_pallas)
        return out[..., 0, :n]

    def solve_lasso(y, mu, gamma, n_iters):
        c = jnp.asarray(coeffs, y.dtype)
        thresh = _mu_threshold(mu, op.eta, y.dtype, gamma)
        phi_y = ops.fused_cheb_recurrence(mv, _pad(y), c, lmax,
                                          use_pallas=use_pallas)

        def body(a, _):
            back = cheb.cheb_apply_adjoint(mv, a, c, lmax)
            gram_a = ops.fused_cheb_recurrence(mv, back, c, lmax,
                                               use_pallas=use_pallas)
            a_new = soft_threshold(a + gamma * (phi_y - gram_a), thresh)
            return a_new, None

        a_star, _ = jax.lax.scan(body, jnp.zeros_like(phi_y), None,
                                 length=n_iters)
        y_star = cheb.cheb_apply_adjoint(mv, a_star, c, lmax)
        return LassoResult(coeffs=a_star[..., :n], signal=y_star[..., :n],
                           objective=jnp.nan, n_iters=n_iters, fused=True)

    def matvec_runner(fn, signals, consts=()):
        padded = tuple(_pad(s) for s in signals)
        outs = fn(mv, *padded, *consts)
        return jax.tree.map(lambda o: o[..., :n], outs)

    return ExecutionPlan(
        op=op, backend="pallas_halo",
        apply=apply, apply_adjoint=apply_adjoint, apply_gram=apply_gram,
        solve_lasso_fn=solve_lasso,
        matvec_runner=matvec_runner,
        info=info,
    )


def _banded_to_dense(parts: BandedPartition) -> np.ndarray:
    """Reassemble the dense (padded) P from a halo `BandedPartition`."""
    S, nl = parts.n_shards, parts.n_local
    diag = np.asarray(parts.diag)
    left = np.asarray(parts.left)
    right = np.asarray(parts.right)
    out = np.zeros((S * nl, S * nl), diag.dtype)
    for s in range(S):
        r = slice(s * nl, (s + 1) * nl)
        out[r, r] = diag[s]
        if s > 0:
            out[r, (s - 1) * nl: s * nl] = left[s]
        if s < S - 1:
            out[r, (s + 1) * nl: (s + 2) * nl] = right[s]
    return out[: parts.n, : parts.n]
