"""'pallas_halo' execution backend: sharded Block-ELL + fused Pallas kernels
with boundary-row ("halo") exchange.

This backend unites the two fastest paths in the registry:

* the `pallas` backend's hot loop — Block-ELL SpMV + the fused Chebyshev
  step kernel (`kernels.ops.fused_cheb_recurrence`), one HBM round-trip per
  order — but run *per shard* inside a shard_map;
* the `halo` backend's distribution strategy — a block-tridiagonal partition
  of a banded (spatially sorted) P over a 1-D device mesh with ring
  neighbour exchange per Chebyshev order.

Where `halo` ships each shard's **entire** block (nl values) to both
neighbours per order, this backend ships only the **boundary rows** that the
neighbour actually reads: the halo width `h` is the bandwidth of the
off-diagonal coupling blocks, so per order each shard sends 2·h values
instead of 2·nl.  That is the TPU analog of the paper's accounting — one
scalar per directed edge per order, 2K|E| messages per application
(Section IV-B) — with the intra-shard edges folded into the local Block-ELL
SpMV and only the cut edges crossing the network.

Per-shard structure (shard s owns rows [s·nl, (s+1)·nl)):

    y_s = D_s x_s  +  L_s x_{s-1}[-h:]  +  R_s x_{s+1}[:h]

`D_s` is the shard's diagonal block in Block-ELL form driven through the
Pallas SpMV kernel; `L_s`/`R_s` are the (nl, h) boundary couplings applied
as small dense matmuls to the halo rows received from the ring neighbours.

Communication per application: K orders x 2 ppermutes of an (h,)-block
(forward/gram; (eta, h) for the adjoint; (..., h) tiles for batched
signals — the round count is batch-invariant, only the tile grows) —
measurable with :mod:`repro.dist.commstats` and compared against the
paper's closed form in ``benchmarks/bench_scaling.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ... import _compat  # noqa: F401  (jax.shard_map / axis_size on old jax)
from ...core import chebyshev as cheb
from ...core import graph as graphmod
from ...core.lasso import soft_threshold
from ...kernels import ops
from ..sharding import ShardingRules, make_rules
from . import register_backend
from .halo import BandedPartition, pad_signal, partition_banded, _sharded

Array = jax.Array


# ---------------------------------------------------------------------------
# Sharded Block-ELL partition
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShardedBlockELL:
    """Per-shard Block-ELL diagonal blocks + dense boundary couplings.

    blocks:  (S, nrb, slots, br, bc) per-shard Block-ELL values of D_s
    indices: (S, nrb, slots) int32 column-block index per slot
    mask:    (S, nrb, slots) bool slot validity
    left:    (S, nl, h) coupling of shard s's rows to the *last* h columns
             of shard s-1 (zero for s = 0)
    right:   (S, nl, h) coupling of shard s's rows to the *first* h columns
             of shard s+1 (zero for s = S-1)
    n:       logical (unpadded) global size; S * nl >= n
    n_local: rows per shard (nl)
    halo:    boundary bandwidth h (rows exchanged per direction per order)
    """

    blocks: Array
    indices: Array
    mask: Array
    left: Array
    right: Array
    n: int
    n_local: int
    halo: int

    @property
    def n_shards(self) -> int:
        return self.blocks.shape[0]

    @property
    def n_padded(self) -> int:
        """Global padded signal size consumed by the plan (S * nl);
        `halo.pad_signal` reads this, so the partition is passed to it
        directly."""
        return self.n_shards * self.n_local

    @property
    def nnz_blocks(self) -> int:
        return int(np.asarray(self.mask).sum())


def _coupling_bandwidth(left: np.ndarray, right: np.ndarray) -> int:
    """Halo width h: how many boundary rows a neighbour actually reads.

    `left[s]` couples shard s to the trailing columns of shard s-1 and
    `right[s]` to the leading columns of shard s+1; h is the widest such
    band over all shards (at least 1 so the exchange shapes stay static).
    """
    nl = left.shape[1]
    h = 1
    lc = np.nonzero(np.any(left != 0, axis=(0, 1)))[0]
    if lc.size:
        h = max(h, nl - int(lc.min()))
    rc = np.nonzero(np.any(right != 0, axis=(0, 1)))[0]
    if rc.size:
        h = max(h, int(rc.max()) + 1)
    return min(h, nl)


def partition_block_ell(
    P_dense: np.ndarray,
    n_shards: int,
    block: Tuple[int, int] = (8, 128),
) -> Tuple[ShardedBlockELL, float]:
    """Split P into per-shard Block-ELL diagonals + boundary couplings.

    Returns (partition, leak); `leak` is the Frobenius norm of entries
    outside the block-tridiagonal band (see `halo.partition_banded` — must
    be ~0 for exactness, use `graph.spatial_sort` first).
    """
    banded, leak = partition_banded(np.asarray(P_dense), n_shards)
    diag = np.asarray(banded.diag)
    left = np.asarray(banded.left)
    right = np.asarray(banded.right)
    nl = banded.n_local
    h = _coupling_bandwidth(left, right)

    cells = [graphmod.to_block_ell(diag[s], block) for s in range(n_shards)]
    slots = max(c.blocks.shape[1] for c in cells)
    blocks, indices, mask = [], [], []
    for c in cells:
        pad = slots - c.blocks.shape[1]
        blocks.append(np.pad(np.asarray(c.blocks),
                             ((0, 0), (0, pad), (0, 0), (0, 0))))
        indices.append(np.pad(np.asarray(c.indices), ((0, 0), (0, pad))))
        mask.append(np.pad(np.asarray(c.mask), ((0, 0), (0, pad))))
    return (
        ShardedBlockELL(
            blocks=jnp.asarray(np.stack(blocks)),
            indices=jnp.asarray(np.stack(indices)),
            mask=jnp.asarray(np.stack(mask)),
            left=jnp.asarray(left[:, :, nl - h:]),
            right=jnp.asarray(right[:, :, :h]),
            n=banded.n,
            n_local=nl,
            halo=h,
        ),
        leak,
    )


# ---------------------------------------------------------------------------
# Per-shard matvec (runs inside shard_map)
# ---------------------------------------------------------------------------
def _halo_row_matvec(local_A: graphmod.BlockELL, left: Array, right: Array,
                     nl: int, h: int, axis: str, use_pallas):
    """Matvec along the last axis of x with a boundary-rows-only exchange.

    x: (..., nl) local block.  Per call each shard ppermutes its first/last
    h entries to its ring neighbours (the only inter-shard traffic — a
    (..., h) boundary tile, so B batched signals ship (B, h) per direction
    in the *same* exchange round), runs the Pallas Block-ELL SpMV on its
    diagonal block (batched tile path: one structure sweep for the whole
    batch), and applies the small dense boundary couplings to the received
    halo rows.  The ring wraps; the first/last shard's out-of-range
    contribution is killed by the zero left/right coupling blocks.
    """
    size = jax.lax.axis_size(axis)

    def local_mv(v: Array) -> Array:
        vp = ops.pad_trailing(v, local_A.padded_n)
        return ops.spmv(local_A, vp, use_pallas=use_pallas)[..., :nl]

    def mv(x: Array) -> Array:
        head = x[..., :h]
        tail = x[..., nl - h:]
        if size > 1:
            # boundary-row exchange: shard s receives s-1's tail (read by
            # `left`) and s+1's head (read by `right`)
            from_left = jax.lax.ppermute(
                tail, axis, perm=[(i, (i + 1) % size) for i in range(size)])
            from_right = jax.lax.ppermute(
                head, axis, perm=[(i, (i - 1) % size) for i in range(size)])
        else:
            from_left, from_right = tail, head
        y = local_mv(x)
        y = y + jnp.einsum("ij,...j->...i", left, from_left)
        y = y + jnp.einsum("ij,...j->...i", right, from_right)
        return y

    return mv


def pallas_halo_bytes_per_apply(parts: ShardedBlockELL, K: int, eta: int = 1,
                                dtype_bytes: int = 4) -> int:
    """Collective-traffic model for one application: per order each shard
    sends its h boundary rows left+right; K orders, S shards.  Contrast
    `halo.halo_bytes_per_apply`, which ships the full nl block."""
    return 2 * K * parts.n_shards * parts.halo * eta * dtype_bytes


# ---------------------------------------------------------------------------
# Plan builder
# ---------------------------------------------------------------------------
@register_backend("pallas_halo")
def build(op, *, mesh=None, partition=None, axis: Optional[str] = None,
          allow_leak: bool = False, block: Tuple[int, int] = (8, 128),
          use_pallas: Optional[bool] = None, **options):
    """Build an ExecutionPlan running the fused Pallas Chebyshev recurrence
    per shard with boundary-row halo exchange.

    Requires a dense, banded P (spatially sorted sensor graph) or a
    precomputed `partition=` (a `ShardedBlockELL`, or a `halo.
    BandedPartition` which is converted).  Without `mesh=`, a 1-D "graph"
    mesh over every visible device is built.  `use_pallas` follows the
    `kernels.ops` dispatch policy (None: native on TPU, jnp oracle on CPU).
    """
    from ..operator import ExecutionPlan

    if mesh is None:
        mesh = jax.make_mesh((len(jax.devices()),), ("graph",))
    axis = axis or mesh.axis_names[0]
    n_shards = int(mesh.shape[axis])
    leak = 0.0
    if partition is None:
        if callable(op.P):
            raise ValueError("pallas_halo backend needs a dense P or "
                             "partition=")
        partition, leak = partition_block_ell(np.asarray(op.P), n_shards,
                                              block)
        if leak > 1e-10 and not allow_leak:
            raise ValueError(
                f"P is not block-tridiagonal under {n_shards} shards "
                f"(leak={leak:.3e}); spatial_sort the graph first, pass "
                "allow_leak=True, or use backend='allgather'")
    elif isinstance(partition, BandedPartition):
        repacked, leak = partition_block_ell(
            np.asarray(_banded_to_dense(partition)), partition.n_shards,
            block)
        partition = repacked
    parts = partition
    if parts.n_shards != n_shards:
        raise ValueError(f"partition has {parts.n_shards} shards but mesh "
                         f"axis {axis!r} has {n_shards}")
    n, nl, h = parts.n, parts.n_local, parts.halo
    coeffs = op.coeffs
    lmax = op.lmax

    def _mk_mv(blocks, indices, mask, left, right):
        local_A = graphmod.BlockELL(blocks=blocks[0], indices=indices[0],
                                    mask=mask[0], n=nl)
        return _halo_row_matvec(local_A, left[0], right[0], nl, h, axis,
                                use_pallas)

    # PartitionSpecs through the logical-axis rules: every per-shard tensor
    # is sharded on its leading "vertex"-block dimension.  The shared _BASE
    # vocabulary maps "vertex" to the conventional "graph" mesh axis; a
    # mesh with a differently-named axis gets a local override.  Signals
    # carry leading batch dims ((..., N) contract), so their specs are
    # built per input rank: batch/eta axes replicate, vertex axis shards.
    rules = (make_rules(mesh) if axis == "graph"
             else ShardingRules(mapping={"vertex": axis}, mesh=mesh))
    vspec = rules.spec("vertex")
    mats = (parts.blocks, parts.indices, parts.mask, parts.left, parts.right)
    mat_specs = (vspec,) * 5

    def _sig_spec(ndim: int) -> P:
        return rules.spec(*([None] * (ndim - 1)), "vertex")

    def apply(f: Array) -> Array:
        def run(blocks, indices, mask, left, right, xl, c):
            mv = _mk_mv(blocks, indices, mask, left, right)
            return ops.fused_cheb_recurrence(mv, xl, c, lmax,
                                             use_pallas=use_pallas)

        c2 = jnp.atleast_2d(jnp.asarray(coeffs, f.dtype))
        out = _sharded(run, mesh, mat_specs + (_sig_spec(f.ndim), P()),
                       _sig_spec(f.ndim + 1))(*mats,
                                              pad_signal(f, parts),
                                              c2)
        return out[..., :n]

    def apply_adjoint(a: Array) -> Array:
        def run(blocks, indices, mask, left, right, al, c):
            mv = _mk_mv(blocks, indices, mask, left, right)
            return cheb.cheb_apply_adjoint(mv, al, c, lmax)

        c = jnp.asarray(coeffs, a.dtype)
        return _sharded(run, mesh, mat_specs + (_sig_spec(a.ndim), P()),
                        _sig_spec(a.ndim - 1))(*mats, pad_signal(a, parts),
                                               c)[..., :n]

    def apply_gram(f: Array) -> Array:
        def run(blocks, indices, mask, left, right, xl, d):
            mv = _mk_mv(blocks, indices, mask, left, right)
            return ops.fused_cheb_recurrence(mv, xl, d, lmax,
                                             use_pallas=use_pallas)[..., 0, :]

        d = jnp.asarray(cheb.gram_coeffs(coeffs), f.dtype)[None]
        return _sharded(run, mesh, mat_specs + (_sig_spec(f.ndim), P()),
                        _sig_spec(f.ndim))(*mats, pad_signal(f, parts),
                                           d)[..., :n]

    def solve_lasso(y, mu, gamma, n_iters):
        from ...core.lasso import LassoResult, _mu_threshold

        def run(blocks, indices, mask, left, right, yl, c, thresh):
            mv = _mk_mv(blocks, indices, mask, left, right)
            phi_y = ops.fused_cheb_recurrence(mv, yl, c, lmax,
                                              use_pallas=use_pallas)

            def body(a, _):
                back = cheb.cheb_apply_adjoint(mv, a, c, lmax)
                gram_a = ops.fused_cheb_recurrence(mv, back, c, lmax,
                                                   use_pallas=use_pallas)
                a_new = soft_threshold(a + gamma * (phi_y - gram_a), thresh)
                return a_new, None

            a0 = jnp.zeros_like(phi_y)
            a_star, _ = jax.lax.scan(body, a0, None, length=n_iters)
            y_star = cheb.cheb_apply_adjoint(mv, a_star, c, lmax)
            return a_star, y_star

        c = jnp.asarray(coeffs, y.dtype)
        thresh = _mu_threshold(mu, op.eta, y.dtype, gamma)
        a_star, y_star = _sharded(
            run, mesh, mat_specs + (_sig_spec(y.ndim), P(), P()),
            (_sig_spec(y.ndim + 1), _sig_spec(y.ndim)),
        )(*mats, pad_signal(y, parts), c, thresh)
        return LassoResult(coeffs=a_star[..., :n], signal=y_star[..., :n],
                           objective=jnp.nan, n_iters=n_iters, fused=True)

    def matvec_runner(fn, signals, consts=()):
        # Section-V solver substrate: one shard_map running `fn` against
        # the per-shard Block-ELL matvec with boundary-rows-only halo
        # exchange — a solver round costs the same 2·h-row traffic as one
        # Chebyshev order.  Vertex-last signals shard (zero-padded tails
        # stay zero under the solvers' reciprocal-diagonal updates);
        # consts replicate; outputs crop to the logical n.
        padded = tuple(pad_signal(jnp.asarray(s), parts) for s in signals)
        local = tuple(
            jax.ShapeDtypeStruct(s.shape[:-1] + (nl,), s.dtype)
            for s in padded)
        out_sds = jax.eval_shape(lambda *a: fn(lambda v: v, *a),
                                 *local, *consts)
        in_specs = (mat_specs
                    + tuple(_sig_spec(s.ndim) for s in padded)
                    + tuple(P() for _ in consts))
        out_specs = jax.tree.map(lambda sd: _sig_spec(len(sd.shape)),
                                 out_sds)

        def run(blocks, indices, mask, left, right, *rest):
            mv = _mk_mv(blocks, indices, mask, left, right)
            return fn(mv, *rest)

        outs = _sharded(run, mesh, in_specs, out_specs)(
            *mats, *padded, *consts)
        return jax.tree.map(lambda o: o[..., :n], outs)

    return ExecutionPlan(
        op=op, backend="pallas_halo",
        apply=apply, apply_adjoint=apply_adjoint, apply_gram=apply_gram,
        solve_lasso_fn=solve_lasso,
        matvec_runner=matvec_runner,
        info={
            "mesh_axis": axis,
            "n_shards": n_shards,
            "n_local": nl,
            "halo_width": h,
            "partition_leak": leak,
            "block": block,
            "nnz_blocks": parts.nnz_blocks,
            "halo_bytes_per_apply": pallas_halo_bytes_per_apply(
                parts, op.K, 1),
            "halo_bytes_per_adjoint": pallas_halo_bytes_per_apply(
                parts, op.K, op.eta),
        },
    )


def _banded_to_dense(parts: BandedPartition) -> np.ndarray:
    """Reassemble the dense (padded) P from a halo `BandedPartition`."""
    S, nl = parts.n_shards, parts.n_local
    diag = np.asarray(parts.diag)
    left = np.asarray(parts.left)
    right = np.asarray(parts.right)
    out = np.zeros((S * nl, S * nl), diag.dtype)
    for s in range(S):
        r = slice(s * nl, (s + 1) * nl)
        out[r, r] = diag[s]
        if s > 0:
            out[r, (s - 1) * nl: s * nl] = left[s]
        if s < S - 1:
            out[r, (s + 1) * nl: (s + 2) * nl] = right[s]
    return out[: parts.n, : parts.n]
