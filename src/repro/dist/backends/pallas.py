"""'pallas' execution backend: Block-ELL SpMV + fused Chebyshev-step kernels.

Converts the dense P once into the Block-ELL layout at plan time, then every
application runs the fused recurrence (`kernels.ops.fused_cheb_apply`) — the
hot path on TPU, interpret mode on CPU.  By default the whole K-order
recurrence dispatches to the single-launch persistent sweep
(`kernels.cheb_sweep` via `ops.fused_cheb_sweep`): iterates pinned in VMEM
across all orders, one kernel launch instead of 2K, guarded by the VMEM
footprint model with a per-order fallback (pass ``sweep=False`` /
``vmem_budget=`` at plan time to control it).  The plan's matvec is tagged
with its Block-ELL structure, so `plan.solve`'s Jacobi/Chebyshev solvers
ride the same one-launch sweep kernels.  Signals are padded to the
Block-ELL padded size internally and the padding is stripped from every
output, so callers see the logical N everywhere.  Batched (..., N) signals
hit the batched SpMV tile path: every Block-ELL block load is amortized
across the batch, so B signals cost one structure sweep per order, not B.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...core import chebyshev as cheb
from ...core import graph as graphmod
from ...kernels import ops
from . import register_backend

Array = jax.Array


@register_backend("pallas")
def build(op, *, mesh=None, partition=None, block: Tuple[int, int] = (8, 128),
          use_pallas: Optional[bool] = True, sweep: Optional[bool] = None,
          vmem_budget: Optional[int] = None,
          sweep_dtype: Optional[str] = None, **options):
    from ..operator import ExecutionPlan

    del mesh, partition  # single-device backend
    if callable(op.P):
        raise ValueError("pallas backend needs a dense P to build Block-ELL")
    L = np.asarray(op.P, dtype=np.float32)
    A = graphmod.to_block_ell(L, block)
    n = L.shape[0]
    total = A.padded_n
    coeffs = op.coeffs
    lmax = op.lmax

    def _pad(x: Array) -> Array:
        return ops.pad_trailing(x, total)

    def _mv(t: Array) -> Array:
        # batched Block-ELL SpMV: leading dims (batch, eta streams, ...)
        # ride one sweep of the sparsity structure
        return ops.spmv(A, t, use_pallas=use_pallas)

    if sweep is None or sweep:
        # tag the matvec so ops.fused_cheb_recurrence / plan.solve collapse
        # whole iterations into the single-launch sweep kernels
        _mv.block_ell = A
        _mv.vmem_budget = vmem_budget
        _mv.sweep_dtype = sweep_dtype

    def apply(f: Array) -> Array:
        c2 = np.atleast_2d(np.asarray(coeffs))
        out = ops.fused_cheb_apply(A, _pad(f), c2, lmax,
                                   use_pallas=use_pallas, sweep=sweep,
                                   vmem_budget=vmem_budget,
                                   scratch_dtype=sweep_dtype)
        return out[..., :n]

    def apply_adjoint(a: Array) -> Array:
        out = cheb.cheb_apply_adjoint(_mv, _pad(a),
                                      jnp.asarray(coeffs, a.dtype), lmax)
        return out[..., :n]

    def apply_gram(f: Array) -> Array:
        d = cheb.gram_coeffs(coeffs)
        out = ops.fused_cheb_apply(A, _pad(f), d[None], lmax,
                                   use_pallas=use_pallas, sweep=sweep,
                                   vmem_budget=vmem_budget,
                                   scratch_dtype=sweep_dtype)
        return out[..., 0, :n]

    def matvec_runner(fn, signals, consts=()):
        # run the iteration body against the Block-ELL SpMV on the padded
        # domain; every output's trailing vertex axis is cropped back to n
        padded = tuple(ops.pad_trailing(jnp.asarray(s), total)
                       for s in signals)
        outs = fn(_mv, *padded, *consts)
        return jax.tree.map(lambda o: o[..., :n], outs)

    nnz_blocks = int(np.asarray(A.mask).sum()) if hasattr(A, "mask") else None
    return ExecutionPlan(
        op=op, backend="pallas",
        apply=apply, apply_adjoint=apply_adjoint, apply_gram=apply_gram,
        matvec_runner=matvec_runner,
        info={
            "block": block,
            "padded_n": total,
            "nnz_blocks": nnz_blocks,
            "flops_per_matvec": (
                None if nnz_blocks is None
                else nnz_blocks * 2 * block[0] * block[1]),
            "sweep_dtype": sweep_dtype or "f32",
            "sweep_vmem_bytes": ops.cheb_sweep_vmem_bytes(
                A, total, op.eta, op.K, scratch_dtype=sweep_dtype),
            "sweep_vmem_budget": (ops.DEFAULT_SWEEP_VMEM_BUDGET
                                  if vmem_budget is None else vmem_budget),
        },
    )
