"""Execution-backend registry for :class:`repro.dist.GraphOperator`.

A backend is a builder ``build(op, *, mesh=None, partition=None, **options)
-> ExecutionPlan``.  Registering is decoupled from dispatch so new execution
strategies (gossip-averaged application, BCSR SpMV variants, async halo, ...)
plug in without touching any caller:

    from repro.dist.backends import register_backend

    @register_backend("my-backend")
    def build(op, *, mesh=None, partition=None, **options):
        ...
        return ExecutionPlan(op=op, backend="my-backend", ...)

Built-in backends (imported at the bottom so their decorators run):
  dense       — matvec against P as given (dense matrix or closure)
  pallas      — Block-ELL SpMV + fused Chebyshev-step Pallas kernels
  halo        — shard_map, ring halo exchange of boundary blocks (banded P)
  pallas_halo — shard_map, per-shard Block-ELL fused kernels, boundary-rows-
                only halo exchange (banded P; the sharded hot path)
  allgather   — shard_map, all_gather of the iterate (general P)
"""
from __future__ import annotations

from typing import Callable, Dict, List

_REGISTRY: Dict[str, Callable] = {}


def register_backend(name: str) -> Callable:
    """Decorator: register an ExecutionPlan builder under `name`."""

    def deco(build: Callable) -> Callable:
        _REGISTRY[name] = build
        return build

    return deco


def get_backend(name: str) -> Callable:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown execution backend {name!r}; "
            f"available: {available_backends()}") from None


def available_backends() -> List[str]:
    return sorted(_REGISTRY)


# Import order matters only in that halo must precede allgather and
# pallas_halo (both reuse halo's shard_map wrapper / partition machinery).
# Each import registers its builder.
from . import dense        # noqa: E402,F401
from . import pallas       # noqa: E402,F401
from . import halo         # noqa: E402,F401
from . import pallas_halo  # noqa: E402,F401
from . import allgather    # noqa: E402,F401
