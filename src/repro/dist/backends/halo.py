"""'halo' execution backend: sharded Algorithm 1/2/3 via shard_map with ring
halo exchange (moved here from repro.core.distributed).

TPU adaptation of the paper's distributed model (DESIGN.md §3): one device
holds a contiguous *block* of vertices instead of one sensor holding one
vertex.  Spatially sorted sensor graphs are banded, so inter-shard coupling
touches only adjacent shards; per Chebyshev order each shard exchanges its
boundary *tile* — the h = coupling-bandwidth rows a neighbour actually
reads — with its two ring neighbours: one collective_permute pair per
order, matching the paper's 2K|E| message accounting.

Interior/boundary split (see docs/ARCHITECTURE.md "Perf accounting"): the
per-order matvec issues the two boundary-tile ppermutes *first*, computes
the interior contribution (the diagonal block product, which needs no
remote data) while the exchange is in flight, and applies the small
(nl, h) boundary couplings only on arrival — the exchange latency hides
behind interior compute instead of serializing in front of it, and the
wire carries 2h values per shard per order instead of the full 2·nl
block.  The measured exchange-round count (and hence the paper-level
2K|E| message count) is unchanged; only the payload shrinks.

The free functions (`dist_cheb_apply` etc.) are the stable low-level API;
:func:`build` packages them into an :class:`~repro.dist.operator.ExecutionPlan`
for the `GraphOperator.plan(backend="halo")` path.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ... import _compat  # noqa: F401  (jax.shard_map / axis_size on old jax)
from ...core import chebyshev as cheb
from ...core.lasso import soft_threshold
from .. import faults, quantize
from . import register_backend

shard_map = jax.shard_map

Array = jax.Array


# ---------------------------------------------------------------------------
# Banded partition of P
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class BandedPartition:
    """P split into per-shard tridiagonal block structure.

    diag:  (S, nl, nl)  coupling within shard i
    left:  (S, nl, nl)  coupling of shard i's rows to shard i-1's columns
    right: (S, nl, nl)  coupling of shard i's rows to shard i+1's columns
    n:     logical size (before padding); S * nl >= n
    """

    diag: Array
    left: Array
    right: Array
    n: int

    @property
    def n_shards(self) -> int:
        return self.diag.shape[0]

    @property
    def n_local(self) -> int:
        return self.diag.shape[1]

    @property
    def n_padded(self) -> int:
        return self.n_shards * self.n_local

    @property
    def halo(self) -> int:
        """Coupling bandwidth h: boundary rows a neighbour actually reads
        (the per-order exchange tile).  Computed once and memoized in the
        instance __dict__ (the frozen-dataclass cache idiom)."""
        h = self.__dict__.get("_halo")
        if h is None:
            h = _coupling_bandwidth(np.asarray(self.left),
                                    np.asarray(self.right))
            self.__dict__["_halo"] = h
        return h

    def boundary_couplings(self) -> Tuple[Array, Array]:
        """(left, right) couplings trimmed to the h columns they read:
        left: (S, nl, h) against neighbour s-1's *last* h rows; right:
        (S, nl, h) against neighbour s+1's *first* h rows."""
        h = self.halo
        nl = self.n_local
        return self.left[:, :, nl - h:], self.right[:, :, :h]


def _coupling_bandwidth(left: np.ndarray, right: np.ndarray) -> int:
    """Halo width h: how many boundary rows a neighbour actually reads.

    `left[s]` couples shard s to the trailing columns of shard s-1 and
    `right[s]` to the leading columns of shard s+1; h is the widest such
    band over all shards (at least 1 so the exchange shapes stay static).
    """
    nl = left.shape[1]
    h = 1
    lc = np.nonzero(np.any(left != 0, axis=(0, 1)))[0]
    if lc.size:
        h = max(h, nl - int(lc.min()))
    rc = np.nonzero(np.any(right != 0, axis=(0, 1)))[0]
    if rc.size:
        h = max(h, int(rc.max()) + 1)
    return min(h, nl)


def partition_banded(
    P_dense: np.ndarray, n_shards: int
) -> Tuple[BandedPartition, float]:
    """Split P into block-tridiagonal shard structure.

    Returns (partition, leak) where `leak` is the Frobenius norm of entries
    outside the block tridiagonal band (must be ~0 for the halo mode to be
    exact — use `spatial_sort` first for sensor graphs, or the 'allgather'
    backend).
    """
    P_dense = np.asarray(P_dense)
    n = P_dense.shape[0]
    nl = -(-n // n_shards)
    pad = n_shards * nl - n
    Pp = np.pad(P_dense, ((0, pad), (0, pad)))
    diag = np.zeros((n_shards, nl, nl), P_dense.dtype)
    left = np.zeros((n_shards, nl, nl), P_dense.dtype)
    right = np.zeros((n_shards, nl, nl), P_dense.dtype)
    covered = np.zeros_like(Pp, dtype=bool)
    for s in range(n_shards):
        r = slice(s * nl, (s + 1) * nl)
        diag[s] = Pp[r, r]
        covered[r, r] = True
        if s > 0:
            c = slice((s - 1) * nl, s * nl)
            left[s] = Pp[r, c]
            covered[r, c] = True
        if s < n_shards - 1:
            c = slice((s + 1) * nl, (s + 2) * nl)
            right[s] = Pp[r, c]
            covered[r, c] = True
    leak = float(np.linalg.norm(Pp[~covered]))
    return (
        BandedPartition(
            diag=jnp.asarray(diag),
            left=jnp.asarray(left),
            right=jnp.asarray(right),
            n=n,
        ),
        leak,
    )


def pad_signal(x: Union[np.ndarray, Array], parts: BandedPartition) -> Array:
    """Zero-pad the trailing (vertex) axis up to the partition's padded size;
    leading batch / eta axes pass through untouched."""
    from ...kernels.ops import pad_trailing

    return pad_trailing(jnp.asarray(x), parts.n_padded)


def _vspec(ndim: int, axis: str) -> P:
    """PartitionSpec sharding only the last of `ndim` axes on `axis` —
    batch / eta axes replicate, the vertex axis splits across shards."""
    return P(*((None,) * (ndim - 1) + (axis,)))


# ---------------------------------------------------------------------------
# Local matvecs (run inside shard_map)
# ---------------------------------------------------------------------------
def _halo_matvec(diag, left, right, nl: int, h: int, axis: str,
                 exchange_dtype: str = "f32", error_feedback: bool = True,
                 fault_spec=None, degradation: str = "zero_fill"):
    """Interior/boundary-split matvec along the *last* axis of x.

    x: (..., nl) local block; left/right are the (nl, h) boundary
    couplings from :meth:`BandedPartition.boundary_couplings`.  Per call:

    1. **boundary tiles encoded and on the wire first** — the first/last
       h entries are compressed to `exchange_dtype` (identity for f32,
       truncating cast for bf16, per-tile-scale int8 with the scale
       bitcast-packed into the same buffer — see `repro.dist.quantize`)
       and ppermute to the ring neighbours (lines 6-7 of Algorithm 1);
    2. **interior compute while the exchange is in flight** — the
       diagonal-block product needs no remote data, so it overlaps the
       collective under an async-collective scheduler;
    3. **decode + boundary coupling on arrival** — the received tiles
       widen back to the compute dtype, then two (nl, h) products.

    Under ``exchange_dtype="int8"`` with ``error_feedback=True`` (and a
    real multi-shard axis) the returned closure is *stateful-capable*:
    ``mv(x)`` stays the plain stateless signature (plain quantize), while
    ``mv(x, state) -> (y, state)`` threads the quantization residual of
    each boundary tile into the next round, and ``mv.init_state(x)``
    builds the zero residuals.  `core.chebyshev` / `kernels.ops` opt in
    via ``getattr(matvec, "init_state", None)``.

    With an *active* ``fault_spec`` (see `repro.dist.faults`) the closure
    is stateful for a second reason: the state carries the int32 round
    counter and the last-delivered tile per incoming link, and every
    received tile passes through the injector's wire-noise / stale /
    drop channels AFTER the ppermute — the collective schedule (and the
    measured 2K|E| rounds) is bitwise identical to the clean plan's.

    The permute indices form a ring; the first/last shard's out-of-range
    contribution is killed by the zero left/right coupling blocks
    (partition_banded leaves left[0] = right[-1] = 0).
    """
    size = jax.lax.axis_size(axis)
    dt = quantize.validate_exchange_dtype(exchange_dtype)
    inj = faults.make_injector(fault_spec, degradation, axis, size > 1)
    use_ef = dt == "int8" and error_feedback and size > 1

    def _run(x, state):
        head = x[..., :h]
        tail = x[..., nl - h:nl]
        if inj is not None:
            k, carried, ef_state = state
        else:
            ef_state = state
        if size > 1:
            if ef_state is None:
                wire_tail = quantize.encode(tail, dt)
                wire_head = quantize.encode(head, dt)
                new_ef = None
            else:
                r_tail, r_head = ef_state
                wire_tail, r_tail = quantize.ef_encode(tail, r_tail, dt)
                wire_head, r_head = quantize.ef_encode(head, r_head, dt)
                new_ef = (r_tail, r_head)
            # (1) issue the boundary-tile exchange: shard s receives s-1's
            # tail (read by `left`) and s+1's head (read by `right`).
            # One ppermute per direction — the int8 scale rides inside the
            # wire buffer, so measured rounds stay the paper's 2K|E|.
            from_left = jax.lax.ppermute(
                wire_tail, axis,
                perm=[(i, (i + 1) % size) for i in range(size)]
            )
            from_right = jax.lax.ppermute(
                wire_head, axis,
                perm=[(i, (i - 1) % size) for i in range(size)]
            )
            # (2) interior: depends only on local data — overlaps the
            # exchange
            y = jnp.einsum("ij,...j->...i", diag, x)
            # (3) decode + boundary coupling, consumed after the interior
            # product; injected faults perturb only what the receiver
            # consumes — the wire traffic above is already committed
            if inj is not None:
                from_left = inj.wire(from_left, k, 0, dt)
                from_right = inj.wire(from_right, k, 1, dt)
            from_left = quantize.decode(from_left, dt, x.dtype)
            from_right = quantize.decode(from_right, dt, x.dtype)
            if inj is not None:
                c_l, c_r = carried
                from_left, c_l = inj.recv(from_left, c_l, k, 0)
                from_right, c_r = inj.recv(from_right, c_r, k, 1)
                new_state = (k + 1, (c_l, c_r), new_ef)
            else:
                new_state = new_ef
        else:
            from_left, from_right = tail, head
            new_state = state
            y = jnp.einsum("ij,...j->...i", diag, x)
        y = y + jnp.einsum("ij,...j->...i", left, from_left)
        y = y + jnp.einsum("ij,...j->...i", right, from_right)
        return y, new_state

    def mv(x, state=None):
        if state is None:
            if inj is not None:
                # one-shot stateless call under faults: a fresh round-0
                # state, deterministic per seed, result state discarded
                return _run(x, mv.init_state(x))[0]
            return _run(x, None)[0]
        return _run(x, state)

    if inj is not None:
        def init_state(x):
            tail = x[..., nl - h:nl]
            head = x[..., :h]
            ef0 = ((quantize.ef_init(tail), quantize.ef_init(head))
                   if use_ef else None)
            return (inj.init_round(), inj.init_carried((tail, head)), ef0)

        mv.init_state = init_state
    elif use_ef:
        def init_state(x):
            return (quantize.ef_init(x[..., nl - h:nl]),
                    quantize.ef_init(x[..., :h]))

        mv.init_state = init_state
    return mv


def _sharded(fn, mesh, in_specs, out_specs):
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_vma=False)


# ---------------------------------------------------------------------------
# Public sharded applications
# ---------------------------------------------------------------------------
def dist_cheb_apply(
    mesh: Mesh,
    parts: BandedPartition,
    x: Array,
    coeffs: Union[Array, np.ndarray],
    lmax: float,
    axis: str = "graph",
    exchange_dtype: str = "f32",
    error_feedback: bool = True,
    fault_spec=None,
    degradation: str = "zero_fill",
) -> Array:
    """Sharded Phi_tilde x (Algorithm 1). x: (..., n_padded) — leading batch
    dims ride the same K halo-exchange rounds ((B, nl) boundary tiles move
    per ppermute, round count unchanged). Returns (..., eta, n_padded) (or
    (..., n_padded) for 1-D coeffs)."""
    single = getattr(coeffs, "ndim", None) == 1 or (
        not hasattr(coeffs, "ndim") and np.asarray(coeffs).ndim == 1)
    c = jnp.atleast_2d(jnp.asarray(coeffs, dtype=x.dtype))
    nl, h = parts.n_local, parts.halo
    left_h, right_h = parts.boundary_couplings()

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), _vspec(x.ndim, axis), P()),
        out_specs=_vspec(x.ndim + 1, axis),
        check_vma=False,
    )
    def run(diag, left, right, xl, c):
        mv = _halo_matvec(diag[0], left[0], right[0], nl, h, axis,
                          exchange_dtype, error_feedback,
                          fault_spec, degradation)
        return cheb.cheb_apply(mv, xl, c, lmax)

    out = run(parts.diag, left_h, right_h, x, c)
    return out[..., 0, :] if single else out


def dist_cheb_apply_adjoint(
    mesh: Mesh,
    parts: BandedPartition,
    a: Array,
    coeffs: Union[Array, np.ndarray],
    lmax: float,
    axis: str = "graph",
    exchange_dtype: str = "f32",
    error_feedback: bool = True,
    fault_spec=None,
    degradation: str = "zero_fill",
) -> Array:
    """Sharded Phi_tilde^* a (Algorithm 2). a: (..., eta, n_padded) ->
    (..., n_padded); one ppermute pair moves all eta streams (and every
    batch signal) per order."""
    c = jnp.asarray(coeffs, dtype=a.dtype)
    nl, h = parts.n_local, parts.halo
    left_h, right_h = parts.boundary_couplings()

    def run(diag, left, right, al, c):
        mv = _halo_matvec(diag[0], left[0], right[0], nl, h, axis,
                          exchange_dtype, error_feedback,
                          fault_spec, degradation)
        return cheb.cheb_apply_adjoint(mv, al, c, lmax)

    return _sharded(
        run, mesh,
        (P(axis), P(axis), P(axis), _vspec(a.ndim, axis), P()),
        _vspec(a.ndim - 1, axis),
    )(parts.diag, left_h, right_h, a, c)


def dist_cheb_apply_gram(
    mesh: Mesh,
    parts: BandedPartition,
    x: Array,
    coeffs: np.ndarray,
    lmax: float,
    axis: str = "graph",
    exchange_dtype: str = "f32",
    error_feedback: bool = True,
    fault_spec=None,
    degradation: str = "zero_fill",
) -> Array:
    """Sharded Phi~*Phi~ x via product coefficients (Section IV-C).
    x: (..., n_padded) -> (..., n_padded)."""
    d = jnp.asarray(cheb.gram_coeffs(coeffs), dtype=x.dtype)
    nl, h = parts.n_local, parts.halo
    left_h, right_h = parts.boundary_couplings()

    def run(diag, left, right, xl, d):
        mv = _halo_matvec(diag[0], left[0], right[0], nl, h, axis,
                          exchange_dtype, error_feedback,
                          fault_spec, degradation)
        return cheb.cheb_apply(mv, xl, d, lmax)

    return _sharded(
        run, mesh,
        (P(axis), P(axis), P(axis), _vspec(x.ndim, axis), P()),
        _vspec(x.ndim, axis),
    )(parts.diag, left_h, right_h, x, d)


def dist_lasso(
    mesh: Mesh,
    parts: BandedPartition,
    y: Array,
    coeffs: np.ndarray,
    lmax: float,
    mu: Array,
    gamma: float = 0.2,
    n_iters: int = 300,
    axis: str = "graph",
    exchange_dtype: str = "f32",
    error_feedback: bool = True,
    fault_spec=None,
    degradation: str = "zero_fill",
) -> Tuple[Array, Array]:
    """Fully sharded Algorithm 3 (distributed lasso).

    y: (..., n_padded) — batched signals share every exchange round; mu:
    scalar, (eta,) per-scale weights, or (..., eta) per-signal weights.
    Returns (a_*, y_*) with a_*: (..., eta, n_padded) wavelet coefficients,
    y_*: (..., n_padded) denoised signals. The entire ISTA loop lives
    inside one shard_map — per soft-thresholding iteration, the only
    communication is the 4K halo exchanges of Phi~ Phi~* (Section VI's
    communication analysis), regardless of batch size.
    """
    from ...core.lasso import _mu_threshold

    c = jnp.asarray(coeffs, dtype=y.dtype)
    eta = c.shape[0]
    thresh = _mu_threshold(mu, eta, y.dtype, gamma)
    nl, h = parts.n_local, parts.halo
    left_h, right_h = parts.boundary_couplings()

    def run(diag, left, right, yl, c, thresh):
        mv = _halo_matvec(diag[0], left[0], right[0], nl, h, axis,
                          exchange_dtype, error_feedback,
                          fault_spec, degradation)
        phi_y = cheb.cheb_apply(mv, yl, c, lmax)  # Alg. 3 line 3

        def body(a, _):
            gram_a = cheb.cheb_apply(
                mv, cheb.cheb_apply_adjoint(mv, a, c, lmax), c, lmax,
            )
            a_new = soft_threshold(a + gamma * (phi_y - gram_a), thresh)
            return a_new, None

        a0 = jnp.zeros_like(phi_y)
        a_star, _ = jax.lax.scan(body, a0, None, length=n_iters)
        y_star = cheb.cheb_apply_adjoint(mv, a_star, c, lmax)
        return a_star, y_star

    return _sharded(
        run, mesh,
        (P(axis), P(axis), P(axis), _vspec(y.ndim, axis), P(), P()),
        (_vspec(y.ndim + 1, axis), _vspec(y.ndim, axis)),
    )(parts.diag, left_h, right_h, y, c, thresh)


def halo_bytes_per_apply(parts: BandedPartition, K: int, eta: int = 1,
                         dtype_bytes: int = 4,
                         exchange_dtype: Optional[str] = None) -> int:
    """Collective-traffic model for one sharded application: per Chebyshev
    order each shard sends its h-row boundary tile left+right, K rounds,
    n_shards shards.  The TPU analog of the paper's 2K|E| message bound —
    the interior/boundary split shrank the payload from the full nl block
    to the h rows a neighbour actually reads, and the compressed exchange
    (`exchange_dtype=`) shrinks each row from 4h bytes (f32) to 2h (bf16)
    or h + 4 (int8 payload + packed scale; `quantize.tile_wire_bytes`),
    while the round count (what the paper-level accounting measures) is
    unchanged.  `dtype_bytes` is the legacy per-element width used when
    `exchange_dtype` is not given."""
    if exchange_dtype is not None:
        row = quantize.tile_wire_bytes(parts.halo, exchange_dtype)
    else:
        row = parts.halo * dtype_bytes
    return 2 * K * parts.n_shards * eta * row


# ---------------------------------------------------------------------------
# Plan builder
# ---------------------------------------------------------------------------
@register_backend("halo")
def build(op, *, mesh=None, partition=None, axis: Optional[str] = None,
          allow_leak: bool = False, exchange_dtype: str = "f32",
          error_feedback: bool = True, partition_method: str = "bfs",
          fault_spec=None, degradation: str = "zero_fill",
          **options):
    """Build an ExecutionPlan running every application inside a shard_map
    over `mesh` with ring halo exchange.

    Requires a dense P (or a precomputed `partition`).  ``partition=``
    accepts None / ``"banded"`` (the block-tridiagonal ring plan — the
    graph must be leak-free under the contiguous split unless
    ``allow_leak=True``), ``"general"`` (edge-cut sharding of *arbitrary*
    sparse graphs via `repro.dist.partition.partition_general`, exact for
    any sparsity; ``partition_method`` picks "bfs" or "spectral"), or a
    precomputed `BandedPartition` / `GeneralPartition` instance.  Without
    `mesh=`, a 1-D "graph" mesh over every visible device is built.

    ``exchange_dtype`` selects the wire precision of the boundary tiles
    ("f32" | "bf16" | "int8", see `repro.dist.quantize`);
    ``error_feedback`` (int8 only) threads the per-tile quantization
    residual across the K orders.
    """
    from ..operator import ExecutionPlan
    from ..partition import build_general_plan, resolve_partition_arg

    quantize.validate_exchange_dtype(exchange_dtype)
    faults.validate_degradation(degradation)
    fault_spec = faults.resolve_fault_spec(fault_spec)
    if mesh is None:
        mesh = jax.make_mesh((len(jax.devices()),), ("graph",))
    axis = axis or mesh.axis_names[0]
    n_shards = int(mesh.shape[axis])
    general = resolve_partition_arg(op, partition, n_shards,
                                    method=partition_method)
    if general is not None:
        return build_general_plan(op, general, mesh, axis,
                                  interior="dense",
                                  exchange_dtype=exchange_dtype,
                                  error_feedback=error_feedback,
                                  fault_spec=fault_spec,
                                  degradation=degradation,
                                  backend_name="halo")
    if isinstance(partition, str):
        partition = None  # "banded": build from op.P below
    leak = 0.0
    if partition is None:
        if callable(op.P):
            raise ValueError("halo backend needs a dense P or partition=")
        partition, leak = partition_banded(np.asarray(op.P), n_shards)
        if leak > 1e-10 and not allow_leak:
            raise ValueError(
                f"P is not block-tridiagonal under {n_shards} shards "
                f"(leak={leak:.3e}); spatial_sort the graph first, pass "
                "allow_leak=True, or use backend='allgather'")
    parts = partition
    n = parts.n
    nl, h = parts.n_local, parts.halo
    coeffs = op.coeffs
    lmax = op.lmax

    def apply(f: Array) -> Array:
        out = dist_cheb_apply(mesh, parts, pad_signal(f, parts),
                              jnp.atleast_2d(jnp.asarray(coeffs, f.dtype)),
                              lmax, axis, exchange_dtype, error_feedback,
                              fault_spec, degradation)
        return out[..., :n]

    def apply_adjoint(a: Array) -> Array:
        return dist_cheb_apply_adjoint(
            mesh, parts, pad_signal(a, parts), coeffs, lmax, axis,
            exchange_dtype, error_feedback, fault_spec, degradation)[..., :n]

    def apply_gram(f: Array) -> Array:
        return dist_cheb_apply_gram(
            mesh, parts, pad_signal(f, parts), coeffs, lmax, axis,
            exchange_dtype, error_feedback, fault_spec, degradation)[..., :n]

    def solve_lasso(y, mu, gamma, n_iters):
        from ...core.lasso import LassoResult

        a_star, y_star = dist_lasso(mesh, parts, pad_signal(y, parts),
                                    coeffs, lmax, mu, gamma=gamma,
                                    n_iters=n_iters, axis=axis,
                                    exchange_dtype=exchange_dtype,
                                    error_feedback=error_feedback,
                                    fault_spec=fault_spec,
                                    degradation=degradation)
        return LassoResult(coeffs=a_star[..., :n], signal=y_star[..., :n],
                           objective=jnp.nan, n_iters=n_iters, fused=True)

    def matvec_runner(fn, signals, consts=()):
        # Backend-generic iteration primitive (the Section-V solver
        # substrate): run `fn` inside ONE shard_map with the ring-halo
        # matvec; vertex-last signals shard on the vertex axis (zero-padded
        # tails stay zero — solver bodies use reciprocal-diagonal updates),
        # consts replicate, outputs crop back to the logical n.
        padded = tuple(pad_signal(jnp.asarray(s), parts) for s in signals)
        local = tuple(
            jax.ShapeDtypeStruct(s.shape[:-1] + (parts.n_local,), s.dtype)
            for s in padded)
        out_sds = jax.eval_shape(lambda *a: fn(lambda v: v, *a),
                                 *local, *consts)
        in_specs = ((P(axis),) * 3
                    + tuple(_vspec(s.ndim, axis) for s in padded)
                    + tuple(P() for _ in consts))
        out_specs = jax.tree.map(lambda sd: _vspec(len(sd.shape), axis),
                                 out_sds)

        def run(diag, left, right, *rest):
            mv = _halo_matvec(diag[0], left[0], right[0], nl, h, axis,
                              exchange_dtype, error_feedback,
                              fault_spec, degradation)
            return fn(mv, *rest)

        left_h, right_h = parts.boundary_couplings()
        outs = _sharded(run, mesh, in_specs, out_specs)(
            parts.diag, left_h, right_h, *padded, *consts)
        return jax.tree.map(lambda o: o[..., :n], outs)

    return ExecutionPlan(
        op=op, backend="halo",
        apply=apply, apply_adjoint=apply_adjoint, apply_gram=apply_gram,
        solve_lasso_fn=solve_lasso,
        matvec_runner=matvec_runner,
        info={
            "mesh_axis": axis,
            "n_shards": n_shards,
            "n_local": nl,
            "halo_width": h,
            "partition": "banded",
            "partition_leak": leak,
            # one exchange round = the left+right ppermute pair (commstats
            # divides the measured ppermute tally by this)
            "exchange_collectives_per_round": 2,
            "exchange_dtype": exchange_dtype,
            "error_feedback": bool(error_feedback),
            "fault_spec": faults.spec_info(fault_spec),
            "degradation": degradation,
            "fault_key": faults.fault_key(fault_spec, degradation),
            # forward/gram ship an eta-independent (..., h) tile per order;
            # only the adjoint's iterate carries the eta streams
            "halo_bytes_per_apply": halo_bytes_per_apply(
                parts, op.K, 1, exchange_dtype=exchange_dtype),
            "halo_bytes_per_adjoint": halo_bytes_per_apply(
                parts, op.K, op.eta, exchange_dtype=exchange_dtype),
        },
    )
