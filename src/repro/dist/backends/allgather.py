"""'allgather' execution backend: row-block sharded P with one all_gather of
the iterate per Chebyshev order (general, non-banded graphs).

Exact for any sparsity pattern — the trade is bandwidth: each order moves
the whole iterate instead of the 2-block halo, so prefer 'halo' whenever
the graph is (or can be sorted to be) banded.
"""
from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ... import _compat  # noqa: F401
from ...core import chebyshev as cheb
from ...kernels.ops import pad_trailing
from . import register_backend
from .halo import _sharded, _vspec

Array = jax.Array


def _allgather_matvec(rows, axis: str):
    """rows: (nl, N_padded) local row block; x gathered each application.

    x: (..., nl) — one gather moves every leading batch / eta stream in the
    same round (the vertex axis stays last, so `axis=x.ndim - 1` is the
    gather axis for any batch rank)."""

    def mv(x: Array) -> Array:
        x_full = jax.lax.all_gather(x, axis, axis=x.ndim - 1, tiled=True)
        return jnp.einsum("ij,...j->...i", rows, x_full)

    return mv


def dist_cheb_apply_allgather(
    mesh: Mesh,
    P_dense: Array,
    x: Array,
    coeffs: Union[Array, np.ndarray],
    lmax: float,
    axis: str = "graph",
) -> Array:
    """Sharded Phi_tilde x for general (non-banded) P: row-block sharding of
    P, one all_gather of the iterate per Chebyshev order.  x: (..., n_padded)
    -> (..., eta, n_padded) ((..., n_padded) for 1-D coeffs)."""
    single = getattr(coeffs, "ndim", None) == 1 or (
        not hasattr(coeffs, "ndim") and np.asarray(coeffs).ndim == 1)
    c = jnp.atleast_2d(jnp.asarray(coeffs, dtype=x.dtype))

    def run(rows, xl, c):
        mv = _allgather_matvec(rows, axis)
        return cheb.cheb_apply(mv, xl, c, lmax)

    out = _sharded(
        run, mesh, (P(axis, None), _vspec(x.ndim, axis), P()),
        _vspec(x.ndim + 1, axis)
    )(P_dense, x, c)
    return out[..., 0, :] if single else out


def dist_cheb_apply_adjoint_allgather(
    mesh: Mesh,
    P_dense: Array,
    a: Array,
    coeffs: Union[Array, np.ndarray],
    lmax: float,
    axis: str = "graph",
) -> Array:
    """Sharded Phi_tilde^* a (Algorithm 2) with all-gather matvecs.
    a: (..., eta, n_padded) -> (..., n_padded); one gather moves all eta
    streams (and all batch signals) per order."""
    c = jnp.asarray(coeffs, dtype=a.dtype)

    def run(rows, al, c):
        mv = _allgather_matvec(rows, axis)
        return cheb.cheb_apply_adjoint(mv, al, c, lmax)

    return _sharded(
        run, mesh, (P(axis, None), _vspec(a.ndim, axis), P()),
        _vspec(a.ndim - 1, axis)
    )(P_dense, a, c)


def dist_cheb_apply_gram_allgather(
    mesh: Mesh,
    P_dense: Array,
    x: Array,
    coeffs: np.ndarray,
    lmax: float,
    axis: str = "graph",
) -> Array:
    """Sharded Phi~*Phi~ x via product coefficients (Section IV-C).
    x: (..., n_padded) -> (..., n_padded)."""
    d = jnp.asarray(cheb.gram_coeffs(coeffs), dtype=x.dtype)

    def run(rows, xl, d):
        mv = _allgather_matvec(rows, axis)
        return cheb.cheb_apply(mv, xl, d, lmax)

    return _sharded(
        run, mesh, (P(axis, None), _vspec(x.ndim, axis), P()),
        _vspec(x.ndim, axis)
    )(P_dense, x, d)


@register_backend("allgather")
def build(op, *, mesh=None, partition=None, axis: Optional[str] = None,
          **options):
    """ExecutionPlan for arbitrary graphs: shard P by row blocks over `mesh`
    and all_gather the iterate once per Chebyshev order.  Without `mesh=`, a
    1-D "graph" mesh over every visible device is built."""
    from ..operator import ExecutionPlan

    del partition  # allgather shards rows directly from the dense P
    if mesh is None:
        mesh = jax.make_mesh((len(jax.devices()),), ("graph",))
    if callable(op.P):
        raise ValueError("allgather backend needs a dense P")
    axis = axis or mesh.axis_names[0]
    n_shards = int(mesh.shape[axis])
    Pm = np.asarray(op.P)
    n = Pm.shape[0]
    total = n_shards * (-(-n // n_shards))
    Pp = jnp.asarray(np.pad(Pm, ((0, total - n), (0, total - n))))
    coeffs = op.coeffs
    lmax = op.lmax

    def _pad(x: Array) -> Array:
        return pad_trailing(x, total)

    def apply(f: Array) -> Array:
        c2 = jnp.atleast_2d(jnp.asarray(coeffs, f.dtype))
        return dist_cheb_apply_allgather(mesh, Pp, _pad(f), c2, lmax,
                                         axis)[..., :n]

    def apply_adjoint(a: Array) -> Array:
        return dist_cheb_apply_adjoint_allgather(mesh, Pp, _pad(a), coeffs,
                                                 lmax, axis)[..., :n]

    def apply_gram(f: Array) -> Array:
        return dist_cheb_apply_gram_allgather(mesh, Pp, _pad(f), coeffs,
                                              lmax, axis)[..., :n]

    def matvec_runner(fn, signals, consts=()):
        # Section-V solver substrate for general graphs: `fn` runs inside
        # one shard_map with the row-block matvec (one all_gather of the
        # iterate per solver matvec); vertex-last signals shard, consts
        # replicate, outputs crop back to the logical n.
        padded = tuple(_pad(jnp.asarray(s)) for s in signals)
        nl = total // n_shards
        local = tuple(
            jax.ShapeDtypeStruct(s.shape[:-1] + (nl,), s.dtype)
            for s in padded)
        out_sds = jax.eval_shape(lambda *a: fn(lambda v: v, *a),
                                 *local, *consts)
        in_specs = ((P(axis, None),)
                    + tuple(_vspec(s.ndim, axis) for s in padded)
                    + tuple(P() for _ in consts))
        out_specs = jax.tree.map(lambda sd: _vspec(len(sd.shape), axis),
                                 out_sds)

        def run(rows, *rest):
            mv = _allgather_matvec(rows, axis)
            return fn(mv, *rest)

        outs = _sharded(run, mesh, in_specs, out_specs)(Pp, *padded, *consts)
        return jax.tree.map(lambda o: o[..., :n], outs)

    return ExecutionPlan(
        op=op, backend="allgather",
        apply=apply, apply_adjoint=apply_adjoint, apply_gram=apply_gram,
        matvec_runner=matvec_runner,
        info={
            "mesh_axis": axis,
            "n_shards": n_shards,
            "gather_bytes_per_apply": 2 * op.K * total * 4,
        },
    )
