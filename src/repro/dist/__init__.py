"""repro.dist — distributed execution layer.

* :mod:`repro.dist.operator`  — `GraphOperator` / `ExecutionPlan`, the
  unified apply surface (plan/execute split).
* :mod:`repro.dist.backends`  — pluggable execution strategies
  (dense | pallas | halo | allgather) behind a registry.
* :mod:`repro.dist.sharding`  — logical-axis `ShardingRules` / `make_rules`.
* :mod:`repro.dist.commstats` — measured communication accounting
  (`CommStats`, `plan_comm_stats`): counts the collectives a plan traces
  to and converts them to the paper's 2K|E| message model.
* :mod:`repro.dist.solvers`   — Section-V iterative solvers (Jacobi,
  Chebyshev-accelerated Jacobi, parallel ARMA) behind `plan.solve`,
  running inside every backend via the `matvec_runner` primitive.
* :mod:`repro.dist.gossip`    — Chebyshev ring consensus (the paper's
  Algorithm 1 on the device ring) for fabric-free gradient averaging.
* :mod:`repro.dist.partition` — pluggable edge-cut partitions for
  arbitrary sparse graphs (`GeneralPartition`, `partition_general`,
  `community_graph_csr`): per-shard Block-ELL plus a ring-offset
  exchange plan consumed by the halo backends via
  ``plan(..., partition="general")``.
* :mod:`repro.dist.faults`    — deterministic, seeded link-fault
  injection for the sharded exchange (`FaultSpec`, graceful-degradation
  policies) behind ``plan(..., fault_spec=...)``.
"""
from . import commstats, faults, gossip, partition, sharding, solvers
from .faults import DEGRADATIONS, FaultSpec
from .backends import available_backends, get_backend, register_backend
from .commstats import (CommStats, plan_comm_stats, solve_comm_stats,
                        verify_message_scaling)
from .operator import ExecutionPlan, GraphOperator, as_graph_operator
from .partition import (CSRMatrix, GeneralPartition, OverfullSlotsError,
                        community_graph_csr, partition_general)
from .sharding import ShardingRules, make_rules
from .solvers import SolveResult, solve_plan

__all__ = [
    "CSRMatrix",
    "CommStats",
    "DEGRADATIONS",
    "ExecutionPlan",
    "FaultSpec",
    "GeneralPartition",
    "GraphOperator",
    "OverfullSlotsError",
    "ShardingRules",
    "SolveResult",
    "as_graph_operator",
    "available_backends",
    "commstats",
    "community_graph_csr",
    "faults",
    "get_backend",
    "gossip",
    "make_rules",
    "partition",
    "partition_general",
    "plan_comm_stats",
    "register_backend",
    "sharding",
    "solve_comm_stats",
    "solve_plan",
    "solvers",
    "verify_message_scaling",
]
