"""Chebyshev gossip consensus on the device ring (the paper's Algorithm 1
with P = the ring-graph Laplacian and the devices as vertices).

The n-device ring Laplacian L_ring has eigenvalues
``lambda_k = 2 - 2 cos(2 pi k / n)`` with the constant vector spanning the
nullspace.  A polynomial p with ``p(0) = 1`` and ``p(lambda_k) = 0`` on
every distinct non-zero eigenvalue therefore satisfies
``p(L_ring) = (1/n) 11^T`` — *finite-time* average consensus after
``K = ceil(n/2)`` neighbour exchange rounds, each round being exactly the
per-order message exchange of Algorithm 1.  For smaller budgets
``K < ceil(n/2)`` the coefficients solve the constrained least-squares
problem (minimise the residual on the non-zero spectrum subject to
p(0) = 1), giving graceful approximate consensus.

Degradation paths (refs [31]-style robustness):
  * ``quantize=True`` — messages ship as REAL int8 wire buffers
    (``repro.dist.quantize`` codec: per-row scale bitcast-packed into the
    payload, ``h + 4`` bytes per h-element row vs ``4h`` for f32 — a
    ``4h/(h+4)`` ~= 4x traffic reduction for large rows; consensus error
    grows to ~the quantization noise floor);
  * ``fault_spec=`` — the SAME seeded link-fault model the sharded halo
    backends use (:mod:`repro.dist.faults`): per-(round, link) Bernoulli
    drop/stale plus bit-noise on quantized wires, with a
    ``degradation=`` policy (``"zero_fill"`` | ``"hold_last"``) for
    dropped deliveries.  Ring link ids match the banded halo convention
    (0 = from-left, 1 = from-right), so one ``FaultSpec`` replays the
    identical fault trace on a filter plan and on the gossip ring.
  * ``drop_left`` / ``drop_right`` — compat shim for the original
    deterministic lost-link model: a device ignores its incoming link
    and substitutes its own state (the ring degrades to a path graph,
    consensus stays bounded).  Kept for callers that want a *static*
    per-device link disable; probabilistic faults should use
    ``fault_spec``.

Usage — gradient averaging without a fabric all-reduce (what
``repro.launch.train --dp-mode gossip`` does)::

    coeffs = consensus_coeffs(mesh.shape["data"])     # host-side, once

    @partial(jax.shard_map, mesh=mesh, ...)
    def step(batch, params):
        grads = ...                                    # per-device grads
        return gossip_mean_tree(grads, "data", coeffs) # ~= all-reduce mean

Communication per call: ``K = ceil(n/2)`` neighbour-exchange rounds of the
full payload per direction (measure with :mod:`repro.dist.commstats`);
``consensus_error(n, coeffs)`` bounds the distance from the true mean.
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from .. import _compat  # noqa: F401  (jax.lax.axis_size on old jax)
from ..core import chebyshev as cheb
from . import faults
from . import quantize as q

Array = jax.Array

#: The ring Laplacian spectrum lives in [0, 4] for every n.
RING_LMAX = 4.0


# ---------------------------------------------------------------------------
# Coefficients
# ---------------------------------------------------------------------------
def ring_eigenvalues(n: int) -> np.ndarray:
    """Distinct eigenvalues of the n-ring Laplacian, ascending (0 first)."""
    ks = np.arange(n // 2 + 1)
    return 2.0 - 2.0 * np.cos(2.0 * np.pi * ks / n)


def _cheb_rows(lam: np.ndarray, K: int) -> np.ndarray:
    """Rows of shifted-Chebyshev basis values (half-c0 convention) at lam."""
    alpha = RING_LMAX / 2.0
    y = (np.asarray(lam, np.float64) - alpha) / alpha
    rows = np.zeros((len(y), K + 1))
    t_km2 = np.ones_like(y)
    rows[:, 0] = 0.5 * t_km2
    if K >= 1:
        t_km1 = y.copy()
        rows[:, 1] = t_km1
        for k in range(2, K + 1):
            t_k = 2.0 * y * t_km1 - t_km2
            rows[:, k] = t_k
            t_km2, t_km1 = t_km1, t_k
    return rows


def consensus_coeffs(n: int, K: Optional[int] = None) -> np.ndarray:
    """Chebyshev coefficients of the degree-K ring-consensus polynomial.

    Default ``K = ceil(n/2)`` hits every distinct non-zero ring eigenvalue
    -> exact (finite-time) consensus.  Smaller K returns the constrained
    least-squares polynomial: p(0) = 1 exactly, residual minimised on the
    non-zero spectrum.  Shape (K+1,), float64, half-c0 convention (as
    consumed by :func:`repro.core.chebyshev.cheb_apply`).
    """
    if K is None:
        K = int(np.ceil(n / 2))
    lam = ring_eigenvalues(n)
    rows = _cheb_rows(lam, K)
    t0, t_nz = rows[0], rows[1:]
    # constrained LS via the nullspace of the p(0)=1 constraint row
    c_part = t0 / float(t0 @ t0)
    _, _, vt = np.linalg.svd(t0[None, :])
    null = vt[1:].T  # (K+1, K)
    z, *_ = np.linalg.lstsq(t_nz @ null, -t_nz @ c_part, rcond=None)
    return c_part + null @ z


def consensus_error(n: int, coeffs: Union[np.ndarray, Sequence[float]]) -> float:
    """Worst-case consensus defect of p on the n-ring spectrum.

    ``max(|p(0) - 1|, max_{k != 0} |p(lambda_k)|)`` — the operator-norm
    distance between p(L_ring) and the averaging projector.
    """
    coeffs = np.asarray(coeffs, np.float64)
    lam = ring_eigenvalues(n)
    vals = _cheb_rows(lam, len(coeffs) - 1) @ coeffs
    err0 = abs(vals[0] - 1.0)
    err_nz = float(np.max(np.abs(vals[1:]))) if len(lam) > 1 else 0.0
    return float(max(err0, err_nz))


# ---------------------------------------------------------------------------
# On-device gossip (runs inside shard_map)
# ---------------------------------------------------------------------------
def quantize_message(x: Array, bits: int = 8) -> Array:
    """Encode a gossip message as a REAL int8 wire buffer.

    Delegates to the shared halo codec (:func:`repro.dist.quantize.encode`):
    per-last-axis-row max-abs scale, 127 signed levels, the f32 scale
    bitcast-packed into the trailing 4 bytes of the int8 payload — so the
    ppermute'd array really is ``h + 4`` bytes per h-element row (vs ``4h``
    for the f32 payload), and :mod:`repro.dist.commstats` counts the
    shrunken traffic automatically.  Decode with :func:`dequantize_message`.
    All-zero rows pass through unchanged (scale clamps to 1).  Only the
    int8 wire format is implemented; other widths raise.
    """
    if bits != 8:
        raise ValueError(f"only bits=8 (int8 wire) is supported, got {bits}")
    return q.encode(x, "int8")


def dequantize_message(wire: Array, out_dtype=jnp.float32) -> Array:
    """Decode an int8 wire buffer from :func:`quantize_message`."""
    return q.decode(wire, "int8", out_dtype)


def _ring_matvec(axis: str, *, quantize: bool = False,
                 drop_left=False, drop_right=False,
                 fault_spec=None, degradation: str = "zero_fill"):
    """L_ring matvec: one left + one right neighbour exchange per call.

    With an active `fault_spec` the matvec is stateful (the shared
    :mod:`repro.dist.faults` protocol: round counter + carried tiles
    threaded by `cheb_apply`); otherwise the original stateless closure
    is returned, bitwise-identical to the pre-faults trace.
    """
    size = jax.lax.axis_size(axis)
    inj = faults.make_injector(fault_spec, degradation, axis,
                               exchanging=size > 1)
    wire_dtype = "int8" if quantize else "f32"

    def _exchange(x: Array):
        msg = quantize_message(x) if quantize else x
        if size > 1:
            from_left = jax.lax.ppermute(
                msg, axis, perm=[(i, (i + 1) % size) for i in range(size)])
            from_right = jax.lax.ppermute(
                msg, axis, perm=[(i, (i - 1) % size) for i in range(size)])
        else:
            from_left = from_right = msg
        return from_left, from_right

    def _finish(x, from_left, from_right):
        # straggler mitigation: a dropped link substitutes local state,
        # degrading the ring to a path graph (still PSD, still consensus-
        # preserving on the constant component).
        from_left = jnp.where(drop_left, x, from_left)
        from_right = jnp.where(drop_right, x, from_right)
        return 2.0 * x - from_left - from_right

    if inj is None:
        def mv(x: Array) -> Array:
            from_left, from_right = _exchange(x)
            if quantize:
                from_left = dequantize_message(from_left, x.dtype)
                from_right = dequantize_message(from_right, x.dtype)
            return _finish(x, from_left, from_right)

        return mv

    def mv(x: Array, state):  # type: ignore[misc]
        k, (c_l, c_r) = state
        from_left, from_right = _exchange(x)
        from_left = inj.wire(from_left, k, 0, wire_dtype)
        from_right = inj.wire(from_right, k, 1, wire_dtype)
        if quantize:
            from_left = dequantize_message(from_left, x.dtype)
            from_right = dequantize_message(from_right, x.dtype)
        from_left, c_l = inj.recv(from_left, c_l, k, 0)
        from_right, c_r = inj.recv(from_right, c_r, k, 1)
        return _finish(x, from_left, from_right), (k + 1, (c_l, c_r))

    def init_state(x):
        return (inj.init_round(), inj.init_carried((x, x)))

    mv.init_state = init_state
    return mv


def gossip_mean(x: Array, axis: str, coeffs, *, quantize: bool = False,
                drop_left=False, drop_right=False,
                fault_spec=None, degradation: str = "zero_fill") -> Array:
    """Approximate per-component mean over the `axis` device ring.

    Must be called inside a shard_map over `axis`; `x` is the local block
    (any shape) and the return value has the same shape, each entry
    replaced by (approximately) the across-devices mean.  With the default
    full-order coefficients the consensus is exact to float32.

    `fault_spec` / `degradation` inject the shared
    :mod:`repro.dist.faults` link-fault model into the ring exchange
    (None or an all-zero spec = the untouched clean path).
    """
    mv = _ring_matvec(axis, quantize=quantize,
                      drop_left=drop_left, drop_right=drop_right,
                      fault_spec=fault_spec, degradation=degradation)
    c = jnp.asarray(np.asarray(coeffs), x.dtype)
    x = jnp.asarray(x)
    if x.ndim == 0:
        # cheb_apply's (..., N) contract needs a trailing axis; the ring
        # "graph" lives on the device axis, so a scalar leaf is a 1-vector
        return cheb.cheb_apply(mv, x[None], c, RING_LMAX)[0]
    return cheb.cheb_apply(mv, x, c, RING_LMAX)


def gossip_mean_tree(tree, axis: str, coeffs, *, quantize: bool = False,
                     fault_spec=None, degradation: str = "zero_fill"):
    """:func:`gossip_mean` mapped over a pytree of same-sharded leaves.

    The gradient-consensus entry point used by ``repro.launch.train
    --dp-mode gossip``: every leaf is averaged over the `axis` device ring
    independently (one Chebyshev recurrence per leaf).  Must be called
    inside a shard_map over `axis`, like :func:`gossip_mean`.
    """
    return jax.tree_util.tree_map(
        lambda leaf: gossip_mean(leaf, axis, coeffs, quantize=quantize,
                                 fault_spec=fault_spec,
                                 degradation=degradation), tree)
