"""The unified execution API: `GraphOperator` + `ExecutionPlan`.

One object owns the paper's math (coefficients of Eq. (14), error bound of
Prop. 4, message accounting of Section IV) and an explicit *plan* step picks
the execution strategy:

    op = GraphOperator(P, multipliers, lmax=lmax, K=20)
    plan = op.plan(backend="halo", mesh=mesh)     # or dense | pallas | allgather
    out  = plan.apply(f)            # Phi~ f          (..., N) -> (..., eta, N)
    sig  = plan.apply_adjoint(out)  # Phi~* a         (..., eta, N) -> (..., N)
    gr   = plan.apply_gram(f)       # Phi~* Phi~ f    (..., N) -> (..., N)
    res  = plan.solve_lasso(y, mu)  # Algorithm 3     (..., N) signals
    sol  = plan.solve(y, method="jacobi", tau=0.5)  # Section V solvers

Signals are ``(..., N)``: leading axes are batch signals, and because the
Chebyshev recurrence is linear every batch signal rides the *same* K
communication rounds (Section III-D's shared-rounds trick as a first-class
contract — B signals cost one sweep, not B).  Every backend honours the
same signatures and the same logical sizes — padding (Block-ELL tiles,
shard grids) is a backend detail, applied on the way in and stripped on
the way out.  New strategies register through :mod:`repro.dist.backends`
without touching any caller.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Dict, Optional

import jax

from ..core.multiplier import UnionMultiplier

Array = jax.Array

logger = logging.getLogger(__name__)


def canonical_kwarg(v) -> Any:
    """Hashable, collision-free canonical form of one solver kwarg value.

    The memo keys of :meth:`ExecutionPlan.compiled_solve` — and the
    serving engine's compatibility keys, which must agree with them —
    key array-valued kwargs by (shape, dtype, bytes) so two solves of
    different systems never share a compiled entry.  ``bool`` is tagged
    before the numeric paths because ``True == 1`` (and hashes equal):
    without the tag, ``use_pallas=True`` and ``use_pallas=1`` would
    collide into one entry keyed by whichever was compiled first.
    """
    if isinstance(v, bool):
        return ("bool", v)
    if isinstance(v, (list, tuple)):
        return tuple(canonical_kwarg(x) for x in v)
    if hasattr(v, "shape") or type(v).__module__ == "numpy":
        import numpy as np

        a = np.asarray(v)
        return (a.shape, str(a.dtype), a.tobytes())
    return v


def canonical_solve_items(solve_kwargs: Dict[str, Any]):
    """Sorted ``(name, canonical_kwarg(value))`` tuple for a kwargs dict.

    This IS the kwargs part of the `compiled_solve` memo key;
    `repro.serve` builds its request-compatibility keys from the same
    function so "same compat key" and "same compiled entry" can never
    drift apart.
    """
    return tuple((k, canonical_kwarg(v))
                 for k, v in sorted(solve_kwargs.items()))


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """A compiled-strategy view of one GraphOperator.

    `apply` / `apply_adjoint` / `apply_gram` are jit-compatible closures with
    the uniform signatures documented on :class:`GraphOperator`.  `info`
    carries backend-specific cost metadata (halo bytes, Block-ELL occupancy,
    ...) for benchmarks and dashboards.  Serving loops should call the
    memoized :meth:`compiled` / :meth:`compiled_solve` wrappers instead of
    re-wrapping the closures in `jax.jit` per request.
    """

    op: UnionMultiplier
    backend: str
    apply: Callable[[Array], Array]
    apply_adjoint: Callable[[Array], Array]
    apply_gram: Callable[[Array], Array]
    info: Dict[str, Any] = dataclasses.field(default_factory=dict)
    solve_lasso_fn: Optional[Callable] = None
    #: Backend-generic distributed-iteration primitive (the Section-V solver
    #: substrate): ``matvec_runner(fn, signals, consts=()) -> outputs`` runs
    #: the jit-compatible body ``fn(mv, *signals, *consts)`` against this
    #: backend's distributed matvec ``mv`` (applies P along the last axis on
    #: the backend's padded/sharded domain).  `signals` are (..., N) arrays
    #: with the vertex axis LAST — the runner pads them on the way in,
    #: shards them on the vertex axis, and crops every output back to the
    #: logical N; `consts` are small replicated arrays (coefficients).
    #: Backends that leave it None fall back to the single-device reference
    #: matvec in `plan.solve` (logged at INFO).
    matvec_runner: Optional[Callable] = None

    # compiled-callable memoization ----------------------------------------
    def _jit_cache(self) -> Dict[Any, Any]:
        """Per-plan memo for jitted callables (frozen-dataclass __dict__
        idiom, like the operator's coefficient cache)."""
        return self.__dict__.setdefault("_compiled", {})

    def compiled(self, kind: str = "apply") -> Callable[[Array], Array]:
        """Memoized `jax.jit`-wrapped plan method for serving loops.

        ``plan.compiled("apply")`` returns THE SAME jit wrapper on every
        call, so repeated serving requests hit jax's per-(shape, dtype)
        trace cache instead of retracing — the failure mode of writing
        ``jax.jit(plan.apply)`` afresh per request, which builds a new
        wrapper (and a new empty cache) every time.  kind: ``"apply"`` |
        ``"apply_adjoint"`` | ``"apply_gram"``.
        """
        fns = {"apply": self.apply, "apply_adjoint": self.apply_adjoint,
               "apply_gram": self.apply_gram}
        if kind not in fns:
            raise KeyError(f"unknown kind {kind!r}; available: "
                           f"{sorted(fns)}")
        # The memo is per-plan, but the exchange precision and partition
        # identity still join the key: plans rebuilt at a different
        # ``exchange_dtype`` or ``partition=`` that share a cache (e.g.
        # via copy/replace) must never serve each other's compiled
        # entries.  GeneralPartition plans carry a content fingerprint;
        # banded plans key on the literal "banded".
        key = (kind, self.info.get("exchange_dtype", "f32"),
               self.info.get("partition_fingerprint",
                             self.info.get("partition", "banded")),
               self.info.get("fault_key", "none"))
        cache = self._jit_cache()
        if key not in cache:
            cache[key] = jax.jit(fns[kind])
        return cache[key]

    def compiled_solve(self, method: str = "chebyshev", **solve_kwargs):
        """Memoized jitted Section-V solver: ``y -> x`` (or ``(x, history)``
        with ``history=True``).

        Keyed per (method, solver kwargs); shapes/dtypes are handled by
        jax's own jit cache, so a serving loop calling
        ``plan.compiled_solve("jacobi", tau=0.5)(y)`` pays the numpy solve
        setup and the trace once per signature.  Array-valued kwargs
        (``den_diag=``, explicit ``poles=``) key by value (bytes), so two
        plans solving different systems never share a cache entry — which
        also means every `compiled_solve` *lookup* re-hashes those arrays:
        hold the returned callable in the request loop rather than calling
        ``compiled_solve(...)`` per request when passing large arrays.
        """
        key = (("solve", method, self.info.get("exchange_dtype", "f32"),
                self.info.get("partition_fingerprint",
                              self.info.get("partition", "banded")),
                self.info.get("fault_key", "none"))
               + canonical_solve_items(solve_kwargs))
        cache = self._jit_cache()
        if key not in cache:
            history = bool(solve_kwargs.get("history", False))

            def run(y):
                res = self.solve(y, method, **solve_kwargs)
                return (res.x, res.history) if history else res.x

            cache[key] = jax.jit(run)
        return cache[key]

    def bucketed_callables(self, buckets, kinds=("apply",),
                           solve_specs=(), n: Optional[int] = None,
                           dtype=None, warm: bool = False):
        """Enumerate the compiled entries a serving loop dispatches onto.

        Continuous-batching serving (``repro.serve``) pads every dynamic
        batch to a fixed set of bucket sizes so the engine only ever
        presents ``len(buckets)`` signatures per callable — this method
        is the inventory of that contract.  Returns an ordered dict

            {(label, B): callable}

        where `label` is a plan kind (``"apply"`` | ``"apply_adjoint"``
        | ``"apply_gram"``) or ``("solve", method, canonical-kwargs)``
        for each ``(method, kwargs)`` pair in `solve_specs`, and the
        callable takes one ``(B, N)`` stack (``(B, eta, N)`` for the
        adjoint).  Entries for the same label share ONE memoized jit
        wrapper (:meth:`compiled` / :meth:`compiled_solve`): bucket
        specialization lives in jax's per-shape trace cache under it, so
        distinct buckets get distinct compiled executables while repeat
        calls at any enumerated bucket never retrace.

        ``warm=True`` runs each entry once on zeros of its bucket shape,
        paying every trace + compile up front so the first real request
        of each bucket is served at steady-state latency.  `n` defaults
        to the operator's dense-P dimension (pass it for closure-P
        operators).
        """
        import collections

        import jax.numpy as jnp
        import numpy as np

        if n is None:
            if callable(self.op.P):
                raise ValueError(
                    "bucketed_callables needs n= for a closure P")
            n = int(np.asarray(self.op.P).shape[0])
        dtype = dtype or jnp.float32
        buckets = tuple(sorted({int(b) for b in buckets}))
        if not buckets or buckets[0] < 1:
            raise ValueError(f"buckets must be positive ints, got {buckets}")
        entries = collections.OrderedDict()
        for kind in kinds:
            fn = self.compiled(kind)
            lead = (self.op.eta,) if kind == "apply_adjoint" else ()
            for B in buckets:
                entries[(kind, B)] = (fn, (B,) + lead + (int(n),))
        for method, kw in solve_specs:
            kw = dict(kw or {})
            label = ("solve", method) + canonical_solve_items(kw)
            fn = self.compiled_solve(method, **kw)
            for B in buckets:
                entries[(label, B)] = (fn, (B, int(n)))
        out = collections.OrderedDict()
        for (label, B), (fn, shape) in entries.items():
            if warm:
                fn(jnp.zeros(shape, dtype))
            out[(label, B)] = fn
        return out

    # mirrored operator metadata -------------------------------------------
    @property
    def eta(self) -> int:
        return self.op.eta

    @property
    def K(self) -> int:
        return self.op.K

    @property
    def lmax(self) -> float:
        return self.op.lmax

    @property
    def coeffs(self):
        return self.op.coeffs

    def error_bound(self) -> float:
        return self.op.error_bound()

    def message_counts(self, n_edges: int) -> dict:
        return self.op.message_counts(n_edges)

    # Section V solvers -----------------------------------------------------
    def solve(self, y: Array, method: str = "chebyshev", **kwargs):
        """Apply x = g(P) y by a Section-V iterative method, distributed.

        The solver problem is the rational filter g = num/den (monomial
        coefficients, low-degree-first) — equivalently: solve
        ``den(P) x = num(P) y`` (Eq. (23), Q = g(P)^{-1}).  Sugar: pass
        ``tau=`` (+ ``r=``, ``h_scale=``) for the Tikhonov/SSL family
        g = tau / (tau + h_scale * lambda^r); named specs live in
        `repro.core.filters` (`tikhonov_rational`,
        `inverse_filter_rational`, `random_walk_rational`).

        method: ``"chebyshev"`` (Section IV truncated approximation, order
        n_iters), ``"jacobi"`` (Eq. (24)), ``"cheb_jacobi"`` (Eq. (25);
        needs rho < 1, estimated if omitted), ``"arma"`` (Eqs. (29)-(30);
        pole/residue recursion, |p_k| > lmax/2 required for convergence).

        y: (..., N) batched signals — every signal shares the exchange
        rounds; each round costs exactly the backend's matvec communication
        (boundary-only halos under halo/pallas_halo), with Jacobi rounds
        costing deg(den) matvecs.  Runs inside this plan's
        ``matvec_runner``; backends without one fall back to the reference
        matvec (logged).  Returns a :class:`repro.dist.solvers.SolveResult`
        (``history=True`` records the per-round iterates).

        Keyword reference: see API.md ("Section V solvers — plan.solve")
        and :func:`repro.dist.solvers.solve_plan`.
        """
        from .solvers import solve_plan

        return solve_plan(self, y, method, **kwargs)

    # Algorithm 3 -----------------------------------------------------------
    def solve_lasso(self, y: Array, mu, gamma: Optional[float] = None,
                    n_iters: int = 300, **kwargs):
        """Distributed wavelet lasso (Section VI) under this plan's backend.

        y: (..., N) — batched signals share every exchange round; mu:
        scalar, (eta,) per-scale, or (..., eta) per-signal weights.

        Backends that can fuse the whole ISTA loop (halo / pallas_halo: one
        shard_map) override the generic path.  The fused path takes no
        extra loop knobs, so kwargs that *change* the loop (a0,
        record_objective, soft_threshold_fn, ...) route to the generic ISTA
        over this plan's apply/apply_adjoint instead of being dropped —
        kwargs explicitly passed at their default values are benign and do
        NOT forfeit fusion.  Every forfeit is logged (INFO) with the
        offending kwargs, and `LassoResult.fused` records which path ran,
        so benchmarks can't silently misattribute the slow path.
        """
        import jax.numpy as jnp

        from ..core import lasso as _lasso

        if gamma is None:
            gamma = _lasso.ista_step_size(self.op)
        if self.solve_lasso_fn is not None:
            # drop benign kwargs (== the generic-ISTA defaults); only
            # genuinely loop-changing kwargs forfeit the fused path
            benign = {"a0": None, "record_objective": False,
                      "soft_threshold_fn": _lasso.soft_threshold}
            blocking = {k: v for k, v in kwargs.items()
                        if not (k in benign and v is benign[k])}
            # per-vertex mu ((..., eta, N): trailing axis is N, not eta)
            # also runs the generic loop — the fused backends thresh on the
            # padded shard domain and take scalar/(eta,)/(..., eta) only
            mu_arr = jnp.asarray(mu)
            if mu_arr.ndim >= 2 and mu_arr.shape[-1] != self.op.eta:
                blocking["mu"] = f"per-vertex, shape {mu_arr.shape}"
            if not blocking:
                return self.solve_lasso_fn(y, mu, gamma, n_iters)
            logger.info(
                "solve_lasso[%s]: %s forfeit the fused in-shard_map "
                "ISTA; running the generic (unfused) loop",
                self.backend, sorted(blocking))
        return _lasso.distributed_lasso(self, y, mu=mu, gamma=gamma,
                                        n_iters=n_iters, **kwargs)


@dataclasses.dataclass(frozen=True)
class GraphOperator(UnionMultiplier):
    """Union of graph multiplier operators with pluggable execution.

    Construction computes the truncated shifted-Chebyshev coefficients once
    (Eq. (14)); `.plan(backend=...)` binds an execution strategy.  Uniform
    plan signatures across all backends (leading `...` = batch signals
    sharing the K communication rounds):

        plan.apply(f)          f: (..., N)      ->  (..., eta, N)
        plan.apply_adjoint(a)  a: (..., eta, N) ->  (..., N)
        plan.apply_gram(f)     f: (..., N)      ->  (..., N)
        plan.solve_lasso(y, mu, ...)            ->  LassoResult (batched)

    GraphOperator also keeps every UnionMultiplier method (`apply`,
    `exact_apply`, `error_bound`, ...), so it is a drop-in replacement —
    `op.apply(f)` is simply shorthand for `op.plan("dense").apply(f)`.
    """

    # `plan` is inherited from UnionMultiplier (defined there so legacy
    # UnionMultiplier instances route through the same registry); the
    # subclass exists to give the unified API its own name + docs and to
    # host future plan-level caching without touching the math core.


def as_graph_operator(op: UnionMultiplier) -> GraphOperator:
    """Re-wrap any UnionMultiplier as a GraphOperator (shares P, no copy)."""
    if isinstance(op, GraphOperator):
        return op
    return GraphOperator(P=op.P, multipliers=op.multipliers, lmax=op.lmax,
                         K=op.K, coeff_points=op.coeff_points)
