"""Continuous-batching serving engine over memoized ExecutionPlan callables.

Arriving filter/solve requests are admitted into per-:class:`CompatKey`
FIFO queues, coalesced into dynamic batches, padded to a fixed set of
compiled bucket sizes, dispatched onto the plan's memoized
``compiled()/compiled_solve()`` callables (one (B, N) launch — B signals
share one set of the paper's 2K|E| exchange rounds), and unpacked back to
per-request futures.  In the spirit of JetStream's slot-based engine API:
the accelerator only ever sees the fixed bucket signatures, the dynamic
part (who rides which batch) lives entirely on the host side of the
queue.

Scheduling policy (deterministic, single-threaded, clock-injected):

* **batch-full flush** — a key whose queue reaches the largest bucket
  dispatches immediately at :meth:`submit` time.
* **deadline flush** — :meth:`poll` dispatches every key whose OLDEST
  request has waited ``max_wait`` seconds; due keys go in
  oldest-request-first order and a flushed key drains completely (in
  largest-bucket chunks), so no admitted request ever waits more than
  ``max_wait`` past its arrival before dispatch — the starvation bound
  `tests/test_serving.py` asserts.
* **bucket choice** — smallest bucket >= group size; zero-padded slots
  are counted as ``padding_waste`` by the accounter.

Time comes exclusively from the injected :mod:`~repro.serve.clock`:
virtual in tests (every decision reproducible without sleeping), wall in
``benchmarks/bench_serving.py``.
"""
from __future__ import annotations

import itertools
import logging
from collections import OrderedDict, deque
from typing import Any, Deque, Dict, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .batching import bucket_for, pack_batch, unpack_batch
from .clock import WallClock
from .metrics import BatchRecord, LatencyAccounter
from .request import (CompatKey, Request, Response, ServeFuture, compat_key)

logger = logging.getLogger(__name__)

DEFAULT_BUCKETS = (1, 8, 64)


class _Group:
    """Per-CompatKey admission queue + the kwargs to rebuild its callable."""

    __slots__ = ("queue", "method", "solve_kwargs")

    def __init__(self, method: Optional[str],
                 solve_kwargs: Optional[Dict[str, Any]]):
        self.queue: Deque[Request] = deque()
        self.method = method
        self.solve_kwargs = dict(solve_kwargs or {})


class ServeEngine:
    """Coalesces compatible requests onto shared bucketed launches.

    plans: one :class:`~repro.dist.operator.ExecutionPlan` or a mapping
    ``{name: plan}`` (requests address operators by name; the default
    single-plan form registers under ``"default"``).  buckets: the
    compiled batch sizes (sorted, deduped).  max_wait: seconds a request
    may queue before a deadline flush.  clock: any ``now()`` provider
    (default :class:`WallClock`).  sync_results=True blocks on each
    dispatched batch so ``t_complete`` is an honest latency sample (the
    one deliberate host sync, at the queue boundary — allowlisted for
    RP-HOST-SYNC); False leaves results as in-flight jax arrays, which
    is the right mode under a virtual clock where execution time is
    modelled as zero anyway.

    Failure containment (every admitted request is answered exactly
    once, as a result or an error Response — see
    :class:`~repro.serve.request.Response`):

    * ``max_queue_depth`` bounds total admitted-but-undispatched
      requests; at the bound, :meth:`submit` returns a future already
      resolved with a ``"rejected"`` error Response (the
      `loadgen.RetryPolicy` backoff hook's trigger) instead of growing
      the queue without bound.
    * ``submit(..., deadline=d)`` gives one request d seconds (engine
      clock, from arrival) to dispatch; past it the request completes
      with an ``"expired"`` error Response — at the next :meth:`poll`
      sweep or at dispatch time, whichever comes first.
    * an exception inside one batch's compiled callable fails ONLY that
      batch: each rider completes with a ``"dispatch"`` error Response,
      the exception does not propagate out of submit()/poll(), and the
      engine keeps serving subsequent batches.
    """

    def __init__(self, plans, *, buckets=DEFAULT_BUCKETS,
                 max_wait: float = 0.005, clock=None,
                 sync_results: bool = True,
                 accounter: Optional[LatencyAccounter] = None,
                 max_queue_depth: Optional[int] = None):
        if not isinstance(plans, Mapping):
            plans = {"default": plans}
        if not plans:
            raise ValueError("ServeEngine needs at least one plan")
        self.plans = dict(plans)
        self.buckets = tuple(sorted({int(b) for b in buckets}))
        if not self.buckets or self.buckets[0] < 1:
            raise ValueError(
                f"buckets must be positive ints, got {buckets!r}")
        if max_wait < 0:
            raise ValueError(f"max_wait must be >= 0, got {max_wait}")
        self.max_wait = float(max_wait)
        if max_queue_depth is not None and int(max_queue_depth) < 1:
            raise ValueError(
                f"max_queue_depth must be >= 1, got {max_queue_depth}")
        self.max_queue_depth = (int(max_queue_depth)
                                if max_queue_depth is not None else None)
        self.clock = clock if clock is not None else WallClock()
        self.sync_results = bool(sync_results)
        self.metrics = accounter if accounter is not None \
            else LatencyAccounter()
        self._groups: "OrderedDict[CompatKey, _Group]" = OrderedDict()
        self._ids = itertools.count()

    # -- admission -----------------------------------------------------------
    def submit(self, signal, *, op: str = "default", kind: str = "apply",
               method: Optional[str] = None, deadline: Optional[float] = None,
               **solve_kwargs) -> ServeFuture:
        """Admit one request; returns its (cooperative) future.

        `signal` is ONE unbatched request — ``(N,)`` for
        apply/apply_gram/solve, ``(eta, N)`` for apply_adjoint; the batch
        axis belongs to the engine.  Compatible requests (same
        :func:`compat_key`) coalesce; a full largest bucket dispatches
        inline before returning.

        ``deadline`` (seconds from now, engine clock) bounds this
        request's queue wait — expired requests complete with an error
        Response.  At a full queue (``max_queue_depth``) the returned
        future is already resolved with a ``"rejected"`` error Response.
        """
        if op not in self.plans:
            raise KeyError(
                f"unknown operator {op!r}; registered: "
                f"{sorted(self.plans)}")
        if deadline is not None and deadline < 0:
            raise ValueError(f"deadline must be >= 0, got {deadline}")
        plan = self.plans[op]
        key = compat_key(op, plan, kind, method, solve_kwargs)
        signal = jnp.asarray(signal)
        self._validate_shape(plan, kind, signal)
        now = self.clock.now()
        rid = next(self._ids)
        future = ServeFuture(rid)
        if (self.max_queue_depth is not None
                and self.pending_count >= self.max_queue_depth):
            self.metrics.record_rejected(rid, now)
            future._resolve(Response(
                id=rid, key=key, value=None, t_arrival=now, t_dispatch=now,
                t_complete=now, bucket=0, occupancy=0,
                error=f"rejected: queue depth {self.pending_count} at "
                      f"max_queue_depth={self.max_queue_depth}"))
            logger.debug("serve reject %s: queue full", key.label())
            return future
        group = self._groups.get(key)
        if group is None:
            group = self._groups.setdefault(
                key, _Group(method, solve_kwargs))
        req = Request(id=rid, key=key, signal=signal, t_arrival=now,
                      future=future,
                      deadline=(now + deadline if deadline is not None
                                else None))
        self.metrics.record_arrival(req.id, now)
        group.queue.append(req)
        while len(group.queue) >= self.buckets[-1]:
            self._dispatch_chunk(key, group)
        return req.future

    def _validate_shape(self, plan, kind: str, signal) -> None:
        n = self._plan_n(plan)
        want_ndim = 2 if kind == "apply_adjoint" else 1
        if signal.ndim != want_ndim:
            raise ValueError(
                f"kind {kind!r} serves ONE unbatched request of rank "
                f"{want_ndim} (the engine owns the batch axis); got "
                f"shape {tuple(signal.shape)}")
        if n is not None and signal.shape[-1] != n:
            raise ValueError(
                f"signal has N={signal.shape[-1]}, plan expects N={n}")
        if kind == "apply_adjoint" and signal.shape[0] != plan.eta:
            raise ValueError(
                f"adjoint request must be (eta, N) = ({plan.eta}, {n}); "
                f"got {tuple(signal.shape)}")

    @staticmethod
    def _plan_n(plan) -> Optional[int]:
        if callable(plan.op.P):
            return None
        return int(np.asarray(plan.op.P).shape[0])

    # -- scheduling ----------------------------------------------------------
    @property
    def pending_count(self) -> int:
        return sum(len(g.queue) for g in self._groups.values())

    def next_deadline(self) -> Optional[float]:
        """Earliest instant any queued group becomes due (None if idle)."""
        heads = [g.queue[0].t_arrival for g in self._groups.values()
                 if g.queue]
        return min(heads) + self.max_wait if heads else None

    def _expire(self, req, now: float) -> None:
        """Answer one deadline-passed request with an error Response."""
        req.future._resolve(Response(
            id=req.id, key=req.key, value=None, t_arrival=req.t_arrival,
            t_dispatch=now, t_complete=now, bucket=0, occupancy=0,
            error=f"expired: deadline {req.deadline:.6f} passed at "
                  f"{now:.6f} before dispatch"))
        self.metrics.record_expired(req.id, now)
        logger.debug("serve expire request %d (%s)", req.id,
                     req.key.label())

    def _sweep_expired(self, now: float) -> int:
        """Resolve every queued request whose deadline has passed."""
        expired = 0
        for group in self._groups.values():
            if not group.queue:
                continue
            live = deque()
            dropped = 0
            for req in group.queue:
                if req.deadline is not None and now > req.deadline:
                    self._expire(req, now)
                    dropped += 1
                else:
                    live.append(req)
            if dropped:
                group.queue = live
                expired += dropped
        return expired

    def poll(self) -> int:
        """Deadline flush: dispatch every due group; returns #requests
        served.  Due groups drain oldest-request-first (FIFO fairness
        across keys), each in largest-bucket chunks.  Queued requests
        whose per-request deadline has passed are answered with an
        ``"expired"`` error Response first — they never ride a batch."""
        now = self.clock.now()
        self._sweep_expired(now)
        # dueness is `now >= arrival + max_wait` — the SAME float
        # expression next_deadline() returns, so advancing a virtual
        # clock exactly to a reported deadline always flushes it
        # ((now - arrival) >= max_wait can round the other way and
        # livelock the deadline-hopping drivers)
        due = [(g.queue[0].t_arrival, key) for key, g in
               self._groups.items()
               if g.queue and now >= g.queue[0].t_arrival + self.max_wait]
        served = 0
        for _, key in sorted(due, key=lambda p: p[0]):
            group = self._groups[key]
            while group.queue:
                served += self._dispatch_chunk(key, group)
        return served

    def flush(self) -> int:
        """Dispatch everything pending regardless of deadlines."""
        served = 0
        for key in list(self._groups):
            group = self._groups[key]
            while group.queue:
                served += self._dispatch_chunk(key, group)
        return served

    def run_until_idle(self, max_steps: int = 100_000) -> int:
        """Virtual-clock driver: hop the clock deadline-to-deadline until
        every admitted request is answered.  Requires a clock with
        ``advance_to`` (the virtual one); wall-clock loops call
        :meth:`poll` on their own cadence instead."""
        advance_to = getattr(self.clock, "advance_to", None)
        if advance_to is None:
            raise TypeError(
                "run_until_idle needs a clock with advance_to() (e.g. "
                "VirtualClock); wall-clock serving loops drive poll()")
        served = 0
        for _ in range(max_steps):
            deadline = self.next_deadline()
            if deadline is None:
                return served
            advance_to(deadline)
            served += self.poll()
        raise RuntimeError(
            f"run_until_idle did not drain in {max_steps} steps")

    # -- dispatch ------------------------------------------------------------
    def _callable(self, key: CompatKey, group: _Group):
        plan = self.plans[key.op]
        if key.kind == "solve":
            return plan.compiled_solve(group.method, **group.solve_kwargs)
        return plan.compiled(key.kind)

    def _dispatch_chunk(self, key: CompatKey, group: _Group) -> int:
        """Pack, launch and unpack the oldest largest-bucket-or-fewer
        requests of one group; resolves their futures.

        Deadline-passed riders are expired (error Response) instead of
        packed.  An exception from the compiled callable fails exactly
        this batch: every rider completes with a ``"dispatch"`` error
        Response and the exception is contained — submit()/poll() keep
        working and later batches (same group included) dispatch
        normally.  Returns the number of requests answered."""
        take = min(len(group.queue), self.buckets[-1])
        now = self.clock.now()
        reqs = []
        expired = 0
        for _ in range(take):
            req = group.queue.popleft()
            if req.deadline is not None and now > req.deadline:
                self._expire(req, now)
                expired += 1
            else:
                reqs.append(req)
        if not reqs:
            return expired
        bucket = bucket_for(len(reqs), self.buckets)
        batch, n_valid = pack_batch([r.signal for r in reqs], bucket)
        t_dispatch = now
        try:
            fn = self._callable(key, group)
            out = fn(batch)
            if self.sync_results:
                # The one deliberate host sync, at the queue boundary: a
                # batch's completion instant IS the latency sample every
                # response in it reports (allowlisted RP-HOST-SYNC).
                out = jax.block_until_ready(out)
            t_complete = self.clock.now()
            rows = unpack_batch(out, n_valid)
        except Exception as exc:  # noqa: BLE001 — contained by design
            t_complete = self.clock.now()
            msg = f"dispatch: {type(exc).__name__}: {exc}"
            logger.exception(
                "serve dispatch %s failed (bucket=%d, occupancy=%d); "
                "failing this batch's %d request(s), engine stays up",
                key.label(), bucket, n_valid, len(reqs))
            for req in reqs:
                req.future._resolve(Response(
                    id=req.id, key=key, value=None,
                    t_arrival=req.t_arrival, t_dispatch=t_dispatch,
                    t_complete=t_complete, bucket=bucket,
                    occupancy=n_valid, error=msg))
                self.metrics.record_failed(req.id, t_complete)
            return expired + len(reqs)
        for req, row in zip(reqs, rows):
            resp = Response(id=req.id, key=key, value=row,
                            t_arrival=req.t_arrival,
                            t_dispatch=t_dispatch,
                            t_complete=t_complete, bucket=bucket,
                            occupancy=n_valid)
            req.future._resolve(resp)
            self.metrics.record_served(req.id, t_dispatch, t_complete)
        self.metrics.record_batch(BatchRecord(
            key=key, bucket=bucket, occupancy=n_valid,
            t_dispatch=t_dispatch, t_complete=t_complete))
        logger.debug("serve dispatch %s: bucket=%d occupancy=%d",
                     key.label(), bucket, n_valid)
        return expired + n_valid

    # -- warmup --------------------------------------------------------------
    def warm(self) -> int:
        """Pre-trace/compile every (registered kind, bucket) signature of
        every plan so first requests are served at steady-state latency.
        Apply kinds only (solve signatures appear with their kwargs at
        first dispatch); returns the number of warmed entries."""
        n = 0
        for plan in self.plans.values():
            n += len(plan.bucketed_callables(self.buckets,
                                             kinds=("apply",), warm=True))
        return n
