"""repro.serve — continuous-batching serving over ExecutionPlan callables.

The production face of the paper's batch-amortization result: B requests
that share a compatibility key ride ONE padded (B, N) launch and hence
one set of 2K|E| Chebyshev exchange rounds, instead of B sets.

* :mod:`repro.serve.engine`   — :class:`ServeEngine`: per-key FIFO
  admission, batch-full/deadline flushing, bucket padding, dispatch onto
  the plan's memoized compiled callables, per-request futures.
* :mod:`repro.serve.request`  — :class:`CompatKey` /
  :func:`compat_key` (grouping = the `compiled_solve` memo key),
  :class:`Response`, :class:`ServeFuture`.
* :mod:`repro.serve.batching` — pad-to-bucket packing and its lossless
  inverse (:func:`pack_batch` / :func:`unpack_batch`,
  :func:`bucket_for`).
* :mod:`repro.serve.clock`    — injectable time (:class:`VirtualClock`
  for deterministic tests, :class:`WallClock` for production).
* :mod:`repro.serve.metrics`  — :class:`LatencyAccounter` (p50/p99,
  signals/sec, batch occupancy, padding waste).
* :mod:`repro.serve.loadgen`  — seeded Poisson/burst arrival streams +
  :func:`replay_virtual`.

Usage: API.md ("Serving"); request walk-through: docs/ARCHITECTURE.md.
"""
from .batching import bucket_for, pack_batch, unpack_batch
from .clock import VirtualClock, WallClock
from .engine import DEFAULT_BUCKETS, ServeEngine
from .loadgen import (ArrivalEvent, RetryPolicy, burst_arrivals,
                      poisson_arrivals, replay_virtual, signal_for)
from .metrics import BatchRecord, LatencyAccounter
from .request import (CompatKey, PendingError, RequestFailed, Response,
                      ServeFuture, compat_key)

__all__ = [
    "ArrivalEvent",
    "BatchRecord",
    "CompatKey",
    "DEFAULT_BUCKETS",
    "LatencyAccounter",
    "PendingError",
    "RequestFailed",
    "Response",
    "RetryPolicy",
    "ServeEngine",
    "ServeFuture",
    "VirtualClock",
    "WallClock",
    "bucket_for",
    "burst_arrivals",
    "compat_key",
    "pack_batch",
    "poisson_arrivals",
    "replay_virtual",
    "signal_for",
    "unpack_batch",
]
