"""Injectable time sources for the serving engine.

The scheduler never reads wall time directly — every timestamp comes from
a ``Clock`` passed at construction, so the same engine runs under:

* :class:`VirtualClock` — tests and discrete-event replays.  Time moves
  only when the driver calls :meth:`VirtualClock.advance` /
  :meth:`VirtualClock.advance_to`, so every scheduling decision (bucket
  choice, flush-on-timeout, starvation bound) is a pure function of the
  submitted arrival times: reproducible, assertable, and free of sleeps
  and timing flakes.
* :class:`WallClock` — production / ``benchmarks/bench_serving.py``.
  ``time.monotonic()`` so latency accounting survives NTP steps.

Anything with a ``now() -> float`` (seconds) method satisfies the
protocol; only virtual-style clocks need ``advance_to`` (required by
:meth:`repro.serve.engine.ServeEngine.run_until_idle`).
"""
from __future__ import annotations

import time


class VirtualClock:
    """Deterministic manually-advanced clock (seconds, monotonic)."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        """Move time forward by `dt` seconds; returns the new time."""
        if dt < 0:
            raise ValueError(f"cannot advance by negative dt {dt!r}")
        self._t += float(dt)
        return self._t

    def advance_to(self, t: float) -> float:
        """Move time forward to absolute `t` (no-op if already past it —
        the engine may ask for a deadline that batch-full dispatch
        already serviced)."""
        if t > self._t:
            self._t = float(t)
        return self._t

    def __repr__(self) -> str:  # pragma: no cover - debug sugar
        return f"VirtualClock(t={self._t:.6f})"


class WallClock:
    """Monotonic wall time for real serving loops and benchmarks."""

    def now(self) -> float:
        return time.monotonic()

    def __repr__(self) -> str:  # pragma: no cover - debug sugar
        return "WallClock()"
