"""Seeded arrival-stream generators + a deterministic replay driver.

Both generators return plain sorted lists of :class:`ArrivalEvent` — no
clock, no randomness at replay time — so the SAME stream can be replayed
against a :class:`~repro.serve.clock.VirtualClock` in tests (zero
wall-clock sleeps, bit-reproducible scheduling) and against a wall clock
in ``benchmarks/bench_serving.py`` (honest latency under offered load).
Per-request signals are derived from the event's own seed
(:func:`signal_for`), so a stream is fully described by
``(generator args, seed)``.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

#: A workload mix entry: (weight, kind, method, solve_kwargs).
MixEntry = Tuple[float, str, Optional[str], Dict[str, Any]]

#: Default mix: mostly filter applications, some Section-V solves —
#: exercises compatibility-key isolation under load.
DEFAULT_MIX: Sequence[MixEntry] = (
    (0.8, "apply", None, {}),
    (0.2, "solve", "jacobi", {"tau": 0.5, "n_iters": 8}),
)


@dataclasses.dataclass(frozen=True)
class ArrivalEvent:
    """One scheduled request: when it arrives and what it asks for."""

    t: float                    # seconds from stream start
    kind: str
    method: Optional[str]
    solve_kwargs: Tuple[Tuple[str, Any], ...]  # hashable kwargs items
    seed: int                   # per-request signal seed
    op: str = "default"

    def kwargs(self) -> Dict[str, Any]:
        return dict(self.solve_kwargs)


def _normalize_mix(mix: Optional[Sequence[MixEntry]]):
    mix = list(mix if mix is not None else DEFAULT_MIX)
    weights = np.asarray([m[0] for m in mix], np.float64)
    if not len(mix) or weights.sum() <= 0:
        raise ValueError("mix needs at least one positive-weight entry")
    return mix, weights / weights.sum()


def _events(times: np.ndarray, mix, probs, rng,
            op: str) -> List[ArrivalEvent]:
    events = []
    picks = rng.choice(len(mix), size=len(times), p=probs)
    seeds = rng.randint(0, 2**31 - 1, size=len(times))
    for t, pick, seed in zip(times, picks, seeds):
        _, kind, method, kwargs = mix[pick]
        events.append(ArrivalEvent(
            t=float(t), kind=kind, method=method,
            solve_kwargs=tuple(sorted(kwargs.items())), seed=int(seed),
            op=op))
    return events


def poisson_arrivals(rate: float, n_requests: int, seed: int = 0,
                     mix: Optional[Sequence[MixEntry]] = None,
                     op: str = "default") -> List[ArrivalEvent]:
    """`n_requests` Poisson arrivals at `rate` req/s (exponential gaps).

    Deterministic per ``(rate, n_requests, seed, mix)``; times start at
    the first gap (never 0.0), sorted ascending.
    """
    if rate <= 0:
        raise ValueError(f"rate must be > 0, got {rate}")
    mix, probs = _normalize_mix(mix)
    rng = np.random.RandomState(seed)
    times = np.cumsum(rng.exponential(1.0 / rate, size=n_requests))
    return _events(times, mix, probs, rng, op)


def burst_arrivals(n_bursts: int, burst_size: int, period: float,
                   seed: int = 0,
                   mix: Optional[Sequence[MixEntry]] = None,
                   op: str = "default") -> List[ArrivalEvent]:
    """`n_bursts` simultaneous bursts of `burst_size` requests, one
    burst every `period` seconds — the adversarial coalescing load (a
    full burst should ride one bucket)."""
    if period <= 0:
        raise ValueError(f"period must be > 0, got {period}")
    mix, probs = _normalize_mix(mix)
    rng = np.random.RandomState(seed)
    times = np.repeat(np.arange(n_bursts, dtype=np.float64) * period,
                      burst_size)
    return _events(times, mix, probs, rng, op)


def signal_for(event: ArrivalEvent, n: int,
               eta: Optional[int] = None) -> np.ndarray:
    """The event's deterministic request signal: ``(n,)`` float32 from
    its seed (``(eta, n)`` for adjoint-kind events)."""
    rng = np.random.RandomState(event.seed)
    shape = (eta, n) if event.kind == "apply_adjoint" else (n,)
    return rng.standard_normal(shape).astype(np.float32)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Retry/backoff hook for admission-rejected submissions.

    When the engine's bounded queue refuses a request ("rejected" error
    Response), :func:`replay_virtual` resubmits it up to `max_retries`
    times, waiting ``backoff * factor**attempt`` seconds before attempt
    `attempt + 1`.  Purely client-side: the engine itself never retries
    (exactly-once stays with the caller)."""

    max_retries: int = 3
    backoff: float = 0.002
    factor: float = 2.0

    def delay(self, attempt: int) -> float:
        """Seconds to wait after rejected attempt number `attempt`
        (0-based) before resubmitting."""
        return self.backoff * (self.factor ** attempt)


def replay_virtual(engine, events: Sequence[ArrivalEvent], n: int,
                   eta: Optional[int] = None,
                   deadline: Optional[float] = None,
                   retry: Optional[RetryPolicy] = None) -> Dict[int, Any]:
    """Replay a stream against a virtual-clock engine, deterministically.

    Advances the engine's clock event-to-event (flushing any deadlines
    that fall inside each hop), submits every event's seeded signal,
    drains with :meth:`run_until_idle`, and returns
    ``{event index: future}``.  Zero sleeps; identical streams produce
    identical scheduling decisions and metrics.

    `deadline` (relative seconds, applied to every submit) forwards to
    ``engine.submit(deadline=...)``.  `retry` enables the client-side
    backoff hook: an admission-rejected submit is re-queued at
    ``t + retry.delay(attempt)`` and the returned future for that event
    index is the LAST attempt's (so a stream can absorb transient
    queue-full windows without losing exactly-once accounting — every
    attempt is its own request id in the metrics).
    """
    heap = []
    for i, ev in enumerate(sorted(events, key=lambda e: e.t)):
        heap.append((ev.t, i, 0, ev))
    heapq.heapify(heap)
    futures: Dict[int, Any] = {}
    while heap:
        t, i, attempt, ev = heapq.heappop(heap)
        while True:
            due = engine.next_deadline()
            if due is None or due > t:
                break
            engine.clock.advance_to(due)
            engine.poll()
        engine.clock.advance_to(t)
        engine.poll()
        fut = engine.submit(
            signal_for(ev, n, eta), op=ev.op, kind=ev.kind,
            method=ev.method, deadline=deadline, **ev.kwargs())
        futures[i] = fut
        if (retry is not None and fut.done() and fut.response.rejected
                and attempt < retry.max_retries):
            heapq.heappush(
                heap, (t + retry.delay(attempt), i, attempt + 1, ev))
    engine.run_until_idle()
    return futures
