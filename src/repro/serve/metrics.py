"""Latency and batch-efficiency accounting for the serving engine.

Every timestamp the accounter sees comes from the engine's injected
clock, so under a :class:`repro.serve.clock.VirtualClock` the whole
summary — p50/p99 latency, signals/sec, batch occupancy, padding waste —
is a deterministic function of the arrival schedule.  The same schema is
what ``benchmarks/bench_serving.py`` writes into ``BENCH_serving.json``
(documented in API.md, "Serving").
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

from .request import CompatKey


@dataclasses.dataclass(frozen=True)
class BatchRecord:
    """One dispatched batch: which key, how full, how long."""

    key: CompatKey
    bucket: int
    occupancy: int          # real requests (the rest is zero padding)
    t_dispatch: float
    t_complete: float

    @property
    def padding(self) -> int:
        return self.bucket - self.occupancy


class LatencyAccounter:
    """Collects per-request and per-batch records; summarizes on demand.

    `record_served` / `record_failed` / `record_expired` enforce the
    exactly-once contract: every admitted request is *answered* exactly
    once — a result, a dispatch failure, or a deadline expiry; a second
    answer for the same id raises immediately (the bench's ``--check``
    gate also re-asserts it from the counts).  Admission rejections never
    enter the admitted set; they are counted separately.
    """

    def __init__(self):
        self._arrivals: Dict[int, float] = {}
        self._served: Dict[int, float] = {}
        self._failed: Dict[int, float] = {}
        self._expired: Dict[int, float] = {}
        self._rejected: Dict[int, float] = {}
        self._latencies: List[float] = []
        self._queue_delays: List[float] = []
        self.batches: List[BatchRecord] = []

    # -- recording (called by the engine) ----------------------------------
    def record_arrival(self, request_id: int, t: float) -> None:
        if request_id in self._arrivals:
            raise RuntimeError(f"request {request_id} submitted twice")
        self._arrivals[request_id] = t

    def _check_unanswered(self, request_id: int, what: str) -> None:
        if (request_id in self._served or request_id in self._failed
                or request_id in self._expired):
            raise RuntimeError(
                f"request {request_id} {what} after being answered — "
                "exactly-once violated")

    def record_served(self, request_id: int, t_dispatch: float,
                      t_complete: float) -> None:
        self._check_unanswered(request_id, "served")
        t_arr = self._arrivals[request_id]
        self._served[request_id] = t_complete
        self._latencies.append(t_complete - t_arr)
        self._queue_delays.append(t_dispatch - t_arr)

    def record_failed(self, request_id: int, t_complete: float) -> None:
        """A dispatch failure answered this request with an error
        Response; it counts toward exactly-once but not latency."""
        self._check_unanswered(request_id, "failed")
        self._failed[request_id] = t_complete

    def record_expired(self, request_id: int, t: float) -> None:
        """The request's deadline passed before dispatch."""
        self._check_unanswered(request_id, "expired")
        self._expired[request_id] = t

    def record_rejected(self, request_id: int, t: float) -> None:
        """Admission refused (full queue) — never entered the queue."""
        self._rejected[request_id] = t

    def record_batch(self, record: BatchRecord) -> None:
        self.batches.append(record)

    # -- views --------------------------------------------------------------
    @property
    def n_submitted(self) -> int:
        return len(self._arrivals)

    @property
    def n_served(self) -> int:
        return len(self._served)

    @property
    def n_failed(self) -> int:
        return len(self._failed)

    @property
    def n_expired(self) -> int:
        return len(self._expired)

    @property
    def n_rejected(self) -> int:
        return len(self._rejected)

    @property
    def n_pending(self) -> int:
        return (self.n_submitted - self.n_served - self.n_failed
                - self.n_expired)

    def summary(self) -> Dict[str, Any]:
        """The serving metrics schema (all times from the engine clock).

        latency_ms/queue_delay_ms: p50/p99/mean/max over served requests;
        signals_per_sec: served / (last completion - first arrival) — the
        *goodput* (error answers don't count); mean_batch_occupancy: mean
        real-requests-per-dispatch; padding_waste: padded rows /
        dispatched rows (0 = every slot did real work);
        served_exactly_once: every admitted id answered exactly once
        (result, failure, or expiry — no request lost, none answered
        twice); n_failed/n_expired/n_rejected: the error-outcome tallies.
        """
        lat = np.asarray(self._latencies, dtype=np.float64)
        qd = np.asarray(self._queue_delays, dtype=np.float64)
        occ = np.asarray([b.occupancy for b in self.batches], np.float64)
        buckets = np.asarray([b.bucket for b in self.batches], np.float64)
        span = 0.0
        if self._served:
            span = max(self._served.values()) - min(self._arrivals.values())
        total_rows = float(buckets.sum()) if len(buckets) else 0.0
        answered = (set(self._served) | set(self._failed)
                    | set(self._expired))
        return {
            "n_submitted": self.n_submitted,
            "n_served": self.n_served,
            "n_failed": self.n_failed,
            "n_expired": self.n_expired,
            "n_rejected": self.n_rejected,
            "served_exactly_once": (
                len(answered) == (self.n_served + self.n_failed
                                  + self.n_expired)
                and answered == set(self._arrivals)),
            "latency_ms": _dist_ms(lat),
            "queue_delay_ms": _dist_ms(qd),
            "span_s": span,
            "signals_per_sec": (self.n_served / span) if span > 0 else 0.0,
            "n_batches": len(self.batches),
            "mean_batch_occupancy": (
                float(occ.mean()) if len(occ) else 0.0),
            "padding_waste": (
                float((buckets - occ).sum() / total_rows)
                if total_rows else 0.0),
        }

    def per_key_counts(self) -> Dict[str, Dict[str, int]]:
        """{key label: {n_batches, n_requests}} — the isolation view."""
        out: Dict[str, Dict[str, int]] = {}
        for b in self.batches:
            d = out.setdefault(b.key.label(),
                               {"n_batches": 0, "n_requests": 0})
            d["n_batches"] += 1
            d["n_requests"] += b.occupancy
        return out


def _dist_ms(samples: np.ndarray) -> Dict[str, Optional[float]]:
    if not len(samples):
        return {"p50": None, "p99": None, "mean": None, "max": None}
    ms = samples * 1e3
    return {
        "p50": float(np.percentile(ms, 50)),
        "p99": float(np.percentile(ms, 99)),
        "mean": float(ms.mean()),
        "max": float(ms.max()),
    }
