"""Pad-to-bucket batch assembly and its exact inverse.

The engine compiles against a FIXED set of batch sizes (the buckets), so
a dynamic group of R compatible requests is stacked and zero-padded up to
the smallest bucket >= R (:func:`bucket_for`), dispatched once, and the
leading R rows of the result are handed back to their requests
(:func:`unpack_batch`).  Packing must be *lossless*: ``stack`` then
row-slice moves bits, never values, so
``unpack_batch(pack_batch(rows, B), len(rows))[i]`` is bitwise equal to
``rows[i]`` — the property `tests/test_property.py` pins.  Zero padding
is correct (not merely harmless) because every served operation is
linear in the signal and the padded rows are discarded before anyone
reads them.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax.numpy as jnp


def bucket_for(n_pending: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= `n_pending`; the largest bucket if none is.

    `buckets` must be sorted ascending (the engine normalizes at
    construction).  Oversized groups are the caller's problem — the
    engine chunks a group to the largest bucket before asking.
    """
    if n_pending < 1:
        raise ValueError(f"n_pending must be >= 1, got {n_pending}")
    for b in buckets:
        if b >= n_pending:
            return int(b)
    return int(buckets[-1])


def pack_batch(rows: Sequence, bucket: int) -> Tuple[jnp.ndarray, int]:
    """Stack equal-shaped `rows` and zero-pad the batch axis to `bucket`.

    Returns ``(batch, n_valid)`` with ``batch.shape == (bucket, *row)``.
    """
    n_valid = len(rows)
    if n_valid == 0:
        raise ValueError("pack_batch needs at least one row")
    if n_valid > bucket:
        raise ValueError(
            f"{n_valid} rows exceed bucket {bucket} — chunk before "
            "packing")
    batch = jnp.stack([jnp.asarray(r) for r in rows])
    pad = bucket - n_valid
    if pad:
        batch = jnp.concatenate(
            [batch, jnp.zeros((pad,) + batch.shape[1:], batch.dtype)])
    return batch, n_valid


def unpack_batch(out, n_valid: int) -> List:
    """The first `n_valid` rows of a batched result, in pack order."""
    return [out[i] for i in range(n_valid)]
