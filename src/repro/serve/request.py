"""Request/response datatypes and the batching compatibility key.

Two requests may share one padded batch — and therefore one set of the
paper's 2K|E| exchange rounds — only when they would trace to the *same*
compiled program.  :func:`compat_key` captures that as a frozen
:class:`CompatKey` over ``(operator, kind, method, K/n_iters, tau)`` plus
the remaining solver kwargs, canonicalized by the SAME function the
`ExecutionPlan.compiled_solve` memo key uses
(:func:`repro.dist.operator.canonical_solve_items`), so "compatible"
in the queue and "one compiled entry" in the plan cache can never drift
apart.  A jacobi solve never rides a chebyshev apply batch because their
keys differ in `kind`/`method`; two jacobi solves at different `tau`
differ in `tau`; same story for `n_iters`, `vmem_budget`, array-valued
kwargs, everything.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

from ..dist.operator import canonical_solve_items

#: Plan kinds the engine serves.  "solve" additionally needs a method.
APPLY_KINDS = ("apply", "apply_adjoint", "apply_gram")
KINDS = APPLY_KINDS + ("solve",)


@dataclasses.dataclass(frozen=True)
class CompatKey:
    """Batching compatibility: requests coalesce iff their keys are equal.

    op: name of the ExecutionPlan in the engine's registry;
    kind: one of :data:`KINDS`; method: Section-V solver method (None for
    the apply kinds); order: the shared round count — the plan's K for
    apply kinds, n_iters (or the plan's K default) for solves; tau: the
    rational-filter sugar (None when not passed); extra: the remaining
    solver kwargs as `canonical_solve_items` tuples.
    """

    op: str
    kind: str
    method: Optional[str] = None
    order: int = 0
    tau: Optional[float] = None
    #: Halo-exchange wire precision of the plan ("f32" | "bf16" | "int8").
    #: Mixed-precision requests must never coalesce with f32 ones — they
    #: trace to different programs AND answer with different accuracy.
    exchange: str = "f32"
    #: Partition identity: "banded" for the ring plans, the
    #: GeneralPartition content fingerprint otherwise.  Plans sharded by
    #: different partitions trace to different exchange programs and must
    #: never coalesce.
    partition: str = "banded"
    #: Fault-injection identity (`repro.dist.faults.fault_key`): "none"
    #: for clean plans.  A fault-injected plan traces a different program
    #: AND answers with degraded accuracy, so its requests must never
    #: coalesce with (or share compiled entries with) clean ones.
    faults: str = "none"
    extra: Tuple[Tuple[str, Any], ...] = ()

    def label(self) -> str:
        """Compact human-readable form for metrics/log output."""
        parts = [self.op, self.kind]
        if self.method:
            parts.append(self.method)
        parts.append(f"order={self.order}")
        if self.exchange != "f32":
            parts.append(f"exchange={self.exchange}")
        if self.partition != "banded":
            parts.append(f"partition={self.partition}")
        if self.faults != "none":
            parts.append(f"faults={self.faults}")
        if self.tau is not None:
            parts.append(f"tau={self.tau}")
        parts += [f"{k}={v}" for k, v in self.extra]
        return ":".join(parts)


def _plan_partition(plan) -> str:
    """Partition identity for the compat key: the GeneralPartition content
    fingerprint when present, else the plan's partition family name."""
    return str(plan.info.get("partition_fingerprint")
               or plan.info.get("partition", "banded"))


def compat_key(op_name: str, plan, kind: str, method: Optional[str],
               solve_kwargs: Optional[Dict[str, Any]] = None) -> CompatKey:
    """Build the :class:`CompatKey` for one request against `plan`.

    Validation lives here so `ServeEngine.submit` rejects malformed
    requests at admission (unknown kind, solve without a method, method
    on a non-solve kind, `history=` which has no per-request unpacking).
    """
    if kind not in KINDS:
        raise ValueError(f"unknown kind {kind!r}; available: {KINDS}")
    kwargs = dict(solve_kwargs or {})
    if kind != "solve":
        if method is not None or kwargs:
            raise ValueError(
                f"kind {kind!r} takes no method/solver kwargs "
                f"(got method={method!r}, kwargs={sorted(kwargs)})")
        return CompatKey(op=op_name, kind=kind, order=int(plan.K),
                         exchange=plan.info.get("exchange_dtype", "f32"),
                         partition=_plan_partition(plan),
                         faults=plan.info.get("fault_key", "none"))
    if method is None:
        raise ValueError("kind='solve' requires method=")
    if kwargs.get("history"):
        raise ValueError(
            "history=True is not servable: iterate histories have no "
            "per-request unpacking — call plan.solve directly")
    order = kwargs.get("n_iters")
    order = int(order) if order is not None else int(plan.K)
    tau = kwargs.get("tau")
    tau = float(tau) if tau is not None else None
    extra = canonical_solve_items(
        {k: v for k, v in kwargs.items() if k not in ("n_iters", "tau")})
    return CompatKey(op=op_name, kind=kind, method=method, order=order,
                     tau=tau, extra=extra,
                     exchange=plan.info.get("exchange_dtype", "f32"),
                     partition=_plan_partition(plan),
                     faults=plan.info.get("fault_key", "none"))


@dataclasses.dataclass(frozen=True)
class Response:
    """One answered request: the unpacked result row + its timeline.

    Every admitted request completes with exactly one Response — either a
    result (``error is None``) or an error outcome: ``"rejected: ..."``
    (admission refused at a full queue), ``"expired: ..."`` (per-request
    deadline passed before dispatch) or ``"dispatch: ..."`` (the batch's
    compiled callable raised; only that batch fails, the engine stays
    serviceable).  ``value`` is None on error responses.
    """

    id: int
    key: CompatKey
    value: Any                 # jax array, the request's row of the batch
    t_arrival: float
    t_dispatch: float
    t_complete: float
    bucket: int                # padded batch size it rode
    occupancy: int             # real requests in that batch
    error: Optional[str] = None

    @property
    def ok(self) -> bool:
        return self.error is None

    @property
    def rejected(self) -> bool:
        """Admission-rejected (the retry/backoff hook's trigger)."""
        return self.error is not None and self.error.startswith("rejected")

    @property
    def latency(self) -> float:
        return self.t_complete - self.t_arrival

    @property
    def queue_delay(self) -> float:
        return self.t_dispatch - self.t_arrival


class PendingError(RuntimeError):
    """`ServeFuture.result()` before the engine dispatched the batch."""


class RequestFailed(RuntimeError):
    """`ServeFuture.result()` on a request that completed with an error
    Response (rejected / expired / dispatch failure).  The full error
    Response stays readable via `ServeFuture.response`."""


class ServeFuture:
    """Single-threaded future resolved by the engine's dispatch.

    The engine is cooperative (no threads): a pending future never
    blocks — drive the engine (`poll` / `run_until_idle` / `flush`)
    until :meth:`done`, then read :meth:`result`.
    """

    __slots__ = ("request_id", "_response")

    def __init__(self, request_id: int):
        self.request_id = request_id
        self._response: Optional[Response] = None

    def done(self) -> bool:
        return self._response is not None

    def _resolve(self, response: Response) -> None:
        if self._response is not None:
            raise RuntimeError(
                f"request {self.request_id} resolved twice — a batch "
                "unpacking bug (each request must be answered exactly "
                "once)")
        self._response = response

    @property
    def response(self) -> Response:
        if self._response is None:
            raise PendingError(
                f"request {self.request_id} is still queued; drive the "
                "engine (poll()/run_until_idle()/flush()) before reading")
        return self._response

    def result(self) -> Any:
        resp = self.response
        if resp.error is not None:
            raise RequestFailed(
                f"request {self.request_id} failed: {resp.error}")
        return resp.value


@dataclasses.dataclass
class Request:
    """Internal queue entry (one submit).

    `deadline` is the ABSOLUTE completion deadline (engine-clock seconds;
    None = wait forever): a request still queued past it completes with
    an ``"expired"`` error Response instead of riding a batch."""

    id: int
    key: CompatKey
    signal: Any
    t_arrival: float
    future: ServeFuture
    deadline: Optional[float] = None
