"""Reusable jaxpr visitor: one walker for every trace-level analysis.

`repro.dist.commstats` started this idiom (PR 2) with a private recursive
walk that tallied collectives and multiplied `scan` trip counts.  Every
jaxpr-level invariant check needs the same traversal — nested jaxprs in
eqn params (pjit / scan / while / shard_map / custom_* bodies), loop
multiplicity, and the execution context an equation sits in — so this
module extracts it as a visitor:

    closed = jax.make_jaxpr(plan.apply)(x_spec)
    def visit(eqn, ctx):
        if eqn.primitive.name == "ppermute":
            ...ctx.mult, ctx.in_while, ctx.axis_sizes...
    walk_jaxpr(closed, visit)

:class:`EqnContext` carries what the traversal knows at each equation:

  * ``mult`` — static trip multiplier: an eqn inside a ``scan`` of length
    L executes L times per application (nested scans multiply);
  * ``in_while`` — whether any enclosing jaxpr is a ``while`` body/cond,
    whose trip count is *unknown at trace time* (checks that need exact
    counts must treat anything here as uncountable — see
    `commstats.measure`, which now refuses to undercount collectives
    found there);
  * ``axis_sizes`` — mesh axis name -> size, collected from enclosing
    ``shard_map`` equations (what the ppermute-bijection check needs to
    decide whether a permutation covers the whole axis);
  * ``path`` — the enclosing primitive names, outermost first (for
    diagnostics).

`commstats.measure` is rebased on this walker; the invariant checks in
:mod:`repro.analysis.checks` are its other consumers.  Keep the walker
purely structural — rule logic lives with the rules.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterable, List, Mapping, Tuple

import jax

#: Collective primitives the communication analyses care about (moved here
#: from `dist.commstats`, which re-exports it for compatibility).
COLLECTIVE_PRIMITIVES = frozenset({
    "ppermute",
    "pgather",
    "all_gather",
    "all_to_all",
    "psum",
    "reduce_scatter",
})


@dataclasses.dataclass(frozen=True)
class EqnContext:
    """Traversal context for one visited equation (see module docstring)."""

    mult: int = 1
    in_while: bool = False
    path: Tuple[str, ...] = ()
    axis_sizes: Mapping[str, int] = dataclasses.field(default_factory=dict)

    def axis_size(self, axis_name) -> int:
        """Product size of a ppermute/all_gather ``axis_name`` param (a
        name or tuple of names); 0 when any axis is unknown here."""
        names = axis_name if isinstance(axis_name, (tuple, list)) \
            else (axis_name,)
        size = 1
        for a in names:
            if a not in self.axis_sizes:
                return 0
            size *= int(self.axis_sizes[a])
        return size


def subjaxprs(value: Any) -> Iterable[Any]:
    """Yield every Jaxpr reachable from one eqn param value."""
    if isinstance(value, jax.core.Jaxpr):
        yield value
    elif isinstance(value, jax.core.ClosedJaxpr):
        yield value.jaxpr
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from subjaxprs(v)


def _child_context(eqn, ctx: EqnContext) -> EqnContext:
    name = eqn.primitive.name
    mult = ctx.mult
    if name == "scan":
        mult *= int(eqn.params.get("length", 1))
    axis_sizes = ctx.axis_sizes
    if name == "shard_map":
        shape = getattr(eqn.params.get("mesh"), "shape", None)
        if shape:
            axis_sizes = {**dict(axis_sizes), **dict(shape)}
    return EqnContext(
        mult=mult,
        in_while=ctx.in_while or name == "while",
        path=ctx.path + (name,),
        axis_sizes=axis_sizes,
    )


def walk_jaxpr(jaxpr, visit: Callable[[Any, EqnContext], None],
               ctx: EqnContext = None) -> None:
    """Depth-first walk calling ``visit(eqn, ctx)`` on every equation.

    `jaxpr` may be a `Jaxpr` or `ClosedJaxpr`.  Equations are visited in
    trace order at each nesting level, parents before their sub-jaxpr
    bodies — so a flat list of visited collectives *is* the static
    collective schedule (what the batch-invariance check compares).
    """
    if isinstance(jaxpr, jax.core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    if ctx is None:
        ctx = EqnContext()
    for eqn in jaxpr.eqns:
        visit(eqn, ctx)
        sub_ctx = _child_context(eqn, ctx)
        for value in eqn.params.values():
            for sub in subjaxprs(value):
                walk_jaxpr(sub, visit, sub_ctx)


def collect_eqns(jaxpr, primitives=None) -> List[Tuple[Any, EqnContext]]:
    """All (eqn, ctx) pairs, optionally filtered to a primitive-name set."""
    out: List[Tuple[Any, EqnContext]] = []

    def visit(eqn, ctx):
        if primitives is None or eqn.primitive.name in primitives:
            out.append((eqn, ctx))

    walk_jaxpr(jaxpr, visit)
    return out


def eqn_payload(eqn) -> Tuple[int, int]:
    """(elems, bytes) moved by one execution of a collective eqn."""
    import numpy as np

    elems = 0
    nbytes = 0
    for var in eqn.invars:
        aval = getattr(var, "aval", None)
        shape = getattr(aval, "shape", None)
        dtype = getattr(aval, "dtype", None)
        if shape is None or dtype is None:
            continue
        n = int(np.prod(shape)) if len(shape) else 1
        elems += n
        nbytes += n * np.dtype(dtype).itemsize
    return elems, nbytes


def source_location(eqn) -> Tuple[str, int]:
    """(file, line) of the user code that traced `eqn`, best effort.

    Uses jax's source-info tracking (private API, so failures degrade to
    ``("", 0)`` rather than breaking a check)."""
    try:
        from jax._src import source_info_util

        frame = source_info_util.user_frame(eqn.source_info)
        if frame is not None:
            return str(frame.file_name), int(frame.start_line)
    except Exception:
        pass
    return "", 0
