"""Findings and the allowlist: the reporting substrate of `repro.analysis`.

Every static check — the jaxpr invariant checkers in
:mod:`repro.analysis.checks` and the AST lint rules in
:mod:`repro.analysis.astlint` — reports :class:`Finding`s: one rule
violation at one location (``file:line`` where the layer can resolve it,
the traced plan method otherwise), carrying a stable rule ID so CI output
is grep-able and the allowlist can pin exceptions to rules.

The :class:`Allowlist` is the *audit trail* for known violations: each
entry names a rule, a location (path glob, optionally ``::symbol`` for the
enclosing function), and a mandatory one-line justification — entries
without a justification are a parse error, so nothing gets silenced
without a recorded reason.  The same file carries the ``[scaffold]``
section: the dormant LM-scaffolding modules (``models/``, the LLM config
presets, ``kernels/flash_attention.py``, the ``launch/`` driver) that the
``RP-LEGACY-SCAFFOLD`` rule fences off from the graph-filter hot path,
each with its audit note.  `tools/lint_allowlist.txt` is the repo's
instance; `tools/lint_repro.py --check` is the CLI that applies it.

File format (stdlib-parsed, comments with ``#``)::

    [scaffold]
    src/repro/models/* -- LM scaffold; not imported by the hot path
    [allow]
    RP-FALLBACK-LOG src/repro/kernels/ops.py::fused_cheb_sweep -- K<2 ...
"""
from __future__ import annotations

import dataclasses
import fnmatch
import os
from typing import Iterable, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one location.

    path: repo-relative file for AST findings; for jaxpr findings the
    source file of the offending equation when jax's source info resolves,
    else the traced-target label.  symbol: enclosing function (AST layer)
    or the traced plan method (jaxpr layer) — what allowlist entries pin
    to, so line drift does not invalidate them.
    """

    rule: str
    path: str
    message: str
    line: int = 0
    symbol: str = ""

    @property
    def location(self) -> str:
        return f"{self.path}:{self.line}" if self.line else self.path

    def __str__(self) -> str:
        sym = f" ({self.symbol})" if self.symbol else ""
        return f"{self.location} [{self.rule}]{sym}: {self.message}"


@dataclasses.dataclass(frozen=True)
class AllowEntry:
    """One allowlisted (rule, location) with its mandatory justification."""

    rule: str
    path_glob: str
    symbol: Optional[str]
    justification: str

    def matches(self, finding: Finding) -> bool:
        if self.rule != "*" and self.rule != finding.rule:
            return False
        path = finding.path.replace(os.sep, "/")
        if not (fnmatch.fnmatch(path, self.path_glob)
                or fnmatch.fnmatch(os.path.basename(path), self.path_glob)):
            return False
        if self.symbol and self.symbol != finding.symbol:
            return False
        return True


@dataclasses.dataclass(frozen=True)
class ScaffoldEntry:
    """One audited legacy-scaffold module (glob) with its justification."""

    path_glob: str
    justification: str


class AllowlistError(ValueError):
    """Malformed allowlist file (e.g. an entry without a justification)."""


@dataclasses.dataclass
class Allowlist:
    """Parsed allowlist: suppression entries + the scaffold audit."""

    entries: List[AllowEntry] = dataclasses.field(default_factory=list)
    scaffold: List[ScaffoldEntry] = dataclasses.field(default_factory=list)
    path: str = ""

    @classmethod
    def load(cls, path: str) -> "Allowlist":
        entries: List[AllowEntry] = []
        scaffold: List[ScaffoldEntry] = []
        section = "allow"
        with open(path, encoding="utf-8") as fh:
            for lineno, raw in enumerate(fh, 1):
                line = raw.split("#", 1)[0].strip() if not raw.lstrip() \
                    .startswith("#") else ""
                if raw.lstrip().startswith("#") or not line:
                    continue
                if line.startswith("[") and line.endswith("]"):
                    section = line[1:-1].strip().lower()
                    if section not in ("allow", "scaffold"):
                        raise AllowlistError(
                            f"{path}:{lineno}: unknown section [{section}]")
                    continue
                if " -- " not in line:
                    raise AllowlistError(
                        f"{path}:{lineno}: entry needs a ' -- justification'"
                        f" (got {line!r}) — every exception is audited")
                spec, justification = line.split(" -- ", 1)
                justification = justification.strip()
                if not justification:
                    raise AllowlistError(
                        f"{path}:{lineno}: empty justification")
                if section == "scaffold":
                    scaffold.append(ScaffoldEntry(spec.strip(), justification))
                    continue
                parts = spec.split(None, 1)
                if len(parts) != 2:
                    raise AllowlistError(
                        f"{path}:{lineno}: allow entry is 'RULE path[::symbol]"
                        f" -- justification' (got {line!r})")
                rule, loc = parts
                symbol = None
                if "::" in loc:
                    loc, symbol = loc.split("::", 1)
                entries.append(AllowEntry(rule.strip(), loc.strip(), symbol,
                                          justification))
        return cls(entries=entries, scaffold=scaffold, path=path)

    @property
    def scaffold_globs(self) -> Tuple[str, ...]:
        return tuple(e.path_glob for e in self.scaffold)

    def split(self, findings: Iterable[Finding]
              ) -> Tuple[List[Finding], List[Finding]]:
        """(kept, suppressed) — kept are the violations that still fail."""
        kept: List[Finding] = []
        suppressed: List[Finding] = []
        for f in findings:
            (suppressed if any(e.matches(f) for e in self.entries)
             else kept).append(f)
        return kept, suppressed

    def unused_entries(self, findings: Sequence[Finding]) -> List[AllowEntry]:
        """Allow entries that matched nothing — stale audit records that
        should be pruned (reported as warnings, not failures)."""
        return [e for e in self.entries
                if not any(e.matches(f) for f in findings)]
