"""Repo-specific AST lint rules over `src/repro` (analysis Layer 2).

Grown from the `tools/check_docs.py` idiom — stdlib-only static passes
that encode decisions this repo already made, so they stop regressing
silently:

* ``RP-DENSE-MAT`` — no dense materialization on library paths: calls to
  ``eigh`` (the O(N^3)/O(N^2)-memory eigendecomposition the paper exists
  to avoid) or ``block_ell_to_dense`` belong only in `kernels/ref.py` and
  explicitly allowlisted oracle paths (the spectral-bound oracle in
  `core/multiplier.py`).
* ``RP-ORDER-LOOP`` — no Python-level loop over Chebyshev orders
  (``for ... in range(.. K ..)``) outside `kernels/ref.py`: the order
  recurrence must run inside `lax.scan`/the fused sweep kernel, or it
  unrolls into K copies of the matvec at trace time (the exact failure
  PR 5's single-launch sweep removed).
* ``RP-HOST-SYNC`` — no ``device_get`` / ``block_until_ready`` in library
  code: host syncs belong to benchmarks and tests, never inside plan
  methods where they serialize the dispatch pipeline.
* ``RP-FALLBACK-LOG`` — every dispatch fallback logs before taking the
  slow path: an ``if`` branch that calls a fallback implementation
  (``_per_order_*``, ``_fallback*``, ``*_recurrence_loop``, ``ref.*`` /
  ``*_ref``, the generic ``distributed_lasso`` loop) must also emit a
  ``logger.info``/``logger.warning`` in that same branch, so benchmarks
  can't silently misattribute the slow path (the repo-wide policy PR 4/5
  established one call site at a time).
* ``RP-LEGACY-SCAFFOLD`` — the dormant LM-scaffolding modules (the
  ``[scaffold]`` section of `tools/lint_allowlist.txt`: `models/`, the
  LLM config presets, `kernels/flash_attention.py`, the `launch/`
  driver) must not be imported from hot-path library code.  Scaffold
  modules may import each other freely.

Findings carry file:line + the enclosing function as ``symbol``, so
allowlist entries pin to ``path::function`` and survive line drift.
`lint_tree` walks a source root; `tools/lint_repro.py --check` is the
entry point that applies `tools/lint_allowlist.txt`.
"""
from __future__ import annotations

import ast
import fnmatch
import os
import re
from typing import Iterable, List, Optional, Sequence, Tuple

from .findings import Finding

#: Rule IDs of the AST layer (catalogued in ARCHITECTURE.md).
AST_RULES = (
    "RP-DENSE-MAT",
    "RP-ORDER-LOOP",
    "RP-HOST-SYNC",
    "RP-FALLBACK-LOG",
    "RP-LEGACY-SCAFFOLD",
)

#: Files where the dense/order-loop reference idioms are the point.
REF_PATHS = ("src/repro/kernels/ref.py",)

_DENSE_CALLS = {"eigh", "block_ell_to_dense"}
_HOST_SYNC_CALLS = {"device_get", "block_until_ready"}
_FALLBACK_NAME = re.compile(
    r"(^_per_order_|^_fallback|_recurrence_loop$|^distributed_lasso$|_ref$)")
_LOG_METHODS = {"info", "warning"}


def _norm(path: str) -> str:
    return path.replace(os.sep, "/")


def _call_name(node: ast.Call) -> Tuple[str, Optional[str]]:
    """(terminal name, attribute base dotted-or-None) of a call target."""
    f = node.func
    if isinstance(f, ast.Name):
        return f.id, None
    if isinstance(f, ast.Attribute):
        base = f.value
        parts = []
        while isinstance(base, ast.Attribute):
            parts.append(base.attr)
            base = base.value
        if isinstance(base, ast.Name):
            parts.append(base.id)
        return f.attr, ".".join(reversed(parts)) or None
    return "", None


def _is_scaffold(relpath: str, scaffold_globs: Sequence[str]) -> bool:
    p = _norm(relpath)
    return any(fnmatch.fnmatch(p, g) for g in scaffold_globs)


def _resolve_import(module: Optional[str], level: int, alias: str,
                    file_relpath: str, src_root: str) -> Optional[str]:
    """Repo-relative path a (possibly relative) import resolves to.

    Handles ``import a.b``, ``from a.b import c`` and relative forms
    (``from . import ops``, ``from .flash_attention import f``); returns
    the module file (or package ``__init__.py``) path relative to the
    repo root, or None when the target is not a file under `src_root`
    (external package, or a symbol rather than a submodule).
    """
    if level:
        base = os.path.dirname(file_relpath)
        for _ in range(level - 1):
            base = os.path.dirname(base)
        parts = [base] + (module.split(".") if module else [])
    else:
        if not module:
            parts = []
        else:
            parts = [src_root] + module.split(".")
    for candidate_parts in ([*parts, alias] if alias else [], parts):
        if not candidate_parts:
            continue
        stem = os.path.join(*candidate_parts)
        for suffix in (".py", os.path.join("", "__init__.py")):
            p = stem + suffix if suffix == ".py" \
                else os.path.join(stem, "__init__.py")
            if os.path.isfile(p):
                return _norm(p)
    return None


class _Visitor(ast.NodeVisitor):
    """Single-pass rule visitor with an enclosing-function stack."""

    def __init__(self, relpath: str, src_root: str,
                 scaffold_globs: Sequence[str]):
        self.relpath = _norm(relpath)
        self.src_root = src_root
        self.scaffold_globs = tuple(scaffold_globs)
        self.is_ref = self.relpath in REF_PATHS
        self.is_scaffold = _is_scaffold(self.relpath, scaffold_globs)
        self.findings: List[Finding] = []
        self._funcs: List[str] = []

    # -- bookkeeping --------------------------------------------------------
    @property
    def symbol(self) -> str:
        return self._funcs[-1] if self._funcs else ""

    def _add(self, rule: str, node: ast.AST, message: str) -> None:
        self.findings.append(Finding(
            rule=rule, path=self.relpath, line=getattr(node, "lineno", 0),
            symbol=self.symbol, message=message))

    def visit_FunctionDef(self, node):
        self._funcs.append(node.name)
        self.generic_visit(node)
        self._funcs.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    # -- RP-LEGACY-SCAFFOLD -------------------------------------------------
    def _check_import(self, node, module: Optional[str], level: int,
                      alias: str) -> None:
        if self.is_scaffold:
            return
        target = _resolve_import(module, level, alias, self.relpath,
                                 self.src_root)
        if target and _is_scaffold(target, self.scaffold_globs):
            self._add("RP-LEGACY-SCAFFOLD", node,
                      f"imports audited legacy scaffold `{target}` from "
                      "non-scaffold library code — the scaffold modules "
                      "are fenced off the graph-filter hot path")

    def visit_Import(self, node: ast.Import):
        for a in node.names:
            self._check_import(node, a.name, 0, "")
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom):
        for a in node.names:
            self._check_import(node, node.module, node.level, a.name)
        self.generic_visit(node)

    # -- RP-DENSE-MAT / RP-HOST-SYNC ---------------------------------------
    def visit_Call(self, node: ast.Call):
        name, _base = _call_name(node)
        if name in _DENSE_CALLS and not self.is_ref:
            self._add("RP-DENSE-MAT", node,
                      f"dense materialization `{name}(...)` outside "
                      "kernels/ref.py — O(N^2) memory defeats the "
                      "distributed Chebyshev path")
        if name in _HOST_SYNC_CALLS:
            self._add("RP-HOST-SYNC", node,
                      f"host sync `{name}(...)` in library code — "
                      "serializes the dispatch pipeline; belongs in "
                      "benchmarks/tests only")
        self.generic_visit(node)

    # -- RP-ORDER-LOOP ------------------------------------------------------
    def visit_For(self, node: ast.For):
        if not self.is_ref and isinstance(node.iter, ast.Call):
            name, _ = _call_name(node.iter)
            if name == "range" and any(
                    isinstance(n, ast.Name) and n.id == "K"
                    for a in node.iter.args for n in ast.walk(a)):
                self._add("RP-ORDER-LOOP", node,
                          "Python loop over Chebyshev orders (range over "
                          "K) outside kernels/ref.py — unrolls K matvecs "
                          "at trace time; use lax.scan or the fused "
                          "sweep kernel")
        self.generic_visit(node)

    # -- RP-FALLBACK-LOG ----------------------------------------------------
    @staticmethod
    def _suite_fallback_calls(stmts: Iterable[ast.stmt]) -> List[ast.Call]:
        calls = []
        for stmt in stmts:
            for n in ast.walk(stmt):
                if not isinstance(n, ast.Call):
                    continue
                name, base = _call_name(n)
                if _FALLBACK_NAME.search(name) or base == "ref":
                    calls.append(n)
        return calls

    @staticmethod
    def _has_log(stmts: Iterable[ast.stmt]) -> bool:
        for stmt in stmts:
            for n in ast.walk(stmt):
                if isinstance(n, ast.Call):
                    name, base = _call_name(n)
                    if name in _LOG_METHODS and base \
                            and base.split(".")[-1] in ("logger", "logging"):
                        return True
        return False

    def visit_If(self, node: ast.If):
        if not self.is_ref:
            for suite in (node.body, node.orelse):
                # an `elif` arm is a nested If in orelse; it gets its own
                # visit, so skip the wrapper suite to avoid double counts
                if len(suite) == 1 and isinstance(suite[0], ast.If):
                    continue
                calls = self._suite_fallback_calls(suite)
                if calls and not self._has_log(suite):
                    name, _ = _call_name(calls[0])
                    self._add("RP-FALLBACK-LOG", calls[0],
                              f"dispatch branch takes fallback `{name}` "
                              "without a logger.info/logger.warning in "
                              "the branch — slow paths must announce "
                              "themselves")
        self.generic_visit(node)


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------
def lint_source(source: str, relpath: str, src_root: str = "src",
                scaffold_globs: Sequence[str] = ()) -> List[Finding]:
    """Lint one module's source text (fixture-friendly entry point).

    Scaffold modules (matching `scaffold_globs`) are skipped wholesale:
    they are dormant, audited legacy code — the rule that concerns them is
    ``RP-LEGACY-SCAFFOLD`` *in their importers*, not their own internals.
    """
    if _is_scaffold(relpath, scaffold_globs):
        return []
    tree = ast.parse(source, filename=relpath)
    visitor = _Visitor(relpath, src_root, scaffold_globs)
    visitor.visit(tree)
    return visitor.findings


def lint_file(path: str, src_root: str = "src",
              scaffold_globs: Sequence[str] = ()) -> List[Finding]:
    with open(path, encoding="utf-8") as fh:
        return lint_source(fh.read(), _norm(path), src_root, scaffold_globs)


def lint_tree(root: str = "src/repro", src_root: str = "src",
              scaffold_globs: Sequence[str] = ()) -> List[Finding]:
    """Lint every ``.py`` under `root`, sorted for stable CI output."""
    findings: List[Finding] = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames.sort()
        for fname in sorted(filenames):
            if fname.endswith(".py"):
                findings += lint_file(os.path.join(dirpath, fname),
                                      src_root, scaffold_globs)
    return findings
