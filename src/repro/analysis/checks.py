"""Jaxpr-level invariant checks over traced plan methods (analysis Layer 1).

Three families, each guarding an invariant PRs 1–5 established but until
now only re-verified where a test author remembered to assert it:

* **Comm-schedule safety** (:func:`check_comm_schedule`) —
  ``JX-PPERMUTE-BIJECTION``: every ``ppermute`` permutation is a complete
  bijection on its mesh axis.  A partial or colliding permutation is a
  latent deadlock / silent-zero: on real interconnects every device must
  both send and receive exactly once per exchange, and jax zero-fills
  devices nobody sends to — either way the 2K|E| accounting breaks.
  ``JX-COLLECTIVE-IN-WHILE``: no collective may sit under ``while_loop``,
  whose trip count is unknown at trace time — the static schedule (and
  `commstats.measure`, which now raises on this) cannot count it.
* **Batch invariance** (:func:`collective_schedule` compared across batch
  sizes; ``JX-BATCH-SCHEDULE``) — the (..., N) contract promises B signals
  share the K exchange rounds.  Statically: the *ordered* collective
  schedule (primitive, axis, permutation, trip multiplier) traced at B=1
  must equal the one traced at B=64.  Payload shapes legitimately scale
  with B and are excluded.
* **Fault-injection honesty** (:func:`check_fault_schedule`;
  ``JX-FAULT-NO-EXTRA-COLLECTIVES``) — a fault-injected plan
  (``fault_spec=`` on the sharded backends, :mod:`repro.dist.faults`)
  must trace the *identical* ordered collective schedule as its clean
  twin: faults are receiver-side value substitutions after the
  ``ppermute``, never extra rounds, retries, or control flow around the
  collective — so `commstats` keeps measuring exactly the paper's 2K|E|
  messages under every injected configuration.
* **VMEM budget** (:func:`check_vmem_budget`; ``JX-VMEM-BUDGET``) — every
  ``pallas_call`` in the trace has its block + scratch footprint
  recomputed from its BlockSpecs and asserted under the PR-5 sweep budget
  (`repro.kernels.ops.DEFAULT_SWEEP_VMEM_BUDGET` unless overridden), so
  no future kernel ships an unguarded launch.
* **Dtype discipline** (:func:`check_dtype_discipline`) —
  ``JX-DTYPE-F64``: no f64 values appear on hot paths (an accidental
  ``astype(float64)`` doubles every halo payload and falls off the fast
  unit paths); ``JX-DTYPE-PROMOTION``: no op silently mixes real floating
  widths (e.g. a bf16 constant meeting f32 state promotes the whole
  recurrence).  Complex dtypes are exempt — the ARMA solver mixes
  complex64 poles with f32 signals by design.  ``JX-DTYPE-MIXED-OK``:
  the sanctioned-site carve-out for PROMOTION — :data:`DTYPE_MIXED_OK`
  names the source paths where mixing widths is intentional (the
  mixed-precision sweep kernels), with the justification recorded as
  rule metadata instead of `tools/lint_allowlist.txt` entries.

:func:`check_plan` bundles all of the above for one `ExecutionPlan`;
`tools/lint_repro.py` runs it across every registered backend.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from .findings import Finding
from .jaxpr_walk import (COLLECTIVE_PRIMITIVES, EqnContext, collect_eqns,
                         source_location, walk_jaxpr)

#: Rule IDs of the jaxpr layer (catalogued in ARCHITECTURE.md).
JAXPR_RULES = (
    "JX-PPERMUTE-BIJECTION",
    "JX-COLLECTIVE-IN-WHILE",
    "JX-BATCH-SCHEDULE",
    "JX-VMEM-BUDGET",
    "JX-DTYPE-F64",
    "JX-DTYPE-PROMOTION",
    "JX-DTYPE-MIXED-OK",
    "JX-FAULT-NO-EXTRA-COLLECTIVES",
)

#: Sanctioned mixed-float-width sites (rule ``JX-DTYPE-MIXED-OK``): source
#: paths where ``JX-DTYPE-PROMOTION`` findings are suppressed because the
#: width mix is the *point* of the code, with the justification recorded
#: here instead of as opaque `tools/lint_allowlist.txt` entries.  Each
#: entry is ``(path fragment, why)``; a PROMOTION finding whose source
#: location contains the fragment is dropped (when ``mixed_ok=True``).
#: Keep this list tight — every fragment is a hole in the lint.
DTYPE_MIXED_OK = (
    ("repro/kernels/cheb_sweep.py",
     "mixed-precision sweep kernels: bf16 blocks/iterate scratch feed an "
     "f32 coefficient table and f32 accumulator (scratch_dtype='bf16', "
     "preferred_element_type=f32) — the pallas_call operands legitimately "
     "span two widths"),
)


def _finding(rule: str, eqn, label: str, message: str) -> Finding:
    path, line = source_location(eqn)
    return Finding(rule=rule, path=path or label, line=line, symbol=label,
                   message=message)


# ---------------------------------------------------------------------------
# Comm-schedule safety
# ---------------------------------------------------------------------------
def perm_problems(perm: Sequence[Tuple[int, int]],
                  axis_size: int) -> List[str]:
    """Why `perm` is not a complete bijection on a size-`axis_size` axis.

    Returns [] for a deadlock-free permutation: every device sends exactly
    once, receives exactly once, and all indices are on-axis.  This is the
    pure core of ``JX-PPERMUTE-BIJECTION`` — unit-testable without a mesh.
    """
    problems: List[str] = []
    pairs = [(int(s), int(d)) for s, d in perm]
    srcs = [s for s, _ in pairs]
    dsts = [d for _, d in pairs]
    off = [i for i in srcs + dsts if not 0 <= i < axis_size]
    if off:
        problems.append(f"indices {sorted(set(off))} outside axis of size "
                        f"{axis_size}")
    if len(set(srcs)) != len(srcs):
        dup = sorted({s for s in srcs if srcs.count(s) > 1})
        problems.append(f"devices {dup} send more than once")
    if len(set(dsts)) != len(dsts):
        dup = sorted({d for d in dsts if dsts.count(d) > 1})
        problems.append(f"devices {dup} receive more than once")
    missing_src = sorted(set(range(axis_size)) - set(srcs))
    missing_dst = sorted(set(range(axis_size)) - set(dsts))
    if missing_src:
        problems.append(f"devices {missing_src} never send")
    if missing_dst:
        problems.append(f"devices {missing_dst} never receive "
                        "(jax zero-fills them; a real interconnect "
                        "deadlocks)")
    return problems


def check_comm_schedule(fn: Callable, *example_args,
                        label: str = "fn") -> List[Finding]:
    """JX-PPERMUTE-BIJECTION + JX-COLLECTIVE-IN-WHILE over a traced `fn`."""
    closed = jax.make_jaxpr(fn)(*example_args)
    findings: List[Finding] = []
    for eqn, ctx in collect_eqns(closed, COLLECTIVE_PRIMITIVES):
        name = eqn.primitive.name
        if ctx.in_while:
            findings.append(_finding(
                "JX-COLLECTIVE-IN-WHILE", eqn, label,
                f"`{name}` under a while_loop (path {'/'.join(ctx.path)}): "
                "trip count is unknown at trace time, so the collective "
                "schedule cannot be statically verified or counted"))
        if name != "ppermute":
            continue
        perm = eqn.params.get("perm")
        axis = eqn.params.get("axis_name")
        size = ctx.axis_size(axis)
        if perm is None or not size:
            # unknown mesh axis (traced outside shard_map) — nothing to
            # verify statically; the 1-shard guards make this legitimate
            continue
        problems = perm_problems(perm, size)
        if problems:
            findings.append(_finding(
                "JX-PPERMUTE-BIJECTION", eqn, label,
                f"ppermute perm={list(perm)} on axis {axis!r} (size {size}) "
                f"is not a complete bijection: " + "; ".join(problems)))
    return findings


# ---------------------------------------------------------------------------
# Batch invariance (static collective schedule)
# ---------------------------------------------------------------------------
def collective_schedule(fn: Callable, *example_args) -> Tuple[Tuple, ...]:
    """The ordered static collective schedule of a traced `fn`.

    Each entry is (primitive, axis_name, perm, trip-multiplier) — the
    structure of the communication, with payload shapes deliberately
    excluded (they scale with batch size; the *schedule* must not).
    """
    closed = jax.make_jaxpr(fn)(*example_args)
    sched: List[Tuple] = []
    for eqn, ctx in collect_eqns(closed, COLLECTIVE_PRIMITIVES):
        perm = eqn.params.get("perm")
        sched.append((
            eqn.primitive.name,
            repr(eqn.params.get("axis_name")),
            tuple((int(s), int(d)) for s, d in perm) if perm else None,
            ctx.mult,
        ))
    return tuple(sched)


def check_batch_schedule(fn_for_batch: Callable[[int], Tuple[Callable, tuple]],
                         batches: Sequence[int] = (1, 64),
                         label: str = "fn") -> List[Finding]:
    """JX-BATCH-SCHEDULE: schedules at every batch size must be identical.

    `fn_for_batch(B)` returns ``(fn, example_args)`` for batch size B.
    """
    ref_b = batches[0]
    fn, args = fn_for_batch(ref_b)
    ref = collective_schedule(fn, *args)
    findings: List[Finding] = []
    for b in batches[1:]:
        fn, args = fn_for_batch(b)
        sched = collective_schedule(fn, *args)
        if sched != ref:
            findings.append(Finding(
                rule="JX-BATCH-SCHEDULE", path=label, symbol=label,
                message=(
                    f"collective schedule at B={b} differs from B={ref_b} "
                    f"({len(sched)} vs {len(ref)} entries): the batched "
                    "path re-runs or re-orders the exchange rounds instead "
                    "of sharing them across the batch")))
    return findings


# ---------------------------------------------------------------------------
# Fault-injection honesty
# ---------------------------------------------------------------------------
def check_fault_schedule(clean_plan, faulted_plan,
                         n: Optional[int] = None,
                         solve_methods: Sequence[str] = ()) -> List[Finding]:
    """JX-FAULT-NO-EXTRA-COLLECTIVES: faulted == clean collective schedule.

    Traces apply / apply_adjoint / apply_gram (plus ``plan.solve`` for
    each of `solve_methods`) on both plans and requires the ordered
    static collective schedules (:func:`collective_schedule` — primitive,
    axis, permutation, trip multiplier; payload shapes excluded) to be
    identical.  Any difference means the fault injection touched the
    exchange *structure* instead of only the received values, which
    breaks the 2K|E| accounting contract of `repro.dist.faults`.
    """
    op = clean_plan.op
    if n is None:
        if callable(op.P):
            raise ValueError("check_fault_schedule needs n= for a closure P")
        n = int(np.asarray(op.P).shape[0])
    fkey = faulted_plan.info.get("fault_key", "none")
    findings: List[Finding] = []

    def spec(*shape) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(shape, np.float32)

    targets: List[Tuple[str, Callable, Callable, tuple]] = [
        ("apply", clean_plan.apply, faulted_plan.apply, (spec(n),)),
        ("apply_adjoint", clean_plan.apply_adjoint,
         faulted_plan.apply_adjoint, (spec(op.eta, n),)),
        ("apply_gram", clean_plan.apply_gram, faulted_plan.apply_gram,
         (spec(n),)),
    ]
    for method in solve_methods:
        def _solve(plan, _m=method):
            return lambda y: plan.solve(y, _m, tau=0.5).x

        targets.append((f"solve[{method}]", _solve(clean_plan),
                        _solve(faulted_plan), (spec(n),)))

    for name, clean_fn, faulted_fn, args in targets:
        label = f"{faulted_plan.backend}.{name}"
        ref = collective_schedule(clean_fn, *args)
        sched = collective_schedule(faulted_fn, *args)
        if sched != ref:
            findings.append(Finding(
                rule="JX-FAULT-NO-EXTRA-COLLECTIVES", path=label,
                symbol=label,
                message=(
                    f"fault-injected plan ({fkey}) traces a different "
                    f"collective schedule than the clean plan "
                    f"({len(sched)} vs {len(ref)} entries): faults must "
                    "be receiver-side value substitutions after the "
                    "ppermute, never extra rounds or reordered exchanges "
                    "— the 2K|E| accounting depends on it")))
    return findings


# ---------------------------------------------------------------------------
# VMEM budget
# ---------------------------------------------------------------------------
def _block_bytes(block_shape, dtype) -> int:
    n = 1
    for d in block_shape:
        if isinstance(d, (int, np.integer)):
            n *= int(d)
        # pallas Mapped/Squeezed dims contribute 1 element
    return n * np.dtype(dtype).itemsize


def pallas_footprint(eqn) -> Dict[str, int]:
    """Recomputed VMEM footprint of one ``pallas_call`` equation.

    Sums the per-grid-step block bytes of every operand/output BlockSpec
    plus all scratch allocations — the resident VMEM one grid step needs,
    the same model as `repro.kernels.ops.cheb_sweep_vmem_bytes` but
    recovered from the *traced* GridMapping rather than the launch
    parameters, so it audits what was actually staged.
    """
    gm = eqn.params["grid_mapping"]
    block = 0
    for bm in gm.block_mappings:
        sds = bm.array_shape_dtype
        block += _block_bytes(bm.block_shape, sds.dtype)
    scratch = 0
    kernel_jaxpr = eqn.params.get("jaxpr")
    n_scratch = int(getattr(gm, "num_scratch_operands", 0) or 0)
    if kernel_jaxpr is not None and n_scratch:
        for var in kernel_jaxpr.invars[-n_scratch:]:
            aval = var.aval
            inner = getattr(aval, "inner_aval", aval)
            shape = getattr(inner, "shape", None)
            dtype = getattr(inner, "dtype", None)
            if shape is not None and dtype is not None:
                scratch += _block_bytes(shape, dtype)
    return {"block_bytes": block, "scratch_bytes": scratch,
            "total_bytes": block + scratch}


def check_vmem_budget(fn: Callable, *example_args,
                      budget: Optional[int] = None,
                      label: str = "fn") -> List[Finding]:
    """JX-VMEM-BUDGET: every traced pallas_call fits the sweep budget."""
    if budget is None:
        from ..kernels import ops as _ops
        budget = _ops.DEFAULT_SWEEP_VMEM_BUDGET
    closed = jax.make_jaxpr(fn)(*example_args)
    findings: List[Finding] = []
    for eqn, _ctx in collect_eqns(closed, {"pallas_call"}):
        fp = pallas_footprint(eqn)
        if fp["total_bytes"] > budget:
            findings.append(_finding(
                "JX-VMEM-BUDGET", eqn, label,
                f"pallas_call footprint {fp['total_bytes']} B "
                f"(blocks {fp['block_bytes']} + scratch "
                f"{fp['scratch_bytes']}) exceeds the sweep VMEM budget "
                f"{budget} B — the launch must shrink its tile or fall "
                "back (see ops.fused_cheb_sweep's budget guard)"))
    return findings


# ---------------------------------------------------------------------------
# Dtype discipline
# ---------------------------------------------------------------------------
def _float_dtypes(vars_) -> List[np.dtype]:
    import jax.numpy as jnp

    out = []
    for v in vars_:
        dt = getattr(getattr(v, "aval", None), "dtype", None)
        if dt is None:
            continue
        dt = np.dtype(dt)
        # jnp.issubdtype, not np.: the ml_dtypes floats (bfloat16, fp8)
        # are exactly the ones implicit promotion bites
        if jnp.issubdtype(dt, jnp.floating):
            out.append(dt)
    return out


def _mixed_ok_site(eqn) -> bool:
    """True when `eqn`'s source location is a :data:`DTYPE_MIXED_OK` site."""
    path, _line = source_location(eqn)
    if not path:
        return False
    return any(frag in path for frag, _why in DTYPE_MIXED_OK)


def check_dtype_discipline(fn: Callable, *example_args,
                           label: str = "fn",
                           mixed_ok: bool = True) -> List[Finding]:
    """JX-DTYPE-F64 + JX-DTYPE-PROMOTION over a traced `fn` (see module
    docstring for rule semantics; complex dtypes are exempt by design).

    ``mixed_ok=True`` (default) silently drops PROMOTION findings whose
    source location is a sanctioned :data:`DTYPE_MIXED_OK` site — the
    carve-out is metadata here, not an allowlist entry, so the
    justification travels with the rule.  Pass ``mixed_ok=False`` to see
    the raw findings (used by the carve-out's own tests).
    """
    closed = jax.make_jaxpr(fn)(*example_args)
    findings: List[Finding] = []

    def visit(eqn, ctx: EqnContext):
        in_f = _float_dtypes(eqn.invars)
        out_f = _float_dtypes(eqn.outvars)
        if any(d == np.float64 for d in out_f) \
                and not all(d == np.float64 for d in in_f):
            findings.append(_finding(
                "JX-DTYPE-F64", eqn, label,
                f"`{eqn.primitive.name}` upcasts to float64 on a hot path "
                f"(inputs {[str(d) for d in in_f]}): doubles every halo "
                "payload and leaves the f32 unit paths"))
        if eqn.primitive.name != "convert_element_type" \
                and len({d.itemsize for d in in_f}) > 1:
            if mixed_ok and _mixed_ok_site(eqn):
                return
            findings.append(_finding(
                "JX-DTYPE-PROMOTION", eqn, label,
                f"`{eqn.primitive.name}` mixes real floating widths "
                f"{sorted({str(d) for d in in_f})}: implicit promotion — "
                "cast explicitly so the recurrence dtype is intentional"))

    walk_jaxpr(closed, visit)
    return findings


# ---------------------------------------------------------------------------
# Plan-level bundle
# ---------------------------------------------------------------------------
def check_plan(plan, n: Optional[int] = None,
               batches: Sequence[int] = (1, 64),
               budget: Optional[int] = None,
               solve_methods: Sequence[str] = ()) -> List[Finding]:
    """Run every jaxpr check over one `ExecutionPlan`.

    Traces apply / apply_adjoint / apply_gram (unbatched (N,) signatures
    for the safety/VMEM/dtype checks; (B, N) for each B in `batches` for
    the schedule-equality check) and optionally ``plan.solve`` for each of
    `solve_methods`.  Findings carry ``symbol = "<backend>.<method>"`` so
    allowlist entries can pin to a traced target.
    """
    op = plan.op
    if n is None:
        if callable(op.P):
            raise ValueError("check_plan needs n= for a closure P")
        n = int(np.asarray(op.P).shape[0])
    findings: List[Finding] = []

    def spec(lead: tuple, *trailing) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(lead + trailing, np.float32)

    targets: List[Tuple[str, Callable, Callable[[tuple], tuple]]] = [
        ("apply", plan.apply, lambda lead: (spec(lead, n),)),
        ("apply_adjoint", plan.apply_adjoint,
         lambda lead: (spec(lead, op.eta, n),)),
        ("apply_gram", plan.apply_gram, lambda lead: (spec(lead, n),)),
    ]
    for method in solve_methods:
        def _solve(y, _m=method):
            return plan.solve(y, _m, tau=0.5).x

        targets.append((f"solve[{method}]", _solve,
                        lambda lead: (spec(lead, n),)))

    for name, fn, args_for in targets:
        label = f"{plan.backend}.{name}"
        args = args_for(())
        findings += check_comm_schedule(fn, *args, label=label)
        findings += check_vmem_budget(fn, *args, budget=budget, label=label)
        findings += check_dtype_discipline(fn, *args, label=label)
        findings += check_batch_schedule(
            lambda b, _fn=fn, _af=args_for: (_fn, _af((b,))),
            batches=batches, label=label)
    return findings
