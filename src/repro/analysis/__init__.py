"""Static-analysis subsystem: jaxpr invariant checks + repo AST lint.

Two layers guard the invariants PRs 1–5 accumulated (the 2K|E| comm
schedule, VMEM-budgeted sweep launches, batch-invariant collective
schedules, f32 hot paths, logged fallbacks, fenced-off legacy scaffold):

* :mod:`repro.analysis.checks` — trace-level checks over plan methods,
  built on the reusable jaxpr visitor in :mod:`repro.analysis.jaxpr_walk`
  (the walker `dist.commstats` is rebased on).  Rule IDs ``JX-*``.
* :mod:`repro.analysis.astlint` — stdlib AST lint over `src/repro`.
  Rule IDs ``RP-*``.

Findings (:class:`Finding`) carry file:line, a stable rule ID and the
enclosing symbol; :class:`Allowlist` (`tools/lint_allowlist.txt`) records
every tolerated violation with a mandatory justification.  The CLI entry
point is ``tools/lint_repro.py --check`` (CI's `lint` job); the rule
catalogue lives in ARCHITECTURE.md ("Static invariants").
"""
from .astlint import AST_RULES, lint_file, lint_source, lint_tree
from .checks import (DTYPE_MIXED_OK, JAXPR_RULES, check_batch_schedule,
                     check_comm_schedule, check_dtype_discipline,
                     check_fault_schedule, check_plan, check_vmem_budget,
                     collective_schedule, pallas_footprint, perm_problems)
from .findings import (AllowEntry, Allowlist, AllowlistError, Finding,
                       ScaffoldEntry)
from .jaxpr_walk import (COLLECTIVE_PRIMITIVES, EqnContext, collect_eqns,
                         eqn_payload, source_location, subjaxprs, walk_jaxpr)

ALL_RULES = JAXPR_RULES + AST_RULES

__all__ = [
    "ALL_RULES",
    "AST_RULES",
    "AllowEntry",
    "Allowlist",
    "AllowlistError",
    "COLLECTIVE_PRIMITIVES",
    "DTYPE_MIXED_OK",
    "EqnContext",
    "Finding",
    "JAXPR_RULES",
    "ScaffoldEntry",
    "check_batch_schedule",
    "check_comm_schedule",
    "check_dtype_discipline",
    "check_fault_schedule",
    "check_plan",
    "check_vmem_budget",
    "collect_eqns",
    "collective_schedule",
    "eqn_payload",
    "lint_file",
    "lint_source",
    "lint_tree",
    "pallas_footprint",
    "perm_problems",
    "source_location",
    "subjaxprs",
    "walk_jaxpr",
]
