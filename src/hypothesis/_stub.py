"""Stub implementation of the hypothesis API subset (see package docstring)."""
from __future__ import annotations

import functools
import inspect
import itertools
import random
from typing import Any, Callable, Dict

from . import strategies

__all__ = ["given", "settings", "strategies", "HealthCheck", "example"]

__version__ = "0.0.0-repro-stub"

_DEFAULT_MAX_EXAMPLES = 25


class HealthCheck:
    """No-op placeholder mirroring hypothesis.HealthCheck members."""

    all = classmethod(lambda cls: [])
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"


def settings(**kwargs) -> Callable:
    """Decorator recording run settings (max_examples, deadline, ...)."""

    def deco(fn):
        merged = dict(getattr(fn, "_stub_settings", {}))
        merged.update(kwargs)
        fn._stub_settings = merged
        return fn

    return deco


def example(*args, **kwargs) -> Callable:
    """Pin an explicit example (run before the random ones)."""

    def deco(fn):
        fn._stub_examples = getattr(fn, "_stub_examples", []) + [(args, kwargs)]
        return fn

    return deco


def given(*given_args, **given_kwargs) -> Callable:
    """Run the wrapped test over sampled strategy draws.

    Mirrors hypothesis' keyword usage: ``@given(x=st.integers(0, 5))``.
    Positional strategies are matched against the test signature in order.
    """

    def deco(fn):
        sig = inspect.signature(fn)
        names = [n for n in sig.parameters if n != "self"]
        kw = dict(given_kwargs)
        for name, strat in zip(names, given_args):
            kw.setdefault(name, strat)

        @functools.wraps(fn)
        def wrapper(*call_args, **call_kwargs):
            cfg: Dict[str, Any] = getattr(wrapper, "_stub_settings", {})
            n_examples = int(cfg.get("max_examples", _DEFAULT_MAX_EXAMPLES))
            rng = random.Random(f"repro-stub:{fn.__module__}.{fn.__qualname__}")
            for eargs, ekwargs in getattr(wrapper, "_stub_examples", []):
                fn(*call_args, *eargs, **call_kwargs, **ekwargs)
            boundary = _boundary_draws(kw)
            for i in range(n_examples):
                if i < len(boundary):
                    draw = boundary[i]
                else:
                    draw = {name: strat.sample(rng) for name, strat in kw.items()}
                fn(*call_args, **call_kwargs, **draw)

        # Hide the strategy-filled parameters from pytest's fixture
        # resolution (real hypothesis rewrites the signature the same way).
        remaining = [p for n, p in sig.parameters.items() if n not in kw]
        wrapper.__signature__ = sig.replace(parameters=remaining)
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        return wrapper

    return deco


def _boundary_draws(kw: Dict[str, "strategies.SearchStrategy"]):
    """First draws: per-strategy boundary values, combined positionally."""
    per_name = {n: s.boundary() for n, s in kw.items()}
    width = max((len(v) for v in per_name.values()), default=0)
    draws = []
    for i in range(width):
        rng = random.Random(f"repro-stub-boundary:{i}")
        draws.append({
            n: (vals[i] if i < len(vals) else kw[n].sample(rng))
            for n, vals in per_name.items()
        })
    return draws
