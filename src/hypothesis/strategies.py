"""Strategy objects for the vendored hypothesis stub (see package docstring)."""
from __future__ import annotations

import random
from typing import Any, Callable, List, Sequence


class SearchStrategy:
    """A sampleable value source: `sample(rng)` draws one value."""

    def __init__(self, sample: Callable[[random.Random], Any],
                 boundary: Sequence[Any] = ()):
        self._sample = sample
        self._boundary = list(boundary)

    def sample(self, rng: random.Random) -> Any:
        return self._sample(rng)

    def boundary(self) -> List[Any]:
        return list(self._boundary)

    def map(self, fn: Callable) -> "SearchStrategy":
        return SearchStrategy(lambda rng: fn(self._sample(rng)),
                              [fn(b) for b in self._boundary])

    def filter(self, pred: Callable) -> "SearchStrategy":
        def draw(rng):
            for _ in range(1000):
                v = self._sample(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate rejected 1000 draws")

        return SearchStrategy(draw, [b for b in self._boundary if pred(b)])


def integers(min_value: int = -(2**31), max_value: int = 2**31 - 1):
    return SearchStrategy(lambda rng: rng.randint(min_value, max_value),
                          [min_value, max_value])


def floats(min_value: float = 0.0, max_value: float = 1.0, **_ignored):
    return SearchStrategy(lambda rng: rng.uniform(min_value, max_value),
                          [min_value, max_value])


def booleans():
    return SearchStrategy(lambda rng: bool(rng.getrandbits(1)), [False, True])


def sampled_from(elements: Sequence):
    elements = list(elements)
    return SearchStrategy(lambda rng: rng.choice(elements),
                          [elements[0], elements[-1]])


def lists(elements: SearchStrategy, min_size: int = 0, max_size: int = 10):
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elements.sample(rng) for _ in range(n)]

    rng0 = random.Random("repro-stub-lists")
    return SearchStrategy(
        draw, [[elements.sample(rng0) for _ in range(max(min_size, 1))]])


def permutations(values: Sequence):
    """Random permutation of `values` (mirrors hypothesis'
    st.permutations).  Boundary draws: the identity ordering and the full
    reversal — the two extremes of arrival-order shuffling the serving
    coalescing tests exercise."""
    values = list(values)
    return SearchStrategy(lambda rng: rng.sample(values, len(values)),
                          [list(values), list(reversed(values))])


def just(value):
    return SearchStrategy(lambda rng: value, [value])


def one_of(*strategies: SearchStrategy):
    return SearchStrategy(lambda rng: rng.choice(strategies).sample(rng),
                          [s.boundary()[0] for s in strategies if s.boundary()])


def tuples(*strategies: SearchStrategy):
    return SearchStrategy(
        lambda rng: tuple(s.sample(rng) for s in strategies),
        [tuple(s.boundary()[0] if s.boundary() else None for s in strategies)])
