"""Vendored fallback for the `hypothesis` property-testing API.

The hermetic CI container does not ship hypothesis and nothing may be pip
installed into it, so this package provides the small subset the repo's
property tests use (see `_stub.py`): ``@given`` with ``strategies``
(integers, floats, lists, sampled_from, booleans) and
``@settings(max_examples=..., deadline=...)``.

Because the repo's standard workflow puts ``src/`` on PYTHONPATH (which
precedes site-packages), this package would otherwise shadow a genuinely
installed hypothesis.  To avoid silently downgrading coverage, import time
first looks for a real hypothesis elsewhere on ``sys.path`` and, when
found, loads it *in place of* this stub (the real module takes over the
``hypothesis`` name in ``sys.modules``).  Only when no real installation
exists does the stub activate.

Stub semantics: deterministic pseudo-random sampling (seeded per test
name), no shrinking, no database.  Each strategy's endpoints are exercised
first so boundary cases are covered before random interior samples.
"""
import importlib.machinery as _machinery
import importlib.util as _util
import os as _os
import sys as _sys


def _find_real_spec():
    """Spec for a real hypothesis anywhere on sys.path except this one."""
    here = _os.path.realpath(_os.path.dirname(_os.path.abspath(__file__)))
    src_dir = _os.path.dirname(here)
    paths = []
    for p in _sys.path:
        try:
            ap = _os.path.realpath(_os.path.abspath(p or _os.getcwd()))
        except (OSError, ValueError):  # pragma: no cover
            continue
        if ap != src_dir:
            paths.append(p)
    try:
        spec = _machinery.PathFinder.find_spec("hypothesis", paths)
    except Exception:  # pragma: no cover
        return None
    if spec is None or not spec.origin:
        return None
    if _os.path.realpath(_os.path.dirname(spec.origin)) == here:
        return None  # found ourselves through a second path spelling
    return spec


_real_spec = _find_real_spec()
if _real_spec is not None:
    _mod = _util.module_from_spec(_real_spec)
    _sys.modules["hypothesis"] = _mod  # real package takes over the name
    _real_spec.loader.exec_module(_mod)
else:
    from . import strategies  # noqa: F401
    from ._stub import (  # noqa: F401
        HealthCheck,
        example,
        given,
        settings,
    )

    __all__ = ["given", "settings", "strategies", "HealthCheck", "example"]
    __version__ = "0.0.0-repro-stub"
