"""Sharded Algorithm 1/2/3 (shard_map) equals the centralized reference —
runs in a subprocess with 8 forced host devices."""
import pytest

from _subproc import run_payload

PAYLOAD = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import graph, multiplier, wavelets, lasso
from repro.core import distributed as dist

key = jax.random.PRNGKey(1)
g, key = graph.connected_sensor_graph(key, n=600, theta=0.07, kappa=0.07)
gs, _ = graph.spatial_sort(g)
L = gs.laplacian()
lmax = gs.lambda_max_bound()
parts, leak = dist.partition_banded(np.asarray(L), 8)
assert leak == 0.0, leak
mesh = jax.make_mesh((8,), ("graph",),
                     axis_types=(jax.sharding.AxisType.Auto,))
y = jax.random.normal(key, (g.n_vertices,))
ypad = dist.pad_signal(y, parts)
mults = wavelets.sgwt_multipliers(lmax, J=3)
uop = multiplier.UnionMultiplier(P=L, multipliers=mults, lmax=lmax, K=15)
coeffs = uop.coeffs
n = g.n_vertices

out_d = dist.dist_cheb_apply(mesh, parts, ypad, coeffs, lmax)
out_c = uop.apply(y)
assert float(jnp.abs(out_d[:, :n] - out_c).max()) < 1e-4

a = out_c
apad = dist.pad_signal(a, parts)  # pads the trailing vertex axis
adj_d = dist.dist_cheb_apply_adjoint(mesh, parts, apad, coeffs, lmax)
assert float(jnp.abs(adj_d[:n] - uop.apply_adjoint(a)).max()) < 1e-4

gram_d = dist.dist_cheb_apply_gram(mesh, parts, ypad, coeffs, lmax)
assert float(jnp.abs(gram_d[:n] - uop.apply_gram(y)).max()) < 1e-4

mu = jnp.array([0.01, 0.75, 0.75, 0.75])
gamma = lasso.ista_step_size(uop)
a_d, y_d = dist.dist_lasso(mesh, parts, ypad, coeffs, lmax, mu,
                           gamma=gamma, n_iters=25)
res_c = lasso.distributed_lasso(uop, y, mu=mu, gamma=gamma, n_iters=25)
assert float(jnp.abs(y_d[:n] - res_c.signal).max()) < 1e-4

# allgather fallback on an unsorted (non-banded) graph
L2 = g.laplacian()
n_pad = 8 * (-(-g.n_vertices // 8))
L2p = jnp.asarray(np.pad(np.asarray(L2), ((0, n_pad - n), (0, n_pad - n))))
y2 = jnp.pad(y, (0, n_pad - n))
uop2 = multiplier.UnionMultiplier(P=L2, multipliers=mults, lmax=lmax, K=15)
out_ag = dist.dist_cheb_apply_allgather(mesh, L2p, y2, uop2.coeffs, lmax)
assert float(jnp.abs(out_ag[:, :n] - uop2.apply(y)).max()) < 1e-4
print("DIST OK")
"""


def test_sharded_equals_centralized():
    out = run_payload(PAYLOAD, n_devices=8)
    assert "DIST OK" in out
