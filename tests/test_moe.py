"""MoE dispatch invariants: baseline vs grouped, capacity semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    T, d, E, F, k = 64, 16, 8, 32, 2
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (T, d))
    router = jax.random.normal(ks[1], (d, E))
    wg = jax.random.normal(ks[2], (E, d, F)) * 0.1
    wu = jax.random.normal(ks[3], (E, d, F)) * 0.1
    wd = jax.random.normal(ks[4], (E, F, d)) * 0.1
    return x, router, wg, wu, wd, k


def test_grouped_equals_global_at_high_capacity(setup):
    x, router, wg, wu, wd, k = setup
    y0 = moe.moe_ffn(x, router, wg, wu, wd, top_k=k, capacity_factor=8.0)
    for G in (1, 2, 4, 8):
        y1 = moe.moe_ffn_grouped(x, router, wg, wu, wd, top_k=k,
                                 capacity_factor=8.0, n_groups=G)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=1e-5)


def test_capacity_drop_reduces_output_norm(setup):
    """Dropped assignments zero their contribution (capacity semantics)."""
    x, router, wg, wu, wd, k = setup
    y_full = moe.moe_ffn(x, router, wg, wu, wd, top_k=k, capacity_factor=8.0)
    y_tight = moe.moe_ffn(x, router, wg, wu, wd, top_k=k, capacity_factor=0.25)
    assert float(jnp.linalg.norm(y_tight)) < float(jnp.linalg.norm(y_full))
    assert bool(jnp.isfinite(y_tight).all())


def test_grouped_differentiable(setup):
    x, router, wg, wu, wd, k = setup

    def loss(x, wg):
        return jnp.sum(moe.moe_ffn_grouped(
            x, router, wg, wu, wd, top_k=k, capacity_factor=2.0,
            n_groups=4) ** 2)

    g1, g2 = jax.grad(loss, argnums=(0, 1))(x, wg)
    assert bool(jnp.isfinite(g1).all()) and bool(jnp.isfinite(g2).all())
    assert float(jnp.abs(g2).max()) > 0


def test_capacity_helper():
    assert moe.capacity(1024, 8, 2, 1.0) == 256
    assert moe.capacity(10, 8, 2, 1.0) >= 8      # floor at `multiple`
    assert moe.capacity(1024, 8, 2, 10.0) <= 1024  # never above n_tokens
