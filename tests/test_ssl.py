"""Section III-D: distributed semi-supervised classification."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import filters, graph, ssl


def test_two_cluster_classification():
    g, labels = graph.two_cluster_graph(jax.random.PRNGKey(3), n_per=25)
    mask = jnp.zeros(50, bool).at[jnp.array([0, 1, 25, 26])].set(True)
    res = ssl.semi_supervised_classify(
        g.laplacian("normalized"), labels, mask, 2, tau=0.5, lmax=2.0
    )
    assert ssl.accuracy(res, labels, mask) > 0.95


def test_kernel_variants_all_classify():
    g, labels = graph.two_cluster_graph(jax.random.PRNGKey(4), n_per=20)
    mask = jnp.zeros(40, bool).at[jnp.array([0, 20])].set(True)
    Ln = g.laplacian("normalized")
    for h in (filters.power_kernel(1), filters.power_kernel(2),
              filters.diffusion_kernel(1.0), filters.inverse_cosine_kernel(),
              filters.random_walk_kernel(2.0, 2)):
        res = ssl.semi_supervised_classify(Ln, labels, mask, 2, h=h,
                                           tau=0.5, lmax=2.0)
        assert ssl.accuracy(res, labels, mask) > 0.8, h


def test_label_matrix_construction():
    labels = jnp.array([0, 1, 2, 1])
    mask = jnp.array([True, True, False, False])
    Y = ssl.label_matrix(labels, mask, 3)
    expect = np.zeros((4, 3), np.float32)
    expect[0, 0] = 1
    expect[1, 1] = 1
    np.testing.assert_array_equal(np.asarray(Y), expect)
