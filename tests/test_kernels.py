"""Pallas kernel sweeps (interpret mode) vs the pure-jnp oracles in ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import chebyshev as cheb
from repro.core import filters, graph
from repro.kernels import ops, ref
from repro.kernels.bcsr_spmv import block_ell_spmv
from repro.kernels.cheb_step import cheb_step
from repro.kernels.flash_attention import flash_attention
from repro.kernels.soft_threshold import ista_shrink


@pytest.mark.parametrize("n,block", [(300, (8, 128)), (513, (8, 128)),
                                     (1024, (16, 128)), (200, (8, 256))])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_ell_spmv_sweep(n, block, dtype):
    g, _ = graph.connected_sensor_graph(jax.random.PRNGKey(n), n=n,
                                        theta=0.15, kappa=0.15)
    L = np.asarray(g.laplacian(), dtype=np.float32)
    A = graph.to_block_ell(L, block)
    blocks = A.blocks.astype(dtype)
    x = jax.random.normal(jax.random.PRNGKey(1), (A.padded_n,), dtype)
    y_k = block_ell_spmv(blocks, A.indices, x, interpret=True)
    y_r = ref.block_ell_spmv_ref(blocks, A.indices, x)
    tol = 1e-4 if dtype == jnp.float32 else 2e-1
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("n,eta", [(1024, 1), (2048, 3), (896, 7)])
def test_cheb_step_sweep(n, eta):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    pt, t1, t2 = (jax.random.normal(k, (n,)) for k in ks[:3])
    acc = jax.random.normal(ks[3], (eta, n))
    coef = jax.random.normal(ks[4], (eta,))
    tk_k, acc_k = cheb_step(pt, t1, t2, acc, coef, alpha=1.3, interpret=True)
    tk_r, acc_r = ref.cheb_step_ref(pt, t1, t2, acc, coef, alpha=1.3)
    np.testing.assert_allclose(np.asarray(tk_k), np.asarray(tk_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(acc_k), np.asarray(acc_r), atol=1e-5)


@pytest.mark.parametrize("eta,n", [(2, 1024), (5, 1280)])
def test_ista_shrink_sweep(eta, n):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    a, phi_y, gram = (jax.random.normal(k, (eta, n)) for k in ks[:3])
    th = jnp.abs(jax.random.normal(ks[3], (eta, 1))) * 0.3
    out_k = ista_shrink(a, phi_y, gram, th, gamma=0.2, interpret=True)
    out_r = ref.ista_shrink_ref(a, phi_y, gram, th, gamma=0.2)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=1e-6)


@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (1, 2, 2, 128, 64),
    (2, 4, 2, 256, 64),    # GQA
    (1, 8, 1, 256, 128),   # MQA
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, hq, hkv, s, d, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (b, hq, s, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), dtype)
    o_k = flash_attention(q, k, v, causal=causal, block_q=128, block_k=128,
                          interpret=True)
    o_r = ref.attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32), atol=tol, rtol=tol)


def test_fused_cheb_apply_matches_core(sensor120):
    L = np.asarray(sensor120.laplacian())
    A = graph.to_block_ell(L, (8, 128))
    lmax = sensor120.lambda_max_bound()
    coeffs = cheb.cheb_coeffs_stack(
        [filters.tikhonov(1.0), filters.heat(0.5)], 12, lmax)
    x = jax.random.normal(jax.random.PRNGKey(3), (A.padded_n,))
    Lp = jnp.asarray(np.pad(L, ((0, A.padded_n - L.shape[0]),) * 2))
    fused = ops.fused_cheb_apply(A, x, coeffs, lmax, use_pallas=True)
    core = cheb.cheb_apply(lambda t: Lp @ t, x,
                           jnp.asarray(coeffs, x.dtype), lmax)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(core), atol=1e-4)
