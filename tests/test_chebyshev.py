"""Section IV: coefficients, recurrence, unions, adjoints, error bounds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import chebyshev as cheb
from repro.core import filters, wavelets
from repro.core.multiplier import UnionMultiplier, graph_multiplier


@pytest.fixture(scope="module")
def setup(sensor120):
    L = sensor120.laplacian()
    lmax = sensor120.lambda_max_bound()
    y = jax.random.normal(jax.random.PRNGKey(2), (sensor120.n_vertices,))
    return sensor120, L, lmax, y


def test_coeffs_exact_for_polynomials():
    # g(x) = x on [0, lmax]: Tbar_0 = 1, Tbar_1 = (x - a)/a  =>  x = a + a*Tbar_1
    lmax = 4.0
    c = cheb.cheb_coeffs(lambda x: x, K=5, lmax=lmax)
    a = lmax / 2
    np.testing.assert_allclose(c[0], 2 * a, atol=1e-10)  # half-c0 convention
    np.testing.assert_allclose(c[1], a, atol=1e-10)
    np.testing.assert_allclose(c[2:], 0.0, atol=1e-10)


def test_cheb_eval_matches_function():
    lmax = 7.3
    g = filters.tikhonov(1.0, 1)
    c = cheb.cheb_coeffs(g, K=40, lmax=lmax)
    lam = np.linspace(0, lmax, 200)
    vals = np.asarray(cheb.cheb_eval(c, jnp.asarray(lam), lmax))
    np.testing.assert_allclose(vals, g(lam), atol=1e-5)


def test_apply_matches_exact_eigendecomposition(setup):
    g, L, lmax, y = setup
    op = graph_multiplier(L, filters.tikhonov(1.0), lmax, K=30)
    err = float(jnp.linalg.norm(op.apply(y) - op.exact_apply(y)))
    assert err / float(jnp.linalg.norm(y)) < 5e-3
    # and the error respects the Prop. 4 bound
    assert err <= op.error_bound() * float(jnp.linalg.norm(y)) + 1e-4


def test_prop4_bound_union(setup):
    g, L, lmax, y = setup
    mults = wavelets.sgwt_multipliers(lmax, J=4)
    op = UnionMultiplier(P=L, multipliers=mults, lmax=lmax, K=25)
    diff = op.apply(y) - op.exact_apply(y)
    lhs = float(jnp.linalg.norm(diff)) / float(jnp.linalg.norm(y))
    assert lhs <= op.error_bound() + 1e-5


def test_prop5_convergence_rate(setup):
    """Smooth multipliers: B(K) decays fast in K (Prop. 5)."""
    _, _, lmax, _ = setup
    g = filters.heat(0.5)
    bs = []
    for K in (5, 10, 20, 40):
        c = cheb.cheb_coeffs(g, K, lmax)
        bs.append(cheb.approx_error_bound([g], c[None, :], lmax))
    assert bs[1] < bs[0] and bs[2] < bs[1]
    assert bs[3] <= bs[2]  # saturates at the f32 eval floor
    assert bs[3] < 1e-6    # spectral convergence for analytic g


def test_adjoint_identity(setup):
    g, L, lmax, y = setup
    mults = wavelets.sgwt_multipliers(lmax, J=3)
    op = UnionMultiplier(P=L, multipliers=mults, lmax=lmax, K=20)
    a = jax.random.normal(jax.random.PRNGKey(3), (op.eta, g.n_vertices))
    lhs = float(jnp.sum(op.apply(y) * a))
    rhs = float(jnp.sum(y * op.apply_adjoint(a)))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4)


def test_gram_equals_adjoint_of_apply(setup):
    g, L, lmax, y = setup
    mults = wavelets.sgwt_multipliers(lmax, J=3)
    op = UnionMultiplier(P=L, multipliers=mults, lmax=lmax, K=15)
    via_pair = op.apply_adjoint(op.apply(y))
    via_gram = op.apply_gram(y)
    np.testing.assert_allclose(np.asarray(via_pair), np.asarray(via_gram),
                               atol=1e-3)


def test_product_coeffs_identity():
    """(sum c_k Tbar_k)^2 evaluated == product-coefficient series."""
    lmax = 5.0
    c1 = cheb.cheb_coeffs(filters.tikhonov(0.7), 12, lmax)
    c2 = cheb.cheb_coeffs(filters.heat(0.3), 9, lmax)
    prod = cheb.cheb_product_coeffs(c1, c2)
    lam = jnp.linspace(0, lmax, 101)
    lhs = cheb.cheb_eval(c1, lam, lmax) * cheb.cheb_eval(c2, lam, lmax)
    rhs = cheb.cheb_eval(prod, lam, lmax)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=1e-5)


def test_message_counts_match_paper(setup):
    """Section IV-B/C: 2K|E| (apply), 2K|E| x eta (adjoint), 4K|E| (gram)."""
    g, L, lmax, _ = setup
    op = UnionMultiplier(P=L, multipliers=wavelets.sgwt_multipliers(lmax, 3),
                         lmax=lmax, K=20)
    mc = op.message_counts(g.n_edges)
    assert mc["apply_messages"] == 2 * 20 * g.n_edges
    assert mc["adjoint_message_len"] == 4
    assert mc["gram_messages"] == 4 * 20 * g.n_edges


def test_matrix_signal_apply(setup):
    """SSL path: the recurrence is linear, batch signals processed jointly
    under the (..., N) contract (leading batch dims, vertex axis last)."""
    g, L, lmax, _ = setup
    op = graph_multiplier(L, filters.tikhonov(0.5), lmax, K=20)
    Y = jax.random.normal(jax.random.PRNGKey(4), (3, g.n_vertices))
    joint = op.apply(Y)
    assert joint.shape == Y.shape
    for j in range(3):
        np.testing.assert_allclose(
            np.asarray(joint[j]), np.asarray(op.apply(Y[j])), atol=1e-4
        )
