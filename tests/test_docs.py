"""Docs stay consistent with the code (the CI `lint` job runs the same
checker via `tools/lint_repro.py`; here it runs under pytest so local
tier-1 catches drift too, plus a live cross-check of the registry
scan)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))

import check_docs


def test_no_broken_intra_repo_links():
    assert check_docs.broken_links() == []


def test_every_registered_backend_documented():
    assert check_docs.undocumented_backends() == []


def test_static_backend_scan_matches_live_registry():
    """The AST scan tools/check_docs.py relies on agrees with what the
    registry actually exposes at import time."""
    from repro.dist import available_backends

    assert check_docs.registered_backends() == set(available_backends())


def test_every_backend_in_api_md():
    assert check_docs.undocumented_backends_api() == []


def test_every_solve_method_documented():
    assert check_docs.undocumented_solve_methods() == []


def test_static_solve_method_scan_matches_live_vocabulary():
    from repro.dist.solvers import METHODS

    assert check_docs.solve_methods() == set(METHODS)
