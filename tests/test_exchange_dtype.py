"""Compressed halo exchange (repro.dist.quantize): wire-byte models,
error-feedback accuracy, and the measured bytes-per-round ratios on a
realistically wide halo (h = 24), for both sharded backends.

The path-graph closed forms live in test_commstats.py; this file uses a
banded Laplacian with coupling bandwidth 24 because the int8 wire row is
``h + 4`` bytes (the f32 scale is bitcast-packed into the payload) — at
h = 1 the scale dominates and int8 is *larger* than f32; the advertised
<= 0.3x ratio only means anything at realistic halo widths.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _subproc import run_payload
from repro.dist import quantize


# ---------------------------------------------------------------------------
# Codec unit tests (single device)
# ---------------------------------------------------------------------------
def test_validate_exchange_dtype():
    for dt in quantize.EXCHANGE_DTYPES:
        quantize.validate_exchange_dtype(dt)
    with pytest.raises(ValueError):
        quantize.validate_exchange_dtype("f16")
    with pytest.raises(ValueError):
        quantize.validate_exchange_dtype("int4")


def test_tile_wire_bytes_models():
    for h in (1, 8, 24, 128):
        assert quantize.tile_wire_bytes(h, "f32") == 4 * h
        assert quantize.tile_wire_bytes(h, "bf16") == 2 * h
        assert quantize.tile_wire_bytes(h, "int8") == h + 4


def test_codec_roundtrip_and_wire_sizes():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((6, 24)).astype(np.float32))
    # f32: identity
    assert quantize.encode(x, "f32") is x
    # bf16: real bf16 on the wire, half the bytes, ~3 significand digits
    w16 = quantize.encode(x, "bf16")
    assert w16.dtype == jnp.bfloat16 and w16.nbytes == x.nbytes // 2
    assert float(jnp.abs(quantize.decode(w16, "bf16") - x).max()) < 2e-2
    # int8: one (h+4)-byte int8 row per tile row — scale packed, no side
    # channel (a separate scale ppermute would double the round count)
    w8 = quantize.encode(x, "int8")
    assert w8.dtype == jnp.int8 and w8.shape == (6, 28)
    back = quantize.decode(w8, "int8")
    scale = jnp.abs(x).max(axis=-1, keepdims=True)
    assert float((jnp.abs(back - x) / scale).max()) <= 0.5 / 127 + 1e-6


def test_codec_all_zero_rows_pass_through():
    z = jnp.zeros((3, 16), jnp.float32)
    for dt in ("bf16", "int8"):
        assert float(jnp.abs(quantize.decode(quantize.encode(z, dt),
                                             dt)).max()) == 0.0


def test_error_feedback_beats_plain_requantization():
    """Accumulating the quantization residual keeps repeated int8
    round-trips from drifting: the EF error after many rounds stays near
    one round's noise floor while plain requantization random-walks."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((4, 24)).astype(np.float32))
    acc_plain = jnp.zeros_like(x)
    acc_ef = jnp.zeros_like(x)
    r = quantize.ef_init(x)
    rounds = 40
    for _ in range(rounds):
        acc_plain = acc_plain + quantize.decode(quantize.encode(x, "int8"),
                                                "int8")
        wire, r = quantize.ef_encode(x, r, "int8")
        acc_ef = acc_ef + quantize.decode(wire, "int8")
    target = x * rounds
    err_plain = float(jnp.abs(acc_plain - target).max())
    err_ef = float(jnp.abs(acc_ef - target).max())
    assert err_ef < err_plain / 4, (err_ef, err_plain)


# ---------------------------------------------------------------------------
# Sharded accuracy + comm gates (8 devices, h = 24)
# ---------------------------------------------------------------------------
PAYLOAD = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.dist.operator import GraphOperator
from repro.dist.commstats import plan_comm_stats

rng = np.random.default_rng(0)
n, S, K, bw = 512, 8, 20, 24
B = np.zeros((n, n), dtype=np.float32)
for i in range(n):
    lo, hi = max(0, i - bw), min(n, i + bw + 1)
    B[i, lo:hi] = rng.standard_normal(hi - lo) * 0.1
B = np.abs(B + B.T) / 2
L = np.diag(B.sum(1)) - B          # banded Laplacian, bandwidth 24
lmax = float(2 * B.sum(1).max())
op = GraphOperator(P=jnp.asarray(L),
                   multipliers=[lambda lam: jnp.exp(-lam)],
                   lmax=lmax, K=K)
mesh = jax.make_mesh((S,), ("graph",))
x = jnp.asarray(rng.standard_normal((4, n)).astype(np.float32))
ref = op.plan("dense").apply(x)
refmax = float(jnp.abs(ref).max())

for backend in ("halo", "pallas_halo"):
    base = plan_comm_stats(op.plan(backend, mesh=mesh))["apply"]
    errs = {}
    for dt in ("f32", "bf16", "int8"):
        plan = op.plan(backend, mesh=mesh, exchange_dtype=dt)
        assert plan.info["exchange_dtype"] == dt
        st = plan_comm_stats(plan)["apply"]
        stb = plan_comm_stats(plan, batch=16)["apply"]
        # rounds: exactly K (the paper's 2K|E| bound), batch-invariant —
        # compression rides the SAME two ppermutes per order
        assert st.exchange_rounds == K, (backend, dt, st.exchange_rounds)
        assert stb.exchange_rounds == K, (backend, dt, stb.exchange_rounds)
        # bytes-per-round ratios at h = 24
        ratio = st.bytes_per_round / base.bytes_per_round
        if dt == "f32":
            assert ratio == 1.0, (backend, ratio)
        elif dt == "bf16":
            assert ratio <= 0.5, (backend, ratio)
        else:
            assert ratio <= 0.3, (backend, ratio)   # (24+4)/96 ~ 0.29
        y = plan.apply(x)
        errs[dt] = float(jnp.abs(y - ref).max()) / refmax
    assert errs["f32"] < 1e-5, (backend, errs)
    assert errs["bf16"] < 5e-3, (backend, errs)
    # int8 + error feedback lands within 10x of bf16 at K = 20
    assert errs["int8"] <= 10 * errs["bf16"], (backend, errs)
    print(backend, "errs", errs)

# Error feedback vs plain int8, in the regime EF is designed for: repeated
# transmission of persistent boundary tiles (streaming re-sends; a solve
# iterating at its fixed point).  Re-sending the SAME tiles, plain int8
# injects the SAME deterministic rounding error every round — the
# accumulated output drifts linearly — while the EF residual telescopes
# the accumulated error back to one round's noise floor.  (On the
# *oscillating* Chebyshev iterates of a single apply the propagation
# weights vary too fast to telescope and EF is neutral: see
# ARCHITECTURE.md "Error feedback".)
from repro.core.chebyshev import _stateful_matvec
R = 20
exact = jnp.einsum("ij,...j->...i", jnp.asarray(L), x) * R
emax = float(jnp.abs(exact).max())
acc_errs = {}
for label, ef in (("ef", True), ("plain", False)):
    plan = op.plan("halo", mesh=mesh, exchange_dtype="int8",
                   error_feedback=ef)

    def fn(mv, xl):
        mv2, st = _stateful_matvec(mv, xl)

        def body(carry, _):
            acc, st = carry
            h, st = mv2(xl, st)
            return (acc + h, st), None

        (acc, _), _ = jax.lax.scan(body, (jnp.zeros_like(xl), st),
                                   None, length=R)
        return acc

    out = plan.matvec_runner(fn, (x,))
    acc_errs[label] = float(jnp.abs(out - exact).max()) / emax
print("streaming acc errs", acc_errs)
assert acc_errs["ef"] < acc_errs["plain"] / 4, acc_errs

# fault injection on the quantized wires: the measured schedule must be
# UNCHANGED — rounds stay exactly K (2K|E| messages), bytes-per-round stay
# the compressed-wire bytes, at every dtype, batched or not.  Receiver-side
# substitution costs accuracy, never messages.
from repro.dist import FaultSpec
fspec = FaultSpec(drop_prob=0.1, stale_prob=0.05, noise_prob=0.05, seed=1)
for backend in ("halo", "pallas_halo"):
    for dt in ("f32", "bf16", "int8"):
        clean = op.plan(backend, mesh=mesh, exchange_dtype=dt)
        for degr in ("zero_fill", "hold_last"):
            plan = op.plan(backend, mesh=mesh, exchange_dtype=dt,
                           fault_spec=fspec, degradation=degr)
            st = plan_comm_stats(plan)["apply"]
            stb = plan_comm_stats(plan, batch=16)["apply"]
            assert st.exchange_rounds == stb.exchange_rounds == K, (
                backend, dt, degr, st.exchange_rounds, stb.exchange_rounds)
            base_st = plan_comm_stats(clean)["apply"]
            assert st.bytes_per_round == base_st.bytes_per_round, (
                backend, dt, degr)
            y = plan.apply(x)
            assert bool(jnp.isfinite(y).all()), (backend, dt, degr)
print("FAULT ROUNDS OK")

# loose end-to-end solver gate: a bf16-exchange jacobi solve still solves
plan16 = op.plan("halo", mesh=mesh, exchange_dtype="bf16")
y = ref[:, 0, :]
x32 = op.plan("dense").solve(y, "jacobi", tau=0.5, n_iters=15).x
x16 = plan16.solve(y, "jacobi", tau=0.5, n_iters=15).x
rel = float(jnp.abs(x16 - x32).max() / jnp.abs(x32).max())
assert rel < 5e-2, rel
print("EXCHANGE DTYPE OK")
"""


def test_exchange_dtypes_8shards():
    out = run_payload(PAYLOAD, n_devices=8)
    assert "FAULT ROUNDS OK" in out
    assert "EXCHANGE DTYPE OK" in out


def test_build_rejects_unknown_exchange_dtype():
    from repro.dist.operator import GraphOperator
    rng = np.random.default_rng(0)
    A = np.abs(rng.standard_normal((16, 16)).astype(np.float32))
    A = (A + A.T) / 2
    L = np.diag(A.sum(1)) - A
    op = GraphOperator(P=jnp.asarray(L),
                       multipliers=[lambda lam: lam],
                       lmax=float(2 * A.sum(1).max()), K=4)
    for backend in ("halo", "pallas_halo"):
        with pytest.raises(ValueError):
            op.plan(backend, exchange_dtype="f16")
