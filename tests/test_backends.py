"""Backend equivalence: one `GraphOperator.plan()` path dispatches to every
registered backend with matching outputs (the unified execution API)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _subproc import run_payload
from repro.core import filters, graph, wavelets
from repro.dist import (GraphOperator, available_backends, get_backend,
                        register_backend)
from repro.dist.backends import _REGISTRY

BACKENDS = ["dense", "pallas", "halo", "pallas_halo", "allgather"]


@pytest.fixture(scope="module")
def small_op():
    """Small sensor graph + eta=3 SGWT union (N=120: not a 128 multiple, so
    the pallas path exercises its auto-padding)."""
    g, _ = graph.connected_sensor_graph(
        jax.random.PRNGKey(0), n=120, theta=0.2, kappa=0.25)
    lmax = g.lambda_max_bound()
    op = GraphOperator(P=g.laplacian(),
                       multipliers=wavelets.sgwt_multipliers(lmax, J=2),
                       lmax=lmax, K=12)
    return g, op


def _plan(op, backend):
    if backend in ("halo", "pallas_halo", "allgather"):
        mesh = jax.make_mesh((1,), ("graph",))
        return op.plan(backend, mesh=mesh)
    return op.plan(backend)


def test_registry_lists_builtin_backends():
    assert set(BACKENDS) <= set(available_backends())


def test_unknown_backend_raises(small_op):
    _, op = small_op
    with pytest.raises(KeyError, match="available"):
        op.plan("no-such-backend")


@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_matches_dense(small_op, backend):
    """plan.apply / apply_adjoint / apply_gram agree across backends."""
    g, op = small_op
    ref = op.plan("dense")
    plan = _plan(op, backend)
    f = jax.random.normal(jax.random.PRNGKey(1), (g.n_vertices,))
    a = jax.random.normal(jax.random.PRNGKey(2), (op.eta, g.n_vertices))

    out = plan.apply(f)
    assert out.shape == (op.eta, g.n_vertices)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.apply(f)),
                               atol=1e-4)
    adj = plan.apply_adjoint(a)
    assert adj.shape == (g.n_vertices,)
    np.testing.assert_allclose(np.asarray(adj),
                               np.asarray(ref.apply_adjoint(a)), atol=1e-4)
    gram = plan.apply_gram(f)
    assert gram.shape == (g.n_vertices,)
    np.testing.assert_allclose(np.asarray(gram),
                               np.asarray(ref.apply_gram(f)), atol=1e-4)


@pytest.mark.parametrize("backend", BACKENDS)
def test_adjoint_consistency(small_op, backend):
    """<Phi f, a> == <f, Phi* a> per backend (true adjoint pairs)."""
    g, op = small_op
    plan = _plan(op, backend)
    f = jax.random.normal(jax.random.PRNGKey(3), (g.n_vertices,))
    a = jax.random.normal(jax.random.PRNGKey(4), (op.eta, g.n_vertices))
    lhs = float(jnp.sum(plan.apply(f) * a))
    rhs = float(jnp.sum(f * plan.apply_adjoint(a)))
    assert abs(lhs - rhs) < 1e-2 * max(1.0, abs(lhs))


@pytest.mark.parametrize("backend", BACKENDS)
def test_plans_are_jittable(small_op, backend):
    g, op = small_op
    plan = _plan(op, backend)
    f = jax.random.normal(jax.random.PRNGKey(5), (g.n_vertices,))
    np.testing.assert_allclose(np.asarray(jax.jit(plan.apply)(f)),
                               np.asarray(plan.apply(f)), atol=1e-5)


@pytest.mark.parametrize("backend", ["halo", "pallas_halo"])
def test_solve_lasso_backend_equivalence(small_op, backend):
    """Algorithm 3 through the plan API: the fused shard_map ISTA loops
    (halo and pallas_halo) match the dense ISTA loop."""
    g, op = small_op
    y = jax.random.normal(jax.random.PRNGKey(6), (g.n_vertices,))
    mu = jnp.array([0.01, 0.75, 0.75])
    res_d = op.plan("dense").solve_lasso(y, mu, gamma=0.1, n_iters=15)
    mesh = jax.make_mesh((1,), ("graph",))
    res_h = op.plan(backend, mesh=mesh).solve_lasso(y, mu, gamma=0.1,
                                                    n_iters=15)
    np.testing.assert_allclose(np.asarray(res_h.signal),
                               np.asarray(res_d.signal), atol=1e-4)
    np.testing.assert_allclose(np.asarray(res_h.coeffs),
                               np.asarray(res_d.coeffs), atol=1e-4)


def test_pallas_halo_partition_roundtrip():
    """partition_block_ell: per-shard Block-ELL + boundary couplings
    reassemble to the original banded matrix, and the halo width matches
    the true coupling bandwidth (1 on a path graph)."""
    from repro.core.graph import path_graph
    from repro.dist.backends.pallas_halo import (partition_block_ell,
                                                 _banded_to_dense)
    from repro.dist.backends.halo import partition_banded

    L = np.asarray(path_graph(32).laplacian())
    parts, leak = partition_block_ell(L, 4)
    assert leak == 0.0
    assert parts.halo == 1 and parts.n_local == 8
    # reassemble: diag blocks from Block-ELL + the boundary columns
    banded, _ = partition_banded(L, 4)
    dense = _banded_to_dense(banded)
    np.testing.assert_allclose(dense, L, atol=0)
    # Block-ELL diagonal blocks match the banded diagonal blocks
    from repro.core.graph import BlockELL
    for s in range(4):
        A = BlockELL(blocks=parts.blocks[s], indices=parts.indices[s],
                     mask=parts.mask[s], n=parts.n_local)
        np.testing.assert_allclose(
            np.asarray(A.todense())[:8, :8],
            np.asarray(banded.diag[s]), atol=0)


def test_register_backend_extensibility(small_op):
    """New strategies plug in without touching callers (registry contract)."""
    g, op = small_op

    @register_backend("_test_echo")
    def build(op, *, mesh=None, partition=None, **options):
        plan = get_backend("dense")(op)
        import dataclasses
        return dataclasses.replace(plan, backend="_test_echo",
                                   info={"echo": True})

    try:
        plan = op.plan("_test_echo")
        assert plan.backend == "_test_echo" and plan.info == {"echo": True}
        f = jax.random.normal(jax.random.PRNGKey(7), (g.n_vertices,))
        np.testing.assert_allclose(np.asarray(plan.apply(f)),
                                   np.asarray(op.plan("dense").apply(f)),
                                   atol=1e-6)
    finally:
        _REGISTRY.pop("_test_echo", None)


def test_cheb_step_autopads_non_128_sizes():
    """Satellite: cheb_step no longer raises on N % 128 != 0."""
    from repro.kernels import ref
    from repro.kernels.cheb_step import cheb_step

    n, eta = 500, 3  # 500 % 128 != 0
    ks = jax.random.split(jax.random.PRNGKey(8), 5)
    pt, t1, t2 = (jax.random.normal(k, (n,)) for k in ks[:3])
    acc = jax.random.normal(ks[3], (eta, n))
    coef = jax.random.normal(ks[4], (eta,))
    tk_k, acc_k = cheb_step(pt, t1, t2, acc, coef, alpha=1.7, interpret=True)
    tk_r, acc_r = ref.cheb_step_ref(pt, t1, t2, acc, coef, alpha=1.7)
    assert tk_k.shape == (n,) and acc_k.shape == (eta, n)
    np.testing.assert_allclose(np.asarray(tk_k), np.asarray(tk_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(acc_k), np.asarray(acc_r),
                               atol=1e-5)


PAYLOAD = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import graph, wavelets
from repro.dist import GraphOperator, verify_message_scaling

key = jax.random.PRNGKey(1)
g, key = graph.connected_sensor_graph(key, n=600, theta=0.07, kappa=0.07)
gs, _ = graph.spatial_sort(g)
L = gs.laplacian()
lmax = gs.lambda_max_bound()
op = GraphOperator(P=L, multipliers=wavelets.sgwt_multipliers(lmax, J=3),
                   lmax=lmax, K=15)
mesh = jax.make_mesh((8,), ("graph",),
                     axis_types=(jax.sharding.AxisType.Auto,))
f = jax.random.normal(key, (g.n_vertices,))
a = jax.random.normal(jax.random.PRNGKey(2), (op.eta, g.n_vertices))
B = 64
F = jax.random.normal(jax.random.PRNGKey(3), (B, g.n_vertices))

ref = op.plan("dense")
out_ref, adj_ref, gram_ref = ref.apply(f), ref.apply_adjoint(a), ref.apply_gram(f)
Fout_ref = ref.apply(F)
for backend in ("pallas", "halo", "pallas_halo", "allgather"):
    plan = (op.plan(backend, mesh=mesh) if backend != "pallas"
            else op.plan(backend))
    assert float(jnp.abs(plan.apply(f) - out_ref).max()) < 1e-4, backend
    assert float(jnp.abs(plan.apply_adjoint(a) - adj_ref).max()) < 1e-4, backend
    assert float(jnp.abs(plan.apply_gram(f) - gram_ref).max()) < 1e-4, backend
    lhs = float(jnp.sum(plan.apply(f) * a))
    rhs = float(jnp.sum(f * plan.apply_adjoint(a)))
    assert abs(lhs - rhs) < 1e-2 * abs(lhs), (backend, lhs, rhs)
    # batched (..., N) contract under genuine sharding: B=64 signals match
    # the dense reference, and the exchange-round count is batch-invariant
    # (per-signal messages = 2K|E|/B)
    Fout = plan.apply(F)
    assert Fout.shape == (B, op.eta, g.n_vertices), (backend, Fout.shape)
    assert float(jnp.abs(Fout - Fout_ref).max()) < 1e-4, backend
    if backend != "pallas":
        v = verify_message_scaling(plan, g.n_edges, batch=B)
        assert v["max_rel_dev"] == 0.0, (backend, v["rel_dev"])
        assert v["per_signal_messages"]["apply"] == (
            2 * op.K * g.n_edges / B), backend
    print(f"{backend} OK", plan.info)
print("BACKENDS OK")
"""


def test_backends_match_dense_8shards():
    """Genuinely sharded (8 forced host devices) backend plans match the
    dense reference (single and B=64 batched signals), stay true adjoint
    pairs, and keep batch-invariant exchange rounds."""
    out = run_payload(PAYLOAD, n_devices=8)
    assert "BACKENDS OK" in out


# ---------------------------------------------------------------------------
# GeneralPartition golden matrix: non-banded community graph, edge-cut
# sharding (ISSUE 9) — dense reference vs halo / pallas_halo at 1 and 8
# devices, incl. B=64 batched and bf16-exchange paths.
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def community_op():
    from repro.dist import partition as pm

    csr, meta = pm.community_graph_csr(192, n_communities=6, seed=7)
    op = GraphOperator(
        P=csr.to_dense(),
        multipliers=wavelets.sgwt_multipliers(meta["lmax"], J=2),
        lmax=meta["lmax"], K=10)
    return csr, op


@pytest.mark.parametrize("backend", ["halo", "pallas_halo"])
def test_general_partition_matches_dense_1dev(community_op, backend):
    """partition="general" on a 1-shard mesh: apply/adjoint/gram/solve all
    match the dense plan (the S=1 degenerate skips collectives but must
    still run the permuted Block-ELL interior)."""
    csr, op = community_op
    n = csr.n
    dense = op.plan("dense")
    mesh = jax.make_mesh((1,), ("graph",))
    plan = op.plan(backend, mesh=mesh, partition="general")
    assert plan.info["partition"] == "general"
    f = jax.random.normal(jax.random.PRNGKey(0), (n,))
    a = jax.random.normal(jax.random.PRNGKey(1), (op.eta, n))
    assert float(jnp.abs(plan.apply(f) - dense.apply(f)).max()) < 1e-4
    assert float(jnp.abs(plan.apply_adjoint(a)
                         - dense.apply_adjoint(a)).max()) < 1e-4
    assert float(jnp.abs(plan.apply_gram(f)
                         - dense.apply_gram(f)).max()) < 1e-4
    xs = plan.solve(f, "jacobi", tau=0.5).x
    xd = dense.solve(f, "jacobi", tau=0.5).x
    assert float(jnp.abs(xs - xd).max()) < 1e-4


GENERAL_PAYLOAD = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import wavelets
from repro.dist import GraphOperator, verify_message_scaling
from repro.dist import partition as pm

csr, meta = pm.community_graph_csr(256, n_communities=8, seed=5)
n, E = csr.n, csr.n_edges
op = GraphOperator(P=csr.to_dense(),
                   multipliers=wavelets.sgwt_multipliers(meta["lmax"], J=3),
                   lmax=meta["lmax"], K=12)
mesh = jax.make_mesh((8,), ("graph",))
parts = pm.partition_general(csr, 8, block=(8, 8))
assert len(parts.offsets) > 2, parts.offsets  # genuinely non-banded

ref = op.plan("dense")
f = jax.random.normal(jax.random.PRNGKey(0), (n,))
a = jax.random.normal(jax.random.PRNGKey(1), (op.eta, n))
B = 64
F = jax.random.normal(jax.random.PRNGKey(2), (B, n))
out_ref, adj_ref = ref.apply(f), ref.apply_adjoint(a)
gram_ref, Fout_ref = ref.apply_gram(f), ref.apply(F)

for backend in ("halo", "pallas_halo"):
    plan = op.plan(backend, mesh=mesh, partition=parts)
    assert plan.info["partition"] == "general", backend
    assert float(jnp.abs(plan.apply(f) - out_ref).max()) < 1e-4, backend
    assert float(jnp.abs(plan.apply_adjoint(a) - adj_ref).max()) < 1e-4, backend
    assert float(jnp.abs(plan.apply_gram(f) - gram_ref).max()) < 1e-4, backend
    Fout = plan.apply(F)
    assert Fout.shape == (B, op.eta, n), (backend, Fout.shape)
    assert float(jnp.abs(Fout - Fout_ref).max()) < 1e-4, backend
    xs = plan.solve(f, "jacobi", tau=0.5).x
    xd = ref.solve(f, "jacobi", tau=0.5).x
    assert float(jnp.abs(xs - xd).max()) < 1e-4, backend
    # measured rounds exactly 2K|E| and batch-invariant
    v = verify_message_scaling(plan, E, n=n, batch=B)
    assert v["max_rel_dev"] == 0.0, (backend, v["rel_dev"])
    assert v["per_signal_messages"]["apply"] == 2 * op.K * E / B, backend
    # bf16 wire path: same rounds, half the f32 bytes, looser accuracy
    p16 = op.plan(backend, mesh=mesh, partition=parts,
                  exchange_dtype="bf16")
    assert float(jnp.abs(p16.apply(f) - out_ref).max()) < 5e-2, backend
    v16 = verify_message_scaling(p16, E, n=n)
    assert v16["max_rel_dev"] == 0.0, backend
    s32 = v["stats"]["apply"]; s16 = v16["stats"]["apply"]
    assert s16["bytes_per_shard"] * 2 == s32["bytes_per_shard"], backend
    print(backend, "OK")
print("GENERAL OK")
"""


def test_general_partition_matches_dense_8shards():
    """Genuinely sharded GeneralPartition plans (8 forced host devices) on
    a non-banded community graph match dense for apply/adjoint/gram/solve,
    keep B=64 batched equivalence, measure exactly 2K|E| with
    batch-invariant rounds, and halve wire bytes under bf16 exchange."""
    out = run_payload(GENERAL_PAYLOAD, n_devices=8)
    assert "GENERAL OK" in out
