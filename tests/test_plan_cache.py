"""ExecutionPlan.compiled()/compiled_solve() cache-key audit: every kwarg
that changes the traced program must be part of the memo key, and repeat
lookups with identical kwargs must return the SAME jitted callable."""
import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graph, wavelets
from repro.dist import GraphOperator


@pytest.fixture(scope="module")
def op():
    g, _ = graph.connected_sensor_graph(jax.random.PRNGKey(0), n=48,
                                        theta=0.3, kappa=0.35)
    lmax = g.lambda_max_bound()
    return GraphOperator(P=g.laplacian(),
                         multipliers=wavelets.sgwt_multipliers(lmax, J=2),
                         lmax=lmax, K=6)


@pytest.fixture(scope="module")
def y(op):
    n = np.asarray(op.P).shape[0]
    return jax.random.normal(jax.random.PRNGKey(1), (n,), jnp.float32)


def test_compiled_memo_identity(op):
    plan = op.plan("dense")
    assert plan.compiled("apply") is plan.compiled("apply")
    assert plan.compiled("apply") is not plan.compiled("apply_gram")
    with pytest.raises(KeyError):
        plan.compiled("nope")


def test_compiled_solve_memo_identity(op):
    plan = op.plan("dense")
    a = plan.compiled_solve("jacobi", tau=0.5)
    assert plan.compiled_solve("jacobi", tau=0.5) is a


def test_compiled_solve_distinct_kwargs_distinct_entries(op, y):
    """The regression this file exists for: two calls differing ONLY in a
    program-changing kwarg must not collide in the memo."""
    plan = op.plan("dense")
    base = plan.compiled_solve("jacobi", tau=0.5)
    assert plan.compiled_solve("cheb_jacobi", tau=0.5, rho=0.5) is not base
    assert plan.compiled_solve("jacobi", tau=0.25) is not base
    assert plan.compiled_solve("jacobi", tau=0.5, n_iters=3) is not base
    assert plan.compiled_solve("jacobi", tau=0.5, vmem_budget=4096) \
        is not base
    # and the distinct entries compute what their kwargs say: n_iters=3
    # really runs 3 rounds, not the colliding default
    x6 = np.asarray(base(y))
    x3 = np.asarray(plan.compiled_solve("jacobi", tau=0.5, n_iters=3)(y))
    assert not np.allclose(x6, x3)


def test_compiled_solve_array_kwargs_key_by_value(op, y):
    plan = op.plan("dense")
    n = y.shape[0]
    d1 = np.full((n,), 2.0, np.float32)
    d2 = np.full((n,), 4.0, np.float32)
    f1 = plan.compiled_solve("jacobi", tau=0.5, den_diag=d1)
    f2 = plan.compiled_solve("jacobi", tau=0.5, den_diag=d2)
    assert f1 is not f2
    assert f1 is plan.compiled_solve("jacobi", tau=0.5,
                                     den_diag=d1.copy())
    assert not np.allclose(np.asarray(f1(y)), np.asarray(f2(y)))


def test_solve_vmem_budget_forces_logged_fallback(op, y, caplog):
    """vmem_budget= reaches the single-launch sweep guard: a starved
    budget takes the logged per-order path and matches the default-budget
    result (the knob changes the execution, never the math)."""
    plan = op.plan("pallas")
    ref = np.asarray(plan.solve(y, "jacobi", tau=0.5, use_pallas=True).x)
    with caplog.at_level(logging.INFO, logger="repro.kernels.ops"):
        out = np.asarray(plan.solve(y, "jacobi", tau=0.5, use_pallas=True,
                                    vmem_budget=64).x)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)
    assert any("exceeds budget" in r.getMessage()
               for r in caplog.records), caplog.records
