"""ExecutionPlan.compiled()/compiled_solve() cache-key audit: every kwarg
that changes the traced program must be part of the memo key, and repeat
lookups with identical kwargs must return the SAME jitted callable.

The serving-safety section audits the engine's call pattern on top:
concurrent bucket sizes B must land in DISTINCT compiled entries (jax's
per-shape cache under the one memoized wrapper) without colliding across
`vmem_budget=` or aliasing bool/int kwarg values, and repeats at any
enumerated bucket must never retrace."""
import dataclasses
import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graph, wavelets
from repro.dist import GraphOperator
from repro.dist.operator import canonical_kwarg, canonical_solve_items


@pytest.fixture(scope="module")
def op():
    g, _ = graph.connected_sensor_graph(jax.random.PRNGKey(0), n=48,
                                        theta=0.3, kappa=0.35)
    lmax = g.lambda_max_bound()
    return GraphOperator(P=g.laplacian(),
                         multipliers=wavelets.sgwt_multipliers(lmax, J=2),
                         lmax=lmax, K=6)


@pytest.fixture(scope="module")
def y(op):
    n = np.asarray(op.P).shape[0]
    return jax.random.normal(jax.random.PRNGKey(1), (n,), jnp.float32)


def test_compiled_memo_identity(op):
    plan = op.plan("dense")
    assert plan.compiled("apply") is plan.compiled("apply")
    assert plan.compiled("apply") is not plan.compiled("apply_gram")
    with pytest.raises(KeyError):
        plan.compiled("nope")


def test_compiled_solve_memo_identity(op):
    plan = op.plan("dense")
    a = plan.compiled_solve("jacobi", tau=0.5)
    assert plan.compiled_solve("jacobi", tau=0.5) is a


def test_compiled_solve_distinct_kwargs_distinct_entries(op, y):
    """The regression this file exists for: two calls differing ONLY in a
    program-changing kwarg must not collide in the memo."""
    plan = op.plan("dense")
    base = plan.compiled_solve("jacobi", tau=0.5)
    assert plan.compiled_solve("cheb_jacobi", tau=0.5, rho=0.5) is not base
    assert plan.compiled_solve("jacobi", tau=0.25) is not base
    assert plan.compiled_solve("jacobi", tau=0.5, n_iters=3) is not base
    assert plan.compiled_solve("jacobi", tau=0.5, vmem_budget=4096) \
        is not base
    # and the distinct entries compute what their kwargs say: n_iters=3
    # really runs 3 rounds, not the colliding default
    x6 = np.asarray(base(y))
    x3 = np.asarray(plan.compiled_solve("jacobi", tau=0.5, n_iters=3)(y))
    assert not np.allclose(x6, x3)


def test_compiled_solve_array_kwargs_key_by_value(op, y):
    plan = op.plan("dense")
    n = y.shape[0]
    d1 = np.full((n,), 2.0, np.float32)
    d2 = np.full((n,), 4.0, np.float32)
    f1 = plan.compiled_solve("jacobi", tau=0.5, den_diag=d1)
    f2 = plan.compiled_solve("jacobi", tau=0.5, den_diag=d2)
    assert f1 is not f2
    assert f1 is plan.compiled_solve("jacobi", tau=0.5,
                                     den_diag=d1.copy())
    assert not np.allclose(np.asarray(f1(y)), np.asarray(f2(y)))


def test_canonical_kwarg_bool_int_no_alias():
    """True == 1 in Python (and hashes equal): without the bool tag the
    memo would hand the int-keyed caller the bool-compiled entry."""
    assert canonical_kwarg(True) != canonical_kwarg(1)
    assert canonical_kwarg(False) != canonical_kwarg(0)
    assert canonical_kwarg(True) == canonical_kwarg(True)
    assert canonical_solve_items({"a": 1, "b": True}) \
        != canonical_solve_items({"a": True, "b": 1})


# ---------------------------------------------------------------------------
# Serving safety: the engine's bucketed call pattern
# ---------------------------------------------------------------------------
def _counting_plan(plan):
    """plan clone whose apply counts traces (runs at trace time only)."""
    traces = []
    orig = plan.apply

    def counting_apply(x):
        traces.append(1)
        return orig(x)

    return dataclasses.replace(plan, apply=counting_apply), traces


def test_bucketed_callables_distinct_buckets_no_retrace(op, y):
    """The engine's exact call pattern: warm the bucket set, then serve
    interleaved bucket sizes repeatedly — each bucket traces exactly
    once (its own compiled entry), repeats hit the cache."""
    plan, traces = _counting_plan(op.plan("dense"))
    n = y.shape[0]
    fns = plan.bucketed_callables((1, 8), kinds=("apply",), warm=True)
    assert set(fns) == {("apply", 1), ("apply", 8)}
    # one memoized wrapper, two per-shape compiled entries
    assert fns[("apply", 1)] is fns[("apply", 8)]
    assert fns[("apply", 1)] is plan.compiled("apply")
    assert len(traces) == 2                       # one trace per bucket
    f1 = jnp.zeros((1, n), jnp.float32)
    f8 = jnp.zeros((8, n), jnp.float32)
    for _ in range(3):                            # serving steady state
        fns[("apply", 1)](f1)
        fns[("apply", 8)](f8)
    assert len(traces) == 2                       # zero retraces
    # distinct buckets really are distinct entries: B=1 and B=8 disagree
    # in output shape, so a collision would be a shape error, not reuse
    assert fns[("apply", 1)](f1).shape[0] == 1
    assert fns[("apply", 8)](f8).shape[0] == 8


def test_bucketed_callables_solve_specs_and_validation(op, y):
    plan = op.plan("dense")
    n = y.shape[0]
    fns = plan.bucketed_callables(
        (1, 4), kinds=(), solve_specs=[("jacobi", {"tau": 0.5})],
        warm=True)
    label = ("solve", "jacobi") + canonical_solve_items({"tau": 0.5})
    assert set(fns) == {(label, 1), (label, 4)}
    assert fns[(label, 1)] is plan.compiled_solve("jacobi", tau=0.5)
    out = fns[(label, 4)](jnp.stack([y] * 4))
    np.testing.assert_allclose(
        np.asarray(out[0]),
        np.asarray(plan.solve(y, "jacobi", tau=0.5).x), atol=1e-5)
    with pytest.raises(ValueError, match="buckets"):
        plan.bucketed_callables((0, 4))
    with pytest.raises(KeyError, match="unknown kind"):
        plan.bucketed_callables((1,), kinds=("nope",))


def test_vmem_budget_times_bucket_no_collision(op, y):
    """Serving two buckets of two vmem_budget variants concurrently: four
    distinct compiled programs, zero cross-contamination — the budget is
    part of the memo key, the bucket is part of jax's shape key."""
    plan = op.plan("dense")
    fa = plan.compiled_solve("jacobi", tau=0.5)
    fb = plan.compiled_solve("jacobi", tau=0.5, vmem_budget=4096)
    assert fa is not fb
    y1 = y[None]
    y8 = jnp.stack([y] * 8)
    outs = [fa(y1), fb(y1), fa(y8), fb(y8)]       # interleaved buckets
    assert [o.shape[0] for o in outs] == [1, 1, 8, 8]
    # identical math either way (the budget changes execution, not
    # results), and the b=8 rows replicate the b=1 answer
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[1]),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(outs[2][7]),
                               np.asarray(outs[0][0]),
                               rtol=1e-6, atol=1e-7)
    # repeats return the SAME callables (no memo churn under load)
    assert plan.compiled_solve("jacobi", tau=0.5) is fa
    assert plan.compiled_solve("jacobi", tau=0.5, vmem_budget=4096) is fb


def test_solve_vmem_budget_forces_logged_fallback(op, y, caplog):
    """vmem_budget= reaches the single-launch sweep guard: a starved
    budget takes the logged per-order path and matches the default-budget
    result (the knob changes the execution, never the math)."""
    plan = op.plan("pallas")
    ref = np.asarray(plan.solve(y, "jacobi", tau=0.5, use_pallas=True).x)
    with caplog.at_level(logging.INFO, logger="repro.kernels.ops"):
        out = np.asarray(plan.solve(y, "jacobi", tau=0.5, use_pallas=True,
                                    vmem_budget=64).x)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)
    assert any("exceeds budget" in r.getMessage()
               for r in caplog.records), caplog.records
