"""Closed-form checks of the pluggable edge-cut partition layer
(`repro.dist.partition`).

The property suite in tests/test_property.py covers the randomized
invariants (every edge covered exactly once, exchange symmetry, perm
bijections); this module pins the closed forms of ISSUE 9:

* partitioner edge-cut <= a sanity bound on path / grid / community
  fixtures (a contiguous chop of a good ordering cannot cut more than the
  boundary structure allows);
* `commstats.verify_message_scaling` == 2K|E| EXACTLY on a non-banded
  8-shard payload (the paper's Section IV-B count, measured from the
  jaxpr — max_rel_dev must be 0.0, not "within 10%");
* bytes-per-round == boundary-size x dtype wire width for each of
  f32 / bf16 / int8 (the PR-8 codec on arbitrary boundary tiles);
* the overfull-slot hazard raises instead of truncating (silently
  dropped blocks are silently wrong matvecs).
"""
import numpy as np
import pytest

from repro.core import graph as graphmod
from repro.dist import partition as pm
from repro.dist.backends.pallas_halo import partition_block_ell

from _subproc import run_payload


def _roundtrip_err(P, parts):
    return float(np.abs(pm.partition_to_dense(parts) - np.asarray(P)).max())


# ---------------------------------------------------------------------------
# Edge-cut sanity bounds
# ---------------------------------------------------------------------------
def test_path_graph_cut_is_minimal():
    # A path chopped into S contiguous runs cuts exactly S-1 edges; BFS
    # from a degree-1 endpoint recovers the natural order, so the
    # partitioner must land on the optimum.
    g = graphmod.path_graph(64)
    parts = pm.partition_general(g.laplacian(), 8, block=(8, 8))
    assert parts.edge_cut == 7
    assert _roundtrip_err(g.laplacian(), parts) < 1e-6


def test_torus_graph_cut_bound():
    # 8x16 torus at 4 shards: any contiguous chop of a row-major-ish BFS
    # order cuts O(rows) edges per shard boundary; gate at the loose
    # closed form 4 * rows * shards (a random order would cut ~ |E|/2
    # = 256, far above it).
    g = graphmod.torus_graph(8, 16)
    parts = pm.partition_general(g.laplacian(), 4, block=(8, 8))
    assert parts.edge_cut <= 4 * 8 * 4
    assert _roundtrip_err(g.laplacian(), parts) < 1e-6


@pytest.mark.parametrize("method", ["bfs", "spectral"])
def test_community_graph_cut_bound(method):
    # 8 communities of 32 vertices, ~2 inter-community edges per
    # community: intra-community edges dominate, so a partitioner that
    # respects community structure cuts a small fraction of |E|.
    csr, meta = pm.community_graph_csr(256, n_communities=8, seed=1)
    parts = pm.partition_general(csr, 8, method=method, block=(8, 8))
    assert parts.edge_cut <= csr.n_edges // 2, (
        f"{method} cut {parts.edge_cut} of {csr.n_edges} edges")
    assert _roundtrip_err(csr.to_dense(), parts) < 1e-6


def test_spectral_beats_random_on_communities():
    csr, _ = pm.community_graph_csr(256, n_communities=8, seed=1)
    rng = np.random.default_rng(0)
    random_parts = pm.partition_general(
        csr, 8, order=rng.permutation(256), block=(8, 8))
    spectral_parts = pm.partition_general(
        csr, 8, method="spectral", block=(8, 8))
    assert spectral_parts.edge_cut < random_parts.edge_cut


# ---------------------------------------------------------------------------
# Overfull-slot hazard: raise, never truncate
# ---------------------------------------------------------------------------
def test_partition_general_overfull_raises():
    # A star graph couples the hub row block to every column block; with
    # max_slots=1 the packer must refuse rather than drop blocks.
    n = 64
    W = np.zeros((n, n), np.float32)
    W[0, 1:] = 1.0
    W[1:, 0] = 1.0
    L = np.asarray(graphmod.laplacian(W))
    with pytest.raises(pm.OverfullSlotsError):
        pm.partition_general(L, 1, block=(8, 8), max_slots=1,
                             order=np.arange(n))
    # generous budget: packs fine and stays exact
    parts = pm.partition_general(L, 1, block=(8, 8), max_slots=8,
                                 order=np.arange(n))
    assert _roundtrip_err(L, parts) < 1e-6


def test_partition_block_ell_overfull_raises():
    import jax

    g = graphmod.sensor_graph(jax.random.PRNGKey(0), n=64, kappa=0.3)
    gs, _ = graphmod.spatial_sort(g)
    with pytest.raises(pm.OverfullSlotsError):
        partition_block_ell(np.asarray(gs.laplacian()), 4, block=(8, 8),
                            max_slots=1)
    # and the default (max_slots=None) still packs losslessly
    parts, leak = partition_block_ell(np.asarray(gs.laplacian()), 4,
                                      block=(8, 8))
    assert leak < 1e-8


# ---------------------------------------------------------------------------
# Measured 2K|E| == closed form, exactly, on a non-banded 8-shard mesh
# ---------------------------------------------------------------------------
PAYLOAD = r"""
import numpy as np, jax
from repro.core.wavelets import sgwt_multipliers
from repro.dist import GraphOperator, verify_message_scaling
from repro.dist import partition as pm
from repro.dist.quantize import tile_wire_bytes

csr, meta = pm.community_graph_csr(256, n_communities=8, seed=5)
E = csr.n_edges
op = GraphOperator(P=csr.to_dense(),
                   multipliers=sgwt_multipliers(meta["lmax"], 3),
                   lmax=meta["lmax"], K=9)
mesh = jax.make_mesh((8,), ("graph",))
parts = pm.partition_general(csr, 8, block=(8, 8))
assert len(parts.offsets) > 2, (
    "fixture is effectively banded — offsets %r" % (parts.offsets,))

for backend in ("halo", "pallas_halo"):
    plan = op.plan(backend, mesh=mesh, partition=parts)
    v = verify_message_scaling(plan, E, n=256, batch=64)
    assert v["max_rel_dev"] == 0.0, (backend, v["measured"], v["predicted"])
    assert v["measured"]["apply"] == 2 * op.K * E
    assert v["measured"]["apply_gram"] == 4 * op.K * E
    assert v["per_signal_messages"]["apply"] == 2 * op.K * E / 64

# bytes per round == boundary size x dtype wire width, per exchange dtype
for dt in ("f32", "bf16", "int8"):
    plan = op.plan("pallas_halo", mesh=mesh, partition=parts,
                   exchange_dtype=dt)
    v = verify_message_scaling(plan, E, n=256)
    s = v["stats"]["apply"]
    got = s["bytes_per_shard"] / s["exchange_rounds"]
    want = sum(tile_wire_bytes(h, dt) for h in parts.tile_widths)
    assert got == want, (dt, got, want)
    assert want == parts.wire_bytes_per_round(dt)
print("OK")
"""


def test_message_scaling_exact_8_shards():
    assert "OK" in run_payload(PAYLOAD, n_devices=8)
