"""Fault injection (repro.dist.faults): spec validation, the
clean-path identity, and the 8-shard determinism / degradation gates.

The contract under test is the module's three-part promise:

* an inactive spec (None, p=0) is the *bitwise* clean path — same trace,
  same cache entries, same numbers;
* an active spec is a pure function of (seed, shard, round, link) — the
  same seed replays the identical fault trace on every backend and
  partition;
* every fault is receiver-side, after the ppermute — commstats keeps
  measuring exactly the paper's 2K|E| rounds under any injected
  configuration (the schedule half is also CI-gated by
  ``JX-FAULT-NO-EXTRA-COLLECTIVES``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _subproc import run_payload
from repro.dist import faults


# ---------------------------------------------------------------------------
# Spec plumbing (single device)
# ---------------------------------------------------------------------------
def test_fault_spec_validation():
    s = faults.FaultSpec(drop_prob=0.1, stale_prob=0.2, noise_prob=0.3,
                         seed=7)
    assert s.active and s.seed == 7
    assert not faults.FaultSpec().active
    for bad in ({"drop_prob": -0.1}, {"stale_prob": 1.5},
                {"noise_prob": 2.0}):
        with pytest.raises(ValueError):
            faults.FaultSpec(**bad)


def test_resolve_fault_spec_forms():
    assert faults.resolve_fault_spec(None) is None
    s = faults.FaultSpec(drop_prob=0.25)
    assert faults.resolve_fault_spec(s) is s
    assert faults.resolve_fault_spec(0.25) == s
    assert faults.resolve_fault_spec({"drop_prob": 0.25}) == s
    with pytest.raises(TypeError):
        faults.resolve_fault_spec(True)   # bool is not a probability
    with pytest.raises(TypeError):
        faults.resolve_fault_spec("0.25")


def test_fault_key_identity():
    # inactive collapses to "none": a p=0 plan may share the clean cache
    assert faults.fault_key(None) == "none"
    assert faults.fault_key(faults.FaultSpec()) == "none"
    assert faults.fault_key(0.0, "hold_last") == "none"
    k1 = faults.fault_key(0.1, "zero_fill")
    k2 = faults.fault_key(0.1, "hold_last")
    k3 = faults.fault_key({"drop_prob": 0.1, "seed": 1}, "zero_fill")
    assert len({k1, k2, k3, "none"}) == 4
    with pytest.raises(ValueError):
        faults.fault_key(0.1, "hold_first")


def test_make_injector_gating():
    # inactive spec or a non-exchanging site -> clean path (None)
    assert faults.make_injector(None, "zero_fill", "graph", True) is None
    assert faults.make_injector(0.0, "zero_fill", "graph", True) is None
    assert faults.make_injector(0.5, "zero_fill", "graph", False) is None
    inj = faults.make_injector(0.5, "hold_last", "graph", True)
    assert inj is not None and inj.degradation == "hold_last"
    # degradation typos raise even when the spec is inactive
    with pytest.raises(ValueError):
        faults.make_injector(None, "zerofill", "graph", True)


def test_spec_info_jsonable():
    import json
    assert faults.spec_info(None) is None
    d = faults.spec_info({"drop_prob": 0.1, "seed": 3})
    assert d == {"drop_prob": 0.1, "stale_prob": 0.0, "noise_prob": 0.0,
                 "seed": 3}
    json.dumps(d)


def test_plan_info_and_compat_key_carry_fault_identity():
    from repro.core import graph
    from repro.dist import GraphOperator
    from repro.serve.request import compat_key

    g = graph.path_graph(32)
    lmax = g.lambda_max_bound()
    op = GraphOperator(P=g.laplacian(),
                       multipliers=[lambda lam: jnp.exp(-lam)],
                       lmax=lmax, K=6)
    mesh = jax.make_mesh((1,), ("graph",))
    clean = op.plan("halo", mesh=mesh)
    assert clean.info["fault_key"] == "none"
    assert clean.info["fault_spec"] is None
    faulted = op.plan("halo", mesh=mesh, fault_spec=0.2,
                      degradation="hold_last")
    assert faulted.info["fault_key"] == faults.fault_key(0.2, "hold_last")
    assert faulted.info["fault_spec"]["drop_prob"] == 0.2
    kc = compat_key("default", clean, "apply", None)
    kf = compat_key("default", faulted, "apply", None)
    assert kc.faults == "none" and kf.faults == faulted.info["fault_key"]
    assert kc != kf and "faults=" in kf.label()


def test_build_rejects_bad_fault_args():
    from repro.core import graph
    from repro.dist import GraphOperator

    g = graph.path_graph(32)
    op = GraphOperator(P=g.laplacian(),
                       multipliers=[lambda lam: lam],
                       lmax=g.lambda_max_bound(), K=4)
    mesh = jax.make_mesh((1,), ("graph",))
    for backend in ("halo", "pallas_halo"):
        with pytest.raises(ValueError):
            op.plan(backend, mesh=mesh, fault_spec=0.1,
                    degradation="drop_everything")
        with pytest.raises(TypeError):
            op.plan(backend, mesh=mesh, fault_spec="lossy")


# ---------------------------------------------------------------------------
# 8-shard determinism / identity / degradation (both backends, both
# partitions, plus the gossip ring)
# ---------------------------------------------------------------------------
PAYLOAD = r"""
import functools
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.dist import FaultSpec, GraphOperator, gossip
from repro.dist.commstats import plan_comm_stats
from repro.dist.partition import community_graph_csr

rng = np.random.default_rng(0)
n, S, K, bw = 256, 8, 10, 8
B = np.zeros((n, n), dtype=np.float32)
for i in range(n):
    lo, hi = max(0, i - bw), min(n, i + bw + 1)
    B[i, lo:hi] = rng.standard_normal(hi - lo) * 0.1
B = np.abs(B + B.T) / 2
L = np.diag(B.sum(1)) - B
lmax = float(2 * B.sum(1).max())
banded_op = GraphOperator(P=jnp.asarray(L),
                          multipliers=[lambda lam: jnp.exp(-lam)],
                          lmax=lmax, K=K)

csr, meta = community_graph_csr(192, n_communities=8, seed=0)
general_op = GraphOperator(P=np.asarray(csr.to_dense()),
                           multipliers=[lambda lam: jnp.exp(-lam)],
                           lmax=meta["lmax"], K=K)

mesh = jax.make_mesh((S,), ("graph",))
spec = FaultSpec(drop_prob=0.2, stale_prob=0.1, noise_prob=0.05, seed=3)

# pallas_halo on the general partition is trimmed: the injector is the
# same exchange-layer code on every backend/partition, its schedule
# equality there is lint-gated (JX-FAULT-NO-EXTRA-COLLECTIVES), and that
# combo's compile time alone pushes the payload past the CI timeout
for op, pkw, backends in ((banded_op, {}, ("halo", "pallas_halo")),
                          (general_op, {"partition": "general"},
                           ("halo",))):
    x = jnp.asarray(rng.standard_normal(
        (op.P.shape[0],)).astype(np.float32))
    for backend in backends:
        for dt in ("f32", "int8"):
            clean = op.plan(backend, mesh=mesh, exchange_dtype=dt, **pkw)
            ref = np.asarray(clean.apply(x))
            # p=0 / None are the bitwise clean path and share its cache key
            for null_spec in (None, FaultSpec(seed=99)):
                p0 = op.plan(backend, mesh=mesh, exchange_dtype=dt,
                             fault_spec=null_spec,
                             degradation="hold_last", **pkw)
                assert p0.info["fault_key"] == "none"
                assert np.array_equal(np.asarray(p0.apply(x)), ref), (
                    backend, dt, pkw, null_spec)
            # same seed -> bitwise-identical faulted runs (fresh plans)
            runs = [np.asarray(
                op.plan(backend, mesh=mesh, exchange_dtype=dt,
                        fault_spec=spec, degradation="zero_fill",
                        **pkw).apply(x)) for _ in range(2)]
            assert np.array_equal(runs[0], runs[1]), (backend, dt, pkw)
            # active faults really perturb, boundedly
            err = float(np.abs(runs[0] - ref).max())
            assert err > 0 and np.isfinite(runs[0]).all(), (
                backend, dt, pkw, err)
            # a different seed replays a different trace
            other = np.asarray(
                op.plan(backend, mesh=mesh, exchange_dtype=dt,
                        fault_spec=FaultSpec(drop_prob=0.2, stale_prob=0.1,
                                             noise_prob=0.05, seed=4),
                        degradation="zero_fill", **pkw).apply(x))
            assert not np.array_equal(other, runs[0]), (backend, dt, pkw)
            # hold_last consumes the carried tiles -> a distinct trace
            held = np.asarray(
                op.plan(backend, mesh=mesh, exchange_dtype=dt,
                        fault_spec=spec, degradation="hold_last",
                        **pkw).apply(x))
            assert not np.array_equal(held, runs[0]), (backend, dt, pkw)
            # honest accounting: rounds identical to the clean plan
            faulted = op.plan(backend, mesh=mesh, exchange_dtype=dt,
                              fault_spec=spec, **pkw)
            stc = plan_comm_stats(clean)["apply"]
            stf = plan_comm_stats(faulted)["apply"]
            assert stf.exchange_rounds == stc.exchange_rounds == K
            assert stf.bytes_per_round == stc.bytes_per_round

# the gossip ring rides the SAME injector (link 0/1 = from-left/right)
coeffs = gossip.consensus_coeffs(S)
xg = jnp.arange(S * 4, dtype=jnp.float32).reshape(S, 4) ** 1.1
target = np.asarray(jnp.mean(xg, axis=0))

def run_gossip(fault_spec, degradation="zero_fill", quantize=False):
    @functools.partial(jax.shard_map, mesh=mesh, in_specs=P("graph"),
                       out_specs=P("graph"), check_vma=False)
    def body(xl):
        return gossip.gossip_mean(xl, "graph", coeffs, quantize=quantize,
                                  fault_spec=fault_spec,
                                  degradation=degradation)
    return np.asarray(body(xg))

g_clean = run_gossip(None)
assert np.array_equal(run_gossip(FaultSpec()), g_clean)
g_f1 = run_gossip(spec)
assert np.array_equal(g_f1, run_gossip(spec))          # deterministic
assert not np.array_equal(g_f1, g_clean)               # really faulted
assert np.isfinite(g_f1).all()
gq = run_gossip(spec, quantize=True)                   # noise on int8 wire
assert np.isfinite(gq).all() and not np.array_equal(gq, g_f1)
# bounded degradation is gated at a survivable drop rate: the consensus
# polynomial's Chebyshev weights oscillate, so at drop_prob=0.2 both
# policies overshoot the mean by >1x (the aggressive spec above is only
# for determinism/trace assertions)
mild = FaultSpec(drop_prob=0.05, stale_prob=0.05, noise_prob=0.05, seed=3)
g_mild = run_gossip(mild)
rel = float(np.abs(g_mild - target[None]).max() / np.abs(target).max())
assert rel < 1.0, rel                                  # degraded, bounded
print("FAULTS OK", rel)
"""


def test_faults_8shards():
    out = run_payload(PAYLOAD, n_devices=8, timeout=900)
    assert "FAULTS OK" in out
