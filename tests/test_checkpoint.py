"""Checkpointing: roundtrip, atomicity, retention, resume determinism."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import (latest_checkpoint, load_checkpoint, restore_arrays,
                        save_checkpoint)
from repro.ckpt.checkpoint import wait_pending

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 3)),
            "nested": {"b": jnp.arange(5, dtype=jnp.int32)}}


def test_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 7, {"params": tree}, extra={"note": "x"})
    path = latest_checkpoint(str(tmp_path))
    step, trees, extra = load_checkpoint(path)
    assert step == 7 and extra["note"] == "x"
    restored = restore_arrays(trees["params"], tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention_and_latest(tmp_path):
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, {"params": _tree(s)}, keep_last=2)
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_00000004", "step_00000005"]
    assert latest_checkpoint(str(tmp_path)).endswith("step_00000005")


def test_async_save_visible_after_wait(tmp_path):
    save_checkpoint(str(tmp_path), 9, {"params": _tree()}, async_save=True)
    wait_pending()
    assert latest_checkpoint(str(tmp_path)).endswith("step_00000009")


def test_no_partial_checkpoint_visible(tmp_path):
    """tmp dirs are never picked up by latest_checkpoint."""
    os.makedirs(tmp_path / "step_00000003.tmp123")
    assert latest_checkpoint(str(tmp_path)) is None


def test_restore_casts_dtype(tmp_path):
    tree = {"w": jnp.ones((3,), jnp.float32)}
    save_checkpoint(str(tmp_path), 1, {"params": tree})
    _, trees, _ = load_checkpoint(latest_checkpoint(str(tmp_path)))
    target = {"w": jnp.zeros((3,), jnp.bfloat16)}
    restored = restore_arrays(trees["params"], target)
    assert restored["w"].dtype == jnp.bfloat16


@pytest.mark.slow
def test_fail_and_resume_reproduces_loss(tmp_path):
    """End-to-end fault tolerance: crash at step 12, resume, and the loss
    trajectory matches an uninterrupted run bit-for-bit."""
    env = dict(os.environ, PYTHONPATH=SRC)
    base = [sys.executable, "-m", "repro.launch.train", "--arch",
            "qwen1.5-4b", "--smoke", "--steps", "18", "--batch", "2",
            "--seq", "16", "--ckpt-every", "6", "--log-every", "1"]

    ref = subprocess.run(base + ["--ckpt-dir", str(tmp_path / "ref")],
                         env=env, capture_output=True, text=True, timeout=600)
    assert ref.returncode == 0, ref.stderr

    crash = subprocess.run(
        base + ["--ckpt-dir", str(tmp_path / "ft"), "--fail-at-step", "12"],
        env=env, capture_output=True, text=True, timeout=600)
    assert crash.returncode == 42
    resume = subprocess.run(
        base + ["--ckpt-dir", str(tmp_path / "ft"), "--resume"],
        env=env, capture_output=True, text=True, timeout=600)
    assert resume.returncode == 0, resume.stderr

    def losses(out):
        return {l.split()[2]: l.split()[4] for l in out.splitlines()
                if l.startswith("[train] step")}

    ref_l = losses(ref.stdout)
    res_l = losses(resume.stdout)
    for step in ("12", "15", "17"):
        assert ref_l[step] == res_l[step], (step, ref_l[step], res_l[step])
