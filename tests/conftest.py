import os
import sys

# Tests run on the default single CPU device; multi-device tests spawn
# subprocesses with XLA_FLAGS (see tests/_subproc.py) so this process never
# forces a device count.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest

from repro.core import graph


@pytest.fixture(scope="session")
def sensor120():
    """Small connected sensor graph shared by core tests."""
    g, _ = graph.connected_sensor_graph(
        jax.random.PRNGKey(0), n=120, theta=0.2, kappa=0.25
    )
    return g


@pytest.fixture(scope="session")
def sensor_banded():
    """Strip-sorted banded sensor graph for sharded-path tests."""
    g, _ = graph.connected_sensor_graph(
        jax.random.PRNGKey(1), n=600, theta=0.07, kappa=0.07
    )
    gs, _ = graph.spatial_sort(g)
    return gs
