"""Section-V solver subsystem: `plan.solve` runs Jacobi / accelerated
Jacobi / ARMA / Chebyshev under every registered backend, batched, with
measured communication (the PR-4 tentpole)."""
import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _subproc import run_payload
from repro.core import arma, filters, graph, jacobi
from repro.dist import GraphOperator, get_backend, register_backend
from repro.dist.backends import _REGISTRY
from repro.dist.solvers import METHODS

BACKENDS = ["dense", "pallas", "halo", "pallas_halo", "allgather"]

TAU = 0.5


@pytest.fixture(scope="module")
def solver_setup():
    """Small sensor graph + scalar SSL multiplier op (P = L_norm)."""
    g, _ = graph.connected_sensor_graph(
        jax.random.PRNGKey(0), n=120, theta=0.2, kappa=0.25)
    Ln = np.asarray(g.laplacian("normalized"))
    op = GraphOperator(
        P=jnp.asarray(Ln),
        multipliers=[filters.ssl_multiplier(filters.power_kernel(1), TAU)],
        lmax=2.0, K=12)
    y = jax.random.normal(jax.random.PRNGKey(1), (g.n_vertices,))
    direct = np.linalg.solve((TAU * np.eye(Ln.shape[0]) + Ln) / TAU,
                             np.asarray(y))
    # exact spectral radius of the Jacobi split (for cheb_jacobi precision)
    Q = (TAU * np.eye(Ln.shape[0]) + Ln) / TAU
    QD = np.diag(np.diag(Q))
    rho = float(np.abs(np.linalg.eigvals(np.linalg.solve(QD, QD - Q))).max())
    return g, Ln, op, y, direct, rho


def _plan(op, backend):
    if backend in ("halo", "pallas_halo", "allgather"):
        return op.plan(backend, mesh=jax.make_mesh((1,), ("graph",)))
    return op.plan(backend)


def _method_kwargs(method, rho):
    if method == "chebyshev":
        return dict(n_iters=40)
    if method == "jacobi":
        return dict(n_iters=250)
    if method == "cheb_jacobi":
        return dict(n_iters=50, rho=rho * 1.0001)
    return dict(n_iters=250)  # arma


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("method", METHODS)
def test_solve_matches_direct_solution(solver_setup, backend, method):
    """All four methods on all five backends converge to the dense direct
    solve of (tau I + L_norm) x = tau y."""
    g, Ln, op, y, direct, rho = solver_setup
    plan = _plan(op, backend)
    res = plan.solve(y, method, tau=TAU, r=1,
                     **_method_kwargs(method, rho))
    assert res.method == method and res.backend == backend
    assert res.x.shape == y.shape
    np.testing.assert_allclose(np.asarray(res.x), direct, atol=2e-4)


@pytest.mark.parametrize("backend", BACKENDS)
def test_solve_batched_matches_per_signal(solver_setup, backend):
    """Batched (B, N) solves equal the per-signal loop (B=64) — the
    (..., N) contract extends to every solver method."""
    g, Ln, op, _, _, rho = solver_setup
    B = 64
    Y = jax.random.normal(jax.random.PRNGKey(2), (B, g.n_vertices))
    plan = _plan(op, backend)
    for method in METHODS:
        kw = dict(tau=TAU, r=1, n_iters=12)
        if method == "cheb_jacobi":
            kw["rho"] = rho * 1.0001
        res = plan.solve(Y, method, **kw)
        assert res.x.shape == (B, g.n_vertices)
        # spot-check a few batch rows against single-signal solves
        for b in (0, 17, 63):
            single = plan.solve(Y[b], method, **kw)
            np.testing.assert_allclose(np.asarray(res.x[b]),
                                       np.asarray(single.x), atol=1e-4,
                                       err_msg=f"{method} row {b}")


def test_solve_single_reference_is_core_functions(solver_setup):
    """plan.solve('jacobi'/'arma') on the dense backend reproduces the
    single-signal core/jacobi.py and core/arma.py references exactly."""
    g, Ln, op, y, direct, rho = solver_setup
    plan = op.plan("dense")
    mv = lambda v: jnp.einsum("ij,...j->...i", jnp.asarray(Ln), v)

    qmv, qdiag = jacobi.tikhonov_q(mv, jnp.diag(jnp.asarray(Ln)), TAU)
    ref = jacobi.jacobi_solve(qmv, qdiag, y, 40)
    res = plan.solve(y, "jacobi", tau=TAU, r=1, n_iters=40)
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(ref),
                               atol=1e-5)

    ref_c = jacobi.jacobi_chebyshev_solve(qmv, qdiag, y, rho * 1.0001, 25)
    res_c = plan.solve(y, "cheb_jacobi", tau=TAU, r=1, n_iters=25,
                       rho=rho * 1.0001)
    np.testing.assert_allclose(np.asarray(res_c.x), np.asarray(ref_c),
                               atol=1e-5)

    r, p, const = arma.arma_tikhonov_first_order(TAU, 2.0)
    ref_a = arma.arma_apply(mv, y, r, p, 2.0, n_iters=60, const=const)
    res_a = plan.solve(y, "arma", tau=TAU, r=1, n_iters=60)
    np.testing.assert_allclose(np.asarray(res_a.x), np.asarray(ref_a),
                               atol=1e-5)
    assert res_a.info["arma_stable"] is True
    # explicit pole/residue form (as returned by the Section V-E presets)
    res_p = plan.solve(y, "arma", poles=p, residues=r, const=const,
                       n_iters=60)
    np.testing.assert_allclose(np.asarray(res_p.x), np.asarray(ref_a),
                               atol=1e-5)


def test_solve_history_hooks(solver_setup):
    """history=True records per-round iterates; history_errors decreases
    toward the fixed point for a convergent method."""
    g, Ln, op, y, direct, rho = solver_setup
    for backend in ("dense", "halo"):
        plan = _plan(op, backend)
        for method, extra in (("jacobi", {}), ("cheb_jacobi",
                                               {"rho": rho * 1.0001}),
                              ("arma", {}), ("chebyshev", {})):
            res = plan.solve(y, method, tau=TAU, n_iters=30, history=True,
                             **extra)
            assert res.history.shape == (30, g.n_vertices), method
            # final history entry is the returned solution
            np.testing.assert_allclose(np.asarray(res.history[-1]),
                                       np.asarray(res.x), atol=1e-6,
                                       err_msg=method)
        res = plan.solve(y, "jacobi", tau=TAU, n_iters=30, history=True)
        errs = res.history_errors(jnp.asarray(direct))
        assert errs.shape == (30,)
        assert errs[-1] < errs[0] * 0.1


def test_solve_chebyshev_defaults_to_op_multiplier(solver_setup):
    """Without a rational spec, method='chebyshev' approximates the plan's
    own scalar multiplier — matching plan.apply at the same order."""
    g, Ln, op, y, _, _ = solver_setup
    plan = op.plan("dense")
    res = plan.solve(y, "chebyshev")
    np.testing.assert_allclose(np.asarray(res.x),
                               np.asarray(plan.apply(y)[0]), atol=1e-6)
    assert res.n_iters == op.K


def test_solve_requires_rational_spec_for_iterative_methods(solver_setup):
    g, Ln, op, y, _, _ = solver_setup
    plan = op.plan("dense")
    with pytest.raises(ValueError, match="rational filter spec"):
        plan.solve(y, "jacobi")
    with pytest.raises(ValueError, match="unknown solve method"):
        plan.solve(y, "gauss_seidel")


def test_cheb_jacobi_rejects_divergent_split(solver_setup):
    """rho >= 1 (the Fig. 2(c) regime) raises instead of silently
    diverging."""
    g, Ln, op, y, _, _ = solver_setup
    plan = op.plan("dense")
    with pytest.raises(ValueError, match="spectral-radius"):
        plan.solve(y, "cheb_jacobi", tau=TAU, r=1, n_iters=10, rho=1.3)


def test_divergence_guard_off_by_default(solver_setup):
    """check_every=0 is exactly the old behavior: no residual evaluation,
    no guard keys in info."""
    g, Ln, op, y, _, _ = solver_setup
    plan = op.plan("dense")
    res = plan.solve(y, "jacobi", tau=TAU, n_iters=20)
    assert "diverged" not in res.info and "residual" not in res.info
    with pytest.raises(ValueError, match="check_every"):
        plan.solve(y, "jacobi", tau=TAU, n_iters=20, check_every=-1)


@pytest.mark.parametrize("backend", ["dense", "halo"])
def test_guarded_jacobi_matches_unguarded(solver_setup, backend):
    """Jacobi is stationary, so chunked-with-checks reproduces the
    unchunked trajectory; the guard reports an honest residual and the
    extra exchange rounds it spent measuring it."""
    g, Ln, op, y, direct, _ = solver_setup
    plan = _plan(op, backend)
    base = plan.solve(y, "jacobi", tau=TAU, n_iters=20)
    res = plan.solve(y, "jacobi", tau=TAU, n_iters=20, check_every=7)
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(base.x),
                               atol=1e-6)
    assert res.n_iters == 20 and not res.info["diverged"]
    assert res.info["check_every"] == 7 and res.info["rounds_run"] == 20
    assert np.isfinite(res.info["residual"])
    assert len(res.info["residual_history"]) == 3      # ceil(20/7) checks
    assert res.info["exchange_rounds"] > base.info["exchange_rounds"]


def test_guarded_jacobi_stops_early_on_divergence(solver_setup, caplog):
    """A demonstrably diverging rational split exits early with
    info['diverged']=True instead of returning garbage silently."""
    g, Ln, op, y, _, _ = solver_setup
    plan = op.plan("dense")
    # den whose Jacobi split has off-diagonal mass >> diagonal: the
    # iteration matrix's spectral radius exceeds 1, iterates blow up
    with caplog.at_level(logging.WARNING, logger="repro.dist.solvers"):
        res = plan.solve(y, "jacobi", num=(1.0,), den=(1.0, -5.0, 1.0),
                         n_iters=60, check_every=5)
    assert res.info["diverged"]
    assert res.n_iters < 60 and res.info["rounds_run"] < 60
    hist = res.info["residual_history"]
    assert hist[-1] > 2.0 or not np.isfinite(hist[-1])
    assert any("diverged" in r.message for r in caplog.records)


@pytest.mark.parametrize("method", ["cheb_jacobi", "chebyshev"])
def test_post_solve_check_reports_honest_residual(solver_setup, method):
    """Methods whose trajectory cannot restart exactly take a single
    post-solve residual/NaN check under check_every>0."""
    g, Ln, op, y, _, rho = solver_setup
    plan = op.plan("dense")
    kwargs = dict(tau=TAU, n_iters=24, check_every=8)
    if method == "cheb_jacobi":
        kwargs.update(r=1, rho=rho)
    res = plan.solve(y, method, **kwargs)
    assert res.info["diverged"] is False
    if method == "chebyshev" and res.info["residual"] is not None:
        assert np.isfinite(res.info["residual"])
    if method == "cheb_jacobi":
        assert res.info["residual"] < 0.5


def test_inverse_filter_solved_distributed(solver_setup):
    """Prop. 3 deconvolution for a polynomial blur: plan.solve on the
    inverse_filter_rational spec matches the dense direct solve of
    (tau Psi^2 + 2 L) f = tau Psi y."""
    g, Ln, op, y, _, _ = solver_setup
    N = Ln.shape[0]
    psi = (1.0, -0.3)  # g_psi(lambda) = 1 - 0.3 lambda (polynomial blur)
    tau, r = 1.0, 1
    num, den = filters.inverse_filter_rational(psi, tau, r)
    Psi = psi[0] * np.eye(N) + psi[1] * Ln
    direct = np.linalg.solve(tau * Psi @ Psi + 2.0 * Ln,
                             tau * Psi @ np.asarray(y))
    for backend in ("dense", "pallas_halo"):
        plan = _plan(op, backend)
        res = plan.solve(y, "jacobi", num=num, den=den, n_iters=400)
        np.testing.assert_allclose(np.asarray(res.x), direct, atol=5e-4)
        assert res.info["matvecs_per_round"] == 2  # deg(den) = 2
    # the rational spec evaluates to filters.inverse_filter pointwise
    lam = np.linspace(0.0, 2.0, 50)
    gp = lambda l: psi[0] + psi[1] * np.asarray(l)
    expect = filters.inverse_filter(gp, tau, r)(lam)
    got = (np.polyval(num[::-1], lam) / np.polyval(den[::-1], lam))
    np.testing.assert_allclose(got, expect, atol=1e-12)


def test_solve_falls_back_without_runner(solver_setup, caplog):
    """A backend registered without matvec_runner still solves (reference
    matvec) and the forfeit is logged."""
    g, Ln, op, y, direct, _ = solver_setup

    @register_backend("_test_norunner")
    def build(op, *, mesh=None, partition=None, **options):
        import dataclasses

        plan = get_backend("dense")(op)
        return dataclasses.replace(plan, backend="_test_norunner",
                                   matvec_runner=None)

    try:
        plan = op.plan("_test_norunner")
        with caplog.at_level(logging.INFO, logger="repro.dist.solvers"):
            res = plan.solve(y, "jacobi", tau=TAU, n_iters=250)
        assert any("no matvec_runner" in r.message for r in caplog.records)
        np.testing.assert_allclose(np.asarray(res.x), direct, atol=2e-4)
    finally:
        _REGISTRY.pop("_test_norunner", None)


def test_jacobi_update_kernel_matches_ref():
    """Fused jacobi_step kernel (interpret mode) == jnp oracle, batched and
    non-128-multiple sizes included."""
    from repro.kernels import ref
    from repro.kernels.jacobi_step import jacobi_step

    rng = np.random.default_rng(3)
    n = 300  # 300 % 128 != 0 — exercises the internal pad
    for shape in [(n,), (7, n)]:
        qx = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        x = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        xp = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        y = jnp.asarray(rng.standard_normal((n,)), jnp.float32)
        invd = jnp.asarray(rng.standard_normal((n,)), jnp.float32)
        out_k = jacobi_step(qx, x, xp, y, invd, w=1.7, s=0.3,
                            interpret=True)
        out_r = ref.jacobi_step_ref(qx, x, xp, y, invd, w=1.7, s=0.3)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   atol=1e-5)


def test_arma_from_rational_matches_presets():
    """The generic partial-fraction path reproduces the Section V-E
    presets (first/second-order Tikhonov, third-order random walk)."""
    tau, lmax = 0.5, 2.0
    lam = np.linspace(0.0, 1.9, 40)
    cases = [
        (filters.power_rational(tau, 1),
         arma.arma_tikhonov_first_order(tau, lmax)),
        (filters.power_rational(tau, 2),
         arma.arma_tikhonov_second_order(tau, lmax)),
        (filters.random_walk_rational(tau, 2.0, 3),
         arma.arma_random_walk_3(tau, lmax)),
    ]
    for (num, den), (r0, p0, c0) in cases:
        r1, p1, c1 = arma.arma_from_rational(num, den, lmax)
        assert c1 == c0
        np.testing.assert_allclose(
            arma.arma_eval(r1, p1, lam, lmax, const=c1),
            arma.arma_eval(r0, p0, lam, lmax, const=c0), atol=1e-8)
    with pytest.raises(ValueError, match="repeated roots"):
        arma.arma_from_rational((1.0,), (1.0, 2.0, 1.0), lmax)  # (1+l)^2


PAYLOAD = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import filters, graph
from repro.dist import GraphOperator, solve_comm_stats

key = jax.random.PRNGKey(1)
g, key = graph.connected_sensor_graph(key, n=600, theta=0.07, kappa=0.07)
gs, _ = graph.spatial_sort(g)
L = jnp.asarray(gs.laplacian())
lmax = gs.lambda_max_bound()
tau = 0.5
op = GraphOperator(P=L, multipliers=[filters.tikhonov(tau, 2)],
                   lmax=lmax, K=12)
mesh = jax.make_mesh((8,), ("graph",),
                     axis_types=(jax.sharding.AxisType.Auto,))
y = jax.random.normal(key, (600,))
B = 64
Y = jax.random.normal(jax.random.PRNGKey(3), (B, 600))
kw = dict(tau=tau, r=2, h_scale=1.0)   # den = tau + lambda^2: 2 mv/round

dense = op.plan("dense")
refs = {m: dense.solve(y, m, n_iters=10, **kw).x for m in
        ("chebyshev", "jacobi", "arma")}
refB = dense.solve(Y, "jacobi", n_iters=10, **kw).x
for backend in ("halo", "pallas_halo", "allgather"):
    plan = op.plan(backend, mesh=mesh)
    for m, ref in refs.items():
        out = plan.solve(y, m, n_iters=10, **kw).x
        assert float(jnp.abs(out - ref).max()) < 1e-4, (backend, m)
    outB = plan.solve(Y, "jacobi", n_iters=10, **kw).x
    assert outB.shape == (B, 600)
    assert float(jnp.abs(outB - refB).max()) < 1e-4, backend
    # measured communication: Fig. 2(b)'s Jacobi rounds cost 2 matvecs
    st = solve_comm_stats(plan, "jacobi", n_iters=10, **kw)
    assert st.exchange_rounds == 20, (backend, st.exchange_rounds)
    stB = solve_comm_stats(plan, "jacobi", n_iters=10, batch=B, **kw)
    assert stB.exchange_rounds == 20, (backend, "batched", stB.exchange_rounds)
    st_c = solve_comm_stats(plan, "chebyshev", n_iters=12, **kw)
    assert st_c.exchange_rounds == 12, backend
    # ARMA: stacked poles ride ONE exchange per round
    st_a = solve_comm_stats(plan, "arma", n_iters=15, **kw)
    assert st_a.exchange_rounds == 15, backend
    print(backend, "OK", st.exchange_rounds, st.bytes_per_shard)
print("SOLVERS OK")
"""


def test_solvers_match_dense_8shards():
    """Genuinely sharded (8 forced host devices) plan.solve matches the
    dense reference for every method, stays batch-equivalent at B=64, and
    the measured exchange rounds land on the closed forms (20 = 10 Jacobi
    iterations x 2 matvecs for den = tau + lambda^2; rounds batch-
    invariant; ARMA poles share one exchange per round)."""
    out = run_payload(PAYLOAD, n_devices=8)
    assert "SOLVERS OK" in out
