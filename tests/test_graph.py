import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graph


def test_laplacian_psd_and_rowsum(sensor120):
    L = np.asarray(sensor120.laplacian())
    assert np.allclose(L, L.T, atol=1e-6)
    lam = np.linalg.eigvalsh(L)
    assert lam[0] > -1e-4                       # PSD
    assert np.abs(L.sum(axis=1)).max() < 1e-3   # zero row sums


def test_lambda_max_bound_dominates(sensor120):
    L = np.asarray(sensor120.laplacian())
    lam_max = np.linalg.eigvalsh(L)[-1]
    assert sensor120.lambda_max_bound() >= lam_max - 1e-4


def test_normalized_laplacian_spectrum(sensor120):
    Ln = np.asarray(sensor120.laplacian("normalized"))
    lam = np.linalg.eigvalsh(Ln)
    assert lam[0] > -1e-5 and lam[-1] < 2.0 + 1e-5


def test_sensor_graph_matches_paper_construction():
    g = graph.sensor_graph(jax.random.PRNGKey(0), n=500)
    W = np.asarray(g.W)
    assert W.shape == (500, 500)
    assert np.allclose(W, W.T)
    assert np.all(np.diag(W) == 0)
    # weights only inside the kappa radius, Gaussian kernel values in (0, 1]
    nz = W[W > 0]
    assert nz.min() > 0 and nz.max() <= 1.0
    coords = np.asarray(g.coords)
    d2 = ((coords[:, None] - coords[None, :]) ** 2).sum(-1)
    assert np.all(d2[W > 0] <= 0.075**2 + 1e-9)


def test_k_scaling_matrix_reduces_to_lnorm(sensor120):
    S0 = np.asarray(graph.k_scaling_matrix(sensor120.W, gamma=0.0))
    Ln = np.asarray(sensor120.laplacian("normalized"))
    assert np.allclose(S0, Ln, atol=1e-5)


def test_block_ell_roundtrip_and_matvec(sensor120):
    L = np.asarray(sensor120.laplacian())
    A = graph.to_block_ell(L, (8, 128))
    x = np.random.RandomState(0).randn(A.padded_n).astype(np.float32)
    y = graph.block_ell_matvec_ref(A, jnp.asarray(x))
    y_ref = np.pad(L, ((0, A.padded_n - L.shape[0]),) * 2) @ x
    np.testing.assert_allclose(np.asarray(y)[: L.shape[0]],
                               y_ref[: L.shape[0]], atol=1e-4)


def test_spatial_sort_banded_partition(sensor_banded):
    from repro.core.distributed import partition_banded

    L = np.asarray(sensor_banded.laplacian())
    parts, leak = partition_banded(L, 8)
    assert leak == 0.0
    dense = np.zeros((parts.n_shards * parts.n_local,) * 2, np.float32)
    nl = parts.n_local
    for s in range(parts.n_shards):
        r = slice(s * nl, (s + 1) * nl)
        dense[r, r] = np.asarray(parts.diag[s])
        if s > 0:
            dense[r, slice((s - 1) * nl, s * nl)] = np.asarray(parts.left[s])
        if s < parts.n_shards - 1:
            dense[r, slice((s + 1) * nl, (s + 2) * nl)] = np.asarray(parts.right[s])
    np.testing.assert_allclose(dense[: L.shape[0], : L.shape[0]], L, atol=1e-6)


def test_ring_and_torus_graphs():
    r = graph.ring_graph(8)
    assert r.degrees().min() == r.degrees().max() == 2.0
    t = graph.torus_graph(4, 4)
    assert t.degrees().min() == t.degrees().max() == 4.0
    assert r.is_connected() and t.is_connected()
