"""Sharding rules + roofline parser unit tests (no multi-device needed —
specs are pure metadata)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import ShardingRules, make_rules
from repro.launch.roofline import Roofline, collective_stats, _type_bytes


class FakeMesh:
    """Duck-typed mesh: rules only need axis_names/axis_sizes."""

    def __init__(self, sizes):
        self.axis_names = tuple(sizes)
        self.axis_sizes = tuple(sizes.values())


def test_default_scheme_tp_axes():
    r = make_rules.__wrapped__ if hasattr(make_rules, "__wrapped__") else None
    rules = ShardingRules(
        mapping={"batch": ("pod", "data"), "heads": "model", "embed": None},
        mesh=FakeMesh({"data": 16, "model": 16}),
    )
    assert rules.spec("batch", "seq", "embed") == P("data", None, None)
    assert rules.spec(None, "heads") == P(None, "model")


def test_spec_deduplicates_mesh_axes():
    rules = ShardingRules(
        mapping={"batch": ("data", "model"), "embed": ("data", "model")},
        mesh=FakeMesh({"data": 16, "model": 16}),
    )
    # batch consumes both axes; embed must come out unsharded
    spec = rules.spec("batch", "embed")
    assert spec == P(("data", "model"), None)


def test_fsdp_scheme_weights_vs_activations():
    mesh = FakeMesh({"data": 16, "model": 16})
    from repro.dist.sharding import _BASE, _SCHEMES

    mapping = dict(_BASE)
    mapping.update(_SCHEMES["fsdp"])
    rules = ShardingRules(mapping=mapping, mesh=mesh)
    # weights: embed fully sharded, no TP on heads
    assert rules.spec("layers", "embed", "heads") == P(None, ("data", "model"), None)
    # activations: batch eats all axes, embed unsharded
    assert rules.spec("batch", "seq", "embed") == P(("data", "model"), None, None)
    # MoE: groups on data, experts on model
    assert rules.spec("moe_group", "expert", None, None) == P("data", "model", None, None)


def test_null_rules_are_noops():
    rules = ShardingRules.null()
    x = jax.numpy.ones((4, 4))
    assert rules.constrain(x, "batch", "embed") is x
    assert rules.spec("batch") == P(None)


# ---------------------------------------------------------------------------
# roofline HLO parsing
# ---------------------------------------------------------------------------
def test_type_bytes():
    assert _type_bytes("f32[16,4096]") == 16 * 4096 * 4
    assert _type_bytes("(bf16[8,2], f32[4])") == 8 * 2 * 2 + 4 * 4
    assert _type_bytes("f8e4m3fn[10]") == 10
    assert _type_bytes("pred[]") == 1


def test_collective_stats_parses_ops():
    hlo = """
  %ar = f32[16,1024]{1,0} all-reduce(%x), replica_groups=[16,16]<=[256]
  %ag = bf16[4,512]{1,0} all-gather(%y), dimensions={0}
  %cp = f32[8]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %not_a_coll = f32[2]{0} add(%a, %b)
"""
    st = collective_stats(hlo, top_k=3)
    assert st["count_by_op"]["all-reduce"] == 1
    assert st["count_by_op"]["all-gather"] == 1
    assert st["count_by_op"]["collective-permute"] == 1
    ar_bytes = 16 * 1024 * 4 * 2  # x2 ring multiplier
    assert st["bytes_by_op"]["all-reduce"] == ar_bytes
    # bf16 correction halves the f32 AR contribution
    assert st["collective_bytes_bf16_corrected"] == (
        st["collective_bytes_per_device"] - ar_bytes // 2)
    assert st["top_collectives"][0]["op"] == "all-reduce"


def test_roofline_terms_and_dominance():
    r = Roofline(flops_per_device=197e12, bytes_per_device=819e9 / 2,
                 collective_bytes_per_device=50e9 / 4)
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 0.5) < 1e-9
    assert abs(r.collective_s - 0.25) < 1e-9
    assert r.dominant == "compute"
    assert abs(r.compute_fraction - 1.0) < 1e-9


def test_fit_spec_trims_uneven_dims():
    from repro.launch.dryrun import _fit_one

    mesh = FakeMesh({"data": 16, "model": 16})
    spec = _fit_one(jax.ShapeDtypeStruct((1, 2048), np.float32),
                    P("data", "model"), mesh)
    assert spec == P(None, "model")   # batch=1 can't shard
    spec = _fit_one(jax.ShapeDtypeStruct((40,), np.float32), P("model"), mesh)
    assert spec == P(None)            # 40 % 16 != 0
    spec = _fit_one(jax.ShapeDtypeStruct((256, 64), np.float32),
                    P(("data", "model"), None), mesh)
    assert spec == P(("data", "model"), None)
