"""Helper to run multi-device test payloads in a subprocess with forced host
devices (keeps the main pytest process single-device)."""
import os
import subprocess
import sys

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_payload(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_devices} "
        + env.get("XLA_FLAGS", "")
    )
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", code],
        env=env, capture_output=True, text=True, timeout=timeout,
    )
    assert proc.returncode == 0, (
        f"subprocess failed (rc={proc.returncode})\n"
        f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}"
    )
    return proc.stdout
