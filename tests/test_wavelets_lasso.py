"""Section VI: SGWT frame + distributed lasso (Algorithm 3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lasso, wavelets
from repro.core.multiplier import UnionMultiplier
from repro.data.pipeline import graph_signal_batch


@pytest.fixture(scope="module")
def op(sensor120):
    lmax = sensor120.lambda_max_bound()
    return UnionMultiplier(
        P=sensor120.laplacian(),
        multipliers=wavelets.sgwt_multipliers(lmax, J=4),
        lmax=lmax, K=20,
    )


def test_frame_bounds_positive(sensor120):
    lmax = sensor120.lambda_max_bound()
    A, B = wavelets.frame_bounds(wavelets.sgwt_multipliers(lmax, J=4), lmax)
    assert A > 0 and B < np.inf and B / A < 100


def test_wavelet_kernel_shape():
    g = wavelets.wavelet_kernel()
    # bandpass: zero at origin, unit at the spline knots, decay at infinity
    assert abs(g(0.0)) < 1e-12
    np.testing.assert_allclose(g(1.0), 1.0, atol=1e-12)
    np.testing.assert_allclose(g(2.0), 1.0, atol=1e-12)
    assert g(50.0) < 2e-3


def test_ista_objective_decreases(op, sensor120):
    y = jax.random.normal(jax.random.PRNGKey(8), (sensor120.n_vertices,))
    gamma = lasso.ista_step_size(op)
    res = lasso.distributed_lasso(op, y, mu=0.1, gamma=gamma, n_iters=40,
                                  record_objective=True)
    obj = np.asarray(res.objective)
    assert obj[-1] <= obj[0]
    assert np.all(np.diff(obj) < 1e-3)  # monotone within tolerance


def test_lasso_denoises_piecewise_signal(sensor120):
    """Paper Section VI experiment, reduced: lasso MSE < noisy MSE."""
    key = jax.random.PRNGKey(9)
    f0 = graph_signal_batch(key, sensor120.coords, "piecewise")
    noise = 0.5 * jax.random.normal(key, f0.shape)
    y = f0 + noise
    lmax = sensor120.lambda_max_bound()
    op = UnionMultiplier(P=sensor120.laplacian(),
                         multipliers=wavelets.sgwt_multipliers(lmax, J=4),
                         lmax=lmax, K=15)
    mu = jnp.array([0.01] + [0.75] * 4)
    res = lasso.distributed_lasso(op, y, mu=mu, gamma=lasso.ista_step_size(op),
                                  n_iters=100)
    mse_noisy = float(jnp.mean((y - f0) ** 2))
    mse_lasso = float(jnp.mean((res.signal - f0) ** 2))
    assert mse_lasso < mse_noisy


def test_soft_threshold_properties():
    z = jnp.linspace(-3, 3, 101)
    out = lasso.soft_threshold(z, 0.5)
    assert float(jnp.max(jnp.abs(out))) <= 2.5 + 1e-6       # shrinks by t
    assert np.all(np.asarray(jnp.abs(out) <= jnp.abs(z)))    # nonexpansive
    assert np.all(np.asarray(out[jnp.abs(z) <= 0.5]) == 0.0)  # dead zone


def test_lasso_cv_scores_and_regularization_path(sensor120):
    """Section VI optional extension: distributed CV over the lasso weights.

    Previously asserted on the CV argmin (``best != 50``), which is not a
    property the finite-iteration masked ISTA guarantees: at 60 iterations
    the masked fits can score worse on held-out vertices than the
    all-zero reconstruction, so the argmin legitimately landed on the
    huge weight for some draws and the test flaked.  What *is* guaranteed
    — and what this now asserts — is the shape of the regularization
    path: the CV machinery returns finite scores for a seeded split, and
    the fitted coefficient mass ||a*(mu)||_1 decreases monotonically in
    mu, from a genuine fit at mu=0 to exactly zero at mu=50 (the
    shrinkage threshold mu*gamma exceeds every update there).
    """
    key = jax.random.PRNGKey(10)
    f0 = graph_signal_batch(key, sensor120.coords, "piecewise")
    y = f0 + 0.5 * jax.random.normal(key, f0.shape)
    lmax = sensor120.lambda_max_bound()
    op = UnionMultiplier(P=sensor120.laplacian(),
                         multipliers=wavelets.sgwt_multipliers(lmax, J=3),
                         lmax=lmax, K=12)
    gamma = lasso.ista_step_size(op)
    grid = [0.0, 0.5, 50.0]
    best, scores = lasso.lasso_cross_validate(
        op, y, grid, jax.random.PRNGKey(1), n_folds=2, gamma=gamma,
        n_iters=60)
    assert len(scores) == 3 and all(np.isfinite(scores))
    assert best in grid

    # regularization path: coefficient mass shrinks monotonically with mu
    norms = []
    for mu in grid:
        res = lasso.distributed_lasso(op, y, mu=mu, gamma=gamma, n_iters=60)
        norms.append(float(jnp.sum(jnp.abs(res.coeffs))))
    assert norms[0] > norms[1] > norms[2], norms
    assert norms[1] > 1.0          # moderate mu keeps real signal
    assert norms[2] < 1e-6, norms  # huge mu kills the coefficients entirely


def test_prop6_lasso_perturbation_bound(sensor120):
    """Prop. 6 / Eq. (34): || Phi~* a~* - Phi* a* ||^2 <=
    (||y||^3 / min mu) * B(K) * sqrt(J+1), with a* from the exact operator
    and a~* from the Chebyshev approximation."""
    import numpy as _np
    from repro.core import chebyshev as cheb

    key = jax.random.PRNGKey(12)
    y = jax.random.normal(key, (sensor120.n_vertices,))
    lmax = sensor120.lambda_max_bound()
    J, K = 3, 10  # low K so the bound is non-trivial
    mults = wavelets.sgwt_multipliers(lmax, J=J)
    op = UnionMultiplier(P=sensor120.laplacian(), multipliers=mults,
                         lmax=lmax, K=K)

    class Exact:
        def __init__(self, op):
            lam, U = _np.linalg.eigh(_np.asarray(op.P))
            self.mats = [jnp.asarray(U @ _np.diag(_np.asarray(g(lam))) @ U.T)
                         for g in op.multipliers]
            self.eta = op.eta

        def apply(self, f):
            return jnp.stack([M @ f for M in self.mats])

        def apply_adjoint(self, a):
            return sum(M @ a[j] for j, M in enumerate(self.mats))

    mu = 0.3
    gamma = lasso.ista_step_size(op) * 0.5
    res_apx = lasso.distributed_lasso(op, y, mu=mu, gamma=gamma, n_iters=400)
    res_ex = lasso.distributed_lasso(Exact(op), y, mu=mu, gamma=gamma,
                                     n_iters=400)
    lhs = float(jnp.sum((res_apx.signal - res_ex.signal) ** 2))
    BK = cheb.approx_error_bound(mults, op.coeffs, lmax)
    rhs = float(jnp.linalg.norm(y)) ** 3 / mu * BK * np.sqrt(J + 1)
    assert lhs <= rhs, (lhs, rhs)
    assert lhs > 0  # operators genuinely differ at K=10
