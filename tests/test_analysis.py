"""repro.analysis: every shipped rule flags its known-bad fixture, the
allowlist machinery audits what it silences, and the real repo comes out
clean across all five backends (1 shard in-process, 8 via subprocess)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _subproc import run_payload
from repro import analysis as A
from repro.dist import commstats

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
P = jax.sharding.PartitionSpec


def _shmap(inner, mesh, in_specs=None, out_specs=None):
    return jax.shard_map(inner, mesh=mesh,
                         in_specs=P("x") if in_specs is None else in_specs,
                         out_specs=P("x") if out_specs is None else out_specs,
                         check_vma=False)


def _rules(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# Comm-schedule safety
# ---------------------------------------------------------------------------
def test_perm_problems_unit():
    ring = [(i, (i + 1) % 8) for i in range(8)]
    assert A.perm_problems(ring, 8) == []
    assert A.perm_problems([(0, 1), (1, 0)], 2) == []
    # incomplete ring: last device dropped from the exchange
    probs = A.perm_problems(ring[:-1], 8)
    assert any("never send" in p for p in probs)
    assert any("never receive" in p for p in probs)
    # collisions and off-axis indices
    assert any("send more than once" in p
               for p in A.perm_problems([(0, 1), (0, 0)], 2))
    assert any("receive more than once" in p
               for p in A.perm_problems([(0, 1), (1, 1), (2, 0)], 3))
    assert any("outside axis" in p for p in A.perm_problems([(0, 9)], 4))


def test_incomplete_ppermute_flagged_in_trace():
    """The traced version: an empty perm on a 1-device axis is incomplete
    (device 0 neither sends nor receives)."""
    mesh = jax.make_mesh((1,), ("x",))

    def bad(v):
        return _shmap(lambda vl: jax.lax.ppermute(vl, "x", perm=[]),
                      mesh)(v)

    fs = A.check_comm_schedule(bad, jax.ShapeDtypeStruct((8,), np.float32),
                               label="fixture.bad_ring")
    assert _rules(fs) == {"JX-PPERMUTE-BIJECTION"}
    assert fs[0].symbol == "fixture.bad_ring"

    def good(v):
        return _shmap(lambda vl: jax.lax.ppermute(vl, "x", perm=[(0, 0)]),
                      mesh)(v)

    assert A.check_comm_schedule(
        good, jax.ShapeDtypeStruct((8,), np.float32)) == []


def test_collective_under_while_flagged():
    mesh = jax.make_mesh((1,), ("x",))

    def bad(v):
        def inner(vl):
            return jax.lax.while_loop(
                lambda c: jnp.sum(c) < 100.0,
                lambda c: jax.lax.ppermute(c, "x", perm=[(0, 0)]) + 1.0,
                vl)
        return _shmap(inner, mesh)(v)

    fs = A.check_comm_schedule(bad, jax.ShapeDtypeStruct((8,), np.float32))
    assert _rules(fs) == {"JX-COLLECTIVE-IN-WHILE"}

    # the commstats satellite: measure() refuses to undercount this
    with pytest.raises(commstats.UncountableCollectiveError):
        commstats.measure(bad, jax.ShapeDtypeStruct((8,), np.float32))
    with pytest.warns(UserWarning, match="lower bound"):
        st = commstats.measure(bad, jax.ShapeDtypeStruct((8,), np.float32),
                               while_loops="warn")
    assert st.n_collectives == 1
    with pytest.raises(ValueError):
        commstats.measure(bad, jax.ShapeDtypeStruct((8,), np.float32),
                          while_loops="ignore")


def test_batch_dependent_schedule_flagged():
    """A batched path that re-runs the exchange per signal (the bug the
    (..., N) contract forbids) has a B-dependent schedule."""
    mesh = jax.make_mesh((1,), ("x",))

    def mk(b):
        def fn(v):
            def inner(vl):
                for _ in range(b):  # one exchange per signal: the bug
                    vl = jax.lax.ppermute(vl, "x", perm=[(0, 0)])
                return vl
            return _shmap(inner, mesh)(v)
        return fn, (jax.ShapeDtypeStruct((8,), np.float32),)

    fs = A.check_batch_schedule(mk, batches=(1, 4), label="fixture.rerun")
    assert _rules(fs) == {"JX-BATCH-SCHEDULE"}

    def mk_good(b):
        fn, _ = mk(1)
        return fn, (jax.ShapeDtypeStruct((8,), np.float32),)

    assert A.check_batch_schedule(mk_good, batches=(1, 4)) == []


# ---------------------------------------------------------------------------
# VMEM budget
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def block_ell():
    from repro.core import graph
    g = graph.path_graph(64)
    return graph.to_block_ell(np.asarray(g.laplacian(), np.float32),
                              (8, 128)), g.lambda_max_bound()


def test_overbudget_pallas_call_flagged(block_ell):
    from repro.kernels import ops
    A_ell, lmax = block_ell
    c = np.ones((2, 6), np.float32)

    def fn(x):
        return ops.fused_cheb_sweep(A_ell, ops.pad_trailing(x, A_ell.padded_n),
                                    c, lmax, use_pallas=True)

    x = jax.ShapeDtypeStruct((64,), np.float32)
    # the real launch fits the real budget...
    assert A.check_vmem_budget(fn, x) == []
    # ...and a starved checker budget flags the same launch, proving the
    # footprint is recomputed from the traced BlockSpecs
    fs = A.check_vmem_budget(fn, x, budget=256, label="fixture.sweep")
    assert _rules(fs) == {"JX-VMEM-BUDGET"}
    assert "exceeds the sweep VMEM budget 256" in fs[0].message


def test_pallas_footprint_matches_ops_model(block_ell):
    """The jaxpr-recovered footprint agrees with the launch-side model for
    the dominant iterate terms (the model also budgets index/coeff slack,
    so launch-model >= traced is the invariant)."""
    from repro.kernels import ops
    A_ell, lmax = block_ell
    eta, K = 2, 5
    c = np.ones((eta, K + 1), np.float32)

    def fn(x):
        return ops.fused_cheb_sweep(A_ell,
                                    ops.pad_trailing(x, A_ell.padded_n),
                                    c, lmax, use_pallas=True)

    closed = jax.make_jaxpr(fn)(jax.ShapeDtypeStruct((64,), np.float32))
    calls = A.collect_eqns(closed, {"pallas_call"})
    assert len(calls) == 1
    traced = A.pallas_footprint(calls[0][0])["total_bytes"]
    model = ops.cheb_sweep_vmem_bytes(A_ell, A_ell.padded_n, eta, K)
    assert 0 < traced <= model


# ---------------------------------------------------------------------------
# Dtype discipline
# ---------------------------------------------------------------------------
def test_f64_upcast_flagged():
    def bad(x):
        return jnp.sum(x.astype(jnp.float64))

    with jax.experimental.enable_x64():
        fs = A.check_dtype_discipline(
            bad, jax.ShapeDtypeStruct((8,), np.float32))
    assert "JX-DTYPE-F64" in _rules(fs)

    def good(x):
        return jnp.sum(x * 2.0)

    assert A.check_dtype_discipline(
        good, jax.ShapeDtypeStruct((8,), np.float32)) == []


def test_mixed_float_width_flagged():
    def bad(x):
        # f32 carry + bf16 xs into one scan: the recurrence dtype is
        # whatever promotion decides, not what the author wrote
        def body(c, w):
            return c + w.astype(jnp.float32), None
        out, _ = jax.lax.scan(body, x, jnp.zeros((3,), jnp.bfloat16))
        return out

    fs = A.check_dtype_discipline(bad, jax.ShapeDtypeStruct((8,),
                                                            np.float32))
    assert "JX-DTYPE-PROMOTION" in _rules(fs)


def test_mixed_ok_carveout_sanctions_bf16_sweep(block_ell):
    """JX-DTYPE-MIXED-OK: the bf16-scratch sweep kernel mixes widths by
    design (f32 coef table + bf16 blocks/iterates).  The raw trace flags
    it; the default carve-out — DTYPE_MIXED_OK rule metadata, NOT an
    allowlist entry — silences exactly those sanctioned-site findings."""
    from repro.kernels import ops
    from repro.kernels.cheb_sweep import cheb_sweep
    A_ell, lmax = block_ell
    c = jnp.ones((2, 6), jnp.float32)

    def fn(x):
        x2 = ops.pad_trailing(x, A_ell.padded_n)
        return cheb_sweep(A_ell.blocks, A_ell.indices, x2, c,
                          alpha=lmax / 2, interpret=True,
                          scratch_dtype="bf16")

    x = jax.ShapeDtypeStruct((64,), np.float32)
    raw = A.check_dtype_discipline(fn, x, mixed_ok=False)
    assert "JX-DTYPE-PROMOTION" in _rules(raw)
    assert all("repro/kernels/cheb_sweep.py" in f.path for f in raw)
    assert A.check_dtype_discipline(fn, x) == []
    # the carve-out is documented metadata, not a bare path list
    assert all(why for _frag, why in A.DTYPE_MIXED_OK)


def test_mixed_ok_carveout_does_not_shadow_accidents():
    """An accidental f32/bf16 mix OUTSIDE a sanctioned path still fires
    with the carve-out active (default mixed_ok=True)."""
    def bad(x):
        def body(c, w):
            return c + w.astype(jnp.float32), None
        out, _ = jax.lax.scan(body, x, jnp.zeros((3,), jnp.bfloat16))
        return out

    fs = A.check_dtype_discipline(bad, jax.ShapeDtypeStruct((8,),
                                                            np.float32))
    assert "JX-DTYPE-PROMOTION" in _rules(fs)


def test_complex_arma_solve_is_exempt():
    """ARMA mixes complex64 poles with f32 signals by design — the dtype
    rules must stay quiet on it."""
    from repro.core import graph, wavelets
    from repro.dist import GraphOperator
    g = graph.path_graph(32)
    lmax = g.lambda_max_bound()
    op = GraphOperator(P=g.laplacian(),
                       multipliers=wavelets.sgwt_multipliers(lmax, J=2),
                       lmax=lmax, K=4)
    plan = op.plan("dense")

    def fn(y):
        return plan.solve(y, "arma", tau=0.5).x

    assert A.check_dtype_discipline(
        fn, jax.ShapeDtypeStruct((32,), np.float32)) == []


# ---------------------------------------------------------------------------
# AST rules (fixture sources through lint_source)
# ---------------------------------------------------------------------------
LIB = "src/repro/somewhere.py"


def _lint(src, relpath=LIB, **kw):
    return A.lint_source(textwrap.dedent(src), relpath, **kw)


def test_ast_dense_materialization():
    src = """
    import jax.numpy as jnp

    def filt(L, f):
        w, v = jnp.linalg.eigh(L)
        return v @ (w * (v.T @ f))
    """
    fs = _lint(src)
    assert _rules(fs) == {"RP-DENSE-MAT"}
    assert fs[0].symbol == "filt"
    assert _lint(src, relpath="src/repro/kernels/ref.py") == []


def test_ast_order_loop():
    src = """
    def apply(mv, x, K):
        for k in range(K + 1):
            x = mv(x)
        return x
    """
    fs = _lint(src)
    assert _rules(fs) == {"RP-ORDER-LOOP"}
    assert _lint(src, relpath="src/repro/kernels/ref.py") == []


def test_ast_host_sync():
    fs = _lint("""
    import jax

    def pull(x):
        jax.block_until_ready(x)
        return jax.device_get(x)
    """)
    assert [f.rule for f in fs] == ["RP-HOST-SYNC", "RP-HOST-SYNC"]


def test_ast_unlogged_fallback():
    bad = """
    def dispatch(use, x):
        if not use:
            return _fallback_apply(x)
        return _fast_apply(x)
    """
    fs = _lint(bad)
    assert _rules(fs) == {"RP-FALLBACK-LOG"}
    good = """
    def dispatch(use, x):
        if not use:
            logger.info("dispatch: taking the fallback path")
            return _fallback_apply(x)
        return _fast_apply(x)
    """
    assert _lint(good) == []


def test_ast_legacy_scaffold_import(monkeypatch):
    monkeypatch.chdir(REPO)
    globs = ("src/repro/models/*", "src/repro/kernels/flash_attention.py")
    bad = "from repro.models import model\n"
    fs = _lint(bad, scaffold_globs=globs)
    assert _rules(fs) == {"RP-LEGACY-SCAFFOLD"}
    # relative form resolves too
    fs = A.lint_source("from .flash_attention import flash_attention\n",
                       "src/repro/kernels/newkernel.py",
                       scaffold_globs=globs)
    assert _rules(fs) == {"RP-LEGACY-SCAFFOLD"}
    # scaffold modules may import each other; non-scaffold imports are fine
    assert A.lint_source("from repro.models import model\n",
                         "src/repro/models/other.py",
                         scaffold_globs=globs) == []
    assert _lint("from repro.core import graph\n",
                 scaffold_globs=globs) == []


def test_ast_scaffold_files_skipped(monkeypatch):
    monkeypatch.chdir(REPO)
    src = "import jax\n\ndef f(L):\n    return jax.numpy.linalg.eigh(L)\n"
    assert A.lint_source(src, "src/repro/models/newthing.py",
                         scaffold_globs=("src/repro/models/*",)) == []


# ---------------------------------------------------------------------------
# Allowlist machinery
# ---------------------------------------------------------------------------
def test_allowlist_requires_justification(tmp_path):
    p = tmp_path / "allow.txt"
    p.write_text("[allow]\nRP-DENSE-MAT src/repro/foo.py\n")
    with pytest.raises(A.AllowlistError, match="justification"):
        A.Allowlist.load(str(p))


def test_allowlist_split_and_staleness(tmp_path):
    p = tmp_path / "allow.txt"
    p.write_text(textwrap.dedent("""
        [scaffold]
        src/repro/models/* -- dormant
        [allow]
        RP-DENSE-MAT src/repro/foo.py::g -- oracle path
        RP-ORDER-LOOP src/repro/never.py -- stale record
    """))
    al = A.Allowlist.load(str(p))
    assert al.scaffold_globs == ("src/repro/models/*",)
    hit = A.Finding(rule="RP-DENSE-MAT", path="src/repro/foo.py",
                    symbol="g", message="m")
    miss_sym = A.Finding(rule="RP-DENSE-MAT", path="src/repro/foo.py",
                         symbol="h", message="m")
    kept, suppressed = al.split([hit, miss_sym])
    assert suppressed == [hit] and kept == [miss_sym]
    stale = al.unused_entries([hit, miss_sym])
    assert [e.path_glob for e in stale] == ["src/repro/never.py"]


def test_repo_allowlist_parses_and_is_fully_exercised():
    """Every [allow] entry in the shipped allowlist must still match a
    real finding — otherwise it is a stale audit record."""
    al = A.Allowlist.load(os.path.join(REPO, "tools", "lint_allowlist.txt"))
    assert al.entries and al.scaffold
    for e in al.entries + al.scaffold:
        assert e.justification
    os.chdir(REPO)
    findings = A.lint_tree("src/repro", scaffold_globs=al.scaffold_globs)
    kept, suppressed = al.split(findings)
    assert kept == [], [str(f) for f in kept]
    assert al.unused_entries(findings) == [], "stale allowlist entries"


# ---------------------------------------------------------------------------
# Clean full-plan runs: all five backends
# ---------------------------------------------------------------------------
def _lint_op():
    from repro.core import graph, wavelets
    from repro.dist import GraphOperator
    g = graph.path_graph(64)
    lmax = g.lambda_max_bound()
    return GraphOperator(P=g.laplacian(),
                         multipliers=wavelets.sgwt_multipliers(lmax, J=2),
                         lmax=lmax, K=10)


def test_all_backends_clean_1shard():
    from repro.dist.backends import available_backends
    op = _lint_op()
    mesh = jax.make_mesh((1,), ("graph",))
    assert set(available_backends()) == {
        "dense", "pallas", "halo", "pallas_halo", "allgather"}
    for backend in available_backends():
        kwargs = {"mesh": mesh} if backend in ("halo", "pallas_halo",
                                               "allgather") else {}
        plan = op.plan(backend, **kwargs)
        fs = A.check_plan(plan, batches=(1, 8),
                          budget=plan.info.get("sweep_vmem_budget"),
                          solve_methods=("jacobi",))
        assert fs == [], (backend, [str(f) for f in fs])


PAYLOAD_8SHARD = r"""
import jax, numpy as np
from repro import analysis as A
from repro.core import graph, wavelets
from repro.dist import GraphOperator

g = graph.path_graph(64)
lmax = g.lambda_max_bound()
op = GraphOperator(P=g.laplacian(),
                   multipliers=wavelets.sgwt_multipliers(lmax, J=2),
                   lmax=lmax, K=10)
mesh = jax.make_mesh((8,), ("graph",))

# clean run: every sharded backend's real 8-shard schedule passes
for backend in ("halo", "pallas_halo", "allgather"):
    plan = op.plan(backend, mesh=mesh)
    fs = A.check_plan(plan, batches=(1, 64),
                      budget=plan.info.get("sweep_vmem_budget"),
                      solve_methods=("jacobi",))
    assert fs == [], (backend, [str(f) for f in fs])

# known-bad at real shard count: drop one link of the ring
P = jax.sharding.PartitionSpec
def bad(v):
    def inner(vl):
        perm = [(i, i + 1) for i in range(7)]   # device 7 never sends
        return jax.lax.ppermute(vl, "graph", perm=perm)
    return jax.shard_map(inner, mesh=mesh, in_specs=P("graph"),
                         out_specs=P("graph"), check_vma=False)(v)

fs = A.check_comm_schedule(bad, jax.ShapeDtypeStruct((64,), np.float32))
assert {f.rule for f in fs} == {"JX-PPERMUTE-BIJECTION"}, fs
assert "devices [7] never send" in fs[0].message, fs[0].message

# JX-FAULT-NO-EXTRA-COLLECTIVES, positive: a fully-armed fault config on
# the quantized wire traces the identical collective schedule as its
# clean twin on both sharded halo backends
fault_spec = {"drop_prob": 0.1, "stale_prob": 0.1, "noise_prob": 0.1,
              "seed": 3}
for backend in ("halo", "pallas_halo"):
    clean = op.plan(backend, mesh=mesh, exchange_dtype="int8")
    faulted = op.plan(backend, mesh=mesh, exchange_dtype="int8",
                      fault_spec=fault_spec, degradation="hold_last")
    fs = A.check_fault_schedule(clean, faulted, solve_methods=("jacobi",))
    assert fs == [], (backend, [str(f) for f in fs])

# negative: a plan whose exchange structure differs (K=12 vs K=10 — four
# extra rounds) is exactly what the rule must flag
op12 = GraphOperator(P=g.laplacian(),
                     multipliers=wavelets.sgwt_multipliers(lmax, J=2),
                     lmax=lmax, K=12)
fs = A.check_fault_schedule(op.plan("halo", mesh=mesh),
                            op12.plan("halo", mesh=mesh))
assert fs and {f.rule for f in fs} == {"JX-FAULT-NO-EXTRA-COLLECTIVES"}, fs
print("ANALYSIS 8SHARD OK")
"""


def test_all_backends_clean_8shards():
    out = run_payload(PAYLOAD_8SHARD, n_devices=8)
    assert "ANALYSIS 8SHARD OK" in out


def test_lint_cli_smoke():
    """The CLI entry point runs the ast+docs layers green on the repo."""
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "lint_repro.py"),
         "--check", "--layers", "ast,docs"],
        capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 finding(s)" in proc.stdout, proc.stdout + proc.stderr
