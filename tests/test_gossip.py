"""Chebyshev gossip (the paper's Algorithm 1 on the device ring)."""
import numpy as np
import pytest

from _subproc import run_payload
from repro.dist import gossip


def test_consensus_coeffs_exact_at_full_order():
    """K = ceil(n/2) hits every distinct ring eigenvalue -> exact consensus
    (finite-time consensus via the paper's machinery)."""
    for n in (4, 8, 16):
        c = gossip.consensus_coeffs(n)
        assert gossip.consensus_error(n, c) < 1e-6  # f32 eval floor


def test_consensus_error_decreases_with_K():
    errs = [gossip.consensus_error(16, gossip.consensus_coeffs(16, K))
            for K in (2, 4, 6, 8)]
    assert all(e2 <= e1 + 1e-12 for e1, e2 in zip(errs, errs[1:]))


PAYLOAD = r"""
import numpy as np, jax, jax.numpy as jnp, functools
from jax.sharding import PartitionSpec as P
from repro.dist import gossip

mesh = jax.make_mesh((8,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
x = jnp.arange(8 * 5, dtype=jnp.float32).reshape(8, 5) ** 1.3
coeffs = gossip.consensus_coeffs(8)

@functools.partial(jax.shard_map, mesh=mesh, in_specs=P("data"),
                   out_specs=P("data"), check_vma=False)
def run(xl):
    return gossip.gossip_mean(xl, "data", coeffs)

out = run(x)
target = jnp.mean(x, axis=0)
err = float(jnp.abs(out - target[None]).max())
assert err < 1e-3, err

# quantized messages with the same coefficients: approximate consensus
@functools.partial(jax.shard_map, mesh=mesh, in_specs=P("data"),
                   out_specs=P("data"), check_vma=False)
def run_q(xl):
    return gossip.gossip_mean(xl, "data", coeffs, quantize=True)

out_q = run_q(x)
rel = float(jnp.abs(out_q - target[None]).max() / (jnp.abs(target).max()))
assert rel < 0.05, rel

# the quantized wire really is int8 and really shrinks: h + 4 bytes per
# h-element row vs 4h for the f32 payload (and commstats counts the
# int8 avals, so measured ring traffic shrinks by the same factor)
msg = jnp.linspace(-1.0, 1.0, 32, dtype=jnp.float32)[None]
wire = gossip.quantize_message(msg)
assert wire.dtype == jnp.int8, wire.dtype
assert wire.nbytes < msg.nbytes, (wire.nbytes, msg.nbytes)
assert wire.nbytes == msg.shape[-1] + 4, wire.nbytes
back = gossip.dequantize_message(wire)
assert float(jnp.abs(back - msg).max()) < 1.0 / 127 + 1e-6

# straggler mitigation: drop one link, consensus still approximate
drop = jnp.zeros((), bool)
@functools.partial(jax.shard_map, mesh=mesh, in_specs=P("data"),
                   out_specs=P("data"), check_vma=False)
def run_drop(xl):
    i = jax.lax.axis_index("data")
    return gossip.gossip_mean(xl, "data", coeffs,
                              drop_left=(i == 3), drop_right=(i == 2))

out_d = run_drop(x)
rel_d = float(jnp.abs(out_d - target[None]).max() / jnp.abs(target).max())
assert rel_d < 0.35, rel_d  # degraded but bounded
print("GOSSIP OK", err, rel, rel_d)
"""


def test_gossip_mean_multidevice():
    out = run_payload(PAYLOAD, n_devices=8)
    assert "GOSSIP OK" in out
