"""Batched (..., N) contract: batched execution == per-signal loop on every
backend, batched ISTA/SSL equivalence, and batch-invariant communication.

The tentpole invariant (ISSUE 3): `plan.apply(F)` for F (B, N) must match
`stack([plan.apply(F[b])])` to 1e-6-grade tolerance on all five backends,
while the collective *round* count stays identical to the unbatched trace
(messages/signal = 2K|E|/B).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graph, lasso, wavelets
from repro.dist import GraphOperator

BACKENDS = ["dense", "pallas", "halo", "pallas_halo", "allgather"]
B = 64


@pytest.fixture(scope="module")
def small_op():
    g, _ = graph.connected_sensor_graph(
        jax.random.PRNGKey(0), n=120, theta=0.2, kappa=0.25)
    lmax = g.lambda_max_bound()
    op = GraphOperator(P=g.laplacian(),
                       multipliers=wavelets.sgwt_multipliers(lmax, J=2),
                       lmax=lmax, K=12)
    return g, op


def _plan(op, backend):
    if backend in ("halo", "pallas_halo", "allgather"):
        mesh = jax.make_mesh((1,), ("graph",))
        return op.plan(backend, mesh=mesh)
    return op.plan(backend)


@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_apply_matches_loop(small_op, backend):
    """plan.apply(F) == stack([plan.apply(f_b)]) at B=64, all three methods.

    The per-signal closures are jitted once so the loop reuses one
    compilation (the numbers are identical either way; eager re-tracing
    64x per backend is just wall-time).
    """
    g, op = small_op
    plan = _plan(op, backend)
    n = g.n_vertices
    F = jax.random.normal(jax.random.PRNGKey(1), (B, n))
    A = jax.random.normal(jax.random.PRNGKey(2), (B, op.eta, n))

    apply1 = jax.jit(plan.apply)
    out = plan.apply(F)
    assert out.shape == (B, op.eta, n)
    looped = jnp.stack([apply1(F[b]) for b in range(B)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(looped), atol=1e-6)

    adjoint1 = jax.jit(plan.apply_adjoint)
    adj = plan.apply_adjoint(A)
    assert adj.shape == (B, n)
    looped = jnp.stack([adjoint1(A[b]) for b in range(B)])
    np.testing.assert_allclose(np.asarray(adj), np.asarray(looped), atol=1e-6)

    gram1 = jax.jit(plan.apply_gram)
    gram = plan.apply_gram(F)
    assert gram.shape == (B, n)
    looped = jnp.stack([gram1(F[b]) for b in range(B)])
    np.testing.assert_allclose(np.asarray(gram), np.asarray(looped),
                               atol=1e-6)


@pytest.mark.parametrize("backend", BACKENDS)
def test_nested_batch_dims(small_op, backend):
    """Arbitrary leading dims: (2, 3, N) == (6, N) reshaped."""
    g, op = small_op
    plan = _plan(op, backend)
    F = jax.random.normal(jax.random.PRNGKey(3), (2, 3, g.n_vertices))
    out = plan.apply(F)
    assert out.shape == (2, 3, op.eta, g.n_vertices)
    flat = plan.apply(F.reshape(6, -1))
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(flat.reshape(out.shape)), atol=1e-6)


@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_plans_are_jittable(small_op, backend):
    g, op = small_op
    plan = _plan(op, backend)
    F = jax.random.normal(jax.random.PRNGKey(4), (4, g.n_vertices))
    np.testing.assert_allclose(np.asarray(jax.jit(plan.apply)(F)),
                               np.asarray(plan.apply(F)), atol=1e-5)


@pytest.mark.parametrize("backend", ["dense", "halo", "pallas_halo"])
def test_batched_lasso_matches_loop(small_op, backend):
    """Batched ISTA (fused and generic) == per-signal solves, including a
    per-signal (B, eta) mu."""
    g, op = small_op
    plan = _plan(op, backend)
    nb = 3
    Y = jax.random.normal(jax.random.PRNGKey(5), (nb, g.n_vertices))
    mu = jnp.array([0.01, 0.75, 0.75])
    solve1 = jax.jit(lambda y, m: plan.solve_lasso(y, m, gamma=0.1,
                                                   n_iters=15).signal)
    res = plan.solve_lasso(Y, mu, gamma=0.1, n_iters=15)
    assert res.coeffs.shape == (nb, op.eta, g.n_vertices)
    assert res.signal.shape == (nb, g.n_vertices)
    assert res.fused == (backend != "dense")
    for b in range(nb):
        np.testing.assert_allclose(np.asarray(res.signal[b]),
                                   np.asarray(solve1(Y[b], mu)), atol=1e-5)
    # per-signal weights: scaling one signal's mu only changes that signal
    mu_b = jnp.stack([mu, 2.0 * mu, 0.5 * mu])
    res_b = plan.solve_lasso(Y, mu_b, gamma=0.1, n_iters=15)
    for b, scale in enumerate([1.0, 2.0, 0.5]):
        np.testing.assert_allclose(np.asarray(res_b.signal[b]),
                                   np.asarray(solve1(Y[b], scale * mu)),
                                   atol=1e-5)


def test_per_vertex_mu_still_accepted(small_op):
    """Regression: the pre-batch API documented mu as 'a full (eta, N)
    array'; per-vertex weights must keep working through the generic loop
    (and extend to (B, eta, N) batched)."""
    g, op = small_op
    n = g.n_vertices
    y = jax.random.normal(jax.random.PRNGKey(8), (n,))
    mu_vertex = jnp.full((op.eta, n), 0.1)
    res = lasso.distributed_lasso(op, y, mu=mu_vertex, gamma=0.1, n_iters=10)
    ref = lasso.distributed_lasso(op, y, mu=0.1, gamma=0.1, n_iters=10)
    np.testing.assert_allclose(np.asarray(res.signal), np.asarray(ref.signal),
                               atol=1e-6)
    Y = jax.random.normal(jax.random.PRNGKey(9), (2, n))
    res_b = lasso.distributed_lasso(op, Y, mu=jnp.stack([mu_vertex,
                                                         2 * mu_vertex]),
                                    gamma=0.1, n_iters=10)
    for b, scale in enumerate([1.0, 2.0]):
        ref = lasso.distributed_lasso(op, Y[b], mu=scale * mu_vertex,
                                      gamma=0.1, n_iters=10)
        np.testing.assert_allclose(np.asarray(res_b.signal[b]),
                                   np.asarray(ref.signal), atol=1e-6)
    # fused backends can't thresh per-vertex on the padded shard domain;
    # plan.solve_lasso must fall back to the generic loop, not raise
    mesh = jax.make_mesh((1,), ("graph",))
    res_h = op.plan("halo", mesh=mesh).solve_lasso(y, mu_vertex, gamma=0.1,
                                                   n_iters=10)
    assert not res_h.fused
    np.testing.assert_allclose(np.asarray(res_h.signal),
                               np.asarray(res.signal), atol=1e-4)


def test_solve_lasso_benign_kwargs_keep_fusion(small_op, caplog):
    """Satellite fix: kwargs explicitly passed at their defaults must not
    forfeit the fused path; loop-changing kwargs must, with an INFO log."""
    import logging

    g, op = small_op
    mesh = jax.make_mesh((1,), ("graph",))
    plan = op.plan("halo", mesh=mesh)
    y = jax.random.normal(jax.random.PRNGKey(6), (g.n_vertices,))
    mu = jnp.array([0.01, 0.75, 0.75])
    res = plan.solve_lasso(y, mu, gamma=0.1, n_iters=5,
                           a0=None, record_objective=False)
    assert res.fused, "benign default-valued kwargs forfeited fusion"
    with caplog.at_level(logging.INFO, logger="repro.dist.operator"):
        res = plan.solve_lasso(y, mu, gamma=0.1, n_iters=5,
                               record_objective=True)
    assert not res.fused
    assert any("forfeit the fused" in r.message for r in caplog.records)


def test_ssl_batched_path_matches_dense(small_op):
    """SSL reroutes its class columns through the batched plan path on
    every backend (no per-column loop anywhere)."""
    from repro.core import ssl

    g, labels = graph.two_cluster_graph(jax.random.PRNGKey(3), n_per=25)
    mask = jnp.zeros(50, bool).at[jnp.array([0, 1, 25, 26])].set(True)
    Ln = g.laplacian("normalized")
    ref = ssl.semi_supervised_classify(Ln, labels, mask, 2, tau=0.5,
                                       lmax=2.0, backend="dense")
    for backend in ("pallas", "halo", "pallas_halo", "allgather"):
        mesh = (jax.make_mesh((1,), ("graph",))
                if backend != "pallas" else None)
        res = ssl.semi_supervised_classify(Ln, labels, mask, 2, tau=0.5,
                                           lmax=2.0, backend=backend,
                                           mesh=mesh)
        np.testing.assert_allclose(np.asarray(res.scores),
                                   np.asarray(ref.scores), atol=1e-4)
        assert ssl.accuracy(res, labels, mask) > 0.95, backend


def test_commstats_batch_accessors():
    """Unit-level: per-signal amortization arithmetic."""
    from repro.dist.commstats import CollectiveCall, CommStats

    stats = CommStats(
        collectives=(CollectiveCall("ppermute", count=20, elems=4,
                                    nbytes=16),),
        n_shards=8, batch=64,
    )
    assert stats.exchange_rounds == 10
    assert stats.paper_messages(63) == 10 * 2 * 63
    assert stats.paper_messages_per_signal(63) == 10 * 2 * 63 / 64
    assert stats.summary()["batch"] == 64


def test_lasso_module_batched_entrypoint(small_op):
    """core.lasso.distributed_lasso takes (..., N) directly."""
    g, op = small_op
    Y = jax.random.normal(jax.random.PRNGKey(7), (2, g.n_vertices))
    res = lasso.distributed_lasso(op, Y, mu=0.1, gamma=0.1, n_iters=10)
    assert res.coeffs.shape == (2, op.eta, g.n_vertices)
    for b in range(2):
        ref = lasso.distributed_lasso(op, Y[b], mu=0.1, gamma=0.1,
                                      n_iters=10)
        np.testing.assert_allclose(np.asarray(res.signal[b]),
                                   np.asarray(ref.signal), atol=1e-5)
