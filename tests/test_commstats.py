"""repro.dist.commstats: measured collective counts match the paper's
closed-form message accounting (Section IV-B/C) on known graphs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _subproc import run_payload
from repro.dist import commstats
from repro.dist.commstats import CollectiveCall, CommStats, measure


def test_measure_counts_scan_multiplied_collectives():
    """A ppermute inside a scan body is counted once per trip."""
    mesh = jax.make_mesh((1,), ("x",))

    def fn(v):
        def inner(vl):
            def body(c, _):
                out = jax.lax.ppermute(c, "x", perm=[(0, 0)])
                return out, None
            c, _ = jax.lax.scan(body, vl, None, length=7)
            return c
        return jax.shard_map(inner, mesh=mesh, in_specs=jax.sharding.PartitionSpec("x"),
                             out_specs=jax.sharding.PartitionSpec("x"),
                             check_vma=False)(v)

    stats = measure(fn, jax.ShapeDtypeStruct((8,), np.float32), n_shards=1)
    pp = [c for c in stats.collectives if c.primitive == "ppermute"]
    assert len(pp) == 1 and pp[0].count == 7
    assert pp[0].elems == 8 and pp[0].nbytes == 32
    assert stats.n_collectives == 7
    assert stats.bytes_per_shard == 7 * 32


def test_measure_dense_plan_has_no_collectives():
    from repro.core import graph, wavelets
    from repro.dist import GraphOperator, plan_comm_stats

    g, _ = graph.connected_sensor_graph(jax.random.PRNGKey(0), n=60,
                                        theta=0.3, kappa=0.35)
    lmax = g.lambda_max_bound()
    op = GraphOperator(P=g.laplacian(),
                       multipliers=wavelets.sgwt_multipliers(lmax, J=2),
                       lmax=lmax, K=8)
    stats = plan_comm_stats(op.plan("dense"))
    for s in stats.values():
        assert s.n_collectives == 0
        assert s.exchange_rounds == 0
        assert s.total_bytes == 0


def test_paper_messages_closed_form():
    """rounds x 2|E| arithmetic (unit-level; the traced version is below)."""
    stats = CommStats(
        collectives=(CollectiveCall("ppermute", count=20, elems=4,
                                    nbytes=16),),
        n_shards=8,
    )
    assert stats.exchange_rounds == 10          # one pair per round
    assert stats.paper_messages(63) == 10 * 2 * 63
    assert stats.total_bytes == 20 * 16 * 8


PAYLOAD = r"""
import numpy as np, jax
from repro.core import graph, wavelets
from repro.dist import GraphOperator, plan_comm_stats, verify_message_scaling

# Path graph: known closed form. |E| = n - 1, banded under any contiguous
# split with coupling bandwidth exactly 1.
n, S, K, J = 64, 8, 10, 2
g = graph.path_graph(n)
E = g.n_edges
assert E == n - 1
lmax = g.lambda_max_bound()
op = GraphOperator(P=g.laplacian(),
                   multipliers=wavelets.sgwt_multipliers(lmax, J=J),
                   lmax=lmax, K=K)
mesh = jax.make_mesh((S,), ("graph",))
predicted = op.message_counts(E)
assert predicted["apply_messages"] == 2 * K * E
assert predicted["gram_messages"] == 4 * K * E

for backend in ("halo", "pallas_halo", "allgather"):
    plan = op.plan(backend, mesh=mesh)
    stats = plan_comm_stats(plan)
    # Algorithm 1 does exactly K exchange rounds, the Gram trick 2K
    assert stats["apply"].exchange_rounds == K, backend
    assert stats["apply_adjoint"].exchange_rounds == K, backend
    assert stats["apply_gram"].exchange_rounds == 2 * K, backend
    # measured message counts hit the 2K|E| / 4K|E| closed forms exactly
    assert stats["apply"].paper_messages(E) == 2 * K * E, backend
    assert stats["apply_gram"].paper_messages(E) == 4 * K * E, backend
    v = verify_message_scaling(plan, E)
    assert v["max_rel_dev"] == 0.0, (backend, v)

# pallas_halo on a path graph has halo width 1: per order each shard sends
# one float left + one right -> byte model 2*K*S*1*4, and the measured
# device bytes agree with the plan's own model.
plan = op.plan("pallas_halo", mesh=mesh)
assert plan.info["halo_width"] == 1
st = plan_comm_stats(plan)["apply"]
assert st.total_bytes == 2 * K * S * 1 * 4 == plan.info["halo_bytes_per_apply"]
assert st.bytes_per_round == 2 * 1 * 4

# the interior/boundary split gives halo the same boundary-tile payload
# (it used to ship the full nl-block, nl/h = 8x more bytes here); the
# round count — what the paper-level 2K|E| accounting measures — is
# identical, only the per-round payload shrank.
halo_plan = op.plan("halo", mesh=mesh)
assert halo_plan.info["halo_width"] == 1
st_halo = plan_comm_stats(halo_plan)["apply"]
assert st_halo.exchange_rounds == K
assert st_halo.total_bytes == 2 * K * S * 1 * 4 \
    == halo_plan.info["halo_bytes_per_apply"]

# compressed exchange: the wire-byte models per dtype are
#   f32: 4h  |  bf16: 2h  |  int8: h + 4 (f32 scale bitcast-packed)
# per boundary row per direction.  Rounds must stay exactly K — the
# codec rides the SAME two ppermutes, compression never adds a round.
# (At h=1 the int8 row is 5 B > 4 B f32: the packed scale dominates —
# the ratio gates live in test_exchange_dtype.py at realistic h.)
for dt, row_bytes in (("bf16", 2), ("int8", 5)):
    for backend in ("halo", "pallas_halo"):
        p = op.plan(backend, mesh=mesh, exchange_dtype=dt)
        s = plan_comm_stats(p)["apply"]
        assert s.exchange_rounds == K, (backend, dt)
        assert s.total_bytes == 2 * K * S * 1 * row_bytes \
            == p.info["halo_bytes_per_apply"], (backend, dt, s.total_bytes)
        assert s.bytes_per_round == 2 * 1 * row_bytes, (backend, dt)

print("COMMSTATS OK")
"""


def test_commstats_closed_form_8shards():
    """Measured messages == 2K|E| (and 4K|E| gram) on a path graph where
    the closed form is known exactly, for every sharded backend."""
    out = run_payload(PAYLOAD, n_devices=8)
    assert "COMMSTATS OK" in out


# ---------------------------------------------------------------------------
# Multi-offset (GeneralPartition) round counting
# ---------------------------------------------------------------------------
def test_exchange_rounds_declared_divisor_wins():
    """A plan-declared exchange_collectives_per_round divides the raw
    ppermute tally — authoritative even when perms collide (at S=2 both
    ring directions share one perm; perm-grouping alone would report 2K)."""
    calls = (CollectiveCall("ppermute", count=20, elems=4, nbytes=16,
                            perm=((0, 1), (1, 0))),)
    assert CommStats(calls, n_shards=2,
                     ppermutes_per_round=2).exchange_rounds == 10
    assert CommStats(calls, n_shards=2,
                     ppermutes_per_round=1).exchange_rounds == 20


def test_exchange_rounds_groups_by_perm():
    """Without a declared divisor, rounds = the max per-perm tally: a
    4-offset general exchange issues 4 distinct ppermutes per matvec, so
    K matvecs measure K rounds, not 4K/2."""
    K = 9
    calls = tuple(
        CollectiveCall("ppermute", count=K, elems=4, nbytes=16,
                       perm=tuple((i, (i + d) % 8) for i in range(8)))
        for d in (1, 2, 6, 7))
    assert CommStats(calls, n_shards=8).exchange_rounds == K


def test_exchange_rounds_legacy_pair_fallback():
    """Hand-built stats with no perm info keep the historical pair
    assumption (pp // 2)."""
    calls = (CollectiveCall("ppermute", count=20, elems=4, nbytes=16),)
    assert CommStats(calls, n_shards=4).exchange_rounds == 10


def test_measured_perm_attached_to_calls():
    """measure() records each ppermute's perm so distinct exchange
    directions are distinct tally entries."""
    mesh = jax.make_mesh((1,), ("x",))

    def fn(v):
        def inner(vl):
            a = jax.lax.ppermute(vl, "x", perm=[(0, 0)])
            return a + jax.lax.ppermute(vl, "x", perm=[(0, 0)])
        return jax.shard_map(inner, mesh=mesh,
                             in_specs=jax.sharding.PartitionSpec("x"),
                             out_specs=jax.sharding.PartitionSpec("x"),
                             check_vma=False)(v)

    stats = measure(fn, jax.ShapeDtypeStruct((8,), np.float32), n_shards=1)
    pp = [c for c in stats.collectives if c.primitive == "ppermute"]
    assert len(pp) == 1 and pp[0].count == 2
    assert pp[0].perm == ((0, 0),)
