"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import chebyshev as cheb
from repro.core import filters, graph, lasso
from repro.core.multiplier import graph_multiplier
from repro.dist import gossip

SET = dict(max_examples=15, deadline=None)


def _graph(seed, n=40):
    key = jax.random.PRNGKey(seed)
    g = graph.sensor_graph(key, n=n, theta=0.3, kappa=0.45)
    return g


@settings(**SET)
@given(seed=st.integers(0, 50), tau=st.floats(0.1, 5.0),
       a=st.floats(-3, 3), b=st.floats(-3, 3))
def test_multiplier_linearity(seed, tau, a, b):
    """Phi~(a f + b h) == a Phi~ f + b Phi~ h (operator linearity)."""
    g = _graph(seed)
    lmax = g.lambda_max_bound()
    op = graph_multiplier(g.laplacian(), filters.tikhonov(tau), lmax, K=10)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed + 1))
    f = jax.random.normal(k1, (g.n_vertices,))
    h = jax.random.normal(k2, (g.n_vertices,))
    lhs = op.apply(a * f + b * h)
    rhs = a * op.apply(f) + b * op.apply(h)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               atol=1e-3, rtol=1e-3)


@settings(**SET)
@given(seed=st.integers(0, 50))
def test_permutation_equivariance(seed):
    """Relabeling vertices commutes with the operator: Phi(Pi W) = Pi Phi(W)."""
    g = _graph(seed)
    lmax = g.lambda_max_bound()
    rng = np.random.RandomState(seed)
    perm = rng.permutation(g.n_vertices)
    W2 = np.asarray(g.W)[np.ix_(perm, perm)]
    f = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (g.n_vertices,)))
    op1 = graph_multiplier(g.laplacian(), filters.heat(0.4), lmax, K=12)
    op2 = graph_multiplier(graph.laplacian(jnp.asarray(W2)),
                           filters.heat(0.4), lmax, K=12)
    out1 = np.asarray(op1.apply(jnp.asarray(f)))
    out2 = np.asarray(op2.apply(jnp.asarray(f[perm])))
    np.testing.assert_allclose(out1[perm], out2, atol=1e-3)


@settings(**SET)
@given(seed=st.integers(0, 50), t=st.floats(0.05, 2.0))
def test_heat_kernel_preserves_constants(seed, t):
    """g(0) = 1 for the heat kernel and constants are L's null space, so
    constant signals pass through (mass preservation)."""
    g = _graph(seed)
    lmax = g.lambda_max_bound()
    op = graph_multiplier(g.laplacian(), filters.heat(t), lmax, K=25)
    const = jnp.ones((g.n_vertices,)) * 3.7
    np.testing.assert_allclose(np.asarray(op.apply(const)),
                               np.asarray(const), atol=2e-2)


@settings(**SET)
@given(z=st.lists(st.floats(-10, 10), min_size=1, max_size=30),
       t=st.floats(0.0, 3.0))
def test_soft_threshold_nonexpansive(z, t):
    zz = jnp.asarray(z, jnp.float32)
    out = lasso.soft_threshold(zz, t)
    assert np.all(np.asarray(jnp.abs(out) <= jnp.abs(zz) + 1e-6))
    # 1-Lipschitz
    z2 = zz + 0.1
    out2 = lasso.soft_threshold(z2, t)
    assert np.all(np.asarray(jnp.abs(out2 - out) <= 0.1 + 1e-6))


@settings(**SET)
@given(k1=st.integers(1, 10), k2=st.integers(1, 10), seed=st.integers(0, 99))
def test_cheb_product_identity(k1, k2, seed):
    rng = np.random.RandomState(seed)
    c1 = rng.randn(k1 + 1)
    c2 = rng.randn(k2 + 1)
    prod = cheb.cheb_product_coeffs(c1, c2)
    lam = jnp.linspace(0, 3.0, 37)
    lhs = (np.asarray(cheb.cheb_eval(c1, lam, 3.0))
           * np.asarray(cheb.cheb_eval(c2, lam, 3.0)))
    rhs = np.asarray(cheb.cheb_eval(prod, lam, 3.0))
    np.testing.assert_allclose(lhs, rhs, atol=1e-6 * max(1, np.abs(lhs).max()))


@settings(**SET)
@given(n=st.sampled_from([2, 4, 6, 8, 12, 16]))
def test_gossip_consensus_filter_exact(n):
    c = gossip.consensus_coeffs(n)
    assert gossip.consensus_error(n, c) < 1e-6  # f32 eval floor
    assert len(c) == int(np.ceil(n / 2)) + 1


@settings(**SET)
@given(seed=st.integers(0, 30), K=st.integers(3, 25))
def test_bound_B_respected_on_spectrum(seed, K):
    """|g - p_K| on the actual eigenvalues is within B(K) (grid sup)."""
    g = _graph(seed)
    lmax = g.lambda_max_bound()
    gf = filters.tikhonov(1.0)
    c = cheb.cheb_coeffs(gf, K, lmax)
    B = cheb.approx_error_bound([gf], c[None], lmax)
    lam = np.linalg.eigvalsh(np.asarray(g.laplacian()))
    vals = np.asarray(cheb.cheb_eval(c, jnp.asarray(lam), lmax))
    assert np.max(np.abs(vals - gf(lam))) <= B + 1e-6
