"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import chebyshev as cheb
from repro.core import filters, graph, lasso, wavelets
from repro.core.multiplier import graph_multiplier
from repro.dist import GraphOperator, faults, gossip
from repro import serve

SET = dict(max_examples=15, deadline=None)


def _graph(seed, n=40):
    key = jax.random.PRNGKey(seed)
    g = graph.sensor_graph(key, n=n, theta=0.3, kappa=0.45)
    return g


@settings(**SET)
@given(seed=st.integers(0, 50), tau=st.floats(0.1, 5.0),
       a=st.floats(-3, 3), b=st.floats(-3, 3))
def test_multiplier_linearity(seed, tau, a, b):
    """Phi~(a f + b h) == a Phi~ f + b Phi~ h (operator linearity)."""
    g = _graph(seed)
    lmax = g.lambda_max_bound()
    op = graph_multiplier(g.laplacian(), filters.tikhonov(tau), lmax, K=10)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed + 1))
    f = jax.random.normal(k1, (g.n_vertices,))
    h = jax.random.normal(k2, (g.n_vertices,))
    lhs = op.apply(a * f + b * h)
    rhs = a * op.apply(f) + b * op.apply(h)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               atol=1e-3, rtol=1e-3)


@settings(**SET)
@given(seed=st.integers(0, 50))
def test_permutation_equivariance(seed):
    """Relabeling vertices commutes with the operator: Phi(Pi W) = Pi Phi(W)."""
    g = _graph(seed)
    lmax = g.lambda_max_bound()
    rng = np.random.RandomState(seed)
    perm = rng.permutation(g.n_vertices)
    W2 = np.asarray(g.W)[np.ix_(perm, perm)]
    f = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (g.n_vertices,)))
    op1 = graph_multiplier(g.laplacian(), filters.heat(0.4), lmax, K=12)
    op2 = graph_multiplier(graph.laplacian(jnp.asarray(W2)),
                           filters.heat(0.4), lmax, K=12)
    out1 = np.asarray(op1.apply(jnp.asarray(f)))
    out2 = np.asarray(op2.apply(jnp.asarray(f[perm])))
    np.testing.assert_allclose(out1[perm], out2, atol=1e-3)


@settings(**SET)
@given(seed=st.integers(0, 50), t=st.floats(0.05, 2.0))
def test_heat_kernel_preserves_constants(seed, t):
    """g(0) = 1 for the heat kernel and constants are L's null space, so
    constant signals pass through (mass preservation)."""
    g = _graph(seed)
    lmax = g.lambda_max_bound()
    op = graph_multiplier(g.laplacian(), filters.heat(t), lmax, K=25)
    const = jnp.ones((g.n_vertices,)) * 3.7
    np.testing.assert_allclose(np.asarray(op.apply(const)),
                               np.asarray(const), atol=2e-2)


@settings(**SET)
@given(z=st.lists(st.floats(-10, 10), min_size=1, max_size=30),
       t=st.floats(0.0, 3.0))
def test_soft_threshold_nonexpansive(z, t):
    zz = jnp.asarray(z, jnp.float32)
    out = lasso.soft_threshold(zz, t)
    assert np.all(np.asarray(jnp.abs(out) <= jnp.abs(zz) + 1e-6))
    # 1-Lipschitz
    z2 = zz + 0.1
    out2 = lasso.soft_threshold(z2, t)
    assert np.all(np.asarray(jnp.abs(out2 - out) <= 0.1 + 1e-6))


@settings(**SET)
@given(k1=st.integers(1, 10), k2=st.integers(1, 10), seed=st.integers(0, 99))
def test_cheb_product_identity(k1, k2, seed):
    rng = np.random.RandomState(seed)
    c1 = rng.randn(k1 + 1)
    c2 = rng.randn(k2 + 1)
    prod = cheb.cheb_product_coeffs(c1, c2)
    lam = jnp.linspace(0, 3.0, 37)
    lhs = (np.asarray(cheb.cheb_eval(c1, lam, 3.0))
           * np.asarray(cheb.cheb_eval(c2, lam, 3.0)))
    rhs = np.asarray(cheb.cheb_eval(prod, lam, 3.0))
    np.testing.assert_allclose(lhs, rhs, atol=1e-6 * max(1, np.abs(lhs).max()))


@settings(**SET)
@given(n=st.sampled_from([2, 4, 6, 8, 12, 16]))
def test_gossip_consensus_filter_exact(n):
    c = gossip.consensus_coeffs(n)
    assert gossip.consensus_error(n, c) < 1e-6  # f32 eval floor
    assert len(c) == int(np.ceil(n / 2)) + 1


# ---------------------------------------------------------------------------
# Serving: pad-to-bucket coalescing is a lossless, correctly-routed bijection
# ---------------------------------------------------------------------------
_SERVE_CACHE = {}


def _serve_fixture():
    """Module-lazy shared (graph, plan): one compile pool across examples
    (the engine's memoized callables make repeat draws cheap)."""
    if not _SERVE_CACHE:
        g, _ = graph.connected_sensor_graph(jax.random.PRNGKey(5), n=40,
                                            theta=0.3, kappa=0.45)
        lmax = g.lambda_max_bound()
        op = GraphOperator(
            P=g.laplacian(),
            multipliers=wavelets.sgwt_multipliers(lmax, J=2),
            lmax=lmax, K=5)
        _SERVE_CACHE["g"] = g
        _SERVE_CACHE["plan"] = op.plan("dense")
    return _SERVE_CACHE["g"], _SERVE_CACHE["plan"]


#: The heterogeneous request pool the randomized mixes draw from.
_REQUEST_SPECS = (
    dict(kind="apply"),
    dict(kind="apply_gram"),
    dict(kind="solve", method="jacobi", tau=0.3, n_iters=3),
    dict(kind="solve", method="jacobi", tau=0.7, n_iters=5),
    dict(kind="solve", method="chebyshev", tau=0.5, n_iters=4),
)


def _direct(plan, spec, signal):
    if spec["kind"] == "solve":
        kw = {k: v for k, v in spec.items() if k not in ("kind", "method")}
        return plan.solve(signal, spec["method"], **kw).x
    return getattr(plan, spec["kind"])(signal)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), n_rows=st.integers(1, 8),
       headroom=st.integers(0, 4))
def test_pack_unpack_lossless_roundtrip(seed, n_rows, headroom):
    """unpack(pack(rows, bucket)) returns the rows BITWISE — padding to a
    bucket moves values around, never through arithmetic."""
    rng = np.random.RandomState(seed)
    rows = [rng.standard_normal(7).astype(np.float32)
            for _ in range(n_rows)]
    bucket = n_rows + headroom
    batch, n_valid = serve.pack_batch(rows, bucket)
    assert batch.shape == (bucket, 7) and n_valid == n_rows
    back = serve.unpack_batch(batch, n_valid)
    for orig, row in zip(rows, back):
        assert np.array_equal(np.asarray(row), orig)
    # padded tail is exactly zero (linearity makes it discardable)
    assert not np.any(np.asarray(batch)[n_rows:])


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 500), order=st.permutations(list(range(8))))
def test_serving_random_mix_routes_every_response(seed, order):
    """Seeded random request mixes (kinds x method x K x tau x arrival
    order): every future resolves with ITS request's answer — coalescing
    and pad/unpack never cross-route rows — and scheduling stays
    exactly-once under any arrival permutation."""
    g, plan = _serve_fixture()
    rng = np.random.RandomState(seed)
    specs = [_REQUEST_SPECS[rng.randint(len(_REQUEST_SPECS))]
             for _ in range(len(order))]
    signals = [rng.standard_normal(g.n_vertices).astype(np.float32)
               for _ in range(len(order))]
    eng = serve.ServeEngine(plan, buckets=(1, 2, 8), max_wait=0.004,
                            clock=serve.VirtualClock(),
                            sync_results=False)
    futs = {}
    for i in order:                      # permuted arrival order
        eng.clock.advance(float(rng.uniform(0.0, 0.003)))
        eng.poll()
        futs[i] = eng.submit(signals[i], **specs[i])
    eng.run_until_idle()
    s = eng.metrics.summary()
    assert s["served_exactly_once"] and s["n_served"] == len(order)
    ids = {f.response.id for f in futs.values()}
    assert len(ids) == len(order)        # one distinct id per request
    for i, fut in futs.items():
        want = np.asarray(_direct(plan, specs[i], jnp.asarray(signals[i])))
        np.testing.assert_allclose(np.asarray(fut.result()), want,
                                   rtol=1e-5, atol=1e-5)
        # the response's key really describes the request it answered
        assert fut.response.key.kind == specs[i]["kind"]
        assert fut.response.key.method == specs[i].get("method")


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 200), n_req=st.integers(1, 12))
def test_serving_batch_partition_covers_requests(seed, n_req):
    """The dispatched batches partition the admitted requests: occupancy
    sums to n_req, every bucket is a configured one, padding accounts
    for the difference."""
    g, plan = _serve_fixture()
    rng = np.random.RandomState(seed)
    eng = serve.ServeEngine(plan, buckets=(1, 4), max_wait=0.002,
                            clock=serve.VirtualClock(),
                            sync_results=False)
    for i in range(n_req):
        eng.clock.advance(float(rng.uniform(0.0, 0.004)))
        eng.poll()
        eng.submit(rng.standard_normal(g.n_vertices).astype(np.float32))
    eng.run_until_idle()
    batches = eng.metrics.batches
    assert sum(b.occupancy for b in batches) == n_req
    assert all(b.bucket in (1, 4) for b in batches)
    assert all(0 <= b.padding < b.bucket for b in batches)
    assert eng.pending_count == 0


@settings(**SET)
@given(seed=st.integers(0, 30), K=st.integers(3, 25))
def test_bound_B_respected_on_spectrum(seed, K):
    """|g - p_K| on the actual eigenvalues is within B(K) (grid sup)."""
    g = _graph(seed)
    lmax = g.lambda_max_bound()
    gf = filters.tikhonov(1.0)
    c = cheb.cheb_coeffs(gf, K, lmax)
    B = cheb.approx_error_bound([gf], c[None], lmax)
    lam = np.linalg.eigvalsh(np.asarray(g.laplacian()))
    vals = np.asarray(cheb.cheb_eval(c, jnp.asarray(lam), lmax))
    assert np.max(np.abs(vals - gf(lam))) <= B + 1e-6


# ---------------------------------------------------------------------------
# Fault-injection invariants (repro.dist.faults)
# ---------------------------------------------------------------------------
@settings(**SET)
@given(drop=st.floats(0.0, 1.0), stale=st.floats(0.0, 1.0),
       noise=st.floats(0.0, 1.0), seed=st.integers(0, 2**31 - 1),
       degr=st.sampled_from(faults.DEGRADATIONS))
def test_fault_key_none_iff_inactive(drop, stale, noise, seed, degr):
    """fault_key collapses to "none" exactly when no channel can fire —
    the cache-sharing contract (a p=0 plan traces the clean program)."""
    spec = faults.FaultSpec(drop_prob=drop, stale_prob=stale,
                            noise_prob=noise, seed=seed)
    key = faults.fault_key(spec, degr)
    assert (key == "none") == (not spec.active)
    # the key is a pure function of the spec: same spec, same key
    assert key == faults.fault_key(
        dict(drop_prob=drop, stale_prob=stale, noise_prob=noise,
             seed=seed), degr)
    # and an injector exists exactly for active specs at exchanging sites
    inj = faults.make_injector(spec, degr, "graph", exchanging=True)
    assert (inj is None) == (not spec.active)


@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1), p=st.floats(0.01, 1.0),
       h=st.integers(1, 64))
def test_flip_low_bits_flips_at_most_one_low_bit(seed, p, h):
    """Wire bit-noise is bounded by construction: each uint8 lane differs
    from the original in at most ONE of its low 8 bits."""
    rng = np.random.RandomState(seed)
    lanes = jnp.asarray(rng.randint(0, 256, size=(3, h)), jnp.uint8)
    out = faults._flip_low_bits(lanes, jax.random.PRNGKey(seed), p)
    diff = np.asarray(jnp.bitwise_xor(lanes, out))
    assert np.isin(diff, [0] + [1 << b for b in range(8)]).all()
    # deterministic per key
    again = faults._flip_low_bits(lanes, jax.random.PRNGKey(seed), p)
    assert np.array_equal(np.asarray(out), np.asarray(again))


# ---------------------------------------------------------------------------
# Pluggable-partition invariants (repro.dist.partition)
# ---------------------------------------------------------------------------
def _random_sparse_laplacian(seed, n):
    """Erdos-Renyi-ish sparse symmetric Laplacian (connected not required —
    the partition contract must hold for any sparse P)."""
    rng = np.random.RandomState(seed)
    m = max(n, int(1.8 * n))
    rows = rng.randint(0, n, m)
    cols = rng.randint(0, n, m)
    keep = rows != cols
    rows, cols = rows[keep], cols[keep]
    W = np.zeros((n, n), np.float32)
    W[rows, cols] = rng.uniform(0.5, 1.5, rows.size).astype(np.float32)
    W = np.maximum(W, W.T)
    return np.asarray(graph.laplacian(jnp.asarray(W)), np.float32)


@settings(**SET)
@given(seed=st.integers(0, 200), n=st.integers(12, 96),
       shards=st.sampled_from([1, 2, 3, 4, 8]),
       method=st.sampled_from(["bfs", "spectral"]))
def test_partition_covers_every_edge_exactly_once(seed, n, shards, method):
    """Reassembling interior blocks + exchange plan reproduces P exactly:
    a dropped edge would show as a zero, a double-covered one as a doubled
    weight."""
    from repro.dist import partition as pm

    L = _random_sparse_laplacian(seed, n)
    parts = pm.partition_general(L, shards, method=method, block=(4, 4))
    np.testing.assert_allclose(pm.partition_to_dense(parts), L,
                               atol=1e-6)


@settings(**SET)
@given(seed=st.integers(0, 200), n=st.integers(12, 96),
       shards=st.sampled_from([2, 3, 4, 8]))
def test_partition_exchange_plan_symmetric_and_bijective(seed, n, shards):
    """The exchange plan's structural contract: offsets are closed under
    d <-> S-d (P is symmetric, so i sends to j iff j sends back), every
    per-round ppermute perm is a complete bijection of the mesh axis
    (JX-PPERMUTE-BIJECTION via the repo's own checker), and every
    declared send slot/coupling is consistent with its tile width."""
    from repro.analysis.checks import perm_problems
    from repro.dist import partition as pm

    L = _random_sparse_laplacian(seed, n)
    parts = pm.partition_general(L, shards, block=(4, 4))
    S = parts.n_shards
    offs = set(parts.offsets)
    assert offs == {(S - d) % S for d in offs}
    assert all(0 < d < S for d in offs)
    for k, d in enumerate(parts.offsets):
        perm = [(i, (i + d) % S) for i in range(S)]
        assert perm_problems(perm, S) == []
        # couplings only index real (unpadded) slots of the arriving tile
        cnt = np.asarray(parts.send_counts[k])
        snd = (np.arange(S) - d) % S  # who shard i receives from
        cols = np.asarray(parts.cpl_cols[k])
        vals = np.asarray(parts.cpl_vals[k])
        real = vals != 0
        assert np.all(cols[real] < cnt[snd][np.nonzero(real)[0]])


@settings(**SET)
@given(seed=st.integers(0, 100), shards=st.sampled_from([2, 4, 8]))
def test_partition_banded_reduces_to_ring_plan(seed, shards):
    """On a banded (path-like) graph under the identity order the general
    plan degenerates to BandedPartition's ring: offsets {1, S-1} only,
    and the same boundary bandwidth h on both."""
    from repro.dist import partition as pm
    from repro.dist.backends.halo import partition_banded

    n = shards * 8
    g = graph.path_graph(n)
    L = np.asarray(g.laplacian())
    parts = pm.partition_general(L, shards, order=np.arange(n),
                                 block=(4, 4))
    assert set(parts.offsets) <= {1, (shards - 1) % shards}
    banded, leak = partition_banded(L, shards)
    assert leak < 1e-8
    assert parts.halo == banded.halo
    np.testing.assert_allclose(pm.partition_to_dense(parts), L, atol=1e-6)
