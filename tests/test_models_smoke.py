"""Per-arch reduced-config smoke tests: forward/train shapes + finiteness,
decode-vs-forward equivalence (the serving-path correctness invariant)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.dist.sharding import ShardingRules
from repro.models import decode as dec
from repro.models import init_params
from repro.models.model import RunConfig, forward, lm_loss
from repro.models.steps import build_serve_step, build_train_step
from repro.optim.adamw import adamw_init

RULES = ShardingRules.null()
RUN = RunConfig(attn_impl="ref", moe_capacity_factor=8.0)


def _batch(cfg, key, B=2, S=16):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            key, (B, cfg.n_vision_tokens, cfg.d_model))
    if cfg.is_encoder_decoder:
        batch["encoder_frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)
    logits = forward(cfg, params, batch["tokens"], RULES, RUN,
                     vision_embeds=batch.get("vision_embeds"),
                     encoder_frames=batch.get("encoder_frames"))
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    loss0 = lm_loss(logits, batch["labels"])
    assert bool(jnp.isfinite(loss0))

    step = jax.jit(build_train_step(cfg, RULES, RUN, lr=1e-3))
    params2, opt2, m = step(params, adamw_init(params), batch)
    assert bool(jnp.isfinite(m["loss"])) and bool(jnp.isfinite(m["grad_norm"]))
    # a second step on the same batch must reduce loss (learnable signal)
    params3, opt3, m2 = step(params2, opt2, batch)
    assert float(m2["loss"]) < float(m["loss"])


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    B, S = 2, 12
    batch = _batch(cfg, key, B, S)
    full = forward(cfg, params, batch["tokens"], RULES, RUN,
                   vision_embeds=batch.get("vision_embeds"),
                   encoder_frames=batch.get("encoder_frames"))
    cache = dec.start_cache(cfg, params, B, S + 4, RULES, RUN,
                            encoder_frames=batch.get("encoder_frames"))
    last, cache = dec.prefill(cfg, params, batch["tokens"], cache, RULES, RUN,
                              vision_embeds=batch.get("vision_embeds"))
    err = float(jnp.abs(full[:, -1] - last).max())
    assert err < 1e-4, f"{arch}: decode/forward mismatch {err}"
    assert int(cache["idx"]) == S


@pytest.mark.parametrize("arch", ["hymba-1.5b", "rwkv6-1.6b"])
def test_subquadratic_decode_constant_state(arch):
    """long_500k eligibility: cache size must not grow with context."""
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    serve = jax.jit(build_serve_step(cfg, RULES, RUN))
    cache = dec.init_cache(cfg, 2, max_seq=1 << 20)
    leaves = jax.tree_util.tree_leaves(cache)
    total_bytes = sum(l.size * l.dtype.itemsize for l in leaves)
    # ring-buffer KV (window) + SSM state only: far below a 1M-token cache
    full_kv = (cfg.n_layers * 2 * 2 * cfg.n_kv_heads * (1 << 20) * cfg.hd)
    assert total_bytes < full_kv / 100
    tok = jnp.zeros((2, 1), jnp.int32)
    nxt, cache = serve(params, cache, tok)
    assert nxt.shape == (2,)


def test_generate_greedy_runs():
    cfg = get_config("qwen1.5-4b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(3))
    prompt = jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0,
                                cfg.vocab_size)
    out = dec.generate(cfg, params, prompt, 6, RULES, RUN)
    assert out.shape == (2, 6)
    assert bool((out >= 0).all()) and bool((out < cfg.vocab_size).all())


def test_f8_kv_cache_decode_close_to_forward():
    """f8 (e4m3) quantized KV cache: decode must track the bf16-forward
    logits within quantization tolerance (the §Perf decode lever)."""
    cfg = get_config("starcoder2-3b").reduced()
    key = jax.random.PRNGKey(7)
    params = init_params(cfg, key)
    B, S = 2, 12
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full = forward(cfg, params, toks, RULES, RUN)
    cache = dec.init_cache(cfg, B, S + 2, dtype=jnp.float8_e4m3fn)
    assert cache["k"].dtype == jnp.float8_e4m3fn
    last, cache = dec.prefill(cfg, params, toks, cache, RULES, RUN)
    ref = full[:, -1]
    # compare top-1 predictions and correlation rather than exact values
    assert bool((jnp.argmax(last, -1) == jnp.argmax(ref, -1)).all())
    c = jnp.corrcoef(last.ravel(), ref.ravel())[0, 1]
    assert float(c) > 0.98, float(c)
