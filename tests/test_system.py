"""End-to-end behaviour tests: the paper's headline experiments, reduced."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _subproc import run_payload
from repro.core import filters, graph, lasso, wavelets
from repro.core.multiplier import UnionMultiplier, graph_multiplier
from repro.data.pipeline import graph_signal_batch


def test_distributed_denoising_section4d():
    """Section IV-D: Tikhonov denoising of the smooth quadratic field.
    The paper reports MSE 0.250 (noisy) -> 0.013 (denoised) over 1000
    trials at N=500; a handful of trials must already show a large gap."""
    key = jax.random.PRNGKey(0)
    mses_noisy, mses_den = [], []
    for trial in range(3):
        g, key = graph.connected_sensor_graph(key, n=500)
        f0 = graph_signal_batch(key, g.coords, "smooth")
        key, sub = jax.random.split(key)
        y = f0 + 0.5 * jax.random.normal(sub, f0.shape)
        lmax = g.lambda_max_bound()
        R = graph_multiplier(g.laplacian(), filters.tikhonov(1.0, 1),
                             lmax, K=20)
        den = R.apply(y)
        mses_noisy.append(float(jnp.mean((y - f0) ** 2)))
        mses_den.append(float(jnp.mean((den - f0) ** 2)))
    assert np.mean(mses_noisy) > 0.2            # ~0.25 by construction
    assert np.mean(mses_den) < 0.05             # paper: 0.013
    assert np.mean(mses_den) < np.mean(mses_noisy) / 5


def test_wavelet_lasso_beats_tikhonov_on_piecewise():
    """Section VI: for piecewise-smooth signals the lasso beats Tikhonov
    (paper: 0.079 vs 0.098)."""
    key = jax.random.PRNGKey(42)
    diffs = []
    for _ in range(2):
        g, key = graph.connected_sensor_graph(key, n=500)
        f0 = graph_signal_batch(key, g.coords, "piecewise")
        key, sub = jax.random.split(key)
        y = f0 + 0.5 * jax.random.normal(sub, f0.shape)
        lmax = g.lambda_max_bound()
        tik = graph_multiplier(g.laplacian(), filters.tikhonov(1.0, 1),
                               lmax, K=15).apply(y)
        op = UnionMultiplier(P=g.laplacian(),
                             multipliers=wavelets.sgwt_multipliers(lmax, J=6),
                             lmax=lmax, K=15)
        mu = jnp.array([0.01] + [0.75] * 6)
        res = lasso.distributed_lasso(op, y, mu=mu, gamma=0.2, n_iters=150)
        mse_t = float(jnp.mean((tik - f0) ** 2))
        mse_l = float(jnp.mean((res.signal - f0) ** 2))
        mse_n = float(jnp.mean((y - f0) ** 2))
        assert mse_l < mse_n            # denoises
        diffs.append(mse_t - mse_l)
    assert np.mean(diffs) > 0           # lasso < tikhonov on average


def test_smoothing_reduces_dirichlet_energy():
    """Section III-B: the heat kernel lowers f^T L f monotonically in t."""
    key = jax.random.PRNGKey(5)
    g, key = graph.connected_sensor_graph(key, n=200, theta=0.12, kappa=0.13)
    L = g.laplacian()
    lmax = g.lambda_max_bound()
    y = jax.random.normal(key, (g.n_vertices,))
    energies = []
    for t in (0.0, 0.5, 1.0, 2.0):
        sm = graph_multiplier(L, filters.heat(t), lmax, K=30).apply(y)
        energies.append(float(sm @ (L @ sm)))
    assert all(e2 < e1 + 1e-5 for e1, e2 in zip(energies, energies[1:]))


@pytest.mark.slow
def test_dryrun_cell_compiles_on_production_mesh():
    """Deliverable (e) sanity: one full cell lower+compiles on the 16x16
    production mesh inside a 512-device subprocess."""
    out = run_payload(
        """
from repro.launch.dryrun import run_cell
rec = run_cell("rwkv6-1.6b", "long_500k")
assert rec["status"] == "ok", rec
assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
print("DRYRUN OK", rec["roofline"]["dominant"])
""",
        n_devices=512, timeout=1200,
    )
    assert "DRYRUN OK" in out
