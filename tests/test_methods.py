"""Section III propositions + Section V alternative methods."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import arma, filters, jacobi
from repro.core.multiplier import graph_multiplier


@pytest.fixture(scope="module")
def setup(sensor120):
    N = sensor120.n_vertices
    L = np.asarray(sensor120.laplacian())
    Ln = np.asarray(sensor120.laplacian("normalized"))
    y = np.asarray(jax.random.normal(jax.random.PRNGKey(5), (N,)))
    return sensor120, L, Ln, jnp.asarray(y)


def test_prop2_tikhonov_solves_regularization(setup):
    """R y == argmin (tau/2)||f-y||^2 + f^T L^r f solved directly."""
    g, L, _, y = setup
    N = L.shape[0]
    for tau, r in ((1.0, 1), (0.5, 2)):
        op = graph_multiplier(jnp.asarray(L), filters.tikhonov(tau, r),
                              g.lambda_max_bound(), K=60)
        direct = np.linalg.solve(
            np.linalg.matrix_power(L, r) + tau / 2 * np.eye(N),
            tau / 2 * np.asarray(y),
        )
        np.testing.assert_allclose(np.asarray(op.apply(y)), direct, atol=2e-3)


def test_prop3_inverse_filter(setup):
    """h(L) y minimizes (tau/2)||y - Psi f||^2 + f^T L^r f."""
    g, L, _, y = setup
    N = L.shape[0]
    tau, r = 1.0, 1
    lmax = g.lambda_max_bound()
    g_psi = filters.heat(0.3)
    op = graph_multiplier(jnp.asarray(L), filters.inverse_filter(g_psi, tau, r),
                          lmax, K=60)
    lam, U = np.linalg.eigh(L)
    Psi = U @ np.diag(g_psi(lam)) @ U.T
    direct = np.linalg.solve(
        np.linalg.matrix_power(L, r) + tau / 2 * Psi @ Psi,
        tau / 2 * Psi @ np.asarray(y),
    )
    np.testing.assert_allclose(np.asarray(op.apply(y)), direct, atol=2e-3)


def test_jacobi_converges_to_solution(setup):
    g, _, Ln, y = setup
    tau = 0.5
    N = Ln.shape[0]
    qmv, qdiag = jacobi.tikhonov_q(lambda x: jnp.asarray(Ln) @ x,
                                   jnp.diag(jnp.asarray(Ln)), tau)
    x = jacobi.jacobi_solve(qmv, qdiag, y, 300)
    direct = np.linalg.solve((tau * np.eye(N) + Ln) / tau, np.asarray(y))
    np.testing.assert_allclose(np.asarray(x), direct, atol=1e-4)


def test_jacobi_chebyshev_accelerates(setup):
    """Eq. (25) reaches lower error than plain Jacobi at equal iterations."""
    g, _, Ln, y = setup
    tau = 0.5
    N = Ln.shape[0]
    qmv, qdiag = jacobi.tikhonov_q(lambda x: jnp.asarray(Ln) @ x,
                                   jnp.diag(jnp.asarray(Ln)), tau)
    direct = np.linalg.solve((tau * np.eye(N) + Ln) / tau, np.asarray(y))
    # spectral radius of Q_D^{-1} Q_O
    Q = (tau * np.eye(N) + Ln) / tau
    QD = np.diag(np.diag(Q))
    rho = np.abs(np.linalg.eigvals(np.linalg.solve(QD, QD - Q))).max()
    iters = 15
    x_j = jacobi.jacobi_solve(qmv, qdiag, y, iters)
    x_c = jacobi.jacobi_chebyshev_solve(qmv, qdiag, y, float(rho) * 1.001, iters)
    e_j = np.linalg.norm(np.asarray(x_j) - direct)
    e_c = np.linalg.norm(np.asarray(x_c) - direct)
    assert e_c < e_j


def test_arma_first_order_fixed_point(setup):
    g, _, Ln, y = setup
    tau = 0.5
    N = Ln.shape[0]
    r, p, const = arma.arma_tikhonov_first_order(tau, 2.0)
    assert arma.arma_stable(p, 2.0)
    # matvec under the (..., N) contract: the pole stack rides leading dims
    mv = lambda v: jnp.einsum("ij,...j->...i", jnp.asarray(Ln), v)
    x = arma.arma_apply(mv, y, r, p, 2.0, n_iters=300, const=const)
    direct = np.linalg.solve((tau * np.eye(N) + Ln) / tau, np.asarray(y))
    np.testing.assert_allclose(np.asarray(x), direct, atol=1e-3)


def test_arma_second_order_matches_filter():
    """Complex-pole ARMA for g = tau/(tau + lambda^2) (Section V-E)."""
    lmax = 10.0
    tau = 0.5
    r, p, const = arma.arma_tikhonov_second_order(tau, lmax)
    assert arma.arma_stable(p, lmax)
    lam = np.linspace(0, lmax, 50)
    np.testing.assert_allclose(
        arma.arma_eval(r, p, lam, lmax, const=const), tau / (tau + lam**2),
        atol=1e-10,
    )


def test_arma_random_walk_matches_filter():
    tau = 0.5
    r, p, const = arma.arma_random_walk_3(tau, 2.0)
    lam = np.linspace(0, 1.9, 40)
    h = filters.random_walk_kernel(2.0, 3)
    np.testing.assert_allclose(
        arma.arma_eval(r, p, lam, 2.0, const=const), tau / (tau + h(lam)),
        atol=1e-9,
    )


def test_chebyshev_beats_alternatives_at_equal_communication(setup):
    """The paper's Fig. 2(a) qualitative claim: at equal message rounds,
    the Chebyshev approximation error is lowest for S = L_norm."""
    g, _, Ln, _ = setup
    N = Ln.shape[0]
    tau = 0.5
    key = jax.random.PRNGKey(7)
    f = jax.random.uniform(key, (N,), minval=-10, maxval=10)
    gfwd = filters.fig2_target(filters.power_kernel(1), tau)
    lam, U = np.linalg.eigh(Ln)
    y = jnp.asarray(U @ np.diag(gfwd(lam)) @ U.T @ np.asarray(f))
    K = 12
    op = graph_multiplier(jnp.asarray(Ln),
                          filters.ssl_multiplier(filters.power_kernel(1), tau),
                          2.0, K=K)
    e_cheb = float(jnp.linalg.norm(op.apply(y) - f))
    qmv, qdiag = jacobi.tikhonov_q(lambda x: jnp.asarray(Ln) @ x,
                                   jnp.diag(jnp.asarray(Ln)), tau)
    e_jac = float(jnp.linalg.norm(jacobi.jacobi_solve(qmv, qdiag, y, K) - f))
    r, p, const = arma.arma_tikhonov_first_order(tau, 2.0)
    x_arma = arma.arma_apply(
        lambda v: jnp.einsum("ij,...j->...i", jnp.asarray(Ln), v),
        y, r, p, 2.0, n_iters=K, const=const)
    e_arma = float(jnp.linalg.norm(x_arma - f))
    assert e_cheb < e_jac and e_cheb < e_arma
