"""Single-launch sweep kernels (`kernels/cheb_sweep.py`) + the
interior/boundary split: sweep == per-order on every backend, the VMEM
guard falls back (and says so), solvers ride the one-launch path, and the
split leaves measured messages at exactly 2K|E|."""
import dataclasses
import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _subproc import run_payload
from repro.core import filters, graph, jacobi, wavelets
from repro.core import chebyshev as cheb
from repro.dist import GraphOperator
from repro.kernels import ops, ref
from repro.kernels.cheb_sweep import cheb_sweep, jacobi_sweep

BACKENDS = ["dense", "pallas", "halo", "pallas_halo", "allgather"]


@pytest.fixture(scope="module")
def op120():
    """n=120 (not a 128 multiple) sensor graph + eta=3 SGWT union."""
    g, _ = graph.connected_sensor_graph(
        jax.random.PRNGKey(0), n=120, theta=0.2, kappa=0.25)
    lmax = g.lambda_max_bound()
    op = GraphOperator(P=g.laplacian(),
                       multipliers=wavelets.sgwt_multipliers(lmax, J=2),
                       lmax=lmax, K=12)
    return g, op


@pytest.fixture(scope="module")
def block_ell_500():
    """Multi-row-block, multi-slot Block-ELL structure (n=500)."""
    g, _ = graph.connected_sensor_graph(
        jax.random.PRNGKey(1), n=500, theta=0.075, kappa=0.075)
    A = graph.to_block_ell(np.asarray(g.laplacian()), (8, 128))
    return g, A


# ---------------------------------------------------------------------------
# Kernel vs reference vs per-order
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("batch_shape", [(), (5,), (64,), (2, 3)])
def test_cheb_sweep_kernel_matches_per_order(block_ell_500, batch_shape):
    """One `cheb_sweep` launch == K per-order SpMV+cheb_step launches ==
    the unrolled jnp oracle, across batch ranks (incl. B=64)."""
    g, A = block_ell_500
    lmax = g.lambda_max_bound()
    K, eta = 9, 3
    coeffs = jnp.asarray(
        np.random.RandomState(0).randn(eta, K + 1), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2),
                          batch_shape + (A.padded_n,))
    per_order = ops.fused_cheb_apply(A, x, coeffs, lmax, use_pallas=False,
                                     sweep=False)
    oracle = ref.cheb_sweep_ref(A.blocks, A.indices, x, coeffs,
                                alpha=lmax / 2)
    kern = cheb_sweep(A.blocks, A.indices, x, coeffs, alpha=lmax / 2,
                      interpret=True)
    assert kern.shape == batch_shape + (eta, A.padded_n)
    np.testing.assert_allclose(np.asarray(oracle), np.asarray(per_order),
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(per_order),
                               atol=2e-5)


def test_sweep_dispatch_auto_and_forced(block_ell_500):
    """`fused_cheb_apply` default routes to the sweep; sweep=False keeps
    the per-order path; both agree."""
    g, A = block_ell_500
    lmax = g.lambda_max_bound()
    coeffs = jnp.asarray(np.random.RandomState(1).randn(2, 8), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, A.padded_n))
    auto = ops.fused_cheb_apply(A, x, coeffs, lmax, use_pallas=False)
    step = ops.fused_cheb_apply(A, x, coeffs, lmax, use_pallas=False,
                                sweep=False)
    np.testing.assert_allclose(np.asarray(auto), np.asarray(step), atol=2e-5)


def test_vmem_guard_falls_back_and_logs(block_ell_500, caplog):
    """An over-budget sweep takes the per-order fallback — logged, same
    numbers."""
    g, A = block_ell_500
    lmax = g.lambda_max_bound()
    coeffs = jnp.asarray(np.random.RandomState(2).randn(2, 8), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (A.padded_n,))
    with caplog.at_level(logging.INFO, logger="repro.kernels.ops"):
        out = ops.fused_cheb_sweep(A, x, coeffs, lmax, use_pallas=True,
                                   vmem_budget=64)
    assert any("falling back to the per-order" in r.message
               for r in caplog.records)
    step = ops.fused_cheb_apply(A, x, coeffs, lmax, use_pallas=True,
                                sweep=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(step), atol=2e-5)
    # within budget: no fallback log
    caplog.clear()
    with caplog.at_level(logging.INFO, logger="repro.kernels.ops"):
        ops.fused_cheb_sweep(A, x, coeffs, lmax, use_pallas=True)
    assert not any("falling back" in r.message for r in caplog.records)


def test_vmem_footprint_model(block_ell_500):
    """The guard formula counts the (3 + eta) iterates + operand + the
    streamed structure."""
    g, A = block_ell_500
    n, eta, K, B = A.padded_n, 3, 10, 4
    got = ops.cheb_sweep_vmem_bytes(A, n, eta, K, B)
    iterates = (3 + eta) * B * n * 4 + B * n * 4
    structure = A.blocks.size * 4 + A.indices.size * 4 + (K + 1) * eta * 4
    assert got == iterates + structure


# ---------------------------------------------------------------------------
# Mixed-precision (bf16-scratch) sweep
# ---------------------------------------------------------------------------
def test_cheb_sweep_bf16_scratch_matches_ref(block_ell_500):
    """scratch_dtype='bf16': iterates/blocks/operand in bf16, f32 coef
    table + f32 accumulator — matches the f32 reference to bf16 tolerance
    and returns f32 output."""
    g, A = block_ell_500
    K, eta = 9, 3
    coeffs = jnp.asarray(
        np.random.RandomState(0).randn(eta, K + 1), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, A.padded_n))
    alpha = g.lambda_max_bound() / 2
    ref_out = ref.cheb_sweep_ref(A.blocks, A.indices, x, coeffs, alpha=alpha)
    got = cheb_sweep(A.blocks, A.indices, x, coeffs, alpha=alpha,
                     interpret=True, scratch_dtype="bf16")
    assert got.dtype == x.dtype
    scale = float(jnp.abs(ref_out).max())
    assert float(jnp.abs(got - ref_out).max()) / scale < 3e-2
    with pytest.raises(ValueError):
        cheb_sweep(A.blocks, A.indices, x, coeffs, alpha=alpha,
                   interpret=True, scratch_dtype="f16")


def test_jacobi_sweep_bf16_scratch_matches_ref(block_ell_500):
    g, A = block_ell_500
    L = np.asarray(g.laplacian())
    tau = 0.5
    den = (tau, 1.0)
    inv_d = ops.pad_trailing(
        jnp.asarray(tau / (tau + np.diag(L)), jnp.float32), A.padded_n)
    b = jax.random.normal(jax.random.PRNGKey(5), (4, A.padded_n))
    ws = jacobi.jacobi_weights(10)
    oracle = ref.jacobi_sweep_ref(A.blocks, A.indices, b, inv_d / tau,
                                  ws, jnp.zeros_like(b), den=den)
    kern = jacobi_sweep(A.blocks, A.indices, b, inv_d / tau, ws,
                        jnp.zeros_like(b), den=den, interpret=True,
                        scratch_dtype="bf16")
    scale = float(jnp.abs(oracle).max())
    assert float(jnp.abs(kern - oracle).max()) / scale < 3e-2


def test_vmem_footprint_model_bf16_and_measured_ratio(block_ell_500):
    """bf16 scratch halves the iterate/operand/structure terms (the f32
    coef table and int32 indices stay) — the model ratio is >= 1.8, and
    the TRACED pallas_call footprint (analysis.pallas_footprint, recovered
    from BlockSpecs + scratch avals) shrinks by >= 1.8x too, so the
    VMEM-guard ceiling genuinely roughly doubles."""
    from repro import analysis as A_
    g, A = block_ell_500
    n, eta, K, B = A.padded_n, 3, 10, 4
    got16 = ops.cheb_sweep_vmem_bytes(A, n, eta, K, B, scratch_dtype="bf16")
    iterates = 3 * B * n * 2 + eta * B * n * 4 + B * n * 2  # acc stays f32
    structure = A.blocks.size * 2 + A.indices.size * 4 + (K + 1) * eta * 4
    assert got16 == iterates + structure
    got32 = ops.cheb_sweep_vmem_bytes(A, n, eta, K, B)
    assert got32 / got16 >= 1.8
    # jacobi model too
    j32 = ops.jacobi_sweep_vmem_bytes(A, n, batch=B)
    j16 = ops.jacobi_sweep_vmem_bytes(A, n, batch=B, scratch_dtype="bf16")
    assert j32 / j16 >= 1.8

    coeffs = jnp.ones((eta, K + 1), jnp.float32)
    x = jax.ShapeDtypeStruct((B, n), np.float32)

    def traced_bytes(sdt):
        def fn(v):
            return cheb_sweep(A.blocks, A.indices, v, coeffs, alpha=2.0,
                              interpret=True, scratch_dtype=sdt)
        closed = jax.make_jaxpr(fn)(x)
        eqns = [e for e, _ in A_.collect_eqns(closed, {"pallas_call"})]
        assert len(eqns) == 1
        return A_.pallas_footprint(eqns[0])["total_bytes"]

    assert traced_bytes("f32") / traced_bytes("bf16") >= 1.8


def test_sweep_dtype_tag_survives_with_budget(op120):
    """`solvers._with_budget` re-tags without dropping the sweep_dtype tag,
    and the single-shard pallas_halo build stamps it on its matvec."""
    from repro.dist import solvers as dsolv
    g, op = op120
    plan = op.plan("pallas_halo", sweep_dtype="bf16")
    assert plan.info["sweep_dtype"] == "bf16"
    assert plan.info["sweep_vmem_bytes"] < op.plan(
        "pallas_halo").info["sweep_vmem_bytes"]

    # the single-device pallas backend takes the same knob
    pplan = op.plan("pallas", use_pallas=False, sweep_dtype="bf16")
    assert pplan.info["sweep_dtype"] == "bf16"
    tag = pplan.matvec_runner(
        lambda mv, v: v + (getattr(mv, "sweep_dtype", None) == "bf16"),
        (jnp.zeros(3),))
    assert float(tag[0]) == 1.0  # the solve path sees the bf16 tag
    assert pplan.info["sweep_vmem_bytes"] < op.plan(
        "pallas", use_pallas=False).info["sweep_vmem_bytes"]

    def mv(v):
        return v

    mv.block_ell = object()
    mv.vmem_budget = None
    mv.sweep_dtype = "bf16"
    wrapped = dsolv._with_budget(mv, 123456)
    assert wrapped.vmem_budget == 123456
    assert wrapped.sweep_dtype == "bf16"
    assert wrapped.block_ell is mv.block_ell


# ---------------------------------------------------------------------------
# Jacobi sweep
# ---------------------------------------------------------------------------
def test_jacobi_sweep_kernel_matches_per_round(block_ell_500):
    """One `jacobi_sweep` launch == the per-round jacobi_solve loop, plain
    and Chebyshev-accelerated."""
    g, A = block_ell_500
    L = np.asarray(g.laplacian())
    tau = 0.5
    den = (tau, 1.0)   # den(P) = tau I + P   (Tikhonov split)
    inv_d = ops.pad_trailing(
        jnp.asarray(tau / (tau + np.diag(L)), jnp.float32), A.padded_n)
    b = jax.random.normal(jax.random.PRNGKey(5), (4, A.padded_n))

    def mv(v):
        return ops.spmv(A, v, use_pallas=False)

    def a_mv(v):
        return (tau * v + mv(v))

    for method, ws in (("jacobi", jacobi.jacobi_weights(10)),
                       ("cheb_jacobi", jacobi.cheb_jacobi_weights(0.8, 10))):
        kern = jacobi_sweep(A.blocks, A.indices, b, inv_d / tau, ws,
                            jnp.zeros_like(b), den=den, interpret=True)
        oracle = ref.jacobi_sweep_ref(A.blocks, A.indices, b, inv_d / tau,
                                      ws, jnp.zeros_like(b), den=den)
        if method == "jacobi":
            loop = jacobi.jacobi_solve(a_mv, None, b, 10,
                                       inv_diag=inv_d / tau,
                                       use_pallas=False)
        else:
            loop = jacobi.jacobi_chebyshev_solve(a_mv, None, b, 0.8, 10,
                                                 inv_diag=inv_d / tau,
                                                 use_pallas=False)
        np.testing.assert_allclose(np.asarray(oracle), np.asarray(loop),
                                   atol=2e-5, err_msg=method)
        np.testing.assert_allclose(np.asarray(kern), np.asarray(loop),
                                   atol=2e-5, err_msg=method)


def test_solve_one_launch_matches_dense(op120):
    """plan.solve on the sweep-tagged backends == dense, for the methods
    the one-launch jacobi_sweep serves (and history still works)."""
    g, op = op120
    y = jax.random.normal(jax.random.PRNGKey(6), (g.n_vertices,))
    Y = jax.random.normal(jax.random.PRNGKey(7), (8, g.n_vertices))
    dense = op.plan("dense")
    mesh = jax.make_mesh((1,), ("graph",))
    for backend in ("pallas", "pallas_halo"):
        plan = (op.plan(backend) if backend == "pallas"
                else op.plan(backend, mesh=mesh))
        for method in ("jacobi", "cheb_jacobi"):
            r = plan.solve(y, method, tau=0.5, n_iters=12)
            r0 = dense.solve(y, method, tau=0.5, n_iters=12)
            np.testing.assert_allclose(np.asarray(r.x), np.asarray(r0.x),
                                       atol=5e-4, err_msg=(backend, method))
            rb = plan.solve(Y, method, tau=0.5, n_iters=12)
            r0b = dense.solve(Y, method, tau=0.5, n_iters=12)
            np.testing.assert_allclose(np.asarray(rb.x), np.asarray(r0b.x),
                                       atol=5e-4, err_msg=(backend, method))
            rh = plan.solve(y, method, tau=0.5, n_iters=12, history=True)
            assert rh.history is not None and rh.history.shape[0] == 12
            np.testing.assert_allclose(np.asarray(rh.x), np.asarray(r0.x),
                                       atol=5e-4, err_msg=(backend, method))


# ---------------------------------------------------------------------------
# Backend equivalence with the sweep engaged
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_apply_matches_dense_with_sweep(op120, backend):
    """All five backends agree on B=64 batched apply with the sweep
    dispatch live (n=120: every kernel path exercises its padding)."""
    g, op = op120
    dense = op.plan("dense")
    if backend in ("halo", "pallas_halo", "allgather"):
        plan = op.plan(backend, mesh=jax.make_mesh((1,), ("graph",)))
    else:
        plan = op.plan(backend)
    F = jax.random.normal(jax.random.PRNGKey(8), (64, g.n_vertices))
    np.testing.assert_allclose(np.asarray(plan.apply(F)),
                               np.asarray(dense.apply(F)), atol=1e-4)


def test_pallas_plan_sweep_off_matches(op120):
    """plan("pallas", sweep=False) keeps the per-order path and agrees."""
    g, op = op120
    f = jax.random.normal(jax.random.PRNGKey(9), (g.n_vertices,))
    on = op.plan("pallas").apply(f)
    off = op.plan("pallas", sweep=False).apply(f)
    np.testing.assert_allclose(np.asarray(on), np.asarray(off), atol=1e-4)


# ---------------------------------------------------------------------------
# ExecutionPlan compiled-callable memoization
# ---------------------------------------------------------------------------
def test_compiled_apply_skips_retrace(op120):
    """plan.compiled("apply") returns one jit wrapper: repeated same-shape
    calls trace once; a new shape traces once more."""
    g, op = op120
    plan = op.plan("dense")
    f = jax.random.normal(jax.random.PRNGKey(10), (g.n_vertices,))
    traces = []
    orig = plan.apply

    def counting_apply(x):
        traces.append(1)          # runs at trace time only
        return orig(x)

    plan2 = dataclasses.replace(plan, apply=counting_apply)
    compiled = plan2.compiled("apply")
    assert plan2.compiled("apply") is compiled
    compiled(f)
    compiled(f)
    compiled(f)
    assert len(traces) == 1
    compiled(jnp.stack([f, f]))   # new shape -> exactly one more trace
    assert len(traces) == 2
    with pytest.raises(KeyError, match="unknown kind"):
        plan2.compiled("nope")


def test_compiled_solve_memoizes(op120):
    """compiled_solve returns the same jitted solver per (method, kwargs)
    and matches plan.solve."""
    g, op = op120
    plan = op.plan("dense")
    y = jax.random.normal(jax.random.PRNGKey(11), (g.n_vertices,))
    s1 = plan.compiled_solve("jacobi", tau=0.5, n_iters=10)
    s2 = plan.compiled_solve("jacobi", tau=0.5, n_iters=10)
    assert s1 is s2
    s3 = plan.compiled_solve("jacobi", tau=0.7, n_iters=10)
    assert s3 is not s1
    np.testing.assert_allclose(
        np.asarray(s1(y)),
        np.asarray(plan.solve(y, "jacobi", tau=0.5, n_iters=10).x),
        atol=1e-5)


# ---------------------------------------------------------------------------
# Sharded: interior/boundary split keeps the 2K|E| accounting exact
# ---------------------------------------------------------------------------
PAYLOAD = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import graph, wavelets
from repro.dist import GraphOperator, plan_comm_stats, verify_message_scaling

key = jax.random.PRNGKey(1)
g, key = graph.connected_sensor_graph(key, n=600, theta=0.07, kappa=0.07)
gs, _ = graph.spatial_sort(g)
lmax = gs.lambda_max_bound()
K = 15
op = GraphOperator(P=gs.laplacian(),
                   multipliers=wavelets.sgwt_multipliers(lmax, J=3),
                   lmax=lmax, K=K)
mesh = jax.make_mesh((8,), ("graph",),
                     axis_types=(jax.sharding.AxisType.Auto,))
f = jax.random.normal(key, (g.n_vertices,))
F = jax.random.normal(jax.random.PRNGKey(3), (64, g.n_vertices))
dense = op.plan("dense")
for backend in ("halo", "pallas_halo"):
    plan = op.plan(backend, mesh=mesh)
    # numbers unchanged by the split, single and B=64 batched
    assert float(jnp.abs(plan.apply(f) - dense.apply(f)).max()) < 1e-4
    assert float(jnp.abs(plan.apply(F) - dense.apply(F)).max()) < 1e-4
    # paper-level: measured messages == 2K|E| exactly, batch-invariant
    v = verify_message_scaling(plan, g.n_edges, batch=64)
    assert v["max_rel_dev"] == 0.0, (backend, v["rel_dev"])
    assert v["measured"]["apply"] == 2 * K * g.n_edges, backend
    # device-level: the wire carries ONLY the h-row boundary tile per
    # direction per round (the split's payload claim), every round
    h = plan.info["halo_width"]
    st = plan_comm_stats(plan)["apply"]
    assert st.exchange_rounds == K, backend
    assert st.bytes_per_shard == 2 * K * h * 4, backend
    assert st.bytes_per_round == 2 * h * 4, backend
    assert st.bytes_per_shard * 8 == plan.info["halo_bytes_per_apply"], backend
    # batched payload grows with B, round count does not
    stB = plan_comm_stats(plan, batch=64)["apply"]
    assert stB.exchange_rounds == K, backend
    assert stB.bytes_per_shard == 64 * st.bytes_per_shard, backend
    # solver rounds through the same split matvec: deg(den)=1 Tikhonov
    # Jacobi costs exactly n_iters exchange rounds + deg(num)=0 for b
    from repro.dist.commstats import solve_comm_stats
    sj = solve_comm_stats(plan, "jacobi", tau=0.5, n_iters=10)
    assert sj.exchange_rounds == 10, backend
    print(backend, "OK")
print("SWEEP SPLIT OK")
"""


def test_interior_boundary_split_8shards():
    """8 genuinely sharded devices: the interior/boundary split leaves the
    measured message count at exactly 2K|E| (batch-invariant) while the
    per-round payload is the 2h boundary tile."""
    out = run_payload(PAYLOAD, n_devices=8)
    assert "SWEEP SPLIT OK" in out
